#!/usr/bin/env python3
"""Validate a `grcim explore` campaign output / checkpoint (JSONL) against
the stable layout `rust/src/explore/checkpoint.rs::header_json` +
`ExplorePoint::to_json` emit:

    line 1:  {"engine": str, "format": "grcim-pareto-ckpt",
              "plan": {...}, "plan_hash": 16 lowercase hex,
              "points": int > 0, "version": 1}
    line 2+: one point record per line — index/nr/nc/n_e/n_m/adc_scale,
             enob_mean, sqnr_db, the component breakdown (adc_fj, dac_fj,
             cells_fj, exp_logic_fj, tree_fj, norm_mult_fj, reduction_fj,
             global_norm_fj, softmax_fj), total_fj, fj_per_mac,
             digital_fj_per_mac, digital_ratio, crossover_enob (number or
             null), workload/shape/arch/adc strings, and (final outputs
             only) a boolean "frontier" flag.

Checks, in order:

  * header sanity (format tag, version, hex plan_hash, point count);
  * every point line parses, indices are exactly 0..points-1 ascending;
  * each breakdown sums to total_fj within 1e-9 relative (the explore
    acceptance invariant), summed in the Rust fold order;
  * the "frontier" flags match a recomputed Pareto filter over
    (fj_per_mac minimized, sqnr_db maximized) and at least one point is
    non-dominated.

`--identical A B` instead compares two campaign outputs byte-for-byte —
CI's kill/resume smoke gates on it: a checkpoint truncated mid-campaign
and resumed must reproduce the uninterrupted output exactly. On
mismatch the first differing line is reported.

`--selftest` runs the built-in negative tests (a broken breakdown, a
wrong frontier flag, and a diverged resume must all fail) and exits; CI
runs it so the gate itself is tested on every push.

Usage: python3 tools/check_pareto.py <pareto.jsonl>
       python3 tools/check_pareto.py --identical <full.jsonl> <resumed.jsonl>
       python3 tools/check_pareto.py --selftest
"""

import json
import sys

FORMAT_TAG = "grcim-pareto-ckpt"
VERSION = 1
BREAKDOWN = (
    "adc_fj", "dac_fj", "cells_fj", "exp_logic_fj", "tree_fj",
    "norm_mult_fj", "reduction_fj", "global_norm_fj", "softmax_fj",
)
NUM_FIELDS = BREAKDOWN + (
    "index", "nr", "nc", "n_e", "n_m", "adc_scale", "enob_mean", "sqnr_db",
    "total_fj", "fj_per_mac", "digital_fj_per_mac", "digital_ratio",
)
STR_FIELDS = ("workload", "shape", "arch", "adc")


class CheckFailed(Exception):
    pass


def fail(msg):
    raise CheckFailed(f"check_pareto: FAIL: {msg}")


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_header(header, where):
    if not isinstance(header, dict):
        fail(f"{where}: header must be an object")
    if header.get("format") != FORMAT_TAG:
        fail(f"{where}: format tag {header.get('format')!r} is not {FORMAT_TAG!r}")
    if header.get("version") != VERSION:
        fail(f"{where}: unsupported version {header.get('version')!r}")
    if not isinstance(header.get("plan"), dict):
        fail(f"{where}: header 'plan' must be an object")
    h = header.get("plan_hash")
    if not (isinstance(h, str) and len(h) == 16
            and all(c in "0123456789abcdef" for c in h)):
        fail(f"{where}: plan_hash {h!r} is not 16 lowercase hex digits")
    n = header.get("points")
    if not is_num(n) or n != int(n) or n < 1:
        fail(f"{where}: 'points' must be a positive integer, got {n!r}")
    if not isinstance(header.get("engine"), str) or not header["engine"]:
        fail(f"{where}: 'engine' must be a non-empty string")
    return int(n)


def check_point(p, where, want_frontier):
    if not isinstance(p, dict):
        fail(f"{where}: must be an object")
    for k in NUM_FIELDS:
        if not is_num(p.get(k, "missing")):
            fail(f"{where}: '{k}' must be a number, got {p.get(k, 'missing')!r}")
    for k in STR_FIELDS:
        if not isinstance(p.get(k), str) or not p[k]:
            fail(f"{where}: '{k}' must be a non-empty string")
    x = p.get("crossover_enob", "missing")
    if x != "missing" and x is not None and not is_num(x):
        fail(f"{where}: 'crossover_enob' must be a number or null, got {x!r}")
    if want_frontier and not isinstance(p.get("frontier"), bool):
        fail(f"{where}: 'frontier' must be a boolean, got {p.get('frontier')!r}")
    # the explore acceptance invariant — sum in the Rust fold order so
    # the comparison is exact, not merely close
    total = p["total_fj"]
    s = 0.0
    for k in BREAKDOWN:
        s += p[k]
    rel = abs(s - total) / max(total, 1e-300)
    if not rel < 1e-9:
        fail(f"{where}: breakdown sum {s!r} vs total_fj {total!r} (rel {rel:.3e})")


def dominates(a, b):
    """Mirror of explore::frontier::Objectives::dominates over
    (fj_per_mac minimized, sqnr_db maximized)."""
    ae, aq = a["fj_per_mac"], a["sqnr_db"]
    be, bq = b["fj_per_mac"], b["sqnr_db"]
    return (ae <= be and aq >= bq) and (ae < be or aq > bq)


def check_frontier(points, where):
    mask = [
        not any(dominates(q, p) for q in points if q is not p)
        for p in points
    ]
    if not any(mask):
        fail(f"{where}: recomputed frontier is empty")
    for p, keep in zip(points, mask):
        if p["frontier"] is not keep:
            fail(
                f"{where}: point {p['index']} has frontier={p['frontier']} "
                f"but the recomputed filter says {keep}"
            )
    return sum(mask)


def check(path, lines=None):
    if lines is None:
        try:
            with open(path) as f:
                lines = f.read().splitlines()
        except OSError as e:
            fail(f"{path}: {e}")
    if not lines:
        fail(f"{path}: empty file (no header)")
    try:
        header = json.loads(lines[0])
    except ValueError as e:
        fail(f"{path}: header is not JSON: {e}")
    total = check_header(header, f"{path}: header")
    points = []
    for ln, line in enumerate(lines[1:], start=2):
        if not line.strip():
            continue
        try:
            p = json.loads(line)
        except ValueError as e:
            fail(f"{path}:{ln}: not JSON: {e}")
        check_point(p, f"{path}:{ln}", want_frontier=True)
        points.append(p)
    if len(points) != total:
        fail(f"{path}: header says {total} points, found {len(points)}")
    indices = [int(p["index"]) for p in points]
    if indices != list(range(total)):
        fail(f"{path}: point indices {indices} are not 0..{total - 1} ascending")
    n_front = check_frontier(points, path)
    print(
        f"check_pareto: OK: {path} ({total} points, {n_front} on the "
        f"frontier, breakdowns reconcile)"
    )


def identical(path_a, path_b):
    """The kill/resume gate: two campaign outputs must match bit-exactly."""
    docs = []
    for path in (path_a, path_b):
        try:
            with open(path, "rb") as f:
                docs.append(f.read())
        except OSError as e:
            fail(f"{path}: {e}")
    a, b = docs
    if a != b:
        for ln, (la, lb) in enumerate(zip(a.splitlines(), b.splitlines()), start=1):
            if la != lb:
                fail(
                    f"{path_a} vs {path_b}: first divergence at line {ln}:\n"
                    f"  a: {la[:120]!r}\n  b: {lb[:120]!r}"
                )
        fail(
            f"{path_a} vs {path_b}: one is a strict prefix of the other "
            f"({len(a)} vs {len(b)} bytes)"
        )
    # a resumed run that diverged AND happens to match would still be a
    # valid output, so sanity-check the shared bytes too
    check(path_a, lines=a.decode().splitlines())
    print(f"check_pareto: OK: {path_a} == {path_b} ({len(a)} bytes)")


def _mk_doc():
    header = {
        "engine": "rust", "format": FORMAT_TAG,
        "plan": {"name": "selftest"}, "plan_hash": "0123456789abcdef",
        "points": 2, "version": 1,
    }
    def point(i, fj, sqnr, frontier):
        p = {k: 0.0 for k in NUM_FIELDS}
        p.update(index=i, nr=8, nc=8, n_e=2, n_m=2, adc_scale=1.0,
                 enob_mean=6.0, sqnr_db=sqnr, adc_fj=3.0 * fj,
                 dac_fj=1.0 * fj, total_fj=4.0 * fj,
                 fj_per_mac=fj, digital_fj_per_mac=2.0 * fj,
                 digital_ratio=0.5, crossover_enob=None,
                 workload="gemm:2x8x4", shape="2x8x4",
                 arch="gr-unit", adc="spec", frontier=frontier)
        return p
    # point 1 dominates point 0 (cheaper AND higher quality)
    return header, point(0, 2.0, 10.0, False), point(1, 1.0, 20.0, True)


def _lines(*docs):
    return [json.dumps(d, sort_keys=True) for d in docs]


def selftest():
    """Negative tests: a broken breakdown, a wrong frontier flag, and a
    diverged resume must all fail; the healthy document must pass."""
    header, p0, p1 = _mk_doc()
    check("healthy", lines=_lines(header, p0, p1))
    # a component drifting away from the total must trip the invariant
    bad = dict(p0, adc_fj=p0["adc_fj"] * (1.0 + 1e-6))
    try:
        check("drifted", lines=_lines(header, bad, p1))
    except CheckFailed as e:
        assert "breakdown sum" in str(e), e
    else:
        raise AssertionError("broken breakdown passed the check")
    # a dominated point flagged as frontier must fail
    lying = dict(p0, frontier=True)
    try:
        check("lying", lines=_lines(header, lying, p1))
    except CheckFailed as e:
        assert "recomputed filter" in str(e), e
    else:
        raise AssertionError("wrong frontier flag passed the check")
    # point-count / index drift must fail
    try:
        check("short", lines=_lines(header, p1))
    except CheckFailed as e:
        assert "header says" in str(e) or "indices" in str(e), e
    else:
        raise AssertionError("missing point passed the check")
    # the identical gate must trip on a single flipped byte
    import os
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        a, b = os.path.join(d, "a.jsonl"), os.path.join(d, "b.jsonl")
        text = "\n".join(_lines(header, p0, p1)) + "\n"
        with open(a, "w") as f:
            f.write(text)
        with open(b, "w") as f:
            f.write(text.replace('"sqnr_db": 10.0', '"sqnr_db": 10.1'))
        identical(a, a)
        try:
            identical(a, b)
        except CheckFailed as e:
            assert "divergence" in str(e), e
        else:
            raise AssertionError("diverged outputs passed the identical gate")
    print("check_pareto: selftest OK")


def main():
    args = sys.argv[1:]
    if args == ["--selftest"]:
        selftest()
    elif len(args) == 3 and args[0] == "--identical":
        identical(args[1], args[2])
    elif len(args) == 1 and not args[0].startswith("-"):
        check(args[0])
    else:
        fail(
            "usage: check_pareto.py <pareto.jsonl> | "
            "check_pareto.py --identical <a.jsonl> <b.jsonl> | "
            "check_pareto.py --selftest"
        )


if __name__ == "__main__":
    try:
        main()
    except CheckFailed as e:
        print(str(e), file=sys.stderr)
        sys.exit(1)
