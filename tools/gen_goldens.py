#!/usr/bin/env python3
"""Generate the golden snapshots under rust/tests/golden/.

This is an *independent twin* of the Rust pipeline (rust/src/{rng,formats,
distributions,mac,stats,spec,analog,figures/fig9}): it re-implements the
seeded deterministic paths in exact IEEE-754 f64 (Python floats are
doubles; all integer RNG state is emulated with masked big ints), so the
snapshots it writes cross-check the Rust implementation against a second
implementation rather than against its own history.

Exactness notes:
  * The FP quantizer chain (decompose/quantize/quantize_parts) uses only
    sign/abs/floor, exact power-of-two scaling (math.ldexp), and the f64
    exponent field (math.frexp) — bit-exact on every platform.
  * Uniform / max-entropy sampling is bit-exact (integer RNG + exact
    scaling). Gaussian sampling goes through libm log(); the golden
    tolerances (1e-6 relative) absorb cross-libm 1-ulp differences.
  * f32 input rounding uses struct pack/unpack (round-to-nearest-even,
    identical to Rust `as f32`).

Run from the repo root:  python3 tools/gen_goldens.py
"""

import json
import math
import os
import struct

M64 = (1 << 64) - 1
M128 = (1 << 128) - 1

# ----------------------------------------------------------------- rng --


def rotl64(x, k):
    k %= 64
    if k == 0:
        return x & M64
    return ((x << k) | (x >> (64 - k))) & M64


def rotr64(x, k):
    k %= 64
    if k == 0:
        return x & M64
    return ((x >> k) | (x << (64 - k))) & M64


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & M64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return z ^ (z >> 31)


def job_seed(campaign_seed, grid_index, batch_index):
    sm = SplitMix64(campaign_seed ^ rotl64(grid_index, 21) ^ rotl64(batch_index, 42))
    sm.next_u64()
    return sm.next_u64()


PCG_MULT = 0x2360ED051FC65DA44385DF649FCCF645


class Pcg64:
    """PCG XSL-RR 128/64, seeded exactly like rust/src/rng/mod.rs."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        state = (sm.next_u64() << 64) | sm.next_u64()
        inc = (sm.next_u64() << 64) | sm.next_u64()
        self.state = 0
        self.inc = ((inc << 1) | 1) & M128
        self.next_u64()
        self.state = (self.state + state) & M128
        self.next_u64()

    def next_u64(self):
        self.state = (self.state * PCG_MULT + self.inc) & M128
        rot = self.state >> 122
        xored = ((self.state >> 64) ^ self.state) & M64
        return rotr64(xored, rot)

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / float(1 << 53))

    def uniform_in(self, lo, hi):
        return lo + (hi - lo) * self.uniform()

    def below(self, n):
        assert n > 0
        zone = M64 - (M64 % n)
        while True:
            v = self.next_u64()
            if v < zone:
                return v % n

    def normal(self):
        while True:
            u = 2.0 * self.uniform() - 1.0
            v = 2.0 * self.uniform() - 1.0
            s = u * u + v * v
            if 0.0 < s < 1.0:
                return u * math.sqrt((-2.0 * math.log(s)) / s)

    def sign(self):
        return 1.0 if (self.next_u64() & 1) == 0 else -1.0


# ------------------------------------------------------------- formats --


def exp2i(t):
    """Rust formats::exp2 for the integer arguments the golden paths use."""
    ti = math.floor(t)
    fr = t - ti
    assert fr == 0.0, "golden paths only use integer exponents"
    if -1022.0 <= ti <= 1023.0:
        return math.ldexp(1.0, int(ti))
    return math.ldexp(1.0, int(ti))  # out-of-range never hit here


class FpFormat:
    def __init__(self, e_max, n_m):
        self.e_max = float(e_max)
        self.n_m = float(n_m)

    @staticmethod
    def fp(n_e, n_m):
        assert n_e >= 1
        return FpFormat(float(1 << n_e) - 1.0, float(n_m))

    @staticmethod
    def int_(n_bits):
        assert n_bits >= 2
        return FpFormat(1.0, float(n_bits) - 2.0)

    @staticmethod
    def fp4_e2m1():
        return FpFormat.fp(2, 1)

    def step(self):
        return exp2i(-(self.n_m + 1.0))

    def vmax(self):
        return 1.0 - self.step()

    def decompose(self, a):
        safe = max(a, 1e-300)
        # floor(log2(safe)) == unbiased f64 exponent field (safe is normal)
        _, e2 = math.frexp(safe)
        floor_log2 = float(e2 - 1)
        e = floor_log2 + 1.0 + self.e_max
        e = min(max(e, 1.0), self.e_max)
        m = a * exp2i(self.e_max - e)
        return m, e

    def quantize(self, x):
        step = self.step()
        s = -1.0 if x < 0.0 else 1.0
        a = abs(x)
        m, e = self.decompose(a)
        m_q = math.floor(m / step + 0.5) * step
        a_q = min(m_q * exp2i(e - self.e_max), self.vmax())
        if a_q == 0.0:
            return 0.0
        return s * a_q

    def ulp(self, a_q):
        _, e = self.decompose(a_q)
        return self.step() * exp2i(e - self.e_max)

    def quantize_parts(self, x):
        step = self.step()
        s = -1.0 if x < 0.0 else 1.0
        a = abs(x)
        m, e = self.decompose(a)
        m_q = math.floor(m / step + 0.5) * step
        a_q = min(m_q * exp2i(e - self.e_max), self.vmax())
        assert self.e_max == math.floor(self.e_max)  # integral formats only
        if a_q >= self.vmax():
            a_f, m_f, e_f = self.vmax(), self.vmax(), self.e_max
        elif m_q >= 1.0:
            a_f, m_f, e_f = a_q, 0.5, e + 1.0
        else:
            a_f, m_f, e_f = a_q, m_q, e
        if a_f == 0.0:
            return 0.0, 0.0, 1.0
        return s * a_f, s * m_f, e_f


class MaxEntropy:
    def __init__(self, fmt):
        self.fmt = fmt
        self.e_codes = int(fmt.e_max) + 1
        self.m_codes = 1 << int(fmt.n_m)

    def decode(self, sign, e_stored, m_stored):
        step = self.fmt.step()
        if e_stored == 0:
            m = float(m_stored) * step
        else:
            m = 0.5 + float(m_stored) * step
        e_eff = float(max(e_stored, 1))
        return sign * m * exp2i(e_eff - self.fmt.e_max)

    def sample(self, rng):
        sign = rng.sign()
        e = rng.below(self.e_codes)
        m = rng.below(self.m_codes)
        return self.decode(sign, e, m)

    def sample_q(self, u):
        """Twin of formats::MaxEntropy::sample_q — sign from the half,
        code rank from the folded magnitude quantile."""
        codes = self.e_codes * self.m_codes
        if u >= 0.5:
            sign, t = 1.0, 2.0 * u - 1.0
        else:
            sign, t = -1.0, 1.0 - 2.0 * u
        r = min(int(t * float(codes)), codes - 1)
        return self.decode(sign, r // self.m_codes, r % self.m_codes)


# -------------------------------------------------------- distributions --

GO_EPS = 0.01
GO_K = 50.0


def go_core_sigma():
    return 1.0 / (3.0 * GO_K)


PROBIT_A = (
    -3.969683028665376e+01,
    2.209460984245205e+02,
    -2.759285104469687e+02,
    1.383577518672690e+02,
    -3.066479806614716e+01,
    2.506628277459239e+00,
)
PROBIT_B = (
    -5.447609879822406e+01,
    1.615858368580409e+02,
    -1.556989798598866e+02,
    6.680131188771972e+01,
    -1.328068155288572e+01,
)
PROBIT_C = (
    -7.784894002430293e-03,
    -3.223964580411365e-01,
    -2.400758277161838e+00,
    -2.549732539343734e+00,
    4.374664141464968e+00,
    2.938163982698783e+00,
)
PROBIT_D = (
    7.784695709041462e-03,
    3.224671290700398e-01,
    2.445134137142996e+00,
    3.754408661907416e+00,
)
PROBIT_P_LOW = 0.02425


def probit(p):
    """Twin of distributions::probit (Acklam) — identical coefficients,
    branch structure, and operation order."""
    A, B, C, D = PROBIT_A, PROBIT_B, PROBIT_C, PROBIT_D
    if p <= 0.0:
        return float("-inf")
    if p >= 1.0:
        return float("inf")
    if p < PROBIT_P_LOW:
        q = math.sqrt(-2.0 * math.log(p))
        return ((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4])
                 * q + C[5])
                / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    if p <= 1.0 - PROBIT_P_LOW:
        q = p - 0.5
        r = q * q
        return (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4])
                * r + A[5]) * q / (
            ((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
            + 1.0)
    q = math.sqrt(-2.0 * math.log(1.0 - p))
    return (-(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4])
              * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))


class Dist:
    UNIFORM = "uniform"

    def __init__(self, kind, fmt=None):
        self.kind = kind
        self.me = MaxEntropy(fmt) if kind == "maxent" else None

    def sample(self, rng):
        if self.kind == "uniform":
            return rng.uniform_in(-1.0, 1.0)
        if self.kind == "maxent":
            return self.me.sample(rng)
        if self.kind == "gauss_outliers":
            if rng.uniform() < GO_EPS:
                return rng.sign() * rng.uniform_in(0.5, 1.0)
            sigma = go_core_sigma()
            v = rng.normal() * sigma
            return min(max(v, -1.0), 1.0)
        if self.kind == "clipped_gauss4":
            v = rng.normal() / 4.0
            return min(max(v, -1.0), 1.0)
        raise ValueError(self.kind)

    def is_outlier(self, x):
        if self.kind == "gauss_outliers":
            return abs(x) > 4.0 * go_core_sigma()
        return False

    def needs_aux(self):
        return self.kind == "gauss_outliers"

    def sample_q(self, u, aux):
        """Twin of distributions::Distribution::sample_q."""
        if self.kind == "uniform":
            return -1.0 + 2.0 * u
        if self.kind == "maxent":
            return self.me.sample_q(u)
        if self.kind == "gauss_outliers":
            if aux < GO_EPS:
                if u >= 0.5:
                    sign, t = 1.0, 2.0 * u - 1.0
                else:
                    sign, t = -1.0, 1.0 - 2.0 * u
                return sign * (0.5 + 0.5 * t)
            sigma = go_core_sigma()
            return min(max(probit(u) * sigma, -1.0), 1.0)
        if self.kind == "clipped_gauss4":
            return min(max(probit(u) / 4.0, -1.0), 1.0)
        raise ValueError(self.kind)


def f32(x):
    return struct.unpack("<f", struct.pack("<f", x))[0]


def fill_f32(dist, rng, n):
    return [f32(dist.sample(rng)) for _ in range(n)]


# ------------------------------------------------------------ workload --

QUANTILE_KNOTS = 513


def interp_sorted(s, pos):
    """Twin of workload::fit::interp_sorted — identical arithmetic."""
    i = int(math.floor(pos))
    if i + 1 >= len(s):
        return s[-1]
    frac = pos - float(i)
    return s[i] + (s[i + 1] - s[i]) * frac


class EmpDist:
    """Twin of workload::EmpiricalDist (fit + inverse-CDF sampling).

    Field-for-field mirror of EmpiricalDist::fit: same normalization,
    same accumulation order, same knot/quantile interpolation formulas.
    """

    def __init__(self, raw):
        assert len(raw) >= 2
        self.scale = max(abs(v) for v in raw)
        assert self.scale > 0.0
        norm = []
        total = 0.0
        total_sq = 0.0
        min_nonzero = float("inf")
        for v in raw:
            x = v / self.scale
            total += x
            total_sq += x * x
            if x != 0.0:
                min_nonzero = min(min_nonzero, abs(x))
            norm.append(x)
        n = len(norm)
        self.mean = total / float(n)
        mean_sq = total_sq / float(n)
        self.std = math.sqrt(max(mean_sq - self.mean * self.mean, 0.0))
        s = sorted(norm)
        self.knots = [
            interp_sorted(s, (j * (n - 1)) / (QUANTILE_KNOTS - 1))
            for j in range(QUANTILE_KNOTS)
        ]
        q = lambda p: interp_sorted(s, p * (n - 1))
        self.sigma_core = (q(0.84) - q(0.16)) / 2.0
        # mirror of workload::fit: sparse traces fall back to 4*std, and
        # constant-magnitude ones to threshold 1.0 (no outliers)
        spread = self.sigma_core if self.sigma_core > 0.0 else self.std
        self.thresh = 4.0 * spread if spread > 0.0 else 1.0
        self.outlier_mass = (
            sum(1 for x in s if abs(x) > self.thresh) / float(n))
        self.min_nonzero = min_nonzero
        self.dr_bits = -math.log2(min_nonzero)

    def sample(self, rng):
        u = rng.uniform()
        pos = u * float(QUANTILE_KNOTS - 1)
        return interp_sorted(self.knots, pos)

    def quantile(self, p):
        """Twin of workload::EmpiricalDist::quantile."""
        p = min(max(p, 0.0), 1.0)
        return interp_sorted(self.knots, p * float(QUANTILE_KNOTS - 1))

    def needs_aux(self):
        return False

    def sample_q(self, u, aux):
        return self.quantile(u)

    def is_outlier(self, x):
        return abs(x) > self.thresh


# ----------------------------------------------------------------- mac --


def simulate_column(x, w, nr, fx, fw):
    """Twin of mac::simulate_column — identical arithmetic order."""
    assert len(x) == len(w) and nr > 0 and len(x) % nr == 0
    b = len(x) // nr
    stx = fx.step()
    out = {k: [] for k in (
        "z_ideal", "z_q", "v_conv", "g_conv", "v_gr", "s_sum", "s2_sum",
        "sx_sum", "g_w", "nf", "wq2_mean")}
    for s in range(b):
        xs = x[s * nr:(s + 1) * nr]
        ws = w[s * nr:(s + 1) * nr]
        z_ideal = 0.0
        z_q = 0.0
        ebx = 1.0
        ebw = 1.0
        v_gr_num = 0.0
        s_sum = 0.0
        s2_sum = 0.0
        sx_sum = 0.0
        nf = 0.0
        wq2 = 0.0
        for i in range(nr):
            z_ideal += xs[i] * ws[i]
            xq, mxi, exi = fx.quantize_parts(xs[i])
            wq, mwi, ewi = fw.quantize_parts(ws[i])
            z_q += xq * wq
            ebx = max(ebx, exi)
            ebw = max(ebw, ewi)
            ux = exp2i(exi - fx.e_max)
            uw = exp2i(ewi - fw.e_max)
            u = ux * uw
            s_sum += u
            s2_sum += u * u
            v_gr_num += mxi * mwi * u
            sx_sum += ux
            dx = stx * ux
            nf += wq * wq * dx * dx
            wq2 += wq * wq
        z_ideal /= float(nr)
        z_q /= float(nr)
        nf /= 12.0 * float(nr * nr)
        g_w = exp2i(ebw - fw.e_max)
        g_conv = exp2i(ebx - fx.e_max) * g_w
        v_conv = z_q / g_conv
        out["z_ideal"].append(z_ideal)
        out["z_q"].append(z_q)
        out["v_conv"].append(v_conv)
        out["g_conv"].append(g_conv)
        out["v_gr"].append(v_gr_num / s_sum)
        out["s_sum"].append(s_sum)
        out["s2_sum"].append(s2_sum)
        out["sx_sum"].append(sx_sum)
        out["g_w"].append(g_w)
        out["nf"].append(nf)
        out["wq2_mean"].append(wq2 / float(nr))
    return out


class Moments:
    def __init__(self):
        self.n = 0
        self.sum = 0.0
        self.sum_sq = 0.0

    def push(self, x):
        self.n += 1
        self.sum += x
        self.sum_sq += x * x

    def mean(self):
        return self.sum / float(self.n) if self.n else 0.0

    def mean_sq(self):
        return self.sum_sq / float(self.n) if self.n else 0.0


class ColumnAgg:
    FIELDS = ("sig", "qerr", "nf", "wq2", "g_conv", "g_unit", "g_row",
              "n_eff", "v_conv", "v_gr")

    def __init__(self, nr):
        self.nr = nr
        for f in self.FIELDS:
            setattr(self, f, Moments())

    def push_batch(self, b):
        nr = float(self.nr)
        n = len(b["z_ideal"])
        for i in range(n):
            self.sig.push(b["z_ideal"][i])
            self.qerr.push(b["z_q"][i] - b["z_ideal"][i])
            self.nf.push(b["nf"][i])
            self.wq2.push(b["wq2_mean"][i])
            self.g_conv.push(b["g_conv"][i])
            self.g_unit.push(b["s_sum"][i] / nr)
            self.g_row.push(b["sx_sum"][i] / nr)
            self.n_eff.push(b["s_sum"][i] * b["s_sum"][i] / b["s2_sum"][i])
            self.v_conv.push(b["v_conv"][i])
            self.v_gr.push(b["v_gr"][i])

    def sqnr_db(self):
        return db(self.sig.mean_sq() / max(self.qerr.mean_sq(), 1e-300))

    def mean_n_eff(self):
        return self.n_eff.mean()

    def signal_power_gain(self):
        return self.v_gr.mean_sq() / max(self.v_conv.mean_sq(), 1e-300)


def db(p):
    return 10.0 * math.log10(p)


def from_db(d):
    return 10.0 ** (d / 10.0)


MARGIN_DB = 6.0


def required_enob(agg, arch):
    if arch == "conv":
        floor, g2 = agg.nf.mean(), 1.0
    elif arch == "unit":
        floor, g2 = agg.nf.mean(), agg.g_unit.mean_sq()
    elif arch == "row":
        floor, g2 = agg.nf.mean(), agg.g_row.mean_sq()
    else:
        raise ValueError(arch)
    floor = max(floor, 1e-300)
    delta_max = math.sqrt(12.0 * floor / (from_db(MARGIN_DB) * g2))
    return math.log2(2.0 / delta_max)


def run_experiment(spec, campaign_seed, preferred_batch=2048):
    jobs = -(-spec["samples"] // preferred_batch)
    agg = ColumnAgg(spec["nr"])
    for j in range(jobs):
        rng = Pcg64(job_seed(campaign_seed, 0, j))
        n = preferred_batch * spec["nr"]
        x = fill_f32(spec["dist_x"], rng, n)
        w = fill_f32(spec["dist_w"], rng, n)
        batch = simulate_column(x, w, spec["nr"], spec["fx"], spec["fw"])
        agg.push_batch(batch)
    return agg


# ------------------------------------------------------------- samplers --
# Twin of distributions::Sampler::fill_slab_f32 and
# coordinator::samples_for_ci (the --target-ci knob).


def shuffle(perm, rng):
    """Twin of distributions::shuffle (Fisher-Yates via Pcg64::below)."""
    for i in range(len(perm) - 1, 0, -1):
        j = rng.below(i + 1)
        perm[i], perm[j] = perm[j], perm[i]


def fill_slab_f32(sampler, dist, rng, n, row_len):
    """Twin of Sampler::fill_slab_f32 — identical RNG consumption order."""
    assert row_len > 0 and n % row_len == 0
    if sampler == "plain":
        return fill_f32(dist, rng, n)
    if sampler == "antithetic":
        needs_aux = dist.needs_aux()
        out = [0.0] * n
        rows = n // row_len
        for p in range(rows // 2):
            base = p * 2 * row_len
            for i in range(row_len):
                u = rng.uniform()
                aux = rng.uniform() if needs_aux else 0.5
                out[base + i] = f32(dist.sample_q(u, aux))
                m = 1.5 - u if u >= 0.5 else 0.5 - u
                out[base + row_len + i] = f32(dist.sample_q(m, aux))
        if rows % 2 == 1:
            out[n - row_len:] = fill_f32(dist, rng, row_len)
        return out
    if sampler == "stratified":
        rows = n // row_len
        out = [0.0] * n
        if rows == 0:
            return out
        needs_aux = dist.needs_aux()
        perm = list(range(rows))
        perm_aux = list(range(rows))
        inv_rows = 1.0 / float(rows)
        for j in range(row_len):
            shuffle(perm, rng)
            if needs_aux:
                shuffle(perm_aux, rng)
            for t in range(rows):
                u = (float(perm[t]) + rng.uniform()) * inv_rows
                if needs_aux:
                    aux = (float(perm_aux[t]) + rng.uniform()) * inv_rows
                else:
                    aux = 0.5
                out[t * row_len + j] = f32(dist.sample_q(u, aux))
        return out
    raise ValueError(sampler)


CI_PILOT_JOBS = 8
CI_PILOT_SAMPLES = 2048
CI_Z = 1.96
SAMPLER_MODES = ("plain", "antithetic", "stratified")


def run_sampler_job(spec, sampler, campaign_seed, batch_idx):
    """Twin of coordinator::run_job_buffered under an estimator mode: one
    job rng fills the x slab then the w slab (chunking is invisible to the
    per-sample aggregation, so one simulate_column call suffices)."""
    rng = Pcg64(job_seed(campaign_seed, 0, batch_idx))
    n = CI_PILOT_SAMPLES * spec["nr"]
    x = fill_slab_f32(sampler, spec["dist_x"], rng, n, spec["nr"])
    w = fill_slab_f32(sampler, spec["dist_w"], rng, n, spec["nr"])
    agg = ColumnAgg(spec["nr"])
    agg.push_batch(simulate_column(x, w, spec["nr"], spec["fx"], spec["fw"]))
    return agg


def samples_for_ci_twin(spec, seed, half_width_db):
    """Twin of coordinator::samples_for_ci — same pilot schedule, same
    sample-variance arithmetic (explicit (v-mean)*(v-mean), left-fold
    sums) so the required counts are bit-identical."""
    out = []
    for mode in SAMPLER_MODES:
        sqnrs = [run_sampler_job(spec, mode, seed, j).sqnr_db()
                 for j in range(CI_PILOT_JOBS)]
        k = float(CI_PILOT_JOBS)
        mean = sum(sqnrs) / k
        var = sum((v - mean) * (v - mean) for v in sqnrs) / (k - 1.0)
        required = max(math.ceil(
            CI_Z * CI_Z * var * float(CI_PILOT_SAMPLES)
            / (half_width_db * half_width_db)), 1)
        out.append({"sampler": mode, "mean": mean,
                    "std": math.sqrt(var), "required": required})
    return out


# -------------------------------------------------------------- energy --
# Twin of energy::TechParams (Table III defaults) + energy::arch.

C_GATE = 0.7
K1 = 100.0
K2 = 0.001
K3 = 50.0
VDD = 0.9
V2 = VDD * VDD


def e_adc(enob):
    return (K1 * enob + K2 * 4.0 ** enob) * V2


def e_dac(bits):
    return K3 * bits * V2


def e_fa():
    return 6.0 * C_GATE * V2


def e_adder_tree(fa_count):
    return e_fa() * fa_count


def e_mult(na, nb):
    return (1.5 * C_GATE * V2 + e_fa()) * na * nb


def e_decoder(n_in, n_out):
    return (0.5 * n_in + n_out + 1.0) * C_GATE * V2


def e_cell_array(n_sw, nr, nc):
    return 0.5 * C_GATE * V2 * n_sw * float(nr * nc)


def adder_tree_fa_count(n, width):
    count = 0.0
    remaining = n
    stage = 1.0
    while remaining > 1:
        pairs = remaining // 2
        count += float(pairs) * (width + stage - 1.0)
        remaining = remaining // 2 + remaining % 2
        stage += 1.0
    return count


def exponent_field_bits(e_max):
    return max(math.log2(e_max + 1.0), 1.0)


def energy_per_op(arch, fx, fw, nr, nc, enob):
    """Twin of energy::arch::energy_per_op — identical formula order.

    Returns the six components; total must be summed in the Rust
    EnergyBreakdown::total() order (adc, dac, cells, exp_logic, tree,
    norm_mult)."""
    ops = 2.0 * float(nr * nc)
    mant_x = fx.n_m + 1.0
    mant_w = fw.n_m + 1.0
    aligned_x = mant_x + (fx.e_max - 1.0)
    aligned_w = mant_w + (fw.e_max - 1.0)
    ebits_x = exponent_field_bits(fx.e_max)
    ebits_w = exponent_field_bits(fw.e_max)
    b = {"adc": float(nc) * e_adc(enob) / ops, "dac": 0.0, "cells": 0.0,
         "exp_logic": 0.0, "tree": 0.0, "norm_mult": 0.0}
    if arch == "conventional":
        b["dac"] = float(nr) * e_dac(aligned_x) / ops
        b["cells"] = e_cell_array(aligned_w, nr, nc) / ops
    elif arch == "gr-unit":
        b["dac"] = float(nr) * e_dac(mant_x) / ops
        b["cells"] = e_cell_array(mant_w + 1.0, nr, nc) / ops
        sum_levels = max(fx.e_max + fw.e_max - 1.0, 1.0)
        sum_bits = max(math.log2(sum_levels), 1.0) + 1.0
        fa_per_cell = max(ebits_x, ebits_w) + 1.0
        cell_logic = e_fa() * fa_per_cell + e_decoder(sum_bits, sum_levels)
        b["exp_logic"] = float(nr * nc) * cell_logic / ops
        b["tree"] = float(nc) * e_adder_tree(
            adder_tree_fa_count(nr, sum_levels)) / ops
        s_bits = sum_levels + math.log2(float(nr))
        b["norm_mult"] = float(nc) * e_mult(enob, s_bits) / ops
    elif arch == "gr-row":
        b["dac"] = float(nr) * e_dac(mant_x) / ops
        b["cells"] = e_cell_array(aligned_w + 1.0, nr, nc) / ops
        levels = max(fx.e_max, 1.0)
        b["exp_logic"] = float(nr) * e_decoder(ebits_x, levels) / ops
        b["tree"] = e_adder_tree(adder_tree_fa_count(nr, levels)) / ops
        s_bits = levels + math.log2(float(nr))
        b["norm_mult"] = float(nc) * e_mult(enob, s_bits) / ops
    else:
        raise ValueError(arch)
    return b


def energy_total(b):
    # the exact EnergyBreakdown::total() addition order
    return (b["adc"] + b["dac"] + b["cells"] + b["exp_logic"] + b["tree"]
            + b["norm_mult"])


def global_norm_energy_per_op(fx, nr, nc):
    ops = 2.0 * float(nr * nc)
    ebits = exponent_field_bits(fx.e_max)
    maxfind = e_adder_tree(adder_tree_fa_count(nr, ebits))
    per_input = e_fa() * ebits + e_decoder(ebits, max(fx.e_max, 1.0))
    return (maxfind + float(nr) * per_input) / ops


def native_ok(arch, fx, fw):
    """Twin of figures::fig12::native_ok (6-bit native gain range)."""
    if arch == "conventional":
        return True
    if arch == "gr-unit":
        return (fx.e_max - 1.0) + (fw.e_max - 1.0) <= 6.0
    if arch == "gr-row":
        return fx.e_max - 1.0 <= 6.0
    raise ValueError(arch)


# ------------------------------------------------------------- digital --
# Twin of energy::digital — the digital-IMC baseline (arxiv 2405.14978)
# and the analog-vs-digital crossover resolution.

MAX_CROSSOVER_ENOB = 32.0


def d_e_reg(bits):
    """Twin of digital::e_reg: 4 * C_gate * V_DD^2 per register bit."""
    return 4.0 * C_GATE * V2 * bits


def d_e_add(bits):
    """Twin of digital::e_add: one full adder per accumulator bit."""
    return e_fa() * bits


def aligned_bits_f(f):
    """Twin of digital::aligned_bits: (n_m + 1) + (e_max - 1)."""
    return (f.n_m + 1.0) + (f.e_max - 1.0)


def acc_width(nx_bits, nw_bits, nr):
    """Twin of digital::acc_width: product width + ceil(log2 NR)."""
    return nx_bits + nw_bits + math.ceil(math.log2(float(nr)))


def digital_mac_fj(fx, fw, nr):
    """Twin of digital::digital_mac_fj: array multiply over the aligned
    magnitude words, full-width accumulate add, register write."""
    nx, nw = aligned_bits_f(fx), aligned_bits_f(fw)
    accw = acc_width(nx, nw, nr)
    return e_mult(nx, nw) + d_e_add(accw) + d_e_reg(accw)


def digital_fj_per_op(fx, fw, nr):
    """Twin of digital::digital_fj_per_op (one MAC = two ops)."""
    return digital_mac_fj(fx, fw, nr) / 2.0


def softmax_element_fj():
    """Twin of digital::softmax_element_fj — the TechParams
    e_softmax_fj default (the exact Rust addition order)."""
    bits = 8.0
    mults = 2.0 * e_mult(bits, bits)
    adds = 2.0 * d_e_add(bits)
    return mults + adds + d_e_reg(bits)


E_SOFTMAX_FJ = softmax_element_fj()


def crossover_enob_twin(arch, fx, fw, nr, nc):
    """Twin of digital::crossover_enob: 80-step bisection of the ADC
    resolution where the analog per-op energy meets the flat digital
    baseline; None when one side wins everywhere in [0, 32]."""
    digital = digital_fj_per_op(fx, fw, nr)

    def analog(enob):
        return energy_total(energy_per_op(arch, fx, fw, nr, nc, enob))

    if analog(0.0) >= digital:
        return None
    if analog(MAX_CROSSOVER_ENOB) < digital:
        return None
    lo, hi = 0.0, MAX_CROSSOVER_ENOB
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if analog(mid) >= digital:
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------- tile --
# Twin of tile::mapper — the layer-scale GEMM on GR-MAC tiles.

TILE_STREAM = 0x711E  # tile::mapper::LAYER_STREAM
MAX_TILE_ENOB = 32.0


def exp2f(t):
    """Twin of formats::exp2 for possibly fractional t."""
    ti = math.floor(t)
    fr = t - ti
    ip = math.ldexp(1.0, int(ti))
    return ip if fr == 0.0 else ip * 2.0 ** fr


def adc_quantize(v, enob):
    """Twin of mac::adc_quantize (ideal mid-rise ADC over [-1, 1])."""
    delta = 2.0 / exp2f(enob)
    q = math.floor(v / delta + 0.5) * delta
    return min(max(q, -1.0), 1.0)


def tile_gemm_twin(x, wt, shape, nr, nc, fx, fw, arch, fixed_enob=None):
    """Twin of tile::mapper::gemm_with_engine over explicit operands:
    kt-major tile grid, per-tile spec-solved ADC (clamped to [0, 32]) or
    a fixed resolution, digitization, ascending-kt partial-sum
    reduction, the float reference GEMM, and the energy totals. Shared
    by the single-layer and model twins (the Rust mapper is shared the
    same way)."""
    m_, k_, n_ = shape
    row_tiles = -(-k_ // nr)
    col_tiles = -(-n_ // nc)
    spec_arch = {"conventional": "conv", "gr-unit": "unit", "gr-row": "row"}[arch]
    mvm_ops = float(2 * nr * nc * m_)

    y = [0.0] * (m_ * n_)
    tiles = []
    tiles_fj = 0.0
    # per-component totals in the Rust LayerReport::component_totals
    # accumulation order (each component summed tile-by-tile)
    comps = {"adc": 0.0, "dac": 0.0, "cells": 0.0, "exp_logic": 0.0,
             "tree": 0.0, "norm_mult": 0.0}
    for kt in range(row_tiles):
        for nt in range(col_tiles):
            k0 = kt * nr
            rows = min(k_ - k0, nr)
            n0 = nt * nc
            cols = min(n_ - n0, nc)
            xs = []
            ws = []
            for mi in range(m_):
                for j in range(cols):
                    xs.extend(x[mi * k_ + k0:mi * k_ + k0 + rows])
                    xs.extend([0.0] * (nr - rows))
                    ws.extend(wt[(n0 + j) * k_ + k0:(n0 + j) * k_ + k0 + rows])
                    ws.extend([0.0] * (nr - rows))
            batch = simulate_column(xs, ws, nr, fx, fw)
            if fixed_enob is None:
                agg = ColumnAgg(nr)
                agg.push_batch(batch)
                enob = min(max(required_enob(agg, spec_arch), 0.0), MAX_TILE_ENOB)
            else:
                enob = fixed_enob
            for mi in range(m_):
                for j in range(cols):
                    s = mi * cols + j
                    if arch == "conventional":
                        v, g = batch["v_conv"][s], batch["g_conv"][s]
                    else:
                        v, g = batch["v_gr"][s], batch["s_sum"][s] / float(nr)
                    y[mi * n_ + n0 + j] += adc_quantize(v, enob) * g * float(nr)
            b = energy_per_op(arch, fx, fw, nr, nc, enob)
            e_fj = energy_total(b) * mvm_ops
            for comp in comps:
                comps[comp] += b[comp] * mvm_ops
            tiles.append({"enob": enob, "fj": e_fj})
            tiles_fj += e_fj

    sig = 0.0
    err = 0.0
    for mi in range(m_):
        for ni in range(n_):
            r = 0.0
            for ki in range(k_):
                r += x[mi * k_ + ki] * wt[ni * k_ + ki]
            sig += r * r
            d = y[mi * n_ + ni] - r
            err += d * d
    sqnr_db = db(sig / max(err, 1e-300))

    if row_tiles > 1:
        max_enob = max(t["enob"] for t in tiles)
        width = max_enob + math.log2(float(nr))
        reduction_fj = (e_adder_tree(adder_tree_fa_count(row_tiles, width))
                        * float(m_ * n_))
    else:
        reduction_fj = 0.0
    if native_ok(arch, fx, fw):
        global_norm_fj = 0.0
    else:
        global_norm_fj = (global_norm_energy_per_op(fx, nr, nc)
                          * float(2 * nr * nc * m_) * float(len(tiles)))

    # plain GEMMs don't exponentiate: softmax_fj stays 0 (the Rust
    # mapper's assemble() convention), so the total is unchanged
    total_fj = tiles_fj + reduction_fj + global_norm_fj
    enob_mean = sum(t["enob"] for t in tiles) / float(len(tiles))
    return {
        "y": y,
        "tiles": tiles,
        "components": comps,
        "tiles_fj": tiles_fj,
        "reduction_fj": reduction_fj,
        "global_norm_fj": global_norm_fj,
        "softmax_fj": 0.0,
        "total_fj": total_fj,
        "fj_per_mac": total_fj / float(m_ * k_ * n_),
        "sqnr_db": sqnr_db,
        "y_abs_sum": sum(abs(v) for v in y),
        "y_sq_sum": sum(v * v for v in y),
        "enob_mean": enob_mean,
    }


def run_layer_twin(shape, nr, nc, fx, fw, arch, dist_x, dist_w, seed):
    """Twin of tile::mapper::run_layer: operand generation (stream
    TILE_STREAM of the campaign seed) followed by the shared tile-grid
    evaluation."""
    m_, k_, n_ = shape
    rng = Pcg64(job_seed(seed, TILE_STREAM, 0))
    x = fill_f32(dist_x, rng, m_ * k_)
    wt = fill_f32(dist_w, rng, n_ * k_)
    return tile_gemm_twin(x, wt, shape, nr, nc, fx, fw, arch)


# -------------------------------------------------------------- im2col --
# Twin of tile::im2col — valid-padding, stride-1 convolution lowered to
# the weight-stationary GEMM mapper. A conv tuple is
# (cout, cin, kh, kw, h, w), the Rust `ConvShape` field order.


def conv_gemm_shape(cv):
    """Twin of ConvShape::gemm_shape: (out_h*out_w, cin*kh*kw, cout)."""
    cout, cin, kh, kw, h, w = cv
    return ((h - kh + 1) * (w - kw + 1), cin * kh * kw, cout)


def conv_img_elems(cv):
    """Twin of ConvShape::img_elems: H*W*Cin."""
    _cout, cin, _kh, _kw, h, w = cv
    return h * w * cin


def im2col_twin(img, cv):
    """Twin of tile::im2col: flatten an HWC image (`img[(y*W+x)*Cin+c]`)
    into the patch-row matrix, row-major `[out_h*out_w][cin*kh*kw]`,
    patch column `(ky*kW + kx)*Cin + ci` — contiguous `kw*cin` runs per
    kernel row, exactly the Rust extend_from_slice order."""
    _cout, cin, kh, kw, h, w = cv
    out = []
    for oy in range(h - kh + 1):
        for ox in range(w - kw + 1):
            for ky in range(kh):
                row = ((oy + ky) * w + ox) * cin
                out.extend(img[row:row + kw * cin])
    return out


# --------------------------------------------------------------- model --
# Twin of model::exec — chained tile layers with inter-layer
# requantization and the float reference chain.

MODEL_STREAM = 0x30DE1  # model::exec::MODEL_STREAM


def softmax_rows_f32_twin(rows, cols):
    """Twin of model::attn::softmax_rows_f32: row-wise max-subtracted
    f32 softmax, every f32 operation emulated as compute-in-f64 then
    round (`exp` runs in f64 on the exactly-representable f32
    difference — the form both sides pin bit-for-bit)."""
    out = list(rows)
    for r0 in range(0, len(out), cols):
        row = out[r0:r0 + cols]
        mx = max(row)
        sm = 0.0
        for i, v in enumerate(row):
            e = f32(math.exp(f32(v - mx)))
            row[i] = e
            sm = f32(sm + e)
        out[r0:r0 + cols] = [f32(v / sm) for v in row]
    return out


def softmax_row_f64_twin(row):
    """Twin of model::attn::softmax_row_f64 (the reference chains)."""
    mx = max(row)
    sm = 0.0
    for i, v in enumerate(row):
        e = math.exp(v - mx)
        row[i] = e
        sm += e
    for i in range(len(row)):
        row[i] /= sm


def attn_twin(xq, a_scale, shape, heads, kv, nr, nc, fx, fw, arch,
              fixed_enob=None):
    """Twin of model::attn::run_attention over the requantized stage
    input `xq`: per-head QK^T tile GEMMs (scores rescaled to the real
    domain, f32-cast, scaled by 1/sqrt(d_h)), the exact digital f32
    softmax, ONE shared probability requantization across every head
    (the second calibration point), per-head A·V tile GEMMs, the
    combined energy totals, and the stage SQNR against exact f64
    attention over the same quantized operands. `kv` is None for
    prefill (S = M, K/V from the fused [Q|K|V] input at `a_scale`) or
    {"ctx", "k", "v"} for decode (full-scale cache)."""
    m_, k_in, d = shape
    dh = d // heads
    if kv is None:
        s_len, k_scale, v_scale = m_, a_scale, a_scale
    else:
        s_len, k_scale, v_scale = kv["ctx"], 1.0, 1.0
    sqrt_dh = math.sqrt(float(dh))

    # phase A: QK^T per head (K weight-stationary), then softmax
    grids = []
    probs = [0.0] * (heads * m_ * s_len)
    for h in range(heads):
        c0 = h * dh
        q = [xq[mi * k_in + c0 + c] for mi in range(m_) for c in range(dh)]
        if kv is None:
            kt = [xq[j * k_in + d + c0 + c]
                  for j in range(s_len) for c in range(dh)]
        else:
            kt = [kv["k"][j * d + c0 + c]
                  for j in range(s_len) for c in range(dh)]
        g = tile_gemm_twin(q, kt, (m_, dh, s_len), nr, nc, fx, fw, arch,
                           fixed_enob=fixed_enob)
        base = h * m_ * s_len
        for i, yv in enumerate(g["y"]):
            probs[base + i] = f32(yv * a_scale * k_scale / sqrt_dh)
        probs[base:base + m_ * s_len] = softmax_rows_f32_twin(
            probs[base:base + m_ * s_len], s_len)
        grids.append(g)

    # second calibration point: one shared probability scale
    a2 = 0.0
    for p in probs:
        a2 = max(a2, p)
    a2_scale = max(a2, 1e-12)
    pq = []
    sig = 0.0
    err = 0.0
    for p in probs:
        s = p / a2_scale
        qv = f32(fx.quantize(f32(s)))
        pq.append(qv)
        sig += s * s
        e = qv - s
        err += e * e
    softmax_requant_db = db(max(sig, 1e-300) / max(err, 1e-300))

    # phase B: A·V per head (V weight-stationary)
    y_out = [0.0] * (m_ * d)
    for h in range(heads):
        c0 = h * dh
        if kv is None:
            vt = [xq[j * k_in + 2 * d + c0 + o]
                  for o in range(dh) for j in range(s_len)]
        else:
            vt = [kv["v"][j * d + c0 + o]
                  for o in range(dh) for j in range(s_len)]
        base = h * m_ * s_len
        g = tile_gemm_twin(pq[base:base + m_ * s_len], vt,
                           (m_, s_len, dh), nr, nc, fx, fw, arch,
                           fixed_enob=fixed_enob)
        for mi in range(m_):
            for o in range(dh):
                y_out[mi * d + c0 + o] = (g["y"][mi * dh + o]
                                          * a2_scale * v_scale)
        grids.append(g)

    # stage SQNR: exact f64 attention over the same quantized operands
    sig = 0.0
    err = 0.0
    for h in range(heads):
        c0 = h * dh
        for mi in range(m_):
            sc = [0.0] * s_len
            for j in range(s_len):
                acc = 0.0
                for c in range(dh):
                    kvq = (xq[j * k_in + d + c0 + c] if kv is None
                           else kv["k"][j * d + c0 + c])
                    acc += xq[mi * k_in + c0 + c] * kvq
                sc[j] = acc * a_scale * k_scale / sqrt_dh
            softmax_row_f64_twin(sc)
            for o in range(dh):
                acc = 0.0
                for j in range(s_len):
                    vvq = (xq[j * k_in + 2 * d + c0 + o] if kv is None
                           else kv["v"][j * d + c0 + o])
                    acc += sc[j] * (vvq * v_scale)
                sig += acc * acc
                dlt = y_out[mi * d + c0 + o] - acc
                err += dlt * dlt
    sqnr_db = db(max(sig, 1e-300) / max(err, 1e-300))

    # combined grid under the virtual M x (2S) x d shape: concatenated
    # sub-GEMM tiles (QK^T heads first, then A·V heads) and summed energy
    tiles = [t for g in grids for t in g["tiles"]]
    tiles_fj = sum(g["tiles_fj"] for g in grids)
    reduction_fj = sum(g["reduction_fj"] for g in grids)
    global_norm_fj = sum(g["global_norm_fj"] for g in grids)
    # digital softmax: heads * M * S probability elements priced at the
    # TechParams e_softmax_fj default (model::attn::run_attention)
    softmax_fj = float(heads * m_ * s_len) * E_SOFTMAX_FJ
    total_fj = tiles_fj + reduction_fj + global_norm_fj + softmax_fj
    macs = 2 * m_ * s_len * d
    return {
        "y": y_out,
        "grids": grids,
        "tiles": tiles,
        "tiles_fj": tiles_fj,
        "reduction_fj": reduction_fj,
        "global_norm_fj": global_norm_fj,
        "softmax_fj": softmax_fj,
        "total_fj": total_fj,
        "fj_per_mac": total_fj / float(macs),
        "sqnr_db": sqnr_db,
        "softmax_requant_db": softmax_requant_db,
        "y_abs_sum": sum(abs(v) for v in y_out),
        "y_sq_sum": sum(v * v for v in y_out),
        "enob_mean": sum(t["enob"] for t in tiles) / float(len(tiles)),
    }


def attn_reference_twin(ref, width, shape, heads, kv):
    """Twin of model::attn::attention_reference: exact f64 attention
    over the unquantized reference activations (leading-K rule applied)
    and the raw KV cache."""
    m_, _k_in, d = shape
    dh = d // heads
    s_len = m_ if kv is None else kv["ctx"]
    sqrt_dh = math.sqrt(float(dh))
    out = [0.0] * (m_ * d)
    for h in range(heads):
        c0 = h * dh
        for mi in range(m_):
            sc = [0.0] * s_len
            for j in range(s_len):
                acc = 0.0
                for c in range(dh):
                    kvv = (ref[j * width + d + c0 + c] if kv is None
                           else kv["k"][j * d + c0 + c])
                    acc += ref[mi * width + c0 + c] * kvv
                sc[j] = acc / sqrt_dh
            softmax_row_f64_twin(sc)
            for o in range(dh):
                acc = 0.0
                for j in range(s_len):
                    vvv = (ref[j * width + 2 * d + c0 + o] if kv is None
                           else kv["v"][j * d + c0 + o])
                    acc += sc[j] * vvv
                out[mi * d + c0 + o] = acc
    return out


def norm_model_layer(e):
    """Normalize a run_model_twin chain entry: a plain (M, K, N) tuple
    is a GEMM layer; dicts carry a `kind` of "attn" ({"shape", "heads",
    "ctx": None|int}) or "conv" ({"conv": (cout,cin,kh,kw,h,w)}) —
    mirroring model::LayerKind."""
    if isinstance(e, dict):
        if e["kind"] == "conv":
            return {"kind": "conv", "conv": e["conv"],
                    "shape": conv_gemm_shape(e["conv"])}
        return dict(e)
    return {"kind": "gemm", "shape": tuple(e)}


def run_model_twin(shapes, nr, nc, fx, fw, arch, dist_x, dist_w, seed,
                   relu=True, fit=True, fixed_enob=None):
    """Twin of model::exec::run_model: model input from stream
    (MODEL_STREAM, 0), layer li's operands from (MODEL_STREAM, li+1),
    then per layer: static max-|x| calibration, requantization of the
    scaled activations to the input format (f32-cast, quantize, f32 —
    the exact Rust order), the shared tile grid (or the attention
    QK^T/softmax/A·V twin), and the float-domain epilogue (rescale,
    hidden-layer ReLU — never on attention). `shapes` entries are
    (M, K, N) tuples for plain GEMMs, with K_i <= N_{i-1} (leading-K
    truncation), or tagged dicts ([`norm_model_layer`]): a conv first
    layer draws its H*W*Cin image at stream 0 and requantizes it
    *before* im2col expansion; an attention layer draws no weights
    (decode draws its KV cache from dist_x instead: all keys, then all
    values, one RNG)."""
    entries = [norm_model_layer(e) for e in shapes]
    first = entries[0]
    m_ = first["shape"][0]
    rng = Pcg64(job_seed(seed, MODEL_STREAM, 0))
    if first["kind"] == "conv":
        acts = fill_f32(dist_x, rng, conv_img_elems(first["conv"]))
    else:
        acts = fill_f32(dist_x, rng, m_ * first["shape"][1])
    ref = list(acts)
    width = first["shape"][1]
    layers = []
    all_tiles = []
    total_macs = 0
    for li, lay in enumerate(entries):
        mm, k_, n_ = lay["shape"]
        kind = lay["kind"]
        assert mm == m_ and k_ <= width
        rng_l = Pcg64(job_seed(seed, MODEL_STREAM, li + 1))
        wt = None
        kv = None
        if kind == "attn":
            if lay["ctx"] is not None:
                c = lay["ctx"]
                kc = fill_f32(dist_x, rng_l, c * n_)
                vc = fill_f32(dist_x, rng_l, c * n_)
                kv = {"ctx": c, "k": kc, "v": vc}
        else:
            wt = fill_f32(dist_w, rng_l, n_ * k_)
        a_scale = max(max(abs(v) for v in acts), 1e-12)
        scaled = []
        sig = 0.0
        err = 0.0
        if kind == "conv":
            imgq = []
            for v in acts:
                s = v / a_scale
                q = f32(fx.quantize(f32(s)))
                imgq.append(q)
                sig += s * s
                d = q - s
                err += d * d
                scaled.append(s)
            xq = im2col_twin(imgq, lay["conv"])
        else:
            xq = []
            for mi in range(m_):
                for ki in range(k_):
                    s = acts[mi * width + ki] / a_scale
                    q = f32(fx.quantize(f32(s)))
                    xq.append(q)
                    sig += s * s
                    d = q - s
                    err += d * d
                    scaled.append(s)
        requant_db = db(max(sig, 1e-300) / max(err, 1e-300))
        stats = EmpDist(scaled) if fit else None
        softmax_db = None
        if kind == "attn":
            r = attn_twin(xq, a_scale, (mm, k_, n_), lay["heads"], kv,
                          nr, nc, fx, fw, arch, fixed_enob=fixed_enob)
            nxt = list(r["y"])
            softmax_db = r["softmax_requant_db"]
            s_len = mm if kv is None else kv["ctx"]
            total_macs += 2 * mm * s_len * n_
            ref_nxt = attn_reference_twin(ref, width, (mm, k_, n_),
                                          lay["heads"], kv)
        else:
            r = tile_gemm_twin(xq, wt, (m_, k_, n_), nr, nc, fx, fw, arch,
                               fixed_enob=fixed_enob)
            total_macs += mm * k_ * n_
            hidden = relu and li + 1 < len(entries)
            nxt = [0.0] * (m_ * n_)
            for mi in range(m_):
                for o in range(n_):
                    v = r["y"][mi * n_ + o] * a_scale * 1.0
                    if hidden:
                        v = max(v, 0.0)
                    nxt[mi * n_ + o] = v
            if kind == "conv":
                rin, stride = im2col_twin(ref, lay["conv"]), k_
            else:
                rin, stride = ref, width
            ref_nxt = [0.0] * (m_ * n_)
            for mi in range(m_):
                for o in range(n_):
                    acc = 0.0
                    for ki in range(k_):
                        acc += rin[mi * stride + ki] * (wt[o * k_ + ki] * 1.0)
                    if hidden:
                        acc = max(acc, 0.0)
                    ref_nxt[mi * n_ + o] = acc
        acts = nxt
        ref = ref_nxt
        width = n_
        all_tiles.extend(r["tiles"])
        layers.append({
            "a_scale": a_scale,
            "requant_db": requant_db,
            "softmax_requant_db": softmax_db,
            "stats": stats,
            "grid": r,
        })
    sig = 0.0
    err = 0.0
    for yv, rv in zip(acts, ref):
        sig += rv * rv
        d = yv - rv
        err += d * d
    e2e_db = db(max(sig, 1e-300) / max(err, 1e-300))
    total_fj = sum(l["grid"]["total_fj"] for l in layers)
    macs = total_macs
    return {
        "layers": layers,
        "y": acts,
        "ref": ref,
        "e2e_sqnr_db": e2e_db,
        "total_fj": total_fj,
        "fj_per_mac": total_fj / float(macs),
        "fj_per_token": total_fj / float(m_),
        "y_abs_sum": sum(abs(v) for v in acts),
        "y_sq_sum": sum(v * v for v in acts),
        "enob_mean": sum(t["enob"] for t in all_tiles) / float(len(all_tiles)),
        "tiles": all_tiles,
    }


# -------------------------------------------------------------- analog --


class GrMacCell:
    def __init__(self, m_bits, levels, c_u, c_p1):
        assert m_bits >= 1 and levels >= 3
        self.c_m = [c_u * float(1 << i) for i in range(m_bits)]
        c_sum = 0.0
        for c in self.c_m:
            c_sum += c

        def t(j):
            return (c_sum + c_p1) / (float(1 << (levels - j + 1)) - 1.0)

        c_e = [t(1)]
        for j in range(2, levels):
            c_e.append(t(j) - t(1))
        c_e.append(t(levels) - t(levels - 1))
        self.c_e = c_e
        self.c_p1 = c_p1

    @staticmethod
    def fp6_e2m3_schematic():
        return GrMacCell(4, 4, 1.0, 0.0)

    def levels(self):
        return len(self.c_e)

    def m_codes(self):
        return 1 << len(self.c_m)

    def c_sum(self):
        s = 0.0
        for c in self.c_m:
            s += c
        return s

    def coupling_total(self, level):
        l = self.levels()
        assert 1 <= level <= l
        t = self.c_e[0]
        if 2 <= level < l:
            t += self.c_e[level - 1]
        elif level == l:
            t += self.c_e[l - 2] + self.c_e[l - 1]
        return t

    def transfer_closed_form(self, w_code, level, v_in):
        c_sel = 0.0
        for i, c in enumerate(self.c_m):
            if (w_code >> i) & 1 == 1:
                c_sel += c
        cs = self.c_sum() + self.c_p1
        t = self.coupling_total(level)
        return v_in * c_sel * t / (cs + t)

    def lsb(self, level, v_in):
        return (self.transfer_closed_form(1, level, v_in)
                - self.transfer_closed_form(0, level, v_in))


# ---------------------------------------------------------------- fig9 --


def fig9_sqnr_db(fmt, dist, samples, seed, core_only, ulp_floor):
    rng = Pcg64(seed)
    sig = 0.0
    noise = 0.0
    n = 0
    for _ in range(samples):
        x = dist.sample(rng)
        if core_only and dist.is_outlier(x):
            continue
        q = fmt.quantize(x)
        sig += x * x
        if ulp_floor:
            u = fmt.ulp(abs(q))
            noise += u * u / 12.0
        else:
            noise += (x - q) * (x - q)
        n += 1
    if n == 0:
        return float("-inf")
    return db(sig / max(noise, 1e-300))


def fig9_fmt_for(n_e):
    if n_e == 0:
        return FpFormat.int_(2 + 2)  # N_M + 2 with N_M = 2
    return FpFormat.fp(n_e, 2)


def fig9_series(samples, seed):
    rows = []
    for n_e in range(0, 6):
        fmt = fig9_fmt_for(n_e)
        uni = fig9_sqnr_db(fmt, Dist("uniform"), samples, seed + 1, False, False)
        me = fig9_sqnr_db(fmt, Dist("maxent", fmt), samples, seed + 2, False, True)
        go = Dist("gauss_outliers")
        go_all = fig9_sqnr_db(fmt, go, samples, seed + 3, False, False)
        go_core = fig9_sqnr_db(fmt, go, samples, seed + 3, True, False)
        rows.append([uni, me, go_all, go_core])
    return rows


# -------------------------------------------------------------- explore --
# Twin of explore — the design-space Pareto explorer: canonical plan
# hashing, lexicographic grid decode, per-point evaluation with the
# component-level energy breakdown, and the non-dominated frontier.

EXPLORE_STREAM = 0x9A2E  # explore::EXPLORE_STREAM


def fnv1a64(data):
    """Twin of explore::fnv1a64 over the canonical plan bytes."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def json_canonical(v):
    """Twin of config::Json::to_string: sorted object keys, no
    whitespace, integer-valued numbers below 1e15 rendered without a
    fraction. (Non-integral values fall back to repr(), which matches
    the Rust shortest-round-trip form for the magnitudes plans use.)"""
    if v is None:
        return "null"
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        f = float(v)
        if f == math.floor(f) and abs(f) < 1e15:
            return str(int(f))
        return repr(f)
    if isinstance(v, str):
        out = ['"']
        for c in v:
            if c == '"':
                out.append('\\"')
            elif c == "\\":
                out.append("\\\\")
            elif c == "\n":
                out.append("\\n")
            elif c == "\t":
                out.append("\\t")
            elif c == "\r":
                out.append("\\r")
            elif ord(c) < 0x20:
                out.append("\\u%04x" % ord(c))
            else:
                out.append(c)
        out.append('"')
        return "".join(out)
    if isinstance(v, list):
        return "[" + ",".join(json_canonical(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(
            json_canonical(k) + ":" + json_canonical(v[k])
            for k in sorted(v)) + "}"
    raise TypeError(type(v))


def plan_hash_twin(plan):
    """Twin of ParetoPlan::content_hash: FNV-1a 64 over the canonical
    serialization (axes nested under "axes", sorted keys)."""
    doc = {
        "name": plan["name"],
        "seed": plan["seed"],
        "tokens": plan["tokens"],
        "distribution": plan["distribution"],
        "axes": {
            "workload": plan["workload"],
            "nr": plan["nr"],
            "nc": plan["nc"],
            "arch": plan["arch"],
            "n_e": plan["n_e"],
            "n_m": plan["n_m"],
            "adc": plan["adc"],
            "adc_scale": plan["adc_scale"],
        },
    }
    return fnv1a64(json_canonical(doc).encode("utf-8"))


def plan_num_points(plan):
    n = 1
    for axis in ("workload", "nr", "nc", "arch", "n_e", "n_m", "adc",
                 "adc_scale"):
        n *= len(plan[axis])
    return n


def plan_point_twin(plan, index):
    """Twin of ParetoPlan::point: decode the lexicographic grid index
    (workload outermost, adc_scale innermost — division peels from the
    right)."""
    rest = index

    def take(axis):
        nonlocal rest
        vals = plan[axis]
        i = rest % len(vals)
        rest //= len(vals)
        return vals[i]

    adc_scale = take("adc_scale")
    adc = take("adc")
    n_m = take("n_m")
    n_e = take("n_e")
    arch = take("arch")
    nc = take("nc")
    nr = take("nr")
    workload = take("workload")
    return {"index": index, "workload": workload, "nr": nr, "nc": nc,
            "arch": arch, "n_e": n_e, "n_m": n_m, "adc": adc,
            "adc_scale": adc_scale}


def pareto_eval_twin(plan, index):
    """Twin of explore::eval_point for `gemm:MxKxN` workloads at
    adc_scale 1: operands from (plan.seed, EXPLORE_STREAM, index) — X
    then the transposed weights — through the shared tile-grid twin,
    with the component breakdown and the digital-IMC comparison."""
    spec = plan_point_twin(plan, index)
    assert spec["workload"].startswith("gemm:"), spec["workload"]
    assert spec["adc_scale"] == 1, "twin prices the unscaled ADC only"
    m_, k_, n_ = (int(d) for d in spec["workload"][5:].split("x"))
    fx = FpFormat.fp(int(spec["n_e"]), int(spec["n_m"]))
    fw = FpFormat.fp4_e2m1()
    dist_x = Dist(plan["distribution"])
    dist_w = Dist("maxent", fw)
    fixed_enob = (None if spec["adc"] == "spec"
                  else float(spec["adc"].split(":")[1]))

    rng = Pcg64(job_seed(plan["seed"], EXPLORE_STREAM, index))
    x = fill_f32(dist_x, rng, m_ * k_)
    wt = fill_f32(dist_w, rng, n_ * k_)
    r = tile_gemm_twin(x, wt, (m_, k_, n_), spec["nr"], spec["nc"], fx, fw,
                       spec["arch"], fixed_enob=fixed_enob)
    dig = digital_mac_fj(fx, fw, spec["nr"])
    return {
        "index": index,
        "enob_mean": r["enob_mean"],
        "sqnr_db": r["sqnr_db"],
        "components": r["components"],
        "reduction_fj": r["reduction_fj"],
        "global_norm_fj": r["global_norm_fj"],
        "softmax_fj": r["softmax_fj"],
        "total_fj": r["total_fj"],
        "fj_per_mac": r["fj_per_mac"],
        "digital_fj_per_mac": dig,
        "digital_ratio": r["fj_per_mac"] / dig,
        "crossover_enob": crossover_enob_twin(
            spec["arch"], fx, fw, spec["nr"], spec["nc"]),
    }


def frontier_mask_twin(points):
    """Twin of explore::frontier::frontier_mask: point i survives iff no
    point dominates it (lower-or-equal fJ/MAC AND higher-or-equal SQNR,
    at least one strict; NaN objectives neither dominate nor are
    dominated)."""
    def dominates(a, b):
        if any(math.isnan(v) for v in (a["fj_per_mac"], a["sqnr_db"],
                                       b["fj_per_mac"], b["sqnr_db"])):
            return False
        no_worse = (a["fj_per_mac"] <= b["fj_per_mac"]
                    and a["sqnr_db"] >= b["sqnr_db"])
        strict = (a["fj_per_mac"] < b["fj_per_mac"]
                  or a["sqnr_db"] > b["sqnr_db"])
        return no_worse and strict

    return [not any(dominates(a, b) for a in points if a is not b)
            for b in points]


# ---------------------------------------------------- self-validation --


def self_check():
    """Pin the twin against value vectors from the Rust unit tests."""
    # SplitMix64 canonical vector (Steele et al. reference, seed 0)
    assert SplitMix64(0).next_u64() == 0xE220A8397B1DCDAF

    # FP4_E2M1 codebook (formats::tests::fp4_e2m1_codebook_is_ocp_set)
    f4 = FpFormat.fp4_e2m1()
    book = sorted({abs(f4.quantize(v / 32.0)) for v in range(-32, 33)})
    assert book == [0.0, 0.0625, 0.125, 0.1875, 0.25, 0.375, 0.5, 0.75], book

    # quantize vectors (formats::tests)
    assert f4.quantize(5.0) == 0.75 and f4.quantize(-5.0) == -0.75
    assert f4.quantize(1.0) == 0.75
    assert f4.quantize(0.0) == 0.0
    assert f4.quantize(0.01) == 0.0
    assert f4.quantize(0.05) == 0.0625
    assert f4.quantize(-0.05) == -0.0625
    assert f4.quantize(0.47) == 0.5  # rollover renormalizes
    assert f4.decompose(0.75) == (0.75, 3.0)
    assert f4.decompose(0.125) == (0.5, 1.0)
    m, e = f4.decompose(0.0625)
    assert e == 1.0 and abs(m - 0.25) < 1e-15
    assert f4.decompose(0.0) == (0.0, 1.0)
    i4 = FpFormat.int_(4)
    assert i4.quantize(0.3) == 0.25
    assert i4.quantize(0.33) == 0.375
    assert i4.vmax() == 0.875

    # quantize_parts zero convention
    assert f4.quantize_parts(0.0) == (0.0, 0.0, 1.0)

    # max-entropy decode vectors (maxent::tests::decode_subnormals_and_normals)
    me = MaxEntropy(f4)
    assert me.decode(1.0, 0, 0) == 0.0
    assert me.decode(1.0, 0, 1) == 0.0625
    assert me.decode(1.0, 1, 0) == 0.125
    assert me.decode(1.0, 3, 1) == 0.75
    assert me.decode(-1.0, 3, 0) == -0.5

    # GR-MAC cell Table I vectors (grmac_cell::tests)
    cell = GrMacCell.fp6_e2m3_schematic()
    assert cell.c_m == [1.0, 2.0, 4.0, 8.0]
    assert abs(cell.c_e[0] - 1.0) < 1e-12
    assert abs(cell.c_e[1] - 8.0 / 7.0) < 1e-12
    assert abs(cell.c_e[2] - 4.0) < 1e-12
    assert abs(cell.c_e[3] - 10.0) < 1e-12
    assert abs(cell.coupling_total(1) - 1.0) < 1e-12
    assert abs(cell.coupling_total(2) - 15.0 / 7.0) < 1e-12
    assert abs(cell.coupling_total(3) - 5.0) < 1e-12
    assert abs(cell.coupling_total(4) - 15.0) < 1e-12

    # rng statistical sanity (mirrors rng::tests tolerances)
    rng = Pcg64(11)
    n = 20000
    xs = [rng.uniform() for _ in range(n)]
    mean = sum(xs) / n
    assert abs(mean - 0.5) < 0.02, mean
    rng = Pcg64(13)
    ys = [rng.normal() for _ in range(n)]
    mv = sum(ys) / n
    var = sum((y - mv) ** 2 for y in ys) / n
    assert abs(mv) < 0.05 and abs(var - 1.0) < 0.05, (mv, var)

    # simulate_column linear-chain identity (mac::tests)
    rng = Pcg64(1)
    nr = 32
    x = [rng.uniform_in(-1.0, 1.0) for _ in range(64 * nr)]
    rngw = Pcg64(2)
    w = [min(max(rngw.normal() / 4.0, -1.0), 1.0) for _ in range(64 * nr)]
    fx = FpFormat.fp(3, 2)
    fw = f4
    b = simulate_column(x, w, nr, fx, fw)
    for i in range(64):
        assert abs(b["z_q"][i] - b["v_conv"][i] * b["g_conv"][i]) < 1e-10
        assert abs(b["z_q"][i] - b["v_gr"][i] * b["s_sum"][i] / 32.0) < 1e-10
        neff = b["s_sum"][i] ** 2 / b["s2_sum"][i]
        assert 1.0 - 1e-12 <= neff <= 32.0 + 1e-9

    print("self-check OK")


# ------------------------------------------------------------ emission --


def write_golden(path, tol, values):
    doc = {"_tol": tol, "values": {k: v for k, v in values}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(values)} values)")


def gen_table1(outdir):
    vals = []
    paper_c_m = [1.0, 2.0, 4.0, 8.0]
    paper_c_e = [1.0, 1.14, 4.0, 10.0]
    cells = [
        ("schematic", GrMacCell.fp6_e2m3_schematic()),
        ("comp05", GrMacCell(4, 4, 1.0, 0.5)),
        ("comp10", GrMacCell(4, 4, 1.0, 1.0)),
    ]
    for label, cell in cells:
        for i, c in enumerate(cell.c_m):
            vals.append((f"{label}_c_m{i}", c))
        for i, c in enumerate(cell.c_e):
            vals.append((f"{label}_c_e{i + 1}", c))
        for level in range(1, cell.levels() + 1):
            vals.append((f"{label}_coupling_t{level}", cell.coupling_total(level)))
            vals.append((f"{label}_q_w15_l{level}",
                         cell.transfer_closed_form(15, level, 1.0)))
    for i, c in enumerate(paper_c_m):
        vals.append((f"paper_c_m{i}", c))
    for i, c in enumerate(paper_c_e):
        vals.append((f"paper_c_e{i + 1}", c))
    write_golden(os.path.join(outdir, "table1.json"), 1e-10, vals)


def gen_fig8(outdir):
    vals = []
    cell = GrMacCell.fp6_e2m3_schematic()
    for level in range(1, cell.levels() + 1):
        sweep = [cell.transfer_closed_form(wc, level, 1.0)
                 for wc in range(cell.m_codes())]
        for w in (1, 7, 15):
            vals.append((f"q_l{level}_w{w}", sweep[w]))
        vals.append((f"lsb_l{level}", cell.lsb(level, 1.0)))
        if level >= 2:
            top = cell.m_codes() - 1
            ratio = (cell.transfer_closed_form(top, level, 1.0)
                     / cell.transfer_closed_form(top, level - 1, 1.0))
            vals.append((f"octave_ratio_l{level}", ratio))
    write_golden(os.path.join(outdir, "fig8.json"), 1e-10, vals)


def gen_fig9(outdir):
    samples = 16384
    seed = 0xF19D
    rows = fig9_series(samples, seed)
    names = ["uniform", "max_entropy", "gauss_outliers", "gauss_core"]
    vals = []
    for i, row in enumerate(rows):
        for j, name in enumerate(names):
            assert math.isfinite(row[j]), (i, name)
            vals.append((f"ne{i}_{name}", row[j]))
    write_golden(os.path.join(outdir, "fig9.json"), 1e-6, vals)


def gen_campaign(outdir):
    fp4 = FpFormat.fp4_e2m1()
    specs = [
        {
            "id": "ne3-uniform",
            "fx": FpFormat.fp(3, 2), "fw": fp4,
            "dist_x": Dist("uniform"), "dist_w": Dist("maxent", fp4),
            "nr": 32, "samples": 2048,
        },
        {
            "id": "ne4-llm",
            "fx": FpFormat.fp(4, 2), "fw": fp4,
            "dist_x": Dist("gauss_outliers"), "dist_w": Dist("maxent", fp4),
            "nr": 32, "samples": 2048,
        },
        {
            "id": "int6",
            "fx": FpFormat.int_(6), "fw": FpFormat.int_(4),
            "dist_x": Dist("uniform"), "dist_w": Dist("uniform"),
            "nr": 16, "samples": 2048,
        },
    ]
    vals = []
    for spec in specs:
        agg = run_experiment(spec, 42)
        assert agg.sig.n == spec["samples"]
        tag = spec["id"]
        conv = required_enob(agg, "conv")
        unit = required_enob(agg, "unit")
        row = required_enob(agg, "row")
        vals.append((f"{tag}_enob_conv", conv))
        vals.append((f"{tag}_enob_unit", unit))
        vals.append((f"{tag}_enob_row", row))
        vals.append((f"{tag}_delta_enob", conv - unit))
        vals.append((f"{tag}_mean_n_eff", agg.mean_n_eff()))
        vals.append((f"{tag}_power_gain", agg.signal_power_gain()))
        vals.append((f"{tag}_sqnr_db", agg.sqnr_db()))
        vals.append((f"{tag}_nf_mean", agg.nf.mean()))
        vals.append((f"{tag}_g_unit_ms", agg.g_unit.mean_sq()))
        vals.append((f"{tag}_g_row_ms", agg.g_row.mean_sq()))
        print(f"  {tag}: enob conv={conv:.4f} unit={unit:.4f} row={row:.4f} "
              f"n_eff={agg.mean_n_eff():.3f}")
    write_golden(os.path.join(outdir, "campaign_enob.json"), 1e-6, vals)


WORKLOAD_TRACE_SEED = 0xE3
WORKLOAD_TRACE_N = 4096
WORKLOAD_SQNR_SAMPLES = 8192
WORKLOAD_SQNR_SEED = 0x17E


def gen_workload(outdir):
    """Twin of tests/golden.rs::golden_workload_empirical: generate the
    same synthetic-LLM trace (seeded f32 gauss+outliers draws), fit the
    EmpiricalDist twin, and pin the fit summary, the Fig. 9-style SQNR
    sweep, and the trace-driven campaign ENOB solutions."""
    rng = Pcg64(WORKLOAD_TRACE_SEED)
    raw = fill_f32(Dist("gauss_outliers"), rng, WORKLOAD_TRACE_N)
    emp = EmpDist(raw)

    vals = [
        ("fit_scale", emp.scale),
        ("fit_dr_bits", emp.dr_bits),
        ("fit_sigma_core", emp.sigma_core),
        ("fit_outlier_mass", emp.outlier_mass),
        ("fit_mean", emp.mean),
        ("fit_std", emp.std),
    ]
    for j in (0, 128, 256, 384, 512):
        vals.append((f"fit_knot{j}", emp.knots[j]))

    for n_e in range(0, 6):
        fmt = fig9_fmt_for(n_e)
        seed = WORKLOAD_SQNR_SEED + n_e
        all_db = fig9_sqnr_db(fmt, emp, WORKLOAD_SQNR_SAMPLES, seed,
                              False, False)
        core_db = fig9_sqnr_db(fmt, emp, WORKLOAD_SQNR_SAMPLES, seed,
                               True, False)
        assert math.isfinite(all_db) and math.isfinite(core_db), n_e
        vals.append((f"sqnr_ne{n_e}_all", all_db))
        vals.append((f"sqnr_ne{n_e}_core", core_db))

    fp4 = FpFormat.fp4_e2m1()
    spec = {
        "id": "trace-ne4",
        "fx": FpFormat.fp(4, 2), "fw": fp4,
        "dist_x": emp, "dist_w": Dist("maxent", fp4),
        "nr": 32, "samples": 2048,
    }
    agg = run_experiment(spec, 42)
    assert agg.sig.n == spec["samples"]
    conv = required_enob(agg, "conv")
    unit = required_enob(agg, "unit")
    row = required_enob(agg, "row")
    vals += [
        ("enob_conv", conv),
        ("enob_unit", unit),
        ("enob_row", row),
        ("delta_enob", conv - unit),
        ("mean_n_eff", agg.mean_n_eff()),
        ("sqnr_db", agg.sqnr_db()),
        ("nf_mean", agg.nf.mean()),
        ("g_unit_ms", agg.g_unit.mean_sq()),
    ]
    print(f"  workload: enob conv={conv:.4f} unit={unit:.4f} "
          f"outlier_mass={emp.outlier_mass:.4f} dr={emp.dr_bits:.2f}b")
    write_golden(os.path.join(outdir, "workload_empirical.json"), 1e-6, vals)


LAYER_SEED = 42
LAYER_SHAPE = (4, 40, 40)
LAYER_NR = 16
LAYER_NC = 16


def gen_layer(outdir):
    """Twin of tests/golden.rs::golden_layer_gemm: evaluate one small
    ragged-edged GEMM (3x3 tile grid, edge tiles 8 deep/wide) under three
    configurations — native gr-unit, conventional, and a wide-format
    gr-unit that needs the global-normalization wrapper — and pin the
    per-tile ENOBs, energy totals, layer SQNR, and output checksums."""
    fp4 = FpFormat.fp4_e2m1()
    dist_x = Dist("gauss_outliers")
    dist_w = Dist("maxent", fp4)
    configs = [
        ("gru", FpFormat.fp(2, 2), "gr-unit"),
        ("conv", FpFormat.fp(2, 2), "conventional"),
        ("wide", FpFormat.fp(4, 2), "gr-unit"),
    ]
    vals = []
    for tag, fx, arch in configs:
        r = run_layer_twin(LAYER_SHAPE, LAYER_NR, LAYER_NC, fx, fp4, arch,
                           dist_x, dist_w, LAYER_SEED)
        for i, t in enumerate(r["tiles"]):
            vals.append((f"{tag}_tile{i}_enob", t["enob"]))
        for key in ("tiles_fj", "reduction_fj", "global_norm_fj", "total_fj",
                    "fj_per_mac", "sqnr_db", "y_abs_sum", "y_sq_sum",
                    "enob_mean"):
            assert math.isfinite(r[key]), (tag, key)
            vals.append((f"{tag}_{key}", r[key]))
        print(f"  layer {tag}: enob_mean={r['enob_mean']:.3f} "
              f"fj/mac={r['fj_per_mac']:.2f} sqnr={r['sqnr_db']:.2f} dB")
    write_golden(os.path.join(outdir, "layer_gemm.json"), 1e-6, vals)


MODEL_SEED = 42
MODEL_SHAPES = [(4, 24, 16), (4, 16, 12), (4, 12, 8)]  # mlp:24x16x12x8 at 4 tokens
MODEL_NR = 8
MODEL_NC = 8


def gen_model(outdir):
    """Twin of tests/golden.rs::golden_model_report: chain a 3-layer MLP
    (ragged tile grids on every layer) under gr-unit and conventional
    signal chains and pin the per-layer ADC means, energy totals, layer
    and requantization SQNRs, activation-fit statistics, and the model
    totals (end-to-end SQNR, fJ/MAC, output checksums)."""
    fp4 = FpFormat.fp4_e2m1()
    dist_x = Dist("gauss_outliers")
    dist_w = Dist("maxent", fp4)
    fx = FpFormat.fp(2, 2)
    vals = []
    for tag, arch in (("gru", "gr-unit"), ("conv", "conventional")):
        r = run_model_twin(MODEL_SHAPES, MODEL_NR, MODEL_NC, fx, fp4, arch,
                           dist_x, dist_w, MODEL_SEED, relu=True, fit=True)
        for li, l in enumerate(r["layers"]):
            vals.append((f"{tag}_l{li}_enob_mean", l["grid"]["enob_mean"]))
            vals.append((f"{tag}_l{li}_total_fj", l["grid"]["total_fj"]))
            vals.append((f"{tag}_l{li}_sqnr_db", l["grid"]["sqnr_db"]))
            vals.append((f"{tag}_l{li}_requant_db", l["requant_db"]))
            vals.append((f"{tag}_l{li}_a_scale", l["a_scale"]))
            stats = l["stats"]
            assert stats is not None, (tag, li)
            vals.append((f"{tag}_l{li}_act_dr_bits", stats.dr_bits))
            vals.append((f"{tag}_l{li}_act_sigma_core", stats.sigma_core))
            vals.append((f"{tag}_l{li}_act_outlier_mass", stats.outlier_mass))
        for key in ("total_fj", "fj_per_mac", "e2e_sqnr_db", "y_abs_sum",
                    "y_sq_sum", "enob_mean"):
            assert math.isfinite(r[key]), (tag, key)
            vals.append((f"{tag}_{key}", r[key]))
        print(f"  model {tag}: enob_mean={r['enob_mean']:.3f} "
              f"fj/mac={r['fj_per_mac']:.2f} e2e={r['e2e_sqnr_db']:.2f} dB")
    write_golden(os.path.join(outdir, "model_report.json"), 1e-6, vals)


ATTN_SEED = 77
ATTN_NR = 16
ATTN_NC = 16
ATTN_TOKENS = 4
DECODE_CTX = 32


def transformer_entries(d, heads, layers, tokens):
    """Twin of model::parse_model's `transformer:<d>x<heads>x<layers>`
    expansion: per block, fused QKV projection, the attention stage,
    the output projection, and the 4x MLP pair."""
    out = []
    for _ in range(layers):
        out.append((tokens, d, 3 * d))
        out.append({"kind": "attn", "shape": (tokens, 3 * d, d),
                    "heads": heads, "ctx": None})
        out.append((tokens, d, d))
        out.append((tokens, d, 4 * d))
        out.append((tokens, 4 * d, d))
    return out


def decode_entries(d, heads, ctx):
    """Twin of model::parse_model's `decode:<d>x<heads>x<ctx>`
    expansion: one token's QKV projection, KV-cache attention (the
    leading-K rule feeds it exactly the Q slice), output projection."""
    return [
        (1, d, 3 * d),
        {"kind": "attn", "shape": (1, d, d), "heads": heads, "ctx": ctx},
        (1, d, d),
    ]


def gen_attention_block(outdir):
    """Twin of tests/golden.rs::golden_attention_block: run the 1-head
    and 4-head transformer:64x*x2 presets (4 tokens) and the
    decode:64x4x32 KV-cache GEMV scenario under gr-unit and
    conventional signal chains, pinning per-layer ADC means, energies,
    layer/requant SQNRs, the attention stages' per-sub-GEMM ADC means
    and softmax-requantization SQNRs, and the model totals (end-to-end
    SQNR, fJ/MAC, fJ/token, output checksums)."""
    fp4 = FpFormat.fp4_e2m1()
    dist_x = Dist("gauss_outliers")
    dist_w = Dist("maxent", fp4)
    fx = FpFormat.fp(4, 2)
    cases = [
        ("t1", transformer_entries(64, 1, 2, ATTN_TOKENS), 1),
        ("t4", transformer_entries(64, 4, 2, ATTN_TOKENS), 4),
        ("dec", decode_entries(64, 4, DECODE_CTX), 4),
    ]
    vals = []
    for ctag, entries, heads in cases:
        for atag, arch in (("gru", "gr-unit"), ("cnv", "conventional")):
            tag = f"{ctag}_{atag}"
            r = run_model_twin(entries, ATTN_NR, ATTN_NC, fx, fp4, arch,
                               dist_x, dist_w, ATTN_SEED,
                               relu=False, fit=False)
            for li, l in enumerate(r["layers"]):
                g = l["grid"]
                vals.append((f"{tag}_l{li}_enob_mean", g["enob_mean"]))
                vals.append((f"{tag}_l{li}_total_fj", g["total_fj"]))
                vals.append((f"{tag}_l{li}_sqnr_db", g["sqnr_db"]))
                vals.append((f"{tag}_l{li}_requant_db", l["requant_db"]))
                if l["softmax_requant_db"] is not None:
                    vals.append((f"{tag}_l{li}_softmax_db",
                                 l["softmax_requant_db"]))
                    # per-sub-GEMM ADC means: QK^T heads, then A·V heads
                    assert len(g["grids"]) == 2 * heads
                    for sub, sg in enumerate(g["grids"]):
                        vals.append((f"{tag}_l{li}_sub{sub}_enob",
                                     sg["enob_mean"]))
            for key in ("total_fj", "fj_per_mac", "fj_per_token",
                        "e2e_sqnr_db", "y_abs_sum", "y_sq_sum",
                        "enob_mean"):
                assert math.isfinite(r[key]), (tag, key)
                vals.append((f"{tag}_{key}", r[key]))
            print(f"  attn {tag}: enob_mean={r['enob_mean']:.3f} "
                  f"fj/tok={r['fj_per_token']:.0f} "
                  f"e2e={r['e2e_sqnr_db']:.2f} dB")
    write_golden(os.path.join(outdir, "attention_block.json"), 1e-6, vals)


CONV_SEED = 91
CONV_SHAPE = (6, 3, 3, 3, 8, 8)  # conv:6x3x3x3@8x8 -> gemm 36x27x6
CONV_NR = 8
CONV_NC = 8


def gen_conv_im2col(outdir):
    """Twin of tests/golden.rs::golden_conv_im2col: a conv-led chain
    (`conv:6x3x3x3@8x8,gemm:36x6x4` — the image requantized once, then
    im2col onto the unchanged weight-stationary mapper) under gr-unit
    and conventional signal chains, pinning per-layer ADC means,
    energies, layer/requant SQNRs, and the model totals."""
    fp4 = FpFormat.fp4_e2m1()
    dist_x = Dist("gauss_outliers")
    dist_w = Dist("maxent", fp4)
    fx = FpFormat.fp(2, 2)
    entries = [{"kind": "conv", "conv": CONV_SHAPE},
               (36, 6, 4)]
    vals = []
    for tag, arch in (("gru", "gr-unit"), ("cnv", "conventional")):
        r = run_model_twin(entries, CONV_NR, CONV_NC, fx, fp4, arch,
                           dist_x, dist_w, CONV_SEED, relu=True, fit=False)
        for li, l in enumerate(r["layers"]):
            g = l["grid"]
            vals.append((f"{tag}_l{li}_enob_mean", g["enob_mean"]))
            vals.append((f"{tag}_l{li}_total_fj", g["total_fj"]))
            vals.append((f"{tag}_l{li}_sqnr_db", g["sqnr_db"]))
            vals.append((f"{tag}_l{li}_requant_db", l["requant_db"]))
            vals.append((f"{tag}_l{li}_a_scale", l["a_scale"]))
        for key in ("total_fj", "fj_per_mac", "e2e_sqnr_db", "y_abs_sum",
                    "y_sq_sum", "enob_mean"):
            assert math.isfinite(r[key]), (tag, key)
            vals.append((f"{tag}_{key}", r[key]))
        print(f"  conv {tag}: enob_mean={r['enob_mean']:.3f} "
              f"fj/mac={r['fj_per_mac']:.2f} e2e={r['e2e_sqnr_db']:.2f} dB")
    write_golden(os.path.join(outdir, "conv_im2col.json"), 1e-6, vals)


PARETO_PLAN = {
    "name": "golden",
    "seed": 42,
    "tokens": 4,
    "distribution": "gauss_outliers",
    "workload": ["gemm:4x32x8"],
    "nr": [8, 16],
    "nc": [8],
    "arch": ["gr-unit", "conventional"],
    "n_e": [2, 4],
    "n_m": [2],
    "adc": ["spec"],
    "adc_scale": [1],
}


def gen_pareto(outdir):
    """Twin of tests/golden.rs::golden_pareto_explore: expand the 8-point
    nr x arch x n_e grid (native gr-unit, the global-norm wide format,
    and the conventional baseline), evaluate every point through the
    explorer's seeded operand stream, and pin the plan content hash, the
    per-point component breakdowns, SQNR, the digital-IMC baseline and
    crossover, and the Pareto-frontier membership."""
    plan = PARETO_PLAN
    n = plan_num_points(plan)
    assert n == 8, n
    pts = [pareto_eval_twin(plan, i) for i in range(n)]
    mask = frontier_mask_twin(pts)
    h = plan_hash_twin(plan)
    vals = [
        ("plan_hash_hi", float(h >> 32)),
        ("plan_hash_lo", float(h & 0xFFFFFFFF)),
        ("num_points", float(n)),
        ("num_frontier", float(sum(mask))),
    ]
    for p, front in zip(pts, mask):
        i = p["index"]
        comps = p["components"]
        # the acceptance invariant: the nine-way breakdown reconciles
        # with the total within 1e-9 relative (exact Rust addition order)
        bsum = (comps["adc"] + comps["dac"] + comps["cells"]
                + comps["exp_logic"] + comps["tree"] + comps["norm_mult"]
                + p["reduction_fj"] + p["global_norm_fj"] + p["softmax_fj"])
        rel = abs(bsum - p["total_fj"]) / max(p["total_fj"], 1e-300)
        assert rel < 1e-9, (i, bsum, p["total_fj"])
        vals.append((f"p{i}_enob_mean", p["enob_mean"]))
        vals.append((f"p{i}_sqnr_db", p["sqnr_db"]))
        for cname in ("adc", "dac", "cells", "exp_logic", "tree",
                      "norm_mult"):
            vals.append((f"p{i}_{cname}_fj", comps[cname]))
        for key in ("reduction_fj", "global_norm_fj", "softmax_fj",
                    "total_fj", "fj_per_mac", "digital_fj_per_mac",
                    "digital_ratio"):
            vals.append((f"p{i}_{key}", p[key]))
        if p["crossover_enob"] is not None:
            vals.append((f"p{i}_crossover_enob", p["crossover_enob"]))
        vals.append((f"p{i}_frontier", 1.0 if front else 0.0))
        print(f"  pareto p{i}: fj/mac={p['fj_per_mac']:.2f} "
              f"sqnr={p['sqnr_db']:.2f} dB vs digital "
              f"{p['digital_ratio']:.2f}x"
              + (" [frontier]" if front else ""))
    write_golden(os.path.join(outdir, "pareto_explore.json"), 1e-6, vals)


def digital_self_check():
    """Pin the digital-IMC twin against the Rust unit-test vectors
    (energy::digital::tests) and the canonical-hash primitives."""
    assert abs(d_e_reg(8.0) - 4.0 * 0.7 * 0.81 * 8.0) < 1e-12
    assert abs(d_e_add(8.0) - 8.0 * e_fa()) < 1e-12
    fp4 = FpFormat.fp4_e2m1()
    assert aligned_bits_f(fp4) == 4.0
    assert acc_width(4.0, 4.0, 32) == 13.0
    assert acc_width(4.0, 4.0, 1) == 8.0
    assert acc_width(4.0, 4.0, 33) == 14.0
    want = e_mult(4.0, 4.0) + d_e_add(13.0) + d_e_reg(13.0)
    assert abs(digital_mac_fj(fp4, fp4, 32) - want) < 1e-12
    assert abs(digital_fj_per_op(fp4, fp4, 32) - want / 2.0) < 1e-12
    # 2*272.16 + 54.432 + 18.144 at the Table III defaults
    assert abs(E_SOFTMAX_FJ - 616.896) < 1e-9
    # the crossover is the energy-equality point, and analog wins below
    x = crossover_enob_twin("gr-unit", fp4, fp4, 32, 32)
    assert x is not None
    analog = energy_total(energy_per_op("gr-unit", fp4, fp4, 32, 32, x))
    dig = digital_fj_per_op(fp4, fp4, 32)
    assert abs(analog - dig) / dig < 1e-6, (analog, dig)
    below = energy_total(energy_per_op("gr-unit", fp4, fp4, 32, 32,
                                       x - 1.0))
    assert below < dig
    # FNV-1a 64 canonical vectors
    assert fnv1a64(b"") == 0xCBF29CE484222325
    assert fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    # canonical JSON: sorted keys, no whitespace, integral floats as ints
    assert json_canonical({"b": [1.0, 0.5], "a": "x"}) == \
        '{"a":"x","b":[1,0.5]}'
    # frontier: trade-offs survive, interior points are filtered,
    # duplicates are all kept
    def pt(e, q):
        return {"fj_per_mac": e, "sqnr_db": q}
    assert frontier_mask_twin([pt(1.0, 30.0), pt(2.0, 40.0),
                               pt(1.5, 29.0), pt(3.0, 39.0)]) == \
        [True, True, False, False]
    assert frontier_mask_twin([pt(1.0, 35.0), pt(1.0, 35.0)]) == \
        [True, True]
    print("digital self-check OK")


CI_GOLDEN_SEED = 0xC1
CI_GOLDEN_HALF_DB = 0.25


def ci_spec():
    """Twin of coordinator::tests::ci_spec — the acceptance-criteria
    point (an FP8-class input near 35 dB under clipped-Gaussian
    activations; the gauss+outliers mix shows no sampler variance
    reduction — outlier-magnitude noise dominates there)."""
    fp4 = FpFormat.fp4_e2m1()
    return {
        "id": "ci35",
        "fx": FpFormat.fp(4, 3), "fw": fp4,
        "dist_x": Dist("clipped_gauss4"), "dist_w": Dist("maxent", fp4),
        "nr": 32, "samples": CI_PILOT_SAMPLES,
    }


def gen_samples_ci(outdir):
    """Twin of tests/golden.rs::golden_samples_ci: pin the
    samples-for-equal-CI pilot estimates (mean/std per-job SQNR and the
    required sample counts) for all three estimator modes at the
    acceptance spec point, seed 0xC1, half-width 0.25 dB."""
    ests = samples_for_ci_twin(ci_spec(), CI_GOLDEN_SEED, CI_GOLDEN_HALF_DB)
    vals = []
    req = {}
    for est in ests:
        tag = est["sampler"]
        req[tag] = est["required"]
        vals.append((f"{tag}_sqnr_db_mean", est["mean"]))
        vals.append((f"{tag}_sqnr_db_std", est["std"]))
        vals.append((f"{tag}_required_samples", float(est["required"])))
        print(f"  ci {tag}: sqnr={est['mean']:.3f}±{est['std']:.4f} dB "
              f"-> {est['required']} samples for ±{CI_GOLDEN_HALF_DB} dB")
    # the acceptance criterion the Rust suite pins at this exact point:
    # a variance-reduced mode reaches the CI with >= 2x fewer samples
    assert 30.0 < ests[0]["mean"] < 40.0, ests[0]["mean"]
    best = min(req["antithetic"], req["stratified"])
    assert req["plain"] >= 2 * best, req
    write_golden(os.path.join(outdir, "samples_ci.json"), 1e-6, vals)


def sampler_self_check():
    """Pin the sampler twins against the Rust unit-test invariants
    (distributions::tests)."""
    # probit: central zero, tail symmetry, a standard-normal vector
    assert probit(0.5) == 0.0
    assert abs(probit(0.975) - 1.959964) < 1e-6
    for p in (0.001, 0.01, 0.2, 0.4):
        assert abs(probit(p) + probit(1.0 - p)) < 1e-9, p
    assert probit(0.0) == float("-inf") and probit(1.0) == float("inf")
    # antithetic pairs on uniform: same sign, magnitudes sum to 1
    rng = Pcg64(3)
    out = fill_slab_f32("antithetic", Dist("uniform"), rng, 8 * 4, 4)
    for p in range(4):
        for i in range(4):
            a = out[p * 8 + i]
            b = out[p * 8 + 4 + i]
            assert a * b >= 0.0, (a, b)
            assert abs(abs(a) + abs(b) - 1.0) < 1e-6, (a, b)
    # stratified pins the gauss+outliers branch count at its expectation
    rng = Pcg64(5)
    rows, nr = 2000, 4
    out = fill_slab_f32("stratified", Dist("gauss_outliers"), rng,
                        rows * nr, nr)
    for j in range(nr):
        c = sum(1 for t in range(rows) if abs(out[t * nr + j]) >= 0.5)
        assert 19 <= c <= 21, (j, c)
    # plain mode is the sequential fill, bit for bit
    a, b = Pcg64(9), Pcg64(9)
    assert fill_slab_f32("plain", Dist("gauss_outliers"), a, 64, 8) == \
        fill_f32(Dist("gauss_outliers"), b, 64)
    # maxent quantile map covers the code book with the sign convention
    me = MaxEntropy(FpFormat.fp4_e2m1())
    assert me.sample_q(0.5) == 0.0
    assert me.sample_q(1.0 - 1e-12) == 0.75
    assert me.sample_q(1e-12) == -0.75
    print("sampler self-check OK")


def model_self_check():
    """Pin the model twin's chain semantics: with a fine input format
    (FP(4,6)), exactly-representable FP4 weights, and a near-transparent
    fixed ADC, the chained output must track the float reference chain
    to input-requantization precision, and the chain truncation
    (K < previous N) must feed exactly the leading K features."""
    fp4 = FpFormat.fp4_e2m1()
    fx = FpFormat.fp(4, 6)
    shapes = [(2, 12, 10), (2, 7, 4)]  # truncation: 7 < 10
    r = run_model_twin(shapes, 4, 3, fx, fp4, "gr-unit",
                       Dist("maxent", fx), Dist("maxent", fp4), 9,
                       relu=False, fit=False, fixed_enob=30.0)
    # transparent ADC + exact weights: only the ~2^-7 input
    # requantization separates the chain from the float reference
    for yv, rv in zip(r["y"], r["ref"]):
        assert abs(yv - rv) < 2e-2 * max(1.0, abs(rv)), (yv, rv)
    assert r["e2e_sqnr_db"] > 30.0, r["e2e_sqnr_db"]
    assert r["layers"][0]["requant_db"] > 30.0, r["layers"][0]["requant_db"]
    # tile accounting: 2 layers, ragged grids (3x4 then 2x2 tiles)
    assert len(r["tiles"]) == 12 + 4, len(r["tiles"])
    assert r["total_fj"] > 0.0
    # the truncated reference really is the leading-7-features GEMM
    m_, k1, n1 = shapes[0]
    rng = Pcg64(job_seed(9, MODEL_STREAM, 0))
    x0 = fill_f32(Dist("maxent", fx), rng, m_ * k1)
    rng_w1 = Pcg64(job_seed(9, MODEL_STREAM, 1))
    wt1 = fill_f32(Dist("maxent", fp4), rng_w1, n1 * k1)
    rng_w2 = Pcg64(job_seed(9, MODEL_STREAM, 2))
    _, k2, n2 = shapes[1]
    wt2 = fill_f32(Dist("maxent", fp4), rng_w2, n2 * k2)
    h = [sum(x0[mi * k1 + ki] * wt1[o * k1 + ki] for ki in range(k1))
         for mi in range(m_) for o in range(n1)]
    want = [sum(h[mi * n1 + ki] * wt2[o * k2 + ki] for ki in range(k2))
            for mi in range(m_) for o in range(n2)]
    for a, b in zip(r["ref"], want):
        assert abs(a - b) < 1e-9, (a, b)


def im2col_self_check():
    """Pin the im2col twin against the Rust unit-test vectors
    (tile::im2col tests) and the 1x1-kernel GEMM equivalence the
    property suite relies on."""
    # 1-channel 3x3 image, 2x2 kernel: 4 patches in scan order
    cv = (1, 1, 2, 2, 3, 3)
    img = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]
    assert im2col_twin(img, cv) == [
        1.0, 2.0, 4.0, 5.0, 2.0, 3.0, 5.0, 6.0,
        4.0, 5.0, 7.0, 8.0, 5.0, 6.0, 8.0, 9.0,
    ]
    assert conv_gemm_shape(cv) == (4, 4, 1)
    # a 1x1 kernel is the identity reshape (HWC row-major == [H*W][Cin])
    cv1 = (5, 3, 1, 1, 4, 4)
    img2 = [float(i) * 0.25 for i in range(conv_img_elems(cv1))]
    assert im2col_twin(img2, cv1) == img2
    assert conv_gemm_shape(cv1) == (16, 3, 5)
    # ...so the conv-led model chain equals the flattened GEMM chain
    # bit for bit (same draws, same requant, same tiles)
    fp4 = FpFormat.fp4_e2m1()
    fx = FpFormat.fp(2, 2)
    a = run_model_twin([{"kind": "conv", "conv": (4, 3, 1, 1, 3, 3)},
                        (9, 4, 2)],
                       4, 4, fx, fp4, "gr-unit",
                       Dist("gauss_outliers"), Dist("maxent", fp4), 5,
                       relu=True, fit=False)
    b = run_model_twin([(9, 3, 4), (9, 4, 2)],
                       4, 4, fx, fp4, "gr-unit",
                       Dist("gauss_outliers"), Dist("maxent", fp4), 5,
                       relu=True, fit=False)
    assert a["y"] == b["y"] and a["total_fj"] == b["total_fj"]
    assert a["e2e_sqnr_db"] == b["e2e_sqnr_db"]
    print("im2col self-check OK")


def attn_self_check():
    """Pin the attention twin's chain semantics: softmax rows normalize
    (a constant row is exactly uniform), and with a fine input format
    plus a near-transparent fixed ADC the prefill attention chain must
    track the f64 reference chain closely."""
    sm = softmax_rows_f32_twin([0.5, 1.5, -0.25, 2.0,
                                3.0, 3.0, 3.0, 3.0], 4)
    for r0 in range(0, 8, 4):
        assert abs(sum(sm[r0:r0 + 4]) - 1.0) < 1e-6
    assert all(p == 0.25 for p in sm[4:])
    # shift invariance is exact in the max-subtracted f32 form
    a = softmax_rows_f32_twin([0.5, -1.0, 2.0, 0.0], 4)
    b = softmax_rows_f32_twin([4.5, 3.0, 6.0, 4.0], 4)
    assert a == b
    # near-transparent prefill chain: qkv -> attn at FP(4,10) for BOTH
    # operand formats and fixed 30-bit ADCs. The weight format must be
    # fine too: K and V are weight-stationary, so the attention stage
    # re-encodes activation slices in the array's *weight* format — at
    # FP4 that quantization dominates the stage error by design.
    fine = FpFormat.fp(4, 10)
    entries = [(3, 8, 24),
               {"kind": "attn", "shape": (3, 24, 8), "heads": 2,
                "ctx": None}]
    r = run_model_twin(entries, 8, 8, fine, fine, "gr-unit",
                       Dist("maxent", fine), Dist("maxent", fine), 13,
                       relu=False, fit=False, fixed_enob=30.0)
    for yv, rv in zip(r["y"], r["ref"]):
        assert abs(yv - rv) < 5e-2 * max(1.0, abs(rv)), (yv, rv)
    assert r["e2e_sqnr_db"] > 25.0, r["e2e_sqnr_db"]
    assert r["layers"][1]["softmax_requant_db"] > 25.0
    # sub-GEMM accounting: 2 heads -> 2 QK^T + 2 A·V grids
    assert len(r["layers"][1]["grid"]["grids"]) == 4
    # decode draws ctx*d keys then values and attends over them
    rd = run_model_twin(decode_entries(8, 2, 6), 8, 8, fine, fine,
                        "gr-unit", Dist("maxent", fine),
                        Dist("maxent", fine), 13,
                        relu=False, fit=False, fixed_enob=30.0)
    assert len(rd["y"]) == 8 and math.isfinite(rd["fj_per_token"])
    assert rd["fj_per_token"] == rd["total_fj"]  # one token
    print("attn self-check OK")


def energy_self_check():
    """Pin the energy/tile twins against the Rust unit-test vectors
    (energy::tests, mac::tests::adc_quantize_basics)."""
    assert abs(e_adc(8.0) - 865.536 * 0.81) < 1e-9
    assert abs(e_adc(4.0) - (400.0 + 0.256) * 0.81) < 1e-9
    assert abs(e_dac(4.0) - 50.0 * 4.0 * 0.81) < 1e-12
    assert abs(e_fa() - 6.0 * 0.7 * 0.81) < 1e-12
    assert abs(e_decoder(3.0, 8.0) - 10.5 * 0.7 * 0.81) < 1e-12
    n = 5.0
    assert abs(e_mult(n, n) - (1.5 * 0.7 * 0.81 + e_fa()) * n * n) < 1e-12
    assert adder_tree_fa_count(2, 4.0) == 4.0
    assert adder_tree_fa_count(4, 4.0) == 2.0 * 4.0 + 5.0
    assert adder_tree_fa_count(1, 4.0) == 0.0
    assert adder_tree_fa_count(3, 4.0) == 4.0 + 5.0
    # conventional has no exponent logic; gr-unit does
    fp4 = FpFormat.fp4_e2m1()
    conv = energy_per_op("conventional", fp4, fp4, 32, 32, 8.0)
    assert conv["exp_logic"] == 0.0 and conv["tree"] == 0.0
    assert conv["norm_mult"] == 0.0
    gru = energy_per_op("gr-unit", fp4, fp4, 32, 32, 8.0)
    assert gru["dac"] < conv["dac"]  # mantissa-only DACs
    assert energy_total(gru) > 0.0
    # adc_quantize vectors (mac::tests::adc_quantize_basics)
    assert adc_quantize(0.3, 1.0) == 0.0
    assert adc_quantize(0.6, 1.0) == 1.0
    assert adc_quantize(-0.6, 1.0) == -1.0
    assert abs(adc_quantize(0.123456, 20.0) - 0.123456) < 2e-6
    # native range gates (figures::fig12::tests::native_limits...)
    assert native_ok("gr-unit", FpFormat.fp4_e2m1(), fp4)
    assert native_ok("gr-row", FpFormat.fp(3, 2), fp4)
    assert not native_ok("gr-unit", FpFormat.fp(3, 2), fp4)
    assert not native_ok("gr-row", FpFormat.fp(4, 3), fp4)


def workload_self_check():
    """Pin the EmpDist twin against the Rust unit-test vectors
    (workload::fit doctest: values [-2,-1,0,1,2])."""
    emp = EmpDist([-2.0, -1.0, 0.0, 1.0, 2.0])
    assert emp.scale == 2.0
    assert emp.knots[0] == -1.0 and emp.knots[-1] == 1.0
    assert abs(interp_sorted(emp.knots, 256.0)) < 1e-12  # median 0
    # one rng draw per sample (the contract fill_f32 relies on)
    a, b = Pcg64(7), Pcg64(7)
    emp.sample(a)
    b.next_u64()
    assert a.next_u64() == b.next_u64()
    # dr example from workload::fit tests: 8 binades
    emp2 = EmpDist([1.0, 0.5, 0.25, 2.0 ** -8, -1.0, 0.0])
    assert abs(emp2.dr_bits - 8.0) < 1e-12


def main():
    self_check()
    workload_self_check()
    energy_self_check()
    digital_self_check()
    model_self_check()
    im2col_self_check()
    attn_self_check()
    sampler_self_check()
    outdir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "..", "rust", "tests", "golden")
    os.makedirs(outdir, exist_ok=True)
    gen_table1(outdir)
    gen_fig8(outdir)
    gen_fig9(outdir)
    gen_campaign(outdir)
    gen_workload(outdir)
    gen_layer(outdir)
    gen_model(outdir)
    gen_samples_ci(outdir)
    gen_attention_block(outdir)
    gen_conv_im2col(outdir)
    gen_pareto(outdir)


if __name__ == "__main__":
    main()
