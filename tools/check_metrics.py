#!/usr/bin/env python3
"""Validate a `grcim query metrics` response line against the stable
schema `rust/src/server/metrics.rs::ServerMetrics::to_json` (wrapped by
`CampaignService::metrics_snapshot`) emits:

    {"ok": true, "cached": false, "result": {
       "proto": int,
       "server": {
         "uptime_us": num >= 0, "accepted": num, "open_conns": num,
         "admitted": num, "rejected_busy": num, "rejected_deadline": num,
         "bad_requests": num,
         "queue": {"depth": num, "cap": num > 0, "in_flight": num},
         "kinds": {<kind>: {"ok": num, "errors": num, "count": num,
                            "p50_us": num|null, "p99_us": num|null,
                            "mean_us": num|null, "max_us": num}, ...}},
       "caches": {<cache>: {"entries": num, "hits": num, "misses": num,
                            "computes": num, "coalesced": num,
                            "evictions": num}, ...}}}

CI starts a real server, drives it with `grcim loadgen`, captures one
metrics response, and gates on this script — a schema regression (a
renamed counter, a dropped kind, percentiles that stop being emitted)
fails the pipeline instead of silently breaking dashboards.

`--nonzero PATH` (repeatable) additionally asserts the numeric value at
a dotted path inside `result` is > 0 — CI uses it to pin the loadgen
smoke's observable effects, e.g.:

    python3 tools/check_metrics.py metrics.json \
        --nonzero server.accepted \
        --nonzero server.kinds.energy.ok \
        --nonzero caches.energies.hits

Usage: python3 tools/check_metrics.py <metrics.json> [--nonzero PATH]...
"""

import json
import sys

KINDS = (
    "info", "metrics", "energy", "sweep", "figure", "workload", "layer", "model", "pareto",
)
CACHES = (
    "aggregates", "energies", "sweeps", "figures", "layers", "models", "workloads", "paretos",
)
COUNTERS = (
    "uptime_us",
    "accepted",
    "open_conns",
    "admitted",
    "rejected_busy",
    "rejected_deadline",
    "bad_requests",
)
CACHE_FIELDS = ("entries", "hits", "misses", "computes", "coalesced", "evictions")


def fail(msg):
    print(f"check_metrics: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def number(doc, where, key, minimum=0):
    v = doc.get(key, "missing")
    if not isinstance(v, (int, float)) or isinstance(v, bool) or v < minimum:
        fail(f"{where}: '{key}' must be a number >= {minimum}, got {v!r}")
    return v


def number_or_null(doc, where, key):
    v = doc.get(key, "missing")
    if v is None:
        return None
    if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
        fail(f"{where}: '{key}' must be a non-negative number or null, got {v!r}")
    return v


def check_kind(name, k):
    where = f"server.kinds.{name}"
    if not isinstance(k, dict):
        fail(f"{where}: must be an object")
    ok = number(k, where, "ok")
    errors = number(k, where, "errors")
    count = number(k, where, "count")
    if count != ok + errors:
        fail(f"{where}: count ({count}) != ok + errors ({ok + errors})")
    p50 = number_or_null(k, where, "p50_us")
    p99 = number_or_null(k, where, "p99_us")
    mean = number_or_null(k, where, "mean_us")
    number(k, where, "max_us")
    # percentiles exist exactly when something was measured
    for label, v in (("p50_us", p50), ("p99_us", p99), ("mean_us", mean)):
        if (v is None) != (count == 0):
            fail(f"{where}: '{label}' is {v!r} with count {count}")
    if count > 0 and p99 < p50:
        fail(f"{where}: p99_us ({p99}) < p50_us ({p50})")


def walk(result, path):
    node = result
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            fail(f"--nonzero {path}: no '{part}' at that path")
        node = node[part]
    if not isinstance(node, (int, float)) or isinstance(node, bool) or node <= 0:
        fail(f"--nonzero {path}: expected a number > 0, got {node!r}")


def check(path, nonzero=()):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")
    if not isinstance(doc, dict) or doc.get("ok") is not True:
        fail(f"{path}: not an ok:true response")
    result = doc.get("result")
    if not isinstance(result, dict):
        fail(f"{path}: 'result' must be an object")
    number(result, "result", "proto", minimum=1)

    server = result.get("server")
    if not isinstance(server, dict):
        fail(f"{path}: 'result.server' must be an object")
    for key in COUNTERS:
        number(server, "server", key)
    queue = server.get("queue")
    if not isinstance(queue, dict):
        fail(f"{path}: 'server.queue' must be an object")
    number(queue, "server.queue", "depth")
    number(queue, "server.queue", "cap", minimum=1)
    number(queue, "server.queue", "in_flight")

    kinds = server.get("kinds")
    if not isinstance(kinds, dict):
        fail(f"{path}: 'server.kinds' must be an object")
    for name in KINDS:
        if name not in kinds:
            fail(f"{path}: kind '{name}' missing from server.kinds")
        check_kind(name, kinds[name])
    for name in kinds:
        if name not in KINDS:
            fail(f"{path}: unknown kind '{name}' in server.kinds")

    caches = result.get("caches")
    if not isinstance(caches, dict):
        fail(f"{path}: 'result.caches' must be an object")
    for name in CACHES:
        c = caches.get(name)
        if not isinstance(c, dict):
            fail(f"{path}: cache '{name}' missing from result.caches")
        for field in CACHE_FIELDS:
            number(c, f"caches.{name}", field)
    for name in caches:
        if name not in CACHES:
            fail(f"{path}: unknown cache '{name}' in result.caches")

    for p in nonzero:
        walk(result, p)
    checked = f"{len(KINDS)} kinds, {len(CACHES)} caches, {len(nonzero)} nonzero pins"
    print(f"check_metrics: OK: {path} ({checked})")


def main():
    args = sys.argv[1:]
    nonzero = []
    paths = []
    i = 0
    while i < len(args):
        if args[i] == "--nonzero":
            if i + 1 >= len(args):
                fail("--nonzero needs a dotted path")
            nonzero.append(args[i + 1])
            i += 2
        else:
            paths.append(args[i])
            i += 1
    if len(paths) != 1:
        fail("usage: check_metrics.py <metrics.json> [--nonzero PATH]...")
    check(paths[0], nonzero)


if __name__ == "__main__":
    main()
