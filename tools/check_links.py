#!/usr/bin/env python3
"""Relative-link checker for the repo's markdown docs.

Usage: python3 tools/check_links.py FILE.md [FILE.md ...]

For every markdown link or image `[text](target)` whose target is not an
external URL (http/https/mailto), verify that the referenced file or
directory exists relative to the markdown file. Anchor fragments are
validated against GitHub-style heading slugs — both cross-document
(`other.md#section`) and intra-document (`#section`) forms — including
the `-1`, `-2`, ... suffixes GitHub appends to duplicate headings, with
link markup inside heading text stripped the way GitHub slugifies it.
Exits non-zero listing every broken link.
"""

import os
import re
import sys

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
CODE_FENCE_RE = re.compile(r"^\s*(```|~~~)")
HEADING_LINK_RE = re.compile(r"!?\[([^\]]*)\]\([^)]*\)")


def slugify(text):
    """One heading's GitHub slug (before duplicate numbering)."""
    # links contribute their text, not their target; drop inline
    # code/emphasis markers, then slugify
    text = HEADING_LINK_RE.sub(r"\1", text)
    text = re.sub(r"[`*_]", "", text)
    slug = re.sub(r"[^\w\- ]", "", text.lower())
    return slug.replace(" ", "-")


def heading_slugs(md_path):
    """GitHub-style anchor slugs of every heading in a markdown file,
    with `-N` suffixes for repeated headings (GitHub's disambiguation)."""
    slugs = set()
    seen = {}
    in_fence = False
    with open(md_path, encoding="utf-8") as fh:
        for line in fh:
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence or not line.startswith("#"):
                continue
            slug = slugify(line.lstrip("#").strip())
            n = seen.get(slug, 0)
            seen[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def iter_links(md_path):
    """(lineno, target) for every link outside fenced code blocks."""
    in_fence = False
    with open(md_path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                yield lineno, m.group(1)


def check_file(md_path):
    errors = []
    base = os.path.dirname(os.path.abspath(md_path))
    for lineno, target in iter_links(md_path):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        if not path:  # pure in-page anchor
            if anchor and anchor not in heading_slugs(md_path):
                errors.append((lineno, target, "missing heading anchor"))
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            errors.append((lineno, target, f"missing file {resolved}"))
            continue
        if anchor and path.endswith(".md"):
            if anchor not in heading_slugs(resolved):
                errors.append(
                    (lineno, target, f"missing heading anchor in {path}")
                )
    return errors


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip())
        return 2
    failed = False
    for md in argv[1:]:
        if not os.path.exists(md):
            print(f"{md}: file not found")
            failed = True
            continue
        errors = check_file(md)
        for lineno, target, why in errors:
            print(f"{md}:{lineno}: broken link '{target}' ({why})")
            failed = True
        if not errors:
            print(f"{md}: ok")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
