#!/usr/bin/env python3
"""Validate a benchkit JSON report (e.g. BENCH_hotpath.json) against the
stable schema `rust/src/benchkit.rs::Bench::to_json` emits:

    {
      "mode": "quick" | "full",
      "measurements": [
        {"name": str, "reps": int > 0,
         "min_s": num > 0, "median_s": num > 0, "mean_s": num > 0,
         "items_per_s": num > 0 | null},
        ...
      ]
    }

CI runs the hotpath bench once per push and gates on this script, so a
schema regression (or a bench that silently wrote nothing) fails the
pipeline instead of corrupting the perf trajectory. The committed
pre-first-run placeholder ({"mode": "pending"}) is rejected too — the CI
step validates the freshly written report, not the placeholder.

`--require PREFIX` (repeatable) additionally asserts that at least one
measurement name starts with PREFIX — CI uses it to pin the bench paths
that must not silently drop out of the smoke run (e.g. `model/` for the
model-scale forward pass).

`--baseline FILE --tolerance PCT` turns the schema check into a
throughput regression gate: every measurement whose name appears in both
the report and the baseline (and carries a non-null items_per_s in both)
must reach at least (100 - PCT)% of the baseline throughput. An empty
overlap fails — a renamed bench must not silently skip the gate. The
tolerance absorbs runner-to-runner variance; pick it per pipeline (CI
uses a loose gate that still catches order-of-magnitude regressions).

`--selftest` runs the built-in negative tests (a regressed report must
fail the gate, a healthy one must pass) and exits; CI runs it so the
gate itself is tested on every push.

Usage: python3 tools/check_bench.py BENCH_hotpath.json
           [--require PREFIX]... [--baseline FILE --tolerance PCT]
       python3 tools/check_bench.py --selftest
"""

import json
import sys

NUMERIC_FIELDS = ("min_s", "median_s", "mean_s")


class CheckFailed(Exception):
    pass


def fail(msg):
    raise CheckFailed(f"check_bench: FAIL: {msg}")


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")
    return doc


def check(doc, path, required=()):
    if not isinstance(doc, dict):
        fail(f"{path}: top level must be an object")
    mode = doc.get("mode")
    if mode not in ("quick", "full"):
        fail(f"{path}: mode must be 'quick' or 'full', got {mode!r}")
    ms = doc.get("measurements")
    if not isinstance(ms, list) or not ms:
        fail(f"{path}: 'measurements' must be a non-empty array")
    names = set()
    for i, m in enumerate(ms):
        where = f"{path}: measurements[{i}]"
        if not isinstance(m, dict):
            fail(f"{where}: must be an object")
        name = m.get("name")
        if not isinstance(name, str) or not name:
            fail(f"{where}: 'name' must be a non-empty string")
        if name in names:
            fail(f"{where}: duplicate name {name!r}")
        names.add(name)
        reps = m.get("reps")
        if not isinstance(reps, (int, float)) or reps != int(reps) or reps < 1:
            fail(f"{where} ({name}): 'reps' must be a positive integer")
        for field in NUMERIC_FIELDS:
            v = m.get(field)
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
                fail(f"{where} ({name}): '{field}' must be a positive number")
        if not m["min_s"] <= m["median_s"]:
            fail(f"{where} ({name}): min_s > median_s")
        thr = m.get("items_per_s", "missing")
        if thr == "missing":
            fail(f"{where} ({name}): 'items_per_s' missing (number or null)")
        if thr is not None and (
            not isinstance(thr, (int, float)) or isinstance(thr, bool) or thr <= 0
        ):
            fail(f"{where} ({name}): 'items_per_s' must be positive or null")
    for prefix in required:
        if not any(n.startswith(prefix) for n in names):
            fail(
                f"{path}: no measurement named '{prefix}*' "
                f"(required entry missing from the bench run)"
            )
    print(f"check_bench: OK: {path} ({len(ms)} measurements, {mode} mode)")


def throughputs(doc):
    return {
        m["name"]: m["items_per_s"]
        for m in doc["measurements"]
        if m.get("items_per_s") is not None
    }


def gate(report_doc, report_path, base_doc, base_path, tolerance_pct):
    """Throughput regression gate: common measurements must reach at
    least (100 - tolerance_pct)% of the baseline items_per_s."""
    if not 0.0 <= tolerance_pct < 100.0:
        fail(f"--tolerance must be in [0, 100), got {tolerance_pct}")
    rep = throughputs(report_doc)
    base = throughputs(base_doc)
    common = sorted(set(rep) & set(base))
    if not common:
        fail(
            f"{report_path} vs {base_path}: no common measurement names "
            f"with items_per_s — the regression gate would be vacuous "
            f"(renamed benches must update the committed baseline)"
        )
    floor_frac = 1.0 - tolerance_pct / 100.0
    regressed = []
    for name in common:
        floor = base[name] * floor_frac
        verdict = "ok" if rep[name] >= floor else "REGRESSED"
        print(
            f"check_bench: {verdict}: {name}: {rep[name]:.3e} items/s "
            f"vs baseline {base[name]:.3e} (floor {floor:.3e})"
        )
        if rep[name] < floor:
            regressed.append(name)
    if regressed:
        fail(
            f"{report_path}: {len(regressed)}/{len(common)} measurements "
            f"regressed beyond {tolerance_pct}% of {base_path}: "
            + ", ".join(regressed)
        )
    print(
        f"check_bench: OK: {len(common)} measurements within "
        f"{tolerance_pct}% of baseline {base_path}"
    )


def _mk_report(items_per_s):
    return {
        "mode": "full",
        "measurements": [
            {
                "name": name,
                "reps": 5,
                "min_s": 0.001,
                "median_s": 0.002,
                "mean_s": 0.002,
                "items_per_s": thr,
            }
            for name, thr in items_per_s.items()
        ],
    }


def selftest():
    """Negative tests: the gate must trip on a regressed report and on a
    vacuous (no-overlap) comparison, and pass a healthy report."""
    base = _mk_report({"a/x": 1000.0, "b/y": 500.0, "c/null": None})
    # healthy: within tolerance (10% slower, 20% gate)
    gate(_mk_report({"a/x": 900.0, "b/y": 495.0}), "rep", base, "base", 20.0)
    # regressed: 60% slower must fail a 20% gate
    try:
        gate(_mk_report({"a/x": 400.0, "b/y": 495.0}), "rep", base, "base", 20.0)
    except CheckFailed as e:
        assert "a/x" in str(e) and "regressed" in str(e), e
    else:
        raise AssertionError("regressed report passed the gate")
    # vacuous: disjoint names must fail, not silently pass
    try:
        gate(_mk_report({"z/other": 1.0}), "rep", base, "base", 20.0)
    except CheckFailed as e:
        assert "no common measurement" in str(e), e
    else:
        raise AssertionError("disjoint report passed the gate")
    # schema: the committed placeholder-style doc must be rejected
    try:
        check({"mode": "pending", "measurements": []}, "placeholder")
    except CheckFailed:
        pass
    else:
        raise AssertionError("pending placeholder passed the schema check")
    # schema: a null-throughput entry is legal and excluded from gating
    check(base, "base")
    assert "c/null" not in throughputs(base)
    print("check_bench: selftest OK")


def main():
    args = sys.argv[1:]
    if args == ["--selftest"]:
        selftest()
        return
    required = []
    paths = []
    baseline = None
    tolerance = None
    i = 0
    while i < len(args):
        if args[i] == "--require":
            if i + 1 >= len(args):
                fail("--require needs a prefix")
            required.append(args[i + 1])
            i += 2
        elif args[i] == "--baseline":
            if i + 1 >= len(args):
                fail("--baseline needs a file")
            baseline = args[i + 1]
            i += 2
        elif args[i] == "--tolerance":
            if i + 1 >= len(args):
                fail("--tolerance needs a percentage")
            try:
                tolerance = float(args[i + 1])
            except ValueError:
                fail(f"--tolerance must be a number, got {args[i + 1]!r}")
            i += 2
        else:
            paths.append(args[i])
            i += 1
    if len(paths) != 1 or (baseline is None) != (tolerance is None):
        fail(
            "usage: check_bench.py <bench-report.json> [--require PREFIX]... "
            "[--baseline FILE --tolerance PCT] | check_bench.py --selftest"
        )
    doc = load(paths[0])
    check(doc, paths[0], required)
    if baseline is not None:
        base_doc = load(baseline)
        check(base_doc, baseline)
        gate(doc, paths[0], base_doc, baseline, tolerance)


if __name__ == "__main__":
    try:
        main()
    except CheckFailed as e:
        print(str(e), file=sys.stderr)
        sys.exit(1)
