#!/usr/bin/env python3
"""Validate a benchkit JSON report (e.g. BENCH_hotpath.json) against the
stable schema `rust/src/benchkit.rs::Bench::to_json` emits:

    {
      "mode": "quick" | "full",
      "measurements": [
        {"name": str, "reps": int > 0,
         "min_s": num > 0, "median_s": num > 0, "mean_s": num > 0,
         "items_per_s": num > 0 | null},
        ...
      ]
    }

CI runs the hotpath bench once per push and gates on this script, so a
schema regression (or a bench that silently wrote nothing) fails the
pipeline instead of corrupting the perf trajectory. The committed
pre-first-run placeholder ({"mode": "pending"}) is rejected too — the CI
step validates the freshly written report, not the placeholder.

`--require PREFIX` (repeatable) additionally asserts that at least one
measurement name starts with PREFIX — CI uses it to pin the bench paths
that must not silently drop out of the smoke run (e.g. `model/` for the
model-scale forward pass).

Usage: python3 tools/check_bench.py BENCH_hotpath.json [--require PREFIX]...
"""

import json
import sys

NUMERIC_FIELDS = ("min_s", "median_s", "mean_s")


def fail(msg):
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check(path, required=()):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")
    if not isinstance(doc, dict):
        fail(f"{path}: top level must be an object")
    mode = doc.get("mode")
    if mode not in ("quick", "full"):
        fail(f"{path}: mode must be 'quick' or 'full', got {mode!r}")
    ms = doc.get("measurements")
    if not isinstance(ms, list) or not ms:
        fail(f"{path}: 'measurements' must be a non-empty array")
    names = set()
    for i, m in enumerate(ms):
        where = f"{path}: measurements[{i}]"
        if not isinstance(m, dict):
            fail(f"{where}: must be an object")
        name = m.get("name")
        if not isinstance(name, str) or not name:
            fail(f"{where}: 'name' must be a non-empty string")
        if name in names:
            fail(f"{where}: duplicate name {name!r}")
        names.add(name)
        reps = m.get("reps")
        if not isinstance(reps, (int, float)) or reps != int(reps) or reps < 1:
            fail(f"{where} ({name}): 'reps' must be a positive integer")
        for field in NUMERIC_FIELDS:
            v = m.get(field)
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
                fail(f"{where} ({name}): '{field}' must be a positive number")
        if not m["min_s"] <= m["median_s"]:
            fail(f"{where} ({name}): min_s > median_s")
        thr = m.get("items_per_s", "missing")
        if thr == "missing":
            fail(f"{where} ({name}): 'items_per_s' missing (number or null)")
        if thr is not None and (
            not isinstance(thr, (int, float)) or isinstance(thr, bool) or thr <= 0
        ):
            fail(f"{where} ({name}): 'items_per_s' must be positive or null")
    for prefix in required:
        if not any(n.startswith(prefix) for n in names):
            fail(
                f"{path}: no measurement named '{prefix}*' "
                f"(required entry missing from the bench run)"
            )
    print(f"check_bench: OK: {path} ({len(ms)} measurements, {mode} mode)")


def main():
    args = sys.argv[1:]
    required = []
    paths = []
    i = 0
    while i < len(args):
        if args[i] == "--require":
            if i + 1 >= len(args):
                fail("--require needs a prefix")
            required.append(args[i + 1])
            i += 2
        else:
            paths.append(args[i])
            i += 1
    if len(paths) != 1:
        fail("usage: check_bench.py <bench-report.json> [--require PREFIX]...")
    check(paths[0], required)


if __name__ == "__main__":
    main()
