#!/usr/bin/env python3
"""Benchmark the Python twin's Monte-Carlo hot path and emit a benchkit
schema report (the same JSON shape `cargo bench --bench hotpath` writes,
see tools/check_bench.py).

This exists for two reasons:

1. It gives the repo a real, regenerable `BENCH_hotpath.json` baseline on
   machines without a Rust toolchain. The report carries
   `"source": "python-twin"` and every measurement name is prefixed
   `twin/`, so it can never be confused with (or gated against) cargo
   bench numbers — the regression gate in check_bench.py only compares
   names present in both report and baseline.
2. CI's toolchain-free job regenerates this report and gates it against
   the committed baseline (`check_bench.py --baseline --tolerance`), so
   a hot-path regression in the twin (which gates every golden) fails
   the pipeline.

Measurements cover the stages the Rust hot path mirrors one-to-one: the
batched RNG, the distribution fills, the estimator-mode slab fills
(plain/antithetic/stratified), and the column-MAC signal chain.

Usage: python3 tools/bench_twin.py [--quick] [-o OUT.json]
"""

import importlib.util
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_spec = importlib.util.spec_from_file_location(
    "gen_goldens", os.path.join(_HERE, "gen_goldens.py"))
gg = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gg)


def run(name, reps, items, fn, out):
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    median = times[len(times) // 2]
    out.append({
        "name": name,
        "reps": reps,
        "min_s": times[0],
        "median_s": median,
        "mean_s": sum(times) / len(times),
        "items_per_s": items / median if median > 0 else None,
    })
    print(f"  {name}: {items / median:.3e} items/s "
          f"(median {median * 1e3:.2f} ms over {reps} reps)")


def main():
    quick = "--quick" in sys.argv
    out_path = os.path.join(_HERE, "..", "BENCH_hotpath.json")
    if "-o" in sys.argv:
        out_path = sys.argv[sys.argv.index("-o") + 1]

    reps = 3 if quick else 7
    n = 16_384 if quick else 65_536
    rows, nr = n // 32, 32
    ms = []

    rng = gg.Pcg64(1)
    run("twin/rng/next_u64", reps, n, lambda: [
        rng.next_u64() for _ in range(n)], ms)
    run("twin/rng/normal", reps, n, lambda: [
        rng.normal() for _ in range(n)], ms)

    go = gg.Dist("gauss_outliers")
    run("twin/gen/gauss_outliers_fill", reps, n,
        lambda: gg.fill_f32(go, rng, n), ms)

    clip = gg.Dist("clipped_gauss4")
    for mode in gg.SAMPLER_MODES:
        run(f"twin/sampler/fill_{mode}_nr{nr}", reps, n,
            lambda m=mode: gg.fill_slab_f32(m, clip, rng, n, nr), ms)

    fx, fw = gg.FpFormat.fp(4, 3), gg.FpFormat.fp4_e2m1()
    x = gg.fill_f32(clip, rng, n)
    w = gg.fill_f32(gg.Dist("maxent", fw), rng, n)
    sim_reps = max(2, reps // 2)
    run(f"twin/mac/simulate_column_nr{nr}", sim_reps, rows,
        lambda: gg.simulate_column(x, w, nr, fx, fw), ms)

    # attention block: per-head QK^T/A.V tile GEMMs around the exact
    # digital softmax (mirrors the Rust `model/attn_block` group;
    # throughput in useful MACs/s)
    attn_entries = gg.transformer_entries(16, 2, 1, 4)
    attn_fx = gg.FpFormat.fp(4, 2)
    attn_args = (attn_entries, 8, 8, attn_fx, fw, "gr-unit",
                 gg.Dist("gauss_outliers"), gg.Dist("maxent", fw), 3)
    attn_macs = 0
    for e in attn_entries:
        if isinstance(e, dict) and e.get("kind") == "attn":
            mm, _, d = e["shape"]
            s = e["ctx"] if e.get("ctx") else mm
            attn_macs += 2 * mm * s * d
        else:
            mm, k_, n_ = e if isinstance(e, tuple) else e["shape"]
            attn_macs += mm * k_ * n_
    run("twin/model/attn_block", sim_reps, attn_macs,
        lambda: gg.run_model_twin(*attn_args, relu=False, fit=False), ms)

    # im2col patch flattening alone (mirrors the Rust `tile/im2col`
    # group; throughput in expanded GEMM-operand elements/s)
    cv = (16, 8, 3, 3, 32, 32)
    img = [float(i % 37) * 0.03125 for i in range(gg.conv_img_elems(cv))]
    m_k = (30 * 30) * (8 * 3 * 3)
    run("twin/tile/im2col", reps, m_k,
        lambda: gg.im2col_twin(img, cv), ms)

    doc = {
        "mode": "quick" if quick else "full",
        "source": "python-twin",
        "measurements": ms,
        "note": ("Python-twin hot-path baseline (tools/bench_twin.py); "
                 "names are twin/-prefixed so the regression gate never "
                 "compares them against cargo bench numbers. A toolchain "
                 "machine running `cargo bench --bench hotpath` appends "
                 "the native trajectory under its own names."),
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(ms)} measurements, {doc['mode']} mode)")


if __name__ == "__main__":
    main()
