#!/usr/bin/env python3
"""Emit TensorTrace files (the `grcim workload` input format).

Two sources:

  * synthetic models of LLM tensor statistics (no dependencies):
      - `llm-acts`:    Gaussian core + probability-eps outliers of
                       magnitude ~k*(3 sigma) — the paper's Sec. IV-A
                       model of emergent outlier features (LLM.int8()).
      - `llm-weights`: plain Gaussian, the standard first-order weight
                       model.
  * a real checkpoint tensor, read from a `.npy` file (NumPy format v1/v2,
    little-endian f16/f32/f64, C order) with a pure-stdlib parser — no
    numpy required. Export one from any framework first, e.g.:
      python -c "import numpy, torch; t = torch.load('ckpt.pt')['w']; \\
                 numpy.save('w.npy', t.float().numpy())"

Trace format (matches rust/src/workload/trace.rs):

  magic b"GRTT" | u32 version=1 | u32 header_len | JSON header
  {"name","dtype":"f32"|"f64","shape":[...]} | little-endian payload

Examples:

  python3 tools/export_trace.py llm-acts   --n 65536 --out acts.grtt
  python3 tools/export_trace.py llm-weights --n 16384 --out w.grtt
  python3 tools/export_trace.py from-npy   --npy layer0.npy --out l0.grtt

Then:  grcim workload --trace acts.grtt
"""

import argparse
import ast
import json
import math
import random
import struct
import sys


def write_trace(path, name, shape, values, dtype="f32"):
    """Write one binary TensorTrace file."""
    count = 1
    for d in shape:
        count *= d
    assert count == len(values), f"shape {shape} vs {len(values)} values"
    for i, v in enumerate(values):
        if not math.isfinite(v):
            raise SystemExit(f"non-finite value {v} at index {i}")
    header = json.dumps(
        {"name": name, "dtype": dtype, "shape": list(shape)},
        separators=(",", ":"),
        sort_keys=True,
    ).encode("utf-8")
    fmt = {"f32": "<f", "f64": "<d"}[dtype]
    with open(path, "wb") as fh:
        fh.write(b"GRTT")
        fh.write(struct.pack("<I", 1))
        fh.write(struct.pack("<I", len(header)))
        fh.write(header)
        for v in values:
            fh.write(struct.pack(fmt, v))
    print(f"wrote {path}: '{name}' shape={list(shape)} dtype={dtype} "
          f"({len(values)} values)")


def gen_llm_acts(n, seed, eps=0.01, k=50.0):
    """Gaussian core (sigma = 1/(3k)) + eps outliers in [0.5, 1]*sign —
    the paper's Gaussian+outliers activation model, in raw units scaled
    to a realistic activation magnitude."""
    rng = random.Random(seed)
    amax = 12.0  # typical pre-norm activation max magnitude
    out = []
    sigma = 1.0 / (3.0 * k)
    for _ in range(n):
        if rng.random() < eps:
            v = rng.choice([-1.0, 1.0]) * rng.uniform(0.5, 1.0)
        else:
            v = max(-1.0, min(1.0, rng.gauss(0.0, sigma)))
        out.append(v * amax)
    return out


def gen_llm_weights(n, seed, sigma=0.02):
    """Plain Gaussian weight model (typical transformer init scale)."""
    rng = random.Random(seed)
    return [rng.gauss(0.0, sigma) for _ in range(n)]


def read_npy(path):
    """Parse a .npy file (format v1/v2) without numpy. Returns
    (shape, values, dtype) for little-endian f16/f32/f64 C-order arrays,
    where dtype is the matching trace dtype ("f32" for f16/f32 sources,
    "f64" for f64 — no silent narrowing)."""
    with open(path, "rb") as fh:
        magic = fh.read(6)
        if magic != b"\x93NUMPY":
            raise SystemExit(f"{path}: not a .npy file")
        major, _minor = struct.unpack("<BB", fh.read(2))
        if major == 1:
            (hlen,) = struct.unpack("<H", fh.read(2))
        elif major in (2, 3):
            (hlen,) = struct.unpack("<I", fh.read(4))
        else:
            raise SystemExit(f"{path}: unsupported .npy version {major}")
        header = ast.literal_eval(fh.read(hlen).decode("latin1"))
        descr = header["descr"]
        if header.get("fortran_order"):
            raise SystemExit(f"{path}: Fortran-order arrays not supported")
        widths = {
            "<f2": ("<e", 2, "f32"),
            "<f4": ("<f", 4, "f32"),
            "<f8": ("<d", 8, "f64"),
        }
        if descr not in widths:
            raise SystemExit(
                f"{path}: dtype {descr} not supported (need <f2/<f4/<f8)")
        fmt, size, trace_dtype = widths[descr]
        shape = list(header["shape"]) or [1]
        count = 1
        for d in shape:
            count *= d
        raw = fh.read(count * size)
        if len(raw) != count * size:
            raise SystemExit(f"{path}: truncated payload")
        values = [v[0] for v in struct.iter_unpack(fmt, raw)]
        return shape, values, trace_dtype


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="mode", required=True)

    acts = sub.add_parser("llm-acts", help="synthetic LLM activations "
                          "(Gaussian core + emergent outliers)")
    acts.add_argument("--n", type=int, default=65536)
    acts.add_argument("--seed", type=int, default=1)
    acts.add_argument("--eps", type=float, default=0.01,
                      help="outlier probability (paper: 0.01)")
    acts.add_argument("--k", type=float, default=50.0,
                      help="outlier relative magnitude (paper: 50)")
    acts.add_argument("--name", default="llm-acts")
    acts.add_argument("--out", required=True)

    w = sub.add_parser("llm-weights", help="synthetic Gaussian weights")
    w.add_argument("--n", type=int, default=16384)
    w.add_argument("--seed", type=int, default=2)
    w.add_argument("--sigma", type=float, default=0.02)
    w.add_argument("--name", default="llm-weights")
    w.add_argument("--out", required=True)

    npy = sub.add_parser("from-npy", help="convert a real checkpoint "
                         "tensor exported as .npy")
    npy.add_argument("--npy", required=True)
    npy.add_argument("--name", default=None,
                     help="trace name (default: the .npy filename)")
    npy.add_argument("--out", required=True)

    args = ap.parse_args()
    if args.mode == "llm-acts":
        vals = gen_llm_acts(args.n, args.seed, args.eps, args.k)
        write_trace(args.out, args.name, [args.n], vals)
    elif args.mode == "llm-weights":
        vals = gen_llm_weights(args.n, args.seed, args.sigma)
        write_trace(args.out, args.name, [args.n], vals)
    elif args.mode == "from-npy":
        shape, vals, dtype = read_npy(args.npy)
        name = args.name or args.npy.rsplit("/", 1)[-1]
        write_trace(args.out, name, shape, vals, dtype=dtype)
    return 0


if __name__ == "__main__":
    sys.exit(main())
