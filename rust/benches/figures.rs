//! `cargo bench --bench figures` — one benchmark per paper table/figure:
//! wall time of the full regeneration pipeline (workload generation, MC
//! engine, spec solve, energy model, report emit) at reduced sample count,
//! plus one full-samples fig10 point as the end-to-end latency anchor.
//!
//! Uses the in-repo `benchkit` harness (no criterion in the vendor set).
//! Set GRCIM_BENCH_QUICK=1 for smoke runs; pass a substring to filter.

use grcim::benchkit::Bench;
use grcim::figures::{self, FigureCtx};
use grcim::runtime::EngineKind;

fn ctx(samples: usize) -> FigureCtx {
    let mut ctx = FigureCtx::default();
    ctx.samples = samples;
    ctx.campaign.engine = EngineKind::Rust;
    ctx.out_dir = std::env::temp_dir().join("grcim_bench_results");
    ctx
}

fn main() {
    let mut b = Bench::new();
    let quick = ctx(4096);

    for id in ["fig4", "table1", "fig8", "fig9"] {
        b.run(&format!("figure/{id}"), 5, || {
            let fr = figures::run(id, &quick).unwrap();
            assert!(fr.all_hold());
        });
    }
    for id in ["fig10", "fig11", "ablations"] {
        b.run(&format!("figure/{id}"), 3, || {
            let fr = figures::run(id, &quick).unwrap();
            assert!(fr.all_hold());
        });
    }
    b.run("figure/fig12", 2, || {
        let fr = figures::run("fig12", &quick).unwrap();
        assert!(fr.all_hold());
    });

    // end-to-end anchor: one fig10 sweep at full default samples via the
    // PJRT engine when compiled in (--features pjrt) and artifacts exist
    // (the production configuration)
    #[cfg(feature = "pjrt")]
    if grcim::runtime::ArtifactRegistry::load(
        &grcim::runtime::ArtifactRegistry::default_dir(),
    )
    .is_ok()
    {
        let mut full = ctx(65_536);
        full.campaign.engine = EngineKind::Pjrt;
        b.run("figure/fig10_full_pjrt", 2, || {
            let fr = figures::run("fig10", &full).unwrap();
            assert!(fr.all_hold());
        });
    }

    b.finish();
}
