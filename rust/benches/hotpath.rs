//! `cargo bench --bench hotpath` — micro/meso benchmarks of the hot paths
//! the §Perf pass optimizes: the pure-Rust MC engine, the PJRT engine
//! (artifact execution), the quantizer, campaign scheduling overhead, the
//! analog solver, and the NN e2e tile path. Throughputs are in MAC
//! samples/s (one sample = one NR-deep column MAC).

use grcim::benchkit::Bench;
use grcim::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
use grcim::distributions::Distribution;
use grcim::formats::FpFormat;
use grcim::mac::{simulate_column, FormatPair};
use grcim::rng::Pcg64;
use grcim::runtime::{ArtifactRegistry, Engine, EngineKind, PjrtEngine, RustEngine};

fn main() {
    let mut b = Bench::new();
    let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
    let nr = 32;
    let batch = 2048;

    // input generation
    let mut rng = Pcg64::seeded(1);
    let mut xf = vec![0.0f64; batch * nr];
    let mut wf = vec![0.0f64; batch * nr];
    b.run_items("gen/gauss_outliers_fill", 20, batch * nr, || {
        Distribution::gauss_outliers().fill(&mut rng, &mut xf);
    });
    Distribution::Uniform.fill(&mut rng, &mut wf);

    // quantizer alone
    let fmt = FpFormat::fp6_e2m3();
    b.run_items("formats/quantize_64k", 20, 65_536, || {
        let mut acc = 0.0;
        for i in 0..65_536 {
            acc += fmt.quantize(xf[i % xf.len()]);
        }
        std::hint::black_box(acc);
    });

    // pure-Rust engine, single batch
    b.run_items("engine/rust_simulate_2048x32", 10, batch, || {
        std::hint::black_box(simulate_column(&xf, &wf, nr, fmts));
    });

    // engine trait path (includes f32->f64 conversion)
    let re = RustEngine;
    let x32: Vec<f32> = xf.iter().map(|&v| v as f32).collect();
    let w32: Vec<f32> = wf.iter().map(|&v| v as f32).collect();
    b.run_items("engine/rust_trait_2048x32", 10, batch, || {
        std::hint::black_box(re.simulate(&x32, &w32, nr, fmts).unwrap());
    });

    // PJRT engine (the production path)
    if let Ok(reg) = ArtifactRegistry::load(&ArtifactRegistry::default_dir()) {
        let pjrt = PjrtEngine::from_registry(&reg).unwrap();
        b.run_items("engine/pjrt_simulate_2048x32", 10, batch, || {
            std::hint::black_box(pjrt.simulate(&x32, &w32, nr, fmts).unwrap());
        });
        for depth in [16usize, 64, 128] {
            if pjrt.supports_nr(depth) {
                let n = batch * depth;
                let xd = vec![0.25f32; n];
                let wd = vec![0.5f32; n];
                b.run_items(
                    &format!("engine/pjrt_simulate_2048x{depth}"),
                    5,
                    batch,
                    || {
                        std::hint::black_box(
                            pjrt.simulate(&xd, &wd, depth, fmts).unwrap(),
                        );
                    },
                );
            }
        }
    }

    // campaign throughput: 16 batches across the pool (scheduling +
    // aggregation overhead on top of the raw engine)
    let spec = ExperimentSpec {
        id: "bench".into(),
        fmts,
        dist_x: Distribution::Uniform,
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr,
        samples: 16 * batch,
    };
    let cfg = CampaignConfig {
        engine: EngineKind::Rust,
        workers: 0,
        seed: 3,
        ..Default::default()
    };
    b.run_items("coordinator/campaign_16x2048", 5, 16 * batch, || {
        std::hint::black_box(run_campaign(&[spec.clone()], &cfg).unwrap());
    });

    // analog substrate: full mismatch MC of Fig. 8
    let cell = grcim::analog::GrMacCell::fp6_e2m3_schematic();
    b.run_items("analog/mismatch_mc_1000", 5, 1000, || {
        std::hint::black_box(grcim::analog::mismatch::mc_dnl_inl(
            &cell,
            grcim::analog::MismatchModel::high(),
            1000,
            9,
        ));
    });

    // capnet nodal solve (2 floating nodes)
    b.run_items("analog/capnet_solve_16k", 5, 16_384, || {
        for _ in 0..16_384 {
            std::hint::black_box(cell.transfer(9, 3, 1.0).unwrap());
        }
    });

    b.finish();
}
