//! `cargo bench --bench hotpath` — micro/meso benchmarks of the hot paths
//! the §Perf pass optimizes: the pure-Rust MC engine (allocating and
//! chunked allocation-free variants), the PJRT engine when compiled in
//! (`--features pjrt`) and artifacts exist, the quantizer, campaign
//! scheduling overhead, the analog solver. Throughputs are in MAC
//! samples/s (one sample = one NR-deep column MAC).
//!
//! The run is persisted to `BENCH_hotpath.json` (override the path with
//! `GRCIM_BENCH_JSON=...`) via the in-repo benchkit JSON schema, so the
//! perf trajectory is comparable across PRs.
//!
//! A counting global allocator verifies the chunked `simulate_column_into`
//! path performs **zero** heap allocations per batch in steady state.

use grcim::benchkit::Bench;
use grcim::coordinator::{
    run_campaign, CampaignConfig, ExperimentSpec, JobBuffers,
};
use grcim::distributions::Distribution;
use grcim::formats::FpFormat;
use grcim::mac::{simulate_column, simulate_column_into, FormatPair};
use grcim::rng::Pcg64;
use grcim::runtime::{Engine, EngineKind, RustEngine, SimScratch};
use grcim::stats::ColumnBatch;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counts heap allocations so the zero-allocation claim of the chunked
/// path is measured, not assumed.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

fn main() {
    let mut b = Bench::new();
    let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
    let nr = 32;
    let batch = 2048;

    // input generation
    let mut rng = Pcg64::seeded(1);
    let mut xf = vec![0.0f64; batch * nr];
    let mut wf = vec![0.0f64; batch * nr];
    b.run_items("gen/gauss_outliers_fill", 20, batch * nr, || {
        Distribution::gauss_outliers().fill(&mut rng, &mut xf);
    });
    Distribution::Uniform.fill(&mut rng, &mut wf);

    // batched RNG primitives (the vector path under every fill; the
    // leapfrog interleave is bit-exact with the sequential stream)
    let mut u64buf = vec![0u64; 65_536];
    b.run_items("rng/fill_u64_64k", 20, 65_536, || {
        rng.fill_u64(&mut u64buf);
        std::hint::black_box(u64buf[0]);
    });
    let mut nbuf = vec![0.0f64; 65_536];
    b.run_items("rng/fill_normal_64k", 20, 65_536, || {
        rng.fill_normal(&mut nbuf);
        std::hint::black_box(nbuf[0]);
    });

    // estimator-mode slab fills (the --sampler hot path; throughput in
    // slab elements/s). Stratified allocates its stratum permutations,
    // so it stays outside the zero-allocation assertions below.
    let mut slab = vec![0.0f32; batch * nr];
    let clip = Distribution::clipped_gauss4();
    for sampler in grcim::distributions::Sampler::ALL {
        b.run_items(
            &format!("sampler/fill_{}_2048x32", sampler.name()),
            10,
            batch * nr,
            || {
                sampler.fill_slab_f32(&clip, &mut rng, &mut slab, nr);
                std::hint::black_box(slab[0]);
            },
        );
    }

    // quantizer alone
    let fmt = FpFormat::fp6_e2m3();
    b.run_items("formats/quantize_64k", 20, 65_536, || {
        let mut acc = 0.0;
        for i in 0..65_536 {
            acc += fmt.quantize(xf[i % xf.len()]);
        }
        std::hint::black_box(acc);
    });

    // pure-Rust engine, single batch (allocating baseline)
    b.run_items("engine/rust_simulate_2048x32", 10, batch, || {
        std::hint::black_box(simulate_column(&xf, &wf, nr, fmts));
    });

    // chunked allocation-free path: same math through a reused batch
    let mut out = ColumnBatch::empty(nr);
    simulate_column_into(&xf, &wf, nr, fmts, &mut out); // warm capacities
    b.run_items("engine/rust_simulate_into_2048x32", 10, batch, || {
        simulate_column_into(&xf, &wf, nr, fmts, &mut out);
        std::hint::black_box(out.len());
    });

    // measured zero-allocation guarantee of the steady-state inner loop
    let inner_batches = 5u64;
    let before = allocs();
    for _ in 0..inner_batches {
        simulate_column_into(&xf, &wf, nr, fmts, &mut out);
    }
    let delta = allocs() - before;
    println!(
        "engine/rust_simulate_into_2048x32: {delta} heap allocations over \
         {inner_batches} steady-state batches"
    );
    assert_eq!(
        delta, 0,
        "chunked simulate_column_into must not allocate in steady state"
    );

    // engine trait path (includes f32->f64 conversion)
    let re = RustEngine;
    let x32: Vec<f32> = xf.iter().map(|&v| v as f32).collect();
    let w32: Vec<f32> = wf.iter().map(|&v| v as f32).collect();
    b.run_items("engine/rust_trait_2048x32", 10, batch, || {
        std::hint::black_box(re.simulate(&x32, &w32, nr, fmts).unwrap());
    });

    // trait buffered path: reusable scratch + batch, also allocation-free
    let mut scratch = SimScratch::default();
    re.simulate_into(&x32, &w32, nr, fmts, &mut scratch, &mut out).unwrap();
    b.run_items("engine/rust_trait_into_2048x32", 10, batch, || {
        re.simulate_into(&x32, &w32, nr, fmts, &mut scratch, &mut out)
            .unwrap();
        std::hint::black_box(out.len());
    });
    let before = allocs();
    for _ in 0..inner_batches {
        re.simulate_into(&x32, &w32, nr, fmts, &mut scratch, &mut out)
            .unwrap();
    }
    let delta = allocs() - before;
    println!(
        "engine/rust_trait_into_2048x32: {delta} heap allocations over \
         {inner_batches} steady-state batches"
    );
    assert_eq!(delta, 0, "trait simulate_into must not allocate in steady state");

    // PJRT engine (the production path, --features pjrt + artifacts)
    #[cfg(feature = "pjrt")]
    {
        use grcim::runtime::{ArtifactRegistry, PjrtEngine};
        if let Ok(reg) =
            ArtifactRegistry::load(&ArtifactRegistry::default_dir())
        {
            match PjrtEngine::from_registry(&reg) {
                Ok(pjrt) => {
                    b.run_items("engine/pjrt_simulate_2048x32", 10, batch, || {
                        std::hint::black_box(
                            pjrt.simulate(&x32, &w32, nr, fmts).unwrap(),
                        );
                    });
                    for depth in [16usize, 64, 128] {
                        if pjrt.supports_nr(depth) {
                            let n = batch * depth;
                            let xd = vec![0.25f32; n];
                            let wd = vec![0.5f32; n];
                            b.run_items(
                                &format!("engine/pjrt_simulate_2048x{depth}"),
                                5,
                                batch,
                                || {
                                    std::hint::black_box(
                                        pjrt.simulate(&xd, &wd, depth, fmts)
                                            .unwrap(),
                                    );
                                },
                            );
                        }
                    }
                }
                Err(e) => eprintln!("pjrt benches skipped: {e}"),
            }
        }
    }

    // campaign throughput: 16 batches across the pool (scheduling +
    // aggregation overhead on top of the raw engine)
    let spec = ExperimentSpec {
        id: "bench".into(),
        fmts,
        dist_x: Distribution::Uniform,
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr,
        samples: 16 * batch,
        sampler: Default::default(),
    };
    let cfg = CampaignConfig {
        engine: EngineKind::Rust,
        workers: 0,
        seed: 3,
        ..Default::default()
    };
    b.run_items("coordinator/campaign_16x2048", 5, 16 * batch, || {
        std::hint::black_box(run_campaign(&[spec.clone()], &cfg).unwrap());
    });

    // single worker-style buffered job loop (what each pool thread runs)
    let mut bufs = JobBuffers::default();
    grcim::coordinator::run_job_buffered(&re, &spec, 3, 0, 0, batch, &mut bufs)
        .unwrap();
    b.run_items("coordinator/job_buffered_2048x32", 10, batch, || {
        std::hint::black_box(
            grcim::coordinator::run_job_buffered(
                &re, &spec, 3, 0, 1, batch, &mut bufs,
            )
            .unwrap(),
        );
    });

    // model-scale chained path: a 3-layer MLP through per-layer
    // requantization + tile grids + the worker pool (the `grcim model`
    // hot path; throughput in useful MACs/s)
    let mut mspec = grcim::model::ModelSpec::preset("mlp:64x48x32", 4).unwrap();
    mspec.cfg.nr = 16;
    mspec.cfg.nc = 8;
    let mcfg = CampaignConfig {
        engine: EngineKind::Rust,
        workers: 0,
        seed: 3,
        ..Default::default()
    };
    b.run_items("model/forward_mlp3", 5, mspec.macs() as usize, || {
        std::hint::black_box(grcim::model::run_model(&mspec, &mcfg).unwrap());
    });

    // attention block: per-head QK^T/A.V tile GEMMs around the exact
    // digital softmax + the second calibration point (the `transformer:`
    // preset hot path; throughput in useful MACs/s)
    let mut aspec = grcim::model::ModelSpec::preset("transformer:32x2x1", 4).unwrap();
    aspec.cfg.nr = 16;
    aspec.cfg.nc = 8;
    b.run_items("model/attn_block", 5, aspec.macs() as usize, || {
        std::hint::black_box(grcim::model::run_model(&aspec, &mcfg).unwrap());
    });

    // im2col patch flattening alone (the conv-layer prologue; throughput
    // in expanded GEMM-operand elements/s)
    let cs = grcim::tile::ConvShape::parse("conv:16x8x3x3@32x32").unwrap();
    let img: Vec<f32> = (0..cs.img_elems()).map(|i| (i % 37) as f32 * 0.03125).collect();
    let expanded = cs.gemm_shape().m * cs.gemm_shape().k;
    b.run_items("tile/im2col", 10, expanded, || {
        std::hint::black_box(grcim::tile::im2col(&img, &cs).len());
    });

    // analog substrate: full mismatch MC of Fig. 8
    let cell = grcim::analog::GrMacCell::fp6_e2m3_schematic();
    b.run_items("analog/mismatch_mc_1000", 5, 1000, || {
        std::hint::black_box(grcim::analog::mismatch::mc_dnl_inl(
            &cell,
            grcim::analog::MismatchModel::high(),
            1000,
            9,
        ));
    });

    // capnet nodal solve (2 floating nodes)
    b.run_items("analog/capnet_solve_16k", 5, 16_384, || {
        for _ in 0..16_384 {
            std::hint::black_box(cell.transfer(9, 3, 1.0).unwrap());
        }
    });

    b.finish();

    // Persist the run. The default baseline path is only written by full,
    // unfiltered runs so a quick smoke or a name-filtered run never
    // clobbers the committed perf trajectory; set GRCIM_BENCH_JSON to
    // force a write anywhere.
    let explicit = std::env::var("GRCIM_BENCH_JSON").ok();
    let quick = std::env::var("GRCIM_BENCH_QUICK").is_ok();
    let filtered = std::env::args()
        .skip(1)
        .any(|a| !a.starts_with('-') && a != "--bench");
    let path = match explicit {
        Some(p) => p,
        None if quick || filtered => {
            println!(
                "not writing BENCH_hotpath.json (quick/filtered run); \
                 set GRCIM_BENCH_JSON=path to record this run"
            );
            return;
        }
        None => "BENCH_hotpath.json".to_string(),
    };
    match b.save_json(std::path::Path::new(&path)) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
