//! Offline stub of the `xla` crate API surface used by `grcim`'s PJRT
//! backend (`rust/src/runtime/pjrt.rs`).
//!
//! The real `xla` bindings wrap a native XLA/PJRT toolchain that is not
//! part of this repository's vendor set. This stub keeps the PJRT code
//! paths *compiling* under `--features pjrt` while failing cleanly at
//! runtime: [`PjRtClient::cpu`] returns an error, so `EngineKind::Auto`
//! falls back to the pure-Rust oracle and `EngineKind::Pjrt` reports a
//! clear message. To execute AOT artifacts for real, replace the
//! `xla = { path = "xla-stub" }` dependency in `rust/Cargo.toml` with the
//! real bindings (same API surface).

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's (only Display is relied upon).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime not available in this build (offline \
         `xla` stub — point rust/Cargo.toml's `xla` dependency at the real \
         bindings to execute artifacts)"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal (stub).
#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
    }
}
