//! Named layer shapes — the transformer GEMMs the trace pipeline (PR 3)
//! and the paper's LLM motivation care about, resolvable from one CLI /
//! wire string.

use super::im2col::ConvShape;
use super::GemmShape;
use anyhow::{bail, Context, Result};

/// The named shape kinds `parse_shape` accepts (plus `gemm:<M>x<K>x<N>`
/// and `conv:<Cout>x<Cin>x<kH>x<kW>@<H>x<W>`).
pub const NAMED_SHAPES: &[&str] = &["mlp-up", "mlp-down", "qkv", "attn-out"];

/// Largest accepted single GEMM dimension (2^20). Bounds every parsed
/// shape so `M·K·N` fits a `u64` without overflow (2^60 max) — the
/// serve layer's MAC cap relies on [`GemmShape::macs`] not wrapping —
/// and so operand-slab sizes stay well inside `usize`.
pub const MAX_DIM: usize = 1 << 20;

fn scaled(d: usize, factor: usize, what: &str) -> Result<usize> {
    d.checked_mul(factor).with_context(|| format!("{what}: d_model {d} is too large"))
}

fn bounded(shape: GemmShape, s: &str) -> Result<GemmShape> {
    if shape.m > MAX_DIM || shape.k > MAX_DIM || shape.n > MAX_DIM {
        bail!("shape '{s}': dimensions must be <= {MAX_DIM}");
    }
    Ok(shape)
}

/// Parse a `--shape` / wire `shape` value into a [`GemmShape`]:
///
/// | value | GEMM |
/// |---|---|
/// | `mlp-up:<d>` | `[tokens×d]·[d×4d]` (FFN up-projection) |
/// | `mlp-down:<d>` | `[tokens×4d]·[4d×d]` (FFN down-projection) |
/// | `qkv:<d>` | `[tokens×d]·[d×3d]` (fused attention QKV) |
/// | `attn-out:<d>` | `[tokens×d]·[d×d]` (attention output projection) |
/// | `gemm:<M>x<K>x<N>` | explicit dimensions (`tokens` is ignored) |
/// | `conv:<Cout>x<Cin>x<kH>x<kW>@<H>x<W>` | the im2col-flattened GEMM (`tokens` is ignored) |
///
/// `tokens` is the batch dimension M of the named shapes. A `conv:`
/// value resolves to its flattened `[Ho·Wo × Cin·kH·kW]·[… × Cout]`
/// geometry ([`ConvShape::gemm_shape`]); callers that need the conv
/// operand layout itself parse the [`ConvShape`] instead.
pub fn parse_shape(s: &str, tokens: usize) -> Result<GemmShape> {
    if tokens == 0 {
        bail!("tokens must be positive");
    }
    let (kind, arg) = s.split_once(':').with_context(|| {
        format!(
            "shape '{s}' must be '<kind>:<d_model>' ({}) or 'gemm:<M>x<K>x<N>'",
            NAMED_SHAPES.join("|")
        )
    })?;
    if kind == "gemm" {
        let dims: Vec<usize> = arg
            .split('x')
            .map(|d| {
                d.parse::<usize>()
                    .with_context(|| format!("shape '{s}': '{d}' is not a dimension"))
            })
            .collect::<Result<_>>()?;
        let &[m, k, n] = dims.as_slice() else {
            bail!("shape '{s}': gemm needs exactly three dimensions, 'gemm:<M>x<K>x<N>'");
        };
        if m == 0 || k == 0 || n == 0 {
            bail!("shape '{s}': dimensions must be positive");
        }
        return bounded(GemmShape { m, k, n }, s);
    }
    if kind == "conv" {
        return Ok(ConvShape::parse_args(arg, s)?.gemm_shape());
    }
    let d: usize = arg
        .parse()
        .with_context(|| format!("shape '{s}': '{arg}' is not a d_model"))?;
    if d == 0 {
        bail!("shape '{s}': d_model must be positive");
    }
    let (k, n) = match kind {
        "mlp-up" => (d, scaled(d, 4, s)?),
        "mlp-down" => (scaled(d, 4, s)?, d),
        "qkv" => (d, scaled(d, 3, s)?),
        "attn-out" => (d, d),
        other => bail!(
            "unknown shape kind '{other}' ({}, or gemm:<M>x<K>x<N>)",
            NAMED_SHAPES.join("|")
        ),
    };
    bounded(GemmShape { m: tokens, k, n }, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_shapes_resolve() {
        assert_eq!(parse_shape("mlp-up:64", 4).unwrap(), GemmShape { m: 4, k: 64, n: 256 });
        assert_eq!(parse_shape("mlp-down:64", 2).unwrap(), GemmShape { m: 2, k: 256, n: 64 });
        assert_eq!(parse_shape("qkv:128", 1).unwrap(), GemmShape { m: 1, k: 128, n: 384 });
        assert_eq!(parse_shape("attn-out:32", 8).unwrap(), GemmShape { m: 8, k: 32, n: 32 });
    }

    #[test]
    fn explicit_gemm_ignores_tokens() {
        assert_eq!(parse_shape("gemm:3x40x40", 99).unwrap(), GemmShape { m: 3, k: 40, n: 40 });
    }

    #[test]
    fn conv_shapes_resolve_to_their_flattened_gemm() {
        assert_eq!(parse_shape("conv:6x3x3x3@8x8", 99).unwrap(), GemmShape { m: 36, k: 27, n: 6 });
        // 1x1 kernel: the flattened GEMM is the plain per-pixel GEMM
        assert_eq!(parse_shape("conv:4x3x1x1@5x7", 4).unwrap(), GemmShape { m: 35, k: 3, n: 4 });
        assert!(parse_shape("conv:6x3x9x3@8x8", 4).is_err());
    }

    #[test]
    fn malformed_shapes_are_clean_errors() {
        for bad in [
            "mlp-up",          // no dims
            "mlp-up:",         // empty d
            "mlp-up:abc",      // non-numeric
            "mlp-up:0",        // zero d
            "conv2d:64",       // unknown kind
            "gemm:4x8",        // missing dim
            "gemm:4x8x0",      // zero dim
            "gemm:4x8x8x8",    // extra dim
        ] {
            assert!(parse_shape(bad, 4).is_err(), "{bad}");
        }
        // tokens must be positive for named shapes
        assert!(parse_shape("mlp-up:64", 0).is_err());
    }

    #[test]
    fn oversized_dimensions_are_rejected_not_wrapped() {
        // a crafted gemm: shape must not wrap GemmShape::macs past the
        // serve layer's MAC cap
        let big = (MAX_DIM + 1).to_string();
        assert!(parse_shape(&format!("gemm:{big}x8x8"), 4).is_err());
        assert!(parse_shape(&format!("gemm:8x{big}x8"), 4).is_err());
        assert!(parse_shape(&format!("gemm:8x8x{big}"), 4).is_err());
        assert!(parse_shape("gemm:4294967296x4294967296x4294967296", 4).is_err());
        assert!(parse_shape(&format!("mlp-up:{big}"), 4).is_err());
        assert!(parse_shape("mlp-up:64", MAX_DIM + 1).is_err());
        // the boundary itself is fine
        assert!(parse_shape(&format!("gemm:1x1x{MAX_DIM}"), 4).is_ok());
    }
}
