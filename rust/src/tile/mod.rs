//! Tiled array mapper — layer-scale GEMM on GR-MAC tiles (paper Sec. V
//! outlook; the macro-level view IMAGINE and AFPR-CIM take of their
//! arrays).
//!
//! The column simulator ([`crate::mac`]) prices one N_R-deep MAC; real
//! workloads execute `[M×K]·[K×N]` GEMMs. This module closes the gap:
//!
//! * [`GemmShape`] / [`shapes::parse_shape`] — layer geometry, including
//!   named transformer shapes (`mlp-up:<d_model>`, `qkv:<d_model>`, …);
//! * [`TileConfig`] — the physical array: rows per column N_R
//!   (accumulation depth), columns per tile N_C, formats, architecture
//!   ([`CimArch`]), ADC policy, and the Table III technology parameters;
//! * [`mapper`] — partitions the GEMM into a `row_tiles × col_tiles` grid
//!   of weight-stationary tiles, routes every tile through the existing
//!   signal chain via [`crate::runtime::Engine::simulate_into`] scratch
//!   buffers (allocation-free in steady state), digitizes each column at
//!   the tile's ADC resolution, and reduces partial sums across row tiles
//!   with a digital shift-add tree;
//! * [`LayerReport`] — per-tile ENOB + energy ([`crate::energy::arch`]
//!   composition), layer-level totals (fJ/MAC, fJ/Op), the layer-output
//!   SQNR against the exact float GEMM, and an ADC-resolution histogram
//!   across tiles.
//!
//! Consumers: the model-scale executor ([`crate::model::exec`]) chains
//! whole networks of these layers — [`crate::nn::cim_forward_batch`]
//! reaches [`mapper::gemm_outputs`] (the no-reference fast path of
//! [`mapper::gemm_with_engine`]) through it; `grcim layer` and the serve
//! layer's `layer` request evaluate named layer shapes via
//! [`mapper::run_layer`], which shards tile jobs across the coordinator's
//! worker pool (bit-identical results at any worker count).
//!
//! # Example
//!
//! ```
//! use grcim::energy::{CimArch, TechParams};
//! use grcim::formats::FpFormat;
//! use grcim::mac::FormatPair;
//! use grcim::runtime::RustEngine;
//! use grcim::tile::{gemm_with_engine, AdcPolicy, GemmShape, TileConfig};
//!
//! // a tiny GEMM on 8x4 tiles with a generous fixed ADC
//! let shape = GemmShape { m: 2, k: 16, n: 6 };
//! let cfg = TileConfig {
//!     nr: 8,
//!     nc: 4,
//!     fmts: FormatPair::new(FpFormat::fp(4, 6), FpFormat::fp(4, 6)),
//!     arch: CimArch::GrUnit,
//!     adc: AdcPolicy::Fixed(20.0),
//!     tech: TechParams::default(),
//! };
//! let x = vec![0.25f32; shape.m * shape.k];
//! let wt = vec![0.5f32; shape.n * shape.k];
//! let res = gemm_with_engine(&RustEngine, "demo", &cfg, shape, &x, &wt)?;
//! assert_eq!(res.y.len(), shape.m * shape.n);
//! assert_eq!(res.report.tiles.len(), 2 * 2); // 16/8 x 6/4 tiles
//! // a 20-bit ADC makes the tiled GEMM track the float reference closely
//! assert!((res.y[0] - 16.0 * 0.25 * 0.5).abs() < 1e-3);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod im2col;
pub mod mapper;
pub mod shapes;

pub use im2col::{im2col, ConvShape};
pub use mapper::{gemm_outputs, gemm_with_engine, run_layer, run_layer_with_data, TileBuffers};
pub use shapes::parse_shape;

use crate::distributions::Distribution;
use crate::energy::{energy_per_op, CimArch, EnergyBreakdown, TechParams};
use crate::figures::fig12;
use crate::mac::FormatPair;
use crate::report::{FigureResult, Table};

/// Largest per-tile ADC resolution the spec policy will request, bits.
/// Degenerate tiles (e.g. an all-zero weight block whose noise floor
/// vanishes) would otherwise solve to unbounded ENOB and infinite 4^ENOB
/// thermal energy; physical ADCs top out far below this.
pub const MAX_TILE_ENOB: f64 = 32.0;

/// How many tiles the per-tile table of [`LayerReport::to_figure_result`]
/// lists before truncating (layer-scale grids run to tens of thousands of
/// tiles; the histogram and totals cover the rest).
pub const TILE_TABLE_CAP: usize = 32;

/// GEMM dimensions: `Y[M×N] = X[M×K] · W[K×N]`.
///
/// `M` is the batch dimension (tokens), `K` the reduction (accumulated in
/// N_R-row chunks on the array), `N` the output width (mapped to tile
/// columns, weight-stationary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    /// Batch rows (tokens).
    pub m: usize,
    /// Reduction depth (input features).
    pub k: usize,
    /// Output columns (output features).
    pub n: usize,
}

impl GemmShape {
    /// Multiply-accumulates of the exact GEMM (padding excluded). Exact
    /// for every shape [`shapes::parse_shape`] can produce (dimensions
    /// are bounded by [`shapes::MAX_DIM`], so the product fits `u64`).
    pub fn macs(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }
}

impl std::fmt::Display for GemmShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.k, self.n)
    }
}

/// Per-tile ADC resolution policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdcPolicy {
    /// Every tile digitizes at this ENOB (the CIM-inference path, where
    /// the resolution is a design input).
    Fixed(f64),
    /// Solve each tile's requirement from its own aggregate via
    /// [`crate::spec::required_enob`] (clamped to [0, [`MAX_TILE_ENOB`]]),
    /// so data-dependent tiles get data-dependent ADCs — the layer-level
    /// analogue of the paper's per-column spec rule.
    PerTileSpec,
}

impl AdcPolicy {
    /// Stable name for reports.
    pub fn name(&self) -> String {
        match self {
            AdcPolicy::Fixed(e) => format!("fixed({e} b)"),
            AdcPolicy::PerTileSpec => "per-tile spec".to_string(),
        }
    }
}

/// The physical array a layer is mapped onto.
#[derive(Debug, Clone, Copy)]
pub struct TileConfig {
    /// Rows per column — the analog accumulation depth N_R.
    pub nr: usize,
    /// Columns per tile N_C (ADCs per tile; amortizes per-array logic).
    pub nc: usize,
    /// Input/weight formats the array quantizes to.
    pub fmts: FormatPair,
    /// Architecture / normalization granularity of every tile.
    pub arch: CimArch,
    /// Per-tile ADC resolution policy.
    pub adc: AdcPolicy,
    /// Technology parameters of the energy composition (Table III).
    pub tech: TechParams,
}

impl TileConfig {
    /// Row tiles needed for reduction depth `k` (ceil(K / N_R)).
    pub fn row_tiles(&self, k: usize) -> usize {
        k.div_ceil(self.nr)
    }

    /// Column tiles needed for output width `n` (ceil(N / N_C)).
    pub fn col_tiles(&self, n: usize) -> usize {
        n.div_ceil(self.nc)
    }

    /// Whether this configuration exceeds the native gain-ranging range
    /// and needs the global-normalization wrapper (Sec. III-D; priced via
    /// [`crate::energy::global_norm_energy_per_op`]).
    pub fn needs_global_norm(&self) -> bool {
        !fig12::native_ok(self.arch, self.fmts.x, self.fmts.w)
    }
}

/// A named layer evaluation: geometry, array configuration, and the
/// workload distributions that generate its operands (activations `X`,
/// weights `W`). Consumed by [`mapper::run_layer`].
#[derive(Debug, Clone)]
pub struct LayerSpec {
    /// Layer label (reports only; not part of seeding or cache identity).
    pub name: String,
    /// GEMM dimensions. For a conv layer this is the im2col-flattened
    /// geometry, [`ConvShape::gemm_shape`].
    pub shape: GemmShape,
    /// Array configuration.
    pub cfg: TileConfig,
    /// Activation workload distribution. For a conv layer it fills the
    /// `H·W·Cin` image, which [`im2col`] then expands into `X`.
    pub dist_x: Distribution,
    /// Weight workload distribution (conv: the `[Cout, Cin·kH·kW]`
    /// flattened filter bank).
    pub dist_w: Distribution,
    /// Convolution geometry when this layer is a `conv:` workload
    /// (`shape` must equal its [`ConvShape::gemm_shape`]); `None` for a
    /// plain GEMM.
    pub conv: Option<ConvShape>,
}

/// Per-tile outcome: geometry, solved ADC resolution, and the energy the
/// tile is charged.
#[derive(Debug, Clone, Copy)]
pub struct TileSummary {
    /// Row-tile index (which N_R-chunk of K).
    pub kt: usize,
    /// Column-tile index (which N_C-chunk of N).
    pub nt: usize,
    /// Active rows (< N_R only on the ragged K edge).
    pub rows: usize,
    /// Active columns (< N_C only on the ragged N edge).
    pub cols: usize,
    /// Monte-Carlo samples aggregated (M × active columns).
    pub samples: u64,
    /// The tile's ADC resolution, bits.
    pub enob: f64,
    /// Per-op energy breakdown at the tile's physical N_R × N_C geometry.
    pub energy: EnergyBreakdown,
    /// Total energy charged to the tile over the layer's M MVMs, fJ.
    pub energy_fj: f64,
    /// Useful MACs the tile executes (M × rows × cols).
    pub macs: u64,
}

/// The layer-level evaluation: per-tile outcomes plus the aggregate
/// energy/fidelity picture. Produced by [`mapper::gemm_with_engine`] /
/// [`mapper::run_layer`]; rendered by [`LayerReport::to_figure_result`].
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// Layer label.
    pub name: String,
    /// GEMM dimensions.
    pub shape: GemmShape,
    /// Array configuration the layer was mapped with.
    pub cfg: TileConfig,
    /// Tiles along the reduction dimension.
    pub row_tiles: usize,
    /// Tiles along the output dimension.
    pub col_tiles: usize,
    /// Per-tile outcomes, in tile-index order (`kt * col_tiles + nt`).
    pub tiles: Vec<TileSummary>,
    /// Σ per-tile energy, fJ (the analog array cost).
    pub tiles_fj: f64,
    /// Digital shift-add partial-sum reduction across row tiles, fJ.
    pub reduction_fj: f64,
    /// Global-normalization wrapper energy, fJ (0 when the configuration
    /// fits the native gain-ranging range).
    pub global_norm_fj: f64,
    /// Digital softmax energy, fJ — `heads · M · S` probability elements
    /// at [`TechParams::e_softmax_fj`] each; 0 for plain GEMM/conv layers
    /// (only attention stages exponentiate).
    pub softmax_fj: f64,
    /// Layer-output SQNR against the exact float GEMM, dB.
    pub sqnr_db: f64,
}

impl LayerReport {
    /// Total layer energy: tiles + partial-sum reduction + (when needed)
    /// the global-normalization wrapper + digital softmax, fJ.
    pub fn total_fj(&self) -> f64 {
        self.tiles_fj + self.reduction_fj + self.global_norm_fj + self.softmax_fj
    }

    /// Energy per useful MAC (padding excluded), fJ.
    pub fn fj_per_mac(&self) -> f64 {
        self.total_fj() / self.shape.macs() as f64
    }

    /// Energy per operation (one MAC = two ops, the paper's convention).
    pub fn fj_per_op(&self) -> f64 {
        self.fj_per_mac() / 2.0
    }

    /// Smallest per-tile ADC resolution, bits.
    pub fn enob_min(&self) -> f64 {
        self.tiles.iter().map(|t| t.enob).fold(f64::INFINITY, f64::min)
    }

    /// Largest per-tile ADC resolution, bits.
    pub fn enob_max(&self) -> f64 {
        self.tiles.iter().map(|t| t.enob).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Mean per-tile ADC resolution, bits.
    pub fn enob_mean(&self) -> f64 {
        self.tiles.iter().map(|t| t.enob).sum::<f64>() / self.tiles.len() as f64
    }

    /// ADC-resolution histogram across tiles: (floor(ENOB), tile count),
    /// ascending.
    pub fn enob_histogram(&self) -> Vec<(i64, usize)> {
        let mut bins = std::collections::BTreeMap::new();
        for t in &self.tiles {
            *bins.entry(t.enob.floor() as i64).or_insert(0usize) += 1;
        }
        bins.into_iter().collect()
    }

    /// Per-component energy totals over all tiles, fJ (the layer-level
    /// Fig. 12 pie).
    pub fn component_totals(&self) -> [(&'static str, f64); 6] {
        let mvm_ops = (2 * self.cfg.nr * self.cfg.nc * self.shape.m) as f64;
        let mut totals = EnergyBreakdown::default().components();
        for t in &self.tiles {
            for (slot, (_, v)) in totals.iter_mut().zip(t.energy.components()) {
                slot.1 += v * mvm_ops;
            }
        }
        totals
    }

    /// Render the report as tables + invariant checks (the `grcim layer`
    /// output and the serve layer's `layer` response).
    pub fn to_figure_result(&self) -> FigureResult {
        let mut fr = FigureResult::new("layer");

        let mut summary = Table::new("layer summary", &["metric", "value"]);
        let mut kv = |k: &str, v: String| summary.row(vec![k.into(), v]);
        kv("layer", self.name.clone());
        kv("shape (MxKxN)", self.shape.to_string());
        kv("macs", self.shape.macs().to_string());
        kv("nr", self.cfg.nr.to_string());
        kv("nc", self.cfg.nc.to_string());
        kv("arch", self.cfg.arch.name().into());
        kv("fmt_x", self.cfg.fmts.x.to_string());
        kv("fmt_w", self.cfg.fmts.w.to_string());
        kv("adc_policy", self.cfg.adc.name());
        kv("tiles", format!("{} ({}x{})", self.tiles.len(), self.row_tiles, self.col_tiles));
        kv("enob_min", Table::f(self.enob_min()));
        kv("enob_mean", Table::f(self.enob_mean()));
        kv("enob_max", Table::f(self.enob_max()));
        kv("layer_sqnr_db", Table::f(self.sqnr_db));
        kv("tiles_fj", Table::f(self.tiles_fj));
        kv("reduction_fj", Table::f(self.reduction_fj));
        kv("global_norm_fj", Table::f(self.global_norm_fj));
        kv("softmax_fj", Table::f(self.softmax_fj));
        kv("needs_global_norm", if self.cfg.needs_global_norm() { "yes" } else { "no" }.into());
        kv("total_fj", Table::f(self.total_fj()));
        kv("fj_per_mac", Table::f(self.fj_per_mac()));
        kv("fj_per_op", Table::f(self.fj_per_op()));
        fr.tables.push(summary);

        let mut comp = Table::new("energy components", &["component", "fj", "pct"]);
        let total = self.total_fj().max(1e-300);
        for (name, v) in self.component_totals() {
            comp.row(vec![name.into(), Table::f(v), Table::f(100.0 * v / total)]);
        }
        comp.row(vec![
            "reduction_tree".into(),
            Table::f(self.reduction_fj),
            Table::f(100.0 * self.reduction_fj / total),
        ]);
        comp.row(vec![
            "global_norm".into(),
            Table::f(self.global_norm_fj),
            Table::f(100.0 * self.global_norm_fj / total),
        ]);
        comp.row(vec![
            "softmax".into(),
            Table::f(self.softmax_fj),
            Table::f(100.0 * self.softmax_fj / total),
        ]);
        fr.tables.push(comp);

        let mut hist = Table::new("adc histogram", &["enob_bin", "tiles", "pct"]);
        for (bin, count) in self.enob_histogram() {
            hist.row(vec![
                format!("[{bin},{})", bin + 1),
                count.to_string(),
                Table::f(100.0 * count as f64 / self.tiles.len() as f64),
            ]);
        }
        fr.tables.push(hist);

        let shown = self.tiles.len().min(TILE_TABLE_CAP);
        let mut per_tile = Table::new(
            format!("tiles (first {shown} of {})", self.tiles.len()),
            &["kt", "nt", "rows", "cols", "enob", "adc_fj", "tile_fj", "macs"],
        );
        let mvm_ops = (2 * self.cfg.nr * self.cfg.nc * self.shape.m) as f64;
        for t in self.tiles.iter().take(TILE_TABLE_CAP) {
            per_tile.row(vec![
                t.kt.to_string(),
                t.nt.to_string(),
                t.rows.to_string(),
                t.cols.to_string(),
                Table::f(t.enob),
                Table::f(t.energy.adc * mvm_ops),
                Table::f(t.energy_fj),
                t.macs.to_string(),
            ]);
        }
        fr.tables.push(per_tile);

        // ---- invariant checks (distribution-independent) ----
        // the acceptance rule: the layer's tile total must reconcile with
        // independent energy::arch evaluations at the reported per-tile
        // resolutions
        let independent: f64 = self
            .tiles
            .iter()
            .map(|t| {
                let cfg = &self.cfg;
                energy_per_op(cfg.arch, cfg.fmts, cfg.nr, cfg.nc, t.enob, &cfg.tech).total()
                    * mvm_ops
            })
            .sum();
        let rel = (independent - self.tiles_fj).abs() / self.tiles_fj.max(1e-300);
        fr.check(
            "per-tile energy totals reconcile with energy::arch",
            "sum of independent per-tile evaluations",
            format!("rel diff {rel:.3e}"),
            rel < 1e-9,
        );
        let covered: u64 = self.tiles.iter().map(|t| t.macs).sum();
        fr.check(
            "tile grid covers the GEMM exactly once",
            format!("{} macs", self.shape.macs()),
            format!("{covered} macs"),
            covered == self.shape.macs(),
        );
        let enob_ok = self
            .tiles
            .iter()
            .all(|t| t.enob.is_finite() && (0.0..=MAX_TILE_ENOB).contains(&t.enob));
        fr.check(
            "per-tile ADC resolutions are finite and physical",
            format!("0 <= enob <= {MAX_TILE_ENOB}"),
            format!("min {} max {}", Table::f(self.enob_min()), Table::f(self.enob_max())),
            enob_ok,
        );
        fr.check(
            "layer SQNR and energy totals are finite",
            "finite",
            format!("sqnr {} dB, total {} fJ", Table::f(self.sqnr_db), Table::f(self.total_fj())),
            self.sqnr_db.is_finite() && self.total_fj().is_finite(),
        );
        fr
    }
}

/// A completed layer evaluation: the report plus the digitized GEMM
/// output `Y[M×N]` (row-major), in the operands' scale.
#[derive(Debug, Clone)]
pub struct LayerResult {
    /// Per-tile and layer-level evaluation.
    pub report: LayerReport,
    /// The digitized GEMM output, row-major `[M][N]`.
    pub y: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;

    fn small_cfg() -> TileConfig {
        TileConfig {
            nr: 8,
            nc: 4,
            fmts: FormatPair::new(FpFormat::fp(2, 2), FpFormat::fp4_e2m1()),
            arch: CimArch::GrUnit,
            adc: AdcPolicy::PerTileSpec,
            tech: TechParams::default(),
        }
    }

    #[test]
    fn shape_display_and_macs() {
        let s = GemmShape { m: 2, k: 16, n: 6 };
        assert_eq!(s.to_string(), "2x16x6");
        assert_eq!(s.macs(), 192);
    }

    #[test]
    fn tile_grid_counts() {
        let cfg = small_cfg();
        assert_eq!(cfg.row_tiles(16), 2);
        assert_eq!(cfg.row_tiles(17), 3);
        assert_eq!(cfg.col_tiles(4), 1);
        assert_eq!(cfg.col_tiles(5), 2);
    }

    #[test]
    fn native_range_gate() {
        // FP(2,2) x FP4 fits the 6-bit gain range on unit normalization
        assert!(!small_cfg().needs_global_norm());
        let mut wide = small_cfg();
        wide.fmts = FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1());
        assert!(wide.needs_global_norm());
    }

    #[test]
    fn adc_policy_names() {
        assert_eq!(AdcPolicy::Fixed(8.0).name(), "fixed(8 b)");
        assert_eq!(AdcPolicy::PerTileSpec.name(), "per-tile spec");
    }
}
