//! The tile mapper's execution engine: weight-stationary tile assembly,
//! signal-chain simulation through [`Engine::simulate_into`] (reused
//! scratch, allocation-free in steady state), per-tile ADC solving and
//! digitization, digital partial-sum reduction, and the pool-sharded
//! layer runner.
//!
//! Determinism contract: a tile's outcome depends only on (operands,
//! config, tile index) — nothing about scheduling or worker count enters
//! it — and partial sums are reduced in ascending row-tile order, so
//! [`run_layer`] is bit-identical for any worker count (asserted in
//! `rust/tests/properties.rs`).

use super::{
    AdcPolicy, GemmShape, LayerReport, LayerResult, LayerSpec, TileConfig, TileSummary,
    MAX_TILE_ENOB,
};
use crate::coordinator::{pool, CampaignConfig};
use crate::energy::{adder_tree_fa_count, energy_per_op, global_norm_energy_per_op, CimArch};
use crate::mac::adc_quantize;
use crate::rng::{job_seed, Pcg64};
use crate::runtime::{build_engine, Engine, SimScratch};
use crate::spec::{required_enob, SpecConfig};
use crate::stats::{ColumnAgg, ColumnBatch};
use anyhow::{bail, Result};
use crate::util::sync::Arc;

/// Grid-index namespace of the layer operand RNG stream in
/// [`crate::rng::job_seed`] — far outside any campaign's spec indices, so
/// layer operands never collide with campaign job streams at the same
/// campaign seed. The Python twin (`tools/gen_goldens.py`) uses the same
/// constant.
pub const LAYER_STREAM: u64 = 0x711E;

/// Reusable per-worker buffers of the tile hot path: the tile's f32
/// operand slabs, the engine's widening scratch, and one [`ColumnBatch`]
/// every tile is simulated into. After the first tile at a given
/// geometry, further tiles perform no heap allocation inside the signal
/// chain (outputs — partial sums and summaries — are results and are
/// allocated per tile).
#[derive(Debug, Default)]
pub struct TileBuffers {
    x: Vec<f32>,
    w: Vec<f32>,
    scratch: SimScratch,
    batch: ColumnBatch,
}

/// Digitization inputs of one simulated column sample: (ADC input
/// voltage, digital renormalization gain) per the architecture. The
/// row-normalized chain is not separately simulated; unit normalization
/// is used for every GR granularity (identical column voltage — the
/// `nn` convention).
fn adc_input(arch: CimArch, batch: &ColumnBatch, s: usize) -> (f64, f64) {
    match arch {
        CimArch::Conventional => (batch.v_conv[s], batch.g_conv[s]),
        CimArch::GrUnit | CimArch::GrRow | CimArch::GrInt => {
            (batch.v_gr[s], batch.s_sum[s] / batch.nr as f64)
        }
    }
}

/// Simulate one weight-stationary tile.
///
/// `x` is the activation matrix, row-major `[M][K]`; `wt` the transposed
/// weight matrix, row-major `[N][K]` (one row per output column — the
/// `nn::Dense` layout). The tile covers rows `kt*nr ..` and columns
/// `nt*nc ..` of the GEMM; samples are all (input row, active column)
/// pairs, zero-padded to the full N_R depth on the ragged K edge.
///
/// Returns the tile summary and the digitized partial sums
/// `zhat * N_R` (row-major `[M][active cols]`), ready for the digital
/// reduction across row tiles.
fn run_tile(
    engine: &dyn Engine,
    cfg: &TileConfig,
    shape: GemmShape,
    x: &[f32],
    wt: &[f32],
    (kt, nt): (usize, usize),
    bufs: &mut TileBuffers,
) -> Result<(TileSummary, Vec<f64>)> {
    let nr = cfg.nr;
    let k0 = kt * nr;
    let rows = (shape.k - k0).min(nr);
    let n0 = nt * cfg.nc;
    let cols = (shape.n - n0).min(cfg.nc);
    let b = shape.m * cols;

    // AOT backends execute fixed batch shapes; pad with zero samples and
    // discard their outputs (the oracle takes the exact batch). Known
    // trade-off: padding is per tile, so an artifact batch far above
    // M x N_C wastes AOT throughput — packing multiple same-geometry
    // tiles into one call is future work; the default oracle is exact.
    let padded = if engine.requires_batch_multiple() {
        let unit = engine.preferred_batch(nr).max(1);
        b.div_ceil(unit) * unit
    } else {
        b
    };

    bufs.x.clear();
    bufs.x.resize(padded * nr, 0.0);
    bufs.w.clear();
    bufs.w.resize(padded * nr, 0.0);
    for m in 0..shape.m {
        for j in 0..cols {
            let s = m * cols + j;
            let base = s * nr;
            let xrow = &x[m * shape.k + k0..m * shape.k + k0 + rows];
            bufs.x[base..base + rows].copy_from_slice(xrow);
            let wrow = &wt[(n0 + j) * shape.k + k0..(n0 + j) * shape.k + k0 + rows];
            bufs.w[base..base + rows].copy_from_slice(wrow);
        }
    }
    engine.simulate_into(&bufs.x, &bufs.w, nr, cfg.fmts, &mut bufs.scratch, &mut bufs.batch)?;
    let batch = &bufs.batch;

    let enob = match cfg.adc {
        // the resolution is a design input; no aggregate needed
        AdcPolicy::Fixed(e) => e,
        AdcPolicy::PerTileSpec => {
            // aggregate the active samples only (padding is discarded)
            let mut agg = ColumnAgg::new(nr);
            agg.push_batch_range(batch, 0, b);
            required_enob(&agg, cfg.arch.spec_arch(), SpecConfig::default())
                .enob
                .clamp(0.0, MAX_TILE_ENOB)
        }
    };

    let mut partial = vec![0.0f64; b];
    for (s, p) in partial.iter_mut().enumerate() {
        let (v, g) = adc_input(cfg.arch, batch, s);
        *p = adc_quantize(v, enob) * g * nr as f64;
    }

    let energy = energy_per_op(cfg.arch, cfg.fmts, nr, cfg.nc, enob, &cfg.tech);
    let mvm_ops = (2 * nr * cfg.nc * shape.m) as f64;
    let summary = TileSummary {
        kt,
        nt,
        rows,
        cols,
        samples: b as u64,
        enob,
        energy,
        energy_fj: energy.total() * mvm_ops,
        macs: (shape.m * rows * cols) as u64,
    };
    Ok((summary, partial))
}

/// Validate operand slabs against the shape and config.
fn validate(cfg: &TileConfig, shape: GemmShape, x: &[f32], wt: &[f32]) -> Result<()> {
    if cfg.nr == 0 || cfg.nc == 0 {
        bail!("tile geometry must be positive (nr={}, nc={})", cfg.nr, cfg.nc);
    }
    if shape.m == 0 || shape.k == 0 || shape.n == 0 {
        bail!("GEMM shape must be positive ({shape})");
    }
    if x.len() != shape.m * shape.k {
        bail!("x has {} values, shape {shape} needs {}", x.len(), shape.m * shape.k);
    }
    if wt.len() != shape.n * shape.k {
        bail!("wt has {} values, shape {shape} needs {}", wt.len(), shape.n * shape.k);
    }
    Ok(())
}

/// Reduce per-tile outcomes into the layer result: digital shift-add
/// partial-sum accumulation (ascending row-tile order — the reduction
/// tree's deterministic schedule), the exact float reference GEMM, and
/// the energy totals.
fn assemble(
    name: &str,
    cfg: &TileConfig,
    shape: GemmShape,
    x: &[f32],
    wt: &[f32],
    outs: Vec<(TileSummary, Vec<f64>)>,
    with_reference: bool,
) -> LayerResult {
    let row_tiles = cfg.row_tiles(shape.k);
    let col_tiles = cfg.col_tiles(shape.n);
    debug_assert_eq!(outs.len(), row_tiles * col_tiles);

    // partial-sum reduction: tile-index order is kt-major, so every
    // output accumulates its row-tile contributions in ascending kt order
    let mut y = vec![0.0f64; shape.m * shape.n];
    let mut tiles = Vec::with_capacity(outs.len());
    let mut tiles_fj = 0.0;
    for (summary, partial) in outs {
        let n0 = summary.nt * cfg.nc;
        for m in 0..shape.m {
            for j in 0..summary.cols {
                y[m * shape.n + n0 + j] += partial[m * summary.cols + j];
            }
        }
        tiles_fj += summary.energy_fj;
        tiles.push(summary);
    }

    // exact float reference (f64 over the same f32 operands, ascending
    // k); skipped on the inference fast path, which only consumes `y`
    let sqnr_db = if with_reference {
        let mut sig = 0.0f64;
        let mut err = 0.0f64;
        for m in 0..shape.m {
            for n in 0..shape.n {
                let mut r = 0.0f64;
                for k in 0..shape.k {
                    r += x[m * shape.k + k] as f64 * wt[n * shape.k + k] as f64;
                }
                sig += r * r;
                let d = y[m * shape.n + n] - r;
                err += d * d;
            }
        }
        crate::util::db(sig / err.max(1e-300))
    } else {
        f64::NAN
    };

    // digital shift-add reduction across row tiles: one adder tree per
    // output over `row_tiles` partial words of (ENOB + log2 N_R) bits
    let reduction_fj = if row_tiles > 1 {
        let max_enob = tiles.iter().map(|t| t.enob).fold(f64::NEG_INFINITY, f64::max);
        let width = max_enob + (cfg.nr as f64).log2();
        let fa = adder_tree_fa_count(row_tiles, width);
        cfg.tech.e_adder_tree(fa) * (shape.m * shape.n) as f64
    } else {
        0.0
    };

    // global-normalization wrapper (charged per tile MVM when the formats
    // exceed the native gain range — Sec. III-D)
    let global_norm_fj = if cfg.needs_global_norm() {
        let per_op = global_norm_energy_per_op(cfg.fmts, cfg.nr, cfg.nc, &cfg.tech);
        per_op * (2 * cfg.nr * cfg.nc * shape.m) as f64 * tiles.len() as f64
    } else {
        0.0
    };

    LayerResult {
        report: LayerReport {
            name: name.to_string(),
            shape,
            cfg: *cfg,
            row_tiles,
            col_tiles,
            tiles,
            tiles_fj,
            reduction_fj,
            global_norm_fj,
            softmax_fj: 0.0, // plain GEMMs don't exponentiate
            sqnr_db,
        },
        y,
    }
}

/// Run a GEMM through the tile mapper on one engine, sequentially (the
/// CIM-inference path — see [`crate::nn::cim_forward_batch`] — and the
/// reference the pooled [`run_layer`] is bit-identical to).
///
/// `x` is row-major `[M][K]`, `wt` row-major `[N][K]` (transposed
/// weights), both pre-scaled to the array's [-1, 1] full scale.
pub fn gemm_with_engine(
    engine: &dyn Engine,
    name: &str,
    cfg: &TileConfig,
    shape: GemmShape,
    x: &[f32],
    wt: &[f32],
) -> Result<LayerResult> {
    gemm_inner(engine, name, cfg, shape, x, wt, true)
}

/// Like [`gemm_with_engine`] but without the exact float reference GEMM
/// — the report's `sqnr_db` is NaN. The CIM-inference hot path
/// ([`crate::nn::cim_forward_batch`]) only consumes the outputs `y`, so
/// it skips the O(M·K·N) reference work entirely.
pub fn gemm_outputs(
    engine: &dyn Engine,
    name: &str,
    cfg: &TileConfig,
    shape: GemmShape,
    x: &[f32],
    wt: &[f32],
) -> Result<LayerResult> {
    gemm_inner(engine, name, cfg, shape, x, wt, false)
}

fn gemm_inner(
    engine: &dyn Engine,
    name: &str,
    cfg: &TileConfig,
    shape: GemmShape,
    x: &[f32],
    wt: &[f32],
    with_reference: bool,
) -> Result<LayerResult> {
    validate(cfg, shape, x, wt)?;
    let row_tiles = cfg.row_tiles(shape.k);
    let col_tiles = cfg.col_tiles(shape.n);
    let mut bufs = TileBuffers::default();
    let mut outs = Vec::with_capacity(row_tiles * col_tiles);
    for kt in 0..row_tiles {
        for nt in 0..col_tiles {
            outs.push(run_tile(engine, cfg, shape, x, wt, (kt, nt), &mut bufs)?);
        }
    }
    Ok(assemble(name, cfg, shape, x, wt, outs, with_reference))
}

/// Run a GEMM with explicit operands, sharding tile jobs across the
/// coordinator worker pool. Each worker builds its own engine and owns
/// one [`TileBuffers`]; results are re-ordered by tile index before the
/// reduction, so the outcome is bit-identical to [`gemm_with_engine`]
/// for any worker count.
pub fn run_layer_with_data(
    name: &str,
    cfg: &TileConfig,
    shape: GemmShape,
    x: Vec<f32>,
    wt: Vec<f32>,
    campaign: &CampaignConfig,
) -> Result<LayerResult> {
    validate(cfg, shape, &x, &wt)?;
    let row_tiles = cfg.row_tiles(shape.k);
    let col_tiles = cfg.col_tiles(shape.n);
    let tiles = row_tiles * col_tiles;
    let x = Arc::new(x);
    let wt = Arc::new(wt);

    let jobs: Vec<usize> = (0..tiles).collect();
    let engine_kind = campaign.engine;
    let artifacts = campaign.artifacts_dir.clone();
    let cfg_worker = *cfg;
    let x_worker = Arc::clone(&x);
    let wt_worker = Arc::clone(&wt);
    let results = pool::run_jobs(jobs, campaign.effective_workers(), move || {
        let engine = build_engine(engine_kind, &artifacts)?;
        let x = Arc::clone(&x_worker);
        let wt = Arc::clone(&wt_worker);
        let mut bufs = TileBuffers::default();
        Ok(move |idx: usize| -> Result<(usize, TileSummary, Vec<f64>)> {
            let tile = (idx / col_tiles, idx % col_tiles);
            let (summary, partial) =
                run_tile(engine.as_ref(), &cfg_worker, shape, &x, &wt, tile, &mut bufs)?;
            Ok((idx, summary, partial))
        })
    })?;

    // results arrive unordered; restore tile-index order for the
    // deterministic reduction schedule
    let mut ordered: Vec<Option<(TileSummary, Vec<f64>)>> = (0..tiles).map(|_| None).collect();
    for (idx, summary, partial) in results {
        ordered[idx] = Some((summary, partial));
    }
    let outs: Vec<(TileSummary, Vec<f64>)> =
        ordered.into_iter().map(|o| o.expect("pool returned every tile")).collect();
    Ok(assemble(name, cfg, shape, &x, &wt, outs, true))
}

/// Evaluate a named layer: draw the operands from the spec's workload
/// distributions (deterministically from the campaign seed, stream
/// [`LAYER_STREAM`]), then run the tiled GEMM across the worker pool.
///
/// A conv layer (`spec.conv` set) draws its `H·W·Cin` image from the
/// same stream position a plain GEMM would draw `X` from, then
/// [`super::im2col`]-expands it — so a 1x1 kernel (identity expansion,
/// same draw count) reproduces the equivalent `gemm:` layer bit-exactly.
///
/// The result is a pure function of (spec, campaign.seed,
/// campaign.engine) — the property the serve layer's
/// [`crate::server::proto::layer_key`] relies on.
pub fn run_layer(spec: &LayerSpec, campaign: &CampaignConfig) -> Result<LayerResult> {
    let shape = spec.shape;
    let mut rng = Pcg64::seeded(job_seed(campaign.seed, LAYER_STREAM, 0));
    let x = match &spec.conv {
        None => {
            let mut x = vec![0.0f32; shape.m * shape.k];
            spec.dist_x.fill_f32(&mut rng, &mut x);
            x
        }
        Some(cs) => {
            anyhow::ensure!(
                cs.gemm_shape() == shape,
                "layer '{}': shape {} does not match conv geometry {cs}",
                spec.name,
                shape
            );
            let mut img = vec![0.0f32; cs.img_elems()];
            spec.dist_x.fill_f32(&mut rng, &mut img);
            super::im2col(&img, cs)
        }
    };
    let mut wt = vec![0.0f32; shape.n * shape.k];
    spec.dist_w.fill_f32(&mut rng, &mut wt);
    run_layer_with_data(&spec.name, &spec.cfg, shape, x, wt, campaign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::Distribution;
    use crate::energy::TechParams;
    use crate::formats::FpFormat;
    use crate::mac::FormatPair;
    use crate::runtime::{EngineKind, RustEngine};

    fn cfg(nr: usize, nc: usize, adc: AdcPolicy) -> TileConfig {
        TileConfig {
            nr,
            nc,
            fmts: FormatPair::new(FpFormat::fp(2, 2), FpFormat::fp4_e2m1()),
            arch: CimArch::GrUnit,
            adc,
            tech: TechParams::default(),
        }
    }

    fn operands(shape: GemmShape, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seeded(seed);
        let mut x = vec![0.0f32; shape.m * shape.k];
        Distribution::clipped_gauss4().fill_f32(&mut rng, &mut x);
        let mut wt = vec![0.0f32; shape.n * shape.k];
        Distribution::max_entropy(FpFormat::fp4_e2m1()).fill_f32(&mut rng, &mut wt);
        (x, wt)
    }

    #[test]
    fn ragged_edges_cover_the_gemm() {
        let shape = GemmShape { m: 3, k: 21, n: 10 };
        let (x, wt) = operands(shape, 5);
        let c = cfg(8, 4, AdcPolicy::PerTileSpec);
        let res = gemm_with_engine(&RustEngine, "t", &c, shape, &x, &wt).unwrap();
        assert_eq!(res.report.row_tiles, 3);
        assert_eq!(res.report.col_tiles, 3);
        let covered: u64 = res.report.tiles.iter().map(|t| t.macs).sum();
        assert_eq!(covered, shape.macs());
        // edge tiles are ragged
        let last = res.report.tiles.last().unwrap();
        assert_eq!(last.rows, 21 - 16);
        assert_eq!(last.cols, 10 - 8);
        // and the report's invariant checks all hold
        let fr = res.report.to_figure_result();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
    }

    #[test]
    fn high_resolution_adc_recovers_the_float_gemm() {
        let shape = GemmShape { m: 2, k: 32, n: 6 };
        let (x, wt) = operands(shape, 7);
        let mut c = cfg(16, 4, AdcPolicy::Fixed(24.0));
        c.fmts = FormatPair::new(FpFormat::fp(4, 6), FpFormat::fp(4, 6));
        let res = gemm_with_engine(&RustEngine, "t", &c, shape, &x, &wt).unwrap();
        for m in 0..shape.m {
            for n in 0..shape.n {
                let mut r = 0.0f64;
                for k in 0..shape.k {
                    r += x[m * shape.k + k] as f64 * wt[n * shape.k + k] as f64;
                }
                let got = res.y[m * shape.n + n];
                assert!((got - r).abs() < 2e-2, "y[{m},{n}] = {got} vs {r}");
            }
        }
        assert!(res.report.sqnr_db > 25.0, "sqnr {}", res.report.sqnr_db);
    }

    #[test]
    fn pooled_layer_matches_sequential_bitwise() {
        let shape = GemmShape { m: 2, k: 24, n: 9 };
        let c = cfg(8, 4, AdcPolicy::PerTileSpec);
        let spec = LayerSpec {
            name: "t".into(),
            shape,
            cfg: c,
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            conv: None,
        };
        let campaign = CampaignConfig {
            engine: EngineKind::Rust,
            workers: 3,
            seed: 9,
            ..Default::default()
        };
        let pooled = run_layer(&spec, &campaign).unwrap();

        // sequential reference over the same deterministic operands
        let mut rng = Pcg64::seeded(job_seed(9, LAYER_STREAM, 0));
        let mut x = vec![0.0f32; shape.m * shape.k];
        spec.dist_x.fill_f32(&mut rng, &mut x);
        let mut wt = vec![0.0f32; shape.n * shape.k];
        spec.dist_w.fill_f32(&mut rng, &mut wt);
        let seq = gemm_with_engine(&RustEngine, "t", &c, shape, &x, &wt).unwrap();

        assert_eq!(pooled.y.len(), seq.y.len());
        for (a, b) in pooled.y.iter().zip(&seq.y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(pooled.report.tiles_fj.to_bits(), seq.report.tiles_fj.to_bits());
        for (a, b) in pooled.report.tiles.iter().zip(&seq.report.tiles) {
            assert_eq!(a.enob.to_bits(), b.enob.to_bits());
        }
    }

    #[test]
    fn one_by_one_conv_layer_matches_the_plain_gemm_layer_bitwise() {
        // identity im2col + identical draw order: the conv layer must be
        // indistinguishable from its flattened gemm twin
        let cs = crate::tile::ConvShape::parse("conv:5x3x1x1@4x6").unwrap();
        let campaign = CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 13,
            ..Default::default()
        };
        let mk = |conv| LayerSpec {
            name: "c".into(),
            shape: cs.gemm_shape(),
            cfg: cfg(8, 4, AdcPolicy::PerTileSpec),
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            conv,
        };
        let conv = run_layer(&mk(Some(cs)), &campaign).unwrap();
        let gemm = run_layer(&mk(None), &campaign).unwrap();
        for (a, b) in conv.y.iter().zip(&gemm.y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(conv.report.tiles_fj.to_bits(), gemm.report.tiles_fj.to_bits());
        // a spec whose shape disagrees with its conv geometry is rejected
        let mut bad = mk(Some(cs));
        bad.shape.n += 1;
        assert!(run_layer(&bad, &campaign).is_err());
    }

    #[test]
    fn outputs_fast_path_is_bit_identical_minus_the_reference() {
        let shape = GemmShape { m: 2, k: 20, n: 6 };
        let (x, wt) = operands(shape, 17);
        let c = cfg(8, 4, AdcPolicy::PerTileSpec);
        let full = gemm_with_engine(&RustEngine, "t", &c, shape, &x, &wt).unwrap();
        let fast = gemm_outputs(&RustEngine, "t", &c, shape, &x, &wt).unwrap();
        for (a, b) in full.y.iter().zip(&fast.y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(full.report.tiles_fj.to_bits(), fast.report.tiles_fj.to_bits());
        assert!(full.report.sqnr_db.is_finite());
        assert!(fast.report.sqnr_db.is_nan());
    }

    #[test]
    fn conventional_and_gr_share_the_linear_chain() {
        // with a transparent ADC both architectures reconstruct the same
        // dot products (the linear-chain identity at layer scale)
        let shape = GemmShape { m: 2, k: 16, n: 4 };
        let (x, wt) = operands(shape, 11);
        let mut cg = cfg(8, 4, AdcPolicy::Fixed(26.0));
        cg.fmts = FormatPair::new(FpFormat::fp(3, 4), FpFormat::fp(3, 4));
        let mut cc = cg;
        cc.arch = CimArch::Conventional;
        let gr = gemm_with_engine(&RustEngine, "gr", &cg, shape, &x, &wt).unwrap();
        let conv = gemm_with_engine(&RustEngine, "conv", &cc, shape, &x, &wt).unwrap();
        for (a, b) in gr.y.iter().zip(&conv.y) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn per_tile_spec_tracks_data_statistics() {
        // an LLM-like activation block needs fewer GR bits than the
        // conventional chain at every tile (the paper's claim, per tile)
        let shape = GemmShape { m: 4, k: 32, n: 8 };
        let mut c = cfg(16, 4, AdcPolicy::PerTileSpec);
        c.fmts = FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1());
        let (x, wt) = {
            let mut rng = Pcg64::seeded(3);
            let mut x = vec![0.0f32; shape.m * shape.k];
            Distribution::gauss_outliers().fill_f32(&mut rng, &mut x);
            let mut wt = vec![0.0f32; shape.n * shape.k];
            Distribution::max_entropy(FpFormat::fp4_e2m1()).fill_f32(&mut rng, &mut wt);
            (x, wt)
        };
        let gr = gemm_with_engine(&RustEngine, "gr", &c, shape, &x, &wt).unwrap();
        let mut conv_cfg = c;
        conv_cfg.arch = CimArch::Conventional;
        let conv = gemm_with_engine(&RustEngine, "conv", &conv_cfg, shape, &x, &wt).unwrap();
        for (g, cv) in gr.report.tiles.iter().zip(&conv.report.tiles) {
            assert!(g.enob < cv.enob, "tile ({},{}): gr {} conv {}", g.kt, g.nt, g.enob, cv.enob);
        }
        // and the GR layer is cheaper end to end (gr-unit fits natively
        // only via the global-norm wrapper here, which is charged)
        assert!(gr.report.total_fj() < conv.report.total_fj());
    }

    #[test]
    fn rejects_bad_operands() {
        let shape = GemmShape { m: 2, k: 8, n: 2 };
        let c = cfg(4, 2, AdcPolicy::Fixed(8.0));
        let x = vec![0.0f32; shape.m * shape.k];
        let wt = vec![0.0f32; shape.n * shape.k];
        assert!(gemm_with_engine(&RustEngine, "t", &c, shape, &x[1..], &wt).is_err());
        assert!(gemm_with_engine(&RustEngine, "t", &c, shape, &x, &wt[1..]).is_err());
        let mut zero = c;
        zero.nr = 0;
        assert!(gemm_with_engine(&RustEngine, "t", &zero, shape, &x, &wt).is_err());
        let empty = GemmShape { m: 0, k: 8, n: 2 };
        assert!(gemm_with_engine(&RustEngine, "t", &c, empty, &[], &wt).is_err());
    }
}
