//! im2col flattener — convolution layers on the weight-stationary GEMM
//! mapper, unchanged (the macro-level mapping IMAGINE-style CNN macros
//! use).
//!
//! A valid-padding, stride-1 `Cout×Cin×kH×kW` convolution over an
//! `H×W×Cin` image is exactly the GEMM
//!
//! ```text
//! Y[(Ho·Wo) × Cout] = X[(Ho·Wo) × (Cin·kH·kW)] · W[(Cin·kH·kW) × Cout]
//! ```
//!
//! where `Ho = H-kH+1`, `Wo = W-kW+1`, each X row is one receptive-field
//! patch, and the weight tensor is flattened `[out, in·kH·kW]` — so the
//! existing tile mapper, ADC spec rule, and energy composition apply
//! verbatim; only the operand layout changes.
//!
//! Layout contract (pinned by the goldens and the 1x1-kernel property):
//! images are HWC row-major (`img[(y*W + x)*Cin + c]`), and a patch
//! column is ordered `(ky, kx, ci)`-major:
//!
//! ```text
//! X[p][(ky·kW + kx)·Cin + ci] = img[((oy+ky)·W + ox+kx)·Cin + ci],
//!     p = oy·Wo + ox
//! ```
//!
//! A 1x1 kernel therefore makes [`im2col`] the identity reshape: the
//! flattened X equals the flat image bit-for-bit, which is what lets
//! `conv:<Cout>x<Cin>x1x1@<H>x<W>` reproduce `gemm:<H·W>x<Cin>x<Cout>`
//! exactly through the whole stack (same draw count, same draw order).

use super::shapes::MAX_DIM;
use super::GemmShape;
use anyhow::{bail, Context, Result};

/// A valid-padding, stride-1 convolution workload:
/// `conv:<Cout>x<Cin>x<kH>x<kW>@<H>x<W>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    /// Output channels (GEMM N; one array column group per filter).
    pub cout: usize,
    /// Input channels.
    pub cin: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
}

impl ConvShape {
    /// Parse the `conv:` argument `<Cout>x<Cin>x<kH>x<kW>@<H>x<W>`
    /// (everything after the `conv:` prefix); `full` is the original
    /// string for error messages.
    pub fn parse_args(arg: &str, full: &str) -> Result<ConvShape> {
        let (filt, img) = arg.split_once('@').with_context(|| {
            format!("shape '{full}' must be 'conv:<Cout>x<Cin>x<kH>x<kW>@<H>x<W>'")
        })?;
        let dims = |part: &str| -> Result<Vec<usize>> {
            part.split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .with_context(|| format!("shape '{full}': '{d}' is not a dimension"))
                })
                .collect()
        };
        let &[cout, cin, kh, kw] = dims(filt)?.as_slice() else {
            bail!("shape '{full}': filter needs exactly four dimensions, '<Cout>x<Cin>x<kH>x<kW>'");
        };
        let &[h, w] = dims(img)?.as_slice() else {
            bail!("shape '{full}': image needs exactly two dimensions, '<H>x<W>'");
        };
        let cs = ConvShape { cout, cin, kh, kw, h, w };
        cs.validate(full)?;
        Ok(cs)
    }

    /// Parse a full `conv:<Cout>x<Cin>x<kH>x<kW>@<H>x<W>` string.
    pub fn parse(s: &str) -> Result<ConvShape> {
        let arg = s
            .strip_prefix("conv:")
            .with_context(|| format!("shape '{s}' must start with 'conv:'"))?;
        ConvShape::parse_args(arg, s)
    }

    fn validate(&self, s: &str) -> Result<()> {
        if [self.cout, self.cin, self.kh, self.kw, self.h, self.w].contains(&0) {
            bail!("shape '{s}': dimensions must be positive");
        }
        if self.kh > self.h || self.kw > self.w {
            bail!(
                "shape '{s}': kernel {}x{} exceeds image {}x{} (valid padding)",
                self.kh,
                self.kw,
                self.h,
                self.w
            );
        }
        // bound the *flattened* GEMM dims like shapes::bounded does, so
        // GemmShape::macs cannot wrap and slab sizes stay inside usize
        let m = self
            .out_h()
            .checked_mul(self.out_w())
            .with_context(|| format!("shape '{s}': output plane overflows"))?;
        let k = self
            .cin
            .checked_mul(self.kh)
            .and_then(|v| v.checked_mul(self.kw))
            .with_context(|| format!("shape '{s}': patch size overflows"))?;
        if m > MAX_DIM || k > MAX_DIM || self.cout > MAX_DIM {
            bail!("shape '{s}': flattened GEMM dimensions must be <= {MAX_DIM}");
        }
        if self.h.checked_mul(self.w).and_then(|v| v.checked_mul(self.cin)).is_none() {
            bail!("shape '{s}': image size overflows");
        }
        Ok(())
    }

    /// Output plane height under valid padding, stride 1.
    pub fn out_h(&self) -> usize {
        self.h - self.kh + 1
    }

    /// Output plane width under valid padding, stride 1.
    pub fn out_w(&self) -> usize {
        self.w - self.kw + 1
    }

    /// The GEMM this convolution flattens to:
    /// `M = Ho·Wo`, `K = Cin·kH·kW`, `N = Cout`.
    pub fn gemm_shape(&self) -> GemmShape {
        GemmShape {
            m: self.out_h() * self.out_w(),
            k: self.cin * self.kh * self.kw,
            n: self.cout,
        }
    }

    /// Elements of the HWC input image (`H·W·Cin`) — what the workload
    /// generator draws before [`im2col`] expands it.
    pub fn img_elems(&self) -> usize {
        self.h * self.w * self.cin
    }
}

impl std::fmt::Display for ConvShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "conv:{}x{}x{}x{}@{}x{}",
            self.cout, self.cin, self.kh, self.kw, self.h, self.w
        )
    }
}

/// Expand an HWC row-major image into the im2col activation matrix
/// `X[(Ho·Wo) × (Cin·kH·kW)]` (row-major, patch columns `(ky, kx, ci)`-
/// major). For a 1x1 kernel this is the identity reshape. Generic over
/// the element type so the f32 array path and the f64 reference chain
/// flatten through the same code.
pub fn im2col<T: Copy>(img: &[T], cs: &ConvShape) -> Vec<T> {
    assert_eq!(img.len(), cs.img_elems(), "image must be H*W*Cin elements");
    let (wo, ho) = (cs.out_w(), cs.out_h());
    let k = cs.cin * cs.kh * cs.kw;
    let mut x = Vec::with_capacity(ho * wo * k);
    for oy in 0..ho {
        for ox in 0..wo {
            for ky in 0..cs.kh {
                let row = ((oy + ky) * cs.w + ox) * cs.cin;
                x.extend_from_slice(&img[row..row + cs.kw * cs.cin]);
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shapes_parse_and_flatten() {
        let cs = ConvShape::parse("conv:6x3x3x3@8x8").unwrap();
        assert_eq!(cs, ConvShape { cout: 6, cin: 3, kh: 3, kw: 3, h: 8, w: 8 });
        assert_eq!(cs.gemm_shape(), GemmShape { m: 36, k: 27, n: 6 });
        assert_eq!(cs.img_elems(), 192);
        assert_eq!(cs.to_string(), "conv:6x3x3x3@8x8");
    }

    #[test]
    fn malformed_conv_shapes_are_clean_errors() {
        for bad in [
            "conv:6x3x3x3",       // no image
            "conv:6x3x3@8x8",     // missing filter dim
            "conv:6x3x3x3@8",     // missing image dim
            "conv:6x3x3x3@8x8x8", // extra image dim
            "conv:6x3x0x3@8x8",   // zero dim
            "conv:6x3x9x3@8x8",   // kernel taller than image
            "conv:axbxcxd@8x8",   // non-numeric
            "gemm:4x8x8",         // wrong prefix for ConvShape::parse
        ] {
            assert!(ConvShape::parse(bad).is_err(), "{bad}");
        }
        // flattened dims are bounded like parse_shape's
        let big = MAX_DIM + 1;
        assert!(ConvShape::parse(&format!("conv:{big}x1x1x1@4x4")).is_err());
        assert!(ConvShape::parse(&format!("conv:1x{big}x1x1@4x4")).is_err());
    }

    #[test]
    fn im2col_matches_hand_expansion() {
        // 1 channel, 2x2 kernel over a 3x3 image: 4 patches of 4 taps
        let cs = ConvShape::parse("conv:1x1x2x2@3x3").unwrap();
        #[rustfmt::skip]
        let img = vec![
            0.0, 1.0, 2.0,
            3.0, 4.0, 5.0,
            6.0, 7.0, 8.0,
        ];
        let x = im2col(&img, &cs);
        #[rustfmt::skip]
        assert_eq!(x, vec![
            0.0, 1.0, 3.0, 4.0,
            1.0, 2.0, 4.0, 5.0,
            3.0, 4.0, 6.0, 7.0,
            4.0, 5.0, 7.0, 8.0,
        ]);
    }

    #[test]
    fn channels_stay_innermost() {
        // 2 channels, 1x2 kernel over a 1x3 image: the (ky, kx, ci) patch
        // order keeps each tap's channels adjacent
        let cs = ConvShape::parse("conv:1x2x1x2@1x3").unwrap();
        let img = vec![10.0, 11.0, 20.0, 21.0, 30.0, 31.0];
        let x = im2col(&img, &cs);
        assert_eq!(x, vec![10.0, 11.0, 20.0, 21.0, 20.0, 21.0, 30.0, 31.0]);
    }

    #[test]
    fn one_by_one_kernel_is_the_identity_reshape() {
        let cs = ConvShape::parse("conv:4x3x1x1@5x7").unwrap();
        assert_eq!(cs.gemm_shape(), GemmShape { m: 35, k: 3, n: 4 });
        let img: Vec<f32> = (0..cs.img_elems()).map(|i| i as f32 * 0.5).collect();
        assert_eq!(im2col(&img, &cs), img);
    }
}
