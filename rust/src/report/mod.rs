//! Result tables: CSV + aligned-text (markdown-ish) emitters used by the
//! figure harness and the CLI, plus the machine-readable JSON forms the
//! serve layer returns over the wire.

use crate::config::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// A rectangular result table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (also names the CSV file stem).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of pre-formatted cells (width must match the headers).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Format a float with sensible figure precision.
    pub fn f(v: f64) -> String {
        if v == 0.0 {
            "0".into()
        } else if v.abs() >= 1000.0 || v.abs() < 1e-3 {
            format!("{v:.4e}")
        } else {
            format!("{v:.4}")
        }
    }

    /// Render as CSV (quoting cells containing commas or quotes).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            s,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        s
    }

    /// Markdown table (also readable as plain text).
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "### {}", self.title);
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(line, " {:<w$} |", c, w = width[i]);
            }
            line
        };
        let _ = writeln!(s, "{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &width {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(s, "{sep}");
        for r in &self.rows {
            let _ = writeln!(s, "{}", fmt_row(r));
        }
        s
    }

    /// Machine-readable form: `{"title", "headers": [...], "rows": [[...]]}`
    /// (cells stay strings — they are already formatted for display).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("title".to_string(), Json::Str(self.title.clone()));
        m.insert(
            "headers".to_string(),
            Json::Arr(self.headers.iter().cloned().map(Json::Str).collect()),
        );
        m.insert(
            "rows".to_string(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| {
                        Json::Arr(r.iter().cloned().map(Json::Str).collect())
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Write `<dir>/<stem>.csv`.
    pub fn save_csv(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(format!("{stem}.csv"));
        std::fs::write(&path, self.to_csv())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

/// A completed figure/table reproduction: tables plus the headline
/// comparisons against the paper.
#[derive(Debug, Clone, Default)]
pub struct FigureResult {
    /// Figure/table identifier (e.g. `"fig9"`).
    pub name: String,
    /// The series/rows the paper plots.
    pub tables: Vec<Table>,
    /// (claim, paper value, measured value, holds?)
    pub checks: Vec<Check>,
}

/// One paper-vs-measured comparison.
#[derive(Debug, Clone)]
pub struct Check {
    /// What the paper claims.
    pub claim: String,
    /// The paper's stated value/shape.
    pub paper: String,
    /// What we measured.
    pub measured: String,
    /// Whether the measurement supports the claim.
    pub holds: bool,
}

impl FigureResult {
    /// An empty result for the named figure.
    pub fn new(name: impl Into<String>) -> Self {
        FigureResult { name: name.into(), ..Default::default() }
    }

    /// Record one paper-vs-measured comparison.
    pub fn check(
        &mut self,
        claim: impl Into<String>,
        paper: impl Into<String>,
        measured: impl Into<String>,
        holds: bool,
    ) {
        self.checks.push(Check {
            claim: claim.into(),
            paper: paper.into(),
            measured: measured.into(),
            holds,
        });
    }

    /// True when every recorded check holds.
    pub fn all_hold(&self) -> bool {
        self.checks.iter().all(|c| c.holds)
    }

    /// Machine-readable form of the whole figure (the serve layer's
    /// `figure` response): name, tables, paper-vs-measured checks, and the
    /// overall verdict.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("all_hold".to_string(), Json::Bool(self.all_hold()));
        m.insert(
            "tables".to_string(),
            Json::Arr(self.tables.iter().map(Table::to_json).collect()),
        );
        m.insert(
            "checks".to_string(),
            Json::Arr(
                self.checks
                    .iter()
                    .map(|c| {
                        let mut cm = BTreeMap::new();
                        cm.insert("claim".into(), Json::Str(c.claim.clone()));
                        cm.insert("paper".into(), Json::Str(c.paper.clone()));
                        cm.insert(
                            "measured".into(),
                            Json::Str(c.measured.clone()),
                        );
                        cm.insert("holds".into(), Json::Bool(c.holds));
                        Json::Obj(cm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Persist all tables and render the summary text.
    pub fn emit(&self, out_dir: &Path) -> Result<String> {
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.name);
        for t in &self.tables {
            let stem = format!(
                "{}_{}",
                self.name,
                t.title.to_lowercase().replace([' ', '/', ':'], "_")
            );
            t.save_csv(out_dir, &stem)?;
            let _ = writeln!(s, "{}", t.to_markdown());
        }
        if !self.checks.is_empty() {
            let mut ct = Table::new(
                format!("{} paper-vs-measured", self.name),
                &["claim", "paper", "measured", "holds"],
            );
            for c in &self.checks {
                ct.row(vec![
                    c.claim.clone(),
                    c.paper.clone(),
                    c.measured.clone(),
                    if c.holds { "yes" } else { "NO" }.into(),
                ]);
            }
            ct.save_csv(out_dir, &format!("{}_checks", self.name))?;
            let _ = writeln!(s, "{}", ct.to_markdown());
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        t.row(vec![Table::f(0.123456), Table::f(12345.6)]);
        let csv = t.to_csv();
        assert!(csv.starts_with("a,b\n"));
        assert!(csv.contains("\"x,y\""));
        let md = t.to_markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| a "));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(Table::f(0.0), "0");
        assert_eq!(Table::f(1.5), "1.5000");
        assert!(Table::f(1e-9).contains('e'));
        assert!(Table::f(1.23e6).contains('e'));
    }

    #[test]
    fn table_and_figure_json_round_trip() {
        let mut t = Table::new("series", &["x", "y"]);
        t.row(vec!["1".into(), "a,b".into()]);
        let j = t.to_json();
        // must survive the wire codec
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(again.get("title").and_then(Json::as_str), Some("series"));
        assert_eq!(again.get("headers").unwrap().items().len(), 2);
        assert_eq!(
            again.get("rows").unwrap().items()[0].items()[1].as_str(),
            Some("a,b")
        );

        let mut fr = FigureResult::new("figX");
        fr.tables.push(t);
        fr.check("gap", ">= 1.5 b", "1.2 b", false);
        let j = Json::parse(&fr.to_json().to_string()).unwrap();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("figX"));
        assert_eq!(j.get("all_hold"), Some(&Json::Bool(false)));
        let checks = j.get("checks").unwrap().items();
        assert_eq!(checks.len(), 1);
        assert_eq!(checks[0].get("holds"), Some(&Json::Bool(false)));
        assert_eq!(j.get("tables").unwrap().items().len(), 1);
    }

    #[test]
    fn figure_result_emits_files() {
        let dir = std::env::temp_dir().join("grcim_test_report");
        let _ = std::fs::remove_dir_all(&dir);
        let mut fr = FigureResult::new("figX");
        let mut t = Table::new("series", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        fr.tables.push(t);
        fr.check("gap", ">= 1.5 b", "1.7 b", true);
        let text = fr.emit(&dir).unwrap();
        assert!(text.contains("figX"));
        assert!(dir.join("figX_series.csv").exists());
        assert!(dir.join("figX_checks.csv").exists());
        assert!(fr.all_hold());
    }
}
