//! Streaming statistics: O(1)-memory moment accumulators, histograms, and
//! the SQNR/N_eff reductions the spec solver and figures consume.

use crate::util::db;

/// Streaming first/second moments (mergeable across worker batches).
#[derive(Debug, Clone, Default)]
pub struct Moments {
    /// Number of accumulated samples.
    pub n: u64,
    /// Running sum of samples.
    pub sum: f64,
    /// Running sum of squared samples.
    pub sum_sq: f64,
}

impl Moments {
    /// Accumulate one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
    }

    /// Accumulate every sample of a slice.
    pub fn push_slice(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    /// Fold another accumulator in (exact: plain sum addition).
    pub fn merge(&mut self, other: &Moments) {
        self.n += other.n;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
    }

    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// E[x^2] — the power of the accumulated quantity.
    pub fn mean_sq(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_sq / self.n as f64
        }
    }

    /// Population variance (0 when empty; clamped non-negative).
    pub fn variance(&self) -> f64 {
        let m = self.mean();
        (self.mean_sq() - m * m).max(0.0)
    }
}

/// Fixed-range histogram (for the Fig. 4 distribution panels).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Lower edge of the binned range.
    pub lo: f64,
    /// Upper edge of the binned range.
    pub hi: f64,
    /// Per-bin sample counts (out-of-range samples clamp to the edges).
    pub counts: Vec<u64>,
    /// Total samples binned (NaN samples are excluded — see [`Histogram::push`]).
    pub total: u64,
    /// NaN samples seen and skipped. NaN is not a value on the binned
    /// axis: `NaN as isize` is 0, so counting it would silently inflate
    /// bin 0 *and* `total`, skewing [`Histogram::density`].
    pub nan_count: u64,
}

impl Histogram {
    /// An empty histogram of `bins` equal bins over [`lo`, `hi`].
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0, nan_count: 0 }
    }

    /// Bin one sample (out-of-range values clamp to the edge bins; NaN
    /// is tracked in [`Histogram::nan_count`] and binned nowhere).
    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            self.nan_count += 1;
            return;
        }
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1);
        self.counts[idx as usize] += 1;
        self.total += 1;
    }

    /// Bin every sample of a slice.
    pub fn push_slice(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    /// True when `other` shares this histogram's exact bin layout —
    /// same `lo`, same `hi` (bit-compared; the edges come from shared
    /// constants, never arithmetic), same bin count — so their per-bin
    /// counts mean the same intervals and may be added.
    pub fn compatible(&self, other: &Histogram) -> bool {
        self.lo.to_bits() == other.lo.to_bits()
            && self.hi.to_bits() == other.hi.to_bits()
            && self.counts.len() == other.counts.len()
    }

    /// Fold another histogram with identical binning in. Panics on a
    /// bin-layout mismatch: merging histograms over different ranges
    /// would silently attribute counts to the wrong intervals (a bin
    /// index only names an interval relative to its own `lo`/`hi`), so
    /// an aggregation bug must fail loudly, not skew the figure panels.
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            self.compatible(other),
            "histogram merge with mismatched bins: [{}, {}] x{} vs [{}, {}] x{}",
            self.lo,
            self.hi,
            self.counts.len(),
            other.lo,
            other.hi,
            other.counts.len()
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.nan_count += other.nan_count;
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f64> {
        let bins = self.counts.len();
        let w = (self.hi - self.lo) / bins as f64;
        (0..bins).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }

    /// Normalized densities (sum * bin_width = 1).
    pub fn density(&self) -> Vec<f64> {
        let bins = self.counts.len();
        let w = (self.hi - self.lo) / bins as f64;
        let norm = (self.total.max(1)) as f64 * w;
        self.counts.iter().map(|&c| c as f64 / norm).collect()
    }
}

/// The full aggregate of one column-simulation experiment: every moment the
/// ADC spec solver (`spec::`) and the figure harness need, streamed over
/// Monte-Carlo batches from either engine (PJRT or pure Rust).
#[derive(Debug, Clone, Default)]
pub struct ColumnAgg {
    /// Array depth the samples were produced with.
    pub nr: usize,
    /// E[z_ideal^2] — output signal power.
    pub sig: Moments,
    /// E[(z_q - z_ideal)^2] — empirical input-quantization noise.
    pub qerr: Moments,
    /// E[nf] — FP ulp-based input noise floor (the GR-side ADC spec
    /// reference).
    pub nf: Moments,
    /// E[w_q^2] — conventional INT-grid floor ingredient.
    pub wq2: Moments,
    /// E[g_conv^2] — conventional-path ADC noise referral power.
    pub g_conv: Moments,
    /// E[(S/NR)^2] — GR unit-normalization referral power.
    pub g_unit: Moments,
    /// E[(S_x/NR)^2] — GR row-normalization referral power (weights are
    /// statically aligned, so only the input factor applies).
    pub g_row: Moments,
    /// N_eff = S^2/S2 statistics (paper Sec. III-B2).
    pub n_eff: Moments,
    /// Conventional ADC-input amplitudes (signal-power comparisons,
    /// Fig. 4).
    pub v_conv: Moments,
    /// GR ADC-input amplitudes (signal-power comparisons, Fig. 4).
    pub v_gr: Moments,
}

/// One batch of per-sample outputs in the artifact's layout (see
/// `python/compile/kernels/ref.py` for definitions).
///
/// Batches are designed for reuse: [`ColumnBatch::reset`] clears the
/// per-sample vectors while keeping their heap capacity, so the chunked
/// simulation path (`mac::simulate_column_into`) runs allocation-free in
/// steady state.
#[derive(Debug, Clone, Default)]
pub struct ColumnBatch {
    /// Array depth the samples were simulated at.
    pub nr: usize,
    /// Ideal (unquantized) outputs `z = (1/NR) Σ x_i w_i`.
    pub z_ideal: Vec<f64>,
    /// Quantized-chain outputs.
    pub z_q: Vec<f64>,
    /// Conventional compute-line voltages (`z_q / g_conv`).
    pub v_conv: Vec<f64>,
    /// Conventional static alignment gains.
    pub g_conv: Vec<f64>,
    /// GR column voltages (exponent-weighted mantissa-product averages).
    pub v_gr: Vec<f64>,
    /// Exponent-weight sums `S = Σ u_i`.
    pub s_sum: Vec<f64>,
    /// Squared-weight sums `S₂ = Σ u_i²` (the N_eff denominator).
    pub s2_sum: Vec<f64>,
    /// Input-exponent-only sums `S_x` (row-normalization referral).
    pub sx_sum: Vec<f64>,
    /// Weight-side block alignment gains.
    pub g_w: Vec<f64>,
    /// Output-referred input ulp noise floors.
    pub nf: Vec<f64>,
    /// Mean squared quantized weights per sample.
    pub wq2_mean: Vec<f64>,
}

impl ColumnBatch {
    /// A batch with no samples for array depth `nr` (no allocation yet).
    pub fn empty(nr: usize) -> Self {
        ColumnBatch { nr, ..Default::default() }
    }

    /// Number of samples in the batch.
    pub fn len(&self) -> usize {
        self.z_ideal.len()
    }

    /// True when the batch holds no samples.
    pub fn is_empty(&self) -> bool {
        self.z_ideal.is_empty()
    }

    /// Re-target the batch to array depth `nr` and drop all samples,
    /// keeping every vector's capacity for reuse.
    pub fn reset(&mut self, nr: usize) {
        self.nr = nr;
        self.z_ideal.clear();
        self.z_q.clear();
        self.v_conv.clear();
        self.g_conv.clear();
        self.v_gr.clear();
        self.s_sum.clear();
        self.s2_sum.clear();
        self.sx_sum.clear();
        self.g_w.clear();
        self.nf.clear();
        self.wq2_mean.clear();
    }

    /// Reserve room for `additional` more samples in every field.
    pub fn reserve(&mut self, additional: usize) {
        self.z_ideal.reserve(additional);
        self.z_q.reserve(additional);
        self.v_conv.reserve(additional);
        self.g_conv.reserve(additional);
        self.v_gr.reserve(additional);
        self.s_sum.reserve(additional);
        self.s2_sum.reserve(additional);
        self.sx_sum.reserve(additional);
        self.g_w.reserve(additional);
        self.nf.reserve(additional);
        self.wq2_mean.reserve(additional);
    }
}

impl ColumnAgg {
    /// An empty aggregate for array depth `nr`.
    pub fn new(nr: usize) -> Self {
        ColumnAgg { nr, ..Default::default() }
    }

    /// Accumulate every sample of a batch (must match this depth).
    pub fn push_batch(&mut self, b: &ColumnBatch) {
        self.push_batch_range(b, 0, b.len());
    }

    /// Accumulate samples `lo..hi` of a batch (must match this depth).
    /// The tile mapper uses this to discard batch-padding samples an AOT
    /// engine required without copying the batch.
    pub fn push_batch_range(&mut self, b: &ColumnBatch, lo: usize, hi: usize) {
        assert_eq!(self.nr, b.nr, "batch from a different array depth");
        assert!(lo <= hi && hi <= b.len(), "range {lo}..{hi} out of batch");
        let nr = b.nr as f64;
        for i in lo..hi {
            self.sig.push(b.z_ideal[i]);
            self.qerr.push(b.z_q[i] - b.z_ideal[i]);
            self.nf.push(b.nf[i]);
            self.wq2.push(b.wq2_mean[i]);
            self.g_conv.push(b.g_conv[i]);
            self.g_unit.push(b.s_sum[i] / nr);
            self.g_row.push(b.sx_sum[i] / nr);
            self.n_eff.push(b.s_sum[i] * b.s_sum[i] / b.s2_sum[i]);
            self.v_conv.push(b.v_conv[i]);
            self.v_gr.push(b.v_gr[i]);
        }
    }

    /// Fold another aggregate of the same depth in (exact).
    pub fn merge(&mut self, other: &ColumnAgg) {
        assert_eq!(self.nr, other.nr);
        self.sig.merge(&other.sig);
        self.qerr.merge(&other.qerr);
        self.nf.merge(&other.nf);
        self.wq2.merge(&other.wq2);
        self.g_conv.merge(&other.g_conv);
        self.g_unit.merge(&other.g_unit);
        self.g_row.merge(&other.g_row);
        self.n_eff.merge(&other.n_eff);
        self.v_conv.merge(&other.v_conv);
        self.v_gr.merge(&other.v_gr);
    }

    /// Number of Monte-Carlo samples accumulated.
    pub fn samples(&self) -> u64 {
        self.sig.n
    }

    /// Global output SQNR (dB): signal power over empirical quantization
    /// error power (Fig. 9's metric, at the MAC output).
    pub fn sqnr_db(&self) -> f64 {
        db(self.sig.mean_sq() / self.qerr.mean_sq().max(1e-300))
    }

    /// Mean effective number of contributors (paper: N_eff <= NR).
    pub fn mean_n_eff(&self) -> f64 {
        self.n_eff.mean()
    }

    /// GR-over-conventional ADC-input power ratio (Fig. 4's "20x").
    pub fn signal_power_gain(&self) -> f64 {
        self.v_gr.mean_sq() / self.v_conv.mean_sq().max(1e-300)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn moments_basic() {
        let mut m = Moments::default();
        m.push_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.n, 4);
        assert!(approx_eq(m.mean(), 2.5, 1e-15));
        assert!(approx_eq(m.mean_sq(), 7.5, 1e-15));
        assert!(approx_eq(m.variance(), 1.25, 1e-12));
    }

    #[test]
    fn moments_merge_equals_concat() {
        let mut a = Moments::default();
        let mut b = Moments::default();
        let mut all = Moments::default();
        for i in 0..100 {
            let x = (i as f64).sin();
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.n, all.n);
        assert!(approx_eq(a.mean(), all.mean(), 1e-12));
        assert!(approx_eq(a.mean_sq(), all.mean_sq(), 1e-12));
    }

    #[test]
    fn empty_moments_are_zero() {
        let m = Moments::default();
        assert_eq!(m.mean(), 0.0);
        assert_eq!(m.mean_sq(), 0.0);
        assert_eq!(m.variance(), 0.0);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        h.push_slice(&[-0.9, -0.1, 0.1, 0.9, 5.0, -5.0]); // outliers clamp
        assert_eq!(h.total, 6);
        assert_eq!(h.counts, vec![2, 1, 1, 2]);
    }

    #[test]
    fn histogram_skips_nan_instead_of_binning_it_as_zero() {
        // the regression this pins: `NaN as isize` is 0, so NaN used to
        // land in bin 0 and count toward `total`, skewing density()
        let mut h = Histogram::new(-1.0, 1.0, 4);
        h.push_slice(&[f64::NAN, -0.9, f64::NAN, 0.9]);
        assert_eq!(h.total, 2);
        assert_eq!(h.nan_count, 2);
        assert_eq!(h.counts, vec![1, 0, 0, 1]);
        // density still integrates to 1 over the real samples
        let w = 0.5;
        let integral: f64 = h.density().iter().map(|d| d * w).sum();
        assert!(approx_eq(integral, 1.0, 1e-12));
        // merge carries the NaN count along
        let mut other = Histogram::new(-1.0, 1.0, 4);
        other.push(f64::NAN);
        h.merge(&other);
        assert_eq!((h.total, h.nan_count), (2, 3));
    }

    #[test]
    fn histogram_density_integrates_to_one() {
        let mut h = Histogram::new(0.0, 2.0, 10);
        let mut rng = crate::rng::Pcg64::seeded(3);
        for _ in 0..10_000 {
            h.push(rng.uniform_in(0.0, 2.0));
        }
        let w = 0.2;
        let integral: f64 = h.density().iter().map(|d| d * w).sum();
        assert!(approx_eq(integral, 1.0, 1e-12));
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new(0.0, 1.0, 2);
        let mut b = Histogram::new(0.0, 1.0, 2);
        a.push(0.25);
        b.push(0.75);
        a.merge(&b);
        assert_eq!(a.counts, vec![1, 1]);
        assert_eq!(a.total, 2);
    }

    #[test]
    fn histogram_compatibility_checks_the_full_bin_layout() {
        let base = Histogram::new(0.0, 1.0, 4);
        assert!(base.compatible(&Histogram::new(0.0, 1.0, 4)));
        // each layout ingredient separates
        assert!(!base.compatible(&Histogram::new(0.5, 1.0, 4)));
        assert!(!base.compatible(&Histogram::new(0.0, 2.0, 4)));
        assert!(!base.compatible(&Histogram::new(0.0, 1.0, 8)));
    }

    #[test]
    #[should_panic(expected = "mismatched bins")]
    fn histogram_merge_rejects_mismatched_ranges() {
        // same bin count but a different range: the old length-only
        // check would silently add counts of disjoint intervals
        let mut a = Histogram::new(0.0, 1.0, 4);
        let b = Histogram::new(-1.0, 1.0, 4);
        a.merge(&b);
    }

    fn tiny_batch() -> ColumnBatch {
        ColumnBatch {
            nr: 4,
            z_ideal: vec![0.1, -0.2],
            z_q: vec![0.11, -0.19],
            v_conv: vec![0.4, -0.5],
            g_conv: vec![1.0, 0.5],
            v_gr: vec![0.6, -0.7],
            s_sum: vec![2.0, 4.0],
            s2_sum: vec![2.0, 4.0],
            sx_sum: vec![2.0, 3.0],
            g_w: vec![1.0, 0.5],
            nf: vec![1e-6, 2e-6],
            wq2_mean: vec![0.3, 0.4],
        }
    }

    #[test]
    fn column_agg_accumulates() {
        let mut agg = ColumnAgg::new(4);
        agg.push_batch(&tiny_batch());
        assert_eq!(agg.samples(), 2);
        // N_eff entries: 4/2=2 and 16/4=4 -> mean 3
        assert!(approx_eq(agg.mean_n_eff(), 3.0, 1e-12));
        // g_unit mean-sq: (0.5^2 + 1^2)/2
        assert!(approx_eq(agg.g_unit.mean_sq(), (0.25 + 1.0) / 2.0, 1e-12));
        // g_row entries: 2/4=0.5, 3/4=0.75
        assert!(approx_eq(
            agg.g_row.mean_sq(),
            (0.25 + 0.5625) / 2.0,
            1e-12
        ));
    }

    #[test]
    fn column_agg_merge_equals_two_pushes() {
        let mut a = ColumnAgg::new(4);
        a.push_batch(&tiny_batch());
        let mut b = ColumnAgg::new(4);
        b.push_batch(&tiny_batch());
        let mut m = ColumnAgg::new(4);
        m.push_batch(&tiny_batch());
        m.push_batch(&tiny_batch());
        a.merge(&b);
        assert_eq!(a.samples(), m.samples());
        assert!(approx_eq(a.nf.sum, m.nf.sum, 1e-15));
    }

    #[test]
    fn column_agg_range_matches_prefix_pushes() {
        let b = tiny_batch();
        let mut full = ColumnAgg::new(4);
        full.push_batch(&b);
        let mut prefix = ColumnAgg::new(4);
        prefix.push_batch_range(&b, 0, 1);
        assert_eq!(prefix.samples(), 1);
        assert_eq!(prefix.sig.sum.to_bits(), b.z_ideal[0].to_bits());
        // prefix + suffix == full, bit-exact
        prefix.push_batch_range(&b, 1, 2);
        assert_eq!(prefix.samples(), full.samples());
        assert_eq!(prefix.nf.sum.to_bits(), full.nf.sum.to_bits());
        assert_eq!(prefix.n_eff.sum.to_bits(), full.n_eff.sum.to_bits());
        // empty range is a no-op
        prefix.push_batch_range(&b, 2, 2);
        assert_eq!(prefix.samples(), 2);
    }

    #[test]
    #[should_panic(expected = "out of batch")]
    fn column_agg_range_bounds_checked() {
        let mut agg = ColumnAgg::new(4);
        agg.push_batch_range(&tiny_batch(), 0, 3);
    }

    #[test]
    #[should_panic(expected = "different array depth")]
    fn column_agg_rejects_mismatched_nr() {
        let mut agg = ColumnAgg::new(8);
        agg.push_batch(&tiny_batch());
    }

    #[test]
    fn column_batch_reset_keeps_capacity() {
        let mut b = tiny_batch();
        let cap = b.z_q.capacity();
        b.reset(16);
        assert_eq!(b.nr, 16);
        assert!(b.is_empty());
        assert_eq!(b.z_q.capacity(), cap);
        b.reserve(8);
        assert!(b.z_q.capacity() >= 8);
        assert_eq!(ColumnBatch::empty(4).nr, 4);
        assert!(ColumnBatch::empty(4).is_empty());
    }
}
