//! Deterministic pseudo-random number generation, built from scratch (the
//! offline vendor set has no `rand` crate).
//!
//! * [`SplitMix64`] — seed expansion / hashing (Steele et al., 2014).
//! * [`Pcg64`] — PCG XSL-RR 128/64 (O'Neill, 2014): the campaign workhorse.
//!   128-bit state, 64-bit output, period 2^128, passes BigCrush.
//!
//! Campaign jobs derive their streams as
//! `Pcg64::seeded(job_seed(campaign_seed, grid_index, batch_index))` so any
//! batch of any experiment is reproducible in isolation (DESIGN.md #8).

/// SplitMix64: used to expand user seeds and hash job coordinates.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the generator (any u64, including 0).
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 pseudo-random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Stable 64-bit hash of job coordinates -> per-job seed.
pub fn job_seed(campaign_seed: u64, grid_index: u64, batch_index: u64) -> u64 {
    let mut sm = SplitMix64::new(
        campaign_seed ^ grid_index.rotate_left(21) ^ batch_index.rotate_left(42),
    );
    // a few rounds decorrelate adjacent coordinates
    sm.next_u64();
    sm.next_u64()
}

/// PCG XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let mut rng = Pcg64 { state: 0, inc: (inc << 1) | 1 };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(state);
        rng.next_u64();
        rng
    }

    /// Next 64 pseudo-random bits (XSL-RR output permutation).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use;
    /// modulo bias is negligible for n << 2^64 but we reject anyway).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via polar Box-Muller (cached second value).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Random sign, +1.0 or -1.0.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Transition coefficients `(m, a)` such that applying `delta` raw LCG
    /// steps maps `state -> m*state + a` (O'Neill's square-multiply jump,
    /// O(log delta)). Pure function of `self.inc`.
    fn jump_coeffs(&self, delta: u64) -> (u128, u128) {
        let mut cur_mult = PCG_MULT;
        let mut cur_add = self.inc;
        let mut acc_mult: u128 = 1;
        let mut acc_add: u128 = 0;
        let mut d = delta;
        while d > 0 {
            if d & 1 == 1 {
                acc_mult = acc_mult.wrapping_mul(cur_mult);
                acc_add = acc_add.wrapping_mul(cur_mult).wrapping_add(cur_add);
            }
            cur_add = cur_mult.wrapping_add(1).wrapping_mul(cur_add);
            cur_mult = cur_mult.wrapping_mul(cur_mult);
            d >>= 1;
        }
        (acc_mult, acc_add)
    }

    /// XSL-RR output permutation of a raw LCG state.
    #[inline]
    fn output(state: u128) -> u64 {
        let rot = (state >> 122) as u32;
        (((state >> 64) as u64) ^ (state as u64)).rotate_right(rot)
    }

    /// Fill `out` with the exact sequence `next_u64()` would produce,
    /// leaving the generator in the exact state repeated calls would.
    ///
    /// Runs 4 leapfrogged LCG lanes so the serial 128-bit multiply chain —
    /// the latency bottleneck of `next_u64` — pipelines across independent
    /// chains, while the interleaved outputs reproduce the sequential
    /// stream bit-for-bit.
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        const LANES: usize = 4;
        if out.len() < 2 * LANES {
            for v in out.iter_mut() {
                *v = self.next_u64();
            }
            return;
        }
        // lane j starts at state after (j+1) raw steps and then strides by
        // LANES steps: its outputs are stream positions j, j+LANES, ...
        let (m, a) = self.jump_coeffs(LANES as u64);
        let mut lane = [0u128; LANES];
        for l in lane.iter_mut() {
            self.state =
                self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
            *l = self.state;
        }
        let mut chunks = out.chunks_exact_mut(LANES);
        let mut first = true;
        for chunk in &mut chunks {
            if !first {
                for l in lane.iter_mut() {
                    *l = l.wrapping_mul(m).wrapping_add(a);
                }
            }
            first = false;
            for (o, &l) in chunk.iter_mut().zip(lane.iter()) {
                *o = Self::output(l);
            }
        }
        // generator state after the vector body = last lane's state
        self.state = lane[LANES - 1];
        for v in chunks.into_remainder().iter_mut() {
            *v = self.next_u64();
        }
    }

    /// Fill `out` with the exact sequence `normal()` would produce,
    /// leaving the generator in the exact state repeated calls would.
    ///
    /// Draws uniforms in blocks through [`Pcg64::fill_u64`] and runs the
    /// same polar rejection over the block; the final state is re-derived
    /// by jumping the entry state forward by the number of raw draws the
    /// rejection loop actually consumed.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        if out.is_empty() {
            return;
        }
        const BLOCK: usize = 128;
        let s0 = self.state;
        let mut buf = [0u64; BLOCK];
        let mut pos = BLOCK; // empty
        let mut consumed: u64 = 0;
        const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
        for o in out.iter_mut() {
            loop {
                // attempts consume aligned pairs, so pos is always even and
                // the buffer drains exactly at BLOCK — the raw stream
                // position at refill time is already self.state
                if pos == BLOCK {
                    self.fill_u64(&mut buf);
                    pos = 0;
                }
                let u = 2.0 * ((buf[pos] >> 11) as f64 * SCALE) - 1.0;
                let v = 2.0 * ((buf[pos + 1] >> 11) as f64 * SCALE) - 1.0;
                pos += 2;
                consumed += 2;
                let s = u * u + v * v;
                if s > 0.0 && s < 1.0 {
                    *o = u * (-2.0 * s.ln() / s).sqrt();
                    break;
                }
            }
        }
        let (m, a) = self.jump_coeffs(consumed);
        self.state = s0.wrapping_mul(m).wrapping_add(a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Pcg64::seeded(11);
        // Under Miri the point is UB detection in the sampler, not
        // statistics; the moment tolerances are calibrated to the full n.
        let n = if cfg!(miri) { 1_000 } else { 200_000 };
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        if cfg!(miri) {
            return;
        }
        assert!(approx_eq(mean, 0.5, 0.01), "mean={mean}");
        assert!(approx_eq(var, 1.0 / 12.0, 0.02), "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(13);
        let n = if cfg!(miri) { 1_000 } else { 200_000 };
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        if cfg!(miri) {
            return;
        }
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!(approx_eq(var, 1.0, 0.02), "var={var}");
        // tail sanity: ~0.27% beyond 3 sigma
        let tail = xs.iter().filter(|x| x.abs() > 3.0).count() as f64 / n as f64;
        assert!(tail > 0.001 && tail < 0.006, "tail={tail}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(17);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = rng.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sign_is_balanced() {
        let mut rng = Pcg64::seeded(19);
        let pos = (0..10_000).filter(|_| rng.sign() > 0.0).count();
        assert!((4500..5500).contains(&pos), "pos={pos}");
    }

    #[test]
    fn job_seed_decorrelates_coordinates() {
        let a = job_seed(1, 0, 0);
        let b = job_seed(1, 0, 1);
        let c = job_seed(1, 1, 0);
        let d = job_seed(2, 0, 0);
        assert!(a != b && a != c && a != d && b != c);
        // stable across calls
        assert_eq!(a, job_seed(1, 0, 0));
    }

    #[test]
    fn fill_u64_matches_sequential_stream_across_chunk_boundaries() {
        // lane width is 4; cover 0, 1, lane-1, lane, lane+1, 2*lane-1,
        // 2*lane (first vectorized length), odd remainders, and large
        for len in [0usize, 1, 3, 4, 5, 7, 8, 9, 13, 64, 1000, 4097] {
            let mut seq = Pcg64::seeded(0xBA7C);
            let mut bat = Pcg64::seeded(0xBA7C);
            let expect: Vec<u64> = (0..len).map(|_| seq.next_u64()).collect();
            let mut got = vec![0u64; len];
            bat.fill_u64(&mut got);
            assert_eq!(expect, got, "len={len}");
            // the generator state must also land where sequential did
            assert_eq!(seq.next_u64(), bat.next_u64(), "state after len={len}");
        }
    }

    #[test]
    fn fill_u64_is_resumable_mid_stream() {
        let mut seq = Pcg64::seeded(99);
        let expect: Vec<u64> = (0..100).map(|_| seq.next_u64()).collect();
        let mut bat = Pcg64::seeded(99);
        let mut got = vec![0u64; 100];
        // split the same stream across differently-sized fill calls
        let (a, rest) = got.split_at_mut(7);
        let (b, c) = rest.split_at_mut(41);
        bat.fill_u64(a);
        bat.fill_u64(b);
        bat.fill_u64(c);
        assert_eq!(expect, got);
    }

    #[test]
    fn fill_normal_matches_sequential_stream_across_chunk_boundaries() {
        // rejection consumes a variable number of raw draws per output, so
        // these lengths also exercise the block-refill and final-jump paths
        for len in [0usize, 1, 3, 4, 5, 63, 64, 65, 2000] {
            let mut seq = Pcg64::seeded(0x90AA);
            let mut bat = Pcg64::seeded(0x90AA);
            let expect: Vec<f64> = (0..len).map(|_| seq.normal()).collect();
            let mut got = vec![0.0f64; len];
            bat.fill_normal(&mut got);
            let eb: Vec<u64> = expect.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(eb, gb, "len={len}");
            assert_eq!(seq.next_u64(), bat.next_u64(), "state after len={len}");
        }
    }

    #[test]
    fn jump_coeffs_match_stepping() {
        let rng = Pcg64::seeded(5);
        for delta in [0u64, 1, 2, 3, 7, 128, 1000] {
            let mut stepped = rng.clone();
            for _ in 0..delta {
                stepped.next_u64();
            }
            let (m, a) = rng.jump_coeffs(delta);
            let jumped = rng.state.wrapping_mul(m).wrapping_add(a);
            assert_eq!(stepped.state, jumped, "delta={delta}");
        }
    }

    #[test]
    fn splitmix_known_sequence_is_stable() {
        let mut sm = SplitMix64::new(0);
        let first = sm.next_u64();
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(first, sm2.next_u64());
        assert_ne!(first, sm.next_u64());
    }
}
