//! Minimal CLI argument parser (the vendor set has no clap).
//!
//! Grammar: `grcim <command> [--flag value] [--switch] [positional...]`.
//! Flags may appear in any order; `--flag=value` is also accepted.
//!
//! The per-subcommand flag sets live in [`flags`] so `main.rs` and the
//! tests validate against the same registry; the full flag reference is
//! `docs/CLI.md`.

pub mod sweep;

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Known value-taking flags per subcommand (`Args::ensure_known` input).
/// `main.rs` consumes these; the tests typo-check against them.
pub mod flags {
    /// Flags shared by every campaign-running subcommand.
    pub const CAMPAIGN: &[&str] = &["engine", "artifacts", "workers", "seed"];

    /// `grcim figures` flags.
    pub const FIGURES: &[&str] =
        &["fig", "out", "samples", "engine", "artifacts", "workers", "seed"];
    /// `grcim energy` flags.
    pub const ENERGY: &[&str] = &[
        "dr", "sqnr", "samples", "sampler", "target-ci", "engine", "artifacts", "workers",
        "seed",
    ];
    /// `grcim validate` flags.
    pub const VALIDATE: &[&str] = &["artifacts", "samples", "seed"];
    /// `grcim sweep` flags.
    pub const SWEEP: &[&str] = &["config"];
    /// `grcim info` flags.
    pub const INFO: &[&str] = &["artifacts"];
    /// `grcim serve` flags.
    pub const SERVE: &[&str] = &[
        "addr", "cache", "mux", "compute", "queue", "engine", "artifacts", "workers", "seed",
    ];
    /// `grcim loadgen` flags.
    pub const LOADGEN: &[&str] = &[
        "addr", "conns", "requests", "mix", "json", "threads", "deadline", "samples", "loris-ms",
    ];
    /// `grcim query` flags.
    pub const QUERY: &[&str] = &[
        "addr", "json", "dr", "sqnr", "samples", "sampler", "seed", "id", "trace", "shape",
        "tokens", "arch", "nr", "nc", "ne", "nm", "dist", "model", "plan",
    ];
    /// `grcim workload` flags.
    pub const WORKLOAD: &[&str] =
        &["trace", "out", "samples", "engine", "artifacts", "workers", "seed"];
    /// `grcim layer` flags.
    pub const LAYER: &[&str] = &[
        "shape", "tokens", "arch", "nr", "nc", "ne", "nm", "dist", "out", "engine",
        "artifacts", "workers", "seed",
    ];
    /// `grcim model` flags (`--fit` is a switch, not listed here).
    pub const MODEL: &[&str] = &[
        "model", "tokens", "arch", "nr", "nc", "ne", "nm", "dist", "out", "engine",
        "artifacts", "workers", "seed",
    ];
    /// `grcim explore` flags.
    pub const EXPLORE: &[&str] = &[
        "plan", "out", "ckpt", "resume", "engine", "artifacts", "workers", "seed",
    ];
}

/// Expand a `--fig` value: `"all"` maps to the full list, otherwise a
/// comma-separated selection (whitespace tolerated, empties dropped).
pub fn fig_list(which: &str, all: &[&str]) -> Vec<String> {
    if which == "all" {
        all.iter().map(|s| s.to_string()).collect()
    } else {
        which
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first non-flag argument).
    pub command: String,
    /// Value-taking flags, e.g. `--samples 4096`.
    pub flags: BTreeMap<String, String>,
    /// Valueless switches, e.g. `--quick`.
    pub switches: Vec<String>,
    /// Remaining positional arguments, in order.
    pub positional: Vec<String>,
}

/// Switch-style flags (no value).
const SWITCHES: &[&str] = &["quick", "verbose", "quiet", "help", "fit"];

/// Switches every subcommand accepts (logging/help/figure-budget).
pub const GLOBAL_SWITCHES: &[&str] = &["quick", "verbose", "quiet", "help"];

impl Args {
    /// Parse an argument vector (excluding the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if SWITCHES.contains(&name) {
                    args.switches.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("--{name} needs a value"))?;
                    args.flags.insert(name.to_string(), v.clone());
                }
            } else if args.command.is_empty() {
                args.command = a.clone();
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    /// Whether a switch was passed.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// A flag's value, if passed.
    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(String::as_str)
    }

    /// A flag's value, or `default` when absent.
    pub fn get_or<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    /// A flag parsed as usize (`default` when absent; parse errors are
    /// reported with the flag name).
    pub fn get_usize(&self, flag: &str, default: usize) -> Result<usize> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .with_context(|| format!("--{flag} expects an integer, got '{v}'")),
        }
    }

    /// A flag parsed as u64 (`default` when absent).
    pub fn get_u64(&self, flag: &str, default: u64) -> Result<u64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .with_context(|| format!("--{flag} expects an integer, got '{v}'")),
        }
    }

    /// A flag parsed as f64 (`default` when absent).
    pub fn get_f64(&self, flag: &str, default: f64) -> Result<f64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .with_context(|| format!("--{flag} expects a number, got '{v}'")),
        }
    }

    /// Error on unknown flags (catches typos early).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }

    /// Error on switches the subcommand does not use — the switch
    /// analogue of [`Args::ensure_known`] ([`GLOBAL_SWITCHES`] are
    /// always accepted). Without this, a command-specific switch like
    /// `--fit` would be silently accepted and ignored everywhere.
    pub fn ensure_known_switches(&self, extra: &[&str]) -> Result<()> {
        for s in &self.switches {
            if !GLOBAL_SWITCHES.contains(&s.as_str()) && !extra.contains(&s.as_str()) {
                bail!("--{s} does not apply to this command");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn basic_command_and_flags() {
        let a = parse(&["figures", "--fig", "fig10", "--samples", "1000", "--quick"]);
        assert_eq!(a.command, "figures");
        assert_eq!(a.get("fig"), Some("fig10"));
        assert_eq!(a.get_usize("samples", 0).unwrap(), 1000);
        assert!(a.has("quick"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["energy", "--dr=30.1", "--sqnr=22.8"]);
        assert_eq!(a.get_f64("dr", 0.0).unwrap(), 30.1);
        assert_eq!(a.get_f64("sqnr", 0.0).unwrap(), 22.8);
    }

    #[test]
    fn positional_args() {
        let a = parse(&["sweep", "configs/fig12.toml"]);
        assert_eq!(a.positional, vec!["configs/fig12.toml"]);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(&["figures".into(), "--fig".into()]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--samples", "abc"]);
        assert!(a.get_usize("samples", 0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["x", "--smaples", "3"]);
        assert!(a.ensure_known(&["samples"]).is_err());
        assert!(a.ensure_known(&["smaples"]).is_ok());
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("engine", "auto"), "auto");
        assert_eq!(a.get_usize("samples", 42).unwrap(), 42);
    }

    #[test]
    fn typoed_flags_rejected_per_subcommand() {
        // a typo'd --samples against each registry entry that accepts it
        for known in [flags::FIGURES, flags::ENERGY, flags::VALIDATE] {
            let a = parse(&["x", "--smaples", "64"]);
            let err = a.ensure_known(known).unwrap_err().to_string();
            assert!(err.contains("--smaples"), "{err}");
            assert!(err.contains("known:"), "{err}");
        }
        // serve/query/loadgen accept their own flags…
        let a = parse(&[
            "serve", "--addr", "127.0.0.1:0", "--cache", "64", "--mux", "2", "--compute", "2",
            "--queue", "32",
        ]);
        assert!(a.ensure_known(flags::SERVE).is_ok());
        let a = parse(&["query", "--json", "{}"]);
        assert!(a.ensure_known(flags::QUERY).is_ok());
        let a = parse(&[
            "loadgen", "--conns", "1000", "--requests", "4", "--mix", "energy,info",
            "--loris-ms", "50", "--deadline", "200",
        ]);
        assert!(a.ensure_known(flags::LOADGEN).is_ok());
        // …and reject each other's
        let a = parse(&["query", "--cache", "64"]);
        assert!(a.ensure_known(flags::QUERY).is_err());
        let a = parse(&["loadgen", "--cache", "64"]);
        assert!(a.ensure_known(flags::LOADGEN).is_err());
    }

    #[test]
    fn campaign_flags_are_a_subset_everywhere_they_apply() {
        for known in [
            flags::FIGURES,
            flags::ENERGY,
            flags::SERVE,
            flags::WORKLOAD,
            flags::LAYER,
            flags::MODEL,
            flags::EXPLORE,
        ] {
            for f in flags::CAMPAIGN {
                assert!(known.contains(f), "{f} missing from {known:?}");
            }
        }
        // workload accepts its trace flag; query forwards it
        let a = parse(&["workload", "--trace", "acts.grtt", "--samples", "64"]);
        assert!(a.ensure_known(flags::WORKLOAD).is_ok());
        let a = parse(&["query", "workload", "--trace", "acts.grtt"]);
        assert!(a.ensure_known(flags::QUERY).is_ok());
        // layer accepts its shape/array flags; query forwards them
        let a = parse(&["layer", "--shape", "mlp-up:4096", "--arch", "gr", "--nc", "64"]);
        assert!(a.ensure_known(flags::LAYER).is_ok());
        let a = parse(&["query", "layer", "--shape", "qkv:1024", "--tokens", "8"]);
        assert!(a.ensure_known(flags::QUERY).is_ok());
        // model accepts its chain flags (--fit is a switch); query forwards
        let a = parse(&["model", "--model", "mlp:64x256x64", "--fit", "--nc", "64"]);
        assert!(a.ensure_known(flags::MODEL).is_ok());
        assert!(a.has("fit"));
        let a = parse(&["query", "model", "--model", "block:1024", "--tokens", "8"]);
        assert!(a.ensure_known(flags::QUERY).is_ok());
        // …but not each other's unrelated flags
        let a = parse(&["layer", "--addr", "127.0.0.1:0"]);
        assert!(a.ensure_known(flags::LAYER).is_err());
    }

    #[test]
    fn command_specific_switches_are_rejected_elsewhere() {
        // --fit only applies to model/query; other subcommands must
        // reject it instead of silently ignoring it
        let a = parse(&["layer", "--fit"]);
        assert!(a.ensure_known_switches(&[]).is_err());
        assert!(a.ensure_known_switches(&["fit"]).is_ok());
        // global switches pass everywhere
        let a = parse(&["figures", "--quick", "--verbose"]);
        assert!(a.ensure_known_switches(&[]).is_ok());
    }

    #[test]
    fn fig_list_expansion() {
        let all = ["fig4", "table1", "fig8"];
        assert_eq!(fig_list("all", &all), vec!["fig4", "table1", "fig8"]);
        assert_eq!(fig_list("fig8", &all), vec!["fig8"]);
        assert_eq!(
            fig_list("fig4, table1", &all),
            vec!["fig4", "table1"],
            "whitespace around commas is tolerated"
        );
        assert_eq!(fig_list("fig4,,table1,", &all), vec!["fig4", "table1"]);
        // unknown ids pass through — figures::run reports them properly
        assert_eq!(fig_list("fig99", &all), vec!["fig99"]);
    }
}
