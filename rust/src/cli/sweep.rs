//! Sweep-campaign construction shared by the `grcim sweep` subcommand,
//! the `grcim query sweep` client, and the serve layer's `sweep` handler —
//! one place turns "experiment descriptions" (TOML sections or JSON
//! request entries) into [`ExperimentSpec`]s, so the CLI and the service
//! cannot drift.

use crate::config::Config;
use crate::coordinator::{CampaignConfig, ExperimentSpec};
use crate::distributions::{Distribution, Sampler};
use crate::energy::{CimArch, TechParams};
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::model::ModelSpec;
use crate::runtime::EngineKind;
use crate::tile::{parse_shape, AdcPolicy, LayerSpec, TileConfig};
use anyhow::{bail, Context, Result};

/// Default Monte-Carlo samples per experiment when the config has no
/// top-level `samples` key.
pub const DEFAULT_SAMPLES: usize = 16_384;

/// Largest accepted input exponent / mantissa bit width. Far beyond
/// anything physical (the paper sweeps N_E ≤ 5), and required for
/// soundness: `FpFormat::fp` shifts `1 << n_e`, so an unchecked wire
/// value like `n_e = 64` would panic inside a worker thread instead of
/// failing validation.
pub const MAX_FORMAT_BITS: f64 = 32.0;

/// Largest accepted tile geometry (N_R rows per column / N_C columns
/// per tile), 2^20 — far beyond any physical array (the paper sweeps
/// N_R ≤ 128), and required for soundness: the serve MAC/slab caps
/// bound the GEMM *shape* only, so an unchecked wire `nr` like 10^12
/// would otherwise reach the tile mapper and make it allocate
/// `nr`-deep zero-padded operand slabs (terabytes) inside a worker.
pub const MAX_TILE_GEOM: usize = 1 << 20;

pub(crate) fn check_tile_geom(what: &str, nr: usize, nc: usize) -> Result<()> {
    if nr == 0 || nc == 0 {
        bail!("{what}: nr and nc must be positive");
    }
    if nr > MAX_TILE_GEOM || nc > MAX_TILE_GEOM {
        bail!("{what}: nr and nc must be <= {MAX_TILE_GEOM}");
    }
    Ok(())
}

pub(crate) fn check_format_bits(what: &str, n_e: f64, n_m: f64) -> Result<()> {
    // NaN fails every comparison, so the range checks alone would wave
    // it through into `as u32` / `FpFormat::fp`'s assert
    if !n_e.is_finite() || !n_m.is_finite() || n_e < 1.0 || n_m < 0.0 {
        bail!("{what}: n_e must be a finite number >= 1 and n_m >= 0");
    }
    if n_e > MAX_FORMAT_BITS || n_m > MAX_FORMAT_BITS {
        bail!("{what}: n_e and n_m must be <= {MAX_FORMAT_BITS}");
    }
    Ok(())
}

/// Input-distribution names accepted by sweep configs and requests.
/// `empirical:<trace-file>` additionally resolves a fitted
/// [`crate::workload::TensorTrace`] (the file is read where the config is
/// interpreted — client-side for `grcim sweep`, server-side for the
/// `sweep` request).
pub const DISTRIBUTIONS: &[&str] =
    &["uniform", "max_entropy", "gauss_outliers", "clipped_gauss"];

/// Resolve a distribution by its config name; `fmt` parameterizes
/// `max_entropy` (the experiment's input format).
pub fn dist_by_name(name: &str, fmt: FpFormat) -> Result<Distribution> {
    if let Some(path) = name.strip_prefix("empirical:") {
        let trace =
            crate::workload::TensorTrace::read(std::path::Path::new(path))?;
        let dist = Distribution::empirical(
            crate::workload::EmpiricalDist::fit(&trace)?,
        );
        return Ok(dist);
    }
    Ok(match name {
        "uniform" => Distribution::Uniform,
        "max_entropy" => Distribution::max_entropy(fmt),
        "gauss_outliers" => Distribution::gauss_outliers(),
        "clipped_gauss" => Distribution::clipped_gauss4(),
        other => bail!(
            "unknown distribution '{other}' (known: {}, or \
             empirical:<trace-file>)",
            DISTRIBUTIONS.join(", ")
        ),
    })
}

/// Build one experiment from sweep fields: input format FP(n_e, n_m)
/// against max-entropy FP4 weights (the paper's sweep convention).
pub fn experiment_spec(
    name: &str,
    n_e: f64,
    n_m: f64,
    nr: usize,
    distribution: &str,
    samples: usize,
) -> Result<ExperimentSpec> {
    check_format_bits(&format!("experiment '{name}'"), n_e, n_m)?;
    if nr == 0 {
        bail!("experiment '{name}': nr must be positive");
    }
    let fmt = FpFormat::fp(n_e as u32, n_m as u32);
    Ok(ExperimentSpec {
        id: name.to_string(),
        fmts: FormatPair::new(fmt, FpFormat::fp4_e2m1()),
        dist_x: dist_by_name(distribution, fmt)?,
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr,
        samples,
        sampler: Sampler::default(),
    })
}

/// The raw fields of a layer evaluation — `grcim layer` flags or the
/// wire `layer` request — before shapes, formats, and distributions
/// resolve. One resolver serves the CLI and the service, so they cannot
/// drift (the `experiment_spec` pattern, for layers).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerParams {
    /// Shape string (see [`crate::tile::parse_shape`]), e.g. `mlp-up:4096`.
    pub shape: String,
    /// Batch rows M of the named shapes (ignored by `gemm:` shapes).
    pub tokens: usize,
    /// Architecture name (see [`CimArch::parse`]); `gr` = unit granularity.
    pub arch: String,
    /// Rows per column (accumulation depth N_R).
    pub nr: usize,
    /// Columns per tile N_C.
    pub nc: usize,
    /// Input exponent bits.
    pub n_e: f64,
    /// Input mantissa bits.
    pub n_m: f64,
    /// Activation distribution name (see [`dist_by_name`]), including
    /// `empirical:<trace-file>`.
    pub distribution: String,
}

impl Default for LayerParams {
    fn default() -> Self {
        LayerParams {
            shape: String::new(),
            tokens: 4,
            arch: "gr".to_string(),
            nr: 32,
            nc: 32,
            n_e: 4.0,
            n_m: 2.0,
            distribution: "gauss_outliers".to_string(),
        }
    }
}

impl LayerParams {
    /// Resolve into a runnable [`LayerSpec`]: input format FP(n_e, n_m)
    /// against max-entropy FP4 weights (the paper's sweep convention),
    /// per-tile spec-solved ADCs, Table III technology parameters. A
    /// `conv:` shape keeps its convolution geometry so the mapper draws
    /// an image and im2col-expands it.
    pub fn resolve(&self) -> Result<LayerSpec> {
        check_format_bits(&format!("layer '{}'", self.shape), self.n_e, self.n_m)?;
        check_tile_geom(&format!("layer '{}'", self.shape), self.nr, self.nc)?;
        let shape = parse_shape(&self.shape, self.tokens)?;
        let conv = if self.shape.starts_with("conv:") {
            Some(crate::tile::ConvShape::parse(&self.shape)?)
        } else {
            None
        };
        let fmt = FpFormat::fp(self.n_e as u32, self.n_m as u32);
        let w_fmt = FpFormat::fp4_e2m1();
        Ok(LayerSpec {
            name: self.shape.clone(),
            shape,
            cfg: TileConfig {
                nr: self.nr,
                nc: self.nc,
                fmts: FormatPair::new(fmt, w_fmt),
                arch: CimArch::parse(&self.arch)?,
                adc: AdcPolicy::PerTileSpec,
                tech: TechParams::default(),
            },
            dist_x: dist_by_name(&self.distribution, fmt)?,
            dist_w: Distribution::max_entropy(w_fmt),
            conv,
        })
    }
}

/// The raw fields of a model evaluation — `grcim model` flags or the
/// wire `model` request — before the layer chain, formats, and
/// distributions resolve. One resolver serves the CLI and the service
/// (the [`LayerParams`] pattern, for whole networks).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelParams {
    /// Model string (see [`crate::model::parse_model`]):
    /// `mlp:<d0>x<d1>x...`, `block:<d_model>`, or a comma list of shape
    /// strings.
    pub model: String,
    /// Shared token/batch dimension M.
    pub tokens: usize,
    /// Architecture name (see [`CimArch::parse`]); `gr` = unit granularity.
    pub arch: String,
    /// Rows per column (accumulation depth N_R).
    pub nr: usize,
    /// Columns per tile N_C.
    pub nc: usize,
    /// Input exponent bits.
    pub n_e: f64,
    /// Input mantissa bits.
    pub n_m: f64,
    /// Model-input activation distribution name (see [`dist_by_name`]),
    /// including `empirical:<trace-file>`.
    pub distribution: String,
    /// Fit per-layer activation statistics into the report.
    pub fit: bool,
}

impl Default for ModelParams {
    fn default() -> Self {
        ModelParams {
            model: String::new(),
            tokens: 4,
            arch: "gr".to_string(),
            nr: 32,
            nc: 32,
            n_e: 4.0,
            n_m: 2.0,
            distribution: "gauss_outliers".to_string(),
            fit: false,
        }
    }
}

impl ModelParams {
    /// Resolve into a runnable [`ModelSpec`]: the library preset
    /// ([`ModelSpec::preset`] — one place owns the defaults and the
    /// ReLU rule) customized by these fields. Input format FP(n_e, n_m)
    /// against max-entropy FP4 weights, per-tile spec-solved ADCs,
    /// Table III technology parameters.
    pub fn resolve(&self) -> Result<ModelSpec> {
        check_format_bits(&format!("model '{}'", self.model), self.n_e, self.n_m)?;
        check_tile_geom(&format!("model '{}'", self.model), self.nr, self.nc)?;
        let mut spec = ModelSpec::preset(&self.model, self.tokens)?;
        let fmt = FpFormat::fp(self.n_e as u32, self.n_m as u32);
        spec.cfg.nr = self.nr;
        spec.cfg.nc = self.nc;
        spec.cfg.fmts.x = fmt;
        spec.cfg.arch = CimArch::parse(&self.arch)?;
        spec.dist_x = dist_by_name(&self.distribution, fmt)?;
        spec.fit_activations = self.fit;
        Ok(spec)
    }
}

/// A fully resolved sweep: campaign settings plus the experiment grid.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    /// Campaign settings (engine, seed, workers).
    pub campaign: CampaignConfig,
    /// Monte-Carlo samples per experiment.
    pub samples: usize,
    /// The experiment grid, in config order.
    pub specs: Vec<ExperimentSpec>,
}

impl SweepPlan {
    /// Resolve a parsed TOML config: top-level `seed`/`samples`/`sampler`,
    /// an optional `[engine] kind`, and one `[[experiment]]` section per
    /// grid point (`name` required; `n_e`, `n_m`, `nr`, `distribution`,
    /// `sampler` optional with the paper's defaults — the per-experiment
    /// `sampler` overrides the top-level one).
    pub fn from_config(cfg: &Config) -> Result<SweepPlan> {
        let mut campaign = CampaignConfig::default();
        if let Some(seed) = cfg.root.get("seed").and_then(|v| v.as_f64()) {
            campaign.seed = seed as u64;
        }
        if let Some(engine) = cfg
            .section("engine")
            .and_then(|t| t.get("kind"))
            .and_then(|v| v.as_str())
        {
            campaign.engine = EngineKind::parse(engine)?;
        }
        let samples = cfg
            .root
            .get("samples")
            .and_then(|v| v.as_usize())
            .unwrap_or(DEFAULT_SAMPLES);
        let sampler = match cfg.root.get("sampler").and_then(|v| v.as_str()) {
            None => Sampler::default(),
            Some(s) => Sampler::parse(s).map_err(anyhow::Error::msg)?,
        };

        let mut specs = Vec::new();
        for exp in cfg.sections_named("experiment") {
            let name = exp
                .get("name")
                .and_then(|v| v.as_str())
                .context("experiment needs a name")?;
            let n_e = exp.get("n_e").and_then(|v| v.as_f64()).unwrap_or(2.0);
            let n_m = exp.get("n_m").and_then(|v| v.as_f64()).unwrap_or(2.0);
            let nr = exp.get("nr").and_then(|v| v.as_usize()).unwrap_or(32);
            let dist = exp
                .get("distribution")
                .and_then(|v| v.as_str())
                .unwrap_or("uniform");
            let mut spec = experiment_spec(name, n_e, n_m, nr, dist, samples)?;
            spec.sampler = match exp.get("sampler").and_then(|v| v.as_str()) {
                None => sampler,
                Some(s) => Sampler::parse(s).map_err(anyhow::Error::msg)?,
            };
            specs.push(spec);
        }
        if specs.is_empty() {
            bail!("config has no [[experiment]] sections");
        }
        Ok(SweepPlan { campaign, samples, specs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
seed = 42
samples = 2048

[engine]
kind = "rust"

[[experiment]]
name = "fig10-e3"
n_e = 3
n_m = 2
nr = 32
distribution = "uniform"

[[experiment]]
name = "llm"
n_e = 4
distribution = "gauss_outliers"
"#;

    #[test]
    fn resolves_full_config() {
        let plan =
            SweepPlan::from_config(&Config::parse(GOOD).unwrap()).unwrap();
        assert_eq!(plan.campaign.seed, 42);
        assert_eq!(plan.campaign.engine, EngineKind::Rust);
        assert_eq!(plan.samples, 2048);
        assert_eq!(plan.specs.len(), 2);
        assert_eq!(plan.specs[0].id, "fig10-e3");
        assert_eq!(plan.specs[0].fmts.x, FpFormat::fp(3, 2));
        assert_eq!(plan.specs[0].samples, 2048);
        // defaults applied: n_m = 2, nr = 32, FP4 max-entropy weights
        assert_eq!(plan.specs[1].fmts.x, FpFormat::fp(4, 2));
        assert_eq!(plan.specs[1].nr, 32);
    }

    #[test]
    fn sampler_keys_resolve_with_per_experiment_override() {
        let text = r#"
sampler = "antithetic"
[[experiment]]
name = "a"
[[experiment]]
name = "b"
sampler = "stratified"
"#;
        let plan =
            SweepPlan::from_config(&Config::parse(text).unwrap()).unwrap();
        assert_eq!(plan.specs[0].sampler, Sampler::Antithetic);
        assert_eq!(plan.specs[1].sampler, Sampler::Stratified);
        // absent everywhere -> the historical plain estimator
        let plain = SweepPlan::from_config(
            &Config::parse("[[experiment]]\nname = \"a\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(plain.specs[0].sampler, Sampler::Plain);
        // unknown names are clean errors at either level
        for bad in [
            "sampler = \"warp\"\n[[experiment]]\nname = \"a\"\n",
            "[[experiment]]\nname = \"a\"\nsampler = \"warp\"\n",
        ] {
            let err = format!(
                "{:#}",
                SweepPlan::from_config(&Config::parse(bad).unwrap())
                    .unwrap_err()
            );
            assert!(err.contains("unknown sampler 'warp'"), "{err}");
        }
    }

    #[test]
    fn missing_experiment_sections_is_an_error() {
        let err = SweepPlan::from_config(
            &Config::parse("seed = 1\nsamples = 64\n").unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("no [[experiment]] sections"), "{err}");
    }

    #[test]
    fn nameless_experiment_is_an_error() {
        let text = "[[experiment]]\nn_e = 2\n";
        let err = SweepPlan::from_config(&Config::parse(text).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("needs a name"), "{err}");
    }

    #[test]
    fn unknown_distribution_is_an_error() {
        let text = "[[experiment]]\nname = \"x\"\ndistribution = \"cauchy\"\n";
        let err = format!(
            "{:#}",
            SweepPlan::from_config(&Config::parse(text).unwrap()).unwrap_err()
        );
        assert!(err.contains("unknown distribution 'cauchy'"), "{err}");
    }

    #[test]
    fn invalid_format_fields_are_errors_not_panics() {
        assert!(experiment_spec("x", 0.0, 2.0, 32, "uniform", 64).is_err());
        assert!(experiment_spec("x", 2.0, 2.0, 0, "uniform", 64).is_err());
    }

    #[test]
    fn every_listed_distribution_resolves() {
        for name in DISTRIBUTIONS {
            assert!(
                dist_by_name(name, FpFormat::fp6_e3m2()).is_ok(),
                "{name}"
            );
        }
    }

    #[test]
    fn layer_params_resolve_with_defaults() {
        let p = LayerParams { shape: "mlp-up:64".to_string(), ..Default::default() };
        let spec = p.resolve().unwrap();
        assert_eq!(spec.shape.m, 4);
        assert_eq!(spec.shape.k, 64);
        assert_eq!(spec.shape.n, 256);
        assert_eq!(spec.cfg.arch, CimArch::GrUnit);
        assert_eq!(spec.cfg.nr, 32);
        assert_eq!(spec.cfg.fmts.x, FpFormat::fp(4, 2));
        assert_eq!(spec.cfg.adc, AdcPolicy::PerTileSpec);
        assert_eq!(spec.name, "mlp-up:64");
        assert!(spec.conv.is_none());
    }

    #[test]
    fn conv_layer_params_keep_their_conv_geometry() {
        let p = LayerParams { shape: "conv:6x3x3x3@8x8".to_string(), ..Default::default() };
        let spec = p.resolve().unwrap();
        assert_eq!(spec.shape.m, 36);
        assert_eq!(spec.shape.k, 27);
        assert_eq!(spec.shape.n, 6);
        let cs = spec.conv.expect("conv shapes must carry their geometry");
        assert_eq!(cs.gemm_shape(), spec.shape);
        assert!(LayerParams { shape: "conv:6x3x9x3@8x8".to_string(), ..Default::default() }
            .resolve()
            .is_err());
    }

    #[test]
    fn layer_params_reject_invalid_fields() {
        let ok = LayerParams { shape: "gemm:2x8x8".to_string(), ..Default::default() };
        assert!(ok.resolve().is_ok());
        for bad in [
            LayerParams { shape: "warp:64".to_string(), ..Default::default() },
            LayerParams { arch: "quantum".to_string(), ..ok.clone() },
            LayerParams { nr: 0, ..ok.clone() },
            LayerParams { nc: 0, ..ok.clone() },
            // unbounded wire geometry must not reach the tile mapper
            LayerParams { nr: MAX_TILE_GEOM + 1, ..ok.clone() },
            LayerParams { nc: MAX_TILE_GEOM + 1, ..ok.clone() },
            LayerParams { n_e: 0.0, ..ok.clone() },
            // beyond the shift width FpFormat::fp could construct
            LayerParams { n_e: 64.0, ..ok.clone() },
            LayerParams { n_m: 64.0, ..ok.clone() },
            // NaN must be a clean validation error, not a worker panic
            LayerParams { n_e: f64::NAN, ..ok.clone() },
            LayerParams { n_m: f64::NAN, ..ok.clone() },
            LayerParams { distribution: "cauchy".to_string(), ..ok.clone() },
        ] {
            assert!(bad.resolve().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn model_params_resolve_with_defaults() {
        let p = ModelParams { model: "mlp:16x12x8".to_string(), ..Default::default() };
        let spec = p.resolve().unwrap();
        assert_eq!(spec.layers.len(), 2);
        assert_eq!(spec.layers[0].shape.m, 4);
        assert_eq!(spec.cfg.arch, CimArch::GrUnit);
        assert_eq!(spec.cfg.fmts.x, FpFormat::fp(4, 2));
        assert!(spec.relu, "mlp presets run with ReLU");
        assert!(!spec.fit_activations);
        let b = ModelParams { model: "block:16".to_string(), ..Default::default() };
        assert!(!b.resolve().unwrap().relu);
    }

    #[test]
    fn model_params_reject_invalid_fields() {
        let ok = ModelParams { model: "mlp:8x8".to_string(), ..Default::default() };
        assert!(ok.resolve().is_ok());
        for bad in [
            ModelParams { model: "mlp:8".to_string(), ..Default::default() },
            ModelParams { model: "warp:8".to_string(), ..Default::default() },
            ModelParams { arch: "quantum".to_string(), ..ok.clone() },
            ModelParams { nr: 0, ..ok.clone() },
            ModelParams { nc: 0, ..ok.clone() },
            // unbounded wire geometry must not reach the tile mapper
            ModelParams { nr: MAX_TILE_GEOM + 1, ..ok.clone() },
            ModelParams { nc: MAX_TILE_GEOM + 1, ..ok.clone() },
            ModelParams { n_e: 0.0, ..ok.clone() },
            ModelParams { n_e: 64.0, ..ok.clone() },
            ModelParams { n_e: f64::NAN, ..ok.clone() },
            ModelParams { tokens: 0, ..ok.clone() },
            ModelParams { distribution: "cauchy".to_string(), ..ok.clone() },
        ] {
            assert!(bad.resolve().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn empirical_distribution_resolves_from_trace_file() {
        use crate::workload::TensorTrace;
        let dir = std::env::temp_dir().join("grcim_sweep_empirical");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("acts.grtt");
        TensorTrace::from_f32("acts", vec![4], vec![0.5, -1.0, 0.25, 0.125])
            .unwrap()
            .write(&path)
            .unwrap();
        let spec = format!("empirical:{}", path.display());
        let d = dist_by_name(&spec, FpFormat::fp6_e3m2()).unwrap();
        assert!(d.name().starts_with("empirical[acts@"), "{}", d.name());
        // a missing trace file is a clean error, not a panic
        assert!(dist_by_name("empirical:/nonexistent/x.grtt", FpFormat::fp6_e3m2())
            .is_err());
    }
}
