//! Sweep-campaign construction shared by the `grcim sweep` subcommand,
//! the `grcim query sweep` client, and the serve layer's `sweep` handler —
//! one place turns "experiment descriptions" (TOML sections or JSON
//! request entries) into [`ExperimentSpec`]s, so the CLI and the service
//! cannot drift.

use crate::config::Config;
use crate::coordinator::{CampaignConfig, ExperimentSpec};
use crate::distributions::Distribution;
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::runtime::EngineKind;
use anyhow::{bail, Context, Result};

/// Default Monte-Carlo samples per experiment when the config has no
/// top-level `samples` key.
pub const DEFAULT_SAMPLES: usize = 16_384;

/// Input-distribution names accepted by sweep configs and requests.
/// `empirical:<trace-file>` additionally resolves a fitted
/// [`crate::workload::TensorTrace`] (the file is read where the config is
/// interpreted — client-side for `grcim sweep`, server-side for the
/// `sweep` request).
pub const DISTRIBUTIONS: &[&str] =
    &["uniform", "max_entropy", "gauss_outliers", "clipped_gauss"];

/// Resolve a distribution by its config name; `fmt` parameterizes
/// `max_entropy` (the experiment's input format).
pub fn dist_by_name(name: &str, fmt: FpFormat) -> Result<Distribution> {
    if let Some(path) = name.strip_prefix("empirical:") {
        let trace =
            crate::workload::TensorTrace::read(std::path::Path::new(path))?;
        let dist = Distribution::empirical(
            crate::workload::EmpiricalDist::fit(&trace)?,
        );
        return Ok(dist);
    }
    Ok(match name {
        "uniform" => Distribution::Uniform,
        "max_entropy" => Distribution::max_entropy(fmt),
        "gauss_outliers" => Distribution::gauss_outliers(),
        "clipped_gauss" => Distribution::clipped_gauss4(),
        other => bail!(
            "unknown distribution '{other}' (known: {}, or \
             empirical:<trace-file>)",
            DISTRIBUTIONS.join(", ")
        ),
    })
}

/// Build one experiment from sweep fields: input format FP(n_e, n_m)
/// against max-entropy FP4 weights (the paper's sweep convention).
pub fn experiment_spec(
    name: &str,
    n_e: f64,
    n_m: f64,
    nr: usize,
    distribution: &str,
    samples: usize,
) -> Result<ExperimentSpec> {
    if n_e < 1.0 || n_m < 0.0 {
        bail!("experiment '{name}': n_e must be >= 1 and n_m >= 0");
    }
    if nr == 0 {
        bail!("experiment '{name}': nr must be positive");
    }
    let fmt = FpFormat::fp(n_e as u32, n_m as u32);
    Ok(ExperimentSpec {
        id: name.to_string(),
        fmts: FormatPair::new(fmt, FpFormat::fp4_e2m1()),
        dist_x: dist_by_name(distribution, fmt)?,
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr,
        samples,
    })
}

/// A fully resolved sweep: campaign settings plus the experiment grid.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    /// Campaign settings (engine, seed, workers).
    pub campaign: CampaignConfig,
    /// Monte-Carlo samples per experiment.
    pub samples: usize,
    /// The experiment grid, in config order.
    pub specs: Vec<ExperimentSpec>,
}

impl SweepPlan {
    /// Resolve a parsed TOML config: top-level `seed`/`samples`, an
    /// optional `[engine] kind`, and one `[[experiment]]` section per
    /// grid point (`name` required; `n_e`, `n_m`, `nr`, `distribution`
    /// optional with the paper's defaults).
    pub fn from_config(cfg: &Config) -> Result<SweepPlan> {
        let mut campaign = CampaignConfig::default();
        if let Some(seed) = cfg.root.get("seed").and_then(|v| v.as_f64()) {
            campaign.seed = seed as u64;
        }
        if let Some(engine) = cfg
            .section("engine")
            .and_then(|t| t.get("kind"))
            .and_then(|v| v.as_str())
        {
            campaign.engine = EngineKind::parse(engine)?;
        }
        let samples = cfg
            .root
            .get("samples")
            .and_then(|v| v.as_usize())
            .unwrap_or(DEFAULT_SAMPLES);

        let mut specs = Vec::new();
        for exp in cfg.sections_named("experiment") {
            let name = exp
                .get("name")
                .and_then(|v| v.as_str())
                .context("experiment needs a name")?;
            let n_e = exp.get("n_e").and_then(|v| v.as_f64()).unwrap_or(2.0);
            let n_m = exp.get("n_m").and_then(|v| v.as_f64()).unwrap_or(2.0);
            let nr = exp.get("nr").and_then(|v| v.as_usize()).unwrap_or(32);
            let dist = exp
                .get("distribution")
                .and_then(|v| v.as_str())
                .unwrap_or("uniform");
            specs.push(experiment_spec(name, n_e, n_m, nr, dist, samples)?);
        }
        if specs.is_empty() {
            bail!("config has no [[experiment]] sections");
        }
        Ok(SweepPlan { campaign, samples, specs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
seed = 42
samples = 2048

[engine]
kind = "rust"

[[experiment]]
name = "fig10-e3"
n_e = 3
n_m = 2
nr = 32
distribution = "uniform"

[[experiment]]
name = "llm"
n_e = 4
distribution = "gauss_outliers"
"#;

    #[test]
    fn resolves_full_config() {
        let plan =
            SweepPlan::from_config(&Config::parse(GOOD).unwrap()).unwrap();
        assert_eq!(plan.campaign.seed, 42);
        assert_eq!(plan.campaign.engine, EngineKind::Rust);
        assert_eq!(plan.samples, 2048);
        assert_eq!(plan.specs.len(), 2);
        assert_eq!(plan.specs[0].id, "fig10-e3");
        assert_eq!(plan.specs[0].fmts.x, FpFormat::fp(3, 2));
        assert_eq!(plan.specs[0].samples, 2048);
        // defaults applied: n_m = 2, nr = 32, FP4 max-entropy weights
        assert_eq!(plan.specs[1].fmts.x, FpFormat::fp(4, 2));
        assert_eq!(plan.specs[1].nr, 32);
    }

    #[test]
    fn missing_experiment_sections_is_an_error() {
        let err = SweepPlan::from_config(
            &Config::parse("seed = 1\nsamples = 64\n").unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("no [[experiment]] sections"), "{err}");
    }

    #[test]
    fn nameless_experiment_is_an_error() {
        let text = "[[experiment]]\nn_e = 2\n";
        let err = SweepPlan::from_config(&Config::parse(text).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("needs a name"), "{err}");
    }

    #[test]
    fn unknown_distribution_is_an_error() {
        let text = "[[experiment]]\nname = \"x\"\ndistribution = \"cauchy\"\n";
        let err = format!(
            "{:#}",
            SweepPlan::from_config(&Config::parse(text).unwrap()).unwrap_err()
        );
        assert!(err.contains("unknown distribution 'cauchy'"), "{err}");
    }

    #[test]
    fn invalid_format_fields_are_errors_not_panics() {
        assert!(experiment_spec("x", 0.0, 2.0, 32, "uniform", 64).is_err());
        assert!(experiment_spec("x", 2.0, 2.0, 0, "uniform", 64).is_err());
    }

    #[test]
    fn every_listed_distribution_resolves() {
        for name in DISTRIBUTIONS {
            assert!(
                dist_by_name(name, FpFormat::fp6_e3m2()).is_ok(),
                "{name}"
            );
        }
    }

    #[test]
    fn empirical_distribution_resolves_from_trace_file() {
        use crate::workload::TensorTrace;
        let dir = std::env::temp_dir().join("grcim_sweep_empirical");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("acts.grtt");
        TensorTrace::from_f32("acts", vec![4], vec![0.5, -1.0, 0.25, 0.125])
            .unwrap()
            .write(&path)
            .unwrap();
        let spec = format!("empirical:{}", path.display());
        let d = dist_by_name(&spec, FpFormat::fp6_e3m2()).unwrap();
        assert!(d.name().starts_with("empirical[acts@"), "{}", d.name());
        // a missing trace file is a clean error, not a panic
        assert!(dist_by_name("empirical:/nonexistent/x.grtt", FpFormat::fp6_e3m2())
            .is_err());
    }
}
