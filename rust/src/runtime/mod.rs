//! Execution engines for the signal-chain simulation.
//!
//! Two interchangeable backends implement [`Engine`]:
//!
//! * [`RustEngine`] — the pure-Rust f64 oracle (`mac::simulate_column`);
//!   always available, deterministic, and the default backend. This is the
//!   self-contained path: no artifacts, no native toolchain.
//! * `PjrtEngine` (behind the `pjrt` cargo feature) — loads AOT artifacts
//!   (`artifacts/*.hlo.txt`, lowered once by `python/compile/aot.py`),
//!   compiles them on the PJRT CPU client via the `xla` crate, and executes
//!   them with f32 literals. Bit-compatible semantics are cross-checked in
//!   `rust/tests/runtime_crosscheck.rs`.
//!
//! Backend selection goes through [`EngineKind`] + [`build_engine`]:
//! `Auto` prefers PJRT when the feature is compiled in *and* artifacts are
//! present, and falls back to [`RustEngine`] otherwise, so default builds
//! run everything end-to-end without artifacts.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod pjrt;
mod rust_engine;

pub use artifact::{ArtifactEntry, ArtifactRegistry};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
pub use rust_engine::RustEngine;

use crate::mac::FormatPair;
use crate::stats::ColumnBatch;
use anyhow::Result;
use std::path::Path;

/// Reusable engine-internal temporaries for the allocation-free
/// [`Engine::simulate_into`] path (e.g. the Rust oracle's f32 -> f64
/// widening buffers). One scratch per worker, reused across jobs.
#[derive(Debug, Default)]
pub struct SimScratch {
    /// f32 -> f64 widening buffer for the inputs.
    pub xf: Vec<f64>,
    /// f32 -> f64 widening buffer for the weights.
    pub wf: Vec<f64>,
}

/// A backend able to run the column simulation.
pub trait Engine {
    /// Simulate `b = x.len()/nr` column MACs; `x`/`w` are row-major
    /// `[b][nr]` raw f32 values. Implementations may require `b` to be a
    /// multiple of their preferred batch (see [`Engine::preferred_batch`]).
    fn simulate(&self, x: &[f32], w: &[f32], nr: usize, fmts: FormatPair)
        -> Result<ColumnBatch>;

    /// Like [`Engine::simulate`], but writes into a caller-owned batch and
    /// uses caller-owned scratch, so steady-state loops do not allocate.
    /// The default implementation falls back to [`Engine::simulate`];
    /// backends with a native buffer-reuse path override it.
    fn simulate_into(
        &self,
        x: &[f32],
        w: &[f32],
        nr: usize,
        fmts: FormatPair,
        scratch: &mut SimScratch,
        out: &mut ColumnBatch,
    ) -> Result<()> {
        let _ = scratch;
        *out = self.simulate(x, w, nr, fmts)?;
        Ok(())
    }

    /// The batch size this engine executes natively (callers should chunk
    /// work into multiples of this).
    fn preferred_batch(&self, nr: usize) -> usize;

    /// Whether [`Engine::simulate`] requires the batch to be a whole
    /// multiple of [`Engine::preferred_batch`] (AOT artifacts have fixed
    /// batch shapes baked in). Callers that cannot chunk — e.g. the tile
    /// mapper's per-tile batches — pad with zero samples and discard the
    /// padded outputs when this is set. The oracle takes exact batches.
    fn requires_batch_multiple(&self) -> bool {
        false
    }

    /// Array depths this engine supports.
    fn supports_nr(&self, nr: usize) -> bool;

    /// Stable backend name (`"rust"` / `"pjrt"`).
    fn name(&self) -> &'static str;
}

/// Which backend a campaign should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// The pure-Rust f64 oracle (always available).
    Rust,
    /// The PJRT artifact executor (requires the `pjrt` feature +
    /// artifacts; an explicit request errors when unavailable).
    Pjrt,
    /// Prefer PJRT, fall back to Rust when the backend is not compiled in,
    /// artifacts are missing, or the requested depth has no artifact.
    Auto,
}

impl EngineKind {
    /// Parse a `--engine` value (`rust` | `pjrt` | `auto`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rust" => Ok(EngineKind::Rust),
            "pjrt" => Ok(EngineKind::Pjrt),
            "auto" => Ok(EngineKind::Auto),
            _ => anyhow::bail!("unknown engine '{s}' (rust|pjrt|auto)"),
        }
    }
}

/// Build an engine for a worker thread. PJRT wrapper types are not `Send`,
/// so each worker constructs its own engine through this factory.
///
/// Without the `pjrt` cargo feature, `Auto` silently resolves to
/// [`RustEngine`] and an explicit `Pjrt` request is an error.
pub fn build_engine(kind: EngineKind, artifacts_dir: &Path) -> Result<Box<dyn Engine>> {
    match kind {
        EngineKind::Rust => Ok(Box::new(RustEngine)),
        EngineKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                let reg = ArtifactRegistry::load(artifacts_dir)?;
                Ok(Box::new(PjrtEngine::from_registry(&reg)?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifacts_dir;
                anyhow::bail!(
                    "this binary was built without the `pjrt` feature — \
                     rebuild with `cargo build --features pjrt`, or use \
                     --engine rust|auto"
                )
            }
        }
        EngineKind::Auto => {
            #[cfg(feature = "pjrt")]
            {
                match ArtifactRegistry::load(artifacts_dir) {
                    Ok(reg) => match PjrtEngine::from_registry(&reg) {
                        Ok(e) => return Ok(Box::new(e)),
                        Err(err) => {
                            crate::warn_!(
                                "PJRT unavailable ({err}); using rust engine"
                            );
                        }
                    },
                    Err(err) => {
                        crate::warn_!("no artifacts ({err}); using rust engine");
                    }
                }
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifacts_dir;
                crate::debug!("pjrt feature not compiled in; using rust engine");
            }
            Ok(Box::new(RustEngine))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("rust").unwrap(), EngineKind::Rust);
        assert_eq!(EngineKind::parse("pjrt").unwrap(), EngineKind::Pjrt);
        assert_eq!(EngineKind::parse("auto").unwrap(), EngineKind::Auto);
        assert!(EngineKind::parse("tpu").is_err());
    }

    #[test]
    fn auto_engine_always_builds() {
        let e = build_engine(
            EngineKind::Auto,
            Path::new("/nonexistent/grcim-artifacts"),
        )
        .unwrap();
        // with no artifacts the auto path must resolve to the oracle
        assert_eq!(e.name(), "rust");
    }

    #[test]
    fn rust_engine_kind_builds_rust() {
        let e = build_engine(EngineKind::Rust, Path::new(".")).unwrap();
        assert_eq!(e.name(), "rust");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_kind_errors_without_feature() {
        let err = build_engine(EngineKind::Pjrt, Path::new("."))
            .err()
            .expect("must fail without the pjrt feature")
            .to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
