//! Execution engines for the signal-chain simulation.
//!
//! Two interchangeable backends implement [`Engine`]:
//!
//! * [`PjrtEngine`] — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//!   lowered once by `python/compile/aot.py`), compiles them on the PJRT
//!   CPU client via the `xla` crate, and executes them with f32 literals.
//!   This is the production path: Python never runs here.
//! * [`RustEngine`] — the pure-Rust f64 oracle (`mac::simulate_column`);
//!   bit-compatible semantics, used for cross-checking, for array depths
//!   with no artifact, and as a no-artifact fallback.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;

pub use artifact::{ArtifactEntry, ArtifactRegistry};

use crate::mac::{self, FormatPair};
use crate::stats::ColumnBatch;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A backend able to run the column simulation.
pub trait Engine {
    /// Simulate `b = x.len()/nr` column MACs; `x`/`w` are row-major
    /// `[b][nr]` raw f32 values. Implementations may require `b` to be a
    /// multiple of their preferred batch (see [`Engine::preferred_batch`]).
    fn simulate(&self, x: &[f32], w: &[f32], nr: usize, fmts: FormatPair)
        -> Result<ColumnBatch>;

    /// The batch size this engine executes natively (callers should chunk
    /// work into multiples of this).
    fn preferred_batch(&self, nr: usize) -> usize;

    /// Array depths this engine supports.
    fn supports_nr(&self, nr: usize) -> bool;

    fn name(&self) -> &'static str;
}

/// Pure-Rust oracle backend.
#[derive(Debug, Default, Clone)]
pub struct RustEngine;

impl Engine for RustEngine {
    fn simulate(&self, x: &[f32], w: &[f32], nr: usize, fmts: FormatPair)
        -> Result<ColumnBatch> {
        if x.len() != w.len() || nr == 0 || x.len() % nr != 0 {
            bail!("ragged input: x={} w={} nr={}", x.len(), w.len(), nr);
        }
        let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
        Ok(mac::simulate_column(&xf, &wf, nr, fmts))
    }

    fn preferred_batch(&self, _nr: usize) -> usize {
        2048
    }

    fn supports_nr(&self, _nr: usize) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// PJRT-backed engine: one compiled executable per array depth.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    /// nr -> (executable, batch)
    execs: HashMap<usize, (xla::PjRtLoadedExecutable, usize)>,
}

impl PjrtEngine {
    /// Load and compile every `macsim` artifact in the registry.
    pub fn from_registry(reg: &ArtifactRegistry) -> Result<Self> {
        Self::from_entries(reg.root(), &reg.macsim_entries())
    }

    /// Load and compile a specific set of artifact entries.
    pub fn from_entries(root: &Path, entries: &[&ArtifactEntry]) -> Result<Self> {
        if entries.is_empty() {
            bail!("no artifacts to load — run `make artifacts` first");
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        let mut execs = HashMap::new();
        for entry in entries {
            let path = root.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
            execs.insert(entry.nr, (exe, entry.batch));
        }
        Ok(PjrtEngine { client, execs })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn depths(&self) -> Vec<usize> {
        let mut d: Vec<usize> = self.execs.keys().copied().collect();
        d.sort();
        d
    }

    fn run_one(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        x: &[f32],
        w: &[f32],
        b: usize,
        nr: usize,
        fmts: FormatPair,
    ) -> Result<Vec<Vec<f64>>> {
        let xl = xla::Literal::vec1(x)
            .reshape(&[b as i64, nr as i64])
            .map_err(|e| anyhow::anyhow!("reshape x: {e}"))?;
        let wl = xla::Literal::vec1(w)
            .reshape(&[b as i64, nr as i64])
            .map_err(|e| anyhow::anyhow!("reshape w: {e}"))?;
        let fmtl = xla::Literal::vec1(&fmts.to_vec4()[..]);
        let result = exe
            .execute::<xla::Literal>(&[xl, wl, fmtl])
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        if parts.len() != artifact::N_OUTPUTS {
            bail!("expected {} outputs, got {}", artifact::N_OUTPUTS, parts.len());
        }
        parts
            .into_iter()
            .map(|p| {
                let v: Vec<f32> = p
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("output to_vec: {e}"))?;
                if v.len() != b {
                    bail!("output length {} != batch {b}", v.len());
                }
                Ok(v.into_iter().map(|f| f as f64).collect())
            })
            .collect()
    }
}

impl Engine for PjrtEngine {
    fn simulate(&self, x: &[f32], w: &[f32], nr: usize, fmts: FormatPair)
        -> Result<ColumnBatch> {
        let (exe, batch) = self
            .execs
            .get(&nr)
            .with_context(|| format!("no artifact for NR={nr}"))?;
        if x.len() != w.len() || x.len() % (nr * batch) != 0 {
            bail!(
                "PJRT engine needs multiples of batch {} x nr {} (got {})",
                batch,
                nr,
                x.len()
            );
        }
        let chunks = x.len() / (nr * batch);
        let mut outs: Vec<Vec<f64>> = vec![Vec::new(); artifact::N_OUTPUTS];
        for c in 0..chunks {
            let lo = c * batch * nr;
            let hi = lo + batch * nr;
            let parts =
                self.run_one(exe, &x[lo..hi], &w[lo..hi], *batch, nr, fmts)?;
            for (acc, part) in outs.iter_mut().zip(parts) {
                acc.extend(part);
            }
        }
        let mut it = outs.into_iter();
        Ok(ColumnBatch {
            nr,
            z_ideal: it.next().unwrap(),
            z_q: it.next().unwrap(),
            v_conv: it.next().unwrap(),
            g_conv: it.next().unwrap(),
            v_gr: it.next().unwrap(),
            s_sum: it.next().unwrap(),
            s2_sum: it.next().unwrap(),
            sx_sum: it.next().unwrap(),
            g_w: it.next().unwrap(),
            nf: it.next().unwrap(),
            wq2_mean: it.next().unwrap(),
        })
    }

    fn preferred_batch(&self, nr: usize) -> usize {
        self.execs.get(&nr).map(|(_, b)| *b).unwrap_or(2048)
    }

    fn supports_nr(&self, nr: usize) -> bool {
        self.execs.contains_key(&nr)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Which backend a campaign should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Rust,
    Pjrt,
    /// Prefer PJRT, fall back to Rust when artifacts are missing or the
    /// requested depth has no artifact.
    Auto,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rust" => Ok(EngineKind::Rust),
            "pjrt" => Ok(EngineKind::Pjrt),
            "auto" => Ok(EngineKind::Auto),
            _ => bail!("unknown engine '{s}' (rust|pjrt|auto)"),
        }
    }
}

/// Build an engine for a worker thread. PJRT wrapper types are not `Send`,
/// so each worker constructs its own engine through this factory.
pub fn build_engine(kind: EngineKind, artifacts_dir: &Path) -> Result<Box<dyn Engine>> {
    match kind {
        EngineKind::Rust => Ok(Box::new(RustEngine)),
        EngineKind::Pjrt => {
            let reg = ArtifactRegistry::load(artifacts_dir)?;
            Ok(Box::new(PjrtEngine::from_registry(&reg)?))
        }
        EngineKind::Auto => match ArtifactRegistry::load(artifacts_dir) {
            Ok(reg) => match PjrtEngine::from_registry(&reg) {
                Ok(e) => Ok(Box::new(e)),
                Err(err) => {
                    crate::warn_!("PJRT unavailable ({err}); using rust engine");
                    Ok(Box::new(RustEngine))
                }
            },
            Err(err) => {
                crate::warn_!("no artifacts ({err}); using rust engine");
                Ok(Box::new(RustEngine))
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;

    #[test]
    fn rust_engine_basic() {
        let e = RustEngine;
        let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
        let x = vec![0.5f32; 64];
        let w = vec![0.25f32; 64];
        let b = e.simulate(&x, &w, 32, fmts).unwrap();
        assert_eq!(b.len(), 2);
        assert!(e.supports_nr(7));
        assert_eq!(e.name(), "rust");
    }

    #[test]
    fn rust_engine_rejects_ragged() {
        let e = RustEngine;
        let fmts = FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1());
        assert!(e.simulate(&[0.0; 33], &[0.0; 33], 32, fmts).is_err());
        assert!(e.simulate(&[0.0; 32], &[0.0; 64], 32, fmts).is_err());
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("rust").unwrap(), EngineKind::Rust);
        assert_eq!(EngineKind::parse("pjrt").unwrap(), EngineKind::Pjrt);
        assert_eq!(EngineKind::parse("auto").unwrap(), EngineKind::Auto);
        assert!(EngineKind::parse("tpu").is_err());
    }
}
