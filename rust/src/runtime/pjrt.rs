//! PJRT-backed engine (behind the `pjrt` cargo feature): compiles the AOT
//! artifacts (`artifacts/*.hlo.txt`) on the PJRT CPU client via the `xla`
//! crate and executes them with f32 literals. This is the production path
//! when a native XLA toolchain is vendored; the default build ships an
//! offline `xla` API stub (see `rust/xla-stub/`) so this module always
//! compiles but reports a clear runtime error until the real bindings are
//! wired in.

use super::artifact::{self, ArtifactEntry, ArtifactRegistry};
use super::Engine;
use crate::mac::FormatPair;
use crate::stats::ColumnBatch;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT-backed engine: one compiled executable per array depth.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    /// nr -> (executable, batch)
    execs: HashMap<usize, (xla::PjRtLoadedExecutable, usize)>,
}

impl PjrtEngine {
    /// Load and compile every `macsim` artifact in the registry.
    pub fn from_registry(reg: &ArtifactRegistry) -> Result<Self> {
        Self::from_entries(reg.root(), &reg.macsim_entries())
    }

    /// Load and compile a specific set of artifact entries.
    pub fn from_entries(root: &Path, entries: &[&ArtifactEntry]) -> Result<Self> {
        if entries.is_empty() {
            bail!("no artifacts to load — regenerate them with python/compile/aot.py");
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        let mut execs = HashMap::new();
        for entry in entries {
            let path = root.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
            execs.insert(entry.nr, (exe, entry.batch));
        }
        Ok(PjrtEngine { client, execs })
    }

    /// The PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Array depths with a compiled executable, ascending.
    pub fn depths(&self) -> Vec<usize> {
        let mut d: Vec<usize> = self.execs.keys().copied().collect();
        d.sort();
        d
    }

    fn run_one(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        x: &[f32],
        w: &[f32],
        b: usize,
        nr: usize,
        fmts: FormatPair,
    ) -> Result<Vec<Vec<f64>>> {
        let xl = xla::Literal::vec1(x)
            .reshape(&[b as i64, nr as i64])
            .map_err(|e| anyhow::anyhow!("reshape x: {e}"))?;
        let wl = xla::Literal::vec1(w)
            .reshape(&[b as i64, nr as i64])
            .map_err(|e| anyhow::anyhow!("reshape w: {e}"))?;
        let fmtl = xla::Literal::vec1(&fmts.to_vec4()[..]);
        let result = exe
            .execute::<xla::Literal>(&[xl, wl, fmtl])
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        if parts.len() != artifact::N_OUTPUTS {
            bail!("expected {} outputs, got {}", artifact::N_OUTPUTS, parts.len());
        }
        parts
            .into_iter()
            .map(|p| {
                let v: Vec<f32> = p
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("output to_vec: {e}"))?;
                if v.len() != b {
                    bail!("output length {} != batch {b}", v.len());
                }
                Ok(v.into_iter().map(|f| f as f64).collect())
            })
            .collect()
    }
}

impl Engine for PjrtEngine {
    fn simulate(&self, x: &[f32], w: &[f32], nr: usize, fmts: FormatPair)
        -> Result<ColumnBatch> {
        let (exe, batch) = self
            .execs
            .get(&nr)
            .with_context(|| format!("no artifact for NR={nr}"))?;
        if x.len() != w.len() || x.len() % (nr * batch) != 0 {
            bail!(
                "PJRT engine needs multiples of batch {} x nr {} (got {})",
                batch,
                nr,
                x.len()
            );
        }
        let chunks = x.len() / (nr * batch);
        let mut outs: Vec<Vec<f64>> = vec![Vec::new(); artifact::N_OUTPUTS];
        for c in 0..chunks {
            let lo = c * batch * nr;
            let hi = lo + batch * nr;
            let parts =
                self.run_one(exe, &x[lo..hi], &w[lo..hi], *batch, nr, fmts)?;
            for (acc, part) in outs.iter_mut().zip(parts) {
                acc.extend(part);
            }
        }
        let mut it = outs.into_iter();
        Ok(ColumnBatch {
            nr,
            z_ideal: it.next().unwrap(),
            z_q: it.next().unwrap(),
            v_conv: it.next().unwrap(),
            g_conv: it.next().unwrap(),
            v_gr: it.next().unwrap(),
            s_sum: it.next().unwrap(),
            s2_sum: it.next().unwrap(),
            sx_sum: it.next().unwrap(),
            g_w: it.next().unwrap(),
            nf: it.next().unwrap(),
            wq2_mean: it.next().unwrap(),
        })
    }

    fn preferred_batch(&self, nr: usize) -> usize {
        self.execs.get(&nr).map(|(_, b)| *b).unwrap_or(2048)
    }

    fn requires_batch_multiple(&self) -> bool {
        true // artifact batch shapes are baked in at lowering time
    }

    fn supports_nr(&self, nr: usize) -> bool {
        self.execs.contains_key(&nr)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
