//! Artifact registry: discovers and validates the AOT outputs that
//! `python/compile/aot.py` wrote into `artifacts/` (HLO text files plus a
//! `manifest.json` describing shapes).

use crate::config::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Number of outputs of the column-simulation graph (must match
/// `python/compile/kernels/grmac.py::N_OUTPUTS`).
pub const N_OUTPUTS: usize = 11;

/// One lowered module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// HLO text file name, relative to the artifact directory.
    pub file: String,
    /// "macsim" (statistics batches) or "mvmsim" (e2e tile batches).
    pub graph: String,
    /// Array depth the module was lowered for.
    pub nr: usize,
    /// Batch size the module was lowered for.
    pub batch: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    root: PathBuf,
    /// Every artifact the manifest lists (all files verified to exist).
    pub entries: Vec<ArtifactEntry>,
}

impl ArtifactRegistry {
    /// Load `<dir>/manifest.json` and verify each artifact file exists.
    pub fn load(dir: &Path) -> Result<Self> {
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {}", man_path.display()))?;
        let json = Json::parse(&text)
            .with_context(|| format!("parsing {}", man_path.display()))?;

        let outputs = json
            .get("outputs")
            .and_then(Json::as_usize)
            .context("manifest missing 'outputs'")?;
        if outputs != N_OUTPUTS {
            bail!(
                "manifest declares {outputs} outputs but this binary expects \
                 {N_OUTPUTS} — re-run `make artifacts`"
            );
        }

        let mut entries = Vec::new();
        for e in json.get("entries").context("manifest missing 'entries'")?.items() {
            let entry = ArtifactEntry {
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .context("entry missing 'file'")?
                    .to_string(),
                graph: e
                    .get("graph")
                    .and_then(Json::as_str)
                    .context("entry missing 'graph'")?
                    .to_string(),
                nr: e.get("nr").and_then(Json::as_usize).context("entry nr")?,
                batch: e
                    .get("batch")
                    .and_then(Json::as_usize)
                    .context("entry batch")?,
            };
            let path = dir.join(&entry.file);
            if !path.exists() {
                bail!("artifact listed but missing: {}", path.display());
            }
            entries.push(entry);
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(ArtifactRegistry { root: dir.to_path_buf(), entries })
    }

    /// The artifact directory the registry was loaded from.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Statistics-graph entries (one per array depth).
    pub fn macsim_entries(&self) -> Vec<&ArtifactEntry> {
        self.entries.iter().filter(|e| e.graph == "macsim").collect()
    }

    /// MVM-tile entries (used by the e2e example).
    pub fn mvmsim_entries(&self) -> Vec<&ArtifactEntry> {
        self.entries.iter().filter(|e| e.graph == "mvmsim").collect()
    }

    /// The entry for a (graph, depth) pair, if lowered.
    pub fn entry(&self, graph: &str, nr: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.graph == graph && e.nr == nr)
    }

    /// Default artifacts directory: `$GRCIM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("GRCIM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, text: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        for file in files {
            std::fs::File::create(dir.join(file)).unwrap();
        }
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("grcim_test_manifest_ok");
        let _ = std::fs::remove_dir_all(&dir);
        write_manifest(
            &dir,
            r#"{"batch":2048,"mvm_batch":32,"outputs":11,"entries":[
                {"file":"macsim_nr32.hlo.txt","graph":"macsim","nr":32,"batch":2048},
                {"file":"mvmsim_nr32.hlo.txt","graph":"mvmsim","nr":32,"batch":32}
            ]}"#,
            &["macsim_nr32.hlo.txt", "mvmsim_nr32.hlo.txt"],
        );
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.entries.len(), 2);
        assert_eq!(reg.macsim_entries().len(), 1);
        assert_eq!(reg.mvmsim_entries().len(), 1);
        assert_eq!(reg.entry("macsim", 32).unwrap().batch, 2048);
        assert!(reg.entry("macsim", 64).is_none());
    }

    #[test]
    fn rejects_output_count_mismatch() {
        let dir = std::env::temp_dir().join("grcim_test_manifest_badout");
        let _ = std::fs::remove_dir_all(&dir);
        write_manifest(
            &dir,
            r#"{"outputs":8,"entries":[
                {"file":"a.hlo.txt","graph":"macsim","nr":32,"batch":2048}
            ]}"#,
            &["a.hlo.txt"],
        );
        let err = ArtifactRegistry::load(&dir).unwrap_err().to_string();
        assert!(err.contains("re-run"), "{err}");
    }

    #[test]
    fn rejects_missing_file() {
        let dir = std::env::temp_dir().join("grcim_test_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        write_manifest(
            &dir,
            r#"{"outputs":11,"entries":[
                {"file":"gone.hlo.txt","graph":"macsim","nr":32,"batch":2048}
            ]}"#,
            &[],
        );
        assert!(ArtifactRegistry::load(&dir).is_err());
    }

    #[test]
    fn rejects_absent_dir() {
        assert!(
            ArtifactRegistry::load(Path::new("/nonexistent/grcim")).is_err()
        );
    }
}
