//! The pure-Rust oracle backend: f64 semantics, always available, and the
//! reference the PJRT artifact path is cross-checked against.

use super::{Engine, SimScratch};
use crate::mac::{self, FormatPair};
use crate::stats::ColumnBatch;
use anyhow::{bail, Result};

/// Pure-Rust oracle backend.
#[derive(Debug, Default, Clone)]
pub struct RustEngine;

impl Engine for RustEngine {
    fn simulate(&self, x: &[f32], w: &[f32], nr: usize, fmts: FormatPair)
        -> Result<ColumnBatch> {
        let mut scratch = SimScratch::default();
        let mut out = ColumnBatch::empty(nr);
        self.simulate_into(x, w, nr, fmts, &mut scratch, &mut out)?;
        Ok(out)
    }

    fn simulate_into(
        &self,
        x: &[f32],
        w: &[f32],
        nr: usize,
        fmts: FormatPair,
        scratch: &mut SimScratch,
        out: &mut ColumnBatch,
    ) -> Result<()> {
        if x.len() != w.len() || nr == 0 || x.len() % nr != 0 {
            bail!("ragged input: x={} w={} nr={}", x.len(), w.len(), nr);
        }
        scratch.xf.clear();
        scratch.xf.extend(x.iter().map(|&v| v as f64));
        scratch.wf.clear();
        scratch.wf.extend(w.iter().map(|&v| v as f64));
        mac::simulate_column_into(&scratch.xf, &scratch.wf, nr, fmts, out);
        Ok(())
    }

    fn preferred_batch(&self, _nr: usize) -> usize {
        2048
    }

    fn supports_nr(&self, _nr: usize) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;

    #[test]
    fn rust_engine_basic() {
        let e = RustEngine;
        let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
        let x = vec![0.5f32; 64];
        let w = vec![0.25f32; 64];
        let b = e.simulate(&x, &w, 32, fmts).unwrap();
        assert_eq!(b.len(), 2);
        assert!(e.supports_nr(7));
        assert_eq!(e.name(), "rust");
    }

    #[test]
    fn rust_engine_rejects_ragged() {
        let e = RustEngine;
        let fmts = FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1());
        assert!(e.simulate(&[0.0; 33], &[0.0; 33], 32, fmts).is_err());
        assert!(e.simulate(&[0.0; 32], &[0.0; 64], 32, fmts).is_err());
    }

    #[test]
    fn simulate_into_matches_simulate_bitwise() {
        let e = RustEngine;
        let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
        let mut rng = crate::rng::Pcg64::seeded(31);
        let mut x = vec![0.0f32; 8 * 32];
        let mut w = vec![0.0f32; 8 * 32];
        crate::distributions::Distribution::Uniform.fill_f32(&mut rng, &mut x);
        crate::distributions::Distribution::Uniform.fill_f32(&mut rng, &mut w);
        let fresh = e.simulate(&x, &w, 32, fmts).unwrap();
        let mut scratch = SimScratch::default();
        let mut reused = ColumnBatch::empty(32);
        // run twice to exercise the reuse path
        e.simulate_into(&x, &w, 32, fmts, &mut scratch, &mut reused).unwrap();
        e.simulate_into(&x, &w, 32, fmts, &mut scratch, &mut reused).unwrap();
        assert_eq!(fresh.len(), reused.len());
        for i in 0..fresh.len() {
            assert_eq!(fresh.z_q[i].to_bits(), reused.z_q[i].to_bits());
            assert_eq!(fresh.v_gr[i].to_bits(), reused.v_gr[i].to_bits());
            assert_eq!(fresh.nf[i].to_bits(), reused.nf[i].to_bits());
        }
    }
}
