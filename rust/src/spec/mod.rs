//! ADC resolution (ENOB) requirement solver — paper Sec. IV-A.
//!
//! The spec rule: noise introduced by the ADC, referred to the MAC output,
//! must sit at least `margin_db` (6 dB) below the quantization noise floor
//! of the data representation the architecture actually processes:
//!
//! Both architectures share the same floor — the output-referred,
//! input-side ulp noise of the quantized data (`nf`; for INT formats the
//! ulp is the uniform grid step, which unifies the Fig. 10 FP->INT view
//! with Fig. 12's static-INT conventional CIM) — and differ in the gain
//! `g` through which ADC noise refers to the output:
//!
//! * **Conventional**: global normalization is static (alignment to the
//!   format maximum, Fig. 2c), so g = 1: the ADC must resolve the floor at
//!   full scale even though accumulation shrank the signal.
//! * **GR unit**: g = S/NR — the exponent-weighted normalization factor the
//!   digital back-end multiplies out; ADC noise is scaled down with it.
//! * **GR row**: g = S_x/NR (input exponents only; weights stored aligned).
//!
//! With an ideal uniform ADC of step `Delta` over full scale `V_FS = 2`:
//!
//! ```text
//! Delta_max^2 = 12 * floor / (10^(margin/10) * E[g^2])
//! ENOB        = log2(V_FS / Delta_max)        (continuous bits)
//! ```
//!
//! The input-side-only convention follows the Fig. 10 caption ("only input
//! quantization noise is considered"); weight quantization is part of the
//! model, not noise to protect.
//!
//! # Example
//!
//! ```
//! use grcim::distributions::Distribution;
//! use grcim::formats::FpFormat;
//! use grcim::mac::{simulate_column, FormatPair};
//! use grcim::rng::Pcg64;
//! use grcim::spec::{required_enob, Arch, SpecConfig};
//! use grcim::stats::ColumnAgg;
//!
//! // a small Monte-Carlo aggregate straight from the oracle
//! let (nr, samples) = (32, 512);
//! let mut rng = Pcg64::seeded(1);
//! let mut x = vec![0.0; samples * nr];
//! let mut w = vec![0.0; samples * nr];
//! Distribution::Uniform.fill(&mut rng, &mut x);
//! Distribution::max_entropy(FpFormat::fp4_e2m1()).fill(&mut rng, &mut w);
//! let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
//! let mut agg = ColumnAgg::new(nr);
//! agg.push_batch(&simulate_column(&x, &w, nr, fmts));
//!
//! // gain ranging needs fewer ADC bits than the conventional path
//! let cfg = SpecConfig::default();
//! let conv = required_enob(&agg, Arch::Conventional, cfg);
//! let gr = required_enob(&agg, Arch::GrUnit, cfg);
//! assert!(conv.enob > gr.enob);
//! ```

use crate::stats::ColumnAgg;
use crate::util::from_db;

/// Which architecture's floor/referral to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    /// Conventional direct-accumulation CIM on statically aligned INT data.
    Conventional,
    /// GR-MAC, per-unit normalization (input + weight exponents ranged).
    GrUnit,
    /// GR-MAC, per-row normalization (input exponents ranged, weights
    /// block-aligned).
    GrRow,
    /// GR-MAC, INT-input normalization (weight exponents ranged only).
    /// Coincides with `GrUnit` referral when the input format is INT
    /// (input exponents are constant).
    GrInt,
}

impl Arch {
    /// Stable lowercase name for reports and wire responses.
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Conventional => "conventional",
            Arch::GrUnit => "gr-unit",
            Arch::GrRow => "gr-row",
            Arch::GrInt => "gr-int",
        }
    }
}

/// The ADC specification produced by the solver.
#[derive(Debug, Clone, Copy)]
pub struct AdcSpec {
    /// Required effective number of bits.
    pub enob: f64,
    /// Maximum tolerable ADC step over V_FS = 2.
    pub delta_max: f64,
    /// The noise floor used (output-referred power).
    pub noise_floor: f64,
    /// The referral power E[g^2] used.
    pub g2: f64,
}

/// Solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct SpecConfig {
    /// Safety margin between ADC noise and the quantization floor.
    pub margin_db: f64,
    /// Use the empirical E[(z_q - z_ideal)^2] instead of the
    /// representation floor (diagnostic only; breaks down for max-entropy
    /// inputs where the empirical error is exactly zero).
    pub empirical_floor: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { margin_db: 6.0, empirical_floor: false }
    }
}

/// Solve the required ENOB for one architecture from an aggregate.
pub fn required_enob(agg: &ColumnAgg, arch: Arch, cfg: SpecConfig) -> AdcSpec {
    assert!(agg.samples() > 0, "empty aggregate");
    let (floor, g2) = match arch {
        // static global alignment: unity referral, the FP ulp floor (for
        // INT formats the ulp is the uniform grid step, so this unifies
        // the Fig. 10 FP->INT view with the Fig. 12 static-INT view)
        Arch::Conventional => (agg.nf.mean(), 1.0),
        Arch::GrUnit | Arch::GrInt => (agg.nf.mean(), agg.g_unit.mean_sq()),
        Arch::GrRow => (agg.nf.mean(), agg.g_row.mean_sq()),
    };
    let floor = if cfg.empirical_floor { agg.qerr.mean_sq() } else { floor };
    assert!(g2 > 0.0, "degenerate referral gain for {arch:?}");
    let floor = floor.max(1e-300);
    let delta_max = (12.0 * floor / (from_db(cfg.margin_db) * g2)).sqrt();
    let enob = (2.0 / delta_max).log2();
    AdcSpec { enob, delta_max, noise_floor: floor, g2 }
}

/// Convenience: ENOB advantage of the GR unit-normalized architecture over
/// the conventional one for the same aggregate (the paper's ΔENOB).
pub fn delta_enob(agg: &ColumnAgg, cfg: SpecConfig) -> f64 {
    required_enob(agg, Arch::Conventional, cfg).enob
        - required_enob(agg, Arch::GrUnit, cfg).enob
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::Distribution;
    use crate::formats::FpFormat;
    use crate::mac::{simulate_column, FormatPair};
    use crate::rng::Pcg64;
    use crate::stats::ColumnAgg;
    use crate::util::approx_eq;

    fn agg_for(
        dist_x: &Distribution,
        dist_w: &Distribution,
        fmts: FormatPair,
        nr: usize,
        samples: usize,
        seed: u64,
    ) -> ColumnAgg {
        let mut rng = Pcg64::seeded(seed);
        let mut x = vec![0.0; samples * nr];
        let mut w = vec![0.0; samples * nr];
        dist_x.fill(&mut rng, &mut x);
        dist_w.fill(&mut rng, &mut w);
        let batch = simulate_column(&x, &w, nr, fmts);
        let mut agg = ColumnAgg::new(nr);
        agg.push_batch(&batch);
        agg
    }

    fn std_fmts() -> FormatPair {
        // Fig. 10 setup: x = FP(N_E=3, 2), w = FP4_E2M1
        FormatPair::new(FpFormat::fp(3, 2), FpFormat::fp4_e2m1())
    }

    #[test]
    fn enob_scales_with_margin() {
        let agg = agg_for(
            &Distribution::Uniform,
            &Distribution::max_entropy(FpFormat::fp4_e2m1()),
            std_fmts(),
            32,
            4096,
            1,
        );
        let e6 = required_enob(&agg, Arch::Conventional, SpecConfig::default());
        let e12 = required_enob(
            &agg,
            Arch::Conventional,
            SpecConfig { margin_db: 12.0, empirical_floor: false },
        );
        // +6 dB margin: delta scales by sqrt(10^0.6) -> +0.9966 bits
        assert!(
            approx_eq(e12.enob - e6.enob, 0.9966, 1e-3),
            "{}",
            e12.enob - e6.enob
        );
    }

    #[test]
    fn gr_requires_less_resolution_than_conventional() {
        // the paper's core claim, under its own upper bound (uniform)
        let agg = agg_for(
            &Distribution::Uniform,
            &Distribution::max_entropy(FpFormat::fp4_e2m1()),
            std_fmts(),
            32,
            8192,
            2,
        );
        let d = delta_enob(&agg, SpecConfig::default());
        assert!(d > 1.0, "delta ENOB = {d}");
    }

    #[test]
    fn conventional_grows_with_range_for_long_tailed_data() {
        // under gauss+outliers, each extra exponent bit refines the core's
        // ulp (the floor drops ~4x per binade) so the conventional
        // requirement keeps climbing, while GR's referral gain tracks it
        let mut conv = Vec::new();
        let mut gr = Vec::new();
        for n_e in [2u32, 3, 4] {
            let fmts =
                FormatPair::new(FpFormat::fp(n_e, 2), FpFormat::fp4_e2m1());
            let agg = agg_for(
                &Distribution::gauss_outliers(),
                &Distribution::max_entropy(FpFormat::fp4_e2m1()),
                fmts,
                32,
                8192,
                10 + n_e as u64,
            );
            let cfg = SpecConfig::default();
            conv.push(required_enob(&agg, Arch::Conventional, cfg).enob);
            gr.push(required_enob(&agg, Arch::GrUnit, cfg).enob);
        }
        // conventional climbs until the core is fully resolved (~E3 for
        // the 1/150-sigma core), then plateaus
        assert!(conv[1] - conv[0] > 1.0, "conv growth {conv:?}");
        assert!(conv[2] >= conv[1] - 0.2, "conv plateau {conv:?}");
        // GR grows far less than conventional
        assert!(gr[2] - gr[0] < 0.5 * (conv[2] - conv[0]), "gr {gr:?}");
    }

    #[test]
    fn gr_advantage_explodes_for_llm_stress() {
        let fmts = FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1());
        let agg = agg_for(
            &Distribution::gauss_outliers(),
            &Distribution::max_entropy(FpFormat::fp4_e2m1()),
            fmts,
            32,
            8192,
            3,
        );
        let d = delta_enob(&agg, SpecConfig::default());
        assert!(d > 6.0, "delta ENOB = {d}");
    }

    #[test]
    fn row_referral_between_unit_and_conventional() {
        let fmts = std_fmts();
        let agg = agg_for(
            &Distribution::clipped_gauss4(),
            &Distribution::clipped_gauss4(),
            fmts,
            32,
            4096,
            4,
        );
        let cfg = SpecConfig::default();
        let conv = required_enob(&agg, Arch::Conventional, cfg).enob;
        let unit = required_enob(&agg, Arch::GrUnit, cfg).enob;
        let row = required_enob(&agg, Arch::GrRow, cfg).enob;
        assert!(unit <= row + 1e-9, "unit {unit} row {row}");
        assert!(row <= conv + 1e-9, "row {row} conv {conv}");
    }

    #[test]
    fn enob_grows_with_finer_input_mantissa() {
        // Fig. 11: ~1 bit per mantissa bit, for both architectures
        let mut prev_gr = 0.0;
        let mut prev_conv = 0.0;
        for n_m in 1..=5 {
            let fmts =
                FormatPair::new(FpFormat::fp(3, n_m), FpFormat::fp4_e2m1());
            let agg = agg_for(
                &Distribution::Uniform,
                &Distribution::max_entropy(FpFormat::fp4_e2m1()),
                fmts,
                32,
                4096,
                20 + n_m as u64,
            );
            let cfg = SpecConfig::default();
            let gr = required_enob(&agg, Arch::GrUnit, cfg).enob;
            let conv = required_enob(&agg, Arch::Conventional, cfg).enob;
            if n_m > 1 {
                assert!(
                    (0.6..1.4).contains(&(gr - prev_gr)),
                    "n_m={n_m}: gr step {}",
                    gr - prev_gr
                );
                assert!(
                    (0.6..1.4).contains(&(conv - prev_conv)),
                    "n_m={n_m}: conv step {}",
                    conv - prev_conv
                );
            }
            prev_gr = gr;
            prev_conv = conv;
        }
    }

    #[test]
    fn int_formats_make_archs_coincide() {
        // for INT inputs the FP ulp floor equals the INT grid floor and
        // the unit referral is weight-driven; conventional == gr-int
        // modulo the weight-side normalization gain
        let fmts = FormatPair::new(FpFormat::int(6), FpFormat::int(4));
        let agg = agg_for(
            &Distribution::Uniform,
            &Distribution::Uniform,
            fmts,
            32,
            4096,
            5,
        );
        let cfg = SpecConfig::default();
        let conv = required_enob(&agg, Arch::Conventional, cfg);
        let gri = required_enob(&agg, Arch::GrInt, cfg);
        // INT weights too: g_unit == 1 exactly, floors identical
        assert!(approx_eq(conv.noise_floor, gri.noise_floor, 1e-9));
        assert!(approx_eq(conv.enob, gri.enob, 1e-6));
    }

    #[test]
    fn empirical_floor_close_to_ulp_floor_for_gr() {
        // with fine weights, the empirical output error approaches the
        // input-only FP ulp floor used by the GR spec
        let fmts = FormatPair::new(FpFormat::fp(3, 2), FpFormat::fp(3, 7));
        let agg = agg_for(
            &Distribution::Uniform,
            &Distribution::Uniform,
            fmts,
            32,
            16384,
            6,
        );
        let ul = required_enob(&agg, Arch::GrUnit, SpecConfig::default());
        let emp = required_enob(
            &agg,
            Arch::GrUnit,
            SpecConfig { margin_db: 6.0, empirical_floor: true },
        );
        assert!((ul.enob - emp.enob).abs() < 1.0, "{} vs {}", ul.enob, emp.enob);
    }

    #[test]
    #[should_panic(expected = "empty aggregate")]
    fn rejects_empty_aggregate() {
        let agg = ColumnAgg::new(32);
        required_enob(&agg, Arch::Conventional, SpecConfig::default());
    }
}
