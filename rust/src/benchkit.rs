//! Minimal benchmark harness (the vendor set has no criterion). Used by
//! the `cargo bench` targets (`rust/benches/*.rs`, `harness = false`).
//!
//! Methodology: warmup, then `reps` timed repetitions of the closure;
//! reports min / median / mean wall time per repetition. Throughput-style
//! benches pass an items count to get items/s. [`Bench::save_json`]
//! persists the run (e.g. `BENCH_hotpath.json`) so successive PRs can
//! track the perf trajectory.

use crate::config::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// One measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark name.
    pub name: String,
    /// Timed repetitions (after one untimed warmup).
    pub reps: usize,
    /// Fastest repetition, seconds.
    pub min_s: f64,
    /// Median repetition, seconds.
    pub median_s: f64,
    /// Mean repetition, seconds.
    pub mean_s: f64,
    /// items/s based on the median, if items were declared.
    pub throughput: Option<f64>,
}

impl Measurement {
    /// One human-readable result line (times auto-scaled, throughput
    /// appended when declared).
    pub fn report(&self) -> String {
        let t = |s: f64| {
            if s < 1e-3 {
                format!("{:.1} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:.2} ms", s * 1e3)
            } else {
                format!("{s:.3} s")
            }
        };
        let tp = match self.throughput {
            Some(v) if v >= 1e6 => format!("  ({:.2} Mitems/s)", v / 1e6),
            Some(v) if v >= 1e3 => format!("  ({:.1} Kitems/s)", v / 1e3),
            Some(v) => format!("  ({v:.1} items/s)"),
            None => String::new(),
        };
        format!(
            "{:<44} min {:>10}  median {:>10}  mean {:>10}{}",
            self.name,
            t(self.min_s),
            t(self.median_s),
            t(self.mean_s),
            tp
        )
    }
}

/// Benchmark runner; collects measurements and prints them.
pub struct Bench {
    /// Everything measured so far, in run order.
    pub measurements: Vec<Measurement>,
    /// Reduce reps for smoke runs (GRCIM_BENCH_QUICK=1).
    quick: bool,
    /// Optional name filter from argv.
    filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// A runner configured from the environment (`GRCIM_BENCH_QUICK`,
    /// argv name filter).
    pub fn new() -> Self {
        let quick = std::env::var("GRCIM_BENCH_QUICK").is_ok();
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        Bench { measurements: Vec::new(), quick, filter }
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_ref().map(|f| name.contains(f.as_str())).unwrap_or(true)
    }

    /// Time `f` for `reps` repetitions (reduced in quick mode), with one
    /// untimed warmup call.
    pub fn run<F: FnMut()>(&mut self, name: &str, reps: usize, mut f: F) {
        self.run_with_items(name, reps, None, &mut f)
    }

    /// Like [`Bench::run`], reporting items/s throughput.
    pub fn run_items<F: FnMut()>(
        &mut self,
        name: &str,
        reps: usize,
        items: usize,
        mut f: F,
    ) {
        self.run_with_items(name, reps, Some(items), &mut f)
    }

    fn run_with_items(
        &mut self,
        name: &str,
        reps: usize,
        items: Option<usize>,
        f: &mut dyn FnMut(),
    ) {
        if !self.enabled(name) {
            return;
        }
        let reps = if self.quick { reps.div_ceil(4).max(2) } else { reps.max(2) };
        f(); // warmup
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let m = Measurement {
            name: name.to_string(),
            reps,
            min_s: times[0],
            median_s: median,
            mean_s: times.iter().sum::<f64>() / reps as f64,
            throughput: items.map(|n| n as f64 / median),
        };
        println!("{}", m.report());
        self.measurements.push(m);
    }

    /// Print the closing summary line.
    pub fn finish(&self) {
        println!(
            "\n{} benchmarks, {} mode",
            self.measurements.len(),
            if self.quick { "quick" } else { "full" }
        );
    }

    /// Serialize all measurements as JSON (stable schema for the perf
    /// trajectory files, e.g. `BENCH_hotpath.json`).
    pub fn to_json(&self) -> Json {
        let measurements: Vec<Json> = self
            .measurements
            .iter()
            .map(|m| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(m.name.clone()));
                o.insert("reps".to_string(), Json::Num(m.reps as f64));
                o.insert("min_s".to_string(), Json::Num(m.min_s));
                o.insert("median_s".to_string(), Json::Num(m.median_s));
                o.insert("mean_s".to_string(), Json::Num(m.mean_s));
                o.insert(
                    "items_per_s".to_string(),
                    match m.throughput {
                        Some(v) => Json::Num(v),
                        None => Json::Null,
                    },
                );
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert(
            "mode".to_string(),
            Json::Str(if self.quick { "quick" } else { "full" }.to_string()),
        );
        root.insert("measurements".to_string(), Json::Arr(measurements));
        Json::Obj(root)
    }

    /// Write the JSON report to `path`.
    pub fn save_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench { measurements: vec![], quick: true, filter: None };
        let mut acc = 0u64;
        b.run_items("spin", 4, 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(b.measurements.len(), 1);
        let m = &b.measurements[0];
        assert!(m.min_s <= m.median_s);
        assert!(m.throughput.unwrap() > 0.0);
        assert!(m.report().contains("spin"));
        assert!(acc > 0);
    }

    #[test]
    fn filter_skips_unmatched() {
        let mut b = Bench {
            measurements: vec![],
            quick: true,
            filter: Some("xyz".into()),
        };
        b.run("abc", 2, || {});
        assert!(b.measurements.is_empty());
        b.run("has_xyz_inside", 2, || {});
        assert_eq!(b.measurements.len(), 1);
    }

    #[test]
    fn json_export_round_trips() {
        let b = Bench {
            measurements: vec![Measurement {
                name: "m".into(),
                reps: 3,
                min_s: 0.001,
                median_s: 0.002,
                mean_s: 0.002,
                throughput: Some(1000.0),
            }],
            quick: true,
            filter: None,
        };
        let j = b.to_json();
        let again = Json::parse(&j.to_string()).unwrap();
        let ms = again.get("measurements").unwrap().items();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].get("name").unwrap().as_str(), Some("m"));
        assert_eq!(ms[0].get("items_per_s").unwrap().as_f64(), Some(1000.0));
        assert_eq!(again.get("mode").unwrap().as_str(), Some("quick"));
    }

    #[test]
    fn report_formats_scales() {
        let m = Measurement {
            name: "n".into(),
            reps: 3,
            min_s: 5e-6,
            median_s: 5e-6,
            mean_s: 5e-6,
            throughput: Some(2e6),
        };
        let r = m.report();
        assert!(r.contains("µs") && r.contains("Mitems/s"));
    }
}
