//! Fig. 9 — quantization SQNR vs exponent bits for the three workload
//! distributions (plus the Gaussian+outliers *core* subset), N_M,x = 2.
//!
//! The paper's point: global SQNR saturates quickly with exponent bits and
//! is dominated by large values — it hides the fact that a long-tailed
//! distribution's core can be completely unresolved. The core-subset
//! series exposes that: ~no signal below N_E = 3, resolved within ~6 dB of
//! the ceiling at N_E = 3, plateau at N_E = 4.

use super::FigureCtx;
use crate::distributions::Distribution;
use crate::formats::FpFormat;
use crate::report::{FigureResult, Table};
use crate::rng::Pcg64;
use crate::util::db;
use anyhow::Result;

/// Input mantissa bits across the sweep (paper: N_M,x = 2).
pub const N_M: u32 = 2;
/// Exponent-bit axis (0 = the same-total-bits INT point).
pub const N_E_RANGE: std::ops::RangeInclusive<u32> = 0..=5;

/// Element-level SQNR of `dist` quantized to `fmt`.
///
/// `core_only` restricts both signal and noise to non-outlier samples.
/// `ulp_floor` replaces the empirical error with the format's ulp noise
/// (exact for max-entropy inputs, whose empirical error is zero).
/// Shared with the workload report (`workload::sqnr_sweep`), which runs
/// the same sweep over an empirical trace distribution.
pub(crate) fn sqnr_db(
    fmt: FpFormat,
    dist: &Distribution,
    samples: usize,
    seed: u64,
    core_only: bool,
    ulp_floor: bool,
) -> f64 {
    let mut rng = Pcg64::seeded(seed);
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    let mut n = 0u64;
    for _ in 0..samples {
        let x = dist.sample(&mut rng);
        if core_only && dist.is_outlier(x) {
            continue;
        }
        let q = fmt.quantize(x);
        sig += x * x;
        noise += if ulp_floor {
            let u = fmt.ulp(q.abs());
            u * u / 12.0
        } else {
            (x - q) * (x - q)
        };
        n += 1;
    }
    if n == 0 {
        return f64::NEG_INFINITY;
    }
    db(sig / noise.max(1e-300))
}

/// The format at `n_e` exponent bits on the Fig. 9 axis: FP(n_e, N_M) for
/// n_e >= 1, and the same-total-bits INT format at the n_e = 0 origin.
pub(crate) fn fmt_for(n_e: u32) -> FpFormat {
    if n_e == 0 {
        FpFormat::int(N_M + 2) // INT with the same total bits
    } else {
        FpFormat::fp(n_e, N_M)
    }
}

/// The Fig. 9 series at exact f64 precision: for each `n_e` in
/// [`N_E_RANGE`], the element-level SQNR (dB) under
/// `[uniform, max_entropy, gauss_outliers, gauss_outliers_core]`.
/// Public so the golden regression suite (`rust/tests/golden.rs`) can pin
/// the values without going through formatted report tables.
pub fn sqnr_series(samples: usize, seed: u64) -> Vec<[f64; 4]> {
    N_E_RANGE
        .map(|n_e| {
            let fmt = fmt_for(n_e);
            let uni = sqnr_db(
                fmt,
                &Distribution::Uniform,
                samples,
                seed + 1,
                false,
                false,
            );
            let me = sqnr_db(
                fmt,
                &Distribution::max_entropy(fmt),
                samples,
                seed + 2,
                false,
                true,
            );
            let go = Distribution::gauss_outliers();
            let go_all = sqnr_db(fmt, &go, samples, seed + 3, false, false);
            let go_core = sqnr_db(fmt, &go, samples, seed + 3, true, false);
            [uni, me, go_all, go_core]
        })
        .collect()
}

/// Regenerate Fig. 9 (SQNR vs exponent bits, four distributions).
pub fn run(ctx: &FigureCtx) -> Result<FigureResult> {
    let samples = ctx.samples.max(16_384);
    let seed = ctx.campaign.seed ^ 0xF19;
    let ceiling = 6.02 * (N_M as f64 + 1.0) + 10.79;

    let mut fr = FigureResult::new("fig9");
    let mut t = Table::new(
        "sqnr vs exponent bits",
        &["n_e", "uniform", "max_entropy", "gauss_outliers", "gauss_outliers_core", "ceiling"],
    );

    let series = sqnr_series(samples, seed);
    for (i, n_e) in N_E_RANGE.enumerate() {
        let [uni, me, go_all, go_core] = series[i];
        t.row(vec![
            n_e.to_string(),
            Table::f(uni),
            Table::f(me),
            Table::f(go_all),
            Table::f(go_core),
            Table::f(ceiling),
        ]);
    }
    fr.tables.push(t);

    // paper-shape checks (indices: n_e = 0..5)
    let uni = |i: usize| series[i][0];
    let go_all = |i: usize| series[i][2];
    let go_core = |i: usize| series[i][3];

    fr.check(
        "uniform saturates: extra exponent bits give negligible benefit",
        "plateau after E2",
        format!("SQNR(E5)-SQNR(E2) = {:.2} dB", uni(5) - uni(2)),
        (uni(5) - uni(2)).abs() < 1.5,
    );
    fr.check(
        "global SQNR of gauss+outliers is high even when the core is dead",
        "~18 dB at E2 while core has no signal",
        format!("global {:.1} dB, core {:.1} dB at E2", go_all(2), go_core(2)),
        go_all(2) > 12.0 && go_core(2) < 8.0,
    );
    fr.check(
        "core resolved to within ~6 dB of ceiling at E3",
        "within 6 dB",
        format!("core {:.1} dB vs ceiling {:.1} dB", go_core(3), ceiling),
        go_core(3) > ceiling - 9.0,
    );
    // note: the 6.02*N_M + 10.79 dB closed form is a *relative-error*
    // SQNR (Widrow/Kollar); our global-power convention weighs noise by
    // magnitude and sits ~3 dB below it. The shape claims are unaffected.
    fr.check(
        "core plateaus at E4",
        "plateau at N_E=4",
        format!("core E4 {:.1}, E5 {:.1} dB", go_core(4), go_core(5)),
        (go_core(5) - go_core(4)).abs() < 1.0
            && go_core(4) > ceiling - 4.5,
    );
    fr.check(
        "max-entropy sits near the format ceiling, flat in N_E",
        "= ceiling (relative-error convention)",
        format!("{:.1} dB vs {:.1} dB at E3", series[3][1], ceiling),
        (series[3][1] - ceiling).abs() < 4.5
            && (series[5][1] - series[2][1]).abs() < 1.0,
    );
    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_reproduces_paper_shape() {
        let ctx = FigureCtx::default().quick();
        let fr = run(&ctx).unwrap();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
    }

    #[test]
    fn sqnr_helper_sane() {
        // fine format on uniform input: empirical ~ ulp-based
        let fmt = FpFormat::fp(3, 6);
        let emp = sqnr_db(fmt, &Distribution::Uniform, 20_000, 1, false, false);
        let ulp = sqnr_db(fmt, &Distribution::Uniform, 20_000, 1, false, true);
        assert!((emp - ulp).abs() < 2.0, "{emp} vs {ulp}");
    }
}
