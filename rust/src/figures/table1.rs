//! Table I — FP6_E2M3 GR-MAC capacitor values.
//!
//! The schematic column comes straight out of the design procedure
//! (eq. (1) + the two layout transformations); the paper's post-layout
//! columns depend on a 22 nm extraction we substitute with explicit
//! parasitic-compensated designs at representative C_p1 values.

use super::FigureCtx;
use crate::analog::GrMacCell;
use crate::report::{FigureResult, Table};
use crate::util::approx_eq;
use anyhow::Result;

/// Paper Table I schematic mantissa-divider values (fF).
pub const PAPER_C_M: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
/// Paper Table I schematic coupling-stage values (fF).
pub const PAPER_C_E: [f64; 4] = [1.0, 1.14, 4.0, 10.0];

/// Regenerate Table I (designed capacitor values vs the paper's).
pub fn run(_ctx: &FigureCtx) -> Result<FigureResult> {
    let mut fr = FigureResult::new("table1");
    let schematic = GrMacCell::fp6_e2m3_schematic();
    let comp05 = GrMacCell::design(4, 4, 1.0, 0.5);
    let comp10 = GrMacCell::design(4, 4, 1.0, 1.0);

    let mut t = Table::new(
        "capacitors",
        &["capacitor", "paper_schematic_fF", "ours_fF", "comp_Cp1_0.5fF", "comp_Cp1_1.0fF"],
    );
    for (i, paper) in PAPER_C_M.iter().enumerate() {
        t.row(vec![
            format!("C_M{i}"),
            Table::f(*paper),
            Table::f(schematic.c_m[i]),
            Table::f(comp05.c_m[i]),
            Table::f(comp10.c_m[i]),
        ]);
    }
    for (i, paper) in PAPER_C_E.iter().enumerate() {
        t.row(vec![
            format!("C_E{}", i + 1),
            Table::f(*paper),
            Table::f(schematic.c_e[i]),
            Table::f(comp05.c_e[i]),
            Table::f(comp10.c_e[i]),
        ]);
    }
    fr.tables.push(t);

    let mut ok = true;
    for (ours, paper) in schematic.c_m.iter().zip(&PAPER_C_M) {
        ok &= approx_eq(*ours, *paper, 1e-9);
    }
    for (ours, paper) in schematic.c_e.iter().zip(&PAPER_C_E) {
        ok &= (ours - paper).abs() < 0.005; // paper rounds 8/7 to 1.14
    }
    fr.check(
        "schematic capacitor values match Table I",
        "C_E = {1, 1.14, 4, 10} fF",
        format!(
            "C_E = {{{}}} fF",
            schematic
                .c_e
                .iter()
                .map(|c| format!("{c:.3}"))
                .collect::<Vec<_>>()
                .join(", ")
        ),
        ok,
    );

    // gain ratios stay exact octaves after compensation
    let q = |cell: &GrMacCell| -> Vec<f64> {
        (1..=4).map(|l| cell.transfer_closed_form(15, l, 1.0)).collect()
    };
    let ratios_ok = |cell: &GrMacCell| -> bool {
        let qs = q(cell);
        qs.windows(2).all(|w| approx_eq(w[1] / w[0], 2.0, 1e-9))
    };
    fr.check(
        "compensated design preserves exact octave gains",
        "eq. (1)",
        "exact at C_p1 = 0.5 and 1.0 fF",
        ratios_ok(&comp05) && ratios_ok(&comp10),
    );
    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let fr = run(&FigureCtx::default()).unwrap();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
    }
}
