//! Fig. 11 — required ADC resolution (ENOB) vs input precision,
//! parameterized by mantissa bits N_M,x (N_E,x = 3 so every studied
//! distribution fits the format's range), weights max-entropy FP4_E2M1,
//! NR = 32.
//!
//! Paper shape: ENOB scales linearly with input precision, and the GR
//! advantage (1.5–6+ bits depending on distribution) is independent of the
//! input resolution.

use super::fig10::{sweep, Dist};
use super::FigureCtx;
use crate::formats::FpFormat;
use crate::report::{FigureResult, Table};
use anyhow::Result;

/// Input exponent bits across the sweep (every distribution fits E3).
pub const N_E_X: u32 = 3;
/// Mantissa-bit axis of the precision sweep.
pub const N_M_RANGE: std::ops::RangeInclusive<u32> = 1..=6;

/// Regenerate Fig. 11 (required ENOB vs input precision).
pub fn run(ctx: &FigureCtx) -> Result<FigureResult> {
    let formats: Vec<(u32, FpFormat)> = N_M_RANGE
        .map(|n_m| (n_m, FpFormat::fp(N_E_X, n_m)))
        .collect();
    let data = sweep(ctx, &formats)?;

    let mut fr = FigureResult::new("fig11");
    let mut t = Table::new(
        "enob vs precision",
        &["n_m_x", "sqnr_db", "distribution", "enob_conventional", "enob_gr_unit", "delta"],
    );
    for &(n_m, dist, conv, gr) in &data.rows {
        let fmt = FpFormat::fp(N_E_X, n_m);
        t.row(vec![
            n_m.to_string(),
            Table::f(fmt.sqnr_db()),
            dist.name().into(),
            Table::f(conv),
            Table::f(gr),
            Table::f(conv - gr),
        ]);
    }
    fr.tables.push(t);

    let series = |d: Dist, gr_side: bool| -> Vec<f64> {
        N_M_RANGE
            .map(|nm| {
                data.rows
                    .iter()
                    .find(|(t, dist, _, _)| *t == nm && *dist == d)
                    .map(|&(_, _, c, g)| if gr_side { g } else { c })
                    .unwrap()
            })
            .collect()
    };

    // linear scaling: successive increments ~1 bit per mantissa bit
    let gr_uni = series(Dist::Uniform, true);
    let incs: Vec<f64> = gr_uni.windows(2).map(|w| w[1] - w[0]).collect();
    let inc_ok = incs.iter().all(|&d| (0.6..=1.4).contains(&d));
    fr.check(
        "ENOB scales linearly with input precision (~1 b per mantissa bit)",
        "linear",
        format!("GR/uniform increments: {incs:?}"),
        inc_ok,
    );

    // advantage independent of resolution
    let conv_uni = series(Dist::Uniform, false);
    let gaps: Vec<f64> = conv_uni
        .iter()
        .zip(&gr_uni)
        .map(|(c, g)| c - g)
        .collect();
    let spread = gaps.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - gaps.iter().cloned().fold(f64::INFINITY, f64::min);
    fr.check(
        "GR advantage independent of input resolution",
        "constant 1.5-6 b offset",
        format!("uniform-dist gap spread {spread:.2} b across N_M=1..6"),
        spread < 1.0 && gaps.iter().all(|&g| g >= 1.3),
    );

    let conv_go = series(Dist::GaussOutliers, false);
    let gr_go = series(Dist::GaussOutliers, true);
    let go_gaps: Vec<f64> =
        conv_go.iter().zip(&gr_go).map(|(c, g)| c - g).collect();
    fr.check(
        "large gauss+outliers advantage at every precision",
        "1.5-6+ bits",
        format!(
            "min {:.1} b",
            go_gaps.iter().cloned().fold(f64::INFINITY, f64::min)
        ),
        go_gaps.iter().all(|&g| g > 4.0),
    );
    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_reproduces_paper_shape() {
        let ctx = FigureCtx::default().quick();
        let fr = run(&ctx).unwrap();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
        assert_eq!(fr.tables[0].rows.len(), 6 * 3);
    }
}
