//! Fig. 10 — required ADC resolution (ENOB) vs input dynamic range,
//! parameterized by input exponent bits N_E,x (N_M,x = 2), weights fixed
//! to max-entropy FP4_E2M1, NR = 32.
//!
//! Series: conventional vs GR-MAC (unit normalization) under the uniform,
//! max-entropy, and Gaussian+outliers input distributions. This is the
//! paper's headline ADC result: the GR upper bound (its *worst* case, the
//! uniform distribution) sits >= 1.5 bits below the conventional lower
//! bound, and the gap exceeds 6 bits for the LLM stress distribution once
//! the format can actually resolve its core (N_E >= 3).

use super::FigureCtx;
use crate::coordinator::{run_campaign, ExperimentSpec};
use crate::distributions::Distribution;
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::report::{FigureResult, Table};
use crate::spec::{required_enob, Arch, SpecConfig};
use anyhow::Result;

/// Array depth of the sweep (paper: NR = 32).
pub const NR: usize = 32;
/// Input mantissa bits (paper: N_M,x = 2).
pub const N_M_X: u32 = 2;
/// Exponent-bit axis of the dynamic-range sweep.
pub const N_E_RANGE: std::ops::RangeInclusive<u32> = 1..=5;

pub(crate) fn weight_fmt() -> FpFormat {
    FpFormat::fp4_e2m1()
}

/// The three input distributions the Fig. 10/11 sweeps compare.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dist {
    /// Uniform on [-1, 1].
    Uniform,
    /// Max-entropy over the input format's bit patterns.
    MaxEntropy,
    /// The Gaussian+outliers LLM stress model.
    GaussOutliers,
}

impl Dist {
    pub(crate) const ALL: [Dist; 3] =
        [Dist::Uniform, Dist::MaxEntropy, Dist::GaussOutliers];

    pub(crate) fn name(&self) -> &'static str {
        match self {
            Dist::Uniform => "uniform",
            Dist::MaxEntropy => "max_entropy",
            Dist::GaussOutliers => "gauss_outliers",
        }
    }

    pub(crate) fn build(&self, input_fmt: FpFormat) -> Distribution {
        match self {
            Dist::Uniform => Distribution::Uniform,
            Dist::MaxEntropy => Distribution::max_entropy(input_fmt),
            Dist::GaussOutliers => Distribution::gauss_outliers(),
        }
    }
}

/// ENOB results per (n_e, distribution): [conventional, gr-unit].
pub struct Fig10Data {
    /// (axis tag, distribution, conventional ENOB, gr-unit ENOB) rows.
    pub rows: Vec<(u32, Dist, f64, f64)>,
}

pub(crate) fn sweep(
    ctx: &FigureCtx,
    formats: &[(u32, FpFormat)],
) -> Result<Fig10Data> {
    let mut specs = Vec::new();
    for &(tag, fmt) in formats {
        for dist in Dist::ALL {
            specs.push(ExperimentSpec {
                id: format!("ne{tag}-{}", dist.name()),
                fmts: FormatPair::new(fmt, weight_fmt()),
                dist_x: dist.build(fmt),
                dist_w: Distribution::max_entropy(weight_fmt()),
                nr: NR,
                samples: ctx.samples,
                sampler: Default::default(),
            });
        }
    }
    let aggs = run_campaign(&specs, &ctx.campaign)?;
    let cfg = SpecConfig::default();
    let mut rows = Vec::new();
    for (i, &(tag, _)) in formats.iter().enumerate() {
        for (j, dist) in Dist::ALL.into_iter().enumerate() {
            let agg = &aggs[i * Dist::ALL.len() + j];
            let conv = required_enob(agg, Arch::Conventional, cfg).enob;
            let gr = required_enob(agg, Arch::GrUnit, cfg).enob;
            rows.push((tag, dist, conv, gr));
        }
    }
    Ok(Fig10Data { rows })
}

/// Regenerate Fig. 10 (required ENOB vs input dynamic range).
pub fn run(ctx: &FigureCtx) -> Result<FigureResult> {
    let formats: Vec<(u32, FpFormat)> = N_E_RANGE
        .map(|n_e| (n_e, FpFormat::fp(n_e, N_M_X)))
        .collect();
    let data = sweep(ctx, &formats)?;

    let mut fr = FigureResult::new("fig10");
    let mut t = Table::new(
        "enob vs dynamic range",
        &["n_e_x", "dr_db", "distribution", "enob_conventional", "enob_gr_unit", "delta"],
    );
    for &(n_e, dist, conv, gr) in &data.rows {
        let fmt = FpFormat::fp(n_e, N_M_X);
        t.row(vec![
            n_e.to_string(),
            Table::f(fmt.dr_db()),
            dist.name().into(),
            Table::f(conv),
            Table::f(gr),
            Table::f(conv - gr),
        ]);
    }
    fr.tables.push(t);

    let get = |n_e: u32, d: Dist| -> (f64, f64) {
        data.rows
            .iter()
            .find(|(ne, dist, _, _)| *ne == n_e && *dist == d)
            .map(|&(_, _, c, g)| (c, g))
            .unwrap()
    };

    // GR upper bound (uniform) vs conventional lower bound (uniform),
    // over the FP formats (N_E >= 2; at N_E = 1 there are no exponents to
    // range, so gain-ranging degenerates and the gap closes by design)
    let min_gap = (2..=5)
        .map(|ne| {
            let (c, g) = get(ne, Dist::Uniform);
            c - g
        })
        .fold(f64::INFINITY, f64::min);
    fr.check(
        "GR upper bound >= 1.5 b below conventional lower bound",
        ">= 1.5 bits",
        format!("min gap {min_gap:.2} bits (uniform, N_E >= 2)"),
        min_gap >= 1.3,
    );

    let (c3, g3) = get(3, Dist::GaussOutliers);
    let (c4, g4) = get(4, Dist::GaussOutliers);
    fr.check(
        "gauss+outliers advantage reaches ~6 bits once the core resolves",
        "> 6 bits at N_E >= 3",
        format!("{:.1} b @E3, {:.1} b @E4", c3 - g3, c4 - g4),
        c3 - g3 > 5.4 && c4 - g4 > 6.0,
    );

    let max_gr = data
        .rows
        .iter()
        .map(|&(_, _, _, g)| g)
        .fold(f64::NEG_INFINITY, f64::max);
    fr.check(
        "GR ENOB stays below the thermal-noise boundary N_cross",
        "< ~10 bits",
        format!("max GR ENOB {max_gr:.2} bits"),
        max_gr < 10.0,
    );

    // GR's uniform case is its own worst case (data-invariant upper bound)
    let gr_invariant = (2..=5).all(|ne| {
        let (_, gu) = get(ne, Dist::Uniform);
        Dist::ALL
            .iter()
            .all(|d| get(ne, *d).1 <= gu + 0.3)
    });
    fr.check(
        "uniform upper-bounds the GR requirement (data-invariant spec)",
        "uniform = upper bound",
        format!("holds across N_E 2..5: {gr_invariant}"),
        gr_invariant,
    );

    // conventional keeps climbing with DR for long-tailed data while GR
    // stays flat or falls (the scaling split of Sec. I)
    let (c2go, g2go) = get(2, Dist::GaussOutliers);
    let (c5go, g5go) = get(5, Dist::GaussOutliers);
    let (c2u, g2u) = get(2, Dist::Uniform);
    let (c5u, g5u) = get(5, Dist::Uniform);
    let _ = (c2u, c5u);
    fr.check(
        "conventional ENOB climbs with DR for long-tailed data; GR does not",
        "conventional DR-dominated",
        format!(
            "conv +{:.1} b, GR {:+.1} b (gauss+outliers E2->E5); GR uniform {:+.1} b",
            c5go - c2go,
            g5go - g2go,
            g5u - g2u
        ),
        (c5go - c2go) > 1.0 && (g5go - g2go) < 0.5 && (g5u - g2u).abs() < 1.0,
    );
    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_reproduces_paper_shape() {
        let ctx = FigureCtx::default().quick();
        let fr = run(&ctx).unwrap();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
        assert_eq!(fr.tables[0].rows.len(), 5 * 3);
    }
}
