//! Fig. 12 — CIM energy per operation over the (dynamic range, precision)
//! design space, with energy-optimal GR normalization-granularity regions,
//! per-format energy-breakdown pies (FP4/FP6/FP8*), the iso-SQNR dynamic-
//! range headlines, and the ±10% ADC-parameter sensitivity study.
//!
//! Modeling conventions (DESIGN.md #3/#7):
//!
//! * A design-space point is (DR_bits, N_M_eff): SQNR_dB = 6.02*N_M_eff +
//!   10.79 and DR_dB = 6.02*DR_bits. Points with e_max < 1 are left of the
//!   INT line (invalid).
//! * **Conventional** = direct-accumulation INT CIM spanning the full DR
//!   statically (`FpFormat::int(DR_bits)`), dimensioned on a uniform input
//!   at the spec's narrowest valid bounds (r = 2 * min_normal of the FP
//!   interpretation) — the paper's worst-case rule.
//! * **GR** = the FP format from the spec, dimensioned on the full-scale
//!   uniform distribution (the GR upper bound). Unit/row granularities are
//!   dimensioned through their own referral gains; the INT granularity
//!   reuses the conventional input (INT) with weight-side gain ranging.
//! * The gain-ranging stage natively supports ~6 bits of range
//!   (Sec. III-D: "a conservative limit of 6 bits is assumed"); points
//!   beyond need global normalization and are marked.

use super::FigureCtx;
use crate::coordinator::{run_campaign, ExperimentSpec};
use crate::distributions::Distribution;
use crate::energy::{energy_per_op, CimArch, EnergyBreakdown, TechParams};
use crate::formats::{exp2, FpFormat};
use crate::mac::FormatPair;
use crate::report::{FigureResult, Table};
use crate::spec::{required_enob, Arch, SpecConfig};
use crate::stats::ColumnAgg;
use anyhow::Result;

/// Array depth of the energy map (paper: 32).
pub const NR: usize = 32;
/// Array width of the energy map (paper: 32).
pub const NC: usize = 32;
/// Native range of the gain-ranging stage, in octaves (bits).
pub const GAIN_RANGE_BITS: f64 = 6.0;
/// The paper's practical energy ceiling (10 TOPS/W).
pub const ENERGY_CAP_FJ: f64 = 100.0;

/// Weights across the whole map: max-entropy FP4 (paper caption).
pub fn weight_fmt() -> FpFormat {
    FpFormat::fp4_e2m1()
}

/// One design-space specification.
#[derive(Debug, Clone, Copy)]
pub struct SpecPoint {
    /// Dynamic range in bits (DR_dB / 6.02).
    pub dr_bits: f64,
    /// Effective mantissa bits, implicit bit included.
    pub n_m_eff: f64,
}

impl SpecPoint {
    /// The point's dynamic-range axis value, dB.
    pub fn dr_db(&self) -> f64 {
        6.02 * self.dr_bits
    }

    /// The point's SQNR axis value, dB.
    pub fn sqnr_db(&self) -> f64 {
        6.02 * self.n_m_eff + 10.79
    }

    /// FP interpretation of the spec (None left of the INT line).
    pub fn fp_format(&self) -> Option<FpFormat> {
        let n_m = self.n_m_eff - 1.0;
        if n_m < 0.0 {
            return None;
        }
        let e_max = self.dr_bits - n_m - 1.0;
        if e_max < 1.0 - 1e-9 {
            return None;
        }
        Some(FpFormat { e_max: e_max.max(1.0), n_m })
    }

    /// Static INT format spanning the DR.
    pub fn int_format(&self) -> Option<FpFormat> {
        if self.dr_bits < 2.0 {
            return None;
        }
        Some(FpFormat { e_max: 1.0, n_m: self.dr_bits - 2.0 })
    }

    /// The design-space point a concrete format occupies.
    pub fn from_format(fmt: FpFormat) -> Self {
        SpecPoint { dr_bits: fmt.dr_bits(), n_m_eff: fmt.n_m + 1.0 }
    }

    /// From the paper's dB axes: DR_dB = 6.02 · DR_bits and
    /// SQNR_dB = 6.02 · N_M_eff + 10.79. The single conversion shared by
    /// `grcim energy` and the serve layer's `energy` request.
    pub fn from_db(dr_db: f64, sqnr_db: f64) -> Self {
        SpecPoint { dr_bits: dr_db / 6.02, n_m_eff: (sqnr_db - 10.79) / 6.02 }
    }
}

/// Whether a granularity fits the native gain-ranging range.
pub fn native_ok(arch: CimArch, fmt_x: FpFormat, fmt_w: FpFormat) -> bool {
    match arch {
        CimArch::Conventional => true,
        CimArch::GrUnit => {
            (fmt_x.e_max - 1.0) + (fmt_w.e_max - 1.0) <= GAIN_RANGE_BITS
        }
        CimArch::GrRow => fmt_x.e_max - 1.0 <= GAIN_RANGE_BITS,
        CimArch::GrInt => fmt_w.e_max - 1.0 <= GAIN_RANGE_BITS,
    }
}

/// Evaluated energies at one spec point.
#[derive(Debug, Clone)]
pub struct PointResult {
    /// The evaluated spec point.
    pub spec: SpecPoint,
    /// Conventional-architecture ADC requirement, bits.
    pub enob_conv: f64,
    /// Conventional-architecture energy breakdown.
    pub e_conv: EnergyBreakdown,
    /// Best native GR option, if any: (granularity, ENOB, breakdown).
    pub gr_best: Option<(CimArch, f64, EnergyBreakdown)>,
    /// All native GR options.
    pub gr_all: Vec<(CimArch, f64, EnergyBreakdown)>,
}

impl PointResult {
    /// Total energy of the best native GR option, if any, fJ/Op.
    pub fn gr_total(&self) -> Option<f64> {
        self.gr_best.as_ref().map(|(_, _, b)| b.total())
    }
}

/// Dimensioning distribution for the conventional/INT side: uniform at the
/// spec's narrowest valid bounds (paper Sec. IV-B). Public because the
/// serve layer builds the same two experiments per spec point to route
/// them through its aggregate cache.
pub fn narrow_bounds_dist(fp: FpFormat) -> Distribution {
    Distribution::UniformScaled { r: (2.0 * exp2(-fp.e_max)).min(1.0) }
}

/// Evaluate one spec point from its two campaign aggregates: `agg_int` is
/// the INT/narrow-bounds experiment (conventional + gr-int dimensioning),
/// `agg_fp` the FP/full-scale one (gr-unit / gr-row). Returns `None` left
/// of the INT line. Shared by [`evaluate_points`] and the serve layer's
/// `energy` handler (which feeds it cached aggregates).
pub fn evaluate_at(
    p: &SpecPoint,
    agg_int: &ColumnAgg,
    agg_fp: &ColumnAgg,
    tech: &TechParams,
) -> Option<PointResult> {
    let (fp, int) = (p.fp_format()?, p.int_format()?);
    let w_fmt = weight_fmt();
    let cfg = SpecConfig::default();

    let enob_conv = required_enob(agg_int, Arch::Conventional, cfg).enob;
    let e_conv = energy_per_op(
        CimArch::Conventional,
        FormatPair::new(int, w_fmt),
        NR,
        NC,
        enob_conv,
        tech,
    );

    let mut gr_all = Vec::new();
    // unit / row on the FP aggregate
    for (arch, sarch) in
        [(CimArch::GrUnit, Arch::GrUnit), (CimArch::GrRow, Arch::GrRow)]
    {
        if native_ok(arch, fp, w_fmt) {
            let enob = required_enob(agg_fp, sarch, cfg).enob;
            let e = energy_per_op(
                arch,
                FormatPair::new(fp, w_fmt),
                NR,
                NC,
                enob,
                tech,
            );
            gr_all.push((arch, enob, e));
        }
    }
    // INT granularity on the INT aggregate (weight-side gain ranging)
    if native_ok(CimArch::GrInt, int, w_fmt) {
        let enob = required_enob(agg_int, Arch::GrInt, cfg).enob;
        let e = energy_per_op(
            CimArch::GrInt,
            FormatPair::new(int, w_fmt),
            NR,
            NC,
            enob,
            tech,
        );
        gr_all.push((CimArch::GrInt, enob, e));
    }
    let gr_best = gr_all
        .iter()
        .min_by(|a, b| a.2.total().partial_cmp(&b.2.total()).unwrap())
        .cloned();
    Some(PointResult { spec: *p, enob_conv, e_conv, gr_best, gr_all })
}

/// Evaluate a set of spec points with a single campaign (two MC
/// experiments per point: INT/narrow-bounds and FP/full-scale), under
/// the plain (historical, golden-pinned) estimator.
pub fn evaluate_points(
    ctx: &FigureCtx,
    points: &[SpecPoint],
    samples: usize,
    tech: &TechParams,
) -> Result<Vec<Option<PointResult>>> {
    evaluate_points_with(ctx, points, samples, Default::default(), tech)
}

/// [`evaluate_points`] under an explicit estimator mode — the CLI's
/// `energy --sampler` entry point.
pub fn evaluate_points_with(
    ctx: &FigureCtx,
    points: &[SpecPoint],
    samples: usize,
    sampler: crate::distributions::Sampler,
    tech: &TechParams,
) -> Result<Vec<Option<PointResult>>> {
    let w_fmt = weight_fmt();
    let w_dist = Distribution::max_entropy(w_fmt);

    // build specs; remember mapping point -> (int_idx, fp_idx)
    let mut specs = Vec::new();
    let mut index: Vec<Option<(usize, usize)>> = Vec::with_capacity(points.len());
    for p in points {
        let (Some(fp), Some(int)) = (p.fp_format(), p.int_format()) else {
            index.push(None);
            continue;
        };
        let int_idx = specs.len();
        specs.push(ExperimentSpec {
            id: format!("int-dr{:.1}-m{:.1}", p.dr_bits, p.n_m_eff),
            fmts: FormatPair::new(int, w_fmt),
            dist_x: narrow_bounds_dist(fp),
            dist_w: w_dist.clone(),
            nr: NR,
            samples,
            sampler,
        });
        let fp_idx = specs.len();
        specs.push(ExperimentSpec {
            id: format!("fp-dr{:.1}-m{:.1}", p.dr_bits, p.n_m_eff),
            fmts: FormatPair::new(fp, w_fmt),
            dist_x: Distribution::Uniform,
            dist_w: w_dist.clone(),
            nr: NR,
            samples,
            sampler,
        });
        index.push(Some((int_idx, fp_idx)));
    }

    let aggs = run_campaign(&specs, &ctx.campaign)?;

    let mut out = Vec::with_capacity(points.len());
    for (p, idx) in points.iter().zip(index) {
        let Some((int_idx, fp_idx)) = idx else {
            out.push(None);
            continue;
        };
        let agg_int: &ColumnAgg = &aggs[int_idx];
        let agg_fp: &ColumnAgg = &aggs[fp_idx];
        out.push(evaluate_at(p, agg_int, agg_fp, tech));
    }
    Ok(out)
}

/// Max DR (bits) achievable at `sqnr` under an energy cap, scanning
/// evaluated points on one iso-SQNR row. Returns (conv, gr).
fn max_dr_under_cap(
    rows: &[Option<PointResult>],
    cap_fj: f64,
) -> (Option<f64>, Option<f64>) {
    let mut conv: Option<f64> = None;
    let mut gr: Option<f64> = None;
    for r in rows.iter().flatten() {
        if r.e_conv.total() <= cap_fj {
            conv = Some(conv.unwrap_or(0.0).max(r.spec.dr_bits));
        }
        if let Some(total) = r.gr_total() {
            if total <= cap_fj {
                gr = Some(gr.unwrap_or(0.0).max(r.spec.dr_bits));
            }
        }
    }
    (conv, gr)
}

fn pie_rows(t: &mut Table, label: &str, arch: &str, enob: f64, b: &EnergyBreakdown) {
    for (name, v) in b.components() {
        t.row(vec![
            label.into(),
            arch.into(),
            Table::f(enob),
            name.into(),
            Table::f(v),
            Table::f(100.0 * v / b.total().max(1e-300)),
        ]);
    }
    t.row(vec![
        label.into(),
        arch.into(),
        Table::f(enob),
        "total".into(),
        Table::f(b.total()),
        "100".into(),
    ]);
}

/// Regenerate Fig. 12 (energy map, pies, headlines, sensitivity).
pub fn run(ctx: &FigureCtx) -> Result<FigureResult> {
    let tech = TechParams::default();
    let grid_samples = ctx.samples.min(16_384);
    let mut fr = FigureResult::new("fig12");

    // ---- the energy map grid ----
    let mut points = Vec::new();
    let mut dr = 3.0;
    while dr <= 17.0 + 1e-9 {
        let mut nm = 1.0;
        while nm <= 8.0 + 1e-9 {
            points.push(SpecPoint { dr_bits: dr, n_m_eff: nm });
            nm += 0.5;
        }
        dr += 1.0;
    }
    let results = evaluate_points(ctx, &points, grid_samples, &tech)?;

    let mut grid = Table::new(
        "energy map",
        &[
            "dr_db", "sqnr_db", "enob_conv", "e_conv_fj", "gr_granularity",
            "enob_gr", "e_gr_fj", "needs_global_norm",
        ],
    );
    for r in results.iter().flatten() {
        let (gran, enob_gr, e_gr) = match &r.gr_best {
            Some((a, e, b)) => {
                (a.name().to_string(), Table::f(*e), Table::f(b.total()))
            }
            None => ("-".into(), "-".into(), "-".into()),
        };
        grid.row(vec![
            Table::f(r.spec.dr_db()),
            Table::f(r.spec.sqnr_db()),
            Table::f(r.enob_conv),
            Table::f(r.e_conv.total()),
            gran,
            enob_gr,
            e_gr,
            if r.gr_best.is_none() { "yes" } else { "no" }.into(),
        ]);
    }
    fr.tables.push(grid);

    // ---- scaling-direction check: conventional DR-dominated, GR SQNR-
    // dominated (compare energy gradients along each axis) ----
    let lookup = |dr: f64, nm: f64| -> Option<&PointResult> {
        results.iter().flatten().find(|r| {
            (r.spec.dr_bits - dr).abs() < 1e-6
                && (r.spec.n_m_eff - nm).abs() < 1e-6
        })
    };
    if let (Some(a), Some(b), Some(c)) =
        (lookup(8.0, 3.0), lookup(10.0, 3.0), lookup(8.0, 5.0))
    {
        let conv_ddr = b.e_conv.total() / a.e_conv.total();
        let conv_dsq = c.e_conv.total() / a.e_conv.total();
        let gr_ddr = match (b.gr_total(), a.gr_total()) {
            (Some(x), Some(y)) => x / y,
            _ => f64::NAN,
        };
        let gr_dsq = match (c.gr_total(), a.gr_total()) {
            (Some(x), Some(y)) => x / y,
            _ => f64::NAN,
        };
        fr.check(
            "conventional scaling is DR-dominated",
            "+2 DR bits costs more than +2 SQNR bits",
            format!("conv: x{conv_ddr:.2} per +2DRb vs x{conv_dsq:.2} per +2SQNRb"),
            conv_ddr > conv_dsq,
        );
        fr.check(
            "GR scaling is SQNR-dominated",
            "+2 SQNR bits costs more than +2 DR bits",
            format!("gr: x{gr_ddr:.2} per +2DRb vs x{gr_dsq:.2} per +2SQNRb"),
            gr_dsq > gr_ddr,
        );
    }

    // ---- format pies ----
    let mut pies = Table::new(
        "pies",
        &["format", "arch", "enob", "component", "fj_per_op", "pct"],
    );
    let fp4 = SpecPoint::from_format(FpFormat::fp4_e2m1());
    let fp6 = SpecPoint::from_format(FpFormat::fp6_e3m2());
    // FP8_E4M3 exceeds the native range: global normalization clamps the
    // per-segment range to the gain stage's capability; only CIM-array
    // energy is included (paper caption).
    let fp8_native = FpFormat {
        e_max: GAIN_RANGE_BITS + 1.0,
        n_m: FpFormat::fp8_e4m3().n_m,
    };
    let fp8 = SpecPoint::from_format(fp8_native);
    let pie_pts =
        evaluate_points(ctx, &[fp4, fp6, fp8], ctx.samples, &tech)?;

    let labels = ["FP4_E2M1", "FP6_E3M2", "FP8*_E4M3(global-norm)"];
    let mut fp4_conv_total = f64::NAN;
    let mut fp4_gr_total = f64::NAN;
    let mut fp6_gr_total = f64::NAN;
    let mut fp6_conv_total = f64::NAN;
    for (i, rp) in pie_pts.iter().enumerate() {
        let Some(r) = rp else { continue };
        pie_rows(&mut pies, labels[i], "conventional", r.enob_conv, &r.e_conv);
        if let Some((arch, enob, b)) = &r.gr_best {
            pie_rows(&mut pies, labels[i], arch.name(), *enob, b);
            if i == 0 {
                fp4_gr_total = b.total();
            }
            if i == 1 {
                fp6_gr_total = b.total();
            }
        }
        if i == 0 {
            fp4_conv_total = r.e_conv.total();
        }
        if i == 1 {
            fp6_conv_total = r.e_conv.total();
        }
    }
    fr.tables.push(pies);

    let fp4_gain = 1.0 - fp4_gr_total / fp4_conv_total;
    fr.check(
        "FP4_E2M1: gain-ranging improves energy/op",
        "23%",
        format!(
            "{:.0}% ({:.1} -> {:.1} fJ/Op)",
            100.0 * fp4_gain,
            fp4_conv_total,
            fp4_gr_total
        ),
        (0.10..0.45).contains(&fp4_gain),
    );
    fr.check(
        "FP6_E3M2 native on GR-CIM at low energy",
        "29 fJ/Op",
        format!("{fp6_gr_total:.1} fJ/Op"),
        (15.0..60.0).contains(&fp6_gr_total),
    );
    fr.check(
        "FP6_E3M2 impractical on conventional CIM",
        "> 100 fJ/Op (outside practical range)",
        format!("{fp6_conv_total:.1} fJ/Op"),
        fp6_conv_total > ENERGY_CAP_FJ,
    );

    // ---- iso-SQNR headlines ----
    //
    // The paper anchors these at absolute energies (30 fJ / 100 fJ). Our
    // spec includes the full sqrt(NR) accumulation excess in the
    // conventional ENOB, which shifts its absolute energy up; the
    // transferable *shape* is the iso-energy DR extension, so each
    // headline is measured at the conventional architecture's own minimum
    // achievable energy for that SQNR (its INT-line point), and at the
    // paper's 100 fJ practical cap.
    let headline = |sqnr_db: f64| -> Result<Vec<Option<PointResult>>> {
        let n_m_eff = (sqnr_db - 10.79) / 6.02;
        let mut pts = Vec::new();
        let mut drb = n_m_eff + 2.0;
        while drb <= 20.0 {
            pts.push(SpecPoint { dr_bits: drb, n_m_eff });
            drb += 0.5;
        }
        evaluate_points(ctx, &pts, grid_samples, &tech)
    };

    let rows35 = headline(35.0)?;
    let conv_min35 = rows35
        .iter()
        .flatten()
        .map(|r| r.e_conv.total())
        .fold(f64::INFINITY, f64::min);
    let (conv35, gr35) = max_dr_under_cap(&rows35, conv_min35 * 1.05);
    let gain35 = match (conv35, gr35) {
        (Some(c), Some(g)) => g - c,
        _ => f64::NAN,
    };
    fr.check(
        "at 35 dB SQNR and iso-energy, GR extends input DR",
        "+4 bits (at 30 fJ/Op)",
        format!(
            "+{gain35:.1} bits at {:.0} fJ/Op (conv {:.1} -> gr {:.1} DR bits)",
            conv_min35,
            conv35.unwrap_or(f64::NAN),
            gr35.unwrap_or(f64::NAN)
        ),
        (2.0..9.0).contains(&gain35),
    );

    let rows47 = headline(47.0)?;
    let (conv47, gr47) = max_dr_under_cap(&rows47, ENERGY_CAP_FJ);
    let conv_min47 = rows47
        .iter()
        .flatten()
        .map(|r| r.e_conv.total())
        .fold(f64::INFINITY, f64::min);
    let gr47_dr = gr47.unwrap_or(f64::NAN);
    fr.check(
        "at the 100 fJ/Op limit and 47 dB SQNR, GR extends the DR envelope",
        "+6 bits over the fixed-point baseline",
        format!(
            "gr reaches {:.1} DR bits within 100 fJ; conventional needs \
             {:.0} fJ for its minimum-DR point ({})",
            gr47_dr,
            conv_min47,
            match conv47 {
                Some(c) => format!("reaches {c:.1} bits"),
                None => "cannot reach 47 dB at any DR".into(),
            }
        ),
        gr47.is_some()
            && (conv47.is_none()
                || gr47_dr - conv47.unwrap_or(f64::NAN) >= 3.0),
    );

    // ---- ADC parameter sensitivity at FP4 ----
    let mut sens = Table::new(
        "adc sensitivity",
        &["k_scale", "e_conv_fj", "e_gr_fj", "gr_improvement_pct"],
    );
    let mut sens_vals = Vec::new();
    for scale in [0.9, 1.0, 1.1] {
        let t = TechParams::default().with_adc_scale(scale);
        let r = evaluate_points(ctx, &[fp4], grid_samples, &t)?;
        let r = r[0].as_ref().unwrap();
        let gr = r.gr_total().unwrap();
        let imp = 100.0 * (1.0 - gr / r.e_conv.total());
        sens.row(vec![
            Table::f(scale),
            Table::f(r.e_conv.total()),
            Table::f(gr),
            Table::f(imp),
        ]);
        sens_vals.push(imp);
    }
    fr.tables.push(sens);
    fr.check(
        "GR advantage robust to ±10% ADC parameters",
        "21% / 23% / 25%",
        format!(
            "{:.0}% / {:.0}% / {:.0}%",
            sens_vals[0], sens_vals[1], sens_vals[2]
        ),
        (sens_vals[2] - sens_vals[0]).abs() < 10.0
            && sens_vals.iter().all(|v| (5.0..50.0).contains(v)),
    );

    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_point_conversions() {
        let p = SpecPoint::from_format(FpFormat::fp4_e2m1());
        assert!((p.dr_bits - 5.0).abs() < 1e-12);
        assert!((p.n_m_eff - 2.0).abs() < 1e-12);
        let fp = p.fp_format().unwrap();
        assert!((fp.e_max - 3.0).abs() < 1e-9);
        // left of INT line
        assert!(SpecPoint { dr_bits: 2.0, n_m_eff: 4.0 }.fp_format().is_none());
    }

    #[test]
    fn native_limits_match_paper_formats() {
        let w = weight_fmt();
        // FP4 input: unit-normalizable
        assert!(native_ok(CimArch::GrUnit, FpFormat::fp4_e2m1(), w));
        // FP6_E3M2: row fits exactly at the 6-bit limit, unit does not
        assert!(native_ok(CimArch::GrRow, FpFormat::fp6_e3m2(), w));
        assert!(!native_ok(CimArch::GrUnit, FpFormat::fp6_e3m2(), w));
        // FP8_E4M3 needs global normalization on either granularity
        assert!(!native_ok(CimArch::GrRow, FpFormat::fp8_e4m3(), w));
    }

    #[test]
    fn evaluate_single_point() {
        let ctx = FigureCtx::default().quick();
        let p = SpecPoint::from_format(FpFormat::fp4_e2m1());
        let r = evaluate_points(&ctx, &[p], 4096, &TechParams::default())
            .unwrap();
        let r = r[0].as_ref().unwrap();
        assert!(r.enob_conv > 2.0 && r.enob_conv < 14.0);
        let (_, enob_gr, _) = r.gr_best.as_ref().unwrap();
        assert!(*enob_gr < r.enob_conv);
        assert!(r.gr_total().unwrap() < r.e_conv.total());
    }
}
