//! Ablations on DESIGN.md's called-out choices (not paper figures, but
//! claims made in the paper's prose):
//!
//! * **granularity** — unit vs row energy crossover as input precision
//!   grows (Sec. III-C1: "the efficiency crossover point is identified at
//!   N_M,x >= 6 in 28 nm"): unit's extra logic pays off only once the
//!   baseline ADC resolution is high.
//! * **array depth** — N_eff and the GR ENOB advantage vs NR (the
//!   shrinkage term the GR-MAC attacks grows with column depth).
//! * **margin** — sensitivity of the ADC spec to the 6 dB safety margin.

use super::FigureCtx;
use crate::coordinator::{run_campaign, ExperimentSpec};
use crate::distributions::Distribution;
use crate::energy::{energy_per_op, CimArch, TechParams};
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::report::{FigureResult, Table};
use crate::spec::{required_enob, Arch, SpecConfig};
use anyhow::Result;

/// Run the three ablations (granularity crossover, array depth, margin).
pub fn run(ctx: &FigureCtx) -> Result<FigureResult> {
    let mut fr = FigureResult::new("ablations");
    let tech = TechParams::default();
    let w_fmt = FpFormat::fp4_e2m1();
    let w_dist = Distribution::max_entropy(w_fmt);
    let samples = ctx.samples.min(16_384);

    // ---- granularity crossover vs input mantissa bits ----
    let mut specs = Vec::new();
    let n_ms: Vec<u32> = (1..=8).collect();
    for &n_m in &n_ms {
        let fmt = FpFormat::fp(2, n_m); // small exponent so unit is native
        specs.push(ExperimentSpec {
            id: format!("gran-m{n_m}"),
            fmts: FormatPair::new(fmt, w_fmt),
            dist_x: Distribution::Uniform,
            dist_w: w_dist.clone(),
            nr: 32,
            samples,
            sampler: Default::default(),
        });
    }
    let aggs = run_campaign(&specs, &ctx.campaign)?;
    let cfg = SpecConfig::default();
    let mut gran = Table::new(
        "granularity crossover",
        &["n_m_x", "enob_unit", "e_unit_fj", "enob_row", "e_row_fj", "winner"],
    );
    let mut crossover: Option<u32> = None;
    let mut prev_winner_row = true;
    for (i, &n_m) in n_ms.iter().enumerate() {
        let fmt = FpFormat::fp(2, n_m);
        let fmts = FormatPair::new(fmt, w_fmt);
        let e_unit = required_enob(&aggs[i], Arch::GrUnit, cfg).enob;
        let e_row = required_enob(&aggs[i], Arch::GrRow, cfg).enob;
        let en_unit =
            energy_per_op(CimArch::GrUnit, fmts, 32, 32, e_unit, &tech).total();
        let en_row =
            energy_per_op(CimArch::GrRow, fmts, 32, 32, e_row, &tech).total();
        let unit_wins = en_unit < en_row;
        if unit_wins && prev_winner_row && crossover.is_none() {
            crossover = Some(n_m);
        }
        prev_winner_row = !unit_wins;
        gran.row(vec![
            n_m.to_string(),
            Table::f(e_unit),
            Table::f(en_unit),
            Table::f(e_row),
            Table::f(en_row),
            if unit_wins { "unit" } else { "row" }.into(),
        ]);
    }
    fr.tables.push(gran);
    fr.check(
        "unit normalization wins only at high input precision",
        "crossover at N_M,x >= 6 (28 nm)",
        match crossover {
            Some(m) => format!("unit wins from N_M,x = {m}"),
            None => "row wins everywhere in 1..=8".to_string(),
        },
        crossover.map(|m| m >= 4).unwrap_or(true),
    );

    // ---- N_eff / advantage vs array depth ----
    let depths = [16usize, 32, 64, 128];
    let mut specs = Vec::new();
    for &nr in &depths {
        specs.push(ExperimentSpec {
            id: format!("nr{nr}"),
            fmts: FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp6_e2m3()),
            dist_x: Distribution::clipped_gauss4(),
            dist_w: Distribution::clipped_gauss4(),
            nr,
            samples,
            sampler: Default::default(),
        });
    }
    let aggs = run_campaign(&specs, &ctx.campaign)?;
    let mut deep = Table::new(
        "array depth",
        &["nr", "mean_n_eff", "n_eff_over_nr", "enob_conv", "enob_gr", "delta"],
    );
    let mut deltas = Vec::new();
    for (i, &nr) in depths.iter().enumerate() {
        let conv = required_enob(&aggs[i], Arch::Conventional, cfg).enob;
        let gr = required_enob(&aggs[i], Arch::GrUnit, cfg).enob;
        deltas.push(conv - gr);
        deep.row(vec![
            nr.to_string(),
            Table::f(aggs[i].mean_n_eff()),
            Table::f(aggs[i].mean_n_eff() / nr as f64),
            Table::f(conv),
            Table::f(gr),
            Table::f(conv - gr),
        ]);
    }
    fr.tables.push(deep);
    fr.check(
        "GR advantage persists across array depths",
        "N_eff << NR at every depth",
        format!("delta ENOB = {deltas:?}"),
        deltas.iter().all(|&d| d > 0.8),
    );

    // ---- margin sensitivity ----
    let spec = ExperimentSpec {
        id: "margin".into(),
        fmts: FormatPair::new(FpFormat::fp6_e3m2(), w_fmt),
        dist_x: Distribution::Uniform,
        dist_w: w_dist.clone(),
        nr: 32,
        samples,
        sampler: Default::default(),
    };
    let aggs = run_campaign(&[spec], &ctx.campaign)?;
    let mut marg =
        Table::new("margin sensitivity", &["margin_db", "enob_conv", "enob_gr"]);
    let mut margin_effect = Vec::new();
    for margin_db in [3.0, 6.0, 9.0, 12.0] {
        let c = SpecConfig { margin_db, empirical_floor: false };
        let conv = required_enob(&aggs[0], Arch::Conventional, c).enob;
        let gr = required_enob(&aggs[0], Arch::GrUnit, c).enob;
        margin_effect.push(conv);
        marg.row(vec![Table::f(margin_db), Table::f(conv), Table::f(gr)]);
    }
    fr.tables.push(marg);
    let per3db = (margin_effect[3] - margin_effect[0]) / 3.0;
    fr.check(
        "ADC spec shifts 0.5 bit per 3 dB of margin (both archs equally)",
        "log2(sqrt(2)) per 3 dB",
        format!("{per3db:.3} bits per 3 dB"),
        (per3db - 0.498).abs() < 0.01,
    );

    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_hold() {
        let ctx = FigureCtx::default().quick();
        let fr = run(&ctx).unwrap();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
    }
}
