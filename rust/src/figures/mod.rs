//! Figure/table regeneration harness — one module per artifact of the
//! paper's evaluation (DESIGN.md §4 experiment index).
//!
//! Every generator returns a [`FigureResult`]: the series/rows the paper
//! plots (persisted as CSV under the output directory) plus explicit
//! paper-vs-measured checks. `grcim figures --fig <id>` drives these;
//! EXPERIMENTS.md records the outcomes.

pub mod ablations;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig4;
pub mod fig8;
pub mod fig9;
pub mod table1;

use crate::coordinator::CampaignConfig;
use crate::report::FigureResult;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Shared settings for figure regeneration.
#[derive(Debug, Clone)]
pub struct FigureCtx {
    /// Campaign settings (engine, workers, seed) for MC-heavy figures.
    pub campaign: CampaignConfig,
    /// Monte-Carlo samples per experiment point.
    pub samples: usize,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
}

impl Default for FigureCtx {
    fn default() -> Self {
        FigureCtx {
            campaign: CampaignConfig::default(),
            samples: 65_536,
            out_dir: PathBuf::from("results"),
        }
    }
}

impl FigureCtx {
    /// Reduced sample count for smoke runs (`--quick`).
    pub fn quick(mut self) -> Self {
        self.samples = 8_192;
        self
    }
}

/// All known figure ids, in paper order.
pub const ALL: &[&str] = &[
    "fig4", "table1", "fig8", "fig9", "fig10", "fig11", "fig12", "ablations",
];

/// Run one figure by id.
pub fn run(id: &str, ctx: &FigureCtx) -> Result<FigureResult> {
    match id {
        "fig4" => fig4::run(ctx),
        "table1" => table1::run(ctx),
        "fig8" => fig8::run(ctx),
        "fig9" => fig9::run(ctx),
        "fig10" => fig10::run(ctx),
        "fig11" => fig11::run(ctx),
        "fig12" => fig12::run(ctx),
        "ablations" => ablations::run(ctx),
        _ => bail!("unknown figure '{id}' (known: {})", ALL.join(", ")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_figure_rejected() {
        let err = run("fig99", &FigureCtx::default()).unwrap_err().to_string();
        assert!(err.contains("unknown figure"));
    }

    #[test]
    fn quick_reduces_samples() {
        let ctx = FigureCtx::default().quick();
        assert!(ctx.samples < FigureCtx::default().samples);
    }
}
