//! Fig. 8 — GR-MAC cell linearity: (a) W-sweep staircases with DNL/INL,
//! nominal and under capacitor-mismatch Monte Carlo at both K_C bounds
//! (n = 1000); (b) E-sweep exponential response with relative error
//! normalized to the W-input LSB.

use super::FigureCtx;
use crate::analog::{
    dnl_inl,
    mismatch::{e_sweep_error_lsb, mc_dnl_inl, w_sweep},
    GrMacCell, MismatchModel,
};
use crate::report::{FigureResult, Table};
use crate::rng::Pcg64;
use anyhow::Result;

/// Mismatch Monte-Carlo instances per K_C bound (paper: n = 1000).
pub const MC_RUNS: usize = 1000;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

/// Regenerate Fig. 8 (cell linearity, nominal + under mismatch).
pub fn run(ctx: &FigureCtx) -> Result<FigureResult> {
    let cell = GrMacCell::fp6_e2m3_schematic();
    let mut fr = FigureResult::new("fig8");

    // (a) nominal staircases + DNL/INL per level
    let mut stair = Table::new(
        "w sweep",
        &["level", "w_code", "charge_fF_V", "dnl_lsb", "inl_lsb"],
    );
    for level in 1..=cell.levels() {
        let vals = w_sweep(&cell, level, 1.0);
        let s = dnl_inl(&vals);
        for (w, &v) in vals.iter().enumerate() {
            let d = if w > 0 { s.dnl[w - 1] } else { 0.0 };
            stair.row(vec![
                level.to_string(),
                w.to_string(),
                Table::f(v),
                Table::f(d),
                Table::f(s.inl[w]),
            ]);
        }
    }
    fr.tables.push(stair);

    // mismatch MC at both K_C bounds
    let mut mc = Table::new(
        "mismatch mc",
        &["k_c", "runs", "p50_dnl", "p99.7_dnl", "p50_inl", "p99.7_inl"],
    );
    let mut all_within_half_lsb = true;
    for model in [MismatchModel::low(), MismatchModel::high()] {
        let runs = mc_dnl_inl(&cell, model, MC_RUNS, ctx.campaign.seed ^ 0xF18);
        let mut dnl: Vec<f64> = runs.iter().map(|r| r.0).collect();
        let mut inl: Vec<f64> = runs.iter().map(|r| r.1).collect();
        dnl.sort_by(|a, b| a.partial_cmp(b).unwrap());
        inl.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p997_dnl = percentile(&dnl, 0.997);
        let p997_inl = percentile(&inl, 0.997);
        all_within_half_lsb &= p997_dnl < 0.5 && p997_inl < 0.5;
        mc.row(vec![
            format!("{}%sqrt(fF)", model.k_c_pct_sqrt_ff),
            MC_RUNS.to_string(),
            Table::f(percentile(&dnl, 0.5)),
            Table::f(p997_dnl),
            Table::f(percentile(&inl, 0.5)),
            Table::f(p997_inl),
        ]);
    }
    fr.tables.push(mc);

    // (b) E-sweep: exponential response + mismatch error percentiles
    let mut esweep = Table::new(
        "e sweep",
        &["level", "charge_nominal", "ratio_to_prev", "p99.7_err_lsb"],
    );
    let mut rng = Pcg64::seeded(ctx.campaign.seed ^ 0xE5);
    let model = MismatchModel::high();
    let mut prev = f64::NAN;
    let mut max_ratio_err = 0.0f64;
    for level in 1..=cell.levels() {
        let q = cell.transfer_closed_form(15, level, 1.0);
        let ratio = q / prev;
        if level > 1 {
            max_ratio_err = max_ratio_err.max((ratio - 2.0).abs());
        }
        // error at this level across mismatch instances
        let mut errs: Vec<f64> = (0..MC_RUNS)
            .map(|_| {
                let inst = model.instance(&cell, &mut rng);
                e_sweep_error_lsb(&inst, &cell, 15, 1.0)[level - 1].abs()
            })
            .collect();
        errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        esweep.row(vec![
            level.to_string(),
            Table::f(q),
            if level > 1 { Table::f(ratio) } else { "-".into() },
            Table::f(percentile(&errs, 0.997)),
        ]);
        prev = q;
    }
    fr.tables.push(esweep);

    // nominal linearity
    let worst_nominal = (1..=cell.levels())
        .map(|l| dnl_inl(&w_sweep(&cell, l, 1.0)).max_abs_inl())
        .fold(0.0f64, f64::max);

    fr.check(
        "nominal DNL/INL negligible",
        "within bounds under nominal conditions",
        format!("max |INL| = {worst_nominal:.2e} LSB"),
        worst_nominal < 1e-6,
    );
    fr.check(
        "3-sigma mismatch within 1/2 LSB at both K_C bounds",
        "within 1/2 LSB",
        format!("p99.7 of max|DNL|,|INL| < 0.5 at K_C in {{0.45, 0.85}}"),
        all_within_half_lsb,
    );
    fr.check(
        "E-sweep response is exponential (x2 per level)",
        "exponential",
        format!("max octave-ratio error {max_ratio_err:.2e}"),
        max_ratio_err < 1e-9,
    );
    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_reproduces_paper_shape() {
        let fr = run(&FigureCtx::default()).unwrap();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
        // 4 levels x 16 codes
        assert_eq!(fr.tables[0].rows.len(), 64);
    }
}
