//! Fig. 4 — signal-shrinkage vs signal-preservation distributions.
//!
//! Monte-Carlo histograms of the six panels (A1..A3 conventional, B1..B3
//! GR), plus the annotated quantities: N_eff, the output signal-power gain
//! (paper: ~20x), and the resulting ΔENOB (paper: 2.2 bits). Setup per the
//! paper's caption: FP6_E2M3 inputs and weights, clipped-4σ Gaussian data,
//! NR = 32.

use super::FigureCtx;
use crate::distributions::Distribution;
use crate::formats::FpFormat;
use crate::mac::{trace::trace_column, FormatPair};
use crate::report::{FigureResult, Table};
use crate::rng::Pcg64;
use crate::spec::{delta_enob, SpecConfig};
use crate::stats::{ColumnAgg, Histogram};
use crate::util::variance;
use anyhow::Result;

/// Array depth of the Fig. 4 setup (paper: NR = 32).
pub const NR: usize = 32;

/// Regenerate Fig. 4 (the six distribution panels + annotations).
pub fn run(ctx: &FigureCtx) -> Result<FigureResult> {
    let fmts = FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp6_e2m3());
    let dist = Distribution::clipped_gauss4();
    let samples = ctx.samples.max(4096);

    // trace path (pure Rust — the artifact reduces per-cell data away)
    let mut rng = Pcg64::seeded(ctx.campaign.seed ^ 0xF16_4);
    let mut x = vec![0.0f64; samples * NR];
    let mut w = vec![0.0f64; samples * NR];
    dist.fill(&mut rng, &mut x);
    dist.fill(&mut rng, &mut w);
    let t = trace_column(&x, &w, NR, fmts);

    // statistics path for ΔENOB (same engine family as figs 10/11)
    let batch = crate::mac::simulate_column(&x, &w, NR, fmts);
    let mut agg = ColumnAgg::new(NR);
    agg.push_batch(&batch);

    let mut fr = FigureResult::new("fig4");

    // six histogram panels
    let bins = 61;
    let panels: [(&str, &[f64]); 6] = [
        ("A1_x_int", &t.a1_x_int),
        ("A2_products", &t.a2_products),
        ("A3_v_conv", &t.a3_v_conv),
        ("B1_mantissa", &t.b1_mantissa),
        ("B2_products", &t.b2_products),
        ("B3_v_gr", &t.b3_v_gr),
    ];
    let mut table = Table::new(
        "distributions",
        &["panel", "bin_center", "density"],
    );
    for (name, data) in panels {
        let mut h = Histogram::new(-1.0, 1.0, bins);
        h.push_slice(data);
        for (c, d) in h.centers().into_iter().zip(h.density()) {
            table.row(vec![name.into(), Table::f(c), Table::f(d)]);
        }
    }
    fr.tables.push(table);

    // annotations
    let mean_neff = agg.mean_n_eff();
    let power_gain = variance(&t.b3_v_gr) / variance(&t.a3_v_conv);
    let denob = delta_enob(&agg, SpecConfig::default());

    let mut ann = Table::new("annotations", &["quantity", "value"]);
    ann.row(vec!["N_R".into(), NR.to_string()]);
    ann.row(vec!["mean N_eff".into(), Table::f(mean_neff)]);
    ann.row(vec!["output power gain (x)".into(), Table::f(power_gain)]);
    ann.row(vec!["delta ENOB (bits)".into(), Table::f(denob)]);
    fr.tables.push(ann);

    fr.check(
        "N_eff well below N_R under exponent weighting",
        "14.6 @ NR=32",
        format!("{mean_neff:.1}"),
        mean_neff > 8.0 && mean_neff < 27.0,
    );
    fr.check(
        "GR output signal power gain",
        "~20x",
        format!("{power_gain:.1}x"),
        power_gain > 8.0 && power_gain < 50.0,
    );
    fr.check(
        "ADC excess-resolution reduction",
        "2.2 bits",
        format!("{denob:.2} bits"),
        denob > 1.0 && denob < 4.0,
    );
    fr.check(
        "GR products wider than aligned products (B2 vs A2)",
        "wider",
        format!(
            "var ratio {:.1}",
            variance(&t.b2_products) / variance(&t.a2_products)
        ),
        variance(&t.b2_products) > 2.0 * variance(&t.a2_products),
    );
    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_reproduces_paper_shape() {
        let ctx = FigureCtx::default().quick();
        let fr = run(&ctx).unwrap();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
        // 6 panels x 61 bins
        assert_eq!(fr.tables[0].rows.len(), 6 * 61);
    }
}
