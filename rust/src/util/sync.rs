//! The repo's single concurrency surface: a thin shim over the `std`
//! primitives that swaps in [loom](https://docs.rs/loom) equivalents
//! under `--cfg loom`, so the load-bearing protocols (single-flight
//! cache, worker pool, compute queue, checkpoint appends) can be
//! exhaustively model-checked by `rust/tests/loom_models.rs` while
//! production builds compile to exactly the `std` types.
//!
//! **Every module outside `util::sync` must import its sync primitives
//! from here, never from `std::sync` directly** — enforced by
//! `grcim-lint` rule `S`. (The one exception: const-initialized statics,
//! like the logger's level atomic in `util`, cannot use loom atomics —
//! those carry an allowlist entry.)
//!
//! Beyond the re-exports, this module owns the shared poisoning policy:
//! [`lock_recover`] and [`cv_wait`] treat a poisoned lock as recoverable
//! (every protected structure in this repo stays valid across an
//! interrupted critical section — counters, queues, append-only files),
//! so one panicking worker can never wedge the metrics path, the
//! rendered-response caches, or the checkpoint writer.
//!
//! It also hosts the two queue primitives the serve core and the worker
//! pool are built on — [`BoundedQueue`] (admission control) and the
//! unbounded [`channel`] (pool results) — precisely so the loom suite
//! can model them without reaching into `pub(super)` server internals.

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(loom)]
pub use loom::thread::JoinHandle;

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::thread::JoinHandle;

// loom has no Barrier model; the one in-tree user (loadgen's
// connection-open rendezvous) is never exercised under loom, so the std
// type is re-exported in both worlds to keep the crate compiling.
pub use std::sync::Barrier;

use std::collections::VecDeque;
use std::time::Duration;

/// Lock a mutex, recovering from poisoning.
///
/// Everything this repo guards with a mutex remains structurally valid
/// after a panic mid-critical-section (queues of whole items, counters,
/// append handles that write whole lines), so the poison flag carries no
/// information worth propagating — recovering keeps one panicking
/// thread from wedging every later locker (the pool regression that
/// motivated this helper, now applied uniformly).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Wait on a condvar, recovering from poisoning (same policy as
/// [`lock_recover`]).
pub fn cv_wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Wait on a condvar with a timeout, recovering from poisoning. Returns
/// the reacquired guard and whether the wait timed out.
#[cfg(not(loom))]
pub fn cv_wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(poisoned) => {
            let (g, t) = poisoned.into_inner();
            (g, t.timed_out())
        }
    }
}

/// Loom build: modeled as a plain wait (loom explores wakeup orders
/// exhaustively, so a timeout adds nothing; no in-tree timed wait is
/// exercised inside a loom model).
#[cfg(loom)]
pub fn cv_wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    _dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    (cv_wait(cv, guard), false)
}

/// Spawn a named thread (loom build: loom's scheduler owns the threads;
/// the name is dropped).
#[cfg(not(loom))]
pub fn spawn_named<T, F>(name: impl Into<String>, f: F) -> std::io::Result<JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    std::thread::Builder::new().name(name.into()).spawn(f)
}

/// Spawn a named thread (loom build: loom's scheduler owns the threads;
/// the name is dropped).
#[cfg(loom)]
pub fn spawn_named<T, F>(name: impl Into<String>, f: F) -> std::io::Result<JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let _ = name.into();
    Ok(loom::thread::spawn(f))
}

/// Describe a caught panic payload (panics carry `&str` or `String`
/// messages in practice; anything else is reported opaquely). Shared by
/// every `catch_unwind` recovery site: the pool, the reactor's mux
/// wrapper, and loadgen's driver join.
pub fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct ChanState<T> {
    queue: VecDeque<T>,
    senders: usize,
    rx_alive: bool,
}

struct ChanShared<T> {
    state: Mutex<ChanState<T>>,
    cv: Condvar,
}

/// Sending half of an unbounded MPSC [`channel`].
pub struct Sender<T>(Arc<ChanShared<T>>);

/// Receiving half of an unbounded MPSC [`channel`].
pub struct Receiver<T>(Arc<ChanShared<T>>);

/// An unbounded multi-producer single-consumer channel over the shim's
/// own `Mutex`/`Condvar` (rather than `std::sync::mpsc`, whose
/// internals loom cannot model). [`Receiver::recv`] returns `None` once
/// every sender is dropped and the queue is drained — the property the
/// pool's result loop terminates on.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(ChanShared {
        state: Mutex::new(ChanState { queue: VecDeque::new(), senders: 1, rx_alive: true }),
        cv: Condvar::new(),
    });
    (Sender(Arc::clone(&shared)), Receiver(shared))
}

impl<T> Sender<T> {
    /// Enqueue one value; `false` when the receiver is gone (the value
    /// is dropped, matching `std::sync::mpsc`'s send-error contract).
    pub fn send(&self, value: T) -> bool {
        let mut st = lock_recover(&self.0.state);
        if !st.rx_alive {
            return false;
        }
        st.queue.push_back(value);
        self.0.cv.notify_one();
        true
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        lock_recover(&self.0.state).senders += 1;
        Sender(Arc::clone(&self.0))
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = lock_recover(&self.0.state);
        st.senders -= 1;
        if st.senders == 0 {
            // wake a receiver blocked on an empty queue so it can see
            // "no senders left" and return None
            self.0.cv.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Next value, blocking while senders exist and the queue is empty;
    /// `None` once every sender is dropped and the queue is drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = lock_recover(&self.0.state);
        loop {
            if let Some(v) = st.queue.pop_front() {
                return Some(v);
            }
            if st.senders == 0 {
                return None;
            }
            st = cv_wait(&self.0.cv, st);
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        // senders never block, so no wakeup is needed — just let later
        // sends fail fast instead of accumulating unread values
        lock_recover(&self.0.state).rx_alive = false;
    }
}

struct BoundedState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded MPMC queue whose full state is an immediate, non-blocking
/// rejection — the admission-control shape: the serve core's
/// `ComputeQueue` is this queue carrying compute jobs, and a `false`
/// from [`BoundedQueue::try_push`] is the wire `busy` error.
///
/// Closing is graceful: [`BoundedQueue::pop`] keeps draining admitted
/// items after [`BoundedQueue::close`] and only then reports `None`, so
/// shutdown finishes every job it accepted.
pub struct BoundedQueue<T> {
    state: Mutex<BoundedState<T>>,
    cv: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    /// An open queue admitting at most `cap` items at a time.
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            state: Mutex::new(BoundedState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            cap,
        }
    }

    /// Admit one item; `false` when the queue is full or closed (the
    /// caller rejects instead of queueing unboundedly).
    pub fn try_push(&self, item: T) -> bool {
        let mut st = lock_recover(&self.state);
        if st.closed || st.items.len() >= self.cap {
            return false;
        }
        st.items.push_back(item);
        self.cv.notify_one();
        true
    }

    /// Next item, blocking while the queue is open and empty. `None`
    /// once the queue is closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = lock_recover(&self.state);
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = cv_wait(&self.cv, st);
        }
    }

    /// Stop admissions and wake every blocked popper (they drain what
    /// was admitted, then see `None`).
    pub fn close(&self) {
        lock_recover(&self.state).closed = true;
        self.cv.notify_all();
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn channel_delivers_then_ends_on_sender_drop() {
        let (tx, rx) = channel::<u32>();
        let tx2 = tx.clone();
        assert!(tx.send(1));
        assert!(tx2.send(2));
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn channel_send_fails_after_receiver_drop() {
        let (tx, rx) = channel::<u32>();
        drop(rx);
        assert!(!tx.send(7));
    }

    #[test]
    fn channel_blocked_receiver_wakes_on_last_sender_drop() {
        let (tx, rx) = channel::<u32>();
        let h = std::thread::spawn(move || rx.recv());
        std::thread::sleep(Duration::from_millis(20));
        drop(tx);
        assert_eq!(h.join().expect("receiver thread"), None);
    }

    #[test]
    fn bounded_queue_rejects_at_cap_and_drains_on_close() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1));
        assert!(q.try_push(2));
        assert!(!q.try_push(3));
        q.close();
        assert!(!q.try_push(4), "no admissions after close");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn lock_recover_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock();
            panic!("poison the lock");
        })
        .join();
        // a plain .lock().unwrap() would panic here; the recovery policy
        // keeps the (still valid) value usable
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 42);
    }

    #[test]
    fn panic_msg_formats_known_payloads() {
        let str_payload: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_msg(&*str_payload), "boom");
        let string_payload: Box<dyn std::any::Any + Send> = Box::new(String::from("kaboom"));
        assert_eq!(panic_msg(&*string_payload), "kaboom");
        let other: Box<dyn std::any::Any + Send> = Box::new(17u32);
        assert_eq!(panic_msg(&*other), "non-string panic payload");
    }
}
