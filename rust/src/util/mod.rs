//! Small shared utilities: leveled stderr logger, wall-clock timing, and
//! numeric helpers used across the crate (dB conversions, approximate
//! comparison). No external deps — the image's vendor set has no `log`
//! facade implementation.

pub mod sync;

use std::io::Write;
// lint-allow S: a const-initialized static cannot use the loom-switchable
// shim (loom atomics are not const-constructible); the logger level is
// plain telemetry never touched by a loom model
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log verbosity. Default `Info`; the CLI's `-q`/`-v` flags move it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Errors only.
    Error = 0,
    /// Warnings and errors.
    Warn = 1,
    /// Normal progress reporting (the default).
    Info = 2,
    /// Everything, including per-phase timings.
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log verbosity (`--verbose` / `--quiet`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current global log verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Write one log line to stderr if `lvl` is enabled (the macro target;
/// prefer `info!`/`warn_!`/`debug!`/`error!`).
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag}] {args}");
    }
}

/// Log at [`util::Level::Info`](crate::util::Level).
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Info, format_args!($($t)*)) };
}
/// Log at [`util::Level::Warn`](crate::util::Level) (named `warn_!` to
/// avoid colliding with the built-in `warn` lint attribute namespace).
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Warn, format_args!($($t)*)) };
}
/// Log at [`util::Level::Debug`](crate::util::Level).
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Debug, format_args!($($t)*)) };
}
/// Log at [`util::Level::Error`](crate::util::Level).
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Error, format_args!($($t)*)) };
}

/// Wall-clock scope timer; reports at Debug level on drop.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    /// Start timing a labeled scope.
    pub fn new(label: impl Into<String>) -> Self {
        Timer { label: label.into(), start: Instant::now() }
    }

    /// Seconds elapsed since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        log(
            Level::Debug,
            format_args!("{}: {:.3}s", self.label, self.elapsed_s()),
        );
    }
}

/// Power ratio -> decibels.
pub fn db(power_ratio: f64) -> f64 {
    10.0 * power_ratio.log10()
}

/// Decibels -> power ratio.
pub fn from_db(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Relative closeness for test assertions on physical quantities.
pub fn approx_eq(a: f64, b: f64, rel: f64) -> bool {
    let scale = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() <= rel * scale
}

/// Mean of a slice (0.0 for empty — callers guard).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance of a slice.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_round_trip() {
        for p in [1e-6, 0.5, 1.0, 42.0, 1e9] {
            assert!(approx_eq(from_db(db(p)), p, 1e-12));
        }
    }

    #[test]
    fn db_of_unity_is_zero() {
        assert_eq!(db(1.0), 0.0);
    }

    #[test]
    fn db_known_values() {
        assert!(approx_eq(db(10.0), 10.0, 1e-12));
        assert!(approx_eq(db(100.0), 20.0, 1e-12));
        assert!(approx_eq(db(2.0), 3.0102999566, 1e-9));
    }

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!(approx_eq(mean(&xs), 2.5, 1e-15));
        assert!(approx_eq(variance(&xs), 1.25, 1e-15));
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
    }

    #[test]
    fn approx_eq_relative_semantics() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6));
        assert!(!approx_eq(1.0, 1.1, 1e-6));
        assert!(approx_eq(0.0, 0.0, 1e-12));
    }

    #[test]
    fn timer_measures_time() {
        let t = Timer::new("x");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}
