//! Workload distribution generators (paper Sec. IV-A, Fig. 9a).
//!
//! Three distributions define the ADC hardware requirements in the paper,
//! plus the clipped Gaussian used for the Fig. 4 illustration:
//!
//! 1. **Uniform** — the conventional INT-CIM analysis baseline; lower-bounds
//!    the conventional ADC requirement and upper-bounds the GR benefit.
//! 2. **Max-entropy(format)** — uniform over the format's bit patterns; the
//!    floating-point analogue of the uniform baseline and the paper's
//!    information-optimal first-order model of empirical weights.
//! 3. **Gaussian + outliers(ε, k)** — the LLM-activation stress test: a
//!    Gaussian core (σ scaled so the largest outlier reaches full scale)
//!    with probability-ε outliers of magnitude ~k·(3σ).
//! 4. **Clipped Gaussian(c)** — N(0, (1/c)²) clipped to ±1 (c sigmas at
//!    full scale); Fig. 4 uses c = 4.
//! 5. **Empirical(trace)** — a fitted tensor trace
//!    ([`crate::workload::EmpiricalDist`]): measured workload statistics
//!    sampled by inverse-CDF lookup, so real activations drive the same
//!    Monte-Carlo paths as the parametric models.
//!
//! # Example
//!
//! ```
//! use grcim::distributions::Distribution;
//! use grcim::rng::Pcg64;
//!
//! let d = Distribution::gauss_outliers();
//! let mut rng = Pcg64::seeded(1);
//! let mut xs = vec![0.0; 10_000];
//! d.fill(&mut rng, &mut xs);
//! // every workload distribution lives on [-1, 1] …
//! assert!(xs.iter().all(|x| x.abs() <= 1.0));
//! // … and the LLM stress model has rare large outliers over a tiny core
//! let outliers = xs.iter().filter(|x| d.is_outlier(**x)).count();
//! assert!(outliers > 0 && outliers < 300, "outliers = {outliers}");
//! assert_eq!(d.name(), "gauss+outliers[eps=0.01,k=50]");
//! ```

use crate::formats::{FpFormat, MaxEntropy};
use crate::rng::Pcg64;
use crate::workload::EmpiricalDist;
use std::sync::Arc;

/// Parameters of the Gaussian+outliers stress distribution.
///
/// The paper picks ε = 0.01 and k = 50 ("consistent with empirical
/// observations regarding the sparsity and magnitude of emergent features"
/// in LLM.int8()/SmoothQuant/AWQ). We place the outlier ceiling at full
/// scale: σ = 1/(3k), outlier magnitude uniform in [0.5, 1.0]·(3kσ) =
/// [0.5, 1.0] (documented substitution — the paper only fixes the relative
/// magnitude k, not the outlier's own spread).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaussOutlierParams {
    /// Outlier probability per element (paper: 0.01).
    pub eps: f64,
    /// Outlier magnitude relative to the core's 3-sigma (paper: 50).
    pub k: f64,
}

impl Default for GaussOutlierParams {
    fn default() -> Self {
        GaussOutlierParams { eps: 0.01, k: 50.0 }
    }
}

/// A workload distribution over [-1, 1].
#[derive(Debug, Clone)]
pub enum Distribution {
    /// Uniform on [-1, 1].
    Uniform,
    /// Uniform over the bit patterns of an integral format.
    MaxEntropy(MaxEntropy),
    /// Gaussian core + rare large outliers (LLM activations).
    GaussOutliers(GaussOutlierParams),
    /// N(0, (1/c)²) clipped to [-1, 1].
    ClippedGauss {
        /// c: how many sigmas full scale sits at (Fig. 4 uses 4).
        clip_sigmas: f64,
    },
    /// Uniform on [-r, r] — the "narrowest valid bounds" dimensioning input
    /// of the Fig. 12 energy map (r = 2 · min_normal of the input format).
    UniformScaled {
        /// Half-range r (≤ 1).
        r: f64,
    },
    /// A fitted empirical tensor trace, sampled by inverse-CDF lookup
    /// (`grcim workload`; see [`crate::workload`]).
    Empirical(Arc<EmpiricalDist>),
}

impl Distribution {
    /// Max-entropy distribution of `fmt` (uniform over its bit patterns).
    pub fn max_entropy(fmt: FpFormat) -> Self {
        Distribution::MaxEntropy(MaxEntropy::new(fmt))
    }

    /// The LLM-activation stress distribution at the paper's (ε, k).
    pub fn gauss_outliers() -> Self {
        Distribution::GaussOutliers(GaussOutlierParams::default())
    }

    /// The Fig. 4 illustration distribution: N(0, (1/4)²) clipped to ±1.
    pub fn clipped_gauss4() -> Self {
        Distribution::ClippedGauss { clip_sigmas: 4.0 }
    }

    /// Wrap a fitted trace ([`crate::workload::EmpiricalDist`]) as a
    /// workload distribution.
    pub fn empirical(fit: EmpiricalDist) -> Self {
        Distribution::Empirical(Arc::new(fit))
    }

    /// Core standard deviation of the Gaussian+outliers distribution.
    pub fn core_sigma(p: GaussOutlierParams) -> f64 {
        1.0 / (3.0 * p.k)
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self {
            Distribution::Uniform => rng.uniform_in(-1.0, 1.0),
            Distribution::MaxEntropy(me) => me.sample(rng),
            Distribution::GaussOutliers(p) => {
                if rng.uniform() < p.eps {
                    rng.sign() * rng.uniform_in(0.5, 1.0)
                } else {
                    let sigma = Self::core_sigma(*p);
                    (rng.normal() * sigma).clamp(-1.0, 1.0)
                }
            }
            Distribution::ClippedGauss { clip_sigmas } => {
                (rng.normal() / clip_sigmas).clamp(-1.0, 1.0)
            }
            Distribution::UniformScaled { r } => rng.uniform_in(-r, *r),
            Distribution::Empirical(e) => e.sample(rng),
        }
    }

    /// Fill a slice.
    pub fn fill(&self, rng: &mut Pcg64, out: &mut [f64]) {
        for v in out {
            *v = self.sample(rng);
        }
    }

    /// Fill an f32 slice (the PJRT artifacts take f32 inputs).
    pub fn fill_f32(&self, rng: &mut Pcg64, out: &mut [f32]) {
        for v in out {
            *v = self.sample(rng) as f32;
        }
    }

    /// Whether a sample magnitude counts as an outlier (used for the
    /// Fig. 9 "core" subset metric). Meaningful for GaussOutliers (beyond
    /// 4 core sigma) and Empirical (beyond the fitted 4·sigma_core
    /// threshold); always false otherwise.
    pub fn is_outlier(&self, x: f64) -> bool {
        match self {
            Distribution::GaussOutliers(p) => {
                x.abs() > 4.0 * Self::core_sigma(*p)
            }
            Distribution::Empirical(e) => e.is_outlier(x),
            _ => false,
        }
    }

    /// Short stable name for reports and seeds.
    pub fn name(&self) -> String {
        match self {
            Distribution::Uniform => "uniform".into(),
            Distribution::MaxEntropy(me) => {
                format!("maxent[{}]", me.format())
            }
            Distribution::GaussOutliers(p) => {
                format!("gauss+outliers[eps={},k={}]", p.eps, p.k)
            }
            Distribution::ClippedGauss { clip_sigmas } => {
                format!("clipgauss[{clip_sigmas}s]")
            }
            Distribution::UniformScaled { r } => format!("uniform[±{r:.3e}]"),
            Distribution::Empirical(e) => {
                format!("empirical[{}@{:016x}]", e.name(), e.content_hash())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{approx_eq, mean, variance};

    fn draw(d: &Distribution, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0.0; n];
        d.fill(&mut rng, &mut v);
        v
    }

    #[test]
    fn uniform_moments_and_support() {
        let xs = draw(&Distribution::Uniform, 100_000, 1);
        assert!(xs.iter().all(|x| (-1.0..1.0).contains(x)));
        assert!(mean(&xs).abs() < 0.01);
        assert!(approx_eq(variance(&xs), 1.0 / 3.0, 0.02));
    }

    #[test]
    fn clipped_gauss_support_and_sigma() {
        let d = Distribution::clipped_gauss4();
        let xs = draw(&d, 100_000, 2);
        assert!(xs.iter().all(|x| x.abs() <= 1.0));
        assert!(approx_eq(variance(&xs).sqrt(), 0.25, 0.02));
    }

    #[test]
    fn gauss_outliers_structure() {
        let d = Distribution::gauss_outliers();
        let xs = draw(&d, 200_000, 3);
        assert!(xs.iter().all(|x| x.abs() <= 1.0));
        // outlier fraction ~ eps (outliers are >> core 4 sigma)
        let frac = xs.iter().filter(|x| d.is_outlier(**x)).count() as f64
            / xs.len() as f64;
        assert!((0.007..0.013).contains(&frac), "outlier frac {frac}");
        // core sigma = 1/150
        let core: Vec<f64> =
            xs.iter().copied().filter(|x| !d.is_outlier(*x)).collect();
        assert!(
            approx_eq(variance(&core).sqrt(), 1.0 / 150.0, 0.05),
            "core sigma {}",
            variance(&core).sqrt()
        );
        // injected outliers live in [0.5, 1]; the only exceptions are the
        // ~6e-5 Gaussian tail mass between 4 sigma and the 0.5 boundary
        let outliers: Vec<f64> = xs
            .iter()
            .copied()
            .filter(|x| d.is_outlier(*x))
            .collect();
        let in_band = outliers
            .iter()
            .filter(|x| (0.5..=1.0).contains(&x.abs()))
            .count() as f64;
        assert!(in_band / outliers.len() as f64 > 0.95);
    }

    #[test]
    fn maxentropy_samples_representable() {
        let fmt = FpFormat::fp6_e2m3();
        let d = Distribution::max_entropy(fmt);
        let xs = draw(&d, 5000, 4);
        for x in xs {
            assert_eq!(fmt.quantize(x), x);
        }
    }

    #[test]
    fn uniform_scaled_support() {
        let r = 0.01;
        let d = Distribution::UniformScaled { r };
        let xs = draw(&d, 10_000, 5);
        assert!(xs.iter().all(|x| x.abs() < r));
        assert!(approx_eq(variance(&xs), r * r / 3.0, 0.05));
    }

    #[test]
    fn deterministic_given_seed() {
        let d = Distribution::gauss_outliers();
        assert_eq!(draw(&d, 100, 42), draw(&d, 100, 42));
        assert_ne!(draw(&d, 100, 42), draw(&d, 100, 43));
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Distribution::Uniform.name(), "uniform");
        assert_eq!(
            Distribution::max_entropy(FpFormat::fp4_e2m1()).name(),
            "maxent[FP4_E2M1]"
        );
    }

    #[test]
    fn empirical_variant_samples_and_names() {
        use crate::workload::{EmpiricalDist, TensorTrace};
        let t = TensorTrace::from_f64(
            "acts",
            vec![4],
            vec![-1.0, -0.5, 0.5, 1.0],
        )
        .unwrap();
        let d = Distribution::empirical(EmpiricalDist::fit(&t).unwrap());
        let xs = draw(&d, 5000, 8);
        assert!(xs.iter().all(|x| x.abs() <= 1.0));
        // symmetric source -> near-zero mean
        assert!(mean(&xs).abs() < 0.05, "mean {}", mean(&xs));
        // deterministic given the seed
        assert_eq!(draw(&d, 64, 9), draw(&d, 64, 9));
        let n = d.name();
        assert!(n.starts_with("empirical[acts@"), "{n}");
    }
}
