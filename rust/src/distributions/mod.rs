//! Workload distribution generators (paper Sec. IV-A, Fig. 9a).
//!
//! Three distributions define the ADC hardware requirements in the paper,
//! plus the clipped Gaussian used for the Fig. 4 illustration:
//!
//! 1. **Uniform** — the conventional INT-CIM analysis baseline; lower-bounds
//!    the conventional ADC requirement and upper-bounds the GR benefit.
//! 2. **Max-entropy(format)** — uniform over the format's bit patterns; the
//!    floating-point analogue of the uniform baseline and the paper's
//!    information-optimal first-order model of empirical weights.
//! 3. **Gaussian + outliers(ε, k)** — the LLM-activation stress test: a
//!    Gaussian core (σ scaled so the largest outlier reaches full scale)
//!    with probability-ε outliers of magnitude ~k·(3σ).
//! 4. **Clipped Gaussian(c)** — N(0, (1/c)²) clipped to ±1 (c sigmas at
//!    full scale); Fig. 4 uses c = 4.
//! 5. **Empirical(trace)** — a fitted tensor trace
//!    ([`crate::workload::EmpiricalDist`]): measured workload statistics
//!    sampled by inverse-CDF lookup, so real activations drive the same
//!    Monte-Carlo paths as the parametric models.
//!
//! # Example
//!
//! ```
//! use grcim::distributions::Distribution;
//! use grcim::rng::Pcg64;
//!
//! let d = Distribution::gauss_outliers();
//! let mut rng = Pcg64::seeded(1);
//! let mut xs = vec![0.0; 10_000];
//! d.fill(&mut rng, &mut xs);
//! // every workload distribution lives on [-1, 1] …
//! assert!(xs.iter().all(|x| x.abs() <= 1.0));
//! // … and the LLM stress model has rare large outliers over a tiny core
//! let outliers = xs.iter().filter(|x| d.is_outlier(**x)).count();
//! assert!(outliers > 0 && outliers < 300, "outliers = {outliers}");
//! assert_eq!(d.name(), "gauss+outliers[eps=0.01,k=50]");
//! ```

use crate::formats::{FpFormat, MaxEntropy};
use crate::rng::Pcg64;
use crate::workload::EmpiricalDist;
use crate::util::sync::Arc;

/// Standard-normal quantile function Φ⁻¹(p) (Acklam's rational
/// approximation, |relative error| < 1.15e-9 — far below the Monte-Carlo
/// noise floor of every estimate in this crate). The Python twin
/// (`tools/gen_goldens.py`) carries the identical coefficients and
/// operation order so quantile-driven sampling is reproducible across
/// both implementations.
pub fn probit(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p <= 0.0 {
        return f64::NEG_INFINITY;
    }
    if p >= 1.0 {
        return f64::INFINITY;
    }
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Parameters of the Gaussian+outliers stress distribution.
///
/// The paper picks ε = 0.01 and k = 50 ("consistent with empirical
/// observations regarding the sparsity and magnitude of emergent features"
/// in LLM.int8()/SmoothQuant/AWQ). We place the outlier ceiling at full
/// scale: σ = 1/(3k), outlier magnitude uniform in [0.5, 1.0]·(3kσ) =
/// [0.5, 1.0] (documented substitution — the paper only fixes the relative
/// magnitude k, not the outlier's own spread).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaussOutlierParams {
    /// Outlier probability per element (paper: 0.01).
    pub eps: f64,
    /// Outlier magnitude relative to the core's 3-sigma (paper: 50).
    pub k: f64,
}

impl Default for GaussOutlierParams {
    fn default() -> Self {
        GaussOutlierParams { eps: 0.01, k: 50.0 }
    }
}

/// A workload distribution over [-1, 1].
#[derive(Debug, Clone)]
pub enum Distribution {
    /// Uniform on [-1, 1].
    Uniform,
    /// Uniform over the bit patterns of an integral format.
    MaxEntropy(MaxEntropy),
    /// Gaussian core + rare large outliers (LLM activations).
    GaussOutliers(GaussOutlierParams),
    /// N(0, (1/c)²) clipped to [-1, 1].
    ClippedGauss {
        /// c: how many sigmas full scale sits at (Fig. 4 uses 4).
        clip_sigmas: f64,
    },
    /// Uniform on [-r, r] — the "narrowest valid bounds" dimensioning input
    /// of the Fig. 12 energy map (r = 2 · min_normal of the input format).
    UniformScaled {
        /// Half-range r (≤ 1).
        r: f64,
    },
    /// A fitted empirical tensor trace, sampled by inverse-CDF lookup
    /// (`grcim workload`; see [`crate::workload`]).
    Empirical(Arc<EmpiricalDist>),
}

impl Distribution {
    /// Max-entropy distribution of `fmt` (uniform over its bit patterns).
    pub fn max_entropy(fmt: FpFormat) -> Self {
        Distribution::MaxEntropy(MaxEntropy::new(fmt))
    }

    /// The LLM-activation stress distribution at the paper's (ε, k).
    pub fn gauss_outliers() -> Self {
        Distribution::GaussOutliers(GaussOutlierParams::default())
    }

    /// The Fig. 4 illustration distribution: N(0, (1/4)²) clipped to ±1.
    pub fn clipped_gauss4() -> Self {
        Distribution::ClippedGauss { clip_sigmas: 4.0 }
    }

    /// Wrap a fitted trace ([`crate::workload::EmpiricalDist`]) as a
    /// workload distribution.
    pub fn empirical(fit: EmpiricalDist) -> Self {
        Distribution::Empirical(Arc::new(fit))
    }

    /// Core standard deviation of the Gaussian+outliers distribution.
    pub fn core_sigma(p: GaussOutlierParams) -> f64 {
        1.0 / (3.0 * p.k)
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self {
            Distribution::Uniform => rng.uniform_in(-1.0, 1.0),
            Distribution::MaxEntropy(me) => me.sample(rng),
            Distribution::GaussOutliers(p) => {
                if rng.uniform() < p.eps {
                    rng.sign() * rng.uniform_in(0.5, 1.0)
                } else {
                    let sigma = Self::core_sigma(*p);
                    (rng.normal() * sigma).clamp(-1.0, 1.0)
                }
            }
            Distribution::ClippedGauss { clip_sigmas } => {
                (rng.normal() / clip_sigmas).clamp(-1.0, 1.0)
            }
            Distribution::UniformScaled { r } => rng.uniform_in(-r, *r),
            Distribution::Empirical(e) => e.sample(rng),
        }
    }

    /// Whether [`Distribution::sample_q`] consumes its auxiliary uniform
    /// (only the Gaussian+outliers mixture needs a branch selector).
    pub fn needs_aux(&self) -> bool {
        matches!(self, Distribution::GaussOutliers(_))
    }

    /// Quantile-driven sample: maps `u` in [0, 1] through the (signed)
    /// quantile function, with `aux` in [0, 1) selecting the mixture
    /// branch where one exists (see [`Distribution::needs_aux`]).
    ///
    /// Same marginal law as [`Distribution::sample`] when `u` and `aux`
    /// are independent uniforms, but the explicit `u` lets the
    /// variance-reduced [`Sampler`] modes place samples deliberately:
    /// antithetic pairing mirrors the magnitude quantile while keeping
    /// the sign (`u' = fract(1.5 - u)`), and stratification spreads `u`
    /// (and `aux`, killing the outlier-count binomial noise) evenly.
    pub fn sample_q(&self, u: f64, aux: f64) -> f64 {
        match self {
            Distribution::Uniform => -1.0 + 2.0 * u,
            Distribution::MaxEntropy(me) => me.sample_q(u),
            Distribution::GaussOutliers(p) => {
                if aux < p.eps {
                    // outlier branch: sign from the half, magnitude
                    // quantile folded so u' = fract(1.5-u) mirrors it
                    let (sign, t) = if u >= 0.5 {
                        (1.0, 2.0 * u - 1.0)
                    } else {
                        (-1.0, 1.0 - 2.0 * u)
                    };
                    sign * (0.5 + 0.5 * t)
                } else {
                    let sigma = Self::core_sigma(*p);
                    (probit(u) * sigma).clamp(-1.0, 1.0)
                }
            }
            Distribution::ClippedGauss { clip_sigmas } => {
                (probit(u) / clip_sigmas).clamp(-1.0, 1.0)
            }
            Distribution::UniformScaled { r } => -*r + (*r + *r) * u,
            Distribution::Empirical(e) => e.quantile(u),
        }
    }

    /// Fill a slice with the exact sequence repeated
    /// [`Distribution::sample`] calls would produce.
    ///
    /// Distributions with a fixed draw count per sample (uniform,
    /// clipped-Gaussian, empirical inverse-CDF) run on the batched RNG
    /// paths ([`Pcg64::fill_u64`] / [`Pcg64::fill_normal`]), which are
    /// bit-exact with the sequential stream; variable-draw distributions
    /// (max-entropy, Gaussian+outliers) fall back to the scalar loop.
    pub fn fill(&self, rng: &mut Pcg64, out: &mut [f64]) {
        const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
        match self {
            Distribution::Uniform | Distribution::UniformScaled { .. } => {
                let (lo, hi) = match self {
                    Distribution::UniformScaled { r } => (-*r, *r),
                    _ => (-1.0, 1.0),
                };
                let mut buf = [0u64; 256];
                for chunk in out.chunks_mut(256) {
                    let b = &mut buf[..chunk.len()];
                    rng.fill_u64(b);
                    for (o, &w) in chunk.iter_mut().zip(b.iter()) {
                        // same expression as uniform_in(lo, hi)
                        *o = lo + (hi - lo) * ((w >> 11) as f64 * SCALE);
                    }
                }
            }
            Distribution::ClippedGauss { clip_sigmas } => {
                rng.fill_normal(out);
                for o in out.iter_mut() {
                    *o = (*o / clip_sigmas).clamp(-1.0, 1.0);
                }
            }
            Distribution::Empirical(e) => {
                let mut buf = [0u64; 256];
                for chunk in out.chunks_mut(256) {
                    let b = &mut buf[..chunk.len()];
                    rng.fill_u64(b);
                    for (o, &w) in chunk.iter_mut().zip(b.iter()) {
                        // quantile() at a [0,1) uniform is the same
                        // interpolation sample() performs
                        *o = e.quantile((w >> 11) as f64 * SCALE);
                    }
                }
            }
            _ => {
                for v in out {
                    *v = self.sample(rng);
                }
            }
        }
    }

    /// Fill an f32 slice (the PJRT artifacts take f32 inputs). Runs the
    /// batched [`Distribution::fill`] paths through a stack chunk, so the
    /// hot campaign fill stays allocation-free.
    pub fn fill_f32(&self, rng: &mut Pcg64, out: &mut [f32]) {
        let mut tmp = [0.0f64; 256];
        for chunk in out.chunks_mut(256) {
            let t = &mut tmp[..chunk.len()];
            self.fill(rng, t);
            for (o, &v) in chunk.iter_mut().zip(t.iter()) {
                *o = v as f32;
            }
        }
    }

    /// Whether a sample magnitude counts as an outlier (used for the
    /// Fig. 9 "core" subset metric). Meaningful for GaussOutliers (beyond
    /// 4 core sigma) and Empirical (beyond the fitted 4·sigma_core
    /// threshold); always false otherwise.
    pub fn is_outlier(&self, x: f64) -> bool {
        match self {
            Distribution::GaussOutliers(p) => {
                x.abs() > 4.0 * Self::core_sigma(*p)
            }
            Distribution::Empirical(e) => e.is_outlier(x),
            _ => false,
        }
    }

    /// Short stable name for reports and seeds.
    pub fn name(&self) -> String {
        match self {
            Distribution::Uniform => "uniform".into(),
            Distribution::MaxEntropy(me) => {
                format!("maxent[{}]", me.format())
            }
            Distribution::GaussOutliers(p) => {
                format!("gauss+outliers[eps={},k={}]", p.eps, p.k)
            }
            Distribution::ClippedGauss { clip_sigmas } => {
                format!("clipgauss[{clip_sigmas}s]")
            }
            Distribution::UniformScaled { r } => format!("uniform[±{r:.3e}]"),
            Distribution::Empirical(e) => {
                format!("empirical[{}@{:016x}]", e.name(), e.content_hash())
            }
        }
    }
}

/// Monte-Carlo estimator mode: how a campaign job turns its RNG stream
/// into an operand slab (`samples` rows of `nr` elements).
///
/// `Plain` is the default and is bit-identical to the historical
/// sequential fill — every pre-existing golden depends on that. The
/// variance-reduced modes draw the same marginal law per element but
/// place samples deliberately, so campaign estimates (SQNR, required
/// ENOB) converge with fewer samples; they are opt-in via
/// `--sampler`, the sweep-config `sampler` key, and the serve request
/// field (see docs/THEORY.md for the estimator math).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Sampler {
    /// Independent draws (the historical estimator).
    #[default]
    Plain,
    /// Antithetic pairing: consecutive row pairs share their uniforms,
    /// the partner mirroring each magnitude quantile while keeping the
    /// sign (`u' = fract(1.5 - u)`), so even-in-sign statistics keep
    /// their sensitivity while magnitude noise cancels within pairs.
    Antithetic,
    /// Stratified (Latin-hypercube) sampling: per element position, the
    /// rows' quantiles are a random permutation of equal strata — for
    /// mixtures, the branch selector axis is stratified too, pinning the
    /// per-slab outlier count at its expectation.
    Stratified,
}

impl Sampler {
    /// Parse a CLI/config/wire name.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "plain" => Ok(Sampler::Plain),
            "antithetic" => Ok(Sampler::Antithetic),
            "stratified" => Ok(Sampler::Stratified),
            _ => Err(format!(
                "unknown sampler '{s}' (expected plain|antithetic|stratified)"
            )),
        }
    }

    /// Stable name (inverse of [`Sampler::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Sampler::Plain => "plain",
            Sampler::Antithetic => "antithetic",
            Sampler::Stratified => "stratified",
        }
    }

    /// All modes, in report order.
    pub const ALL: [Sampler; 3] =
        [Sampler::Plain, Sampler::Antithetic, Sampler::Stratified];

    /// Fill an operand slab of `out.len() / row_len` rows under this
    /// estimator mode. `Plain` delegates to the (bit-identical, batched)
    /// sequential fill; the other modes consume the same job RNG, so a
    /// job's slab remains a pure function of its seed — worker-count and
    /// chunking invariance of pooled aggregates carries over unchanged.
    pub fn fill_slab_f32(
        &self,
        dist: &Distribution,
        rng: &mut Pcg64,
        out: &mut [f32],
        row_len: usize,
    ) {
        assert!(row_len > 0 && out.len() % row_len == 0, "ragged slab");
        match self {
            Sampler::Plain => dist.fill_f32(rng, out),
            Sampler::Antithetic => {
                let needs_aux = dist.needs_aux();
                let mut pairs = out.chunks_exact_mut(2 * row_len);
                for pair in &mut pairs {
                    let (r0, r1) = pair.split_at_mut(row_len);
                    for i in 0..row_len {
                        let u = rng.uniform();
                        let aux =
                            if needs_aux { rng.uniform() } else { 0.5 };
                        r0[i] = dist.sample_q(u, aux) as f32;
                        let m = if u >= 0.5 { 1.5 - u } else { 0.5 - u };
                        r1[i] = dist.sample_q(m, aux) as f32;
                    }
                }
                // odd trailing row: no partner, draw it plain
                dist.fill_f32(rng, pairs.into_remainder());
            }
            Sampler::Stratified => {
                let rows = out.len() / row_len;
                if rows == 0 {
                    return;
                }
                let needs_aux = dist.needs_aux();
                let mut perm: Vec<u32> = (0..rows as u32).collect();
                let mut perm_aux: Vec<u32> = (0..rows as u32).collect();
                let inv_rows = 1.0 / rows as f64;
                for j in 0..row_len {
                    shuffle(&mut perm, rng);
                    if needs_aux {
                        shuffle(&mut perm_aux, rng);
                    }
                    for t in 0..rows {
                        let u = (perm[t] as f64 + rng.uniform()) * inv_rows;
                        let aux = if needs_aux {
                            (perm_aux[t] as f64 + rng.uniform()) * inv_rows
                        } else {
                            0.5
                        };
                        out[t * row_len + j] =
                            dist.sample_q(u, aux) as f32;
                    }
                }
            }
        }
    }
}

/// Fisher–Yates shuffle driven by `Pcg64::below` (twinned in
/// `tools/gen_goldens.py`).
fn shuffle(perm: &mut [u32], rng: &mut Pcg64) {
    for i in (1..perm.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        perm.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{approx_eq, mean, variance};

    fn draw(d: &Distribution, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0.0; n];
        d.fill(&mut rng, &mut v);
        v
    }

    #[test]
    fn uniform_moments_and_support() {
        let xs = draw(&Distribution::Uniform, 100_000, 1);
        assert!(xs.iter().all(|x| (-1.0..1.0).contains(x)));
        assert!(mean(&xs).abs() < 0.01);
        assert!(approx_eq(variance(&xs), 1.0 / 3.0, 0.02));
    }

    #[test]
    fn clipped_gauss_support_and_sigma() {
        let d = Distribution::clipped_gauss4();
        let xs = draw(&d, 100_000, 2);
        assert!(xs.iter().all(|x| x.abs() <= 1.0));
        assert!(approx_eq(variance(&xs).sqrt(), 0.25, 0.02));
    }

    #[test]
    fn gauss_outliers_structure() {
        let d = Distribution::gauss_outliers();
        let xs = draw(&d, 200_000, 3);
        assert!(xs.iter().all(|x| x.abs() <= 1.0));
        // outlier fraction ~ eps (outliers are >> core 4 sigma)
        let frac = xs.iter().filter(|x| d.is_outlier(**x)).count() as f64
            / xs.len() as f64;
        assert!((0.007..0.013).contains(&frac), "outlier frac {frac}");
        // core sigma = 1/150
        let core: Vec<f64> =
            xs.iter().copied().filter(|x| !d.is_outlier(*x)).collect();
        assert!(
            approx_eq(variance(&core).sqrt(), 1.0 / 150.0, 0.05),
            "core sigma {}",
            variance(&core).sqrt()
        );
        // injected outliers live in [0.5, 1]; the only exceptions are the
        // ~6e-5 Gaussian tail mass between 4 sigma and the 0.5 boundary
        let outliers: Vec<f64> = xs
            .iter()
            .copied()
            .filter(|x| d.is_outlier(*x))
            .collect();
        let in_band = outliers
            .iter()
            .filter(|x| (0.5..=1.0).contains(&x.abs()))
            .count() as f64;
        assert!(in_band / outliers.len() as f64 > 0.95);
    }

    #[test]
    fn maxentropy_samples_representable() {
        let fmt = FpFormat::fp6_e2m3();
        let d = Distribution::max_entropy(fmt);
        let xs = draw(&d, 5000, 4);
        for x in xs {
            assert_eq!(fmt.quantize(x), x);
        }
    }

    #[test]
    fn uniform_scaled_support() {
        let r = 0.01;
        let d = Distribution::UniformScaled { r };
        let xs = draw(&d, 10_000, 5);
        assert!(xs.iter().all(|x| x.abs() < r));
        assert!(approx_eq(variance(&xs), r * r / 3.0, 0.05));
    }

    #[test]
    fn deterministic_given_seed() {
        let d = Distribution::gauss_outliers();
        assert_eq!(draw(&d, 100, 42), draw(&d, 100, 42));
        assert_ne!(draw(&d, 100, 42), draw(&d, 100, 43));
    }

    #[test]
    fn batched_fill_is_bit_exact_with_sequential_sampling() {
        use crate::workload::{EmpiricalDist, TensorTrace};
        let t = TensorTrace::from_f64(
            "bx",
            vec![6],
            vec![-1.0, -0.7, -0.1, 0.2, 0.6, 1.0],
        )
        .unwrap();
        let dists = [
            Distribution::Uniform,
            Distribution::UniformScaled { r: 0.125 },
            Distribution::clipped_gauss4(),
            Distribution::empirical(EmpiricalDist::fit(&t).unwrap()),
            Distribution::gauss_outliers(),
            Distribution::max_entropy(FpFormat::fp4_e2m1()),
        ];
        for d in &dists {
            // chunk-boundary lengths around the 256-element fill chunk
            // and the 4-lane RNG width
            for len in [0usize, 1, 3, 4, 5, 255, 256, 257, 1000] {
                let mut seq = Pcg64::seeded(0xD157);
                let expect: Vec<u64> = (0..len)
                    .map(|_| d.sample(&mut seq).to_bits())
                    .collect();
                let mut bat = Pcg64::seeded(0xD157);
                let mut got = vec![0.0f64; len];
                d.fill(&mut bat, &mut got);
                let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(expect, gb, "{} len={len}", d.name());
                // and the RNG must land in the sequential state
                assert_eq!(
                    seq.next_u64(),
                    bat.next_u64(),
                    "{} state after len={len}",
                    d.name()
                );
            }
        }
    }

    #[test]
    fn fill_f32_matches_per_sample_casts() {
        let d = Distribution::clipped_gauss4();
        let mut seq = Pcg64::seeded(77);
        let expect: Vec<f32> =
            (0..700).map(|_| d.sample(&mut seq) as f32).collect();
        let mut bat = Pcg64::seeded(77);
        let mut got = vec![0.0f32; 700];
        d.fill_f32(&mut bat, &mut got);
        assert_eq!(expect, got);
    }

    #[test]
    fn probit_inverts_the_normal_cdf() {
        // spot values: probit(0.5) = 0, probit(0.975) ~ 1.95996,
        // symmetry, and tail-branch sanity
        assert_eq!(probit(0.5), 0.0);
        assert!((probit(0.975) - 1.959964).abs() < 1e-5);
        for p in [0.001, 0.01, 0.2, 0.5, 0.8, 0.99, 0.999] {
            assert!(
                (probit(p) + probit(1.0 - p)).abs() < 1e-9,
                "asymmetric at {p}"
            );
        }
        assert!(probit(0.0) == f64::NEG_INFINITY);
        assert!(probit(1.0) == f64::INFINITY);
        // monotone across the branch joints at 0.02425
        let mut prev = f64::NEG_INFINITY;
        for i in 1..1000 {
            let v = probit(i as f64 / 1000.0);
            assert!(v > prev, "not monotone at {i}");
            prev = v;
        }
    }

    #[test]
    fn sample_q_marginals_match_sample() {
        use crate::util::mean;
        // pushing i.i.d. uniforms through sample_q must reproduce the
        // distribution's moments (the unbiasedness the samplers rely on)
        let dists = [
            Distribution::Uniform,
            Distribution::clipped_gauss4(),
            Distribution::gauss_outliers(),
            Distribution::max_entropy(FpFormat::fp4_e2m1()),
        ];
        for d in &dists {
            let via_sample = draw(d, 200_000, 55);
            let mut rng = Pcg64::seeded(56);
            let via_q: Vec<f64> = (0..200_000)
                .map(|_| {
                    let u = rng.uniform();
                    let aux =
                        if d.needs_aux() { rng.uniform() } else { 0.5 };
                    d.sample_q(u, aux)
                })
                .collect();
            let (m1, m2) = (mean(&via_sample), mean(&via_q));
            assert!((m1 - m2).abs() < 0.01, "{}: {m1} vs {m2}", d.name());
            let (v1, v2) = (variance(&via_sample), variance(&via_q));
            let scale = v1.max(1e-12);
            assert!(
                ((v1 - v2) / scale).abs() < 0.05,
                "{}: var {v1} vs {v2}",
                d.name()
            );
        }
    }

    #[test]
    fn sampler_parse_roundtrip() {
        for s in Sampler::ALL {
            assert_eq!(Sampler::parse(s.name()).unwrap(), s);
        }
        assert!(Sampler::parse("sobol").is_err());
        assert_eq!(Sampler::default(), Sampler::Plain);
    }

    #[test]
    fn plain_slab_fill_is_bit_identical_to_direct_fill() {
        let d = Distribution::gauss_outliers();
        let mut a = Pcg64::seeded(91);
        let mut direct = vec![0.0f32; 64 * 8];
        d.fill_f32(&mut a, &mut direct);
        let mut b = Pcg64::seeded(91);
        let mut slab = vec![0.0f32; 64 * 8];
        Sampler::Plain.fill_slab_f32(&d, &mut b, &mut slab, 8);
        assert_eq!(direct, slab);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn antithetic_rows_are_exact_magnitude_mirrors() {
        // for Uniform the signed quantile is -1+2u, so a pair must keep
        // the sign and split the magnitude: |a| + |b| = 1
        let d = Distribution::Uniform;
        let mut rng = Pcg64::seeded(92);
        let nr = 16;
        let mut slab = vec![0.0f32; 64 * nr];
        Sampler::Antithetic.fill_slab_f32(&d, &mut rng, &mut slab, nr);
        for pair in slab.chunks_exact(2 * nr) {
            for i in 0..nr {
                let (a, b) = (pair[i] as f64, pair[nr + i] as f64);
                assert!(
                    a.signum() == b.signum() || a == 0.0 || b == 0.0,
                    "sign flip in pair: {a} {b}"
                );
                assert!(
                    (a.abs() + b.abs() - 1.0).abs() < 1e-6,
                    "not mirrored: {a} {b}"
                );
            }
        }
    }

    #[test]
    fn stratified_pins_outlier_count_at_expectation() {
        let d = Distribution::gauss_outliers();
        let mut rng = Pcg64::seeded(93);
        let rows = 2000;
        let nr = 4;
        let mut slab = vec![0.0f32; rows * nr];
        Sampler::Stratified.fill_slab_f32(&d, &mut rng, &mut slab, nr);
        // selector-axis LHS: each column gets eps*rows = 20 +- 1 outliers
        // (injected outliers have magnitude >= 0.5)
        for j in 0..nr {
            let count = (0..rows)
                .filter(|t| slab[t * nr + j].abs() >= 0.5)
                .count();
            assert!(
                (19..=21).contains(&count),
                "column {j}: {count} outliers"
            );
        }
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Distribution::Uniform.name(), "uniform");
        assert_eq!(
            Distribution::max_entropy(FpFormat::fp4_e2m1()).name(),
            "maxent[FP4_E2M1]"
        );
    }

    #[test]
    fn empirical_variant_samples_and_names() {
        use crate::workload::{EmpiricalDist, TensorTrace};
        let t = TensorTrace::from_f64(
            "acts",
            vec![4],
            vec![-1.0, -0.5, 0.5, 1.0],
        )
        .unwrap();
        let d = Distribution::empirical(EmpiricalDist::fit(&t).unwrap());
        let xs = draw(&d, 5000, 8);
        assert!(xs.iter().all(|x| x.abs() <= 1.0));
        // symmetric source -> near-zero mean
        assert!(mean(&xs).abs() < 0.05, "mean {}", mean(&xs));
        // deterministic given the seed
        assert_eq!(draw(&d, 64, 9), draw(&d, 64, 9));
        let n = d.name();
        assert!(n.starts_with("empirical[acts@"), "{n}");
    }
}
