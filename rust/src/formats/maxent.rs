//! Maximum-entropy sampling of a floating-point format.
//!
//! The paper (Sec. IV-A, distribution ii) defines the maximum-entropy
//! distribution of a format as "the distribution matching the quantizer
//! prior ... obtained by uniformly randomizing the bits of a given format":
//! sign, stored exponent code, and stored mantissa field are each drawn
//! uniformly and independently. It is the floating-point analogue of the
//! uniform INT baseline and is information-optimal for the format (QLoRA's
//! explicit objective), so the paper uses it as the first-order model of
//! empirical weight distributions.

use super::FpFormat;
use crate::rng::Pcg64;

/// Sampler over uniformly random bit patterns of an integral format.
#[derive(Debug, Clone)]
pub struct MaxEntropy {
    fmt: FpFormat,
    e_codes: u64, // 2^N_E  (stored exponent codes, incl. subnormal code 0)
    m_codes: u64, // 2^N_M  (stored mantissa codes)
}

impl MaxEntropy {
    /// A sampler for `fmt` (must be integral — bit fields are enumerable).
    pub fn new(fmt: FpFormat) -> Self {
        assert!(
            fmt.is_integral(),
            "max-entropy sampling needs an integral format, got {fmt:?}"
        );
        let e_codes = fmt.e_max as u64 + 1;
        let m_codes = 1u64 << (fmt.n_m as u64);
        MaxEntropy { fmt, e_codes, m_codes }
    }

    /// The format being sampled.
    pub fn format(&self) -> FpFormat {
        self.fmt
    }

    /// Decode (sign, stored exponent code, stored mantissa code) -> value.
    pub fn decode(&self, sign: f64, e_stored: u64, m_stored: u64) -> f64 {
        debug_assert!(e_stored < self.e_codes && m_stored < self.m_codes);
        let step = self.fmt.step();
        let m = if e_stored == 0 {
            // subnormal: M = 0.M_stored / 2
            m_stored as f64 * step
        } else {
            // normal: M = 1.M_stored / 2 in [0.5, 1)
            0.5 + m_stored as f64 * step
        };
        let e_eff = e_stored.max(1) as f64;
        sign * m * super::exp2(e_eff - self.fmt.e_max)
    }

    /// Exact quantile of the max-entropy distribution at `u` in [0, 1]:
    /// the sign comes from the half of the unit interval, the magnitude
    /// from the rank-`r` code pair in ascending-magnitude order — which
    /// is exactly (e, m) lexicographic order, because each binade's top
    /// value sits below the next binade's bottom. Same marginal law as
    /// [`MaxEntropy::sample`]; used by the variance-reduced samplers.
    pub fn sample_q(&self, u: f64) -> f64 {
        let codes = self.e_codes * self.m_codes;
        let (sign, t) = if u >= 0.5 {
            (1.0, 2.0 * u - 1.0)
        } else {
            (-1.0, 1.0 - 2.0 * u)
        };
        let r = ((t * codes as f64) as u64).min(codes - 1);
        self.decode(sign, r / self.m_codes, r % self.m_codes)
    }

    /// Draw one value with uniformly random bit fields.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        let sign = rng.sign();
        let e = rng.below(self.e_codes);
        let m = rng.below(self.m_codes);
        self.decode(sign, e, m)
    }

    /// Fill a slice.
    pub fn fill(&self, rng: &mut Pcg64, out: &mut [f64]) {
        for v in out {
            *v = self.sample(rng);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_exactly_representable() {
        let me = MaxEntropy::new(FpFormat::fp4_e2m1());
        let mut rng = Pcg64::seeded(23);
        for _ in 0..2000 {
            let v = me.sample(&mut rng);
            assert_eq!(me.format().quantize(v), v, "v={v}");
        }
    }

    #[test]
    fn covers_full_codebook() {
        let fmt = FpFormat::fp4_e2m1();
        let me = MaxEntropy::new(fmt);
        let mut rng = Pcg64::seeded(29);
        let book = fmt.codebook();
        let mut seen = vec![false; book.len()];
        for _ in 0..5000 {
            let v = me.sample(&mut rng).abs();
            let idx = book.iter().position(|b| (b - v).abs() < 1e-12);
            // +0 and -0 both map to magnitude 0
            seen[idx.expect("sample not in codebook")] = true;
        }
        assert!(seen.iter().all(|&s| s), "not all codes seen: {seen:?}");
    }

    #[test]
    fn exponent_codes_are_uniform() {
        // with 2 exponent bits, each of the 4 codes should get ~25%
        let fmt = FpFormat::fp4_e2m1();
        let me = MaxEntropy::new(fmt);
        let mut rng = Pcg64::seeded(31);
        // Miri runs exercise the sampler for UB, not the statistics;
        // the 0.02 tolerance is calibrated to the full sample count.
        let n = if cfg!(miri) { 1_000 } else { 40_000 };
        // count samples in the top binade [0.5, 1): exactly the e_max code
        let top = (0..n)
            .filter(|_| {
                let v = me.sample(&mut rng).abs();
                v >= 0.5
            })
            .count() as f64
            / n as f64;
        if cfg!(miri) {
            return;
        }
        assert!((top - 0.25).abs() < 0.02, "top binade frac = {top}");
    }

    #[test]
    fn decode_subnormals_and_normals() {
        let me = MaxEntropy::new(FpFormat::fp4_e2m1()); // e_max=3, step=.25
        assert_eq!(me.decode(1.0, 0, 0), 0.0);
        assert_eq!(me.decode(1.0, 0, 1), 0.0625); // 0.25 * 2^-2
        assert_eq!(me.decode(1.0, 1, 0), 0.125); // 0.5 * 2^-2
        assert_eq!(me.decode(1.0, 3, 1), 0.75); // 0.75 * 2^0
        assert_eq!(me.decode(-1.0, 3, 0), -0.5);
    }

    #[test]
    #[should_panic(expected = "integral")]
    fn rejects_fractional_formats() {
        MaxEntropy::new(FpFormat { e_max: 2.5, n_m: 1.0 });
    }
}
