//! Floating-point format arithmetic — the Rust twin of
//! `python/compile/fpfmt.py` (kept in lockstep; see the cross-check
//! integration test `rust/tests/runtime_crosscheck.rs`).
//!
//! The paper's value convention (Sec. III-A):
//!
//! ```text
//! x = (-1)^S * M * 2^(E - e_max),   e_max = 2^N_E - 1
//! ```
//!
//! with effective significand `M in [0.5, 1)` for normals
//! (`M = 1.M_stored / 2`), `M in [0, 0.5)` for subnormals (stored exponent
//! code 0, effective exponent `E = 1`), effective exponent
//! `E = max(1, E_stored)`.
//!
//! Formats are parameterized by `(e_max, n_m)` rather than `(N_E, N_M)`:
//! `e_max` and `n_m` may be **fractional** — the continuous dynamic-range /
//! SQNR axes of the Fig. 12 design-space map — and the quantizer stays
//! well-defined (the exponent grid remains integer-stepped, offset by
//! `e_max`). `INT-N` is the exact degenerate case `e_max = 1`
//! (uniform grid of step `2^-(N-1)` over [-1, 1]); see [`FpFormat::int`].
//!
//! # Example
//!
//! ```
//! use grcim::formats::FpFormat;
//!
//! let fp4 = FpFormat::fp4_e2m1(); // the OCP MX 4-bit format
//! assert_eq!(fp4.to_string(), "FP4_E2M1");
//! assert_eq!(fp4.quantize(5.0), 0.75); // saturates at vmax
//! assert_eq!(fp4.quantize(0.26), 0.25); // rounds on the mantissa grid
//! assert_eq!(fp4.codebook().len(), 8); // non-negative magnitudes
//!
//! // INT-N is the e_max = 1 degenerate case of the same quantizer
//! let int8 = FpFormat::int(8);
//! assert_eq!(int8.dr_bits(), 8.0);
//! assert_eq!(int8.quantize(0.3), 0.296875); // uniform 2^-7 grid
//! ```

pub mod maxent;

pub use maxent::MaxEntropy;

/// Exact 2^t for integer t (bit-constructed), standard exp2 otherwise.
///
/// Mirrors `fpfmt.exp2` on the Python side, where XLA-CPU's f32 `exp2` is
/// inexact even at integer arguments. Rust's `f64::exp2` is exact at
/// integers on every libm we target, but the bit construction makes the
/// contract explicit and cheap.
#[inline]
pub fn exp2(t: f64) -> f64 {
    let ti = t.floor();
    let fr = t - ti;
    let ip = if (-1022.0..=1023.0).contains(&ti) {
        f64::from_bits((((ti as i64) + 1023) as u64) << 52)
    } else {
        ti.exp2()
    };
    if fr == 0.0 {
        ip
    } else {
        ip * fr.exp2()
    }
}

/// A (possibly fractional) floating-point format specification.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpFormat {
    /// Largest stored exponent code (2^N_E - 1 for integer N_E); effective
    /// exponents live in [1, e_max], code 0 is the subnormal marker.
    pub e_max: f64,
    /// Stored mantissa bits (excluding the implicit leading bit).
    pub n_m: f64,
}

impl FpFormat {
    /// Standard format from exponent/mantissa bit widths: FP(N_E, N_M).
    pub fn fp(n_e: u32, n_m: u32) -> Self {
        assert!(n_e >= 1, "FP formats need at least one exponent bit");
        FpFormat { e_max: (1u64 << n_e) as f64 - 1.0, n_m: n_m as f64 }
    }

    /// Signed integer format INT-N on [-1, 1]: the e_max = 1 degenerate
    /// case (uniform grid, step 2^-(N-1); vmax = 1 - 2^-(N-1)).
    pub fn int(n_bits: u32) -> Self {
        assert!(n_bits >= 2, "INT formats need sign + at least one bit");
        FpFormat { e_max: 1.0, n_m: n_bits as f64 - 2.0 }
    }

    /// Continuous-axis format from a (DR_dB, SQNR_dB) design-space point.
    ///
    /// DESIGN.md #2/#3 conventions:
    ///   SQNR_dB = 6.02 * (n_m + 1) + 10.79   (paper Sec. IV-A, N_M incl.
    ///                                         implicit bit)
    ///   DR_bits = e_max + n_m + 1,  DR_dB = 6.02 * DR_bits
    ///             (full scale over smallest step; reduces to N for INT-N)
    ///
    /// Returns None when the point is left of the INT line (e_max < 1):
    /// the dynamic range is below the minimum needed for that SQNR.
    pub fn from_spec(dr_db: f64, sqnr_db: f64) -> Option<Self> {
        let n_m = (sqnr_db - 10.79) / 6.02 - 1.0;
        if n_m < 0.0 {
            return None;
        }
        let e_max = dr_db / 6.02 - n_m - 1.0;
        if e_max < 1.0 - 1e-9 {
            return None;
        }
        Some(FpFormat { e_max: e_max.max(1.0), n_m })
    }

    /// FP4_E2M1 — the OCP MX 4-bit format.
    pub fn fp4_e2m1() -> Self {
        Self::fp(2, 1)
    }

    /// FP6_E2M3.
    pub fn fp6_e2m3() -> Self {
        Self::fp(2, 3)
    }

    /// FP6_E3M2.
    pub fn fp6_e3m2() -> Self {
        Self::fp(3, 2)
    }

    /// FP8_E4M3.
    pub fn fp8_e4m3() -> Self {
        Self::fp(4, 3)
    }

    /// Mantissa grid step on the effective significand: 2^-(n_m + 1).
    #[inline]
    pub fn step(&self) -> f64 {
        exp2(-(self.n_m + 1.0))
    }

    /// Largest representable magnitude: (1 - step) * 2^0.
    #[inline]
    pub fn vmax(&self) -> f64 {
        1.0 - self.step()
    }

    /// Smallest positive normal magnitude: 0.5 * 2^(1 - e_max).
    #[inline]
    pub fn min_normal(&self) -> f64 {
        0.5 * exp2(1.0 - self.e_max)
    }

    /// Smallest positive (subnormal) step: step * 2^(1 - e_max).
    #[inline]
    pub fn min_step(&self) -> f64 {
        self.step() * exp2(1.0 - self.e_max)
    }

    /// Dynamic range in bits: full-scale (2.0) over the smallest step,
    /// log2. Equals e_max + n_m + 1 (and N for INT-N).
    pub fn dr_bits(&self) -> f64 {
        self.e_max + self.n_m + 1.0
    }

    /// Dynamic range in dB (power convention: 6.02 dB / bit).
    pub fn dr_db(&self) -> f64 {
        6.02 * self.dr_bits()
    }

    /// Format SQNR in dB: 6.02 * N_M + 10.79 with N_M counting the implicit
    /// bit (paper Sec. IV-A, from Widrow & Kollar).
    pub fn sqnr_db(&self) -> f64 {
        6.02 * (self.n_m + 1.0) + 10.79
    }

    /// True if (e_max, n_m) are integers — required for codebook
    /// enumeration and max-entropy sampling.
    pub fn is_integral(&self) -> bool {
        self.e_max.fract() == 0.0 && self.n_m.fract() == 0.0
    }

    /// Number of exponent bits for integral formats.
    pub fn n_e_bits(&self) -> f64 {
        (self.e_max + 1.0).log2()
    }

    /// Decompose a magnitude into (M, E_eff).
    ///
    /// `a == 0` maps to `(0.0, 1.0)`: the zero encoding keeps the subnormal
    /// exponent, which matters for the GR-MAC — a zero-mantissa cell still
    /// drives its one-hot exponent coupling switches (Sec. III-B2).
    #[inline]
    pub fn decompose(&self, a: f64) -> (f64, f64) {
        let safe = a.max(1e-300);
        // floor(log2(safe)) is exactly the unbiased f64 exponent field
        // (safe is normal by construction): a bit extraction instead of a
        // libm log2 — exact AND ~3x faster (§Perf iteration 2).
        let floor_log2 = ((safe.to_bits() >> 52) & 0x7ff) as f64 - 1023.0;
        let e = (floor_log2 + 1.0 + self.e_max).clamp(1.0, self.e_max);
        let m = a * exp2(self.e_max - e);
        (m, e)
    }

    /// Quantize to this format: round-half-up on the mantissa grid,
    /// saturating at +/- vmax; sub-grid magnitudes flush on the subnormal
    /// grid. Matches `fpfmt.quantize` (Python) semantics.
    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        let step = self.step();
        let s = if x < 0.0 { -1.0 } else { 1.0 };
        let a = x.abs();
        let (m, e) = self.decompose(a);
        let m_q = (m / step + 0.5).floor() * step;
        let a_q = (m_q * exp2(e - self.e_max)).min(self.vmax());
        if a_q == 0.0 {
            0.0 // avoid -0.0
        } else {
            s * a_q
        }
    }

    /// Local quantization step at quantized magnitude `a_q`:
    /// Delta = step * 2^(E_eff - e_max).
    #[inline]
    pub fn ulp(&self, a_q: f64) -> f64 {
        let (_, e) = self.decompose(a_q);
        self.step() * exp2(e - self.e_max)
    }

    /// Fused quantize + decompose: returns `(x_q, M_signed, E_eff)` such
    /// that `x_q == quantize(x)` and `(|M|, E) == decompose(|x_q|)` — one
    /// log2 instead of two. This is the Monte-Carlo engine's hot call
    /// (see EXPERIMENTS.md §Perf).
    #[inline]
    pub fn quantize_parts(&self, x: f64) -> (f64, f64, f64) {
        let step = self.step();
        let s = if x < 0.0 { -1.0 } else { 1.0 };
        let a = x.abs();
        let (m, e) = self.decompose(a);
        let m_q = (m / step + 0.5).floor() * step;
        let a_q = (m_q * exp2(e - self.e_max)).min(self.vmax());
        if self.e_max.fract() != 0.0 {
            // fractional e_max (the Fig. 12 continuous DR axis): the e = 1
            // clamp is offset from the binade ladder, so mantissa rounding
            // can cross binades — recanonicalize through decompose. This
            // is the cold path; campaigns run integral formats.
            let (m_f, e_f) = self.decompose(a_q);
            return if a_q == 0.0 {
                (0.0, 0.0, 1.0)
            } else {
                (s * a_q, s * m_f, e_f)
            };
        }
        let (a_f, m_f, e_f) = if a_q >= self.vmax() {
            // saturation (includes the m_q == 1.0 rollover at e == e_max)
            (self.vmax(), self.vmax(), self.e_max)
        } else if m_q >= 1.0 {
            // rollover renormalizes to 0.5 at the next binade
            (a_q, 0.5, e + 1.0)
        } else {
            (a_q, m_q, e)
        };
        if a_f == 0.0 {
            (0.0, 0.0, 1.0)
        } else {
            (s * a_f, s * m_f, e_f)
        }
    }

    /// Enumerate all representable magnitudes (integral formats only),
    /// ascending, including 0.
    pub fn codebook(&self) -> Vec<f64> {
        assert!(self.is_integral(), "codebook needs an integral format");
        let step = self.step();
        let n_sub = (0.5 / step).round() as u64;
        let n_norm = (0.5 / step).round() as u64;
        let mut vals = Vec::new();
        let sub_scale = exp2(1.0 - self.e_max);
        for k in 0..n_sub {
            vals.push(k as f64 * step * sub_scale);
        }
        for e in 1..=(self.e_max as u64) {
            let scale = exp2(e as f64 - self.e_max);
            for k in 0..n_norm {
                vals.push((0.5 + k as f64 * step) * scale);
            }
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        vals
    }
}

impl std::fmt::Display for FpFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_integral() {
            if self.e_max == 1.0 {
                write!(f, "INT{}", self.n_m as u64 + 2)
            } else {
                let n_e = self.n_e_bits();
                if n_e.fract() == 0.0 {
                    let total = 1 + n_e as u64 + self.n_m as u64;
                    write!(f, "FP{}_E{}M{}", total, n_e as u64, self.n_m as u64)
                } else {
                    write!(f, "FP(emax={},m={})", self.e_max, self.n_m)
                }
            }
        } else {
            write!(f, "FP(emax={:.2},m={:.2})", self.e_max, self.n_m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn exp2_exact_at_integers() {
        for e in -60..=60 {
            assert_eq!(exp2(e as f64), (e as f64).exp2(), "e={e}");
            let bits = exp2(e as f64);
            assert_eq!(bits, 2f64.powi(e));
        }
        assert_eq!(exp2(13.0), 8192.0);
    }

    #[test]
    fn exp2_fractional_close() {
        assert!(approx_eq(exp2(0.5), std::f64::consts::SQRT_2, 1e-12));
        assert!(approx_eq(exp2(-2.5), 2f64.powf(-2.5), 1e-12));
    }

    #[test]
    fn fp4_e2m1_codebook_is_ocp_set() {
        let f = FpFormat::fp4_e2m1();
        let book: Vec<f64> = f.codebook().iter().map(|v| v * 8.0).collect();
        assert_eq!(book, vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn codebook_values_are_quantizer_fixed_points() {
        for f in [
            FpFormat::fp4_e2m1(),
            FpFormat::fp6_e2m3(),
            FpFormat::fp6_e3m2(),
            FpFormat::fp8_e4m3(),
            FpFormat::int(4),
            FpFormat::int(8),
        ] {
            for v in f.codebook() {
                assert_eq!(f.quantize(v), v, "{f} value {v}");
                assert_eq!(f.quantize(-v), -v, "{f} value -{v}");
            }
        }
    }

    #[test]
    fn quantize_saturates() {
        let f = FpFormat::fp4_e2m1();
        assert_eq!(f.quantize(5.0), 0.75);
        assert_eq!(f.quantize(-5.0), -0.75);
        assert_eq!(f.quantize(1.0), 0.75);
    }

    #[test]
    fn quantize_zero_and_subnormals() {
        let f = FpFormat::fp4_e2m1();
        assert_eq!(f.quantize(0.0), 0.0);
        assert_eq!(f.quantize(0.01), 0.0); // below half-subnormal-step
        assert_eq!(f.quantize(0.05), 0.0625);
        assert_eq!(f.quantize(-0.05), -0.0625);
    }

    #[test]
    fn quantize_error_within_half_ulp() {
        let f = FpFormat::fp6_e2m3();
        let mut rng = crate::rng::Pcg64::seeded(3);
        for _ in 0..5000 {
            let x = rng.uniform_in(-f.vmax(), f.vmax());
            let q = f.quantize(x);
            let delta = f.ulp(q.abs());
            assert!(
                (q - x).abs() <= 0.5 * delta + 1e-15,
                "x={x} q={q} delta={delta}"
            );
        }
    }

    #[test]
    fn quantize_monotone() {
        let f = FpFormat::fp6_e3m2();
        let mut rng = crate::rng::Pcg64::seeded(5);
        for _ in 0..2000 {
            let a = rng.uniform_in(-1.0, 1.0);
            let b = rng.uniform_in(-1.0, 1.0);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            assert!(f.quantize(lo) <= f.quantize(hi));
        }
    }

    #[test]
    fn quantize_idempotent_and_odd() {
        let f = FpFormat::fp(3, 2);
        let mut rng = crate::rng::Pcg64::seeded(5);
        for _ in 0..2000 {
            let x = rng.uniform_in(-1.5, 1.5);
            let q = f.quantize(x);
            assert_eq!(f.quantize(q), q);
            assert_eq!(f.quantize(-x), -q);
        }
    }

    #[test]
    fn decompose_convention_matches_paper() {
        let f = FpFormat::fp4_e2m1(); // e_max = 3
        assert_eq!(f.decompose(0.75), (0.75, 3.0));
        assert_eq!(f.decompose(0.125), (0.5, 1.0)); // 0.5 * 2^-2, min normal
        let (m, e) = f.decompose(0.0625); // subnormal
        assert_eq!(e, 1.0);
        assert!(approx_eq(m, 0.25, 1e-15));
        assert_eq!(f.decompose(0.0), (0.0, 1.0)); // zero keeps E_eff = 1
    }

    #[test]
    fn int_format_is_uniform_grid()
    {
        let f = FpFormat::int(4); // step 2^-3 = 0.125 on [-1,1]
        let book = f.codebook();
        for w in book.windows(2) {
            assert!(approx_eq(w[1] - w[0], 0.125, 1e-12));
        }
        assert_eq!(f.quantize(0.3), 0.25);
        assert_eq!(f.quantize(0.33), 0.375);
        assert_eq!(f.vmax(), 0.875);
        assert_eq!(f.dr_bits(), 4.0);
    }

    #[test]
    fn dr_and_sqnr_conventions() {
        assert_eq!(FpFormat::fp4_e2m1().dr_bits(), 5.0);
        assert_eq!(FpFormat::fp6_e3m2().dr_bits(), 10.0);
        assert_eq!(FpFormat::fp8_e4m3().dr_bits(), 19.0);
        assert_eq!(FpFormat::int(8).dr_bits(), 8.0);
        // SQNR: FP4_E2M1 has 2 effective mantissa bits
        assert!(approx_eq(FpFormat::fp4_e2m1().sqnr_db(), 22.83, 1e-2));
    }

    #[test]
    fn from_spec_round_trips_formats() {
        for f in [FpFormat::fp4_e2m1(), FpFormat::fp6_e3m2(), FpFormat::fp(2, 3)] {
            let g = FpFormat::from_spec(f.dr_db(), f.sqnr_db()).unwrap();
            assert!(approx_eq(g.e_max, f.e_max, 1e-9), "{f}: {g:?}");
            assert!(approx_eq(g.n_m + 1.0, f.n_m + 1.0, 1e-9), "{f}: {g:?}");
        }
    }

    #[test]
    fn from_spec_rejects_points_left_of_int_line() {
        // DR far below what the SQNR needs
        assert!(FpFormat::from_spec(12.0, 47.0).is_none());
        // INT line itself is valid
        let f = FpFormat::int(6);
        assert!(FpFormat::from_spec(f.dr_db(), f.sqnr_db()).is_some());
    }

    #[test]
    fn fractional_format_quantizer_is_sane() {
        let f = FpFormat { e_max: 5.5, n_m: 2.25 };
        let mut rng = crate::rng::Pcg64::seeded(7);
        for _ in 0..1000 {
            let x = rng.uniform_in(-1.0, 1.0);
            let q = f.quantize(x);
            assert!(q.is_finite());
            assert_eq!(f.quantize(q), q); // idempotent
        }
    }

    #[test]
    fn display_names() {
        assert_eq!(FpFormat::fp4_e2m1().to_string(), "FP4_E2M1");
        assert_eq!(FpFormat::fp6_e3m2().to_string(), "FP6_E3M2");
        assert_eq!(FpFormat::int(8).to_string(), "INT8");
    }

    #[test]
    fn quantize_parts_consistent_with_quantize_and_decompose() {
        let mut rng = crate::rng::Pcg64::seeded(91);
        for fmt in [
            FpFormat::fp4_e2m1(),
            FpFormat::fp6_e2m3(),
            FpFormat::fp(4, 2),
            FpFormat::int(5),
            FpFormat { e_max: 5.5, n_m: 2.25 },
        ] {
            for _ in 0..3000 {
                let x = rng.uniform_in(-1.5, 1.5);
                let (xq, m, e) = fmt.quantize_parts(x);
                assert_eq!(xq, fmt.quantize(x), "{fmt} at {x}");
                let (md, ed) = fmt.decompose(xq.abs());
                assert_eq!(m.abs(), md, "{fmt} mantissa at {x}");
                assert_eq!(e, ed, "{fmt} exponent at {x}");
                if xq != 0.0 {
                    assert_eq!(m.signum(), xq.signum());
                }
            }
            // exact edge cases
            assert_eq!(fmt.quantize_parts(0.0), (0.0, 0.0, 1.0));
        }
    }

    #[test]
    fn rollover_renormalizes() {
        // FP(e_max=3, n_m=1): 0.47 -> m = 0.94 -> rounds to 1.0 -> 0.5 @ e+1
        let f = FpFormat::fp4_e2m1();
        assert_eq!(f.quantize(0.47), 0.5);
    }
}
