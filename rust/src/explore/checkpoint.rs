//! Crash-safe JSONL checkpointing for explore campaigns.
//!
//! Layout: line 1 is a header object carrying the format tag, the
//! canonical plan (so `--resume <ckpt>` needs no other input), the
//! plan's content hash, the engine, and the expanded point count; every
//! further line is one completed [`super::ExplorePoint`] in canonical
//! JSON. Workers append whole lines under a mutex and fsync each one,
//! so a kill at any instant loses at most the line being written; the
//! loader tolerates (and reports) one partial trailing line.
//!
//! Bit-identity across resume: point records serialize floats in
//! shortest round-trip form ([`crate::config::json::Json`]), so loading
//! a completed point and re-serializing it reproduces the original
//! bytes exactly — a resumed campaign's final output cannot differ from
//! an uninterrupted run's.

use super::{ExplorePoint, ParetoPlan};
use crate::config::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use crate::util::sync::{lock_recover, Arc, Mutex};
use std::path::Path;

/// The header's format tag — bumped only on incompatible layout changes.
pub const CKPT_FORMAT: &str = "grcim-pareto-ckpt";
/// Current checkpoint layout version.
pub const CKPT_VERSION: f64 = 1.0;

/// A shared append handle: workers lock, write one full line, flush,
/// and fsync before unlocking — lines never interleave and a completed
/// point survives any later crash.
#[derive(Clone)]
pub struct CkptWriter(Arc<Mutex<File>>);

impl CkptWriter {
    /// Append one completed point (one line + fsync).
    pub fn append(&self, point: &ExplorePoint) -> Result<()> {
        let line = point.to_json().to_string();
        // recover from poisoning: the file is valid after any
        // interrupted append — at worst the loader reports one partial
        // trailing line, exactly the crash case it already tolerates
        let mut f = lock_recover(&self.0);
        f.write_all(line.as_bytes()).context("appending checkpoint point")?;
        f.write_all(b"\n").context("appending checkpoint newline")?;
        f.flush().context("flushing checkpoint")?;
        f.sync_data().context("fsyncing checkpoint")?;
        Ok(())
    }
}

/// What a checkpoint file opened for resume (or creation) holds.
pub struct Checkpoint {
    /// The plan the campaign runs (from the header on resume).
    pub plan: ParetoPlan,
    /// Engine name the campaign ran on (resume must reuse it — the
    /// point records are engine-dependent).
    pub engine: String,
    /// Completed points loaded from the file, keyed by point index.
    pub done: BTreeMap<usize, ExplorePoint>,
    /// Append handle for the remaining points.
    pub writer: CkptWriter,
}

/// The header object both the checkpoint file and the final campaign
/// output lead with: format tag, version, the canonical plan, its
/// content hash, the engine, and the expanded point count.
pub fn header_json(plan: &ParetoPlan, engine: &str) -> Json {
    let mut h = BTreeMap::new();
    h.insert("format".to_string(), Json::Str(CKPT_FORMAT.to_string()));
    h.insert("version".to_string(), Json::Num(CKPT_VERSION));
    h.insert("plan".to_string(), plan.to_json());
    h.insert(
        "plan_hash".to_string(),
        Json::Str(format!("{:016x}", plan.content_hash())),
    );
    h.insert("engine".to_string(), Json::Str(engine.to_string()));
    h.insert("points".to_string(), Json::Num(plan.num_points() as f64));
    Json::Obj(h)
}

/// Create a fresh checkpoint file at `path` (truncating any previous
/// one) and write its header.
pub fn create(path: &Path, plan: &ParetoPlan, engine: &str) -> Result<Checkpoint> {
    let mut f = File::create(path)
        .with_context(|| format!("creating checkpoint {}", path.display()))?;
    f.write_all(header_json(plan, engine).to_string().as_bytes())?;
    f.write_all(b"\n")?;
    f.flush()?;
    f.sync_data()?;
    Ok(Checkpoint {
        plan: plan.clone(),
        engine: engine.to_string(),
        done: BTreeMap::new(),
        writer: CkptWriter(Arc::new(Mutex::new(f))),
    })
}

/// Open an existing checkpoint for resume: validate the header (format
/// tag, version, plan hash vs the embedded plan), load every completed
/// point, drop at most one partial trailing line, and reopen the file
/// in append mode. When `expect_plan` is given (resume with an explicit
/// `--plan` too), its hash must match the header's.
pub fn resume(path: &Path, expect_plan: Option<&ParetoPlan>) -> Result<Checkpoint> {
    let f = File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut lines = BufReader::new(f).lines();
    let header_line = match lines.next() {
        Some(l) => l.context("reading checkpoint header")?,
        None => bail!("checkpoint {} is empty (no header)", path.display()),
    };
    let header = Json::parse(header_line.trim())
        .with_context(|| format!("checkpoint {} header is not JSON", path.display()))?;
    match header.get("format").and_then(Json::as_str) {
        Some(CKPT_FORMAT) => {}
        other => bail!(
            "checkpoint {}: format tag {:?} is not '{CKPT_FORMAT}'",
            path.display(),
            other
        ),
    }
    match header.get("version").and_then(Json::as_f64) {
        Some(v) if v == CKPT_VERSION => {}
        other => bail!("checkpoint {}: unsupported version {other:?}", path.display()),
    }
    let plan_json = header
        .get("plan")
        .context("checkpoint header has no plan")?;
    let plan = ParetoPlan::from_json(plan_json)
        .context("checkpoint header plan does not resolve")?;
    let stored_hash = header
        .get("plan_hash")
        .and_then(Json::as_str)
        .context("checkpoint header has no plan_hash")?;
    let actual = format!("{:016x}", plan.content_hash());
    if stored_hash != actual {
        bail!(
            "checkpoint {}: plan_hash {stored_hash} does not match its plan ({actual}) — \
             the file was edited or corrupted",
            path.display()
        );
    }
    if let Some(expect) = expect_plan {
        let want = format!("{:016x}", expect.content_hash());
        if want != actual {
            bail!(
                "checkpoint {}: plan hash {actual} does not match the supplied plan ({want})",
                path.display()
            );
        }
    }
    let engine = header
        .get("engine")
        .and_then(Json::as_str)
        .context("checkpoint header has no engine")?
        .to_string();
    let total = plan.num_points();

    let mut done = BTreeMap::new();
    let mut partial = 0usize;
    for line in lines {
        let line = line.context("reading checkpoint line")?;
        if line.trim().is_empty() {
            continue;
        }
        // a kill mid-append leaves at most one unparseable trailing
        // line; anything unparseable before the end is real corruption
        match Json::parse(line.trim()).ok().map(|j| ExplorePoint::from_json(&j)) {
            Some(Ok(p)) => {
                if p.index >= total {
                    bail!(
                        "checkpoint {}: point index {} out of range (plan has {total})",
                        path.display(),
                        p.index
                    );
                }
                if partial > 0 {
                    bail!(
                        "checkpoint {}: valid point after a corrupt line — \
                         the file was edited or corrupted",
                        path.display()
                    );
                }
                done.insert(p.index, p);
            }
            _ => partial += 1,
        }
    }
    if partial > 1 {
        bail!(
            "checkpoint {}: {partial} unparseable lines (only one partial \
             trailing line is tolerated)",
            path.display()
        );
    }

    let f = OpenOptions::new()
        .append(true)
        .open(path)
        .with_context(|| format!("reopening checkpoint {}", path.display()))?;
    Ok(Checkpoint {
        plan,
        engine,
        done,
        writer: CkptWriter(Arc::new(Mutex::new(f))),
    })
}

#[cfg(test)]
mod tests {
    use super::super::tests::tiny_plan;
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grcim_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn create_then_resume_roundtrips_the_plan() {
        let plan = tiny_plan();
        let path = tmp("roundtrip.jsonl");
        create(&path, &plan, "rust").unwrap();
        let ck = resume(&path, Some(&plan)).unwrap();
        assert_eq!(ck.plan.content_hash(), plan.content_hash());
        assert_eq!(ck.engine, "rust");
        assert!(ck.done.is_empty());
    }

    #[test]
    fn mismatched_plan_is_rejected() {
        let plan = tiny_plan();
        let path = tmp("mismatch.jsonl");
        create(&path, &plan, "rust").unwrap();
        let mut other = tiny_plan();
        other.seed += 1;
        let err = resume(&path, Some(&other)).unwrap_err().to_string();
        assert!(err.contains("does not match the supplied plan"), "{err}");
    }

    #[test]
    fn partial_trailing_line_is_tolerated() {
        let plan = tiny_plan();
        let path = tmp("partial.jsonl");
        create(&path, &plan, "rust").unwrap();
        // simulate a kill mid-append: garbage tail bytes
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"index\":0,\"trunc").unwrap();
        }
        let ck = resume(&path, None).unwrap();
        assert!(ck.done.is_empty());
    }

    #[test]
    fn tampered_header_hash_is_rejected() {
        let plan = tiny_plan();
        let path = tmp("tampered.jsonl");
        create(&path, &plan, "rust").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let bad = text.replacen("\"plan_hash\":\"", "\"plan_hash\":\"0", 1);
        std::fs::write(&path, bad).unwrap();
        let err = resume(&path, None).unwrap_err().to_string();
        assert!(err.contains("plan_hash"), "{err}");
    }

    #[test]
    fn empty_or_alien_files_are_clean_errors() {
        let path = tmp("empty.jsonl");
        std::fs::write(&path, "").unwrap();
        assert!(resume(&path, None).unwrap_err().to_string().contains("empty"));
        std::fs::write(&path, "{\"format\":\"other\"}\n").unwrap();
        let err = resume(&path, None).unwrap_err().to_string();
        assert!(err.contains("format tag"), "{err}");
    }
}
