//! Design-space Pareto explorer (the ROADMAP's campaign-scale item):
//! a [`ParetoPlan`] — TOML grid axes over workload, tile geometry,
//! input format, architecture, ADC policy, and ADC technology scale —
//! expands into a deterministic point list, shards across the generic
//! worker pool, and yields one [`ExplorePoint`] per configuration with
//! a component-level energy breakdown, the achieved layer SQNR, the
//! digital-IMC baseline ([`crate::energy::digital`]), and the
//! analog-vs-digital crossover resolution.
//!
//! Determinism contract (the same one the tile mapper keeps): a point's
//! outcome depends only on (plan, engine, point index) — operands are
//! drawn from `job_seed(plan.seed, EXPLORE_STREAM, index)` and each
//! point runs the sequential [`crate::tile::gemm_with_engine`] path
//! inside its worker — so results are bit-identical for any worker
//! count, any sharding, and any resume split ([`checkpoint`]).
//!
//! Frontier: a point survives ([`frontier`]) iff no other point has
//! lower-or-equal fJ/MAC **and** higher-or-equal SQNR with one strict.
//! Membership is a pure function of the point set, so it is recomputed
//! from scratch whenever points are rendered.

pub mod checkpoint;
pub mod frontier;

use crate::config::json::Json;
use crate::config::{Config, Table, Value};
use crate::coordinator::{pool, CampaignConfig};
use crate::energy::{digital, CimArch, TechParams};
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::rng::{job_seed, Pcg64};
use crate::runtime::{build_engine, Engine, EngineKind};
use crate::server::{MAX_LAYER_ELEMS, MAX_LAYER_MACS};
use crate::tile::{
    gemm_with_engine, im2col, parse_shape, AdcPolicy, ConvShape, TileConfig, MAX_TILE_ENOB,
};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use crate::util::sync::Arc;

pub use checkpoint::{Checkpoint, CkptWriter};
pub use frontier::{frontier_indices, frontier_mask, Objectives};

/// Grid-index namespace of explore-point operand streams in
/// [`crate::rng::job_seed`] — disjoint from the layer runner's
/// [`crate::tile::mapper::LAYER_STREAM`] and from campaign job streams,
/// so explorer operands never collide with any other draw at the same
/// seed. The Python twin (`tools/gen_goldens.py`) uses the same
/// constant.
pub const EXPLORE_STREAM: u64 = 0x9A2E;

/// Largest expanded grid a plan may describe. Keeps a typo'd axis from
/// turning one `explore` invocation into an unbounded campaign; real
/// studies (the paper sweeps ≤ a few dozen configurations per figure)
/// sit far below this.
pub const MAX_PLAN_POINTS: usize = 4096;

/// Default campaign seed when the plan has none.
pub const DEFAULT_PLAN_SEED: u64 = 42;

/// Default batch rows M for named workload shapes (`mlp-up:<d>`, …).
pub const DEFAULT_PLAN_TOKENS: usize = 16;

/// FNV-1a 64 over the canonical plan serialization — the checkpoint
/// header's and the serve cache's content hash. (Same constants as the
/// trace reader's integrity hash; tiny and dependency-free.)
fn fnv1a64(bytes: &[u8]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Stable engine-kind name recorded in checkpoint headers and cache
/// keys (matches the serve layer's `--engine` spellings).
pub fn engine_name(kind: EngineKind) -> &'static str {
    match kind {
        EngineKind::Rust => "rust",
        EngineKind::Pjrt => "pjrt",
        EngineKind::Auto => "auto",
    }
}

/// Shortest round-trip rendering of a number (the [`Json`] convention),
/// used wherever an axis value becomes part of a canonical string.
fn fmt_num(n: f64) -> String {
    Json::Num(n).to_string()
}

/// Parse a plan's ADC-policy string: `spec` (per-tile solved
/// resolution) or `fixed:<bits>`. Returns the policy plus its canonical
/// rendering (what the plan hash and point records carry).
pub fn parse_adc(s: &str) -> Result<(AdcPolicy, String)> {
    if s == "spec" {
        return Ok((AdcPolicy::PerTileSpec, "spec".to_string()));
    }
    if let Some(bits) = s.strip_prefix("fixed:") {
        let b: f64 = bits
            .parse()
            .with_context(|| format!("adc '{s}': '{bits}' is not a resolution"))?;
        if !b.is_finite() || b <= 0.0 || b > MAX_TILE_ENOB {
            bail!("adc '{s}': resolution must be in (0, {MAX_TILE_ENOB}] bits");
        }
        return Ok((AdcPolicy::Fixed(b), format!("fixed:{}", fmt_num(b))));
    }
    bail!("unknown adc policy '{s}' (spec | fixed:<bits>)")
}

/// A design-space exploration plan: scalar campaign knobs plus the grid
/// axes, expanded as a lexicographic cartesian product in the fixed
/// axis order workload → nr → nc → arch → n_e → n_m → adc → adc_scale.
///
/// Axis values are stored canonicalized (arch names, adc strings,
/// shortest-form numbers), so two plans that mean the same grid hash
/// identically regardless of how they were spelled.
#[derive(Debug, Clone)]
pub struct ParetoPlan {
    /// Plan label (reports only; part of the canonical form).
    pub name: String,
    /// Campaign seed every point's operand stream derives from.
    pub seed: u64,
    /// Batch rows M for named workload shapes.
    pub tokens: usize,
    /// Activation workload distribution (weights are always max-entropy
    /// FP4, the paper's sweep convention).
    pub distribution: String,
    /// Workload axis: `gemm:MxKxN`, `conv:…`, or a named shape.
    pub workload: Vec<String>,
    /// Accumulation-depth axis N_R.
    pub nr: Vec<usize>,
    /// Columns-per-tile axis N_C.
    pub nc: Vec<usize>,
    /// Architecture axis.
    pub arch: Vec<CimArch>,
    /// Input exponent-bits axis.
    pub n_e: Vec<f64>,
    /// Input mantissa-bits axis.
    pub n_m: Vec<f64>,
    /// ADC-policy axis, canonical strings (`spec` | `fixed:<bits>`).
    pub adc: Vec<String>,
    /// ADC technology-scale axis (scales the Table III k1/k2 terms via
    /// [`TechParams::with_adc_scale`]).
    pub adc_scale: Vec<f64>,
}

/// One `[axes]` value as a list (scalars promote to one-element lists).
fn axis_values<'a>(t: &'a Table, key: &str) -> Option<Vec<&'a Value>> {
    t.get(key).map(|v| match v {
        Value::Arr(items) => items.iter().collect(),
        scalar => vec![scalar],
    })
}

fn axis_nums(t: &Table, key: &str) -> Result<Option<Vec<f64>>> {
    let Some(vals) = axis_values(t, key) else { return Ok(None) };
    let nums = vals
        .iter()
        .map(|v| v.as_f64().with_context(|| format!("axes.{key}: values must be numbers")))
        .collect::<Result<Vec<_>>>()?;
    if nums.is_empty() {
        bail!("axes.{key}: axis must not be empty");
    }
    Ok(Some(nums))
}

fn axis_strs(t: &Table, key: &str) -> Result<Option<Vec<String>>> {
    let Some(vals) = axis_values(t, key) else { return Ok(None) };
    let strs = vals
        .iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .with_context(|| format!("axes.{key}: values must be strings"))
        })
        .collect::<Result<Vec<_>>>()?;
    if strs.is_empty() {
        bail!("axes.{key}: axis must not be empty");
    }
    Ok(Some(strs))
}

impl ParetoPlan {
    /// Build and validate a plan from raw field values (the shared path
    /// under [`ParetoPlan::from_config`] and [`ParetoPlan::from_json`]).
    #[allow(clippy::too_many_arguments)]
    fn build(
        name: String,
        seed: u64,
        tokens: usize,
        distribution: String,
        workload: Vec<String>,
        nr: Vec<usize>,
        nc: Vec<usize>,
        arch_names: Vec<String>,
        n_e: Vec<f64>,
        n_m: Vec<f64>,
        adc_raw: Vec<String>,
        adc_scale: Vec<f64>,
    ) -> Result<ParetoPlan> {
        if workload.is_empty() {
            bail!("plan '{name}': axes.workload is required and must not be empty");
        }
        for w in &workload {
            parse_shape(w, tokens).with_context(|| format!("plan '{name}'"))?;
        }
        if distribution.starts_with("empirical:") {
            bail!(
                "plan '{name}': empirical distributions are not allowed in explore \
                 plans (the plan must be self-contained for content hashing)"
            );
        }
        crate::cli::sweep::dist_by_name(&distribution, FpFormat::fp(4, 2))
            .with_context(|| format!("plan '{name}'"))?;
        for (&r, &c) in nr.iter().flat_map(|r| nc.iter().map(move |c| (r, c))) {
            crate::cli::sweep::check_tile_geom(&format!("plan '{name}'"), r, c)?;
        }
        let arch = arch_names
            .iter()
            .map(|a| CimArch::parse(a).with_context(|| format!("plan '{name}'")))
            .collect::<Result<Vec<_>>>()?;
        for (&e, &m) in n_e.iter().flat_map(|e| n_m.iter().map(move |m| (e, m))) {
            crate::cli::sweep::check_format_bits(&format!("plan '{name}'"), e, m)?;
        }
        let adc = adc_raw
            .iter()
            .map(|a| parse_adc(a).map(|(_, canon)| canon))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("plan '{name}'"))?;
        for &s in &adc_scale {
            if !s.is_finite() || s <= 0.0 {
                bail!("plan '{name}': adc_scale values must be finite and positive");
            }
        }
        if [arch.len(), adc.len(), adc_scale.len()].contains(&0) {
            bail!("plan '{name}': axes must not be empty");
        }
        let plan = ParetoPlan {
            name,
            seed,
            tokens,
            distribution,
            workload,
            nr,
            nc,
            arch,
            n_e,
            n_m,
            adc,
            adc_scale,
        };
        let n = plan.num_points();
        if n == 0 {
            bail!("plan '{}': the grid is empty", plan.name);
        }
        if n > MAX_PLAN_POINTS {
            bail!(
                "plan '{}': {n} grid points exceed the {MAX_PLAN_POINTS}-point cap",
                plan.name
            );
        }
        plan.check_caps()?;
        Ok(plan)
    }

    /// Parse a plan from its TOML document: root keys `name`, `seed`,
    /// `tokens`, `distribution`, and an `[axes]` section whose values
    /// are scalars or flat arrays (`workload` required; every other
    /// axis has a single-value default).
    pub fn from_config(cfg: &Config) -> Result<ParetoPlan> {
        let name = cfg
            .root
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("explore")
            .to_string();
        let seed = cfg
            .root
            .get("seed")
            .map(|v| v.as_f64().context("seed must be a number"))
            .transpose()?
            .map(|n| n as u64)
            .unwrap_or(DEFAULT_PLAN_SEED);
        let tokens = cfg
            .root
            .get("tokens")
            .map(|v| v.as_usize().context("tokens must be a number"))
            .transpose()?
            .unwrap_or(DEFAULT_PLAN_TOKENS);
        let distribution = cfg
            .root
            .get("distribution")
            .map(|v| v.as_str().context("distribution must be a string").map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "gauss_outliers".to_string());
        let empty = Table::new();
        let axes = cfg.section("axes").unwrap_or(&empty);
        let workload = axis_strs(axes, "workload")?
            .with_context(|| format!("plan '{name}': [axes] needs a workload axis"))?;
        let to_usize = |v: Option<Vec<f64>>| v.map(|ns| ns.iter().map(|&n| n as usize).collect());
        Self::build(
            name,
            seed,
            tokens,
            distribution,
            workload,
            to_usize(axis_nums(axes, "nr")?).unwrap_or_else(|| vec![32]),
            to_usize(axis_nums(axes, "nc")?).unwrap_or_else(|| vec![32]),
            axis_strs(axes, "arch")?.unwrap_or_else(|| vec!["gr-unit".to_string()]),
            axis_nums(axes, "n_e")?.unwrap_or_else(|| vec![4.0]),
            axis_nums(axes, "n_m")?.unwrap_or_else(|| vec![2.0]),
            axis_strs(axes, "adc")?.unwrap_or_else(|| vec!["spec".to_string()]),
            axis_nums(axes, "adc_scale")?.unwrap_or_else(|| vec![1.0]),
        )
    }

    /// Parse plan TOML text directly.
    pub fn from_toml(text: &str) -> Result<ParetoPlan> {
        Self::from_config(&Config::parse(text)?)
    }

    /// The canonical serialization the content hash covers.
    pub fn to_json(&self) -> Json {
        let strs = |v: &[String]| Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect());
        let nums = |v: &[f64]| Json::Arr(v.iter().map(|&n| Json::Num(n)).collect());
        let ints = |v: &[usize]| Json::Arr(v.iter().map(|&n| Json::Num(n as f64)).collect());
        let mut axes = BTreeMap::new();
        axes.insert("workload".to_string(), strs(&self.workload));
        axes.insert("nr".to_string(), ints(&self.nr));
        axes.insert("nc".to_string(), ints(&self.nc));
        axes.insert(
            "arch".to_string(),
            Json::Arr(self.arch.iter().map(|a| Json::Str(a.name().to_string())).collect()),
        );
        axes.insert("n_e".to_string(), nums(&self.n_e));
        axes.insert("n_m".to_string(), nums(&self.n_m));
        axes.insert("adc".to_string(), strs(&self.adc));
        axes.insert("adc_scale".to_string(), nums(&self.adc_scale));
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert("tokens".to_string(), Json::Num(self.tokens as f64));
        m.insert("distribution".to_string(), Json::Str(self.distribution.clone()));
        m.insert("axes".to_string(), Json::Obj(axes));
        Json::Obj(m)
    }

    /// Rebuild (and re-validate) a plan from its canonical JSON — the
    /// checkpoint-header path.
    pub fn from_json(j: &Json) -> Result<ParetoPlan> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("plan json has no name")?
            .to_string();
        let seed = j.get("seed").and_then(Json::as_f64).context("plan json has no seed")? as u64;
        let tokens = j.get("tokens").and_then(Json::as_usize).context("plan json has no tokens")?;
        let distribution = j
            .get("distribution")
            .and_then(Json::as_str)
            .context("plan json has no distribution")?
            .to_string();
        let axes = j.get("axes").context("plan json has no axes")?;
        let strs = |key: &str| -> Result<Vec<String>> {
            axes.get(key)
                .with_context(|| format!("plan json axes has no {key}"))?
                .items()
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .with_context(|| format!("plan json axes.{key}: not a string"))
                })
                .collect()
        };
        let nums = |key: &str| -> Result<Vec<f64>> {
            axes.get(key)
                .with_context(|| format!("plan json axes has no {key}"))?
                .items()
                .iter()
                .map(|v| {
                    v.as_f64().with_context(|| format!("plan json axes.{key}: not a number"))
                })
                .collect()
        };
        Self::build(
            name,
            seed,
            tokens,
            distribution,
            strs("workload")?,
            nums("nr")?.iter().map(|&n| n as usize).collect(),
            nums("nc")?.iter().map(|&n| n as usize).collect(),
            strs("arch")?,
            nums("n_e")?,
            nums("n_m")?,
            strs("adc")?,
            nums("adc_scale")?,
        )
    }

    /// FNV-1a 64 over the canonical serialization — the identity the
    /// checkpoint header and the serve `pareto` cache key carry.
    pub fn content_hash(&self) -> u64 {
        fnv1a64(self.to_json().to_string().as_bytes())
    }

    /// Number of grid points (product of axis lengths).
    pub fn num_points(&self) -> usize {
        self.workload.len()
            * self.nr.len()
            * self.nc.len()
            * self.arch.len()
            * self.n_e.len()
            * self.n_m.len()
            * self.adc.len()
            * self.adc_scale.len()
    }

    /// Decode grid point `index` (lexicographic: workload outermost,
    /// adc_scale innermost).
    pub fn point(&self, index: usize) -> Result<PointSpec> {
        if index >= self.num_points() {
            bail!("point index {index} out of range (plan has {})", self.num_points());
        }
        let mut rest = index;
        let mut take = |len: usize| {
            let stride: usize = rest % len;
            rest /= len;
            stride
        };
        // innermost axis first (division peels from the right)
        let i_scale = take(self.adc_scale.len());
        let i_adc = take(self.adc.len());
        let i_nm = take(self.n_m.len());
        let i_ne = take(self.n_e.len());
        let i_arch = take(self.arch.len());
        let i_nc = take(self.nc.len());
        let i_nr = take(self.nr.len());
        let i_w = take(self.workload.len());
        let adc_str = self.adc[i_adc].clone();
        let (adc, _) = parse_adc(&adc_str)?;
        Ok(PointSpec {
            index,
            workload: self.workload[i_w].clone(),
            nr: self.nr[i_nr],
            nc: self.nc[i_nc],
            arch: self.arch[i_arch],
            n_e: self.n_e[i_ne],
            n_m: self.n_m[i_nm],
            adc,
            adc_str,
            adc_scale: self.adc_scale[i_scale],
        })
    }

    /// Enforce the serve-layer resource caps across the whole grid at
    /// plan time: every workload within the per-request MAC and
    /// operand-slab caps, and the grid's total MACs within the same
    /// budget the `model` request grants a whole network.
    pub fn check_caps(&self) -> Result<()> {
        let mut total_macs = 0u64;
        let points_per_workload = (self.num_points() / self.workload.len()) as u64;
        for w in &self.workload {
            let shape = parse_shape(w, self.tokens)?;
            if shape.macs() > MAX_LAYER_MACS {
                bail!(
                    "plan '{}': workload {w} is too large ({} MACs > {MAX_LAYER_MACS})",
                    self.name,
                    shape.macs()
                );
            }
            let slab = ((shape.m * shape.k) as u64).max((shape.n * shape.k) as u64);
            if slab > MAX_LAYER_ELEMS {
                bail!(
                    "plan '{}': workload {w} needs an operand slab of {slab} elements \
                     (> {MAX_LAYER_ELEMS})",
                    self.name
                );
            }
            total_macs = total_macs.saturating_add(shape.macs().saturating_mul(points_per_workload));
        }
        if total_macs > MAX_LAYER_MACS {
            bail!(
                "plan '{}': the whole grid executes {total_macs} MACs \
                 (> {MAX_LAYER_MACS}); shrink the axes or the workloads",
                self.name
            );
        }
        Ok(())
    }
}

/// One decoded grid point, ready to evaluate.
#[derive(Debug, Clone)]
pub struct PointSpec {
    /// Grid index (lexicographic).
    pub index: usize,
    /// Workload shape string.
    pub workload: String,
    /// Accumulation depth N_R.
    pub nr: usize,
    /// Columns per tile N_C.
    pub nc: usize,
    /// Architecture.
    pub arch: CimArch,
    /// Input exponent bits.
    pub n_e: f64,
    /// Input mantissa bits.
    pub n_m: f64,
    /// Resolved ADC policy.
    pub adc: AdcPolicy,
    /// Canonical policy string (what the record carries).
    pub adc_str: String,
    /// ADC technology scale.
    pub adc_scale: f64,
}

/// One evaluated design point: the configuration echo, the achieved
/// fidelity, the component-level energy breakdown (summing to
/// `total_fj` within 1e-9 relative — the acceptance invariant), and the
/// digital-IMC baseline comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct ExplorePoint {
    /// Grid index in the plan's lexicographic expansion.
    pub index: usize,
    /// Workload shape string.
    pub workload: String,
    /// Resolved GEMM dimensions, `MxKxN`.
    pub shape: String,
    /// Accumulation depth N_R.
    pub nr: usize,
    /// Columns per tile N_C.
    pub nc: usize,
    /// Architecture name.
    pub arch: String,
    /// Input exponent bits.
    pub n_e: f64,
    /// Input mantissa bits.
    pub n_m: f64,
    /// ADC policy, canonical string.
    pub adc: String,
    /// ADC technology scale.
    pub adc_scale: f64,
    /// Mean per-tile ADC resolution, bits.
    pub enob_mean: f64,
    /// Layer-output SQNR vs the exact float GEMM, dB.
    pub sqnr_db: f64,
    /// Column-ADC energy over the layer, fJ.
    pub adc_fj: f64,
    /// Row-DAC energy, fJ.
    pub dac_fj: f64,
    /// Cell-array switching energy, fJ.
    pub cells_fj: f64,
    /// Exponent-logic energy, fJ.
    pub exp_logic_fj: f64,
    /// Column exponent adder-tree energy, fJ.
    pub tree_fj: f64,
    /// Output-normalization multiplier energy, fJ.
    pub norm_mult_fj: f64,
    /// Digital partial-sum reduction energy, fJ.
    pub reduction_fj: f64,
    /// Global-normalization wrapper energy, fJ.
    pub global_norm_fj: f64,
    /// Digital softmax energy, fJ (0 for GEMM/conv workloads).
    pub softmax_fj: f64,
    /// Total layer energy, fJ.
    pub total_fj: f64,
    /// Energy per useful MAC, fJ.
    pub fj_per_mac: f64,
    /// The digital-IMC baseline at matched formats and depth, fJ/MAC.
    pub digital_fj_per_mac: f64,
    /// `fj_per_mac / digital_fj_per_mac` — < 1 means the analog array
    /// beats the digital baseline at this configuration.
    pub digital_ratio: f64,
    /// ADC resolution where this configuration's analog energy crosses
    /// the digital baseline (None when one side wins everywhere in
    /// [0, [`digital::MAX_CROSSOVER_ENOB`]]).
    pub crossover_enob: Option<f64>,
}

impl ExplorePoint {
    /// Sum of every breakdown component, fJ. The acceptance invariant
    /// requires this to match `total_fj` within 1e-9 relative.
    pub fn breakdown_sum(&self) -> f64 {
        self.adc_fj
            + self.dac_fj
            + self.cells_fj
            + self.exp_logic_fj
            + self.tree_fj
            + self.norm_mult_fj
            + self.reduction_fj
            + self.global_norm_fj
            + self.softmax_fj
    }

    /// Whether the breakdown reconciles with the total (1e-9 relative).
    pub fn breakdown_reconciles(&self) -> bool {
        let rel = (self.breakdown_sum() - self.total_fj).abs() / self.total_fj.max(1e-300);
        rel < 1e-9
    }

    /// The objectives the frontier filter sees.
    pub fn objectives(&self) -> Objectives {
        Objectives { energy: self.fj_per_mac, quality: self.sqnr_db }
    }

    /// Canonical record (sorted keys, shortest round-trip floats) — the
    /// checkpoint line format. Does NOT include frontier membership:
    /// that is a property of the point *set*, added at render time.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let mut num = |k: &str, v: f64| m.insert(k.to_string(), Json::Num(v));
        num("index", self.index as f64);
        num("nr", self.nr as f64);
        num("nc", self.nc as f64);
        num("n_e", self.n_e);
        num("n_m", self.n_m);
        num("adc_scale", self.adc_scale);
        num("enob_mean", self.enob_mean);
        num("sqnr_db", self.sqnr_db);
        num("adc_fj", self.adc_fj);
        num("dac_fj", self.dac_fj);
        num("cells_fj", self.cells_fj);
        num("exp_logic_fj", self.exp_logic_fj);
        num("tree_fj", self.tree_fj);
        num("norm_mult_fj", self.norm_mult_fj);
        num("reduction_fj", self.reduction_fj);
        num("global_norm_fj", self.global_norm_fj);
        num("softmax_fj", self.softmax_fj);
        num("total_fj", self.total_fj);
        num("fj_per_mac", self.fj_per_mac);
        num("digital_fj_per_mac", self.digital_fj_per_mac);
        num("digital_ratio", self.digital_ratio);
        m.insert(
            "crossover_enob".to_string(),
            match self.crossover_enob {
                Some(e) => Json::Num(e),
                None => Json::Null,
            },
        );
        m.insert("workload".to_string(), Json::Str(self.workload.clone()));
        m.insert("shape".to_string(), Json::Str(self.shape.clone()));
        m.insert("arch".to_string(), Json::Str(self.arch.clone()));
        m.insert("adc".to_string(), Json::Str(self.adc.clone()));
        Json::Obj(m)
    }

    /// Parse a checkpoint record (ignores any extra keys, e.g. the
    /// `frontier` flag final outputs add).
    pub fn from_json(j: &Json) -> Result<ExplorePoint> {
        let num = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).with_context(|| format!("point has no number {k}"))
        };
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("point has no string {k}"))
        };
        Ok(ExplorePoint {
            index: num("index")? as usize,
            workload: s("workload")?,
            shape: s("shape")?,
            nr: num("nr")? as usize,
            nc: num("nc")? as usize,
            arch: s("arch")?,
            n_e: num("n_e")?,
            n_m: num("n_m")?,
            adc: s("adc")?,
            adc_scale: num("adc_scale")?,
            enob_mean: num("enob_mean")?,
            sqnr_db: num("sqnr_db")?,
            adc_fj: num("adc_fj")?,
            dac_fj: num("dac_fj")?,
            cells_fj: num("cells_fj")?,
            exp_logic_fj: num("exp_logic_fj")?,
            tree_fj: num("tree_fj")?,
            norm_mult_fj: num("norm_mult_fj")?,
            reduction_fj: num("reduction_fj")?,
            global_norm_fj: num("global_norm_fj")?,
            softmax_fj: num("softmax_fj")?,
            total_fj: num("total_fj")?,
            fj_per_mac: num("fj_per_mac")?,
            digital_fj_per_mac: num("digital_fj_per_mac")?,
            digital_ratio: num("digital_ratio")?,
            crossover_enob: match j.get("crossover_enob") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().context("crossover_enob is not a number")?),
            },
        })
    }
}

/// Evaluate grid point `index` of `plan` on `engine`, sequentially (the
/// unit of work one pool worker executes). Deterministic in
/// (plan, engine, index) only.
pub fn eval_point(engine: &dyn Engine, plan: &ParetoPlan, index: usize) -> Result<ExplorePoint> {
    let spec = plan.point(index)?;
    let shape = parse_shape(&spec.workload, plan.tokens)?;
    let fmt_x = FpFormat::fp(spec.n_e as u32, spec.n_m as u32);
    let fmts = FormatPair::new(fmt_x, FpFormat::fp4_e2m1());
    let dist_x = crate::cli::sweep::dist_by_name(&plan.distribution, fmt_x)?;
    let dist_w = crate::distributions::Distribution::max_entropy(FpFormat::fp4_e2m1());
    let cfg = TileConfig {
        nr: spec.nr,
        nc: spec.nc,
        fmts,
        arch: spec.arch,
        adc: spec.adc,
        tech: TechParams::default().with_adc_scale(spec.adc_scale),
    };

    // operand draw order mirrors the layer runner: X (or the conv
    // image, then im2col) first, then the transposed weights
    let mut rng = Pcg64::seeded(job_seed(plan.seed, EXPLORE_STREAM, index as u64));
    let x = if spec.workload.starts_with("conv:") {
        let cs = ConvShape::parse(&spec.workload)?;
        let mut img = vec![0.0f32; cs.img_elems()];
        dist_x.fill_f32(&mut rng, &mut img);
        im2col(&img, &cs)
    } else {
        let mut x = vec![0.0f32; shape.m * shape.k];
        dist_x.fill_f32(&mut rng, &mut x);
        x
    };
    let mut wt = vec![0.0f32; shape.n * shape.k];
    dist_w.fill_f32(&mut rng, &mut wt);

    let label = format!("p{index}");
    let res = gemm_with_engine(engine, &label, &cfg, shape, &x, &wt)?;
    let report = &res.report;
    let comps = report.component_totals();
    let by = |name: &str| -> Result<f64> {
        comps
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
            .ok_or_else(|| anyhow!("tile report is missing the '{name}' energy component"))
    };
    let digital_fj_per_mac = digital::digital_mac_fj(&cfg.tech, &fmts, spec.nr);
    Ok(ExplorePoint {
        index,
        workload: spec.workload.clone(),
        shape: shape.to_string(),
        nr: spec.nr,
        nc: spec.nc,
        arch: spec.arch.name().to_string(),
        n_e: spec.n_e,
        n_m: spec.n_m,
        adc: spec.adc_str.clone(),
        adc_scale: spec.adc_scale,
        enob_mean: report.enob_mean(),
        sqnr_db: report.sqnr_db,
        adc_fj: by("adc")?,
        dac_fj: by("dac")?,
        cells_fj: by("cells")?,
        exp_logic_fj: by("exp_logic")?,
        tree_fj: by("tree")?,
        norm_mult_fj: by("norm_mult")?,
        reduction_fj: report.reduction_fj,
        global_norm_fj: report.global_norm_fj,
        softmax_fj: report.softmax_fj,
        total_fj: report.total_fj(),
        fj_per_mac: report.fj_per_mac(),
        digital_fj_per_mac,
        digital_ratio: report.fj_per_mac() / digital_fj_per_mac,
        crossover_enob: digital::crossover_enob(spec.arch, fmts, spec.nr, spec.nc, &cfg.tech),
    })
}

/// A completed exploration: every point (ascending index) plus the
/// index-aligned frontier mask.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    /// The plan that ran.
    pub plan: ParetoPlan,
    /// Every evaluated point, ascending index.
    pub points: Vec<ExplorePoint>,
    /// Frontier membership, index-aligned with `points`.
    pub frontier: Vec<bool>,
}

impl ExploreOutcome {
    /// Recompute the frontier mask over a full point set.
    fn assemble(plan: ParetoPlan, mut points: Vec<ExplorePoint>) -> ExploreOutcome {
        points.sort_by_key(|p| p.index);
        let objs: Vec<Objectives> = points.iter().map(ExplorePoint::objectives).collect();
        let frontier = frontier_mask(&objs);
        ExploreOutcome { plan, points, frontier }
    }

    /// The non-dominated points.
    pub fn frontier_points(&self) -> Vec<&ExplorePoint> {
        self.points
            .iter()
            .zip(&self.frontier)
            .filter_map(|(p, &keep)| keep.then_some(p))
            .collect()
    }

    /// The final campaign output: the checkpoint header line followed
    /// by every point record (ascending index) with its `frontier`
    /// flag. Bit-identical for any worker count and any resume split.
    pub fn out_jsonl(&self, engine: &str) -> String {
        let mut out = checkpoint::header_json(&self.plan, engine).to_string();
        out.push('\n');
        for (p, &front) in self.points.iter().zip(&self.frontier) {
            let mut j = match p.to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("point records are objects"),
            };
            j.insert("frontier".to_string(), Json::Bool(front));
            out.push_str(&Json::Obj(j).to_string());
            out.push('\n');
        }
        out
    }
}

/// Run (or finish) a plan across the coordinator worker pool. `done`
/// holds already-completed points (from a resumed [`Checkpoint`]) that
/// are adopted verbatim — only the remainder is sharded. Each completed
/// point is appended to `writer` (when given) before the pool returns,
/// so a kill loses at most the in-flight points.
pub fn run_plan(
    plan: &ParetoPlan,
    campaign: &CampaignConfig,
    writer: Option<CkptWriter>,
    done: BTreeMap<usize, ExplorePoint>,
) -> Result<ExploreOutcome> {
    let total = plan.num_points();
    for (&idx, _) in &done {
        if idx >= total {
            bail!("completed point index {idx} out of range (plan has {total})");
        }
    }
    let pending: Vec<usize> = (0..total).filter(|i| !done.contains_key(i)).collect();
    let mut points: Vec<ExplorePoint> = done.into_values().collect();
    if !pending.is_empty() {
        let plan_w = Arc::new(plan.clone());
        let engine_kind = campaign.engine;
        let artifacts = campaign.artifacts_dir.clone();
        let fresh = pool::run_jobs(pending, campaign.effective_workers(), move || {
            let engine = build_engine(engine_kind, &artifacts)?;
            let plan = Arc::clone(&plan_w);
            let writer = writer.clone();
            Ok(move |idx: usize| -> Result<ExplorePoint> {
                let point = eval_point(engine.as_ref(), &plan, idx)?;
                if let Some(w) = &writer {
                    w.append(&point)?;
                }
                Ok(point)
            })
        })?;
        points.extend(fresh);
    }
    if points.len() != total {
        bail!("explore produced {} of {total} points", points.len());
    }
    Ok(ExploreOutcome::assemble(plan.clone(), points))
}

/// Run a plan with no checkpoint file (the serve `pareto` path).
pub fn run_fresh(plan: &ParetoPlan, campaign: &CampaignConfig) -> Result<ExploreOutcome> {
    run_plan(plan, campaign, None, BTreeMap::new())
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::runtime::{EngineKind, RustEngine};

    pub(crate) fn tiny_plan() -> ParetoPlan {
        ParetoPlan::from_toml(
            r#"
name = "tiny"
seed = 7
tokens = 2

[axes]
workload = "gemm:2x8x4"
nr = [4, 8]
nc = 2
arch = ["gr-unit", "conventional"]
n_e = 2
n_m = 2
"#,
        )
        .unwrap()
    }

    fn campaign(workers: usize) -> CampaignConfig {
        CampaignConfig { engine: EngineKind::Rust, workers, seed: 7, ..Default::default() }
    }

    #[test]
    fn plan_parses_with_defaults_and_expands_lexicographically() {
        let p = tiny_plan();
        assert_eq!(p.num_points(), 4);
        assert_eq!(p.distribution, "gauss_outliers");
        assert_eq!(p.adc, vec!["spec".to_string()]);
        assert_eq!(p.adc_scale, vec![1.0]);
        // workload → nr → nc → arch: arch is the innermost varying axis
        let p0 = p.point(0).unwrap();
        let p1 = p.point(1).unwrap();
        let p2 = p.point(2).unwrap();
        assert_eq!((p0.nr, p0.arch), (4, CimArch::GrUnit));
        assert_eq!((p1.nr, p1.arch), (4, CimArch::Conventional));
        assert_eq!((p2.nr, p2.arch), (8, CimArch::GrUnit));
        assert!(p.point(4).is_err());
    }

    #[test]
    fn canonical_hash_survives_json_round_trip_and_spelling() {
        let p = tiny_plan();
        let again = ParetoPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(again.content_hash(), p.content_hash());
        assert_eq!(again.to_json().to_string(), p.to_json().to_string());
        // alias arch spellings canonicalize to the same hash
        let aliased = ParetoPlan::from_toml(
            r#"
name = "tiny"
seed = 7
tokens = 2

[axes]
workload = ["gemm:2x8x4"]
nr = [4, 8]
nc = [2]
arch = ["gr", "conv"]
n_e = [2]
n_m = [2]
adc = ["spec"]
adc_scale = [1.0]
"#,
        )
        .unwrap();
        assert_eq!(aliased.content_hash(), p.content_hash());
    }

    #[test]
    fn invalid_plans_are_clean_errors() {
        for (label, toml) in [
            ("no workload", "name = \"x\"\n[axes]\nnr = 8\n"),
            ("empty axis", "[axes]\nworkload = \"gemm:2x8x4\"\nnr = []\n"),
            ("bad arch", "[axes]\nworkload = \"gemm:2x8x4\"\narch = \"analog\"\n"),
            ("bad adc", "[axes]\nworkload = \"gemm:2x8x4\"\nadc = \"fixed\"\n"),
            ("bad adc bits", "[axes]\nworkload = \"gemm:2x8x4\"\nadc = \"fixed:0\"\n"),
            ("bad scale", "[axes]\nworkload = \"gemm:2x8x4\"\nadc_scale = -1\n"),
            ("bad shape", "[axes]\nworkload = \"gemm:2x8\"\n"),
            ("zero geom", "[axes]\nworkload = \"gemm:2x8x4\"\nnr = 0\n"),
            (
                "empirical",
                "distribution = \"empirical:/tmp/x\"\n[axes]\nworkload = \"gemm:2x8x4\"\n",
            ),
        ] {
            assert!(ParetoPlan::from_toml(toml).is_err(), "{label}");
        }
        // the point cap: 17^3 > 4096
        let axis: Vec<String> = (1..=17).map(|n| n.to_string()).collect();
        let toml = format!(
            "[axes]\nworkload = \"gemm:2x8x4\"\nnr = [{a}]\nnc = [{a}]\nn_m = [{b}]\n",
            a = axis.join(", "),
            b = (0..17).map(|n| n.to_string()).collect::<Vec<_>>().join(", "),
        );
        let err = ParetoPlan::from_toml(&toml).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn adc_policy_axis_round_trips() {
        let (policy, canon) = parse_adc("fixed:6.5").unwrap();
        assert_eq!(policy, AdcPolicy::Fixed(6.5));
        assert_eq!(canon, "fixed:6.5");
        let (policy, canon) = parse_adc("fixed:8").unwrap();
        assert_eq!(policy, AdcPolicy::Fixed(8.0));
        assert_eq!(canon, "fixed:8");
        assert!(parse_adc("fixed:33").is_err());
        assert!(parse_adc("auto").is_err());
    }

    #[test]
    fn grid_caps_are_enforced_at_plan_time() {
        // one huge workload trips the per-point cap
        let toml = "tokens = 2\n[axes]\nworkload = \"gemm:1048576x1048576x1\"\n";
        let err = ParetoPlan::from_toml(toml).unwrap_err().to_string();
        assert!(err.contains("MACs") || err.contains("slab"), "{err}");
    }

    #[test]
    fn point_record_round_trips_bit_exactly() {
        let p = tiny_plan();
        let pt = eval_point(&RustEngine, &p, 1).unwrap();
        let line = pt.to_json().to_string();
        let back = ExplorePoint::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), line);
        assert_eq!(back.sqnr_db.to_bits(), pt.sqnr_db.to_bits());
        assert_eq!(back.total_fj.to_bits(), pt.total_fj.to_bits());
    }

    #[test]
    fn breakdown_sums_to_total_within_1e_minus_9() {
        let p = tiny_plan();
        for idx in 0..p.num_points() {
            let pt = eval_point(&RustEngine, &p, idx).unwrap();
            assert!(
                pt.breakdown_reconciles(),
                "point {idx}: breakdown {} vs total {}",
                pt.breakdown_sum(),
                pt.total_fj
            );
            assert!(pt.fj_per_mac > 0.0 && pt.sqnr_db.is_finite());
            assert!(pt.digital_fj_per_mac > 0.0);
        }
    }

    #[test]
    fn run_plan_is_bit_identical_across_worker_counts() {
        let p = tiny_plan();
        let a = run_fresh(&p, &campaign(1)).unwrap();
        let b = run_fresh(&p, &campaign(3)).unwrap();
        assert_eq!(a.points.len(), p.num_points());
        assert_eq!(a.frontier, b.frontier);
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.to_json().to_string(), y.to_json().to_string());
        }
        assert_eq!(a.out_jsonl("rust"), b.out_jsonl("rust"));
    }

    #[test]
    fn resume_split_reproduces_the_uninterrupted_point_set() {
        let p = tiny_plan();
        let full = run_fresh(&p, &campaign(2)).unwrap();
        // adopt half the points as "already checkpointed" and run the rest
        let done: BTreeMap<usize, ExplorePoint> = full
            .points
            .iter()
            .filter(|pt| pt.index % 2 == 0)
            .map(|pt| (pt.index, pt.clone()))
            .collect();
        let resumed = run_plan(&p, &campaign(2), None, done).unwrap();
        assert_eq!(resumed.out_jsonl("rust"), full.out_jsonl("rust"));
    }

    #[test]
    fn frontier_flags_mark_non_dominated_points() {
        let p = tiny_plan();
        let out = run_fresh(&p, &campaign(2)).unwrap();
        assert!(!out.frontier_points().is_empty());
        // recompute independently
        let objs: Vec<Objectives> = out.points.iter().map(ExplorePoint::objectives).collect();
        assert_eq!(frontier_mask(&objs), out.frontier);
    }
}
