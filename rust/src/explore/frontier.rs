//! Pareto-frontier extraction: the dominated-point filter over the
//! explorer's (energy, quality) plane.
//!
//! A design point is on the frontier iff no other point is at least as
//! good on **both** objectives and strictly better on one — lower
//! fJ/MAC at no SQNR loss, or higher SQNR at no energy cost. Duplicate
//! objective pairs are all kept (neither strictly dominates the other),
//! so frontier membership is a pure function of the objective values and
//! resume/reshard cannot change it.

/// One candidate in objective space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    /// Minimized — the explorer uses fJ/MAC.
    pub energy: f64,
    /// Maximized — the explorer uses the achieved SQNR, dB.
    pub quality: f64,
}

impl Objectives {
    /// True when `self` dominates `other`: at least as good on both
    /// axes, strictly better on one. NaN comparisons are all false, so
    /// a NaN-valued point neither dominates nor is dominated (it cannot
    /// evict real points); the explorer only produces finite objectives.
    pub fn dominates(&self, other: &Objectives) -> bool {
        let no_worse = self.energy <= other.energy && self.quality >= other.quality;
        let better = self.energy < other.energy || self.quality > other.quality;
        no_worse && better
    }
}

/// Frontier membership flags, index-aligned with `points`. O(n²) — the
/// plan-point cap bounds `n` far below where that matters.
pub fn frontier_mask(points: &[Objectives]) -> Vec<bool> {
    points
        .iter()
        .map(|p| !points.iter().any(|q| q.dominates(p)))
        .collect()
}

/// Indices of the non-dominated points, ascending.
pub fn frontier_indices(points: &[Objectives]) -> Vec<usize> {
    frontier_mask(points)
        .iter()
        .enumerate()
        .filter_map(|(i, &keep)| keep.then_some(i))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn o(energy: f64, quality: f64) -> Objectives {
        Objectives { energy, quality }
    }

    #[test]
    fn cheaper_and_better_dominates() {
        assert!(o(1.0, 40.0).dominates(&o(2.0, 35.0)));
        assert!(!o(2.0, 35.0).dominates(&o(1.0, 40.0)));
    }

    #[test]
    fn trade_offs_do_not_dominate_each_other() {
        // cheaper-but-worse vs pricier-but-better: both survive
        let pts = [o(1.0, 30.0), o(2.0, 40.0)];
        assert_eq!(frontier_mask(&pts), vec![true, true]);
    }

    #[test]
    fn equal_points_are_both_kept() {
        let pts = [o(1.0, 35.0), o(1.0, 35.0)];
        assert!(!pts[0].dominates(&pts[1]));
        assert_eq!(frontier_mask(&pts), vec![true, true]);
    }

    #[test]
    fn interior_points_are_filtered() {
        let pts = [o(1.0, 30.0), o(2.0, 40.0), o(1.5, 29.0), o(3.0, 39.0)];
        assert_eq!(frontier_indices(&pts), vec![0, 1]);
    }

    #[test]
    fn single_axis_improvements_dominate() {
        assert!(o(1.0, 35.0).dominates(&o(1.0, 30.0)));
        assert!(o(1.0, 35.0).dominates(&o(2.0, 35.0)));
    }

    #[test]
    fn nan_quality_never_evicts_real_points() {
        let pts = [o(1.0, f64::NAN), o(2.0, 35.0)];
        // the NaN point dominates nothing; the finite point survives
        assert!(!pts[0].dominates(&pts[1]));
        let mask = frontier_mask(&pts);
        assert!(mask[1]);
    }
}
