//! Pure-Rust signal-chain engine — the semantic twin of the L1 Pallas
//! kernel (`python/compile/kernels/grmac.py`), in f64.
//!
//! Serves three roles:
//! 1. **Oracle** for the PJRT artifact (cross-checked in
//!    `rust/tests/runtime_crosscheck.rs`);
//! 2. **Fallback backend** for the coordinator when artifacts are absent or
//!    a non-artifact array depth is requested;
//! 3. **Trace source** for the Fig. 4 distribution panels (per-cell
//!    intermediates that the statistics artifact intentionally reduces
//!    away).

pub mod trace;

use crate::formats::{exp2, FpFormat};
use crate::stats::ColumnBatch;

/// Formats of one experiment: input (activation) and weight.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FormatPair {
    /// Input (activation) format.
    pub x: FpFormat,
    /// Weight format.
    pub w: FpFormat,
}

impl FormatPair {
    /// Pair an input format with a weight format.
    pub fn new(x: FpFormat, w: FpFormat) -> Self {
        FormatPair { x, w }
    }

    /// The artifact's runtime format vector [e_max_x, n_m_x, e_max_w, n_m_w].
    pub fn to_vec4(&self) -> [f32; 4] {
        [
            self.x.e_max as f32,
            self.x.n_m as f32,
            self.w.e_max as f32,
            self.w.n_m as f32,
        ]
    }
}

/// Simulate a batch of column MACs. `x` and `w` are row-major `[b][nr]`
/// raw (pre-quantization) values; returns the ten per-sample statistics in
/// the artifact's layout (see `kernels/ref.py` for definitions).
///
/// Allocates a fresh [`ColumnBatch`] per call; hot loops should hold one
/// batch and call [`simulate_column_into`] instead.
pub fn simulate_column(x: &[f64], w: &[f64], nr: usize, fmts: FormatPair) -> ColumnBatch {
    let mut out = ColumnBatch::empty(nr);
    simulate_column_into(x, w, nr, fmts, &mut out);
    out
}

/// Per-sample accumulator of the fused signal-chain pass. One instance
/// carries every running statistic of one MC trial; the lane-batched
/// driver below keeps [`MAC_LANES`] of them live at once.
#[derive(Clone, Copy)]
struct SampleAcc {
    z_ideal: f64,
    z_q: f64,
    ebx: f64,
    ebw: f64,
    v_gr_num: f64,
    s_sum: f64,
    s2_sum: f64,
    sx_sum: f64,
    nf: f64,
    wq2: f64,
}

impl SampleAcc {
    const ZERO: SampleAcc = SampleAcc {
        z_ideal: 0.0,
        z_q: 0.0,
        ebx: 1.0,
        ebw: 1.0,
        v_gr_num: 0.0,
        s_sum: 0.0,
        s2_sum: 0.0,
        sx_sum: 0.0,
        nf: 0.0,
        wq2: 0.0,
    };

    /// Fuse one (x, w) element pair into the running statistics (§Perf
    /// iteration 1): `quantize_parts` folds quantize + decompose into one
    /// log2; the per-value scale factors 2^(E - e_max) are computed once
    /// and reused by the GR weight, the row factor, and the ulp floor.
    #[inline(always)]
    fn update(&mut self, xi: f64, wi: f64, fx: FpFormat, fw: FpFormat, stx: f64) {
        self.z_ideal += xi * wi;
        let (xq, mxi, exi) = fx.quantize_parts(xi);
        let (wq, mwi, ewi) = fw.quantize_parts(wi);
        self.z_q += xq * wq;
        self.ebx = self.ebx.max(exi);
        self.ebw = self.ebw.max(ewi);
        // per-value binade scales, shared by every statistic below
        let ux = exp2(exi - fx.e_max);
        let uw = exp2(ewi - fw.e_max);
        let u = ux * uw;
        self.s_sum += u;
        self.s2_sum += u * u;
        self.v_gr_num += mxi * mwi * u;
        self.sx_sum += ux;
        // ulp-based *input* noise floor (input-side only: the ADC spec
        // protects the input format's fidelity; weight quantization is
        // part of the model, not noise — paper Fig. 10 caption)
        let dx = stx * ux;
        self.nf += wq * wq * dx * dx;
        self.wq2 += wq * wq;
    }

    /// Finalize one trial: the conventional compute-line voltage is
    /// reconstructed exactly from the linear-chain identity
    /// v_conv = z_q / g_conv (power-of-two scaling is lossless), removing
    /// any second (alignment) pass entirely.
    #[inline(always)]
    fn push(self, nr: usize, fx: FpFormat, fw: FpFormat, out: &mut ColumnBatch) {
        let z_ideal = self.z_ideal / nr as f64;
        let z_q = self.z_q / nr as f64;
        let nf = self.nf / (12.0 * (nr * nr) as f64);
        let g_w = exp2(self.ebw - fw.e_max);
        let g_conv = exp2(self.ebx - fx.e_max) * g_w;
        let v_conv = z_q / g_conv;

        out.z_ideal.push(z_ideal);
        out.z_q.push(z_q);
        out.v_conv.push(v_conv);
        out.g_conv.push(g_conv);
        out.v_gr.push(self.v_gr_num / self.s_sum);
        out.s_sum.push(self.s_sum);
        out.s2_sum.push(self.s2_sum);
        out.sx_sum.push(self.sx_sum);
        out.g_w.push(g_w);
        out.nf.push(nf);
        out.wq2_mean.push(self.wq2 / nr as f64);
    }
}

/// Lane width of the batched MC driver: enough independent accumulator
/// chains to hide the per-sample serial-add latency without spilling the
/// whole accumulator set out of registers.
const MAC_LANES: usize = 4;

/// Allocation-free form of [`simulate_column`]: resets `out` (keeping its
/// vector capacities) and fills it with the batch's per-sample statistics.
/// After the first call at a given batch size, subsequent calls perform no
/// heap allocation — the coordinator's chunked job path reuses one batch
/// per worker (see `coordinator::JobBuffers`).
///
/// The driver runs [`MAC_LANES`] MC trials abreast (§Perf iteration 2):
/// the element loop advances all lanes together, so the per-trial
/// accumulation chains — the only loop-carried dependencies — interleave
/// and the pure-arithmetic tail of [`SampleAcc::update`] vectorizes.
/// Per-trial operation order is exactly the scalar order, so results are
/// bit-identical to the historical per-sample loop (pinned by
/// `lane_batched_path_matches_scalar_reference` below).
pub fn simulate_column_into(
    x: &[f64],
    w: &[f64],
    nr: usize,
    fmts: FormatPair,
    out: &mut ColumnBatch,
) {
    assert_eq!(x.len(), w.len());
    assert!(nr > 0 && x.len() % nr == 0);
    let b = x.len() / nr;
    let fx = fmts.x;
    let fw = fmts.w;
    let stx = fx.step();

    out.reset(nr);
    out.reserve(b);

    let full = (b / MAC_LANES) * MAC_LANES;
    let mut s = 0;
    while s < full {
        let xs = &x[s * nr..(s + MAC_LANES) * nr];
        let ws = &w[s * nr..(s + MAC_LANES) * nr];
        let mut acc = [SampleAcc::ZERO; MAC_LANES];
        for i in 0..nr {
            for (l, a) in acc.iter_mut().enumerate() {
                a.update(xs[l * nr + i], ws[l * nr + i], fx, fw, stx);
            }
        }
        for a in acc {
            a.push(nr, fx, fw, out);
        }
        s += MAC_LANES;
    }
    for t in full..b {
        let xs = &x[t * nr..(t + 1) * nr];
        let ws = &w[t * nr..(t + 1) * nr];
        let mut a = SampleAcc::ZERO;
        for i in 0..nr {
            a.update(xs[i], ws[i], fx, fw, stx);
        }
        a.push(nr, fx, fw, out);
    }
}

/// Apply an ideal mid-rise ADC of the given ENOB over full scale [-1, 1]
/// to a voltage (the digital post-normalization is the caller's job).
pub fn adc_quantize(v: f64, enob: f64) -> f64 {
    let delta = 2.0 / exp2(enob);
    let q = ((v / delta + 0.5).floor()) * delta;
    q.clamp(-1.0, 1.0)
}

/// In-place slice form of [`adc_quantize`]: the step is computed once and
/// the loop body is branch-free arithmetic, so it vectorizes. Bit-exact
/// with the scalar call per element (`exp2` is pure).
pub fn adc_quantize_slice(vs: &mut [f64], enob: f64) {
    let delta = 2.0 / exp2(enob);
    for v in vs {
        *v = (((*v / delta + 0.5).floor()) * delta).clamp(-1.0, 1.0);
    }
}

/// Reconstruct the final dot-product outputs of each architecture after an
/// ADC of `enob` bits, from a simulated batch. Returns (conventional, GR).
pub fn apply_adc(b: &ColumnBatch, enob: f64) -> (Vec<f64>, Vec<f64>) {
    let nr = b.nr as f64;
    let mut conv: Vec<f64> = b.v_conv.clone();
    adc_quantize_slice(&mut conv, enob);
    for (c, &g) in conv.iter_mut().zip(&b.g_conv) {
        *c *= g;
    }
    let mut gr: Vec<f64> = b.v_gr.clone();
    adc_quantize_slice(&mut gr, enob);
    for (o, &s) in gr.iter_mut().zip(&b.s_sum) {
        *o = *o * s / nr;
    }
    (conv, gr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::Distribution;
    use crate::rng::Pcg64;
    use crate::util::approx_eq;

    fn rand_case(seed: u64, b: usize, nr: usize) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let mut x = vec![0.0; b * nr];
        let mut w = vec![0.0; b * nr];
        Distribution::Uniform.fill(&mut rng, &mut x);
        Distribution::clipped_gauss4().fill(&mut rng, &mut w);
        (x, w)
    }

    fn fp63() -> FormatPair {
        FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1())
    }

    #[test]
    fn linear_chain_identity() {
        // z_q == v_conv * g_conv == v_gr * S / NR for every sample
        let (x, w) = rand_case(1, 64, 32);
        let b = simulate_column(&x, &w, 32, fp63());
        for i in 0..b.len() {
            assert!(
                approx_eq(b.z_q[i], b.v_conv[i] * b.g_conv[i], 1e-10),
                "conv sample {i}"
            );
            assert!(
                approx_eq(b.z_q[i], b.v_gr[i] * b.s_sum[i] / 32.0, 1e-10),
                "gr sample {i}"
            );
        }
    }

    #[test]
    fn adc_inputs_within_full_scale() {
        let (x, w) = rand_case(2, 128, 32);
        let b = simulate_column(&x, &w, 32, fp63());
        for i in 0..b.len() {
            assert!(b.v_conv[i].abs() <= 1.0 + 1e-12);
            assert!(b.v_gr[i].abs() <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn neff_bounds() {
        let (x, w) = rand_case(3, 128, 32);
        let b = simulate_column(&x, &w, 32, fp63());
        for i in 0..b.len() {
            let neff = b.s_sum[i] * b.s_sum[i] / b.s2_sum[i];
            assert!(neff >= 1.0 - 1e-12 && neff <= 32.0 + 1e-9);
        }
    }

    #[test]
    fn equal_exponents_give_neff_equal_nr() {
        let nr = 16;
        let x = vec![0.6; nr]; // e = e_max for all
        let w = vec![0.55; nr];
        let b = simulate_column(&x, &w, nr, fp63());
        let neff = b.s_sum[0] * b.s_sum[0] / b.s2_sum[0];
        assert!(approx_eq(neff, nr as f64, 1e-12));
        // and no shrinkage benefit: S/NR = 1 exactly
        assert!(approx_eq(b.s_sum[0] / nr as f64, 1.0, 1e-12));
    }

    #[test]
    fn int_formats_have_unity_referral() {
        // INT x INT: every exponent is 1 == e_max -> g_conv = 1, u = 1
        let fmts = FormatPair::new(FpFormat::int(4), FpFormat::int(4));
        let (x, w) = rand_case(4, 32, 8);
        let b = simulate_column(&x, &w, 8, fmts);
        for i in 0..b.len() {
            assert_eq!(b.g_conv[i], 1.0);
            assert_eq!(b.s_sum[i], 8.0);
            assert!(approx_eq(b.v_conv[i], b.z_q[i], 1e-12));
        }
    }

    #[test]
    fn zero_inputs() {
        let b = simulate_column(&[0.0; 32], &[0.0; 32], 32, fp63());
        assert_eq!(b.z_q[0], 0.0);
        assert_eq!(b.v_gr[0], 0.0);
        assert!(b.s_sum[0] > 0.0); // zero cells still couple
    }

    #[test]
    fn gr_signal_power_exceeds_conventional_for_spread_data() {
        let mut rng = Pcg64::seeded(9);
        let nr = 32;
        let bsz = 2048;
        let mut x = vec![0.0; bsz * nr];
        let mut w = vec![0.0; bsz * nr];
        Distribution::clipped_gauss4().fill(&mut rng, &mut x);
        Distribution::clipped_gauss4().fill(&mut rng, &mut w);
        let b = simulate_column(&x, &w, nr, fp63());
        let p_gr: f64 =
            b.v_gr.iter().map(|v| v * v).sum::<f64>() / bsz as f64;
        let p_conv: f64 =
            b.v_conv.iter().map(|v| v * v).sum::<f64>() / bsz as f64;
        assert!(p_gr > 3.0 * p_conv, "gr={p_gr} conv={p_conv}");
    }

    #[test]
    fn quantization_error_matches_noise_floor_order() {
        let (x, w) = rand_case(11, 4096, 32);
        let b = simulate_column(&x, &w, 32, fp63());
        let emp: f64 = b
            .z_q
            .iter()
            .zip(&b.z_ideal)
            .map(|(q, i)| (q - i) * (q - i))
            .sum::<f64>()
            / b.len() as f64;
        let floor: f64 = b.nf.iter().sum::<f64>() / b.len() as f64;
        // floor is input-side only; empirical error also carries weight
        // quantization noise (coarse FP4 weights), so the ratio sits above 1
        let ratio = emp / floor;
        assert!(ratio > 0.2 && ratio < 40.0, "ratio={ratio}");
    }

    #[test]
    fn adc_quantize_basics() {
        // 1-bit ADC over [-1,1]: step 1.0, levels {-1, 0, 1}
        assert_eq!(adc_quantize(0.3, 1.0), 0.0);
        assert_eq!(adc_quantize(0.6, 1.0), 1.0);
        assert_eq!(adc_quantize(-0.6, 1.0), -1.0);
        // high-res ADC is nearly transparent
        let v = 0.123456;
        assert!((adc_quantize(v, 20.0) - v).abs() < 2e-6);
    }

    #[test]
    fn apply_adc_converges_to_zq_with_resolution() {
        let (x, w) = rand_case(13, 256, 32);
        let b = simulate_column(&x, &w, 32, fp63());
        let (conv, gr) = apply_adc(&b, 24.0);
        for i in 0..b.len() {
            assert!(approx_eq(conv[i], b.z_q[i], 1e-4));
            assert!(approx_eq(gr[i], b.z_q[i], 1e-4));
        }
        // and a coarse ADC hurts the conventional path more (shrinkage)
        let (conv4, gr4) = apply_adc(&b, 6.0);
        let err = |o: &[f64]| -> f64 {
            o.iter()
                .zip(&b.z_q)
                .map(|(a, q)| (a - q) * (a - q))
                .sum::<f64>()
        };
        assert!(err(&conv4) > err(&gr4));
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_input() {
        simulate_column(&[0.0; 33], &[0.0; 33], 32, fp63());
    }

    #[test]
    fn lane_batched_path_matches_scalar_reference() {
        // pin the tentpole's bit-compat contract: the MAC_LANES-wide
        // driver must equal a straight per-sample evaluation for batch
        // sizes around the lane width (remainder 0..LANES-1)
        for b in [1usize, 2, 3, 4, 5, 7, 8, 9, 31] {
            let (x, w) = rand_case(0xAB + b as u64, b, 16);
            let batched = simulate_column(&x, &w, 16, fp63());
            // scalar reference: one sample at a time (always the
            // remainder path)
            let mut scalar = crate::stats::ColumnBatch::empty(16);
            for s in 0..b {
                let one = simulate_column(
                    &x[s * 16..(s + 1) * 16],
                    &w[s * 16..(s + 1) * 16],
                    16,
                    fp63(),
                );
                scalar.z_ideal.extend_from_slice(&one.z_ideal);
                scalar.z_q.extend_from_slice(&one.z_q);
                scalar.v_conv.extend_from_slice(&one.v_conv);
                scalar.g_conv.extend_from_slice(&one.g_conv);
                scalar.v_gr.extend_from_slice(&one.v_gr);
                scalar.s_sum.extend_from_slice(&one.s_sum);
                scalar.s2_sum.extend_from_slice(&one.s2_sum);
                scalar.sx_sum.extend_from_slice(&one.sx_sum);
                scalar.g_w.extend_from_slice(&one.g_w);
                scalar.nf.extend_from_slice(&one.nf);
                scalar.wq2_mean.extend_from_slice(&one.wq2_mean);
            }
            for i in 0..b {
                assert_eq!(
                    batched.z_q[i].to_bits(),
                    scalar.z_q[i].to_bits(),
                    "b={b} i={i}"
                );
                assert_eq!(
                    batched.nf[i].to_bits(),
                    scalar.nf[i].to_bits(),
                    "b={b} i={i}"
                );
                assert_eq!(
                    batched.v_gr[i].to_bits(),
                    scalar.v_gr[i].to_bits(),
                    "b={b} i={i}"
                );
                assert_eq!(
                    batched.s2_sum[i].to_bits(),
                    scalar.s2_sum[i].to_bits(),
                    "b={b} i={i}"
                );
            }
        }
    }

    #[test]
    fn adc_quantize_slice_matches_scalar() {
        let (x, _) = rand_case(0x51, 8, 32);
        for enob in [1.0, 3.5, 7.0, 12.25] {
            let mut vs = x.clone();
            adc_quantize_slice(&mut vs, enob);
            for (q, &v) in vs.iter().zip(&x) {
                assert_eq!(q.to_bits(), adc_quantize(v, enob).to_bits());
            }
        }
    }

    #[test]
    fn simulate_into_reused_batch_matches_fresh_batch() {
        let (x1, w1) = rand_case(21, 96, 32);
        let (x2, w2) = rand_case(22, 16, 8);
        let mut reused = crate::stats::ColumnBatch::empty(32);
        // first fill at one shape, then reuse at another: results must be
        // bit-identical to fresh simulate_column calls
        simulate_column_into(&x1, &w1, 32, fp63(), &mut reused);
        let fresh1 = simulate_column(&x1, &w1, 32, fp63());
        assert_eq!(reused.len(), fresh1.len());
        for i in 0..fresh1.len() {
            assert_eq!(reused.z_q[i].to_bits(), fresh1.z_q[i].to_bits());
            assert_eq!(reused.nf[i].to_bits(), fresh1.nf[i].to_bits());
        }
        let fmts = FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1());
        simulate_column_into(&x2, &w2, 8, fmts, &mut reused);
        let fresh2 = simulate_column(&x2, &w2, 8, fmts);
        assert_eq!(reused.nr, 8);
        assert_eq!(reused.len(), fresh2.len());
        for i in 0..fresh2.len() {
            assert_eq!(reused.v_gr[i].to_bits(), fresh2.v_gr[i].to_bits());
            assert_eq!(
                reused.s_sum[i].to_bits(),
                fresh2.s_sum[i].to_bits()
            );
        }
    }
}
