//! Per-cell intermediate traces of both signal chains — the data behind the
//! Fig. 4 distribution panels (A1..A3, B1..B3), which the statistics
//! artifact intentionally reduces away.

use super::FormatPair;
use crate::formats::exp2;

/// Intermediates of one Monte-Carlo run.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// A1: aligned integer inputs x_int (conventional path, per cell).
    pub a1_x_int: Vec<f64>,
    /// A2: aligned products x_int * w_int (per cell).
    pub a2_products: Vec<f64>,
    /// A3: conventional compute-line voltages (per column sample).
    pub a3_v_conv: Vec<f64>,
    /// B1: signed normalized mantissas M_x (GR path, per cell).
    pub b1_mantissa: Vec<f64>,
    /// B2: signed mantissa products M_x * M_w (per cell).
    pub b2_products: Vec<f64>,
    /// B3: GR column voltages (per column sample).
    pub b3_v_gr: Vec<f64>,
    /// Per-sample N_eff.
    pub n_eff: Vec<f64>,
}

/// Run the trace over `[b][nr]` row-major raw inputs.
pub fn trace_column(x: &[f64], w: &[f64], nr: usize, fmts: FormatPair) -> Trace {
    assert_eq!(x.len(), w.len());
    assert!(nr > 0 && x.len() % nr == 0);
    let b = x.len() / nr;
    let fx = fmts.x;
    let fw = fmts.w;
    let mut t = Trace::default();

    for s in 0..b {
        let xs = &x[s * nr..(s + 1) * nr];
        let ws = &w[s * nr..(s + 1) * nr];

        let mut dec = Vec::with_capacity(nr);
        let mut ebx = 1.0f64;
        let mut ebw = 1.0f64;
        for i in 0..nr {
            let xq = fx.quantize(xs[i]);
            let wq = fw.quantize(ws[i]);
            let (mx, ex) = fx.decompose(xq.abs());
            let (mw, ew) = fw.decompose(wq.abs());
            let sx = if xq < 0.0 { -1.0 } else { 1.0 };
            let sw = if wq < 0.0 { -1.0 } else { 1.0 };
            dec.push((sx * mx, ex, sw * mw, ew));
            ebx = ebx.max(ex);
            ebw = ebw.max(ew);
        }

        let mut v_conv = 0.0;
        let mut v_gr_num = 0.0;
        let mut s_sum = 0.0;
        let mut s2_sum = 0.0;
        for &(mx, ex, mw, ew) in &dec {
            let x_int = mx * exp2(ex - ebx);
            let w_int = mw * exp2(ew - ebw);
            t.a1_x_int.push(x_int);
            t.a2_products.push(x_int * w_int);
            t.b1_mantissa.push(mx);
            t.b2_products.push(mx * mw);
            v_conv += x_int * w_int;
            let u = exp2(ex + ew - fx.e_max - fw.e_max);
            s_sum += u;
            s2_sum += u * u;
            v_gr_num += mx * mw * u;
        }
        t.a3_v_conv.push(v_conv / nr as f64);
        t.b3_v_gr.push(v_gr_num / s_sum);
        t.n_eff.push(s_sum * s_sum / s2_sum);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::Distribution;
    use crate::formats::FpFormat;
    use crate::rng::Pcg64;
    use crate::util::{approx_eq, variance};

    fn fig4_setup(bsz: usize) -> Trace {
        // Fig. 4: FP6_E2M3 inputs and weights, clipped-4sigma Gaussian, NR=32
        let mut rng = Pcg64::seeded(4);
        let nr = 32;
        let mut x = vec![0.0; bsz * nr];
        let mut w = vec![0.0; bsz * nr];
        let d = Distribution::clipped_gauss4();
        d.fill(&mut rng, &mut x);
        d.fill(&mut rng, &mut w);
        trace_column(
            &x,
            &w,
            nr,
            FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp6_e2m3()),
        )
    }

    #[test]
    fn trace_matches_engine_outputs() {
        let mut rng = Pcg64::seeded(5);
        let nr = 16;
        let mut x = vec![0.0; 8 * nr];
        let mut w = vec![0.0; 8 * nr];
        Distribution::Uniform.fill(&mut rng, &mut x);
        Distribution::Uniform.fill(&mut rng, &mut w);
        let fmts = FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp4_e2m1());
        let t = trace_column(&x, &w, nr, fmts);
        let b = crate::mac::simulate_column(&x, &w, nr, fmts);
        for i in 0..8 {
            assert!(approx_eq(t.a3_v_conv[i], b.v_conv[i], 1e-12));
            assert!(approx_eq(t.b3_v_gr[i], b.v_gr[i], 1e-12));
            let neff = b.s_sum[i] * b.s_sum[i] / b.s2_sum[i];
            assert!(approx_eq(t.n_eff[i], neff, 1e-12));
        }
    }

    #[test]
    fn mantissas_are_normalized() {
        let t = fig4_setup(64);
        for &m in &t.b1_mantissa {
            assert!(m.abs() < 1.0);
        }
        // a majority of nonzero mantissas are normal (in [0.5, 1));
        // with sigma = 0.25 and e_max = 3, ~38% of magnitudes fall below
        // the 0.125 min-normal and stay subnormal
        let nonzero: Vec<f64> =
            t.b1_mantissa.iter().copied().filter(|m| *m != 0.0).collect();
        let normal =
            nonzero.iter().filter(|m| m.abs() >= 0.5).count() as f64;
        assert!(normal / nonzero.len() as f64 > 0.5);
    }

    #[test]
    fn gr_products_wider_than_aligned_products() {
        // Fig. 4 (A2) vs (B2): mantissa products have larger variance than
        // block-aligned integer products
        let t = fig4_setup(256);
        assert!(variance(&t.b2_products) > 2.0 * variance(&t.a2_products));
    }

    #[test]
    fn output_signal_power_gain_matches_paper_order() {
        // Fig. 4 (A3) vs (B3): ~20x output power improvement for the
        // clipped-Gaussian FP6 example. Accept [8, 50] as "paper shape".
        let t = fig4_setup(2048);
        let gain = variance(&t.b3_v_gr) / variance(&t.a3_v_conv);
        assert!(gain > 8.0 && gain < 50.0, "gain={gain}");
    }

    #[test]
    fn neff_matches_paper_example_shape() {
        // Paper Fig. 4 quotes N_eff = 14.6 at NR = 32 for this setup; our
        // reconstruction of its (not fully specified) Monte-Carlo gives
        // ~21. The claim that matters is the *shape*: N_eff well below NR
        // with exponent-weighted averaging. See EXPERIMENTS.md fig4 notes.
        let t = fig4_setup(2048);
        let mean_neff =
            t.n_eff.iter().sum::<f64>() / t.n_eff.len() as f64;
        assert!(
            (10.0..27.0).contains(&mean_neff),
            "mean N_eff = {mean_neff}"
        );
    }
}
