//! Tiny neural-network substrate for the end-to-end driver
//! (`examples/mlp_inference.rs`): a from-scratch MLP with SGD training on
//! synthetic data, plus CIM-quantized inference that routes every layer
//! matmul through the simulated analog array (conventional or GR-MAC
//! signal chain, ADC at the configured ENOB) via a
//! [`crate::runtime::Engine`]. Inference is a thin wrapper over the
//! model-scale executor ([`crate::model::forward_stages`]):
//! [`cim_forward_batch`] runs the no-reference fast path, and
//! [`cim_model_report`] produces the full [`crate::model::ModelReport`]
//! — per-layer energy, requantization/layer SQNRs, and the
//! classification-accuracy delta vs float inference.
//!
//! # Example
//!
//! Train a small classifier on synthetic blobs, then run the same batch
//! through the simulated CIM array at high precision:
//!
//! ```
//! use grcim::formats::FpFormat;
//! use grcim::mac::FormatPair;
//! use grcim::nn::{accuracy, cim_accuracy, make_blobs, CimInference, Mlp};
//! use grcim::rng::Pcg64;
//! use grcim::runtime::RustEngine;
//! use grcim::spec::Arch;
//!
//! let (xs, ys) = make_blobs(128, 8, 2, 0.15, 7);
//! let mut mlp = Mlp::new(&[8, 8, 2], 3);
//! let mut rng = Pcg64::seeded(11);
//! for _ in 0..10 {
//!     mlp.train_epoch(&xs, &ys, 0.1, &mut rng);
//! }
//! let float_acc = accuracy(&mlp, &xs, &ys);
//! assert!(float_acc > 0.8, "float accuracy {float_acc}");
//!
//! // fine formats + generous ADC: CIM inference tracks float inference
//! let cfg = CimInference {
//!     fmts: FormatPair::new(FpFormat::fp(4, 6), FpFormat::fp(4, 6)),
//!     arch: Arch::GrUnit,
//!     enob: 16.0,
//!     nr: 8,
//!     nc: 8,
//! };
//! let cim_acc = cim_accuracy(&mlp, &RustEngine, &cfg, &xs[..32], &ys[..32])?;
//! assert!(cim_acc >= float_acc - 0.1);
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::energy::{CimArch, TechParams};
use crate::mac::FormatPair;
use crate::model::{forward_stages, ForwardOpts, ModelResult, Runner, Stage};
use crate::rng::Pcg64;
use crate::runtime::Engine;
use crate::spec::Arch;
use crate::tile::{AdcPolicy, GemmShape, TileConfig};
use anyhow::Result;

/// A dense layer: row-major weights `[out][inp]`, bias `[out]`.
#[derive(Debug, Clone)]
pub struct Dense {
    /// Input width.
    pub inp: usize,
    /// Output width.
    pub out: usize,
    /// Row-major weights `[out][inp]`.
    pub w: Vec<f64>,
    /// Per-output biases.
    pub b: Vec<f64>,
}

impl Dense {
    fn new(inp: usize, out: usize, rng: &mut Pcg64) -> Self {
        // He init
        let scale = (2.0 / inp as f64).sqrt();
        let w = (0..inp * out).map(|_| rng.normal() * scale).collect();
        Dense { inp, out, w, b: vec![0.0; out] }
    }

    fn forward(&self, x: &[f64], z: &mut Vec<f64>) {
        z.clear();
        for o in 0..self.out {
            let row = &self.w[o * self.inp..(o + 1) * self.inp];
            let mut acc = self.b[o];
            for i in 0..self.inp {
                acc += row[i] * x[i];
            }
            z.push(acc);
        }
    }
}

/// Multi-layer perceptron with ReLU hidden activations and softmax output.
#[derive(Debug, Clone)]
pub struct Mlp {
    /// Dense layers, input to output.
    pub layers: Vec<Dense>,
}

impl Mlp {
    /// He-initialized MLP with the given layer widths (at least
    /// `[input, output]`).
    pub fn new(dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2);
        let mut rng = Pcg64::seeded(seed);
        let layers = dims
            .windows(2)
            .map(|d| Dense::new(d[0], d[1], &mut rng))
            .collect();
        Mlp { layers }
    }

    /// Float forward; returns logits.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut act = x.to_vec();
        let mut z = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            layer.forward(&act, &mut z);
            if li + 1 < self.layers.len() {
                for v in z.iter_mut() {
                    *v = v.max(0.0); // ReLU
                }
            }
            std::mem::swap(&mut act, &mut z);
        }
        act
    }

    /// Class prediction: argmax of the float logits.
    pub fn predict(&self, x: &[f64]) -> usize {
        argmax(&self.forward(x))
    }

    /// One SGD epoch of softmax cross-entropy; returns mean loss.
    pub fn train_epoch(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[usize],
        lr: f64,
        rng: &mut Pcg64,
    ) -> f64 {
        assert_eq!(xs.len(), ys.len());
        let mut order: Vec<usize> = (0..xs.len()).collect();
        // Fisher-Yates shuffle
        for i in (1..order.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        let mut total_loss = 0.0;
        for &idx in &order {
            total_loss += self.sgd_step(&xs[idx], ys[idx], lr);
        }
        total_loss / xs.len() as f64
    }

    fn sgd_step(&mut self, x: &[f64], y: usize, lr: f64) -> f64 {
        // forward with cached activations
        let mut acts: Vec<Vec<f64>> = vec![x.to_vec()];
        let mut z = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            layer.forward(acts.last().unwrap(), &mut z);
            let mut a = z.clone();
            if li + 1 < self.layers.len() {
                for v in a.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            acts.push(a);
        }
        // softmax + loss
        let logits = acts.last().unwrap().clone();
        let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|v| (v - max).exp()).collect();
        let sum: f64 = exps.iter().sum();
        let probs: Vec<f64> = exps.iter().map(|e| e / sum).collect();
        let loss = -probs[y].max(1e-12).ln();

        // backward
        let mut delta: Vec<f64> = probs;
        delta[y] -= 1.0;
        for li in (0..self.layers.len()).rev() {
            let (prev_act, this_act) = (&acts[li], &acts[li + 1]);
            // relu grad for hidden layers
            if li + 1 < self.layers.len() {
                for (d, a) in delta.iter_mut().zip(this_act) {
                    if *a <= 0.0 {
                        *d = 0.0;
                    }
                }
            }
            let layer = &mut self.layers[li];
            let mut next_delta = vec![0.0; layer.inp];
            for o in 0..layer.out {
                let d = delta[o];
                let row = &mut layer.w[o * layer.inp..(o + 1) * layer.inp];
                for i in 0..layer.inp {
                    next_delta[i] += row[i] * d;
                    row[i] -= lr * d * prev_act[i];
                }
                layer.b[o] -= lr * d;
            }
            delta = next_delta;
        }
        loss
    }
}

/// Index of the largest element (0 for an empty slice).
pub fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Synthetic k-class Gaussian-blob dataset in d dimensions.
pub fn make_blobs(
    n: usize,
    d: usize,
    k: usize,
    spread: f64,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<usize>) {
    let mut rng = Pcg64::seeded(seed);
    // class centers on a scaled hypercube corner pattern
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect())
        .collect();
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % k;
        let x: Vec<f64> = centers[c]
            .iter()
            .map(|&m| m + rng.normal() * spread)
            .collect();
        xs.push(x);
        ys.push(c);
    }
    (xs, ys)
}

/// CIM inference configuration.
#[derive(Debug, Clone, Copy)]
pub struct CimInference {
    /// Input/weight formats the array quantizes to.
    pub fmts: FormatPair,
    /// Which signal chain digitizes each column.
    pub arch: Arch,
    /// ADC resolution, effective bits.
    pub enob: f64,
    /// Array depth (row-chunk size of each tiled matmul).
    pub nr: usize,
    /// Columns per CIM tile (the output dimension is split into N_C-wide
    /// tiles by the array mapper; column results are independent, so this
    /// only affects energy amortization, not the outputs).
    pub nc: usize,
}

impl CimInference {
    /// The array-mapper configuration this inference setup runs on
    /// (fixed-ENOB digitization — the resolution is a design input here,
    /// not a per-tile solve).
    pub fn tile_config(&self) -> TileConfig {
        TileConfig {
            nr: self.nr,
            nc: self.nc,
            fmts: self.fmts,
            arch: CimArch::from_spec(self.arch),
            adc: AdcPolicy::Fixed(self.enob),
            tech: TechParams::default(),
        }
    }
}

/// Build the model-executor stages of a trained MLP on one array
/// configuration: per-layer max-|w| weight calibration, biases, and the
/// hidden-layer ReLU epilogue — the [`crate::model`] form of this
/// network's inference pass.
pub fn mlp_stages(mlp: &Mlp, cfg: &CimInference, batch: usize) -> Vec<Stage> {
    let tcfg = cfg.tile_config();
    let layers = mlp.layers.len();
    mlp.layers
        .iter()
        .enumerate()
        .map(|(li, layer)| {
            let w_scale = layer.w.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1e-12);
            let wt: Vec<f32> = layer.w.iter().map(|v| (v / w_scale) as f32).collect();
            Stage {
                name: format!("fc{li}"),
                shape: GemmShape { m: batch, k: layer.inp, n: layer.out },
                cfg: tcfg,
                wt,
                w_scale,
                bias: Some(layer.b.clone()),
                relu: li + 1 < layers,
                attn: None,
                conv: None,
            }
        })
        .collect()
}

/// Run a batch of inputs through the network with every matmul executed
/// by the simulated CIM array. A thin wrapper over the model executor
/// ([`crate::model::forward_stages`], no-reference fast path): per-layer
/// static calibration, inter-layer requantization to the input format,
/// one tiled GEMM per layer (weight-stationary N_R × N_C tiles, the
/// selected analog signal chain, ADC at `enob`, renormalization, digital
/// partial-sum reduction), and the bias/ReLU epilogue in the float
/// domain.
pub fn cim_forward_batch(
    mlp: &Mlp,
    engine: &dyn Engine,
    cfg: &CimInference,
    xs: &[Vec<f64>],
) -> Result<Vec<Vec<f64>>> {
    let n = xs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let stages = mlp_stages(mlp, cfg, n);
    let x0: Vec<f64> = xs.iter().flat_map(|x| x.iter().copied()).collect();
    let res = forward_stages(
        &Runner::Sequential(engine),
        "mlp",
        &stages,
        &x0,
        ForwardOpts { with_reference: false, fit_activations: false },
    )?;
    let out = mlp.layers.last().expect("mlp has layers").out;
    Ok(res.y.chunks(out).map(|c| c.to_vec()).collect())
}

/// Full model-scale evaluation of a trained MLP's CIM inference: the
/// [`crate::model::ModelReport`] (per-layer energy, requantization and
/// layer SQNRs, activation statistics, end-to-end SQNR) with the
/// classification-accuracy delta vs float inference filled in — the
/// "MLP path" of the model-scale energy pipeline.
pub fn cim_model_report(
    mlp: &Mlp,
    engine: &dyn Engine,
    cfg: &CimInference,
    xs: &[Vec<f64>],
    ys: &[usize],
) -> Result<ModelResult> {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        // a zero-row model run would report 0/0 = NaN accuracies
        anyhow::bail!("cim_model_report needs at least one labeled input");
    }
    let stages = mlp_stages(mlp, cfg, xs.len());
    let x0: Vec<f64> = xs.iter().flat_map(|x| x.iter().copied()).collect();
    let mut res = forward_stages(
        &Runner::Sequential(engine),
        "mlp",
        &stages,
        &x0,
        ForwardOpts { with_reference: true, fit_activations: true },
    )?;
    let out = mlp.layers.last().expect("mlp has layers").out;
    let correct = res
        .y
        .chunks(out)
        .zip(ys)
        .filter(|(logits, &y)| argmax(logits) == y)
        .count();
    res.report.accuracy_cim = Some(correct as f64 / ys.len() as f64);
    res.report.accuracy_float = Some(accuracy(mlp, xs, ys));
    Ok(res)
}

/// Single-input convenience wrapper over [`cim_forward_batch`].
pub fn cim_forward(
    mlp: &Mlp,
    engine: &dyn Engine,
    cfg: &CimInference,
    x: &[f64],
) -> Result<Vec<f64>> {
    Ok(cim_forward_batch(mlp, engine, cfg, &[x.to_vec()])?.remove(0))
}

/// Classification accuracy of float inference.
pub fn accuracy(mlp: &Mlp, xs: &[Vec<f64>], ys: &[usize]) -> f64 {
    let correct = xs
        .iter()
        .zip(ys)
        .filter(|(x, &y)| mlp.predict(x) == y)
        .count();
    correct as f64 / xs.len() as f64
}

/// Classification accuracy of CIM-simulated inference (batched).
pub fn cim_accuracy(
    mlp: &Mlp,
    engine: &dyn Engine,
    cfg: &CimInference,
    xs: &[Vec<f64>],
    ys: &[usize],
) -> Result<f64> {
    let logits = cim_forward_batch(mlp, engine, cfg, xs)?;
    let correct = logits
        .iter()
        .zip(ys)
        .filter(|(l, &y)| argmax(l) == y)
        .count();
    Ok(correct as f64 / xs.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;
    use crate::runtime::RustEngine;

    fn train_small() -> (Mlp, Vec<Vec<f64>>, Vec<usize>) {
        let (xs, ys) = make_blobs(512, 16, 4, 0.25, 7);
        let mut mlp = Mlp::new(&[16, 32, 4], 3);
        let mut rng = Pcg64::seeded(11);
        for _ in 0..30 {
            mlp.train_epoch(&xs, &ys, 0.05, &mut rng);
        }
        (mlp, xs, ys)
    }

    #[test]
    fn training_reduces_loss_and_fits_blobs() {
        let (xs, ys) = make_blobs(512, 16, 4, 0.25, 7);
        let mut mlp = Mlp::new(&[16, 32, 4], 3);
        let mut rng = Pcg64::seeded(11);
        let first = mlp.train_epoch(&xs, &ys, 0.05, &mut rng);
        let mut last = first;
        for _ in 0..29 {
            last = mlp.train_epoch(&xs, &ys, 0.05, &mut rng);
        }
        assert!(last < 0.5 * first, "loss {first} -> {last}");
        assert!(accuracy(&mlp, &xs, &ys) > 0.9);
    }

    #[test]
    fn cim_inference_with_fine_format_matches_float() {
        let (mlp, xs, ys) = train_small();
        let float_acc = accuracy(&mlp, &xs, &ys);
        let cfg = CimInference {
            fmts: FormatPair::new(FpFormat::fp(4, 6), FpFormat::fp(4, 6)),
            arch: Arch::GrUnit,
            enob: 16.0,
            nr: 16,
            nc: 16,
        };
        let acc =
            cim_accuracy(&mlp, &RustEngine, &cfg, &xs[..128], &ys[..128])
                .unwrap();
        assert!(
            acc >= float_acc - 0.05,
            "cim {acc} vs float {float_acc}"
        );
    }

    #[test]
    fn cim_forward_logits_close_to_float_at_high_precision() {
        let (mlp, xs, _) = train_small();
        let cfg = CimInference {
            fmts: FormatPair::new(FpFormat::fp(4, 7), FpFormat::fp(4, 7)),
            arch: Arch::GrUnit,
            enob: 18.0,
            nr: 16,
            nc: 16,
        };
        let f = mlp.forward(&xs[0]);
        let c = cim_forward(&mlp, &RustEngine, &cfg, &xs[0]).unwrap();
        for (a, b) in f.iter().zip(&c) {
            assert!((a - b).abs() < 0.05 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn coarse_adc_degrades_conventional_more_than_gr() {
        let (mlp, xs, ys) = train_small();
        let fmts = FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp6_e2m3());
        let acc_at = |arch: Arch, enob: f64| {
            cim_accuracy(
                &mlp,
                &RustEngine,
                &CimInference { fmts, arch, enob, nr: 16, nc: 16 },
                &xs[..192],
                &ys[..192],
            )
            .unwrap()
        };
        let gr = acc_at(Arch::GrUnit, 6.0);
        let conv = acc_at(Arch::Conventional, 6.0);
        assert!(
            gr >= conv - 0.02,
            "gr {gr} should not trail conventional {conv} at coarse ADC"
        );
    }

    #[test]
    fn model_report_carries_accuracy_delta_and_matches_the_wrapper() {
        let (mlp, xs, ys) = train_small();
        let cfg = CimInference {
            fmts: FormatPair::new(FpFormat::fp(4, 6), FpFormat::fp(4, 6)),
            arch: Arch::GrUnit,
            enob: 16.0,
            nr: 16,
            nc: 16,
        };
        let res =
            cim_model_report(&mlp, &RustEngine, &cfg, &xs[..128], &ys[..128])
                .unwrap();
        let rep = &res.report;
        assert_eq!(rep.layers.len(), 2);
        assert!(rep.sqnr_db > 20.0, "e2e sqnr {}", rep.sqnr_db);
        // fine formats + generous ADC: accuracy tracks float inference
        let delta = rep.accuracy_delta().unwrap();
        assert!(delta.abs() <= 0.05, "accuracy delta {delta}");
        assert!(rep.to_figure_result().all_hold());
        // the inference wrapper is the same pipeline minus the reference
        // work: its logits match the report's outputs bit for bit
        let logits =
            cim_forward_batch(&mlp, &RustEngine, &cfg, &xs[..128]).unwrap();
        let out = mlp.layers.last().unwrap().out;
        for (row, chunk) in logits.iter().zip(res.y.chunks(out)) {
            for (a, b) in row.iter().zip(chunk) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn blobs_are_deterministic_and_labeled() {
        let (xa, ya) = make_blobs(64, 8, 4, 0.1, 5);
        let (xb, _) = make_blobs(64, 8, 4, 0.1, 5);
        assert_eq!(xa[0], xb[0]);
        assert!(ya.iter().all(|&y| y < 4));
    }
}
