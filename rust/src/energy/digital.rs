//! Digital-IMC cost model — adder/multiplier/register energies per op at
//! matched precision, the baseline the analog-vs-digital crossover
//! analysis of "Analog or Digital In-memory Computing?" (arxiv
//! 2405.14978, PAPERS.md) compares against.
//!
//! A digital IMC macro computes the same `NR`-deep MAC column the analog
//! array does, but in full-swing CMOS logic: an `Nx x Nw` array
//! multiplier per cell row, a ripple accumulate-add at the full
//! accumulator width, and an accumulator register write per MAC. No
//! DAC, no ADC, no mismatch — the cost is exact-precision arithmetic at
//! gate-switching energy, priced from the same Table II/III primitives
//! ([`TechParams`]) as the analog model so the comparison shares one
//! technology point.
//!
//! The headline question the model answers per design point: at what
//! ADC resolution does the analog MVM stop being cheaper than just
//! doing the arithmetic digitally? That resolution is the **crossover
//! ENOB** ([`crossover_enob`]); analog wins strictly below it.
//!
//! # Example
//!
//! ```
//! use grcim::energy::{digital, CimArch, TechParams};
//! use grcim::formats::FpFormat;
//! use grcim::mac::FormatPair;
//!
//! let t = TechParams::default();
//! let fmts = FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1());
//! // the digital baseline is flat in ENOB; analog crosses it somewhere
//! let per_op = digital::digital_fj_per_op(&t, &fmts, 32);
//! assert!(per_op > 0.0);
//! if let Some(x) = digital::crossover_enob(CimArch::GrUnit, fmts, 32, 32, &t) {
//!     assert!(x > 0.0);
//! }
//! ```

use super::arch::{energy_per_op, CimArch};
use super::TechParams;
use crate::formats::FpFormat;
use crate::mac::FormatPair;

/// Upper bisection bound for [`crossover_enob`] — matches the tile
/// layer's physical ADC ceiling ([`crate::tile::MAX_TILE_ENOB`]).
pub const MAX_CROSSOVER_ENOB: f64 = 32.0;

/// Register (D flip-flop) write energy: `4 * C_gate * V_DD^2` per bit —
/// the standard ~4-gate-equivalent master/slave cost at the Table II
/// switching model.
pub fn e_reg(t: &TechParams, bits: f64) -> f64 {
    assert!(bits >= 0.0);
    4.0 * t.c_gate_ff * t.v2() * bits
}

/// Ripple-carry add energy: one full adder per accumulator bit.
pub fn e_add(t: &TechParams, bits: f64) -> f64 {
    assert!(bits >= 0.0);
    t.e_fa() * bits
}

/// Aligned integer magnitude width of an FP operand —
/// `(n_m + 1) + (e_max - 1)`, the same FP->INT convention the
/// conventional-CIM DAC/cell widths use ([`super::arch`] header). For
/// `fp4_e2m1` this is 4 bits; fractional widths pass through.
pub fn aligned_bits(f: &FpFormat) -> f64 {
    (f.n_m + 1.0) + (f.e_max - 1.0)
}

/// Accumulator width for an `NR`-deep column of `Nx x Nw`-bit products:
/// the product width plus `ceil(log2 NR)` carry-growth bits.
pub fn acc_width(nx_bits: f64, nw_bits: f64, nr: usize) -> f64 {
    assert!(nr >= 1);
    nx_bits + nw_bits + (nr as f64).log2().ceil()
}

/// Digital-IMC energy of one matched-precision MAC: an `Nx x Nw` array
/// multiply over the aligned magnitude words, a full-width accumulate
/// add, and an accumulator register write. `nr` sets the accumulator
/// width (deeper columns carry wider partial sums — the digital
/// analogue of the analog array's dynamic-range growth).
pub fn digital_mac_fj(t: &TechParams, fmts: &FormatPair, nr: usize) -> f64 {
    let (nx, nw) = (aligned_bits(&fmts.x), aligned_bits(&fmts.w));
    let accw = acc_width(nx, nw, nr);
    t.e_mult(nx, nw) + e_add(t, accw) + e_reg(t, accw)
}

/// Digital-IMC energy per operation (one MAC = two ops, the paper's
/// convention) — directly comparable to
/// [`energy_per_op`](super::energy_per_op)`.total()`.
pub fn digital_fj_per_op(t: &TechParams, fmts: &FormatPair, nr: usize) -> f64 {
    digital_mac_fj(t, fmts, nr) / 2.0
}

/// Per-element digital softmax energy: an 8-bit fixed-point exp
/// (range-reduction shift-add plus a two-multiply polynomial), the
/// running-sum accumulate, and the normalization multiply, with one
/// register write for the probability word. This is the
/// [`TechParams::e_softmax_fj`] default — the term that un-zeroes the
/// transformer/decode softmax cost the ROADMAP flags.
pub fn softmax_element_fj(t: &TechParams) -> f64 {
    let bits = 8.0;
    // exp polynomial multiply + normalization multiply
    let mults = 2.0 * t.e_mult(bits, bits);
    // range-reduction shift-add + running-sum accumulate
    let adds = 2.0 * e_add(t, bits);
    mults + adds + e_reg(t, bits)
}

/// The analog-vs-digital crossover: the ADC resolution at which the
/// analog architecture's energy per op ([`energy_per_op`]) matches the
/// flat digital baseline at the same formats/geometry. `None` when the
/// analog path is never cheaper (already above digital at ENOB 0) or
/// never crosses within the physical ADC range — analog wins strictly
/// below the returned ENOB.
pub fn crossover_enob(
    arch: CimArch,
    fmts: FormatPair,
    nr: usize,
    nc: usize,
    t: &TechParams,
) -> Option<f64> {
    let digital = digital_fj_per_op(t, &fmts, nr);
    let analog = |enob: f64| energy_per_op(arch, fmts, nr, nc, enob, t).total();
    if analog(0.0) >= digital {
        return None;
    }
    if analog(MAX_CROSSOVER_ENOB) < digital {
        return None;
    }
    // analog per-op energy is monotone increasing in ENOB (the ADC is
    // its only ENOB-dependent component) — bisect the sign change
    let (mut lo, mut hi) = (0.0f64, MAX_CROSSOVER_ENOB);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if analog(mid) >= digital {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(0.5 * (lo + hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;
    use crate::util::approx_eq;

    fn fmts44() -> FormatPair {
        FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1())
    }

    #[test]
    fn register_and_add_formulas() {
        let t = TechParams::default();
        assert!(approx_eq(e_reg(&t, 8.0), 4.0 * 0.7 * 0.81 * 8.0, 1e-12));
        assert!(approx_eq(e_add(&t, 8.0), 8.0 * t.e_fa(), 1e-12));
    }

    #[test]
    fn aligned_widths_match_arch_convention() {
        // fp4_e2m1: (1+1) + (3-1) = 4 magnitude bits
        assert_eq!(aligned_bits(&FpFormat::fp4_e2m1()), 4.0);
        // fp8_e4m3: (3+1) + (15-1) = 18 aligned bits
        assert_eq!(aligned_bits(&FpFormat::fp8_e4m3()), 18.0);
    }

    #[test]
    fn acc_width_tracks_column_depth() {
        // 4x4-bit products over 32 rows: 8 + 5 carry bits
        assert_eq!(acc_width(4.0, 4.0, 32), 13.0);
        // one row adds no carry bits
        assert_eq!(acc_width(4.0, 4.0, 1), 8.0);
        // non-power-of-two rounds up
        assert_eq!(acc_width(4.0, 4.0, 33), 14.0);
    }

    #[test]
    fn digital_mac_decomposes() {
        let t = TechParams::default();
        let f = fmts44();
        let accw = acc_width(4.0, 4.0, 32);
        let want = t.e_mult(4.0, 4.0) + e_add(&t, accw) + e_reg(&t, accw);
        assert!(approx_eq(digital_mac_fj(&t, &f, 32), want, 1e-12));
        assert!(approx_eq(
            digital_fj_per_op(&t, &f, 32),
            want / 2.0,
            1e-12
        ));
    }

    #[test]
    fn softmax_element_matches_hand_total() {
        let t = TechParams::default();
        // 2*272.16 + 54.432 + 18.144 = 616.896 fJ at Table III defaults
        assert!(approx_eq(softmax_element_fj(&t), 616.896, 1e-9));
    }

    #[test]
    fn crossover_is_the_energy_equality_point() {
        let t = TechParams::default();
        let f = fmts44();
        let x = crossover_enob(CimArch::GrUnit, f, 32, 32, &t)
            .expect("gr-unit at fp4/fp4 must start below the digital baseline");
        let analog = energy_per_op(CimArch::GrUnit, f, 32, 32, x, &t).total();
        let digital = digital_fj_per_op(&t, &f, 32);
        assert!(approx_eq(analog, digital, 1e-6), "analog {analog} digital {digital}");
        // strictly below the crossover, analog wins
        let below = energy_per_op(CimArch::GrUnit, f, 32, 32, x - 1.0, &t).total();
        assert!(below < digital);
    }
}
