//! Array-level energy composition per architecture and normalization
//! granularity (paper Sec. III-C and IV-B; DESIGN.md #7).
//!
//! Bit-width conventions (fractional widths allowed — the Fig. 12 axes are
//! continuous):
//!
//! * aligned magnitude width (FP->INT): `(n_m + 1) + (e_max - 1)` —
//!   mantissa incl. implicit bit plus the exponent shift range;
//! * normalized mantissa width (GR): `n_m + 1`;
//! * exponent field bits: `log2(e_max + 1)`;
//! * one-hot exponent-sum range (unit norm): `e_max_x + e_max_w - 1`
//!   levels, fed to a `log2`-bit adder.
//!
//! Amortization (Sec. III-C): per-cell logic is not amortized; per-row
//! logic amortizes over N_C; per-column logic over N_R; per-array over
//! N_R * N_C. Energy per op divides one MVM by 2 * NR * NC.

use super::{adder_tree_fa_count, TechParams};
use crate::mac::FormatPair;

/// CIM architecture / normalization granularity (Sec. III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CimArch {
    /// Conventional direct-accumulation CIM on FP->INT-aligned data.
    Conventional,
    /// GR-MAC, per-unit normalization (input + weight exponents ranged).
    GrUnit,
    /// GR-MAC, per-row normalization (input exponents only; weights stored
    /// pre-aligned as in [18]).
    GrRow,
    /// GR-MAC, INT-input normalization (weight exponents ranged only;
    /// column exponent sums precomputed at compile time).
    GrInt,
}

impl CimArch {
    /// Stable lowercase name for reports and wire responses.
    pub fn name(&self) -> &'static str {
        match self {
            CimArch::Conventional => "conventional",
            CimArch::GrUnit => "gr-unit",
            CimArch::GrRow => "gr-row",
            CimArch::GrInt => "gr-int",
        }
    }

    /// The spec-solver architecture whose referral gain dimensions this
    /// granularity's ADC.
    pub fn spec_arch(&self) -> crate::spec::Arch {
        match self {
            CimArch::Conventional => crate::spec::Arch::Conventional,
            CimArch::GrUnit => crate::spec::Arch::GrUnit,
            CimArch::GrRow => crate::spec::Arch::GrRow,
            CimArch::GrInt => crate::spec::Arch::GrInt,
        }
    }

    /// The energy-model granularity matching a spec-solver architecture
    /// (the inverse of [`CimArch::spec_arch`]).
    pub fn from_spec(arch: crate::spec::Arch) -> Self {
        match arch {
            crate::spec::Arch::Conventional => CimArch::Conventional,
            crate::spec::Arch::GrUnit => CimArch::GrUnit,
            crate::spec::Arch::GrRow => CimArch::GrRow,
            crate::spec::Arch::GrInt => CimArch::GrInt,
        }
    }

    /// Parse a `--arch` / wire `arch` value. `gr` is an alias for the
    /// unit granularity (the paper's default gain-ranging configuration).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "conventional" | "conv" => CimArch::Conventional,
            "gr" | "gr-unit" | "unit" => CimArch::GrUnit,
            "gr-row" | "row" => CimArch::GrRow,
            "gr-int" | "int" => CimArch::GrInt,
            other => anyhow::bail!(
                "unknown arch '{other}' (conventional|gr|gr-unit|gr-row|gr-int)"
            ),
        })
    }
}

/// Per-op energy breakdown in fJ (the Fig. 12 pie charts).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Column ADCs.
    pub adc: f64,
    /// Row DACs.
    pub dac: f64,
    /// Cell-array capacitor switching.
    pub cells: f64,
    /// Per-cell / per-row exponent logic (adders + decoders).
    pub exp_logic: f64,
    /// Column exponent adder trees.
    pub tree: f64,
    /// Column output normalization multipliers.
    pub norm_mult: f64,
}

impl EnergyBreakdown {
    /// Total energy per operation (sum of every component), fJ.
    pub fn total(&self) -> f64 {
        self.adc + self.dac + self.cells + self.exp_logic + self.tree + self.norm_mult
    }

    /// Named components for reports.
    pub fn components(&self) -> [(&'static str, f64); 6] {
        [
            ("adc", self.adc),
            ("dac", self.dac),
            ("cells", self.cells),
            ("exp_logic", self.exp_logic),
            ("tree", self.tree),
            ("norm_mult", self.norm_mult),
        ]
    }
}

fn exponent_field_bits(e_max: f64) -> f64 {
    (e_max + 1.0).log2().max(1.0)
}

/// Energy per operation of one architecture at a given ADC ENOB.
///
/// `enob` comes from the spec solver (`spec::required_enob`) with the
/// matching [`CimArch::spec_arch`] referral gain.
pub fn energy_per_op(
    arch: CimArch,
    fmts: FormatPair,
    nr: usize,
    nc: usize,
    enob: f64,
    tech: &TechParams,
) -> EnergyBreakdown {
    assert!(nr > 0 && nc > 0);
    let ops = 2.0 * (nr * nc) as f64;
    let fx = fmts.x;
    let fw = fmts.w;

    let mant_x = fx.n_m + 1.0; // mantissa incl. implicit bit
    let mant_w = fw.n_m + 1.0;
    let aligned_x = mant_x + (fx.e_max - 1.0); // FP->INT width (magnitude)
    let aligned_w = mant_w + (fw.e_max - 1.0);
    let ebits_x = exponent_field_bits(fx.e_max);
    let ebits_w = exponent_field_bits(fw.e_max);

    let mut b = EnergyBreakdown::default();

    // Column ADCs: one conversion per column per MVM.
    b.adc = nc as f64 * tech.e_adc(enob) / ops;

    match arch {
        CimArch::Conventional => {
            // Row DACs drive the aligned input word (sign handled
            // differentially, charged on magnitude bits as in [27]).
            b.dac = nr as f64 * tech.e_dac(aligned_x) / ops;
            // Cell divider switches span the aligned weight width.
            b.cells = tech.e_cell_array(aligned_w, nr, nc) / ops;
        }
        CimArch::GrUnit => {
            // DAC carries only the normalized mantissa.
            b.dac = nr as f64 * tech.e_dac(mant_x) / ops;
            // mantissa switches + the gain-ranging coupling toggle
            b.cells = tech.e_cell_array(mant_w + 1.0, nr, nc) / ops;
            // per-cell: exponent adder (max field width + carry) + decoder
            // driving the one-hot coupling switches
            let sum_levels = (fx.e_max + fw.e_max - 1.0).max(1.0);
            let sum_bits = sum_levels.log2().max(1.0) + 1.0;
            let fa_per_cell = ebits_x.max(ebits_w) + 1.0;
            let cell_logic = tech.e_fa() * fa_per_cell
                + tech.e_decoder(sum_bits, sum_levels);
            b.exp_logic = (nr * nc) as f64 * cell_logic / ops;
            // per-column adder tree over NR one-hot magnitude words
            let fa = adder_tree_fa_count(nr, sum_levels);
            b.tree = nc as f64 * tech.e_adder_tree(fa) / ops;
            // per-column normalization multiplier: ADC word x S word
            let s_bits = sum_levels + (nr as f64).log2();
            b.norm_mult = nc as f64 * tech.e_mult(enob, s_bits) / ops;
        }
        CimArch::GrRow => {
            b.dac = nr as f64 * tech.e_dac(mant_x) / ops;
            // weights stored pre-aligned; + gain-ranging toggle
            b.cells = tech.e_cell_array(aligned_w + 1.0, nr, nc) / ops;
            // one decoder per row (input exponent -> one-hot), amortized
            // over the row's NC cells
            let levels = fx.e_max.max(1.0);
            let row_logic = tech.e_decoder(ebits_x, levels);
            b.exp_logic = nr as f64 * row_logic / ops;
            // one exponent adder tree per array (inputs shared by columns)
            let fa = adder_tree_fa_count(nr, levels);
            b.tree = tech.e_adder_tree(fa) / ops;
            let s_bits = levels + (nr as f64).log2();
            b.norm_mult = nc as f64 * tech.e_mult(enob, s_bits) / ops;
        }
        CimArch::GrInt => {
            // INT inputs: DAC carries the full input word (= its DR bits,
            // which for an INT format equals its total width - sign).
            b.dac = nr as f64 * tech.e_dac(fx.dr_bits() - 1.0) / ops;
            b.cells = tech.e_cell_array(mant_w + 1.0, nr, nc) / ops;
            // per-cell decoder on the stored weight exponent
            let levels = fw.e_max.max(1.0);
            b.exp_logic =
                (nr * nc) as f64 * tech.e_decoder(ebits_w, levels) / ops;
            // column exponent sums precomputed at compile time: no tree
            b.tree = 0.0;
            let s_bits = levels + (nr as f64).log2();
            b.norm_mult = nc as f64 * tech.e_mult(enob, s_bits) / ops;
        }
    }
    b
}

/// Energy per op of the optional global-normalization wrapper (Sec. III,
/// Fig. 3 dashed): per-MVM max-exponent search over the input block plus a
/// per-input exponent subtract; modeled with the paper's FA primitives.
/// Charged identically to either architecture when a spec exceeds native
/// DR; excluded from Fig. 12's pies ("only CIM array energy is included").
pub fn global_norm_energy_per_op(
    fmts: FormatPair,
    nr: usize,
    nc: usize,
    tech: &TechParams,
) -> f64 {
    let ops = 2.0 * (nr * nc) as f64;
    let ebits = exponent_field_bits(fmts.x.e_max);
    // max-find tree: NR-1 comparators ~ ebits-bit adders each
    let maxfind = tech.e_adder_tree(adder_tree_fa_count(nr, ebits));
    // per-input exponent subtract + shift control decoder
    let per_input = tech.e_fa() * ebits
        + tech.e_decoder(ebits, fmts.x.e_max.max(1.0));
    (maxfind + nr as f64 * per_input) / ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;

    fn fp4_pair() -> FormatPair {
        FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1())
    }

    #[test]
    fn breakdown_total_is_sum() {
        let t = TechParams::default();
        let b = energy_per_op(CimArch::GrUnit, fp4_pair(), 32, 32, 8.0, &t);
        let sum: f64 = b.components().iter().map(|(_, v)| v).sum();
        assert!((b.total() - sum).abs() < 1e-12);
    }

    #[test]
    fn conventional_has_no_exponent_logic() {
        let t = TechParams::default();
        let b =
            energy_per_op(CimArch::Conventional, fp4_pair(), 32, 32, 8.0, &t);
        assert_eq!(b.exp_logic, 0.0);
        assert_eq!(b.tree, 0.0);
        assert_eq!(b.norm_mult, 0.0);
        assert!(b.adc > 0.0 && b.dac > 0.0 && b.cells > 0.0);
    }

    #[test]
    fn gr_dac_cheaper_than_conventional_dac() {
        // GR drives mantissa-only DACs; conventional drives aligned words
        let t = TechParams::default();
        let conv =
            energy_per_op(CimArch::Conventional, fp4_pair(), 32, 32, 8.0, &t);
        let gr = energy_per_op(CimArch::GrUnit, fp4_pair(), 32, 32, 8.0, &t);
        assert!(gr.dac < conv.dac);
    }

    #[test]
    fn unit_logic_exceeds_row_logic() {
        // per-cell adders+decoders vs per-row decoders (Sec. III-C)
        let t = TechParams::default();
        let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
        let unit = energy_per_op(CimArch::GrUnit, fmts, 32, 32, 8.0, &t);
        let row = energy_per_op(CimArch::GrRow, fmts, 32, 32, 8.0, &t);
        assert!(unit.exp_logic > row.exp_logic);
        assert!(unit.tree > row.tree); // per-column trees vs one tree
    }

    #[test]
    fn adc_dominates_at_high_enob() {
        let t = TechParams::default();
        let b = energy_per_op(CimArch::Conventional, fp4_pair(), 32, 32, 12.0, &t);
        assert!(b.adc > 0.5 * b.total());
    }

    #[test]
    fn energy_monotone_in_enob() {
        let t = TechParams::default();
        let mut prev = 0.0;
        for enob in [4.0, 6.0, 8.0, 10.0, 12.0] {
            let e = energy_per_op(CimArch::GrUnit, fp4_pair(), 32, 32, enob, &t)
                .total();
            assert!(e > prev);
            prev = e;
        }
    }

    #[test]
    fn adc_amortizes_over_rows() {
        // deeper arrays amortize the column ADC over more ops
        let t = TechParams::default();
        let e32 = energy_per_op(CimArch::Conventional, fp4_pair(), 32, 32, 10.0, &t);
        let e128 =
            energy_per_op(CimArch::Conventional, fp4_pair(), 128, 32, 10.0, &t);
        assert!(e128.adc < e32.adc);
        // but cell switching per op is depth-independent
        assert!((e128.cells - e32.cells).abs() < 1e-12);
    }

    #[test]
    fn fp4_energy_in_paper_ballpark() {
        // Fig. 12 pie: FP4 inputs at 32x32 sit around tens of fJ/Op.
        // This pins the units (fJ) more than the exact value.
        let t = TechParams::default();
        let b = energy_per_op(CimArch::GrUnit, fp4_pair(), 32, 32, 7.0, &t);
        assert!(
            b.total() > 5.0 && b.total() < 100.0,
            "total = {} fJ/Op",
            b.total()
        );
    }

    #[test]
    fn global_norm_wrapper_is_small_but_nonzero() {
        let t = TechParams::default();
        let fmts = FormatPair::new(FpFormat::fp8_e4m3(), FpFormat::fp4_e2m1());
        let e = global_norm_energy_per_op(fmts, 32, 32, &t);
        assert!(e > 0.0 && e < 5.0, "global norm = {e} fJ/Op");
    }

    #[test]
    fn spec_arch_mapping() {
        assert_eq!(CimArch::GrUnit.spec_arch(), crate::spec::Arch::GrUnit);
        assert_eq!(
            CimArch::Conventional.spec_arch(),
            crate::spec::Arch::Conventional
        );
        // from_spec is the exact inverse
        for arch in [
            CimArch::Conventional,
            CimArch::GrUnit,
            CimArch::GrRow,
            CimArch::GrInt,
        ] {
            assert_eq!(CimArch::from_spec(arch.spec_arch()), arch);
        }
    }

    #[test]
    fn arch_names_parse() {
        assert_eq!(CimArch::parse("gr").unwrap(), CimArch::GrUnit);
        assert_eq!(CimArch::parse("gr-unit").unwrap(), CimArch::GrUnit);
        assert_eq!(CimArch::parse("conventional").unwrap(), CimArch::Conventional);
        assert_eq!(CimArch::parse("conv").unwrap(), CimArch::Conventional);
        assert_eq!(CimArch::parse("gr-row").unwrap(), CimArch::GrRow);
        assert_eq!(CimArch::parse("gr-int").unwrap(), CimArch::GrInt);
        assert!(CimArch::parse("quantum").is_err());
        // every canonical name round-trips through parse
        for arch in [
            CimArch::Conventional,
            CimArch::GrUnit,
            CimArch::GrRow,
            CimArch::GrInt,
        ] {
            assert_eq!(CimArch::parse(arch.name()).unwrap(), arch);
        }
    }
}
