//! Energy models — the paper's Appendix (Tables II and III), from Sun et
//! al., ICCAD'23, plus the array-level composition for each architecture
//! and normalization granularity (Sec. III-C, Sec. IV-B).
//!
//! All component energies are in **femtojoules** (capacitance parameters in
//! fF, V_DD in volts). Per-operation figures divide one matrix-vector
//! multiplication by `2 * NR * NC` (each MAC counts as two operations).
//!
//! # Example
//!
//! ```
//! use grcim::energy::{energy_per_op, CimArch, TechParams};
//! use grcim::formats::FpFormat;
//! use grcim::mac::FormatPair;
//!
//! let tech = TechParams::default();
//! // ADC energy grows with resolution (linear + 4^ENOB thermal terms)
//! assert!(tech.e_adc(8.0) > tech.e_adc(6.0));
//!
//! let fmts = FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1());
//! let e = energy_per_op(CimArch::GrUnit, fmts, 32, 32, 6.0, &tech);
//! assert!(e.total() > 0.0);
//! let sum: f64 = e.components().iter().map(|(_, v)| *v).sum();
//! assert!((sum - e.total()).abs() < 1e-9);
//! ```

pub mod arch;
pub mod digital;

pub use arch::{energy_per_op, global_norm_energy_per_op, CimArch, EnergyBreakdown};

/// Technology/cost parameters (paper Table III: 0.9 V, 28 nm).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TechParams {
    /// Reference NAND2/NOR2 gate capacitance, fF.
    pub c_gate_ff: f64,
    /// ADC linear coefficient, fF (energy per conversion step).
    pub k1_ff: f64,
    /// ADC thermal-noise coefficient, fF (multiplies 4^ENOB). 1 aF.
    pub k2_ff: f64,
    /// DAC switching capacitance per bit, fF.
    pub k3_ff: f64,
    /// Supply voltage, V.
    pub vdd: f64,
    /// Digital softmax energy per probability element, fJ — the exp +
    /// normalize + register cost charged once per attention score
    /// (defaults to [`digital::softmax_element_fj`] at this technology
    /// point; was silently zero before PR 9, the ROADMAP-documented
    /// PR-8 undercount).
    pub e_softmax_fj: f64,
}

impl Default for TechParams {
    fn default() -> Self {
        let mut t = TechParams {
            c_gate_ff: 0.7,
            k1_ff: 100.0,
            k2_ff: 0.001, // 1 aF
            k3_ff: 50.0,
            vdd: 0.9,
            e_softmax_fj: 0.0,
        };
        t.e_softmax_fj = digital::softmax_element_fj(&t);
        t
    }
}

impl TechParams {
    /// Scale the ADC coefficients (the paper's ±10% sensitivity study).
    pub fn with_adc_scale(mut self, scale: f64) -> Self {
        self.k1_ff *= scale;
        self.k2_ff *= scale;
        self
    }

    fn v2(&self) -> f64 {
        self.vdd * self.vdd
    }

    /// ADC energy per conversion: (k1*ENOB + k2*4^ENOB) * V_DD^2.
    ///
    /// Linear term = technology-limited regime; 4^ENOB term = thermal-noise
    /// -limited regime (SAR). Crossover N_cross ~ 10 bits with Table III
    /// values (Murmann's boundary).
    pub fn e_adc(&self, enob: f64) -> f64 {
        assert!(enob >= 0.0);
        (self.k1_ff * enob + self.k2_ff * 4f64.powf(enob)) * self.v2()
    }

    /// ADC thermal/technology crossover resolution: k1*N = k2*4^N.
    pub fn adc_crossover_bits(&self) -> f64 {
        // solve by bisection; monotone in N for N >= 1
        let f = |n: f64| self.k2_ff * 4f64.powf(n) - self.k1_ff * n;
        let (mut lo, mut hi) = (1.0, 20.0);
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if f(mid) > 0.0 {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// DAC energy per conversion: k3 * res * V_DD^2.
    pub fn e_dac(&self, res_bits: f64) -> f64 {
        assert!(res_bits >= 0.0);
        self.k3_ff * res_bits * self.v2()
    }

    /// Full-adder energy: 6 * C_gate * V_DD^2.
    pub fn e_fa(&self) -> f64 {
        6.0 * self.c_gate_ff * self.v2()
    }

    /// Adder-tree energy from its full-adder count.
    pub fn e_adder_tree(&self, fa_count: f64) -> f64 {
        self.e_fa() * fa_count
    }

    /// Na x Nb multiplier: (1.5*C_gate*V^2 + E_FA) * Na * Nb.
    ///
    /// Table II gives the square-array N-bit form (N^2); the rectangular
    /// generalization keeps the same per-cell (AND + FA) cost.
    pub fn e_mult(&self, na_bits: f64, nb_bits: f64) -> f64 {
        (1.5 * self.c_gate_ff * self.v2() + self.e_fa()) * na_bits * nb_bits
    }

    /// Binary decoder: (0.5*N_in + N_out + 1) * C_gate * V_DD^2.
    pub fn e_decoder(&self, n_in: f64, n_out: f64) -> f64 {
        (0.5 * n_in + n_out + 1.0) * self.c_gate_ff * self.v2()
    }

    /// Cell-array switching for one MVM:
    /// 0.5 * C_gate * V^2 * N_SW * NR * NC.
    pub fn e_cell_array(&self, n_sw: f64, nr: usize, nc: usize) -> f64 {
        0.5 * self.c_gate_ff * self.v2() * n_sw * (nr * nc) as f64
    }
}

/// Full-adder count of a balanced binary adder tree over `n` operands of
/// `width` bits each: stage k has floor(remaining/2) adders of
/// (width + k - 1) bits. (The GR exponent trees sum one-hot magnitude
/// words — low activity, but the paper's model charges per-FA switching
/// uniformly, which is conservative for us.)
pub fn adder_tree_fa_count(n: usize, width: f64) -> f64 {
    assert!(n >= 1);
    let mut count = 0.0;
    let mut remaining = n;
    let mut stage = 1.0;
    while remaining > 1 {
        let pairs = remaining / 2;
        count += pairs as f64 * (width + stage - 1.0);
        remaining = remaining / 2 + remaining % 2;
        stage += 1.0;
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn table_iii_defaults() {
        let t = TechParams::default();
        assert_eq!(t.c_gate_ff, 0.7);
        assert_eq!(t.k1_ff, 100.0);
        assert_eq!(t.k2_ff, 0.001);
        assert_eq!(t.k3_ff, 50.0);
        assert_eq!(t.vdd, 0.9);
        // softmax per-element default comes from the digital cost model
        assert!(approx_eq(t.e_softmax_fj, digital::softmax_element_fj(&t), 1e-12));
        assert!(approx_eq(t.e_softmax_fj, 616.896, 1e-9));
    }

    #[test]
    fn adc_energy_formula() {
        let t = TechParams::default();
        // 8-bit: (100*8 + 0.001*65536) * 0.81 = (800 + 65.536)*0.81
        assert!(approx_eq(t.e_adc(8.0), 865.536 * 0.81, 1e-9));
        // linear regime dominates at low ENOB
        assert!(approx_eq(t.e_adc(4.0), (400.0 + 0.256) * 0.81, 1e-9));
    }

    #[test]
    fn adc_crossover_near_ten_bits() {
        // paper: N_cross ~ 10 bits for these parameters
        let n = TechParams::default().adc_crossover_bits();
        assert!((9.5..10.5).contains(&n), "N_cross = {n}");
    }

    #[test]
    fn adc_thermal_regime_quadruples_per_bit() {
        let t = TechParams::default();
        let r = t.e_adc(16.0) / t.e_adc(15.0);
        assert!((3.5..4.1).contains(&r), "ratio {r}");
    }

    #[test]
    fn dac_energy_linear() {
        let t = TechParams::default();
        assert!(approx_eq(t.e_dac(4.0), 50.0 * 4.0 * 0.81, 1e-12));
        assert!(approx_eq(t.e_dac(8.0), 2.0 * t.e_dac(4.0), 1e-12));
    }

    #[test]
    fn fa_and_mult_formulas() {
        let t = TechParams::default();
        assert!(approx_eq(t.e_fa(), 6.0 * 0.7 * 0.81, 1e-12));
        // square multiplier reduces to Table II's N^2 form
        let n = 5.0;
        assert!(approx_eq(
            t.e_mult(n, n),
            (1.5 * 0.7 * 0.81 + t.e_fa()) * n * n,
            1e-12
        ));
    }

    #[test]
    fn decoder_formula() {
        let t = TechParams::default();
        // 3-in, 8-out: (1.5 + 8 + 1) * 0.7 * 0.81
        assert!(approx_eq(t.e_decoder(3.0, 8.0), 10.5 * 0.7 * 0.81, 1e-12));
    }

    #[test]
    fn cell_array_scales_with_size() {
        let t = TechParams::default();
        let e32 = t.e_cell_array(4.0, 32, 32);
        let e64 = t.e_cell_array(4.0, 64, 64);
        assert!(approx_eq(e64, 4.0 * e32, 1e-12));
    }

    #[test]
    fn adder_tree_counts() {
        // 2 operands, width w: one w-bit adder
        assert_eq!(adder_tree_fa_count(2, 4.0), 4.0);
        // 4 operands: 2 adders @ w + 1 adder @ w+1
        assert_eq!(adder_tree_fa_count(4, 4.0), 2.0 * 4.0 + 5.0);
        // 1 operand: nothing to add
        assert_eq!(adder_tree_fa_count(1, 4.0), 0.0);
        // odd count: 3 operands -> 1 adder @ w, then 2 -> 1 adder @ w+1
        assert_eq!(adder_tree_fa_count(3, 4.0), 4.0 + 5.0);
    }

    #[test]
    fn adder_tree_grows_log_depth() {
        let w = 6.0;
        let f32_ = adder_tree_fa_count(32, w);
        let f64_ = adder_tree_fa_count(64, w);
        // doubling operands roughly doubles FAs (31 vs 63 adders)
        assert!(f64_ / f32_ > 1.9 && f64_ / f32_ < 2.2);
    }

    #[test]
    fn adc_sensitivity_scaling() {
        let t = TechParams::default().with_adc_scale(1.1);
        assert!(approx_eq(t.k1_ff, 110.0, 1e-12));
        assert!(approx_eq(t.k2_ff, 0.0011, 1e-12));
        assert_eq!(t.k3_ff, 50.0); // DAC untouched
    }
}
