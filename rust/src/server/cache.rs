//! Sharded LRU result cache with single-flight miss coalescing.
//!
//! The serve layer keys every expensive computation (a Monte-Carlo
//! campaign aggregate, a rendered figure) by a **canonical spec string**
//! (see [`crate::server::proto::spec_key`]) and stores the result behind
//! an `Arc`, so repeated requests share one immutable value. Two
//! guarantees matter for correctness under load:
//!
//! 1. **Bit-stable hits** — a hit returns the exact value the cold
//!    compute produced (same `Arc`), so responses rendered from it are
//!    byte-identical to the cold response.
//! 2. **Single-flight misses** — concurrent requests for the same key
//!    perform the computation exactly once; followers block on the
//!    leader's flight and receive its result. The `computes` counter
//!    therefore equals the number of distinct cold keys, which the
//!    integration test asserts directly.
//!
//! Sharding bounds lock contention: keys hash to one of
//! [`ShardedCache::SHARDS`] independently locked maps, so concurrent
//! requests for different keys rarely serialize. Eviction is
//! least-recently-used per shard (an access-tick scan — shards are small,
//! so the O(len) scan on insert is noise next to the campaigns being
//! cached).
//!
//! Every lock here goes through [`crate::util::sync`]: shard and flight
//! mutexes recover from poisoning (a panicking compute already fails its
//! flight via [`FlightGuard`]; the maps and counters stay valid), so a
//! crashed request can never wedge later lookups — and the single-flight
//! protocol itself (leader panic, follower wakeup, no key poisoning) is
//! model-checked across all interleavings in `rust/tests/loom_models.rs`.
//!
//! # Example
//!
//! ```
//! use grcim::server::cache::{Outcome, ShardedCache};
//!
//! let cache: ShardedCache<u64> = ShardedCache::new(64);
//! let (v, how) = cache.get_or_compute("answer", || Ok(42)).unwrap();
//! assert_eq!((*v, how), (42, Outcome::Computed));
//! let (v, how) = cache.get_or_compute("answer", || unreachable!()).unwrap();
//! assert_eq!((*v, how), (42, Outcome::Hit));
//! assert_eq!(cache.stats().computes, 1);
//! ```

use crate::util::sync::{cv_wait, lock_recover, Arc, AtomicU64, Condvar, Mutex, Ordering};
use anyhow::{anyhow, Result};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// How a [`ShardedCache::get_or_compute`] call was served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Served from the cache without blocking.
    Hit,
    /// Coalesced onto another thread's in-flight computation.
    Coalesced,
    /// This call ran the computation (cold miss).
    Computed,
}

impl Outcome {
    /// True when no fresh computation ran for this call.
    pub fn is_cached(&self) -> bool {
        !matches!(self, Outcome::Computed)
    }
}

/// Monotonic counters exposed by the `info` request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that found no resident entry.
    pub misses: u64,
    /// Computations actually executed (single-flight leaders only).
    pub computes: u64,
    /// Misses that waited on another thread's computation.
    pub coalesced: u64,
    /// Entries discarded by per-shard LRU eviction.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: u64,
}

impl StatsSnapshot {
    /// Render as a JSON object — the per-cache block of the `info` and
    /// `metrics` responses.
    pub fn to_json(&self) -> crate::config::Json {
        use crate::config::Json;
        crate::server::proto::obj(vec![
            ("hits", Json::Num(self.hits as f64)),
            ("misses", Json::Num(self.misses as f64)),
            ("computes", Json::Num(self.computes as f64)),
            ("coalesced", Json::Num(self.coalesced as f64)),
            ("evictions", Json::Num(self.evictions as f64)),
            ("entries", Json::Num(self.entries as f64)),
        ])
    }
}

struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    computes: AtomicU64,
    coalesced: AtomicU64,
    evictions: AtomicU64,
}

impl Default for Counters {
    // written out because the shim's loom atomics don't implement Default
    fn default() -> Self {
        Counters {
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            computes: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }
}

struct Entry<V> {
    value: Arc<V>,
    /// Last-access tick for LRU eviction.
    tick: u64,
}

struct Shard<V> {
    map: HashMap<String, Entry<V>>,
    tick: u64,
}

impl<V> Default for Shard<V> {
    fn default() -> Self {
        Shard { map: HashMap::new(), tick: 0 }
    }
}

/// One in-flight computation; followers wait on the condvar.
struct Flight<V> {
    /// `None` while pending; errors are carried as strings so followers
    /// can reconstruct them (`anyhow::Error` is not `Clone`).
    state: Mutex<Option<std::result::Result<Arc<V>, String>>>,
    cv: Condvar,
}

impl<V> Flight<V> {
    fn new() -> Self {
        Flight { state: Mutex::new(None), cv: Condvar::new() }
    }

    fn finish(&self, res: std::result::Result<Arc<V>, String>) {
        let mut st = lock_recover(&self.state);
        if st.is_none() {
            *st = Some(res);
        }
        self.cv.notify_all();
    }

    fn wait(&self) -> std::result::Result<Arc<V>, String> {
        let mut st = lock_recover(&self.state);
        loop {
            if let Some(res) = st.as_ref() {
                return res.clone();
            }
            st = cv_wait(&self.cv, st);
        }
    }
}

/// If the leader's computation panics, deregister the flight and mark it
/// failed, so followers neither wait forever nor inherit a permanently
/// poisoned key.
struct FlightGuard<'a, V> {
    flight: &'a Flight<V>,
    flights: &'a Mutex<HashMap<String, Arc<Flight<V>>>>,
    key: &'a str,
    done: bool,
}

impl<V> Drop for FlightGuard<'_, V> {
    fn drop(&mut self) {
        if !self.done {
            lock_recover(self.flights).remove(self.key);
            self.flight.finish(Err("computation panicked".into()));
        }
    }
}

/// A sharded, capacity-bounded, single-flight LRU cache.
pub struct ShardedCache<V> {
    shards: Vec<Mutex<Shard<V>>>,
    per_shard_cap: usize,
    flights: Mutex<HashMap<String, Arc<Flight<V>>>>,
    counters: Counters,
}

impl<V: Send + Sync> ShardedCache<V> {
    /// Lock stripes; capacity divides evenly across them.
    pub const SHARDS: usize = 8;

    /// A cache holding at most `capacity` entries (minimum one per shard).
    pub fn new(capacity: usize) -> Self {
        let per_shard_cap = (capacity / Self::SHARDS).max(1);
        ShardedCache {
            shards: (0..Self::SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            per_shard_cap,
            flights: Mutex::new(HashMap::new()),
            counters: Counters::default(),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<Shard<V>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Look up `key` without computing on a miss.
    pub fn get(&self, key: &str) -> Option<Arc<V>> {
        let mut shard = lock_recover(self.shard(key));
        shard.tick += 1;
        let tick = shard.tick;
        shard.map.get_mut(key).map(|e| {
            e.tick = tick;
            Arc::clone(&e.value)
        })
    }

    fn insert(&self, key: &str, value: Arc<V>) {
        let mut shard = lock_recover(self.shard(key));
        shard.tick += 1;
        let tick = shard.tick;
        if !shard.map.contains_key(key) && shard.map.len() >= self.per_shard_cap {
            // evict the least-recently-used entry of this shard
            if let Some(lru) = shard
                .map
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| k.clone())
            {
                shard.map.remove(&lru);
                self.counters.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.map.insert(key.to_string(), Entry { value, tick });
    }

    /// Return the cached value for `key`, or run `compute` exactly once
    /// across all concurrent callers and cache its result.
    ///
    /// The returned [`Outcome`] reports how this particular call was
    /// served. Errors are not cached: a failed computation is re-run by
    /// the next request for the same key (its followers receive the same
    /// error).
    pub fn get_or_compute(
        &self,
        key: &str,
        compute: impl FnOnce() -> Result<V>,
    ) -> Result<(Arc<V>, Outcome)> {
        if let Some(v) = self.get(key) {
            self.counters.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((v, Outcome::Hit));
        }

        // Become the leader or join an existing flight. The cache is
        // re-checked under the flights lock: a leader that just finished
        // inserts into the cache *before* removing its flight (also under
        // this lock), so a miss here cannot lose a completed value. The
        // miss counter is bumped only once the role is decided, keeping
        // the invariant hits + coalesced + computes == lookups exact
        // (and misses == coalesced + computes).
        let (flight, leader) = {
            let mut flights = lock_recover(&self.flights);
            if let Some(v) = self.get(key) {
                self.counters.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((v, Outcome::Hit));
            }
            self.counters.misses.fetch_add(1, Ordering::Relaxed);
            match flights.get(key) {
                Some(f) => (Arc::clone(f), false),
                None => {
                    let f = Arc::new(Flight::new());
                    flights.insert(key.to_string(), Arc::clone(&f));
                    (f, true)
                }
            }
        };

        if !leader {
            self.counters.coalesced.fetch_add(1, Ordering::Relaxed);
            return match flight.wait() {
                Ok(v) => Ok((v, Outcome::Coalesced)),
                Err(msg) => Err(anyhow!(msg)),
            };
        }

        self.counters.computes.fetch_add(1, Ordering::Relaxed);
        let mut guard = FlightGuard {
            flight: &flight,
            flights: &self.flights,
            key,
            done: false,
        };
        let result = compute();
        guard.done = true;
        drop(guard);

        match result {
            Ok(v) => {
                let v = Arc::new(v);
                {
                    // insert, then retire the flight under the flights
                    // lock (see the re-check above)
                    let mut flights = lock_recover(&self.flights);
                    self.insert(key, Arc::clone(&v));
                    flights.remove(key);
                }
                flight.finish(Ok(Arc::clone(&v)));
                Ok((v, Outcome::Computed))
            }
            Err(e) => {
                let msg = format!("{e:#}");
                {
                    lock_recover(&self.flights).remove(key);
                }
                flight.finish(Err(msg));
                Err(e)
            }
        }
    }

    /// Current counter values plus resident entry count.
    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            hits: self.counters.hits.load(Ordering::Relaxed),
            misses: self.counters.misses.load(Ordering::Relaxed),
            computes: self.counters.computes.load(Ordering::Relaxed),
            coalesced: self.counters.coalesced.load(Ordering::Relaxed),
            evictions: self.counters.evictions.load(Ordering::Relaxed),
            entries: self
                .shards
                .iter()
                .map(|s| lock_recover(s).map.len() as u64)
                .sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::AssertUnwindSafe;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Barrier;

    #[test]
    fn hit_returns_same_arc() {
        let c: ShardedCache<Vec<f64>> = ShardedCache::new(16);
        let (a, o1) = c.get_or_compute("k", || Ok(vec![1.0, 2.0])).unwrap();
        let (b, o2) = c.get_or_compute("k", || Ok(vec![9.0])).unwrap();
        assert_eq!(o1, Outcome::Computed);
        assert_eq!(o2, Outcome::Hit);
        assert!(Arc::ptr_eq(&a, &b));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.computes), (1, 1, 1));
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn errors_are_not_cached() {
        let c: ShardedCache<u32> = ShardedCache::new(16);
        assert!(c.get_or_compute("k", || anyhow::bail!("nope")).is_err());
        let (v, o) = c.get_or_compute("k", || Ok(7)).unwrap();
        assert_eq!((*v, o), (7, Outcome::Computed));
        assert_eq!(c.stats().computes, 2);
    }

    #[test]
    fn concurrent_identical_keys_compute_once() {
        const THREADS: usize = 8;
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let c: Arc<ShardedCache<u64>> = Arc::new(ShardedCache::new(16));
        let barrier = Arc::new(Barrier::new(THREADS));
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let c = Arc::clone(&c);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    let (v, _) = c
                        .get_or_compute("shared", || {
                            CALLS.fetch_add(1, Ordering::SeqCst);
                            // widen the race window
                            std::thread::sleep(
                                std::time::Duration::from_millis(20),
                            );
                            Ok(99)
                        })
                        .unwrap();
                    *v
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 99);
        }
        assert_eq!(CALLS.load(Ordering::SeqCst), 1, "single-flight violated");
        assert_eq!(c.stats().computes, 1);
    }

    #[test]
    fn followers_see_leader_error() {
        let c: Arc<ShardedCache<u64>> = Arc::new(ShardedCache::new(16));
        let barrier = Arc::new(Barrier::new(2));
        let c2 = Arc::clone(&c);
        let b2 = Arc::clone(&barrier);
        let follower = std::thread::spawn(move || {
            b2.wait();
            // let the leader claim the flight first
            std::thread::sleep(std::time::Duration::from_millis(10));
            c2.get_or_compute("k", || Ok(1)).map(|(v, o)| (*v, o))
        });
        barrier.wait();
        let lead = c.get_or_compute("k", || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            anyhow::bail!("leader failed")
        });
        // overwhelmingly this thread leads (the follower sleeps first);
        // if scheduling inverts the race, it coalesced onto the
        // follower's successful compute instead — both are valid
        if let Err(e) = &lead {
            assert!(format!("{e:#}").contains("leader failed"));
        }
        // the follower either coalesced onto the failing flight (error),
        // arrived after its removal and recomputed, or led successfully
        match follower.join().unwrap() {
            Err(e) => assert!(format!("{e:#}").contains("leader failed")),
            Ok((v, _)) => assert_eq!(v, 1),
        }
    }

    #[test]
    fn panicking_leader_wakes_followers_and_does_not_poison_the_key() {
        // the single-flight audit this pins: if the leader's compute
        // panics (not Errs), FlightGuard must deregister the flight and
        // fail it, so (a) followers blocked on the Condvar wake with an
        // error or recompute — never hang — and (b) the next request for
        // the key computes fresh instead of inheriting a dead flight
        let c: Arc<ShardedCache<u64>> = Arc::new(ShardedCache::new(16));
        let barrier = Arc::new(Barrier::new(2));

        let c_leader = Arc::clone(&c);
        let b_leader = Arc::clone(&barrier);
        let leader = std::thread::spawn(move || {
            b_leader.wait();
            // the follower sleeps first, so this thread claims the flight
            let _ = std::panic::catch_unwind(AssertUnwindSafe(|| {
                c_leader.get_or_compute("k", || -> Result<u64> {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    panic!("compute exploded");
                })
            }));
        });

        let c_follower = Arc::clone(&c);
        let b_follower = Arc::clone(&barrier);
        let follower = std::thread::spawn(move || {
            b_follower.wait();
            std::thread::sleep(std::time::Duration::from_millis(10));
            c_follower.get_or_compute("k", || Ok(5)).map(|(v, o)| (*v, o))
        });

        leader.join().unwrap();
        // the follower either coalesced onto the panicked flight (clean
        // error naming the panic) or arrived after its removal and
        // computed fresh — both are fine; hanging is not (join returns)
        match follower.join().unwrap() {
            Err(e) => {
                assert!(format!("{e:#}").contains("panicked"), "{e:#}")
            }
            Ok((v, _)) => assert_eq!(v, 5),
        }
        // the key is not poisoned: a later request computes normally
        let (v, o) = c.get_or_compute("k", || Ok(7)).unwrap();
        assert!(*v == 5 || *v == 7, "got {v}");
        assert!(matches!(o, Outcome::Computed | Outcome::Hit));
        let (v2, _) = c.get_or_compute("k", || Ok(9)).unwrap();
        assert_eq!(*v2, *v, "cached value must be stable");
    }

    #[test]
    fn poisoned_shard_lock_recovers() {
        // a thread panicking while holding a shard lock (anything
        // unwinding through a cache call) poisons the std Mutex; every
        // later lookup must recover instead of propagating the panic —
        // the rendered-response caches serve `info`/`metrics` inline and
        // must never wedge
        let c: Arc<ShardedCache<u32>> = Arc::new(ShardedCache::new(16));
        c.get_or_compute("k", || Ok(1)).unwrap();
        let c2 = Arc::clone(&c);
        let _ = std::thread::spawn(move || {
            let _guard = c2.shard("k").lock();
            panic!("poison the shard");
        })
        .join();
        let (v, o) = c.get_or_compute("k", || Ok(9)).unwrap();
        assert_eq!((*v, o), (1, Outcome::Hit), "poisoned shard lost its entry");
        assert_eq!(c.stats().entries, 1);
    }

    #[test]
    fn lru_evicts_oldest_within_shard() {
        // capacity 8 over 8 shards = 1 entry per shard: inserting two keys
        // that land in the same shard must evict the older one
        let c: ShardedCache<u32> = ShardedCache::new(8);
        let keys: Vec<String> = (0..64).map(|i| format!("k{i}")).collect();
        for (i, k) in keys.iter().enumerate() {
            c.get_or_compute(k, || Ok(i as u32)).unwrap();
        }
        let s = c.stats();
        assert!(s.entries as usize <= ShardedCache::<u32>::SHARDS);
        assert_eq!(s.evictions, 64 - s.entries);
        // most recent key per shard survives; re-getting an evicted key
        // recomputes
        assert!(c.get("k0").is_none() || c.get("k63").is_some());
    }

    #[test]
    fn distinct_keys_do_not_coalesce() {
        let c: ShardedCache<u32> = ShardedCache::new(16);
        c.get_or_compute("a", || Ok(1)).unwrap();
        c.get_or_compute("b", || Ok(2)).unwrap();
        let s = c.stats();
        assert_eq!(s.computes, 2);
        assert_eq!(s.coalesced, 0);
        assert_eq!(*c.get("a").unwrap(), 1);
        assert_eq!(*c.get("b").unwrap(), 2);
    }
}
