//! `grcim loadgen` — a concurrent load generator for the serve core.
//!
//! Opens many simultaneous connections (phase 1, synchronized on a
//! barrier so they are all open at once), then drives rounds of
//! requests over every connection (phase 2). Each round writes one
//! request per connection before reading any response, so up to one
//! request per *connection* — not per driver thread — is in flight at a
//! time, which is exactly the per-connection ordering the server
//! guarantees.
//!
//! Beyond raw load, the generator checks the server's core caching
//! contract: every response to the same deterministic request line must
//! be **byte-identical** across all connections and rounds (cache hits
//! return the stored bytes). `info`/`metrics` lines are exempt — their
//! counters legitimately change between calls. Typed `busy` and
//! `deadline` errors are tallied separately from real errors: under
//! deliberate overload they are correct behavior, not failures.
//!
//! An optional slow-loris mode (`loris_ms`) writes the first half of
//! every request line, stalls, then completes it — proving the event
//! loop's muxes keep serving other connections while thousands of
//! half-written lines sit in their accumulators.

use crate::config::Json;
use crate::server::proto::obj;
use crate::util::sync::{lock_recover, panic_msg, Barrier, Mutex};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// How long a driver waits for one response line before declaring the
/// request failed (covers cold multi-second campaigns under load).
const READ_TIMEOUT: Duration = Duration::from_secs(120);

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address to connect to.
    pub addr: String,
    /// Concurrent connections to hold open.
    pub conns: usize,
    /// Requests sent per connection (rounds).
    pub per_conn: usize,
    /// Request lines to cycle through; connection `c` sends line
    /// `(c + round) % lines.len()` each round, so every line sees many
    /// connections and every connection sees a mix of lines.
    pub lines: Vec<String>,
    /// Driver threads (0 = auto: one per 125 connections, 1–8). Each
    /// drives a contiguous share of the connections.
    pub threads: usize,
    /// When nonzero, slow-loris every request: write half the line,
    /// stall this many milliseconds, then complete it.
    pub loris_ms: u64,
}

/// What one load-generation run observed.
#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    /// Connections successfully opened (all held concurrently).
    pub connected: u64,
    /// Connections that failed to open.
    pub connect_errors: u64,
    /// Request lines written.
    pub sent: u64,
    /// `"ok":true` responses.
    pub ok: u64,
    /// Typed `busy` rejections (admission control working as designed).
    pub busy: u64,
    /// Typed `deadline` rejections.
    pub deadline: u64,
    /// Everything else: error responses, short reads, timeouts.
    pub errors: u64,
    /// Deterministic request lines whose response bytes differed from
    /// the first `ok` response to the same line. Must be zero: cache
    /// hits are byte-identical by construction.
    pub divergent: u64,
    /// `ok` responses per request line (index-aligned with the config's
    /// `lines`).
    pub ok_per_line: Vec<u64>,
    /// Wall-clock time of the whole run, milliseconds.
    pub elapsed_ms: u64,
}

impl LoadgenReport {
    /// True when the run saw no hard failures (`busy`/`deadline` are
    /// tolerated — they are typed backpressure, not breakage).
    pub fn clean(&self) -> bool {
        self.connect_errors == 0 && self.errors == 0 && self.divergent == 0
    }

    /// Render as JSON (the `grcim loadgen` output).
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        obj(vec![
            ("connected", n(self.connected)),
            ("connect_errors", n(self.connect_errors)),
            ("sent", n(self.sent)),
            ("ok", n(self.ok)),
            ("busy", n(self.busy)),
            ("deadline", n(self.deadline)),
            ("errors", n(self.errors)),
            ("divergent", n(self.divergent)),
            (
                "ok_per_line",
                Json::Arr(self.ok_per_line.iter().map(|&v| n(v)).collect()),
            ),
            ("elapsed_ms", n(self.elapsed_ms)),
            ("clean", Json::Bool(self.clean())),
        ])
    }
}

/// One driver-side connection: the stream plus a carry-over read buffer
/// (a read can return bytes past the newline).
struct ClientConn {
    id: usize,
    stream: TcpStream,
    buf: Vec<u8>,
}

impl ClientConn {
    /// Read one newline-terminated response line (blocking, bounded by
    /// the stream's read timeout).
    fn read_line(&mut self) -> Result<String> {
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(i) = self.buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.buf.drain(..=i).collect();
                return Ok(String::from_utf8_lossy(&line).trim_end().to_string());
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => bail!("server closed the connection mid-response"),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("reading response"),
            }
        }
    }
}

#[derive(Default)]
struct Counts {
    connected: u64,
    connect_errors: u64,
    sent: u64,
    ok: u64,
    busy: u64,
    deadline: u64,
    errors: u64,
    divergent: u64,
    ok_per_line: Vec<u64>,
}

/// Lines whose responses are deterministic (everything except
/// `info`/`metrics`, whose counters move between calls) take part in
/// the byte-identity check.
fn deterministic_lines(lines: &[String]) -> Vec<bool> {
    lines
        .iter()
        .map(|l| {
            !matches!(
                Json::parse(l).ok().as_ref().and_then(|j| j.get("cmd")).and_then(Json::as_str),
                Some("info") | Some("metrics")
            )
        })
        .collect()
}

fn drive(
    cfg: &LoadgenConfig,
    ids: std::ops::Range<usize>,
    deterministic: &[bool],
    refs: &[Mutex<Option<String>>],
    barrier: &Barrier,
) -> Counts {
    let mut c = Counts { ok_per_line: vec![0; cfg.lines.len()], ..Counts::default() };
    // phase 1: open this thread's share of the connections; they all
    // stay open for the whole run
    let mut conns: Vec<ClientConn> = Vec::new();
    for id in ids {
        match TcpStream::connect(&cfg.addr) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                c.connected += 1;
                conns.push(ClientConn { id, stream, buf: Vec::new() });
            }
            Err(_) => c.connect_errors += 1,
        }
    }
    barrier.wait(); // every thread's connections are open before any request flows

    // phase 2: rounds of one request per connection; all writes land
    // before any read, so the whole connection set is in flight at once
    for round in 0..cfg.per_conn {
        let mut alive: Vec<bool> = Vec::with_capacity(conns.len());
        for conn in conns.iter_mut() {
            let line = cfg.lines[(conn.id + round) % cfg.lines.len()].as_bytes();
            let first = if cfg.loris_ms > 0 { &line[..line.len() / 2] } else { line };
            let ok = conn.stream.write_all(first).is_ok();
            if ok {
                c.sent += 1;
            } else {
                c.errors += 1;
            }
            alive.push(ok);
        }
        if cfg.loris_ms > 0 {
            // every connection now holds a half-written line server-side
            std::thread::sleep(Duration::from_millis(cfg.loris_ms));
            for (conn, ok) in conns.iter_mut().zip(alive.iter_mut()) {
                if !*ok {
                    continue;
                }
                let line = cfg.lines[(conn.id + round) % cfg.lines.len()].as_bytes();
                *ok = conn.stream.write_all(&line[line.len() / 2..]).is_ok();
                if !*ok {
                    c.errors += 1;
                }
            }
        }
        for (conn, ok) in conns.iter_mut().zip(alive.iter()) {
            if *ok && conn.stream.write_all(b"\n").is_err() {
                c.errors += 1;
                continue;
            }
            if !*ok {
                continue;
            }
            let li = (conn.id + round) % cfg.lines.len();
            match conn.read_line() {
                Err(_) => c.errors += 1,
                Ok(resp) => match Json::parse(&resp) {
                    Err(_) => c.errors += 1,
                    Ok(j) if j.get("ok") == Some(&Json::Bool(true)) => {
                        c.ok += 1;
                        c.ok_per_line[li] += 1;
                        if deterministic[li] {
                            let mut slot = lock_recover(&refs[li]);
                            match slot.as_ref() {
                                None => *slot = Some(resp),
                                Some(first) if *first != resp => c.divergent += 1,
                                Some(_) => {}
                            }
                        }
                    }
                    Ok(j) => match j.get("kind").and_then(Json::as_str) {
                        Some("busy") => c.busy += 1,
                        Some("deadline") => c.deadline += 1,
                        _ => c.errors += 1,
                    },
                },
            }
        }
    }
    c
}

/// Run one load-generation campaign against a serve instance.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.conns == 0 || cfg.per_conn == 0 || cfg.lines.is_empty() {
        bail!("loadgen needs at least one connection, one round, and one request line");
    }
    let threads = if cfg.threads > 0 {
        cfg.threads.min(cfg.conns)
    } else {
        (cfg.conns / 125).clamp(1, 8)
    };
    let deterministic = deterministic_lines(&cfg.lines);
    let refs: Vec<Mutex<Option<String>>> =
        cfg.lines.iter().map(|_| Mutex::new(None)).collect();
    let barrier = Barrier::new(threads);
    let start = Instant::now();

    let counts: Result<Vec<Counts>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let lo = t * cfg.conns / threads;
            let hi = (t + 1) * cfg.conns / threads;
            let (deterministic, refs, barrier) = (&deterministic, &refs, &barrier);
            handles.push(s.spawn(move || drive(cfg, lo..hi, deterministic, refs, barrier)));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join().map_err(|p| {
                    anyhow!("loadgen driver thread panicked: {}", panic_msg(&*p))
                })
            })
            .collect()
    });
    let counts = counts?;

    let mut r = LoadgenReport {
        ok_per_line: vec![0; cfg.lines.len()],
        elapsed_ms: start.elapsed().as_millis() as u64,
        ..LoadgenReport::default()
    };
    for c in counts {
        r.connected += c.connected;
        r.connect_errors += c.connect_errors;
        r.sent += c.sent;
        r.ok += c.ok;
        r.busy += c.busy;
        r.deadline += c.deadline;
        r.errors += c.errors;
        r.divergent += c.divergent;
        for (total, v) in r.ok_per_line.iter_mut().zip(&c.ok_per_line) {
            *total += v;
        }
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_and_metrics_are_exempt_from_identity_checks() {
        let lines = vec![
            r#"{"cmd":"info"}"#.to_string(),
            r#"{"cmd":"metrics"}"#.to_string(),
            r#"{"cmd":"energy","dr":30.0,"sqnr":22.0}"#.to_string(),
            "not json at all".to_string(),
        ];
        assert_eq!(deterministic_lines(&lines), vec![false, false, true, true]);
    }

    #[test]
    fn report_json_carries_every_counter() {
        let r = LoadgenReport {
            connected: 10,
            sent: 20,
            ok: 18,
            busy: 2,
            ok_per_line: vec![9, 9],
            elapsed_ms: 5,
            ..LoadgenReport::default()
        };
        assert!(r.clean());
        let j = r.to_json();
        assert_eq!(j.get("connected").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("busy").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("clean"), Some(&Json::Bool(true)));
        assert_eq!(j.get("ok_per_line").unwrap().items().len(), 2);
        let bad = LoadgenReport { divergent: 1, ..LoadgenReport::default() };
        assert!(!bad.clean());
    }

    #[test]
    fn run_rejects_empty_configs() {
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:1".to_string(),
            conns: 0,
            per_conn: 1,
            lines: vec![r#"{"cmd":"info"}"#.to_string()],
            threads: 0,
            loris_ms: 0,
        };
        assert!(run(&cfg).is_err());
    }
}
