//! Wire protocol of `grcim serve`: newline-delimited JSON over TCP, plus
//! the canonical spec keys the result cache is addressed with.
//!
//! Every request is one JSON object on one line with a `"cmd"` field;
//! every response is one JSON object on one line:
//!
//! ```text
//! -> {"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":4096}
//! <- {"ok":true,"cached":false,"result":{...}}
//! -> {"cmd":"nonsense"}
//! <- {"ok":false,"error":"unknown cmd 'nonsense' (energy|sweep|figure|info)"}
//! ```
//!
//! The `"cached"` flag sits **outside** `"result"` so clients (and the
//! integration test) can compare the result payload of a cache hit
//! byte-for-byte against the cold compute — numbers serialize in shortest
//! round-trip form, so bit-identical aggregates produce identical result
//! strings.
//!
//! # Example
//!
//! ```
//! use grcim::server::proto::{parse_request, Request};
//!
//! let req = parse_request(r#"{"cmd":"energy","dr":30.1,"sqnr":22.83}"#).unwrap();
//! match req {
//!     Request::Energy { dr_db, sqnr_db, .. } => {
//!         assert_eq!(dr_db, 30.1);
//!         assert_eq!(sqnr_db, 22.83);
//!     }
//!     _ => panic!("wrong request kind"),
//! }
//! assert!(parse_request("{\"cmd\":\"warp\"}").is_err());
//! ```

use crate::config::Json;
use crate::coordinator::ExperimentSpec;
use crate::distributions::Distribution;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Protocol revision; bumped on any incompatible wire or key change.
pub const PROTO_VERSION: u64 = 1;

/// Default Monte-Carlo samples for `energy`/`sweep` requests — one
/// definition shared with the sweep-TOML path so the CLI and the service
/// cannot drift.
pub const DEFAULT_SAMPLES: usize = crate::cli::sweep::DEFAULT_SAMPLES;

/// Largest seed a JSON number can carry exactly (2^53; JSON numbers are
/// f64). Larger seeds are rejected rather than silently truncated.
pub const MAX_JSON_SEED: u64 = 1 << 53;
/// Default samples for `figure` requests (the `--quick` figure budget —
/// figures sweep many campaign points, so the service default is modest).
pub const DEFAULT_FIGURE_SAMPLES: usize = 8_192;

/// One `[[experiment]]`-shaped entry of a `sweep` request.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepExperiment {
    pub name: String,
    pub n_e: f64,
    pub n_m: f64,
    pub nr: usize,
    pub distribution: String,
}

/// A parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Server, engine, and cache status.
    Info,
    /// Energy model at one (DR, SQNR) spec point — the Fig. 12 query unit.
    Energy {
        dr_db: f64,
        sqnr_db: f64,
        samples: usize,
        seed: Option<u64>,
    },
    /// A campaign over explicit experiments (the TOML sweep, as JSON).
    Sweep {
        samples: usize,
        seed: Option<u64>,
        experiments: Vec<SweepExperiment>,
    },
    /// Regenerate one paper figure/table and return it as JSON.
    Figure {
        id: String,
        samples: usize,
        seed: Option<u64>,
    },
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line.trim()).context("request is not valid JSON")?;
    let cmd = j
        .get("cmd")
        .and_then(Json::as_str)
        .context("request needs a string 'cmd' field")?;
    let seed = match j.get("seed").and_then(Json::as_f64) {
        None => None,
        Some(s) => {
            if s < 0.0 || s.fract() != 0.0 || s > MAX_JSON_SEED as f64 {
                bail!(
                    "seed must be a non-negative integer <= 2^53 \
                     (JSON numbers are f64), got {s}"
                );
            }
            Some(s as u64)
        }
    };
    match cmd {
        "info" => Ok(Request::Info),
        "energy" => Ok(Request::Energy {
            dr_db: j.get("dr").and_then(Json::as_f64).unwrap_or(30.1),
            sqnr_db: j.get("sqnr").and_then(Json::as_f64).unwrap_or(22.83),
            samples: j
                .get("samples")
                .and_then(Json::as_usize)
                .unwrap_or(DEFAULT_SAMPLES),
            seed,
        }),
        "sweep" => {
            let mut experiments = Vec::new();
            let items = j
                .get("experiments")
                .context("sweep needs an 'experiments' array")?
                .items();
            for e in items {
                experiments.push(SweepExperiment {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .context("experiment needs a name")?
                        .to_string(),
                    n_e: e.get("n_e").and_then(Json::as_f64).unwrap_or(2.0),
                    n_m: e.get("n_m").and_then(Json::as_f64).unwrap_or(2.0),
                    nr: e.get("nr").and_then(Json::as_usize).unwrap_or(32),
                    distribution: e
                        .get("distribution")
                        .and_then(Json::as_str)
                        .unwrap_or("uniform")
                        .to_string(),
                });
            }
            if experiments.is_empty() {
                bail!("sweep has no experiments");
            }
            Ok(Request::Sweep {
                samples: j
                    .get("samples")
                    .and_then(Json::as_usize)
                    .unwrap_or(DEFAULT_SAMPLES),
                seed,
                experiments,
            })
        }
        "figure" => Ok(Request::Figure {
            id: j
                .get("id")
                .and_then(Json::as_str)
                .context("figure needs an 'id' field")?
                .to_string(),
            samples: j
                .get("samples")
                .and_then(Json::as_usize)
                .unwrap_or(DEFAULT_FIGURE_SAMPLES),
            seed,
        }),
        other => bail!("unknown cmd '{other}' (energy|sweep|figure|info)"),
    }
}

/// Build a JSON object from key/value pairs (stable key order courtesy of
/// the underlying `BTreeMap`).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Render a success response line (no trailing newline).
pub fn ok_line(result: Json, cached: bool) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("cached", Json::Bool(cached)),
        ("result", result),
    ])
    .to_string()
}

/// Render an error response line (no trailing newline).
pub fn err_line(message: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(message.to_string())),
    ])
    .to_string()
}

/// Hex of the exact bit pattern of an `f64` — canonical-key fragments must
/// distinguish parameters that differ in any bit (display rounding like
/// `{:.3}` would alias nearby design-space points onto one key).
fn bits(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn canonical_dist(d: &Distribution) -> String {
    match d {
        Distribution::Uniform => "uniform".into(),
        Distribution::MaxEntropy(me) => {
            let f = me.format();
            format!("maxent:{}:{}", bits(f.e_max), bits(f.n_m))
        }
        Distribution::GaussOutliers(p) => {
            format!("gaussout:{}:{}", bits(p.eps), bits(p.k))
        }
        Distribution::ClippedGauss { clip_sigmas } => {
            format!("clipgauss:{}", bits(*clip_sigmas))
        }
        Distribution::UniformScaled { r } => format!("uscaled:{}", bits(*r)),
    }
}

/// Canonical cache key of one experiment's campaign aggregate.
///
/// Covers exactly the inputs that determine the aggregate bit pattern:
/// both formats (exact bits), both distributions (exact parameter bits),
/// array depth, requested samples, campaign seed, and the engine kind.
/// The experiment `id` is deliberately excluded (it labels reports, it
/// does not seed anything), as is the worker count (aggregates are
/// bit-identical for any worker count — a coordinator invariant asserted
/// in `rust/tests/properties.rs`).
pub fn spec_key(spec: &ExperimentSpec, seed: u64, engine: &str) -> String {
    format!(
        "v{PROTO_VERSION}|agg|eng={engine}|seed={seed}|nr={}|n={}|x={}:{}|w={}:{}|dx={}|dw={}",
        spec.nr,
        spec.samples,
        bits(spec.fmts.x.e_max),
        bits(spec.fmts.x.n_m),
        bits(spec.fmts.w.e_max),
        bits(spec.fmts.w.n_m),
        canonical_dist(&spec.dist_x),
        canonical_dist(&spec.dist_w),
    )
}

/// Canonical cache key of one rendered figure.
pub fn figure_key(id: &str, samples: usize, seed: u64, engine: &str) -> String {
    format!("v{PROTO_VERSION}|fig|eng={engine}|seed={seed}|n={samples}|id={id}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;
    use crate::mac::FormatPair;

    fn spec() -> ExperimentSpec {
        ExperimentSpec {
            id: "t".into(),
            fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: 4096,
        }
    }

    #[test]
    fn parses_every_request_kind() {
        assert_eq!(parse_request(r#"{"cmd":"info"}"#).unwrap(), Request::Info);
        let e = parse_request(
            r#"{"cmd":"energy","dr":36.12,"sqnr":28.85,"samples":2048,"seed":9}"#,
        )
        .unwrap();
        assert_eq!(
            e,
            Request::Energy {
                dr_db: 36.12,
                sqnr_db: 28.85,
                samples: 2048,
                seed: Some(9)
            }
        );
        let s = parse_request(
            r#"{"cmd":"sweep","samples":1024,"experiments":[
                {"name":"a","n_e":3,"n_m":2,"nr":32,"distribution":"uniform"}]}"#,
        )
        .unwrap();
        match s {
            Request::Sweep { samples, seed, experiments } => {
                assert_eq!(samples, 1024);
                assert_eq!(seed, None);
                assert_eq!(experiments.len(), 1);
                assert_eq!(experiments[0].name, "a");
                assert_eq!(experiments[0].distribution, "uniform");
            }
            other => panic!("{other:?}"),
        }
        let f = parse_request(r#"{"cmd":"figure","id":"table1"}"#).unwrap();
        assert_eq!(
            f,
            Request::Figure {
                id: "table1".into(),
                samples: DEFAULT_FIGURE_SAMPLES,
                seed: None
            }
        );
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"no_cmd":1}"#).is_err());
        assert!(parse_request(r#"{"cmd":"warp"}"#).is_err());
        // seeds a JSON f64 cannot carry exactly are rejected, not aliased
        assert!(parse_request(r#"{"cmd":"info","seed":-1}"#).is_err());
        assert!(parse_request(r#"{"cmd":"info","seed":1.5}"#).is_err());
        assert!(
            parse_request(r#"{"cmd":"info","seed":18446744073709551615}"#)
                .is_err()
        );
        assert!(parse_request(r#"{"cmd":"figure"}"#).is_err()); // no id
        assert!(parse_request(r#"{"cmd":"sweep","experiments":[]}"#).is_err());
        assert!(
            parse_request(r#"{"cmd":"sweep","experiments":[{"n_e":2}]}"#)
                .is_err(),
            "experiment without a name must be rejected"
        );
    }

    #[test]
    fn response_lines_are_parseable_json() {
        let ok = ok_line(obj(vec![("x", Json::Num(1.5))]), true);
        let j = Json::parse(&ok).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(j.get("result").unwrap().get("x").unwrap().as_f64(), Some(1.5));

        let err = err_line("boom \"quoted\"");
        let j = Json::parse(&err).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("boom \"quoted\""));
    }

    #[test]
    fn spec_key_distinguishes_every_input() {
        let base = spec();
        let k0 = spec_key(&base, 7, "rust");
        // id does NOT participate
        let mut renamed = base.clone();
        renamed.id = "other".into();
        assert_eq!(spec_key(&renamed, 7, "rust"), k0);
        // everything else does
        let mut m = base.clone();
        m.nr = 64;
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        let mut m = base.clone();
        m.samples = 8192;
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        let mut m = base.clone();
        m.fmts = FormatPair::new(FpFormat::fp(3, 3), FpFormat::fp4_e2m1());
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        let mut m = base.clone();
        m.dist_x = Distribution::clipped_gauss4();
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        assert_ne!(spec_key(&base, 8, "rust"), k0);
        assert_ne!(spec_key(&base, 7, "pjrt"), k0);
    }

    #[test]
    fn spec_key_separates_nearby_scaled_distributions() {
        // display rounding would alias these; exact bits must not
        let mut a = spec();
        a.dist_x = Distribution::UniformScaled { r: 0.001953125 };
        let mut b = spec();
        b.dist_x = Distribution::UniformScaled { r: 0.0019531251 };
        assert_ne!(spec_key(&a, 7, "rust"), spec_key(&b, 7, "rust"));
    }

    #[test]
    fn figure_keys_are_distinct() {
        let a = figure_key("fig9", 1024, 7, "rust");
        assert_ne!(a, figure_key("fig10", 1024, 7, "rust"));
        assert_ne!(a, figure_key("fig9", 2048, 7, "rust"));
        assert_ne!(a, figure_key("fig9", 1024, 8, "rust"));
    }
}
