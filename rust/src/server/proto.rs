//! Wire protocol of `grcim serve`: newline-delimited JSON over TCP, plus
//! the canonical spec keys the result cache is addressed with.
//!
//! Every request is one JSON object on one line with a `"cmd"` field;
//! every response is one JSON object on one line:
//!
//! ```text
//! -> {"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":4096}
//! <- {"ok":true,"cached":false,"result":{...}}
//! -> {"cmd":"nonsense"}
//! <- {"ok":false,"kind":"bad_request","error":"unknown cmd 'nonsense' (energy|sweep|figure|workload|layer|model|pareto|metrics|info)"}
//! ```
//!
//! Error responses carry a `"kind"` tag so clients can react without
//! string-matching the message: `bad_request` (the line could not be
//! parsed as a request), `busy` (admission control rejected the
//! request — retry later), `deadline` (the request's `deadline_ms`
//! expired before a response was ready), and `error` (validation or
//! the computation itself failed).
//!
//! Any request may carry `"deadline_ms"`: a positive number of
//! milliseconds after which the server abandons the request and
//! answers with a `deadline` error instead (see `docs/CLI.md`).
//!
//! The `"cached"` flag sits **outside** `"result"` so clients (and the
//! integration test) can compare the result payload of a cache hit
//! byte-for-byte against the cold compute — numbers serialize in shortest
//! round-trip form, so bit-identical aggregates produce identical result
//! strings.
//!
//! # Example
//!
//! ```
//! use grcim::server::proto::{parse_request, Request};
//!
//! let req = parse_request(r#"{"cmd":"energy","dr":30.1,"sqnr":22.83}"#).unwrap();
//! match req {
//!     Request::Energy { dr_db, sqnr_db, .. } => {
//!         assert_eq!(dr_db, 30.1);
//!         assert_eq!(sqnr_db, 22.83);
//!     }
//!     _ => panic!("wrong request kind"),
//! }
//! assert!(parse_request("{\"cmd\":\"warp\"}").is_err());
//! ```

use crate::cli::sweep::{LayerParams, ModelParams};
use crate::config::Json;
use crate::coordinator::ExperimentSpec;
use crate::distributions::{Distribution, Sampler};
use crate::model::ModelSpec;
use crate::tile::LayerSpec;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// Protocol revision; bumped on any incompatible wire or key change.
pub const PROTO_VERSION: u64 = 1;

/// Default Monte-Carlo samples for `energy`/`sweep` requests — one
/// definition shared with the sweep-TOML path so the CLI and the service
/// cannot drift.
pub const DEFAULT_SAMPLES: usize = crate::cli::sweep::DEFAULT_SAMPLES;

/// Largest seed a JSON number can carry exactly (2^53; JSON numbers are
/// f64). Larger seeds are rejected rather than silently truncated.
pub const MAX_JSON_SEED: u64 = 1 << 53;
/// Default samples for `figure` requests (the `--quick` figure budget —
/// figures sweep many campaign points, so the service default is modest).
pub const DEFAULT_FIGURE_SAMPLES: usize = 8_192;

/// One `[[experiment]]`-shaped entry of a `sweep` request.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepExperiment {
    /// Experiment label (reports only).
    pub name: String,
    /// Input exponent bits.
    pub n_e: f64,
    /// Input mantissa bits.
    pub n_m: f64,
    /// Array depth.
    pub nr: usize,
    /// Input distribution name (see `cli::sweep::dist_by_name`).
    pub distribution: String,
}

/// The kind of a request — the unit the server dispatches, caches, and
/// meters by. `Metrics` and `Info` are *inline* kinds (answered on the
/// connection multiplexer without touching the compute pool); everything
/// else goes through admission control and a compute worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RequestKind {
    /// Server, engine, and cache status.
    Info,
    /// Server metrics snapshot (counters, queue depth, latency).
    Metrics,
    /// One (DR, SQNR) energy spec point.
    Energy,
    /// A campaign over explicit experiments.
    Sweep,
    /// One rendered paper figure/table.
    Figure,
    /// One empirical-trace workload report.
    Workload,
    /// One tiled-layer report.
    Layer,
    /// One chained-model report.
    Model,
    /// One design-space Pareto exploration (a full plan grid).
    Pareto,
}

impl RequestKind {
    /// Every kind, in wire-protocol order (indexes the per-kind metrics).
    pub const ALL: [RequestKind; 9] = [
        RequestKind::Info,
        RequestKind::Metrics,
        RequestKind::Energy,
        RequestKind::Sweep,
        RequestKind::Figure,
        RequestKind::Workload,
        RequestKind::Layer,
        RequestKind::Model,
        RequestKind::Pareto,
    ];

    /// The wire name (`"cmd"` value) of this kind.
    pub fn name(self) -> &'static str {
        match self {
            RequestKind::Info => "info",
            RequestKind::Metrics => "metrics",
            RequestKind::Energy => "energy",
            RequestKind::Sweep => "sweep",
            RequestKind::Figure => "figure",
            RequestKind::Workload => "workload",
            RequestKind::Layer => "layer",
            RequestKind::Model => "model",
            RequestKind::Pareto => "pareto",
        }
    }

    /// Index of this kind in [`RequestKind::ALL`].
    pub fn index(self) -> usize {
        RequestKind::ALL.iter().position(|k| *k == self).expect("kind in ALL")
    }

    /// Inline kinds are answered directly by the connection multiplexer —
    /// they read shared counters and never run a campaign, so routing
    /// them through the bounded compute queue would only add latency.
    pub fn is_inline(self) -> bool {
        matches!(self, RequestKind::Info | RequestKind::Metrics)
    }
}

/// A parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Server, engine, and cache status.
    Info,
    /// Server metrics snapshot: request/error counters, cache stats,
    /// queue depth, and per-kind latency percentiles.
    Metrics,
    /// Energy model at one (DR, SQNR) spec point — the Fig. 12 query unit.
    Energy {
        /// Dynamic range, dB.
        dr_db: f64,
        /// SQNR, dB.
        sqnr_db: f64,
        /// Monte-Carlo samples per campaign point.
        samples: usize,
        /// Campaign seed override (server default when absent).
        seed: Option<u64>,
        /// Estimator mode (`"sampler"` field; plain when absent).
        sampler: Sampler,
    },
    /// A campaign over explicit experiments (the TOML sweep, as JSON).
    Sweep {
        /// Monte-Carlo samples per experiment.
        samples: usize,
        /// Campaign seed override (server default when absent).
        seed: Option<u64>,
        /// Estimator mode (`"sampler"` field; plain when absent).
        sampler: Sampler,
        /// The experiment grid.
        experiments: Vec<SweepExperiment>,
    },
    /// Regenerate one paper figure/table and return it as JSON.
    Figure {
        /// Figure id (one of [`crate::figures::ALL`]).
        id: String,
        /// Monte-Carlo samples per campaign point.
        samples: usize,
        /// Campaign seed override (server default when absent).
        seed: Option<u64>,
    },
    /// Evaluate a named layer shape on the tiled array mapper (`grcim
    /// layer` over the wire): per-tile ENOB + energy, layer totals, ADC
    /// histogram. Cached by [`layer_key`] (the resolved spec's exact
    /// parameter bits).
    Layer {
        /// The raw layer fields (resolved server-side via
        /// [`LayerParams::resolve`]).
        params: LayerParams,
        /// Campaign seed override (server default when absent).
        seed: Option<u64>,
    },
    /// Evaluate a multi-layer model on the chained tile pipeline (`grcim
    /// model` over the wire): per-layer energy/SQNR, inter-layer
    /// requantization, network totals. Cached by [`model_key`] (the
    /// resolved spec's exact parameter bits).
    Model {
        /// The raw model fields (resolved server-side via
        /// [`ModelParams::resolve`]).
        params: ModelParams,
        /// Campaign seed override (server default when absent).
        seed: Option<u64>,
    },
    /// Analyze an empirical tensor trace: summary, SQNR sweep, and the
    /// conventional-vs-GR energy-bound comparison (`grcim workload` over
    /// the wire). Cached by the trace's content hash.
    Workload {
        /// Where the trace comes from.
        source: TraceSource,
        /// Monte-Carlo samples per campaign point.
        samples: usize,
        /// Campaign seed override (server default when absent).
        seed: Option<u64>,
    },
    /// Explore a design-space plan grid and return the full point set
    /// plus its Pareto frontier (`grcim explore` over the wire). Cached
    /// by [`pareto_key`] (the canonical plan's content hash — the plan
    /// carries its own seed, so no request-level seed participates).
    Pareto {
        /// The plan as TOML text (resolved server-side via
        /// [`crate::explore::ParetoPlan::from_toml`], which also
        /// enforces the grid-wide MAC/slab caps at plan time).
        plan: String,
    },
}

impl Request {
    /// The kind of this request.
    pub fn kind(&self) -> RequestKind {
        match self {
            Request::Info => RequestKind::Info,
            Request::Metrics => RequestKind::Metrics,
            Request::Energy { .. } => RequestKind::Energy,
            Request::Sweep { .. } => RequestKind::Sweep,
            Request::Figure { .. } => RequestKind::Figure,
            Request::Workload { .. } => RequestKind::Workload,
            Request::Layer { .. } => RequestKind::Layer,
            Request::Model { .. } => RequestKind::Model,
            Request::Pareto { .. } => RequestKind::Pareto,
        }
    }
}

/// How a `workload` request supplies its trace.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceSource {
    /// A trace file resolved on the *server's* filesystem (binary or JSON
    /// form; see `docs/CLI.md`).
    Path(String),
    /// Payload carried inline in the request (small traces, tests).
    Inline {
        /// Trace label (reports only; not part of the cache identity).
        name: String,
        /// The tensor values (a flat f64 vector).
        values: Vec<f64>,
    },
}

/// Parse one request line, ignoring transport metadata (`deadline_ms`).
///
/// Equality-friendly entry point for tests and simple clients; the
/// server itself uses [`parse_request_meta`] so deadlines survive.
pub fn parse_request(line: &str) -> Result<Request> {
    parse_request_meta(line).map(|(req, _)| req)
}

/// Parse one request line plus its transport metadata: the optional
/// `deadline_ms` budget (how long the client is willing to wait before
/// the server should answer with a `deadline` error instead).
pub fn parse_request_meta(line: &str) -> Result<(Request, Option<Duration>)> {
    let j = Json::parse(line.trim()).context("request is not valid JSON")?;
    let cmd = j
        .get("cmd")
        .and_then(Json::as_str)
        .context("request needs a string 'cmd' field")?;
    let deadline = match j.get("deadline_ms").map(Json::as_f64) {
        None => None,
        Some(Some(ms)) if ms.is_finite() && ms >= 0.0 => {
            Some(Duration::from_micros((ms * 1000.0) as u64))
        }
        Some(_) => bail!("deadline_ms must be a non-negative number of milliseconds"),
    };
    let seed = match j.get("seed").and_then(Json::as_f64) {
        None => None,
        Some(s) => {
            if s < 0.0 || s.fract() != 0.0 || s > MAX_JSON_SEED as f64 {
                bail!(
                    "seed must be a non-negative integer <= 2^53 \
                     (JSON numbers are f64), got {s}"
                );
            }
            Some(s as u64)
        }
    };
    let sampler = match j.get("sampler") {
        None => Sampler::default(),
        Some(Json::Str(s)) => match Sampler::parse(s) {
            Ok(s) => s,
            Err(e) => bail!("{e}"),
        },
        Some(other) => bail!("sampler must be a string, got {other}"),
    };
    let req = match cmd {
        "info" => Ok(Request::Info),
        "metrics" => Ok(Request::Metrics),
        "energy" => Ok(Request::Energy {
            dr_db: j.get("dr").and_then(Json::as_f64).unwrap_or(30.1),
            sqnr_db: j.get("sqnr").and_then(Json::as_f64).unwrap_or(22.83),
            samples: j
                .get("samples")
                .and_then(Json::as_usize)
                .unwrap_or(DEFAULT_SAMPLES),
            seed,
            sampler,
        }),
        "sweep" => {
            let mut experiments = Vec::new();
            let items = j
                .get("experiments")
                .context("sweep needs an 'experiments' array")?
                .items();
            for e in items {
                experiments.push(SweepExperiment {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .context("experiment needs a name")?
                        .to_string(),
                    n_e: e.get("n_e").and_then(Json::as_f64).unwrap_or(2.0),
                    n_m: e.get("n_m").and_then(Json::as_f64).unwrap_or(2.0),
                    nr: e.get("nr").and_then(Json::as_usize).unwrap_or(32),
                    distribution: e
                        .get("distribution")
                        .and_then(Json::as_str)
                        .unwrap_or("uniform")
                        .to_string(),
                });
            }
            if experiments.is_empty() {
                bail!("sweep has no experiments");
            }
            Ok(Request::Sweep {
                samples: j
                    .get("samples")
                    .and_then(Json::as_usize)
                    .unwrap_or(DEFAULT_SAMPLES),
                seed,
                sampler,
                experiments,
            })
        }
        "figure" => Ok(Request::Figure {
            id: j
                .get("id")
                .and_then(Json::as_str)
                .context("figure needs an 'id' field")?
                .to_string(),
            samples: j
                .get("samples")
                .and_then(Json::as_usize)
                .unwrap_or(DEFAULT_FIGURE_SAMPLES),
            seed,
        }),
        "layer" => {
            let d = LayerParams::default();
            let params = LayerParams {
                shape: j
                    .get("shape")
                    .and_then(Json::as_str)
                    .context("layer needs a 'shape' field (e.g. \"mlp-up:4096\")")?
                    .to_string(),
                tokens: j.get("tokens").and_then(Json::as_usize).unwrap_or(d.tokens),
                arch: j
                    .get("arch")
                    .and_then(Json::as_str)
                    .unwrap_or(&d.arch)
                    .to_string(),
                nr: j.get("nr").and_then(Json::as_usize).unwrap_or(d.nr),
                nc: j.get("nc").and_then(Json::as_usize).unwrap_or(d.nc),
                n_e: j.get("n_e").and_then(Json::as_f64).unwrap_or(d.n_e),
                n_m: j.get("n_m").and_then(Json::as_f64).unwrap_or(d.n_m),
                distribution: j
                    .get("distribution")
                    .and_then(Json::as_str)
                    .unwrap_or(&d.distribution)
                    .to_string(),
            };
            Ok(Request::Layer { params, seed })
        }
        "model" => {
            let d = ModelParams::default();
            let params = ModelParams {
                model: j
                    .get("model")
                    .and_then(Json::as_str)
                    .context("model needs a 'model' field (e.g. \"mlp:4096x16384x4096\")")?
                    .to_string(),
                tokens: j.get("tokens").and_then(Json::as_usize).unwrap_or(d.tokens),
                arch: j
                    .get("arch")
                    .and_then(Json::as_str)
                    .unwrap_or(&d.arch)
                    .to_string(),
                nr: j.get("nr").and_then(Json::as_usize).unwrap_or(d.nr),
                nc: j.get("nc").and_then(Json::as_usize).unwrap_or(d.nc),
                n_e: j.get("n_e").and_then(Json::as_f64).unwrap_or(d.n_e),
                n_m: j.get("n_m").and_then(Json::as_f64).unwrap_or(d.n_m),
                distribution: j
                    .get("distribution")
                    .and_then(Json::as_str)
                    .unwrap_or(&d.distribution)
                    .to_string(),
                fit: j.get("fit") == Some(&Json::Bool(true)),
            };
            Ok(Request::Model { params, seed })
        }
        "workload" => {
            let source = match (j.get("path"), j.get("values")) {
                (Some(p), None) => TraceSource::Path(
                    p.as_str()
                        .context("workload 'path' must be a string")?
                        .to_string(),
                ),
                (None, Some(vals)) => {
                    let mut values = Vec::new();
                    for v in vals.items() {
                        values.push(
                            v.as_f64()
                                .context("workload values must be numbers")?,
                        );
                    }
                    if values.is_empty() {
                        bail!("workload 'values' array is empty");
                    }
                    TraceSource::Inline {
                        name: j
                            .get("name")
                            .and_then(Json::as_str)
                            .unwrap_or("inline")
                            .to_string(),
                        values,
                    }
                }
                (Some(_), Some(_)) => {
                    bail!("workload takes 'path' or 'values', not both")
                }
                (None, None) => bail!(
                    "workload needs a 'path' (server-side trace file) or a \
                     'values' array"
                ),
            };
            Ok(Request::Workload {
                source,
                samples: j
                    .get("samples")
                    .and_then(Json::as_usize)
                    .unwrap_or(DEFAULT_FIGURE_SAMPLES),
                seed,
            })
        }
        "pareto" => Ok(Request::Pareto {
            plan: j
                .get("plan")
                .and_then(Json::as_str)
                .context("pareto needs a 'plan' field (the plan TOML text)")?
                .to_string(),
        }),
        other => {
            bail!(
                "unknown cmd '{other}' \
                 (energy|sweep|figure|workload|layer|model|pareto|metrics|info)"
            )
        }
    }?;
    Ok((req, deadline))
}

/// Build a JSON object from key/value pairs (stable key order courtesy of
/// the underlying `BTreeMap`).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Render a success response line (no trailing newline).
pub fn ok_line(result: Json, cached: bool) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("cached", Json::Bool(cached)),
        ("result", result),
    ])
    .to_string()
}

/// Render an error response line (no trailing newline). Equivalent to
/// [`err_kind_line`] with kind `"error"` — validation or compute failure.
pub fn err_line(message: &str) -> String {
    err_kind_line("error", message)
}

/// Render a typed error response line (no trailing newline). `kind` is
/// one of `"bad_request"`, `"busy"`, `"deadline"`, or `"error"` — see
/// the module docs for when each applies.
pub fn err_kind_line(kind: &str, message: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("kind", Json::Str(kind.to_string())),
        ("error", Json::Str(message.to_string())),
    ])
    .to_string()
}

/// Hex of the exact bit pattern of an `f64` — canonical-key fragments must
/// distinguish parameters that differ in any bit (display rounding like
/// `{:.3}` would alias nearby design-space points onto one key).
fn bits(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn canonical_dist(d: &Distribution) -> String {
    match d {
        Distribution::Uniform => "uniform".into(),
        Distribution::MaxEntropy(me) => {
            let f = me.format();
            format!("maxent:{}:{}", bits(f.e_max), bits(f.n_m))
        }
        Distribution::GaussOutliers(p) => {
            format!("gaussout:{}:{}", bits(p.eps), bits(p.k))
        }
        Distribution::ClippedGauss { clip_sigmas } => {
            format!("clipgauss:{}", bits(*clip_sigmas))
        }
        Distribution::UniformScaled { r } => format!("uscaled:{}", bits(*r)),
        // the content hash covers dtype + shape + exact payload bits; the
        // trace *name* is a label and is deliberately excluded (same rule
        // as the experiment id)
        Distribution::Empirical(e) => {
            format!("empirical:{:016x}", e.content_hash())
        }
    }
}

/// Canonical cache key of one experiment's campaign aggregate.
///
/// Covers exactly the inputs that determine the aggregate bit pattern:
/// both formats (exact bits), both distributions (exact parameter bits),
/// the estimator mode (sampler), array depth, requested samples,
/// campaign seed, and the engine kind.
/// The experiment `id` is deliberately excluded (it labels reports, it
/// does not seed anything), as is the worker count (aggregates are
/// bit-identical for any worker count — a coordinator invariant asserted
/// in `rust/tests/properties.rs`).
pub fn spec_key(spec: &ExperimentSpec, seed: u64, engine: &str) -> String {
    format!(
        "v{PROTO_VERSION}|agg|eng={engine}|seed={seed}|samp={}|nr={}|n={}|x={}:{}|w={}:{}|dx={}|dw={}",
        spec.sampler.name(),
        spec.nr,
        spec.samples,
        bits(spec.fmts.x.e_max),
        bits(spec.fmts.x.n_m),
        bits(spec.fmts.w.e_max),
        bits(spec.fmts.w.n_m),
        canonical_dist(&spec.dist_x),
        canonical_dist(&spec.dist_w),
    )
}

/// Canonical cache key of one rendered `energy` response — the
/// response-level cache over [`spec_key`]'s aggregate cache, so repeat
/// spec-point queries skip even the solve/render step. Keyed by the
/// exact (DR, SQNR) bits, samples, seed, sampler, and engine.
pub fn energy_key(
    dr_db: f64,
    sqnr_db: f64,
    samples: usize,
    seed: u64,
    sampler: Sampler,
    engine: &str,
) -> String {
    format!(
        "v{PROTO_VERSION}|energy|eng={engine}|seed={seed}|samp={}|n={samples}|dr={}|sqnr={}",
        sampler.name(),
        bits(dr_db),
        bits(sqnr_db),
    )
}

/// Canonical cache key of one rendered `sweep` response. Covers each
/// experiment's aggregate identity ([`spec_key`]) *and* its id — the
/// response echoes experiment names, so two sweeps that differ only in
/// labels must not share a rendered entry (their aggregates still share
/// the inner cache, where ids deliberately do not participate).
pub fn sweep_key(specs: &[ExperimentSpec], seed: u64, engine: &str) -> String {
    let frags: Vec<String> = specs
        .iter()
        .map(|spec| format!("{}={}", spec.id, spec_key(spec, seed, engine)))
        .collect();
    format!("v{PROTO_VERSION}|sweep|{}", frags.join(";"))
}

/// Canonical cache key of one rendered figure.
pub fn figure_key(id: &str, samples: usize, seed: u64, engine: &str) -> String {
    format!("v{PROTO_VERSION}|fig|eng={engine}|seed={seed}|n={samples}|id={id}")
}

/// Canonical cache key of one rendered layer report. Built from the
/// **resolved** [`LayerSpec`] (not the raw request fields), so aliases
/// that resolve identically — `--arch gr` vs `--arch gr-unit`, or a
/// named shape vs the equivalent explicit `gemm:` — share one entry.
/// Covers exactly what determines the report's bits: the GEMM
/// dimensions, tile geometry, architecture, exact format bits, both
/// distributions (empirical traces by content hash), seed, and engine.
pub fn layer_key(spec: &LayerSpec, seed: u64, engine: &str) -> String {
    let cfg = &spec.cfg;
    // adc policy and technology parameters are pinned by
    // LayerParams::resolve today, but both determine the report's bits —
    // keying them keeps the cache sound if a future entry point exposes
    // either (fixed-ENOB or --adc-scale knobs already exist elsewhere)
    let adc = match cfg.adc {
        crate::tile::AdcPolicy::Fixed(e) => format!("fixed:{}", bits(e)),
        crate::tile::AdcPolicy::PerTileSpec => "spec".to_string(),
    };
    let t = &cfg.tech;
    format!(
        "v{PROTO_VERSION}|layer|eng={engine}|seed={seed}|shape={}|nr={}|nc={}|arch={}|adc={adc}|tech={}:{}:{}:{}:{}:{}|x={}:{}|w={}:{}|dx={}|dw={}",
        spec.shape,
        cfg.nr,
        cfg.nc,
        cfg.arch.name(),
        bits(t.c_gate_ff),
        bits(t.k1_ff),
        bits(t.k2_ff),
        bits(t.k3_ff),
        bits(t.vdd),
        bits(t.e_softmax_fj),
        bits(cfg.fmts.x.e_max),
        bits(cfg.fmts.x.n_m),
        bits(cfg.fmts.w.e_max),
        bits(cfg.fmts.w.n_m),
        canonical_dist(&spec.dist_x),
        canonical_dist(&spec.dist_w),
    )
}

/// One canonical-key fragment per layer's effective configuration. The
/// kind tag keeps semantically different layers with identical chain
/// shapes apart — `transformer:64x4x2`'s attention stages differ from
/// `transformer:64x1x2`'s only in head count, and a `conv:` layer
/// differs from its flattened `gemm:` only in operand layout, yet each
/// pair produces different report bits.
fn layer_fragment(spec: &ModelSpec, li: usize) -> String {
    let cfg = spec.layer_cfg(li);
    let kind = match spec.layers[li].kind {
        crate::model::LayerKind::Gemm => String::new(),
        crate::model::LayerKind::Conv(cs) => format!("conv{}x{}x{}x{}@{}x{}:", cs.cout, cs.cin, cs.kh, cs.kw, cs.h, cs.w),
        crate::model::LayerKind::Attention { heads, ctx } => match ctx {
            None => format!("attn{heads}:"),
            Some(c) => format!("attn{heads}c{c}:"),
        },
    };
    format!(
        "{kind}{}@{}:{}:{}:{}",
        spec.layers[li].shape,
        bits(cfg.fmts.x.e_max),
        bits(cfg.fmts.x.n_m),
        bits(cfg.fmts.w.e_max),
        bits(cfg.fmts.w.n_m),
    )
}

/// Canonical cache key of one rendered model report. Built from the
/// **resolved** [`ModelSpec`] like [`layer_key`], so request aliases
/// share one entry. Covers exactly what determines the report's bits:
/// every layer's GEMM dimensions and effective formats, the base tile
/// geometry/architecture/ADC policy/TechParams, both distributions, the
/// ReLU and activation-fit switches, seed, and engine.
pub fn model_key(spec: &ModelSpec, seed: u64, engine: &str) -> String {
    let cfg = &spec.cfg;
    let adc = match cfg.adc {
        crate::tile::AdcPolicy::Fixed(e) => format!("fixed:{}", bits(e)),
        crate::tile::AdcPolicy::PerTileSpec => "spec".to_string(),
    };
    let t = &cfg.tech;
    let layers: Vec<String> =
        (0..spec.layers.len()).map(|li| layer_fragment(spec, li)).collect();
    format!(
        "v{PROTO_VERSION}|model|eng={engine}|seed={seed}|nr={}|nc={}|arch={}|adc={adc}|tech={}:{}:{}:{}:{}:{}|relu={}|fit={}|dx={}|dw={}|layers={}",
        cfg.nr,
        cfg.nc,
        cfg.arch.name(),
        bits(t.c_gate_ff),
        bits(t.k1_ff),
        bits(t.k2_ff),
        bits(t.k3_ff),
        bits(t.vdd),
        bits(t.e_softmax_fj),
        spec.relu,
        spec.fit_activations,
        canonical_dist(&spec.dist_x),
        canonical_dist(&spec.dist_w),
        layers.join(","),
    )
}

/// Canonical cache key of one rendered `pareto` response. The plan's
/// content hash ([`crate::explore::ParetoPlan::content_hash`], FNV-1a
/// over the canonical plan JSON) already covers every axis value, the
/// workload list, the distribution, the seed, and the token count — so
/// alias spellings of the same plan (`gr` vs `gr-unit`, `fixed:8` vs
/// `fixed:8.0`) share one entry, and any semantic change misses.
pub fn pareto_key(plan_hash: u64, engine: &str) -> String {
    format!("v{PROTO_VERSION}|pareto|eng={engine}|plan={plan_hash:016x}")
}

/// Canonical cache key of one rendered workload report: the trace is
/// identified by its content hash ([`crate::workload::TensorTrace::content_hash`]
/// — dtype, shape, and exact payload bits; *not* the trace name or the
/// path it was read from), so renamed or re-uploaded copies of the same
/// tensor hit the same entry.
pub fn workload_key(
    content_hash: u64,
    samples: usize,
    seed: u64,
    engine: &str,
) -> String {
    format!(
        "v{PROTO_VERSION}|wl|eng={engine}|seed={seed}|n={samples}|trace={content_hash:016x}"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;
    use crate::mac::FormatPair;

    fn spec() -> ExperimentSpec {
        ExperimentSpec {
            id: "t".into(),
            fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: 4096,
            sampler: Sampler::Plain,
        }
    }

    #[test]
    fn parses_every_request_kind() {
        assert_eq!(parse_request(r#"{"cmd":"info"}"#).unwrap(), Request::Info);
        let e = parse_request(
            r#"{"cmd":"energy","dr":36.12,"sqnr":28.85,"samples":2048,"seed":9}"#,
        )
        .unwrap();
        assert_eq!(
            e,
            Request::Energy {
                dr_db: 36.12,
                sqnr_db: 28.85,
                samples: 2048,
                seed: Some(9),
                sampler: Sampler::Plain,
            }
        );
        let e = parse_request(
            r#"{"cmd":"energy","dr":36.12,"sqnr":28.85,"sampler":"antithetic"}"#,
        )
        .unwrap();
        assert!(matches!(e, Request::Energy { sampler: Sampler::Antithetic, .. }));
        let s = parse_request(
            r#"{"cmd":"sweep","samples":1024,"experiments":[
                {"name":"a","n_e":3,"n_m":2,"nr":32,"distribution":"uniform"}]}"#,
        )
        .unwrap();
        match s {
            Request::Sweep { samples, seed, sampler, experiments } => {
                assert_eq!(sampler, Sampler::Plain);
                assert_eq!(samples, 1024);
                assert_eq!(seed, None);
                assert_eq!(experiments.len(), 1);
                assert_eq!(experiments[0].name, "a");
                assert_eq!(experiments[0].distribution, "uniform");
            }
            other => panic!("{other:?}"),
        }
        let f = parse_request(r#"{"cmd":"figure","id":"table1"}"#).unwrap();
        assert_eq!(
            f,
            Request::Figure {
                id: "table1".into(),
                samples: DEFAULT_FIGURE_SAMPLES,
                seed: None
            }
        );
    }

    #[test]
    fn parses_metrics_and_deadlines() {
        assert_eq!(parse_request(r#"{"cmd":"metrics"}"#).unwrap(), Request::Metrics);
        let (req, dl) = parse_request_meta(r#"{"cmd":"info","deadline_ms":250}"#).unwrap();
        assert_eq!(req, Request::Info);
        assert_eq!(dl, Some(Duration::from_millis(250)));
        let (_, dl) = parse_request_meta(r#"{"cmd":"info","deadline_ms":0.5}"#).unwrap();
        assert_eq!(dl, Some(Duration::from_micros(500)));
        let (_, dl) = parse_request_meta(r#"{"cmd":"info"}"#).unwrap();
        assert_eq!(dl, None);
        // a zero deadline is legal (and expires immediately — tests use it)
        let (_, dl) = parse_request_meta(r#"{"cmd":"info","deadline_ms":0}"#).unwrap();
        assert_eq!(dl, Some(Duration::ZERO));
        assert!(parse_request_meta(r#"{"cmd":"info","deadline_ms":-1}"#).is_err());
        assert!(parse_request_meta(r#"{"cmd":"info","deadline_ms":"soon"}"#).is_err());
    }

    #[test]
    fn request_kinds_round_trip() {
        for (i, kind) in RequestKind::ALL.iter().enumerate() {
            assert_eq!(kind.index(), i);
        }
        assert!(RequestKind::Info.is_inline());
        assert!(RequestKind::Metrics.is_inline());
        assert!(!RequestKind::Energy.is_inline());
        assert_eq!(parse_request(r#"{"cmd":"metrics"}"#).unwrap().kind(), RequestKind::Metrics);
        assert_eq!(
            parse_request(r#"{"cmd":"energy"}"#).unwrap().kind().name(),
            "energy"
        );
    }

    #[test]
    fn typed_error_lines_carry_their_kind() {
        let j = Json::parse(&err_kind_line("busy", "queue full")).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("busy"));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("queue full"));
        let j = Json::parse(&err_line("boom")).unwrap();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("error"));
    }

    #[test]
    fn energy_and_sweep_keys_cover_their_inputs() {
        let p = Sampler::Plain;
        let k0 = energy_key(30.1, 22.83, 4096, 7, p, "rust");
        assert_ne!(k0, energy_key(30.2, 22.83, 4096, 7, p, "rust"));
        assert_ne!(k0, energy_key(30.1, 22.84, 4096, 7, p, "rust"));
        assert_ne!(k0, energy_key(30.1, 22.83, 8192, 7, p, "rust"));
        assert_ne!(k0, energy_key(30.1, 22.83, 4096, 8, p, "rust"));
        assert_ne!(k0, energy_key(30.1, 22.83, 4096, 7, p, "pjrt"));
        assert_ne!(k0, energy_key(30.1, 22.83, 4096, 7, Sampler::Stratified, "rust"));
        assert_eq!(k0, energy_key(30.1, 22.83, 4096, 7, p, "rust"));

        let a = spec();
        let mut b = spec();
        b.nr = 64;
        let k = sweep_key(&[a.clone(), b.clone()], 7, "rust");
        // order and membership matter
        assert_ne!(k, sweep_key(&[b.clone(), a.clone()], 7, "rust"));
        assert_ne!(k, sweep_key(&[a.clone()], 7, "rust"));
        // experiment ids participate (the response echoes them)...
        let mut renamed = a.clone();
        renamed.id = "other".into();
        assert_ne!(
            sweep_key(&[a.clone()], 7, "rust"),
            sweep_key(&[renamed], 7, "rust")
        );
        // ...and so do seed, engine, and the estimator mode
        assert_ne!(k, sweep_key(&[a.clone(), b.clone()], 8, "rust"));
        assert_ne!(k, sweep_key(&[a.clone(), b.clone()], 7, "pjrt"));
        let mut resampled = a.clone();
        resampled.sampler = Sampler::Antithetic;
        assert_ne!(spec_key(&a, 7, "rust"), spec_key(&resampled, 7, "rust"));
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"no_cmd":1}"#).is_err());
        assert!(parse_request(r#"{"cmd":"warp"}"#).is_err());
        // seeds a JSON f64 cannot carry exactly are rejected, not aliased
        assert!(parse_request(r#"{"cmd":"info","seed":-1}"#).is_err());
        assert!(parse_request(r#"{"cmd":"info","seed":1.5}"#).is_err());
        assert!(
            parse_request(r#"{"cmd":"info","seed":18446744073709551615}"#)
                .is_err()
        );
        assert!(parse_request(r#"{"cmd":"energy","sampler":"warp"}"#).is_err());
        assert!(parse_request(r#"{"cmd":"energy","sampler":3}"#).is_err());
        assert!(parse_request(r#"{"cmd":"figure"}"#).is_err()); // no id
        assert!(parse_request(r#"{"cmd":"sweep","experiments":[]}"#).is_err());
        assert!(
            parse_request(r#"{"cmd":"sweep","experiments":[{"n_e":2}]}"#)
                .is_err(),
            "experiment without a name must be rejected"
        );
    }

    #[test]
    fn response_lines_are_parseable_json() {
        let ok = ok_line(obj(vec![("x", Json::Num(1.5))]), true);
        let j = Json::parse(&ok).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(j.get("result").unwrap().get("x").unwrap().as_f64(), Some(1.5));

        let err = err_line("boom \"quoted\"");
        let j = Json::parse(&err).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("boom \"quoted\""));
    }

    #[test]
    fn spec_key_distinguishes_every_input() {
        let base = spec();
        let k0 = spec_key(&base, 7, "rust");
        // id does NOT participate
        let mut renamed = base.clone();
        renamed.id = "other".into();
        assert_eq!(spec_key(&renamed, 7, "rust"), k0);
        // everything else does
        let mut m = base.clone();
        m.nr = 64;
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        let mut m = base.clone();
        m.samples = 8192;
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        let mut m = base.clone();
        m.fmts = FormatPair::new(FpFormat::fp(3, 3), FpFormat::fp4_e2m1());
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        let mut m = base.clone();
        m.dist_x = Distribution::clipped_gauss4();
        assert_ne!(spec_key(&m, 7, "rust"), k0);
        assert_ne!(spec_key(&base, 8, "rust"), k0);
        assert_ne!(spec_key(&base, 7, "pjrt"), k0);
    }

    #[test]
    fn spec_key_separates_nearby_scaled_distributions() {
        // display rounding would alias these; exact bits must not
        let mut a = spec();
        a.dist_x = Distribution::UniformScaled { r: 0.001953125 };
        let mut b = spec();
        b.dist_x = Distribution::UniformScaled { r: 0.0019531251 };
        assert_ne!(spec_key(&a, 7, "rust"), spec_key(&b, 7, "rust"));
    }

    #[test]
    fn figure_keys_are_distinct() {
        let a = figure_key("fig9", 1024, 7, "rust");
        assert_ne!(a, figure_key("fig10", 1024, 7, "rust"));
        assert_ne!(a, figure_key("fig9", 2048, 7, "rust"));
        assert_ne!(a, figure_key("fig9", 1024, 8, "rust"));
    }

    #[test]
    fn parses_workload_requests() {
        let p = parse_request(
            r#"{"cmd":"workload","path":"acts.grtt","samples":2048,"seed":3}"#,
        )
        .unwrap();
        assert_eq!(
            p,
            Request::Workload {
                source: TraceSource::Path("acts.grtt".into()),
                samples: 2048,
                seed: Some(3),
            }
        );
        let i = parse_request(
            r#"{"cmd":"workload","name":"t","values":[0.5,-0.5,1,-1]}"#,
        )
        .unwrap();
        match i {
            Request::Workload {
                source: TraceSource::Inline { name, values },
                samples,
                seed,
            } => {
                assert_eq!(name, "t");
                assert_eq!(values, vec![0.5, -0.5, 1.0, -1.0]);
                assert_eq!(samples, DEFAULT_FIGURE_SAMPLES);
                assert_eq!(seed, None);
            }
            other => panic!("{other:?}"),
        }
        // neither / both / empty sources are rejected
        assert!(parse_request(r#"{"cmd":"workload"}"#).is_err());
        assert!(parse_request(
            r#"{"cmd":"workload","path":"x","values":[1]}"#
        )
        .is_err());
        assert!(parse_request(r#"{"cmd":"workload","values":[]}"#).is_err());
        assert!(
            parse_request(r#"{"cmd":"workload","values":["a"]}"#).is_err()
        );
    }

    #[test]
    fn parses_layer_requests_with_defaults_and_overrides() {
        let r = parse_request(r#"{"cmd":"layer","shape":"mlp-up:4096"}"#).unwrap();
        match r {
            Request::Layer { params, seed } => {
                assert_eq!(params.shape, "mlp-up:4096");
                let want = LayerParams { shape: "mlp-up:4096".into(), ..Default::default() };
                assert_eq!(params, want);
                assert_eq!(seed, None);
            }
            other => panic!("{other:?}"),
        }
        let r = parse_request(
            r#"{"cmd":"layer","shape":"gemm:2x8x8","arch":"conventional",
                "tokens":8,"nr":16,"nc":8,"n_e":3,"n_m":1,
                "distribution":"uniform","seed":5}"#,
        )
        .unwrap();
        match r {
            Request::Layer { params, seed } => {
                assert_eq!(params.arch, "conventional");
                assert_eq!(params.tokens, 8);
                assert_eq!(params.nr, 16);
                assert_eq!(params.nc, 8);
                assert_eq!(params.n_e, 3.0);
                assert_eq!(params.n_m, 1.0);
                assert_eq!(params.distribution, "uniform");
                assert_eq!(seed, Some(5));
            }
            other => panic!("{other:?}"),
        }
        // shape is mandatory
        assert!(parse_request(r#"{"cmd":"layer"}"#).is_err());
    }

    #[test]
    fn parses_model_requests_with_defaults_and_overrides() {
        let r = parse_request(r#"{"cmd":"model","model":"mlp:64x256x64"}"#).unwrap();
        match r {
            Request::Model { params, seed } => {
                let want =
                    ModelParams { model: "mlp:64x256x64".into(), ..Default::default() };
                assert_eq!(params, want);
                assert_eq!(seed, None);
            }
            other => panic!("{other:?}"),
        }
        let r = parse_request(
            r#"{"cmd":"model","model":"block:32","arch":"conventional",
                "tokens":8,"nr":16,"nc":8,"n_e":3,"n_m":1,
                "distribution":"uniform","fit":true,"seed":5}"#,
        )
        .unwrap();
        match r {
            Request::Model { params, seed } => {
                assert_eq!(params.model, "block:32");
                assert_eq!(params.arch, "conventional");
                assert_eq!(params.tokens, 8);
                assert_eq!((params.nr, params.nc), (16, 8));
                assert_eq!((params.n_e, params.n_m), (3.0, 1.0));
                assert!(params.fit);
                assert_eq!(seed, Some(5));
            }
            other => panic!("{other:?}"),
        }
        // model string is mandatory
        assert!(parse_request(r#"{"cmd":"model"}"#).is_err());
    }

    #[test]
    fn model_keys_cover_every_resolved_input() {
        let base = ModelParams { model: "mlp:16x12x8".into(), ..Default::default() };
        let k0 = model_key(&base.resolve().unwrap(), 7, "rust");
        // arch aliases share the entry
        let alias = ModelParams { arch: "gr-unit".into(), ..base.clone() };
        assert_eq!(model_key(&alias.resolve().unwrap(), 7, "rust"), k0);
        for changed in [
            ModelParams { model: "mlp:16x12x9".into(), ..base.clone() },
            ModelParams { model: "mlp:16x12x8x8".into(), ..base.clone() },
            ModelParams { tokens: 8, ..base.clone() },
            ModelParams { arch: "conventional".into(), ..base.clone() },
            ModelParams { nr: 16, ..base.clone() },
            ModelParams { nc: 16, ..base.clone() },
            ModelParams { n_e: 3.0, ..base.clone() },
            ModelParams { n_m: 3.0, ..base.clone() },
            ModelParams { distribution: "uniform".into(), ..base.clone() },
            ModelParams { fit: true, ..base.clone() },
        ] {
            assert_ne!(model_key(&changed.resolve().unwrap(), 7, "rust"), k0, "{changed:?}");
        }
        assert_ne!(model_key(&base.resolve().unwrap(), 8, "rust"), k0);
        assert_ne!(model_key(&base.resolve().unwrap(), 7, "pjrt"), k0);
        // per-layer format overrides and the relu switch key too
        let mut spec = base.resolve().unwrap();
        spec.layers[1].fmts = Some(crate::mac::FormatPair::new(
            crate::formats::FpFormat::fp(5, 2),
            crate::formats::FpFormat::fp4_e2m1(),
        ));
        assert_ne!(model_key(&spec, 7, "rust"), k0);
        let mut norelu = base.resolve().unwrap();
        norelu.relu = false;
        assert_ne!(model_key(&norelu, 7, "rust"), k0);
    }

    #[test]
    fn model_keys_separate_layer_kinds() {
        let key = |model: &str, tokens: usize| {
            let params =
                ModelParams { model: model.into(), tokens, ..Default::default() };
            model_key(&params.resolve().unwrap(), 7, "rust")
        };
        // head count changes nothing about the chain shapes, but the
        // attention stages compute differently — the kind tag separates
        assert_ne!(key("transformer:64x4x2", 4), key("transformer:64x1x2", 4));
        // decode ctx is only visible through the kind tag (the chain
        // shape is M×d×d regardless of cache depth)
        assert_ne!(key("decode:64x4x128", 1), key("decode:64x4x256", 1));
        // a conv layer and its flattened GEMM share chain shapes but
        // not operand layout
        assert_ne!(key("conv:6x3x3x3@8x8,gemm:36x6x4", 1), key("gemm:36x27x6,gemm:36x6x4", 1));
        // prefill attention is not the old block: truncation stand-in
        assert_ne!(key("transformer:64x1x1", 4), key("block:64", 4));
    }

    #[test]
    fn layer_keys_cover_every_resolved_input() {
        let base = LayerParams { shape: "gemm:2x16x8".into(), ..Default::default() };
        let k0 = layer_key(&base.resolve().unwrap(), 7, "rust");
        // arch aliases share the entry (keys are built from the resolved spec)
        let alias = LayerParams { arch: "gr-unit".into(), ..base.clone() };
        assert_eq!(layer_key(&alias.resolve().unwrap(), 7, "rust"), k0);
        // every resolved input separates
        for changed in [
            LayerParams { shape: "gemm:2x16x9".into(), ..base.clone() },
            LayerParams { tokens: 4, shape: "mlp-up:4".into(), ..base.clone() },
            LayerParams { arch: "conventional".into(), ..base.clone() },
            LayerParams { nr: 16, ..base.clone() },
            LayerParams { nc: 16, ..base.clone() },
            LayerParams { n_e: 3.0, ..base.clone() },
            LayerParams { n_m: 3.0, ..base.clone() },
            LayerParams { distribution: "uniform".into(), ..base.clone() },
        ] {
            assert_ne!(layer_key(&changed.resolve().unwrap(), 7, "rust"), k0, "{changed:?}");
        }
        assert_ne!(layer_key(&base.resolve().unwrap(), 8, "rust"), k0);
        assert_ne!(layer_key(&base.resolve().unwrap(), 7, "pjrt"), k0);
        // adc policy and tech params are keyed too (pinned by resolve
        // today, but they determine the report's bits)
        let mut fixed = base.resolve().unwrap();
        fixed.cfg.adc = crate::tile::AdcPolicy::Fixed(8.0);
        assert_ne!(layer_key(&fixed, 7, "rust"), k0);
        let mut scaled = base.resolve().unwrap();
        scaled.cfg.tech = scaled.cfg.tech.with_adc_scale(1.1);
        assert_ne!(layer_key(&scaled, 7, "rust"), k0);
        let mut priced = base.resolve().unwrap();
        priced.cfg.tech.e_softmax_fj *= 2.0;
        assert_ne!(layer_key(&priced, 7, "rust"), k0);
    }

    #[test]
    fn parses_pareto_requests() {
        let r = parse_request(
            r#"{"cmd":"pareto","plan":"workload = \"gemm:2x8x4\"\n"}"#,
        )
        .unwrap();
        match r {
            Request::Pareto { plan } => {
                assert!(plan.contains("gemm:2x8x4"));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            parse_request(r#"{"cmd":"pareto","plan":"x"}"#).unwrap().kind(),
            RequestKind::Pareto
        );
        // the plan text is mandatory and must be a string
        assert!(parse_request(r#"{"cmd":"pareto"}"#).is_err());
        assert!(parse_request(r#"{"cmd":"pareto","plan":7}"#).is_err());
    }

    #[test]
    fn pareto_keys_cover_hash_and_engine() {
        let a = pareto_key(0xDEAD_BEEF, "rust");
        assert_ne!(a, pareto_key(0xDEAD_BEF0, "rust"));
        assert_ne!(a, pareto_key(0xDEAD_BEEF, "pjrt"));
        assert_eq!(a, pareto_key(0xDEAD_BEEF, "rust"));
    }

    #[test]
    fn workload_keys_cover_hash_samples_seed_engine() {
        let a = workload_key(0xDEAD_BEEF, 1024, 7, "rust");
        assert_ne!(a, workload_key(0xDEAD_BEF0, 1024, 7, "rust"));
        assert_ne!(a, workload_key(0xDEAD_BEEF, 2048, 7, "rust"));
        assert_ne!(a, workload_key(0xDEAD_BEEF, 1024, 8, "rust"));
        assert_ne!(a, workload_key(0xDEAD_BEEF, 1024, 7, "pjrt"));
        assert_eq!(a, workload_key(0xDEAD_BEEF, 1024, 7, "rust"));
    }

    #[test]
    fn spec_key_distinguishes_empirical_traces_by_content() {
        use crate::workload::{EmpiricalDist, TensorTrace};
        let fit = |name: &str, vals: Vec<f64>| {
            let t =
                TensorTrace::from_f64(name, vec![vals.len()], vals).unwrap();
            Distribution::empirical(EmpiricalDist::fit(&t).unwrap())
        };
        let mut a = spec();
        a.dist_x = fit("a", vec![0.5, -0.5, 1.0]);
        let mut renamed = spec();
        renamed.dist_x = fit("b", vec![0.5, -0.5, 1.0]);
        let mut different = spec();
        different.dist_x = fit("a", vec![0.5, -0.5, 0.9999]);
        // same bits, different name -> same key; different bits -> new key
        assert_eq!(spec_key(&a, 7, "rust"), spec_key(&renamed, 7, "rust"));
        assert_ne!(spec_key(&a, 7, "rust"), spec_key(&different, 7, "rust"));
    }
}
