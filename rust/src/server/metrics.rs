//! Server observability: request/error counters, admission-control
//! gauges, and per-request-kind latency histograms, snapshotted by the
//! `metrics` wire request.
//!
//! One [`ServerMetrics`] is shared (via `Arc`) by the acceptor, the
//! connection multiplexers, and the compute workers. Counters are
//! relaxed atomics — they are monotonic telemetry, not synchronization.
//! Latencies are binned into a log2-microsecond [`Histogram`]
//! (40 one-octave bins, so the range spans 1 µs to ~2^40 µs ≈ 12 days),
//! from which p50/p99 are read at bin centers: quantiles are accurate
//! to about a factor of √2, which is plenty to tell a cache hit from a
//! cold campaign while keeping recording O(1) and allocation-free.
//!
//! # Example
//!
//! ```
//! use grcim::server::metrics::ServerMetrics;
//! use grcim::server::proto::RequestKind;
//! use std::time::Duration;
//!
//! let m = ServerMetrics::new();
//! m.record(RequestKind::Energy, true, Duration::from_millis(3));
//! let j = m.to_json();
//! let energy = j.get("kinds").unwrap().get("energy").unwrap();
//! assert_eq!(energy.get("ok").unwrap().as_usize(), Some(1));
//! ```

use crate::config::Json;
use crate::server::proto::{obj, RequestKind};
use crate::stats::Histogram;
use crate::util::sync::{lock_recover, AtomicU64, Mutex, Ordering};
use std::time::{Duration, Instant};

/// Latency accumulator of one request kind: a log2-microsecond
/// histogram plus exact running sum/max (the histogram buckets are a
/// factor-√2 grid; sum and max stay exact).
#[derive(Debug)]
pub struct LatencyHist {
    hist: Histogram,
    sum_us: u64,
    max_us: u64,
}

/// One-octave bins over log2(µs): bin i counts latencies in
/// [2^i, 2^(i+1)) µs, clamped at both ends.
const LAT_BINS: usize = 40;

impl LatencyHist {
    /// An empty accumulator.
    pub fn new() -> Self {
        LatencyHist {
            hist: Histogram::new(0.0, LAT_BINS as f64, LAT_BINS),
            sum_us: 0,
            max_us: 0,
        }
    }

    /// Record one latency sample.
    pub fn push(&mut self, latency: Duration) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        // sub-microsecond latencies land in bin 0 ([1, 2) µs)
        self.hist.push((us.max(1) as f64).log2());
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.hist.total
    }

    /// The `q`-quantile in microseconds, read at the matching bin's
    /// center (so accurate to ~√2×), or `None` while empty.
    pub fn quantile_us(&self, q: f64) -> Option<f64> {
        if self.hist.total == 0 {
            return None;
        }
        let target = ((q * self.hist.total as f64).ceil()).max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.hist.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Some(2f64.powf(i as f64 + 0.5));
            }
        }
        Some(2f64.powf(LAT_BINS as f64 - 0.5))
    }

    /// Mean latency in microseconds (exact, from the running sum), or
    /// `None` while empty.
    pub fn mean_us(&self) -> Option<f64> {
        if self.hist.total == 0 {
            None
        } else {
            Some(self.sum_us as f64 / self.hist.total as f64)
        }
    }

    /// Largest latency seen, in microseconds (exact).
    pub fn max_us(&self) -> u64 {
        self.max_us
    }
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist::new()
    }
}

#[cfg_attr(not(loom), derive(Debug))]
struct KindMetrics {
    ok: AtomicU64,
    errors: AtomicU64,
    lat: Mutex<LatencyHist>,
}

// written out because the shim's loom atomics don't implement Default
impl Default for KindMetrics {
    fn default() -> Self {
        KindMetrics {
            ok: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            lat: Mutex::new(LatencyHist::new()),
        }
    }
}

impl KindMetrics {
    fn to_json(&self) -> Json {
        // a recording thread that panicked mid-push leaves at worst one
        // inexact histogram sample — telemetry stays serveable
        let lat = lock_recover(&self.lat);
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        obj(vec![
            ("ok", Json::Num(self.ok.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            ("count", Json::Num(lat.count() as f64)),
            ("p50_us", opt(lat.quantile_us(0.50))),
            ("p99_us", opt(lat.quantile_us(0.99))),
            ("mean_us", opt(lat.mean_us())),
            ("max_us", Json::Num(lat.max_us() as f64)),
        ])
    }
}

/// Shared server telemetry; see the module docs.
#[cfg_attr(not(loom), derive(Debug))]
pub struct ServerMetrics {
    started: Instant,
    /// Connections accepted since start.
    pub accepted: AtomicU64,
    /// Connections currently open.
    pub open_conns: AtomicU64,
    /// Compute requests admitted to the queue.
    pub admitted: AtomicU64,
    /// Compute requests rejected with a `busy` error (queue full).
    pub rejected_busy: AtomicU64,
    /// Requests answered with a `deadline` error.
    pub rejected_deadline: AtomicU64,
    /// Lines that failed to parse as a request (`bad_request` errors).
    pub bad_requests: AtomicU64,
    /// Compute jobs queued but not yet picked up by a worker.
    pub queue_depth: AtomicU64,
    /// Compute jobs currently executing on a worker.
    pub in_flight: AtomicU64,
    queue_cap: AtomicU64,
    kinds: Vec<KindMetrics>,
}

impl ServerMetrics {
    /// Fresh metrics; the uptime clock starts now.
    pub fn new() -> Self {
        ServerMetrics {
            started: Instant::now(),
            accepted: AtomicU64::new(0),
            open_conns: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected_busy: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            queue_cap: AtomicU64::new(0),
            kinds: RequestKind::ALL.iter().map(|_| KindMetrics::default()).collect(),
        }
    }

    /// Record the admission-queue capacity (reported, not enforced, here).
    pub fn set_queue_cap(&self, cap: usize) {
        self.queue_cap.store(cap as u64, Ordering::Relaxed);
    }

    /// Record one completed request of `kind`: whether it succeeded, and
    /// its latency from admission (or parse, for inline kinds) to the
    /// response being ready.
    pub fn record(&self, kind: RequestKind, ok: bool, latency: Duration) {
        let k = &self.kinds[kind.index()];
        if ok {
            k.ok.fetch_add(1, Ordering::Relaxed);
        } else {
            k.errors.fetch_add(1, Ordering::Relaxed);
        }
        lock_recover(&k.lat).push(latency);
    }

    /// Total successful responses across kinds.
    pub fn total_ok(&self) -> u64 {
        self.kinds.iter().map(|k| k.ok.load(Ordering::Relaxed)).sum()
    }

    /// Snapshot as the `metrics` response's `server` block: uptime,
    /// connection/admission counters, queue gauges, and the per-kind
    /// table (every kind always present, `Null` percentiles while empty
    /// — a schema the CI validator can check unconditionally).
    pub fn to_json(&self) -> Json {
        let n = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        let kinds = RequestKind::ALL
            .iter()
            .map(|k| (k.name(), self.kinds[k.index()].to_json()))
            .collect();
        obj(vec![
            ("uptime_us", Json::Num(self.started.elapsed().as_micros() as f64)),
            ("accepted", n(&self.accepted)),
            ("open_conns", n(&self.open_conns)),
            ("admitted", n(&self.admitted)),
            ("rejected_busy", n(&self.rejected_busy)),
            ("rejected_deadline", n(&self.rejected_deadline)),
            ("bad_requests", n(&self.bad_requests)),
            (
                "queue",
                obj(vec![
                    ("depth", n(&self.queue_depth)),
                    ("cap", n(&self.queue_cap)),
                    ("in_flight", n(&self.in_flight)),
                ]),
            ),
            ("kinds", obj(kinds)),
        ])
    }
}

impl Default for ServerMetrics {
    fn default() -> Self {
        ServerMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_quantiles_land_in_the_right_octave() {
        let mut h = LatencyHist::new();
        assert_eq!(h.quantile_us(0.5), None);
        assert_eq!(h.mean_us(), None);
        for _ in 0..99 {
            h.push(Duration::from_micros(100)); // bin 6: [64, 128)
        }
        h.push(Duration::from_millis(100)); // bin 16: [65536, 131072) µs
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_us(0.5).unwrap();
        assert!((64.0..128.0).contains(&p50), "p50 {p50}");
        let p99 = h.quantile_us(0.99).unwrap();
        assert!((64.0..128.0).contains(&p99), "p99 {p99}");
        // the single outlier is the true max and sits above p99
        assert_eq!(h.max_us(), 100_000);
        let mean = h.mean_us().unwrap();
        assert!((mean - (99.0 * 100.0 + 100_000.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn sub_microsecond_and_huge_latencies_clamp() {
        let mut h = LatencyHist::new();
        h.push(Duration::ZERO);
        h.push(Duration::from_secs(60 * 60 * 24 * 365));
        assert_eq!(h.count(), 2);
        assert!(h.quantile_us(0.0).unwrap() < 2.0);
        assert!(h.quantile_us(1.0).unwrap() > 1e9);
    }

    #[test]
    fn metrics_snapshot_has_every_kind_and_counts_records() {
        let m = ServerMetrics::new();
        m.record(RequestKind::Energy, true, Duration::from_micros(50));
        m.record(RequestKind::Energy, true, Duration::from_micros(70));
        m.record(RequestKind::Figure, false, Duration::from_micros(10));
        m.accepted.fetch_add(3, Ordering::Relaxed);
        m.set_queue_cap(64);
        assert_eq!(m.total_ok(), 2);

        let j = m.to_json();
        let kinds = j.get("kinds").unwrap();
        for k in RequestKind::ALL {
            assert!(kinds.get(k.name()).is_some(), "missing {}", k.name());
        }
        let energy = kinds.get("energy").unwrap();
        assert_eq!(energy.get("ok").unwrap().as_usize(), Some(2));
        assert_eq!(energy.get("errors").unwrap().as_usize(), Some(0));
        assert!(energy.get("p50_us").unwrap().as_f64().is_some());
        assert!(energy.get("p99_us").unwrap().as_f64().is_some());
        let figure = kinds.get("figure").unwrap();
        assert_eq!(figure.get("errors").unwrap().as_usize(), Some(1));
        // empty kinds render Null percentiles, not garbage
        let model = kinds.get("model").unwrap();
        assert_eq!(model.get("p50_us"), Some(&Json::Null));
        assert_eq!(j.get("accepted").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("queue").unwrap().get("cap").unwrap().as_usize(), Some(64));
    }

    #[test]
    fn poisoned_latency_lock_recovers() {
        // a thread panicking while holding a latency-histogram lock
        // must not take metrics down: record() and to_json() keep
        // working on the recovered histogram
        let m = std::sync::Arc::new(ServerMetrics::new());
        m.record(RequestKind::Energy, true, Duration::from_micros(50));
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.kinds[RequestKind::Energy.index()].lat.lock();
            panic!("poison the latency lock");
        })
        .join();
        m.record(RequestKind::Energy, true, Duration::from_micros(70));
        let j = m.to_json();
        let energy = j.get("kinds").unwrap().get("energy").unwrap();
        assert_eq!(energy.get("ok").unwrap().as_usize(), Some(2));
        assert_eq!(energy.get("count").unwrap().as_usize(), Some(2));
    }
}
