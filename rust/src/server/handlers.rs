//! Unified request dispatch: every compute request kind is one
//! [`Handler`] impl driven through a single
//! `Request → cache key → compute → render` pipeline
//! ([`CampaignService::run_handler`]), so the sharded single-flight
//! cache, the MAC/operand-slab caps, and error rendering apply
//! uniformly — and a new request kind is one more impl, not a seventh
//! hand-rolled handler method.
//!
//! The pipeline's contract:
//!
//! 1. [`Handler::plan`] validates the request, enforces resource caps,
//!    resolves specs, and returns the canonical cache key. Nothing
//!    expensive may run here — `plan` executes on every request,
//!    including cache hits.
//! 2. [`Handler::compute`] runs only for single-flight leaders on a
//!    cold key and returns the cacheable payload as rendered JSON
//!    *text* — the cache stores exact bytes, so hits are byte-identical
//!    to the cold compute.
//! 3. [`Handler::render`] wraps the (possibly cached) payload with
//!    per-request echo fields that must *not* be cached (request
//!    aliases share one payload entry but echo their own spelling).

use super::{confined_trace_path, CampaignService, MAX_LAYER_ELEMS, MAX_LAYER_MACS};
use crate::cli::sweep::{experiment_spec, LayerParams, ModelParams};
use crate::config::Json;
use crate::coordinator::{CampaignConfig, ExperimentSpec};
use crate::distributions::{Distribution, Sampler};
use crate::energy::{EnergyBreakdown, TechParams};
use crate::explore::{self, ParetoPlan};
use crate::figures::{self, fig12, FigureCtx};
use crate::mac::FormatPair;
use crate::model::ModelSpec;
use crate::server::cache::ShardedCache;
use crate::server::proto::{self, obj, Request, RequestKind, SweepExperiment, TraceSource};
use crate::spec::{required_enob, Arch, SpecConfig};
use crate::tile::LayerSpec;
use crate::workload::{self, EmpiricalDist, TensorTrace};
use anyhow::{anyhow, bail, Context, Result};
use crate::util::sync::Arc;

/// One request kind's compute pipeline; see the module docs for the
/// three-phase contract.
pub(super) trait Handler {
    /// The kind this handler serves (selects its rendered-payload cache
    /// and its metrics slot).
    fn kind(&self) -> RequestKind;
    /// Validate, enforce caps, resolve specs, return the canonical key.
    fn plan(&mut self, svc: &CampaignService) -> Result<String>;
    /// Cold path: produce the cacheable payload (rendered JSON text).
    fn compute(&self, svc: &CampaignService) -> Result<String>;
    /// Wrap the payload with per-request (uncached) echo fields.
    fn render(&self, svc: &CampaignService, payload: Json) -> Result<Json>;
}

impl CampaignService {
    /// The rendered-payload cache of one compute kind.
    fn rendered(&self, kind: RequestKind) -> &ShardedCache<String> {
        match kind {
            RequestKind::Energy => &self.energies,
            RequestKind::Sweep => &self.sweeps,
            RequestKind::Figure => &self.figs,
            RequestKind::Workload => &self.workloads,
            RequestKind::Layer => &self.layers,
            RequestKind::Model => &self.models,
            RequestKind::Pareto => &self.paretos,
            RequestKind::Info | RequestKind::Metrics => {
                unreachable!("inline kinds are answered without a cache")
            }
        }
    }

    /// Run one handler through the unified pipeline: plan → single-flight
    /// cached compute → render. The `bool` is the wire `cached` flag
    /// (true when no fresh computation ran for this call).
    pub(super) fn run_handler<H: Handler>(&self, h: &mut H) -> Result<(Json, bool)> {
        let key = h.plan(self)?;
        let (text, outcome) = self.rendered(h.kind()).get_or_compute(&key, || h.compute(self))?;
        let payload = Json::parse(&text)
            .with_context(|| format!("re-parsing cached {} payload", h.kind().name()))?;
        Ok((h.render(self, payload)?, outcome.is_cached()))
    }
}

/// Dispatch one parsed request to its handler. Inline kinds
/// (`info`/`metrics`) are answered directly — they read shared counters
/// and are never cached.
pub(super) fn dispatch(svc: &CampaignService, req: &Request) -> Result<(Json, bool)> {
    let seed_of = |seed: &Option<u64>| seed.unwrap_or(svc.campaign.seed);
    match req {
        Request::Info => svc.info().map(|j| (j, false)),
        Request::Metrics => Ok((svc.metrics_snapshot(), false)),
        Request::Energy { dr_db, sqnr_db, samples, seed, sampler } => {
            svc.run_handler(&mut EnergyHandler {
                dr_db: *dr_db,
                sqnr_db: *sqnr_db,
                samples: *samples,
                seed: seed_of(seed),
                sampler: *sampler,
            })
        }
        Request::Sweep { samples, seed, sampler, experiments } => {
            svc.run_handler(&mut SweepHandler {
                samples: *samples,
                seed: seed_of(seed),
                sampler: *sampler,
                experiments: experiments.clone(),
                specs: Vec::new(),
            })
        }
        Request::Figure { id, samples, seed } => svc.run_handler(&mut FigureHandler {
            id: id.clone(),
            samples: *samples,
            seed: seed_of(seed),
        }),
        Request::Layer { params, seed } => svc.run_handler(&mut LayerHandler {
            params: params.clone(),
            seed: seed_of(seed),
            spec: None,
        }),
        Request::Model { params, seed } => svc.run_handler(&mut ModelHandler {
            params: params.clone(),
            seed: seed_of(seed),
            spec: None,
        }),
        Request::Workload { source, samples, seed } => svc.run_handler(&mut WorkloadHandler {
            source: source.clone(),
            samples: *samples,
            seed: seed_of(seed),
            fit: None,
            trace_name: String::new(),
            trace_len: 0,
        }),
        Request::Pareto { plan } => svc.run_handler(&mut ParetoHandler {
            plan_text: plan.clone(),
            plan: None,
        }),
    }
}

fn arch_json(name: &str, enob: f64, b: &EnergyBreakdown) -> Json {
    obj(vec![
        ("arch", Json::Str(name.to_string())),
        ("enob", Json::Num(enob)),
        ("total_fj", Json::Num(b.total())),
        ("adc", Json::Num(b.adc)),
        ("dac", Json::Num(b.dac)),
        ("cells", Json::Num(b.cells)),
        ("exp_logic", Json::Num(b.exp_logic)),
        ("tree", Json::Num(b.tree)),
        ("norm_mult", Json::Num(b.norm_mult)),
    ])
}

/// A typed cap/validation rejection — rendered as a `bad_request`
/// error line by the dispatcher (see [`super::BadRequest`]).
fn bad_request(msg: String) -> anyhow::Error {
    anyhow::Error::new(super::BadRequest(msg))
}

/// The shared sample-count gate every Monte-Carlo request kind applies
/// in `plan` — one call site per handler, checked by the repo lint
/// (`grcim-lint` rule H).
fn check_samples(samples: usize) -> Result<()> {
    if samples == 0 {
        bail!("samples must be positive");
    }
    Ok(())
}

/// The `layer` request's MAC and operand-slab caps (also applied, over
/// the layer sum, by [`check_model_caps`]). Oversized shapes are a
/// client mistake, so both caps reject with a typed `bad_request`.
fn check_layer_caps(spec: &LayerSpec) -> Result<()> {
    if spec.shape.macs() > MAX_LAYER_MACS {
        return Err(bad_request(format!(
            "layer shape {} is too large for the service ({} MACs > {MAX_LAYER_MACS})",
            spec.shape,
            spec.shape.macs()
        )));
    }
    // parse_shape bounds each dimension to 2^20, so these products
    // cannot overflow u64
    let x_elems = spec.shape.m as u64 * spec.shape.k as u64;
    let wt_elems = spec.shape.n as u64 * spec.shape.k as u64;
    if x_elems.max(wt_elems) > MAX_LAYER_ELEMS {
        return Err(bad_request(format!(
            "layer shape {} is too large for the service (operand slab \
             of {} elements > {MAX_LAYER_ELEMS})",
            spec.shape,
            x_elems.max(wt_elems)
        )));
    }
    Ok(())
}

/// The `model` request's caps: the `layer` budgets applied across the
/// **layer sum**, so chaining layers cannot smuggle in more compute or
/// memory than one maximal layer gets. Per-kind accounting goes through
/// [`crate::model::ModelLayer`]: attention layers charge `2·M·S·d` MACs
/// and their slab counts the KV cache plus the per-head probability
/// matrices (`2·heads·M·S`) — the O(ctx²) terms that make an oversized
/// `decode:` request trip *here*, as a typed `bad_request`, instead of
/// OOMing a worker.
fn check_model_caps(spec: &ModelSpec) -> Result<()> {
    let total_macs = spec.macs();
    if total_macs > MAX_LAYER_MACS {
        return Err(bad_request(format!(
            "model '{}' is too large for the service ({total_macs} MACs across \
             {} layers > {MAX_LAYER_MACS})",
            spec.name,
            spec.layers.len()
        )));
    }
    // the slab cap applies to the **sum** of every layer's operand
    // elements: run_model materializes all weight slabs (and KV caches)
    // for the whole run, so a per-layer cap would let a 64-layer chain
    // allocate 64x the budget one maximal layer gets
    let sum_elems =
        spec.layers.iter().fold(0u64, |acc, l| acc.saturating_add(l.slab_elems()));
    if sum_elems > MAX_LAYER_ELEMS {
        return Err(bad_request(format!(
            "model '{}' is too large for the service (operand slabs \
             of {sum_elems} total elements > {MAX_LAYER_ELEMS})",
            spec.name
        )));
    }
    Ok(())
}

/// `energy` — the Fig. 12 spec-point query: two cached aggregates
/// (INT/narrow bounds and FP/full scale) evaluated through
/// [`fig12::evaluate_at`]. The rendered response is itself cached (by
/// [`proto::energy_key`]) on top of the aggregate cache, so repeat
/// queries skip even the solve/render step while the aggregates stay
/// reusable across `energy` and `sweep` requests.
struct EnergyHandler {
    dr_db: f64,
    sqnr_db: f64,
    samples: usize,
    seed: u64,
    sampler: Sampler,
}

impl Handler for EnergyHandler {
    fn kind(&self) -> RequestKind {
        RequestKind::Energy
    }

    fn plan(&mut self, svc: &CampaignService) -> Result<String> {
        check_samples(self.samples)?;
        let p = fig12::SpecPoint::from_db(self.dr_db, self.sqnr_db);
        if p.fp_format().is_none() || p.int_format().is_none() {
            bail!(
                "spec point (DR {} dB, SQNR {} dB) is left of the INT line",
                self.dr_db,
                self.sqnr_db
            );
        }
        Ok(proto::energy_key(
            self.dr_db,
            self.sqnr_db,
            self.samples,
            self.seed,
            self.sampler,
            svc.engine_name(),
        ))
    }

    fn compute(&self, svc: &CampaignService) -> Result<String> {
        let p = fig12::SpecPoint::from_db(self.dr_db, self.sqnr_db);
        let (Some(fp), Some(int)) = (p.fp_format(), p.int_format()) else {
            bail!("spec point invalidated between plan and compute");
        };
        let w_fmt = fig12::weight_fmt();
        let w_dist = Distribution::max_entropy(w_fmt);
        let int_spec = ExperimentSpec {
            id: "serve-int".to_string(),
            fmts: FormatPair::new(int, w_fmt),
            dist_x: fig12::narrow_bounds_dist(fp),
            dist_w: w_dist.clone(),
            nr: fig12::NR,
            samples: self.samples,
            sampler: self.sampler,
        };
        let fp_spec = ExperimentSpec {
            id: "serve-fp".to_string(),
            fmts: FormatPair::new(fp, w_fmt),
            dist_x: Distribution::Uniform,
            dist_w: w_dist,
            nr: fig12::NR,
            samples: self.samples,
            sampler: self.sampler,
        };
        let (agg_int, _) = svc.aggregate(&int_spec, self.seed)?;
        let (agg_fp, _) = svc.aggregate(&fp_spec, self.seed)?;
        let tech = TechParams::default();
        let r = fig12::evaluate_at(&p, &agg_int, &agg_fp, &tech)
            .ok_or_else(|| anyhow!("spec point invalidated between plan and compute"))?;

        let mut archs = vec![arch_json("conventional", r.enob_conv, &r.e_conv)];
        for (arch, enob, b) in &r.gr_all {
            archs.push(arch_json(arch.name(), *enob, b));
        }
        let gr_best = match &r.gr_best {
            Some((a, _, _)) => Json::Str(a.name().to_string()),
            None => Json::Null,
        };
        Ok(obj(vec![
            ("dr_db", Json::Num(self.dr_db)),
            ("sqnr_db", Json::Num(self.sqnr_db)),
            ("samples", Json::Num(agg_int.samples() as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("gr_best", gr_best),
            ("archs", Json::Arr(archs)),
        ])
        .to_string())
    }

    fn render(&self, _svc: &CampaignService, payload: Json) -> Result<Json> {
        Ok(payload)
    }
}

/// `sweep` — one cached aggregate per experiment, reported like the
/// CLI's sweep table. Each experiment runs as its own single-spec
/// campaign, so its aggregate is reusable across sweeps that mix
/// experiments differently; the rendered table is cached by
/// [`proto::sweep_key`] (which, unlike the aggregate key, covers the
/// experiment names the response echoes).
struct SweepHandler {
    samples: usize,
    seed: u64,
    sampler: Sampler,
    experiments: Vec<SweepExperiment>,
    /// Resolved by `plan`, read by `compute`.
    specs: Vec<ExperimentSpec>,
}

impl Handler for SweepHandler {
    fn kind(&self) -> RequestKind {
        RequestKind::Sweep
    }

    fn plan(&mut self, svc: &CampaignService) -> Result<String> {
        check_samples(self.samples)?;
        self.specs.clear();
        for e in &self.experiments {
            // empirical distributions read a server-side trace file; the
            // same confinement as the workload request applies
            if let Some(path) = e.distribution.strip_prefix("empirical:") {
                confined_trace_path(path)?;
            }
            let mut spec = experiment_spec(
                &e.name,
                e.n_e,
                e.n_m,
                e.nr,
                &e.distribution,
                self.samples,
            )?;
            spec.sampler = self.sampler;
            self.specs.push(spec);
        }
        Ok(proto::sweep_key(&self.specs, self.seed, svc.engine_name()))
    }

    fn compute(&self, svc: &CampaignService) -> Result<String> {
        let scfg = SpecConfig::default();
        let mut rows = Vec::new();
        for (e, spec) in self.experiments.iter().zip(&self.specs) {
            let (agg, _) = svc.aggregate(spec, self.seed)?;
            rows.push(obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("samples", Json::Num(agg.samples() as f64)),
                (
                    "enob_conv",
                    Json::Num(required_enob(&agg, Arch::Conventional, scfg).enob),
                ),
                (
                    "enob_gr_unit",
                    Json::Num(required_enob(&agg, Arch::GrUnit, scfg).enob),
                ),
                (
                    "enob_gr_row",
                    Json::Num(required_enob(&agg, Arch::GrRow, scfg).enob),
                ),
                ("mean_n_eff", Json::Num(agg.mean_n_eff())),
                ("sqnr_db", Json::Num(agg.sqnr_db())),
            ]));
        }
        Ok(obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("experiments", Json::Arr(rows)),
        ])
        .to_string())
    }

    fn render(&self, _svc: &CampaignService, payload: Json) -> Result<Json> {
        Ok(payload)
    }
}

/// `figure` — regenerate one paper figure/table as JSON
/// ([`crate::report::FigureResult::to_json`]); the rendered JSON text
/// is the cached payload.
struct FigureHandler {
    id: String,
    samples: usize,
    seed: u64,
}

impl Handler for FigureHandler {
    fn kind(&self) -> RequestKind {
        RequestKind::Figure
    }

    fn plan(&mut self, svc: &CampaignService) -> Result<String> {
        check_samples(self.samples)?;
        // unknown ids fail in compute (figures::run validates); errors
        // are never cached, so the key for a bad id stays vacant
        Ok(proto::figure_key(&self.id, self.samples, self.seed, svc.engine_name()))
    }

    fn compute(&self, svc: &CampaignService) -> Result<String> {
        let campaign = CampaignConfig { seed: self.seed, ..svc.campaign.clone() };
        let ctx = FigureCtx {
            campaign,
            samples: self.samples,
            // figures only write files through `FigureResult::emit`,
            // which the service never calls; out_dir is unused
            out_dir: std::env::temp_dir(),
        };
        let fr = figures::run(&self.id, &ctx)?;
        Ok(fr.to_json().to_string())
    }

    fn render(&self, _svc: &CampaignService, payload: Json) -> Result<Json> {
        Ok(obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("figure", payload),
        ]))
    }
}

/// `layer` — evaluate a named layer shape on the tiled array mapper
/// ([`crate::tile::run_layer`]), cached by [`proto::layer_key`] over
/// the **resolved** spec, so request aliases (`gr` vs `gr-unit`, named
/// shape vs explicit `gemm:`) share one entry. Empirical activation
/// traces are confined like workload paths.
struct LayerHandler {
    params: LayerParams,
    seed: u64,
    /// Resolved by `plan`, read by `compute` and `render`.
    spec: Option<LayerSpec>,
}

impl Handler for LayerHandler {
    fn kind(&self) -> RequestKind {
        RequestKind::Layer
    }

    fn plan(&mut self, svc: &CampaignService) -> Result<String> {
        // empirical distributions read a server-side trace file
        if let Some(path) = self.params.distribution.strip_prefix("empirical:") {
            confined_trace_path(path)?;
        }
        let spec = self.params.resolve()?;
        check_layer_caps(&spec)?;
        let key = proto::layer_key(&spec, self.seed, svc.engine_name());
        self.spec = Some(spec);
        Ok(key)
    }

    fn compute(&self, svc: &CampaignService) -> Result<String> {
        let spec = self
            .spec
            .clone()
            .ok_or_else(|| anyhow!("layer compute ran before plan resolved the spec"))?;
        let campaign = CampaignConfig { seed: self.seed, ..svc.campaign.clone() };
        let res = crate::tile::run_layer(&spec, &campaign)?;
        Ok(res.report.to_figure_result().to_json().to_string())
    }

    fn render(&self, _svc: &CampaignService, payload: Json) -> Result<Json> {
        let spec = self
            .spec
            .as_ref()
            .ok_or_else(|| anyhow!("layer render ran before plan resolved the spec"))?;
        Ok(obj(vec![
            ("shape", Json::Str(self.params.shape.clone())),
            ("gemm", Json::Str(spec.shape.to_string())),
            ("arch", Json::Str(spec.cfg.arch.name().to_string())),
            ("seed", Json::Num(self.seed as f64)),
            ("layer", payload),
        ]))
    }
}

/// `model` — evaluate a multi-layer model on the chained tile pipeline
/// ([`crate::model::run_model`]), cached by [`proto::model_key`] over
/// the **resolved** spec. The `layer` caps are enforced across the
/// layer sum by [`check_model_caps`].
struct ModelHandler {
    params: ModelParams,
    seed: u64,
    /// Resolved by `plan`, read by `compute` and `render`.
    spec: Option<ModelSpec>,
}

impl Handler for ModelHandler {
    fn kind(&self) -> RequestKind {
        RequestKind::Model
    }

    fn plan(&mut self, svc: &CampaignService) -> Result<String> {
        // empirical model-input distributions read a server-side trace
        if let Some(path) = self.params.distribution.strip_prefix("empirical:") {
            confined_trace_path(path)?;
        }
        let spec = self.params.resolve()?;
        check_model_caps(&spec)?;
        let key = proto::model_key(&spec, self.seed, svc.engine_name());
        self.spec = Some(spec);
        Ok(key)
    }

    fn compute(&self, svc: &CampaignService) -> Result<String> {
        let spec = self
            .spec
            .clone()
            .ok_or_else(|| anyhow!("model compute ran before plan resolved the spec"))?;
        let campaign = CampaignConfig { seed: self.seed, ..svc.campaign.clone() };
        let res = crate::model::run_model(&spec, &campaign)?;
        Ok(res.report.to_figure_result().to_json().to_string())
    }

    fn render(&self, _svc: &CampaignService, payload: Json) -> Result<Json> {
        let spec = self
            .spec
            .as_ref()
            .ok_or_else(|| anyhow!("model render ran before plan resolved the spec"))?;
        Ok(obj(vec![
            ("model", Json::Str(self.params.model.clone())),
            ("layers", Json::Num(spec.layers.len() as f64)),
            ("arch", Json::Str(spec.cfg.arch.name().to_string())),
            ("seed", Json::Num(self.seed as f64)),
            ("report", payload),
        ]))
    }
}

/// `pareto` — expand a design-space plan and run the full exploration
/// ([`crate::explore::run_fresh`]), cached by [`proto::pareto_key`]
/// over the canonical plan's content hash, so alias spellings of the
/// same plan share one entry. [`ParetoPlan::from_toml`] enforces the
/// service's MAC and operand-slab caps across the **whole grid** at
/// plan time (every workload, and the grid-total MAC budget), so an
/// oversized plan is rejected before any point runs; the plan carries
/// its own seed, so no request-level seed participates.
struct ParetoHandler {
    plan_text: String,
    /// Resolved by `plan`, read by `compute`.
    plan: Option<ParetoPlan>,
}

impl Handler for ParetoHandler {
    fn kind(&self) -> RequestKind {
        RequestKind::Pareto
    }

    fn plan(&mut self, svc: &CampaignService) -> Result<String> {
        let plan = ParetoPlan::from_toml(&self.plan_text)
            .map_err(|e| bad_request(format!("{e:#}")))?;
        let key = proto::pareto_key(plan.content_hash(), svc.engine_name());
        self.plan = Some(plan);
        Ok(key)
    }

    fn compute(&self, svc: &CampaignService) -> Result<String> {
        let plan = self
            .plan
            .clone()
            .ok_or_else(|| anyhow!("pareto compute ran before plan parsed the plan"))?;
        let outcome = explore::run_fresh(&plan, &svc.campaign)?;
        let mut points = Vec::new();
        let mut frontier = Vec::new();
        for (p, &front) in outcome.points.iter().zip(&outcome.frontier) {
            let mut m = match p.to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("point records are objects"),
            };
            m.insert("frontier".to_string(), Json::Bool(front));
            points.push(Json::Obj(m));
            if front {
                frontier.push(Json::Num(p.index as f64));
            }
        }
        Ok(obj(vec![
            ("plan", plan.to_json()),
            ("plan_hash", Json::Str(format!("{:016x}", plan.content_hash()))),
            ("points", Json::Arr(points)),
            ("frontier_indices", Json::Arr(frontier)),
        ])
        .to_string())
    }

    fn render(&self, _svc: &CampaignService, payload: Json) -> Result<Json> {
        Ok(payload)
    }
}

/// `workload` — fit an empirical trace and run the full `grcim
/// workload` analysis ([`crate::workload::report`]), cached by the
/// trace's **content hash**: two uploads of the same tensor (even under
/// different names or paths) share one entry, and hits are
/// byte-identical to the cold compute. Server-side paths are confined
/// (see [`confined_trace_path`]).
struct WorkloadHandler {
    source: TraceSource,
    samples: usize,
    seed: u64,
    /// Fit by `plan` (the content hash is the cache identity), read by
    /// `compute` and `render`.
    fit: Option<Arc<EmpiricalDist>>,
    trace_name: String,
    trace_len: usize,
}

impl Handler for WorkloadHandler {
    fn kind(&self) -> RequestKind {
        RequestKind::Workload
    }

    fn plan(&mut self, svc: &CampaignService) -> Result<String> {
        check_samples(self.samples)?;
        let trace = match &self.source {
            TraceSource::Path(p) => TensorTrace::read(&confined_trace_path(p)?)?,
            TraceSource::Inline { name, values } => {
                TensorTrace::from_f64(name.clone(), vec![values.len()], values.clone())?
            }
        };
        self.trace_name = trace.name().to_string();
        self.trace_len = trace.len();
        let fit = Arc::new(EmpiricalDist::fit(&trace)?);
        let key =
            proto::workload_key(fit.content_hash(), self.samples, self.seed, svc.engine_name());
        self.fit = Some(fit);
        Ok(key)
    }

    fn compute(&self, svc: &CampaignService) -> Result<String> {
        let fit = self
            .fit
            .as_ref()
            .ok_or_else(|| anyhow!("workload compute ran before plan fit the trace"))?;
        let campaign = CampaignConfig { seed: self.seed, ..svc.campaign.clone() };
        let fr = workload::report(fit, &campaign, self.samples)?;
        Ok(fr.to_json().to_string())
    }

    fn render(&self, _svc: &CampaignService, payload: Json) -> Result<Json> {
        let fit = self
            .fit
            .as_ref()
            .ok_or_else(|| anyhow!("workload render ran before plan fit the trace"))?;
        Ok(obj(vec![
            ("trace", Json::Str(self.trace_name.clone())),
            ("content_hash", Json::Str(format!("{:016x}", fit.content_hash()))),
            ("samples_in_trace", Json::Num(self.trace_len as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("workload", payload),
        ]))
    }
}
