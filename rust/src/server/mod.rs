//! `grcim serve` — a resident campaign service over TCP.
//!
//! The one-shot CLI pays the full Monte-Carlo cost on every invocation.
//! This layer keeps the process resident and serves spec-point queries
//! over newline-delimited JSON (see [`proto`]), with these properties:
//!
//! * **Spec-keyed caching** — every campaign aggregate is addressed by a
//!   canonical key ([`proto::spec_key`]) covering exactly the inputs that
//!   determine its bits, and every compute request kind additionally
//!   caches its *rendered response text*, so repeat queries are O(lookup)
//!   and hits are byte-identical to the cold compute.
//! * **Single-flight coalescing** — concurrent identical requests share
//!   one computation ([`cache::ShardedCache`]), so a thundering herd of
//!   the same spec costs one campaign.
//! * **Unified dispatch** — the six compute request kinds run through one
//!   `Request → cache key → compute → render` pipeline
//!   ([`handlers`]); misses dispatch into
//!   [`crate::coordinator::run_campaign`] and its per-worker
//!   `JobBuffers`, so the MC hot path stays allocation-free under load.
//! * **Admission control** — compute requests pass through a bounded
//!   queue; when it is full the client gets a typed `busy` error
//!   immediately instead of unbounded queueing. A request may carry a
//!   `deadline_ms`; one that expires before a worker picks it up gets a
//!   typed `deadline` error instead of a stale result.
//! * **Observability** — the `metrics` request snapshots cache
//!   hit/miss/compute counters, queue depth, and per-kind latency
//!   p50/p99 (see [`metrics`]).
//!
//! Request lifecycle (threads are **O(muxes + workers)**, not
//! O(connections) — see [`reactor`] for the event-loop internals):
//!
//! ```text
//!  client line ──▶ mux thread ── parse_request_meta ──▶ Request
//!                   │   inline (info/metrics): answered on the mux
//!                   ▼
//!            bounded ComputeQueue ──full──▶ {"ok":false,"kind":"busy"}
//!                   │ pop (deadline checked here)
//!                   ▼
//!             compute worker ──▶ handlers::dispatch
//!                                  │ plan (validate, caps, cache key)
//!                                  ▼
//!                       ShardedCache::get_or_compute
//!                        hit │          │ miss (single-flight leader)
//!                            │          ▼
//!                            │   run_campaign ──▶ worker pool
//!                            ▼          │         (JobBuffers)
//!                     rendered JSON ◀───┘
//!                                  │ render (uncached echo fields)
//!                                  ▼
//!  client line ◀── mux thread ◀── ok_line/err_line
//! ```
//!
//! Shutdown is one shared drain path ([`Server::shutdown`] and
//! [`Server::join`] both end in it): stop accepting, finish every
//! admitted compute job, flush every response, join every thread.

pub mod cache;
mod handlers;
pub mod loadgen;
pub mod metrics;
pub mod proto;
mod reactor;

use crate::config::Json;
use crate::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
use crate::runtime::EngineKind;
use crate::stats::ColumnAgg;
use anyhow::{anyhow, bail, Context, Result};
use cache::{Outcome, ShardedCache, StatsSnapshot};
use metrics::ServerMetrics;
use proto::{obj, Request};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use crate::util::sync::Arc;

/// Default listen address of `grcim serve`.
pub const DEFAULT_ADDR: &str = "127.0.0.1:4080";

/// Largest accepted request line; a client streaming more without a
/// newline gets an error, the rest of that line is discarded up to its
/// newline (never parsed as a request), and the connection keeps
/// serving. Bounds per-connection memory.
const MAX_LINE: usize = 1 << 20;

/// Largest layer a `layer` request may evaluate, in MACs (M·K·N) — caps
/// the reference-GEMM compute (a 4096-d MLP up-projection at 4 tokens is
/// ~2.7e8 MACs, far below it). A `model` request's **layer sum** is held
/// to the same budget: chaining layers must not smuggle in more compute
/// than one maximal layer.
pub const MAX_LAYER_MACS: u64 = 1 << 36;

/// Largest operand slab (`M·K` or `N·K` f32 elements) a `layer` request
/// may allocate — caps request *memory* independently of the MAC
/// product (a skinny `gemm:1x1048576x65536` is only 2^36 MACs but would
/// otherwise allocate a 256 GiB weight slab). 2^27 elements = 512 MiB;
/// `mlp-up:4096` needs exactly 2^26. Model requests audit the same cap
/// per layer through [`crate::model::ModelLayer::slab_elems`], which
/// for attention layers counts the KV cache and the `heads·M·S`
/// probability matrices — the O(ctx²) terms a decode request can blow
/// up (`decode:1024x4x1000000` trips this cap, not a worker OOM).
pub const MAX_LAYER_ELEMS: u64 = 1 << 27;

/// A request rejected at validation time — malformed or over the serve
/// caps. [`CampaignService::respond_with_status`] renders any error
/// whose chain carries one of these as a typed `bad_request` line, so
/// clients can tell "fix your request" from server-side failures.
#[derive(Debug)]
pub struct BadRequest(pub String);

impl std::fmt::Display for BadRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for BadRequest {}

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; port 0 binds an ephemeral port (tests).
    pub addr: String,
    /// Campaign settings every computation runs under (engine, workers,
    /// default seed, artifacts directory).
    pub campaign: CampaignConfig,
    /// Total cached entries across the aggregate and rendered-response
    /// caches.
    pub cache_entries: usize,
    /// Connection-multiplexer threads (0 = auto: ~1 per 4 cores, 1–4).
    /// Each mux owns a share of the open connections; connection count
    /// does not add threads.
    pub mux_threads: usize,
    /// Compute worker threads (0 = auto: ~1 per 2 cores, 1–4). Each
    /// worker runs one admitted request at a time; the campaign's own
    /// worker pool parallelizes within a request.
    pub compute_threads: usize,
    /// Admission-queue capacity (0 = auto: 4× compute threads, min 16).
    /// Requests beyond it get a typed `busy` error immediately.
    pub queue_cap: usize,
    /// Test-only fault injection: a request line containing this
    /// substring panics the mux thread that reads it, exercising the
    /// dead-mux recovery path (acceptor rerouting + the panic surfacing
    /// from [`Server::join`]). Always `None` in production.
    #[doc(hidden)]
    pub mux_panic_line: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: DEFAULT_ADDR.to_string(),
            campaign: CampaignConfig::default(),
            cache_entries: 1024,
            mux_threads: 0,
            compute_threads: 0,
            queue_cap: 0,
            mux_panic_line: None,
        }
    }
}

fn parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

impl ServeConfig {
    /// `mux_threads` with 0 resolved to the auto policy.
    pub fn resolved_mux_threads(&self) -> usize {
        if self.mux_threads > 0 {
            self.mux_threads
        } else {
            (parallelism() / 4).clamp(1, 4)
        }
    }

    /// `compute_threads` with 0 resolved to the auto policy.
    pub fn resolved_compute_threads(&self) -> usize {
        if self.compute_threads > 0 {
            self.compute_threads
        } else {
            (parallelism() / 2).clamp(1, 4)
        }
    }

    /// `queue_cap` with 0 resolved to the auto policy.
    pub fn resolved_queue_cap(&self) -> usize {
        if self.queue_cap > 0 {
            self.queue_cap
        } else {
            (4 * self.resolved_compute_threads()).max(16)
        }
    }
}

/// The request handlers plus their result caches and telemetry —
/// everything the server shares across connections. Usable without the
/// TCP layer (the unit tests drive [`CampaignService::respond`]
/// directly).
pub struct CampaignService {
    campaign: CampaignConfig,
    metrics: Arc<ServerMetrics>,
    aggs: ShardedCache<ColumnAgg>,
    energies: ShardedCache<String>,
    sweeps: ShardedCache<String>,
    figs: ShardedCache<String>,
    workloads: ShardedCache<String>,
    layers: ShardedCache<String>,
    models: ShardedCache<String>,
    paretos: ShardedCache<String>,
}

impl CampaignService {
    /// Build the handlers around one campaign configuration and a total
    /// cache budget (split across the aggregate and rendered-response
    /// caches).
    pub fn new(campaign: CampaignConfig, cache_entries: usize) -> Self {
        let sub = (cache_entries / 8).max(8);
        CampaignService {
            campaign,
            metrics: Arc::new(ServerMetrics::new()),
            aggs: ShardedCache::new(cache_entries),
            energies: ShardedCache::new(sub),
            sweeps: ShardedCache::new(sub),
            figs: ShardedCache::new(sub),
            workloads: ShardedCache::new(sub),
            layers: ShardedCache::new(sub),
            models: ShardedCache::new(sub),
            paretos: ShardedCache::new(sub),
        }
    }

    fn engine_name(&self) -> &'static str {
        match self.campaign.engine {
            EngineKind::Rust => "rust",
            EngineKind::Pjrt => "pjrt",
            EngineKind::Auto => "auto",
        }
    }

    /// The server telemetry this service reports through `metrics`
    /// responses (the event loop's threads update it).
    pub fn metrics(&self) -> &Arc<ServerMetrics> {
        &self.metrics
    }

    /// The campaign aggregate for one spec, through the cache. A miss
    /// runs the spec as its own single-spec campaign (grid index 0 in the
    /// seeding scheme), so the result is a pure function of
    /// (spec, seed, engine) — the property the cache key relies on.
    pub fn aggregate(&self, spec: &ExperimentSpec, seed: u64) -> Result<(Arc<ColumnAgg>, Outcome)> {
        let key = proto::spec_key(spec, seed, self.engine_name());
        self.aggs.get_or_compute(&key, || {
            let cfg = CampaignConfig { seed, ..self.campaign.clone() };
            let mut aggs = run_campaign(std::slice::from_ref(spec), &cfg)?;
            aggs.pop().ok_or_else(|| anyhow!("campaign returned no aggregate for the spec"))
        })
    }

    /// Cache counters for the aggregate cache (the integration test's
    /// single-flight assertion reads `computes` from here via `info`).
    pub fn aggregate_stats(&self) -> StatsSnapshot {
        self.aggs.stats()
    }

    /// Handle one parsed request; returns the response line (no newline).
    pub fn respond(&self, req: &Request) -> String {
        self.respond_with_status(req).0
    }

    /// Handle one parsed request; returns the response line (no newline)
    /// and whether it is a success (`"ok":true`) — the event loop's
    /// per-kind ok/error metrics read the flag without re-parsing.
    pub fn respond_with_status(&self, req: &Request) -> (String, bool) {
        match handlers::dispatch(self, req) {
            Ok((result, cached)) => (proto::ok_line(result, cached), true),
            Err(e) => {
                let kind = if e.chain().any(|c| c.downcast_ref::<BadRequest>().is_some()) {
                    "bad_request"
                } else {
                    "error"
                };
                (proto::err_kind_line(kind, &format!("{e:#}")), false)
            }
        }
    }

    fn info(&self) -> Result<Json> {
        Ok(obj(vec![
            ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
            ("proto", Json::Num(proto::PROTO_VERSION as f64)),
            ("engine", Json::Str(self.engine_name().to_string())),
            ("workers", Json::Num(self.campaign.effective_workers() as f64)),
            ("seed", Json::Num(self.campaign.seed as f64)),
            ("aggregates", self.aggs.stats().to_json()),
            ("energies", self.energies.stats().to_json()),
            ("sweeps", self.sweeps.stats().to_json()),
            ("figures", self.figs.stats().to_json()),
            ("layers", self.layers.stats().to_json()),
            ("models", self.models.stats().to_json()),
            ("workloads", self.workloads.stats().to_json()),
            ("paretos", self.paretos.stats().to_json()),
        ]))
    }

    /// The `metrics` response: server telemetry (connections, admission,
    /// queue gauges, per-kind latency percentiles) plus every cache's
    /// counters. Answered inline by the event loop — never queued, never
    /// cached.
    fn metrics_snapshot(&self) -> Json {
        obj(vec![
            ("proto", Json::Num(proto::PROTO_VERSION as f64)),
            ("server", self.metrics.to_json()),
            (
                "caches",
                obj(vec![
                    ("aggregates", self.aggs.stats().to_json()),
                    ("energies", self.energies.stats().to_json()),
                    ("sweeps", self.sweeps.stats().to_json()),
                    ("figures", self.figs.stats().to_json()),
                    ("layers", self.layers.stats().to_json()),
                    ("models", self.models.stats().to_json()),
                    ("workloads", self.workloads.stats().to_json()),
                    ("paretos", self.paretos.stats().to_json()),
                ]),
            ),
        ])
    }
}

/// A running `grcim serve` instance: the [`reactor`] event loop (bounded
/// acceptor, connection-multiplexer threads, compute workers) around one
/// shared [`CampaignService`].
pub struct Server {
    addr: SocketAddr,
    service: Arc<CampaignService>,
    reactor: Option<reactor::Reactor>,
}

impl Server {
    /// Bind and start serving in background threads; returns immediately.
    pub fn spawn(cfg: ServeConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let service = Arc::new(CampaignService::new(cfg.campaign.clone(), cfg.cache_entries));
        let reactor = reactor::Reactor::spawn(
            listener,
            Arc::clone(&service),
            Arc::clone(service.metrics()),
            cfg.resolved_mux_threads(),
            cfg.resolved_compute_threads(),
            cfg.resolved_queue_cap(),
            cfg.mux_panic_line.clone(),
        )?;
        Ok(Server { addr, service, reactor: Some(reactor) })
    }

    /// The actually bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Direct access to the handlers/caches (stats, in-process queries).
    pub fn service(&self) -> &CampaignService {
        &self.service
    }

    /// Stop accepting, finish every admitted request, flush and join
    /// every thread (the one shared drain path). Errors if the acceptor
    /// had stopped on a fatal `accept` failure or a mux thread panicked.
    pub fn shutdown(mut self) -> Result<()> {
        match self.reactor.take() {
            Some(mut r) => r.drain(),
            // the reactor runs until the server is consumed; Self taken
            // by value makes a second teardown unrepresentable, so this
            // arm is a no-op safety net rather than an expect()
            None => Ok(()),
        }
    }

    /// Block until the acceptor exits — an external shutdown, a fatal
    /// `accept` error, or every mux thread dying — then run the same
    /// drain path as [`Server::shutdown`]. `grcim serve` runs this; a
    /// fatal accept error or a mux panic surfaces here instead of
    /// leaving a silent half-dead server.
    pub fn join(mut self) -> Result<()> {
        let Some(mut r) = self.reactor.take() else {
            return Ok(());
        };
        let accepted = r.join_acceptor();
        let drained = r.drain();
        accepted.and(drained)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(mut r) = self.reactor.take() {
            let _ = r.drain();
        }
    }
}

/// Confine a trace path received over the wire: requests may only name
/// **relative** paths without `..` components, and the path must
/// *resolve* (symlinks included) to a file under the serve process's
/// working directory. Without this, any TCP client could read and
/// statistically summarize arbitrary files on the server (the other
/// request kinds never touch the filesystem); the canonicalization step
/// closes the symlink escape a purely lexical check would leave open.
fn confined_trace_path(p: &str) -> Result<std::path::PathBuf> {
    use std::path::Component;
    let path = std::path::Path::new(p);
    let confined = !path.is_absolute()
        && path.components().all(|c| matches!(c, Component::Normal(_) | Component::CurDir));
    if !confined {
        bail!(
            "trace path '{p}' is not allowed over the wire: server-side \
             traces must be relative paths without '..' (resolved in the \
             serve process's working directory)"
        );
    }
    let cwd = std::env::current_dir()
        .and_then(|d| d.canonicalize())
        .context("resolving the serve working directory")?;
    let real = path.canonicalize().with_context(|| format!("resolving trace path '{p}'"))?;
    if !real.starts_with(&cwd) {
        bail!(
            "trace path '{p}' is not allowed over the wire: it resolves to \
             {} outside the serve working directory",
            real.display()
        );
    }
    Ok(real)
}

/// One-shot client: send a single request line, read a single response
/// line. Backs `grcim query` and the integration tests.
pub fn query_once(addr: &str, request_line: &str) -> Result<String> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.write_all(request_line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        bail!("server closed the connection without responding");
    }
    Ok(line.trim_end().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::Distribution;

    fn test_service() -> CampaignService {
        CampaignService::new(
            CampaignConfig {
                engine: EngineKind::Rust,
                workers: 2,
                seed: 11,
                ..Default::default()
            },
            64,
        )
    }

    fn result_str(line: &str) -> String {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
        j.get("result").unwrap().to_string()
    }

    #[test]
    fn energy_response_shape_and_cache_flag() {
        let svc = test_service();
        let req = proto::parse_request(
            r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512}"#,
        )
        .unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        // rounded up to one whole coordinator job
        assert_eq!(r.get("samples").unwrap().as_usize(), Some(2048));
        let archs = r.get("archs").unwrap().items();
        assert!(archs.len() >= 2, "conventional + at least one GR");
        assert_eq!(
            archs[0].get("arch").and_then(Json::as_str),
            Some("conventional")
        );
        for a in archs {
            assert!(a.get("total_fj").unwrap().as_f64().unwrap() > 0.0);
        }

        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm), "hit must be bit-identical");
        assert_eq!(svc.aggregate_stats().computes, 2); // int + fp aggregates
        // the rendered response is itself cached: the warm call was a
        // response-level hit, not a re-render over aggregate hits
        assert_eq!(svc.energies.stats().computes, 1);
        assert_eq!(svc.energies.stats().hits, 1);
    }

    #[test]
    fn energy_left_of_int_line_is_an_error() {
        let svc = test_service();
        let req = proto::parse_request(
            r#"{"cmd":"energy","dr":12.0,"sqnr":47.0}"#,
        )
        .unwrap();
        let resp = svc.respond(&req);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert!(j
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("INT line"));
    }

    #[test]
    fn sweep_reuses_energy_aggregates_only_when_specs_match() {
        let svc = test_service();
        let req = proto::parse_request(
            r#"{"cmd":"sweep","samples":512,"experiments":[
                {"name":"a","n_e":3,"n_m":2,"nr":32,"distribution":"uniform"},
                {"name":"b","n_e":4,"n_m":2,"nr":32,"distribution":"gauss_outliers"}]}"#,
        )
        .unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let rows = j.get("result").unwrap().get("experiments").unwrap().items();
        assert_eq!(rows.len(), 2);
        for row in rows {
            let conv = row.get("enob_conv").unwrap().as_f64().unwrap();
            let unit = row.get("enob_gr_unit").unwrap().as_f64().unwrap();
            assert!(conv > unit, "conv {conv} vs gr-unit {unit}");
        }
        assert_eq!(svc.aggregate_stats().computes, 2);
        let warm = svc.respond(&req);
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.aggregate_stats().computes, 2);
        // the rendered sweep table is cached whole
        assert_eq!(svc.sweeps.stats().computes, 1);
        assert_eq!(svc.sweeps.stats().hits, 1);
    }

    #[test]
    fn figure_request_is_cached_and_identical() {
        let svc = test_service();
        // table1 is closed-form: fast and deterministic
        let req = proto::parse_request(
            r#"{"cmd":"figure","id":"table1","samples":256}"#,
        )
        .unwrap();
        let cold = svc.respond(&req);
        let warm = svc.respond(&req);
        assert_eq!(result_str(&cold), result_str(&warm));
        let j = Json::parse(&warm).unwrap();
        assert_eq!(j.get("cached"), Some(&Json::Bool(true)));
        let fig = j.get("result").unwrap().get("figure").unwrap();
        assert_eq!(fig.get("name").and_then(Json::as_str), Some("table1"));
        assert_eq!(fig.get("all_hold"), Some(&Json::Bool(true)));
    }

    #[test]
    fn unknown_figure_id_is_a_clean_error() {
        let svc = test_service();
        let req =
            proto::parse_request(r#"{"cmd":"figure","id":"fig99"}"#).unwrap();
        let j = Json::parse(&svc.respond(&req)).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert!(j
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("unknown figure"));
    }

    #[test]
    fn layer_request_cached_and_reconciled() {
        let svc = test_service();
        let line = r#"{"cmd":"layer","shape":"gemm:2x24x10","nr":8,"nc":4,
            "n_e":2,"arch":"gr","distribution":"gauss_outliers"}"#;
        let req = proto::parse_request(line).unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{cold}");
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        assert_eq!(r.get("gemm").and_then(Json::as_str), Some("2x24x10"));
        assert_eq!(r.get("arch").and_then(Json::as_str), Some("gr-unit"));
        let layer = r.get("layer").unwrap();
        assert_eq!(layer.get("name").and_then(Json::as_str), Some("layer"));
        // the invariant checks (incl. energy reconciliation) all hold
        assert_eq!(layer.get("all_hold"), Some(&Json::Bool(true)), "{layer}");
        // summary + components + histogram + tiles
        assert_eq!(layer.get("tables").unwrap().items().len(), 4);

        // byte-identical hit
        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.layers.stats().computes, 1);

        // an alias that resolves identically shares the entry
        let alias = line.replace("\"gr\"", "\"gr-unit\"");
        let req2 = proto::parse_request(&alias).unwrap();
        let j2 = Json::parse(&svc.respond(&req2)).unwrap();
        assert_eq!(j2.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(svc.layers.stats().computes, 1);
    }

    #[test]
    fn pareto_request_cached_by_plan_hash() {
        let svc = test_service();
        // a tiny 2-point grid; \n-joined TOML carried as the plan text
        let plan = "name = \"t\"\nseed = 7\ntokens = 2\n\
                    workload = \"gemm:2x8x4\"\n\
                    [axes]\nnr = [4, 8]\nnc = 4\nn_e = 2\nn_m = 2\n";
        let line = proto::obj(vec![
            ("cmd", Json::Str("pareto".to_string())),
            ("plan", Json::Str(plan.to_string())),
        ])
        .to_string();
        let req = proto::parse_request(&line).unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{cold}");
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        assert_eq!(r.get("points").unwrap().items().len(), 2);
        assert!(!r.get("frontier_indices").unwrap().items().is_empty());
        // every point's breakdown reconciles against its total
        for p in r.get("points").unwrap().items() {
            let pt = crate::explore::ExplorePoint::from_json(p).unwrap();
            assert!(pt.breakdown_reconciles(), "{p}");
        }

        // byte-identical hit
        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.paretos.stats().computes, 1);

        // an alias spelling of the same plan shares the entry
        let alias = line.replace("nc = 4", "nc = [4]");
        let req2 = proto::parse_request(&alias).unwrap();
        let j2 = Json::parse(&svc.respond(&req2)).unwrap();
        assert_eq!(j2.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j2.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(svc.paretos.stats().computes, 1);

        // a malformed plan is a clean error, not a panic
        let bad = proto::obj(vec![
            ("cmd", Json::Str("pareto".to_string())),
            ("plan", Json::Str("workload = \"warp:9\"\n".to_string())),
        ])
        .to_string();
        let req3 = proto::parse_request(&bad).unwrap();
        let j3 = Json::parse(&svc.respond(&req3)).unwrap();
        assert_eq!(j3.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn layer_request_bad_inputs_are_clean_errors() {
        let svc = test_service();
        for line in [
            r#"{"cmd":"layer","shape":"warp:64"}"#,
            r#"{"cmd":"layer","shape":"gemm:2x8x8","arch":"quantum"}"#,
            r#"{"cmd":"layer","shape":"gemm:2x8x8","nr":0}"#,
            // formats a worker thread could not even construct
            r#"{"cmd":"layer","shape":"gemm:2x8x8","n_e":64}"#,
            // over the MAC cap
            r#"{"cmd":"layer","shape":"gemm:100000x100000x100000"}"#,
            // under the MAC cap but over the operand-slab cap
            r#"{"cmd":"layer","shape":"gemm:1x1048576x65536"}"#,
            // empirical activation traces are confined like workload paths
            r#"{"cmd":"layer","shape":"gemm:2x8x8",
                "distribution":"empirical:/etc/hostname"}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
        }
    }

    #[test]
    fn model_request_cached_and_reconciled() {
        let svc = test_service();
        let line = r#"{"cmd":"model","model":"mlp:16x12x8","tokens":2,"nr":8,"nc":4,
            "n_e":2,"arch":"gr","fit":true}"#;
        let req = proto::parse_request(line).unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{cold}");
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        assert_eq!(r.get("model").and_then(Json::as_str), Some("mlp:16x12x8"));
        assert_eq!(r.get("layers").unwrap().as_usize(), Some(2));
        assert_eq!(r.get("arch").and_then(Json::as_str), Some("gr-unit"));
        let report = r.get("report").unwrap();
        assert_eq!(report.get("name").and_then(Json::as_str), Some("model"));
        // the invariant checks (incl. energy reconciliation) all hold
        assert_eq!(report.get("all_hold"), Some(&Json::Bool(true)), "{report}");
        // summary + layers + histogram
        assert_eq!(report.get("tables").unwrap().items().len(), 3);

        // byte-identical hit
        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.models.stats().computes, 1);

        // an arch alias resolving identically shares the entry
        let alias = line.replace("\"gr\"", "\"gr-unit\"");
        let req2 = proto::parse_request(&alias).unwrap();
        let j2 = Json::parse(&svc.respond(&req2)).unwrap();
        assert_eq!(j2.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(svc.models.stats().computes, 1);
    }

    #[test]
    fn concurrent_model_requests_coalesce_to_one_compute() {
        use std::sync::Barrier;
        const THREADS: usize = 6;
        let svc = Arc::new(test_service());
        let barrier = Arc::new(Barrier::new(THREADS));
        let line = r#"{"cmd":"model","model":"mlp:16x12x8","tokens":2,"nr":8,"nc":4,"n_e":2}"#;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let svc = Arc::clone(&svc);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    let req = proto::parse_request(line).unwrap();
                    barrier.wait();
                    svc.respond(&req)
                })
            })
            .collect();
        let responses: Vec<String> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // single-flight: one compute total, every result byte-identical
        assert_eq!(svc.models.stats().computes, 1, "{:?}", svc.models.stats());
        let first = result_str(&responses[0]);
        for resp in &responses {
            let j = Json::parse(resp).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
            assert_eq!(result_str(resp), first);
        }
    }

    #[test]
    fn model_request_bad_inputs_are_clean_errors() {
        let svc = test_service();
        for line in [
            r#"{"cmd":"model","model":"warp:64"}"#,
            r#"{"cmd":"model","model":"mlp:16"}"#,
            r#"{"cmd":"model","model":"mlp:16x8","arch":"quantum"}"#,
            r#"{"cmd":"model","model":"mlp:16x8","nr":0}"#,
            r#"{"cmd":"model","model":"mlp:16x8","n_e":64}"#,
            // a chain whose layer *sum* exceeds the MAC cap even though
            // every single layer is within it (2 x 2^36 MACs at 4 tokens)
            r#"{"cmd":"model","model":"mlp:1048576x16384x1048576","tokens":4}"#,
            // under the MAC cap but over the operand-slab cap
            r#"{"cmd":"model","model":"gemm:1x1048576x65536"}"#,
            // each layer's slabs are individually within the cap, but
            // run_model holds every weight slab at once — the *sum* is
            // capped (2 x ~2^27 weight elements here)
            r#"{"cmd":"model","model":"gemm:1x16384x8192,gemm:1x8192x16384"}"#,
            // empirical model inputs are confined like workload paths
            r#"{"cmd":"model","model":"mlp:16x8",
                "distribution":"empirical:/etc/hostname"}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
        }
    }

    #[test]
    fn workload_request_cached_by_content_hash() {
        let svc = test_service();
        // a small deterministic synthetic-LLM trace, inline
        let mut vals = String::new();
        let mut rng = crate::rng::Pcg64::seeded(21);
        let d = Distribution::gauss_outliers();
        for i in 0..256 {
            if i > 0 {
                vals.push(',');
            }
            vals.push_str(&format!("{}", d.sample(&mut rng) as f32));
        }
        let line = format!(
            r#"{{"cmd":"workload","name":"acts","values":[{vals}],"samples":256}}"#
        );
        let req = proto::parse_request(&line).unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{cold}");
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        assert_eq!(r.get("trace").and_then(Json::as_str), Some("acts"));
        let wl = r.get("workload").unwrap();
        assert_eq!(wl.get("name").and_then(Json::as_str), Some("workload"));
        assert_eq!(wl.get("all_hold"), Some(&Json::Bool(true)));
        // three tables: summary, sqnr sweep, energy bounds
        assert_eq!(wl.get("tables").unwrap().items().len(), 3);

        // repeat: byte-identical hit
        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.workloads.stats().computes, 1);

        // the same payload under a different *name* shares the cache
        // entry (content-hash identity, names are labels)
        let renamed = format!(
            r#"{{"cmd":"workload","name":"other","values":[{vals}],"samples":256}}"#
        );
        let req2 = proto::parse_request(&renamed).unwrap();
        let j2 = Json::parse(&svc.respond(&req2)).unwrap();
        assert_eq!(j2.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(svc.workloads.stats().computes, 1);

        // a perturbed payload is a different trace
        let perturbed = format!(
            r#"{{"cmd":"workload","name":"acts","values":[{vals},0.123],"samples":256}}"#
        );
        let req3 = proto::parse_request(&perturbed).unwrap();
        let j3 = Json::parse(&svc.respond(&req3)).unwrap();
        assert_eq!(j3.get("cached"), Some(&Json::Bool(false)));
        assert_eq!(svc.workloads.stats().computes, 2);
    }

    #[test]
    fn workload_bad_traces_are_clean_errors() {
        let svc = test_service();
        for line in [
            r#"{"cmd":"workload","values":[0,0,0]}"#, // all-zero
            r#"{"cmd":"workload","values":[1.0]}"#,   // too small
            r#"{"cmd":"workload","path":"nonexistent-grcim.trace"}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
        }
    }

    #[test]
    fn wire_trace_paths_are_confined() {
        let svc = test_service();
        // escaping paths are rejected before touching the filesystem, for
        // both the workload request and empirical sweep distributions
        for line in [
            r#"{"cmd":"workload","path":"/etc/hostname"}"#,
            r#"{"cmd":"workload","path":"../secrets.json"}"#,
            r#"{"cmd":"workload","path":"a/../../b.grtt"}"#,
            r#"{"cmd":"sweep","experiments":[{"name":"x",
                "distribution":"empirical:/etc/hostname"}]}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
            assert!(
                j.get("error")
                    .and_then(Json::as_str)
                    .unwrap()
                    .contains("not allowed over the wire"),
                "{line}"
            );
        }
        // a relative path to a real file under the cwd resolves (tests run
        // with cwd = the package root, where Cargo.toml exists)
        assert!(confined_trace_path("Cargo.toml").is_ok());
        assert!(confined_trace_path("./Cargo.toml").is_ok());
        // nonexistent paths fail at resolution rather than being probed
        assert!(confined_trace_path("traces/acts.grtt").is_err());
        assert!(confined_trace_path("/abs").is_err());
        assert!(confined_trace_path("up/../../x").is_err());
    }

    #[cfg(unix)]
    #[test]
    fn wire_trace_paths_reject_symlink_escapes() {
        // a lexically clean relative path whose symlink resolves outside
        // the cwd must be rejected (canonicalization-based confinement)
        let outside = std::env::temp_dir().join("grcim_symlink_target.json");
        std::fs::write(&outside, r#"{"values":[1,2]}"#).unwrap();
        let link = std::path::Path::new("grcim-test-escape-link.json");
        let _ = std::fs::remove_file(link);
        std::os::unix::fs::symlink(&outside, link).unwrap();
        let res = confined_trace_path("grcim-test-escape-link.json");
        let _ = std::fs::remove_file(link);
        let err = format!("{:#}", res.unwrap_err());
        assert!(
            err.contains("outside the serve working directory"),
            "{err}"
        );
    }

    #[test]
    fn info_reports_engine_and_stats() {
        let svc = test_service();
        let j = Json::parse(&svc.respond(&Request::Info)).unwrap();
        let r = j.get("result").unwrap();
        assert_eq!(r.get("engine").and_then(Json::as_str), Some("rust"));
        assert_eq!(r.get("proto").unwrap().as_usize(), Some(1));
        let aggs = r.get("aggregates").unwrap();
        assert_eq!(aggs.get("computes").unwrap().as_usize(), Some(0));
        // the response-level caches report alongside
        assert!(r.get("energies").is_some());
        assert!(r.get("sweeps").is_some());
    }

    #[test]
    fn metrics_response_has_full_schema_even_when_idle() {
        let svc = test_service();
        let j = Json::parse(&svc.respond(&Request::Metrics)).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        let server = r.get("server").unwrap();
        assert_eq!(server.get("accepted").unwrap().as_usize(), Some(0));
        assert!(server.get("queue").unwrap().get("depth").is_some());
        let kinds = server.get("kinds").unwrap();
        for k in proto::RequestKind::ALL {
            let kj = kinds.get(k.name()).unwrap();
            // idle kinds report Null percentiles, never garbage
            assert_eq!(kj.get("p50_us"), Some(&Json::Null), "{}", k.name());
        }
        let caches = r.get("caches").unwrap();
        for c in [
            "aggregates", "energies", "sweeps", "figures", "layers", "models", "workloads",
            "paretos",
        ] {
            assert_eq!(caches.get(c).unwrap().get("computes").unwrap().as_usize(), Some(0), "{c}");
        }
    }

    #[test]
    fn serve_config_resolves_auto_thread_counts() {
        let auto = ServeConfig::default();
        assert!(auto.resolved_mux_threads() >= 1);
        assert!(auto.resolved_compute_threads() >= 1);
        assert!(auto.resolved_queue_cap() >= 16);
        let fixed = ServeConfig {
            mux_threads: 3,
            compute_threads: 2,
            queue_cap: 7,
            ..Default::default()
        };
        assert_eq!(fixed.resolved_mux_threads(), 3);
        assert_eq!(fixed.resolved_compute_threads(), 2);
        assert_eq!(fixed.resolved_queue_cap(), 7);
    }

    #[test]
    fn server_spawns_serves_and_shuts_down() {
        let server = Server::spawn(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            campaign: CampaignConfig {
                engine: EngineKind::Rust,
                workers: 2,
                seed: 3,
                ..Default::default()
            },
            cache_entries: 64,
            ..Default::default()
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let resp = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
        assert!(Json::parse(&resp).unwrap().get("ok") == Some(&Json::Bool(true)));
        // malformed input gets a typed error line, connection stays usable
        let resp = query_once(&addr, "definitely not json").unwrap();
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("bad_request"));
        server.shutdown().unwrap();
        assert!(
            TcpStream::connect(&addr).is_err(),
            "listener must be closed after shutdown"
        );
    }

    #[test]
    fn event_loop_pipelines_requests_in_order_on_one_connection() {
        let server = Server::spawn(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            campaign: CampaignConfig {
                engine: EngineKind::Rust,
                workers: 2,
                seed: 3,
                ..Default::default()
            },
            cache_entries: 64,
            ..Default::default()
        })
        .unwrap();
        let addr = server.local_addr();
        let mut stream = TcpStream::connect(addr).unwrap();
        // several requests written up front; responses must come back in
        // order (one in flight at a time per connection), including a
        // parse error in the middle without desync
        stream
            .write_all(b"{\"cmd\":\"info\"}\nnot json\n{\"cmd\":\"metrics\"}\n")
            .unwrap();
        let mut reader = BufReader::new(stream);
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0);
            lines.push(Json::parse(line.trim_end()).unwrap());
        }
        assert_eq!(lines[0].get("ok"), Some(&Json::Bool(true)));
        assert!(lines[0].get("result").unwrap().get("version").is_some());
        assert_eq!(lines[1].get("kind").and_then(Json::as_str), Some("bad_request"));
        assert_eq!(lines[2].get("ok"), Some(&Json::Bool(true)));
        let server_block = lines[2].get("result").unwrap().get("server").unwrap();
        // both inline requests already answered on this connection
        let info_ok = server_block.get("kinds").unwrap().get("info").unwrap();
        assert_eq!(info_ok.get("ok").unwrap().as_usize(), Some(1));
        assert_eq!(server_block.get("bad_requests").unwrap().as_usize(), Some(1));
        drop(reader);
        server.shutdown().unwrap();
    }

    #[test]
    fn expired_deadline_is_a_typed_error() {
        let server = Server::spawn(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            campaign: CampaignConfig {
                engine: EngineKind::Rust,
                workers: 2,
                seed: 3,
                ..Default::default()
            },
            cache_entries: 64,
            ..Default::default()
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        // deadline_ms:0 expires before any worker can dequeue it —
        // deterministically a `deadline` error, and cheap (no compute)
        let resp = query_once(
            &addr,
            r#"{"cmd":"figure","id":"table1","samples":256,"deadline_ms":0}"#,
        )
        .unwrap();
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{resp}");
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("deadline"));
        let m = Json::parse(&query_once(&addr, r#"{"cmd":"metrics"}"#).unwrap()).unwrap();
        let server_block = m.get("result").unwrap().get("server").unwrap();
        assert_eq!(server_block.get("rejected_deadline").unwrap().as_usize(), Some(1));
        server.shutdown().unwrap();
    }
}
