//! `grcim serve` — a resident campaign service over TCP.
//!
//! The one-shot CLI pays the full Monte-Carlo cost on every invocation.
//! This layer keeps the process resident and serves spec-point queries
//! over newline-delimited JSON (see [`proto`]), with three properties:
//!
//! * **Spec-keyed caching** — every campaign aggregate is addressed by a
//!   canonical key ([`proto::spec_key`]) covering exactly the inputs that
//!   determine its bits; repeated queries are O(lookup).
//! * **Single-flight coalescing** — concurrent identical requests share
//!   one computation ([`cache::ShardedCache`]), so a thundering herd of
//!   the same spec costs one campaign.
//! * **Coordinator dispatch** — misses run through
//!   [`crate::coordinator::run_campaign`] and its per-worker
//!   `JobBuffers`, so the MC hot path stays allocation-free under load.
//!
//! Request lifecycle:
//!
//! ```text
//!  client line ── parse_request ──▶ Request
//!                                     │ canonicalize (spec_key)
//!                                     ▼
//!                          ShardedCache::get_or_compute
//!                           hit │          │ miss (single-flight leader)
//!                               │          ▼
//!                               │   run_campaign ──▶ worker pool
//!                               ▼          │         (JobBuffers)
//!                           Arc<ColumnAgg> ◀─────────┘
//!                                     │ evaluate (spec solver + energy)
//!                                     ▼
//!  client line ◀── ok_line/err_line ── Json result
//! ```
//!
//! Threading: one acceptor thread plus one thread per connection; all
//! handles are joined on [`Server::shutdown`], which is graceful (idle
//! handlers notice the flag within one read-timeout tick; busy handlers
//! finish their in-flight request first).

pub mod cache;
pub mod proto;

use crate::cli::sweep::{experiment_spec, LayerParams, ModelParams};
use crate::config::Json;
use crate::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
use crate::distributions::Distribution;
use crate::energy::{EnergyBreakdown, TechParams};
use crate::figures::{self, fig12, FigureCtx};
use crate::mac::FormatPair;
use crate::runtime::EngineKind;
use crate::spec::{required_enob, Arch, SpecConfig};
use crate::stats::ColumnAgg;
use crate::workload::{self, EmpiricalDist, TensorTrace};
use anyhow::{bail, Context, Result};
use cache::{Outcome, ShardedCache, StatsSnapshot};
use proto::{obj, Request, TraceSource};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Default listen address of `grcim serve`.
pub const DEFAULT_ADDR: &str = "127.0.0.1:4080";

/// How often idle connection handlers re-check the shutdown flag.
const IDLE_TICK: Duration = Duration::from_millis(200);

/// Largest accepted request line; a client streaming more without a
/// newline gets an error, the rest of that line is discarded up to its
/// newline (never parsed as a request), and the connection keeps
/// serving. Bounds per-connection memory.
const MAX_LINE: usize = 1 << 20;

/// Largest layer a `layer` request may evaluate, in MACs (M·K·N) — caps
/// the reference-GEMM compute (a 4096-d MLP up-projection at 4 tokens is
/// ~2.7e8 MACs, far below it). A `model` request's **layer sum** is held
/// to the same budget: chaining layers must not smuggle in more compute
/// than one maximal layer.
pub const MAX_LAYER_MACS: u64 = 1 << 36;

/// Largest operand slab (`M·K` or `N·K` f32 elements) a `layer` request
/// may allocate — caps request *memory* independently of the MAC
/// product (a skinny `gemm:1x1048576x65536` is only 2^36 MACs but would
/// otherwise allocate a 256 GiB weight slab). 2^27 elements = 512 MiB;
/// `mlp-up:4096` needs exactly 2^26.
pub const MAX_LAYER_ELEMS: u64 = 1 << 27;

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; port 0 binds an ephemeral port (tests).
    pub addr: String,
    /// Campaign settings every computation runs under (engine, workers,
    /// default seed, artifacts directory).
    pub campaign: CampaignConfig,
    /// Total cached entries across the aggregate and figure caches.
    pub cache_entries: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: DEFAULT_ADDR.to_string(),
            campaign: CampaignConfig::default(),
            cache_entries: 1024,
        }
    }
}

/// The request handlers plus their result caches — everything the server
/// shares across connections. Usable without the TCP layer (the unit
/// tests drive [`CampaignService::respond`] directly).
pub struct CampaignService {
    campaign: CampaignConfig,
    aggs: ShardedCache<ColumnAgg>,
    figs: ShardedCache<String>,
    workloads: ShardedCache<String>,
    layers: ShardedCache<String>,
    models: ShardedCache<String>,
}

fn arch_json(name: &str, enob: f64, b: &EnergyBreakdown) -> Json {
    obj(vec![
        ("arch", Json::Str(name.to_string())),
        ("enob", Json::Num(enob)),
        ("total_fj", Json::Num(b.total())),
        ("adc", Json::Num(b.adc)),
        ("dac", Json::Num(b.dac)),
        ("cells", Json::Num(b.cells)),
        ("exp_logic", Json::Num(b.exp_logic)),
        ("tree", Json::Num(b.tree)),
        ("norm_mult", Json::Num(b.norm_mult)),
    ])
}

fn stats_json(s: &StatsSnapshot) -> Json {
    obj(vec![
        ("entries", Json::Num(s.entries as f64)),
        ("hits", Json::Num(s.hits as f64)),
        ("misses", Json::Num(s.misses as f64)),
        ("computes", Json::Num(s.computes as f64)),
        ("coalesced", Json::Num(s.coalesced as f64)),
        ("evictions", Json::Num(s.evictions as f64)),
    ])
}

impl CampaignService {
    /// Build the handlers around one campaign configuration and a total
    /// cache budget (split across the aggregate/figure/workload caches).
    pub fn new(campaign: CampaignConfig, cache_entries: usize) -> Self {
        CampaignService {
            campaign,
            aggs: ShardedCache::new(cache_entries),
            figs: ShardedCache::new((cache_entries / 8).max(8)),
            workloads: ShardedCache::new((cache_entries / 8).max(8)),
            layers: ShardedCache::new((cache_entries / 8).max(8)),
            models: ShardedCache::new((cache_entries / 8).max(8)),
        }
    }

    fn engine_name(&self) -> &'static str {
        match self.campaign.engine {
            EngineKind::Rust => "rust",
            EngineKind::Pjrt => "pjrt",
            EngineKind::Auto => "auto",
        }
    }

    /// The campaign aggregate for one spec, through the cache. A miss
    /// runs the spec as its own single-spec campaign (grid index 0 in the
    /// seeding scheme), so the result is a pure function of
    /// (spec, seed, engine) — the property the cache key relies on.
    pub fn aggregate(
        &self,
        spec: &ExperimentSpec,
        seed: u64,
    ) -> Result<(Arc<ColumnAgg>, Outcome)> {
        let key = proto::spec_key(spec, seed, self.engine_name());
        self.aggs.get_or_compute(&key, || {
            let cfg = CampaignConfig { seed, ..self.campaign.clone() };
            let mut aggs = run_campaign(std::slice::from_ref(spec), &cfg)?;
            Ok(aggs.pop().expect("one aggregate per spec"))
        })
    }

    /// Cache counters for the aggregate cache (the integration test's
    /// single-flight assertion reads `computes` from here via `info`).
    pub fn aggregate_stats(&self) -> StatsSnapshot {
        self.aggs.stats()
    }

    /// Handle one parsed request; returns the response line (no newline).
    pub fn respond(&self, req: &Request) -> String {
        let out = match req {
            Request::Info => self.info().map(|j| (j, false)),
            Request::Energy { dr_db, sqnr_db, samples, seed } => {
                self.energy(*dr_db, *sqnr_db, *samples, *seed)
            }
            Request::Sweep { samples, seed, experiments } => {
                self.sweep(*samples, *seed, experiments)
            }
            Request::Figure { id, samples, seed } => {
                self.figure(id, *samples, *seed)
            }
            Request::Layer { params, seed } => self.layer(params, *seed),
            Request::Model { params, seed } => self.model(params, *seed),
            Request::Workload { source, samples, seed } => {
                self.workload(source, *samples, *seed)
            }
        };
        match out {
            Ok((result, cached)) => proto::ok_line(result, cached),
            Err(e) => proto::err_line(&format!("{e:#}")),
        }
    }

    fn info(&self) -> Result<Json> {
        Ok(obj(vec![
            ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
            ("proto", Json::Num(proto::PROTO_VERSION as f64)),
            ("engine", Json::Str(self.engine_name().to_string())),
            ("workers", Json::Num(self.campaign.effective_workers() as f64)),
            ("seed", Json::Num(self.campaign.seed as f64)),
            ("aggregates", stats_json(&self.aggs.stats())),
            ("figures", stats_json(&self.figs.stats())),
            ("layers", stats_json(&self.layers.stats())),
            ("models", stats_json(&self.models.stats())),
            ("workloads", stats_json(&self.workloads.stats())),
        ]))
    }

    /// The Fig. 12 spec-point query: two cached aggregates (INT/narrow
    /// bounds and FP/full scale) evaluated through
    /// [`fig12::evaluate_at`].
    fn energy(
        &self,
        dr_db: f64,
        sqnr_db: f64,
        samples: usize,
        seed: Option<u64>,
    ) -> Result<(Json, bool)> {
        if samples == 0 {
            bail!("samples must be positive");
        }
        let seed = seed.unwrap_or(self.campaign.seed);
        let p = fig12::SpecPoint::from_db(dr_db, sqnr_db);
        let (Some(fp), Some(int)) = (p.fp_format(), p.int_format()) else {
            bail!(
                "spec point (DR {dr_db} dB, SQNR {sqnr_db} dB) is left of \
                 the INT line"
            );
        };
        let w_fmt = fig12::weight_fmt();
        let w_dist = Distribution::max_entropy(w_fmt);
        let int_spec = ExperimentSpec {
            id: "serve-int".to_string(),
            fmts: FormatPair::new(int, w_fmt),
            dist_x: fig12::narrow_bounds_dist(fp),
            dist_w: w_dist.clone(),
            nr: fig12::NR,
            samples,
        };
        let fp_spec = ExperimentSpec {
            id: "serve-fp".to_string(),
            fmts: FormatPair::new(fp, w_fmt),
            dist_x: Distribution::Uniform,
            dist_w: w_dist,
            nr: fig12::NR,
            samples,
        };
        let (agg_int, o1) = self.aggregate(&int_spec, seed)?;
        let (agg_fp, o2) = self.aggregate(&fp_spec, seed)?;
        let tech = TechParams::default();
        let r = fig12::evaluate_at(&p, &agg_int, &agg_fp, &tech)
            .expect("formats validated above");

        let mut archs = vec![arch_json("conventional", r.enob_conv, &r.e_conv)];
        for (arch, enob, b) in &r.gr_all {
            archs.push(arch_json(arch.name(), *enob, b));
        }
        let gr_best = match &r.gr_best {
            Some((a, _, _)) => Json::Str(a.name().to_string()),
            None => Json::Null,
        };
        let result = obj(vec![
            ("dr_db", Json::Num(dr_db)),
            ("sqnr_db", Json::Num(sqnr_db)),
            ("samples", Json::Num(agg_int.samples() as f64)),
            ("seed", Json::Num(seed as f64)),
            ("gr_best", gr_best),
            ("archs", Json::Arr(archs)),
        ]);
        Ok((result, o1.is_cached() && o2.is_cached()))
    }

    /// The sweep query: one cached aggregate per experiment, reported
    /// like the CLI's sweep table. (Each experiment runs as its own
    /// single-spec campaign, so its aggregate is reusable across sweeps
    /// that mix experiments differently — see [`CampaignService::aggregate`].)
    fn sweep(
        &self,
        samples: usize,
        seed: Option<u64>,
        experiments: &[proto::SweepExperiment],
    ) -> Result<(Json, bool)> {
        if samples == 0 {
            bail!("samples must be positive");
        }
        let seed = seed.unwrap_or(self.campaign.seed);
        let scfg = SpecConfig::default();
        let mut rows = Vec::new();
        let mut cached = true;
        for e in experiments {
            // empirical distributions read a server-side trace file; the
            // same confinement as the workload request applies
            if let Some(path) = e.distribution.strip_prefix("empirical:") {
                confined_trace_path(path)?;
            }
            let spec = experiment_spec(
                &e.name,
                e.n_e,
                e.n_m,
                e.nr,
                &e.distribution,
                samples,
            )?;
            let (agg, o) = self.aggregate(&spec, seed)?;
            cached &= o.is_cached();
            rows.push(obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("samples", Json::Num(agg.samples() as f64)),
                (
                    "enob_conv",
                    Json::Num(
                        required_enob(&agg, Arch::Conventional, scfg).enob,
                    ),
                ),
                (
                    "enob_gr_unit",
                    Json::Num(required_enob(&agg, Arch::GrUnit, scfg).enob),
                ),
                (
                    "enob_gr_row",
                    Json::Num(required_enob(&agg, Arch::GrRow, scfg).enob),
                ),
                ("mean_n_eff", Json::Num(agg.mean_n_eff())),
                ("sqnr_db", Json::Num(agg.sqnr_db())),
            ]));
        }
        let result = obj(vec![
            ("seed", Json::Num(seed as f64)),
            ("experiments", Json::Arr(rows)),
        ]);
        Ok((result, cached))
    }

    /// The figure query: regenerate one paper figure/table and return it
    /// as JSON ([`crate::report::FigureResult::to_json`]); the rendered
    /// JSON text is what the figure cache stores.
    fn figure(
        &self,
        id: &str,
        samples: usize,
        seed: Option<u64>,
    ) -> Result<(Json, bool)> {
        if samples == 0 {
            bail!("samples must be positive");
        }
        let seed = seed.unwrap_or(self.campaign.seed);
        let key = proto::figure_key(id, samples, seed, self.engine_name());
        let campaign = CampaignConfig { seed, ..self.campaign.clone() };
        let id_owned = id.to_string();
        let (text, o) = self.figs.get_or_compute(&key, move || {
            let ctx = FigureCtx {
                campaign,
                samples,
                // figures only write files through `FigureResult::emit`,
                // which the service never calls; out_dir is unused
                out_dir: std::env::temp_dir(),
            };
            let fr = figures::run(&id_owned, &ctx)?;
            Ok(fr.to_json().to_string())
        })?;
        let figure =
            Json::parse(&text).context("re-parsing cached figure JSON")?;
        let result = obj(vec![
            ("id", Json::Str(id.to_string())),
            ("figure", figure),
        ]);
        Ok((result, o.is_cached()))
    }

    /// The layer query: evaluate a named layer shape on the tiled array
    /// mapper ([`crate::tile::run_layer`] — tile jobs shard across the
    /// worker pool), cached by [`proto::layer_key`] over the **resolved**
    /// spec, so request aliases (`gr` vs `gr-unit`, named shape vs
    /// explicit `gemm:`) share one entry. Empirical activation traces are
    /// confined like workload paths.
    fn layer(&self, params: &LayerParams, seed: Option<u64>) -> Result<(Json, bool)> {
        let seed = seed.unwrap_or(self.campaign.seed);
        // empirical distributions read a server-side trace file
        if let Some(path) = params.distribution.strip_prefix("empirical:") {
            confined_trace_path(path)?;
        }
        let spec = params.resolve()?;
        if spec.shape.macs() > MAX_LAYER_MACS {
            bail!(
                "layer shape {} is too large for the service ({} MACs > {MAX_LAYER_MACS})",
                spec.shape,
                spec.shape.macs()
            );
        }
        // parse_shape bounds each dimension to 2^20, so these products
        // cannot overflow u64
        let x_elems = spec.shape.m as u64 * spec.shape.k as u64;
        let wt_elems = spec.shape.n as u64 * spec.shape.k as u64;
        if x_elems.max(wt_elems) > MAX_LAYER_ELEMS {
            bail!(
                "layer shape {} is too large for the service (operand slab \
                 of {} elements > {MAX_LAYER_ELEMS})",
                spec.shape,
                x_elems.max(wt_elems)
            );
        }
        let key = proto::layer_key(&spec, seed, self.engine_name());
        let campaign = CampaignConfig { seed, ..self.campaign.clone() };
        let gemm = spec.shape;
        let arch = spec.cfg.arch;
        let (text, o) = self.layers.get_or_compute(&key, move || {
            let res = crate::tile::run_layer(&spec, &campaign)?;
            Ok(res.report.to_figure_result().to_json().to_string())
        })?;
        let report = Json::parse(&text).context("re-parsing cached layer JSON")?;
        let result = obj(vec![
            ("shape", Json::Str(params.shape.clone())),
            ("gemm", Json::Str(gemm.to_string())),
            ("arch", Json::Str(arch.name().to_string())),
            ("seed", Json::Num(seed as f64)),
            ("layer", report),
        ]);
        Ok((result, o.is_cached()))
    }

    /// The model query: evaluate a multi-layer model on the chained tile
    /// pipeline ([`crate::model::run_model`] — every layer's tile jobs
    /// shard across the worker pool), cached by [`proto::model_key`]
    /// over the **resolved** spec. The `layer` request's MAC and
    /// operand-slab caps are enforced **across the layer sum**, so a
    /// chain of layers cannot exceed the budget one maximal layer gets.
    fn model(&self, params: &ModelParams, seed: Option<u64>) -> Result<(Json, bool)> {
        let seed = seed.unwrap_or(self.campaign.seed);
        // empirical model-input distributions read a server-side trace
        if let Some(path) = params.distribution.strip_prefix("empirical:") {
            confined_trace_path(path)?;
        }
        let spec = params.resolve()?;
        let total_macs = spec.macs();
        if total_macs > MAX_LAYER_MACS {
            bail!(
                "model '{}' is too large for the service ({total_macs} MACs across \
                 {} layers > {MAX_LAYER_MACS})",
                spec.name,
                spec.layers.len()
            );
        }
        // parse_shape bounds each dimension to 2^20, so these products
        // cannot overflow u64. The slab cap applies to the **sum** of
        // every layer's operand elements: run_model materializes all
        // weight slabs for the whole run, so a per-layer cap would let a
        // 64-layer chain allocate 64x the budget one maximal layer gets
        let mut sum_elems = 0u64;
        for l in &spec.layers {
            let x_elems = l.shape.m as u64 * l.shape.k as u64;
            let wt_elems = l.shape.n as u64 * l.shape.k as u64;
            let act_elems = l.shape.m as u64 * l.shape.n as u64;
            sum_elems = sum_elems
                .saturating_add(x_elems)
                .saturating_add(wt_elems)
                .saturating_add(act_elems);
        }
        if sum_elems > MAX_LAYER_ELEMS {
            bail!(
                "model '{}' is too large for the service (operand slabs \
                 of {sum_elems} total elements > {MAX_LAYER_ELEMS})",
                spec.name
            );
        }
        let key = proto::model_key(&spec, seed, self.engine_name());
        let campaign = CampaignConfig { seed, ..self.campaign.clone() };
        let layers = spec.layers.len();
        let arch = spec.cfg.arch;
        let (text, o) = self.models.get_or_compute(&key, move || {
            let res = crate::model::run_model(&spec, &campaign)?;
            Ok(res.report.to_figure_result().to_json().to_string())
        })?;
        let report = Json::parse(&text).context("re-parsing cached model JSON")?;
        let result = obj(vec![
            ("model", Json::Str(params.model.clone())),
            ("layers", Json::Num(layers as f64)),
            ("arch", Json::Str(arch.name().to_string())),
            ("seed", Json::Num(seed as f64)),
            ("report", report),
        ]);
        Ok((result, o.is_cached()))
    }

    /// The workload query: fit an empirical trace and run the full
    /// `grcim workload` analysis ([`crate::workload::report`]), cached by
    /// the trace's **content hash** — two uploads of the same tensor (even
    /// under different names or paths) share one entry, and hits are
    /// byte-identical to the cold compute (the cache stores the rendered
    /// JSON text). Server-side trace paths are confined (see
    /// [`confined_trace_path`]).
    fn workload(
        &self,
        source: &TraceSource,
        samples: usize,
        seed: Option<u64>,
    ) -> Result<(Json, bool)> {
        if samples == 0 {
            bail!("samples must be positive");
        }
        let seed = seed.unwrap_or(self.campaign.seed);
        let trace = match source {
            TraceSource::Path(p) => {
                TensorTrace::read(&confined_trace_path(p)?)?
            }
            TraceSource::Inline { name, values } => TensorTrace::from_f64(
                name.clone(),
                vec![values.len()],
                values.clone(),
            )?,
        };
        let fit = Arc::new(EmpiricalDist::fit(&trace)?);
        let key = proto::workload_key(
            fit.content_hash(),
            samples,
            seed,
            self.engine_name(),
        );
        let campaign = CampaignConfig { seed, ..self.campaign.clone() };
        let fit_for_compute = Arc::clone(&fit);
        let (text, o) = self.workloads.get_or_compute(&key, move || {
            let fr = workload::report(&fit_for_compute, &campaign, samples)?;
            Ok(fr.to_json().to_string())
        })?;
        let report =
            Json::parse(&text).context("re-parsing cached workload JSON")?;
        let result = obj(vec![
            ("trace", Json::Str(trace.name().to_string())),
            (
                "content_hash",
                Json::Str(format!("{:016x}", fit.content_hash())),
            ),
            ("samples_in_trace", Json::Num(trace.len() as f64)),
            ("seed", Json::Num(seed as f64)),
            ("workload", report),
        ]);
        Ok((result, o.is_cached()))
    }
}

/// A running `grcim serve` instance: acceptor thread + per-connection
/// handler threads, all joined on [`Server::shutdown`].
pub struct Server {
    addr: SocketAddr,
    service: Arc<CampaignService>,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind and start serving in background threads; returns immediately.
    pub fn spawn(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let service =
            Arc::new(CampaignService::new(cfg.campaign, cfg.cache_entries));
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let service = Arc::clone(&service);
            let shutdown = Arc::clone(&shutdown);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("grcim-accept".to_string())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(_) => {
                                // e.g. EMFILE under fd exhaustion: back
                                // off instead of busy-spinning on a
                                // persistently failing accept
                                std::thread::sleep(IDLE_TICK);
                                continue;
                            }
                        };
                        let service = Arc::clone(&service);
                        let flag = Arc::clone(&shutdown);
                        let handle = std::thread::Builder::new()
                            .name("grcim-conn".to_string())
                            .spawn(move || handle_conn(stream, service, flag));
                        let mut guard = conns.lock().unwrap();
                        // reap finished handlers so the handle list stays
                        // bounded by the number of live connections
                        let (done, live): (Vec<_>, Vec<_>) = guard
                            .drain(..)
                            .partition(|h: &JoinHandle<()>| h.is_finished());
                        *guard = live;
                        for h in done {
                            let _ = h.join();
                        }
                        if let Ok(h) = handle {
                            guard.push(h);
                        }
                    }
                })
                .context("spawning accept thread")?
        };
        Ok(Server { addr, service, shutdown, accept: Some(accept), conns })
    }

    /// The actually bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Direct access to the handlers/caches (stats, in-process queries).
    pub fn service(&self) -> &CampaignService {
        &self.service
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // idle handlers notice the flag within one IDLE_TICK; busy ones
        // finish their current request first
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// Stop accepting, drain and join every thread. Clean by
    /// construction: the acceptor and all connection handlers are joined
    /// before this returns.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner();
        Ok(())
    }

    /// Block on the acceptor (until the process is killed or another
    /// thread trips the shutdown flag). `grcim serve` runs this.
    pub fn join(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join()
                .map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown_inner();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Arc<CampaignService>,
    shutdown: Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(IDLE_TICK)).is_err() {
        return;
    }
    let reader_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_half);
    let mut writer = BufWriter::new(stream);
    // Lines are accumulated as raw *bytes* and converted lossily at
    // dispatch: `read_line`'s UTF-8 validation would disconnect a client
    // whose multi-byte character straddles the byte cap, and std
    // truncates a whole chunk when a read timeout splits a character —
    // byte accumulation has neither failure mode (invalid UTF-8 simply
    // parses as a malformed request and gets an error response).
    let mut line: Vec<u8> = Vec::new();
    // after an oversized request line is rejected, the reader *resyncs*:
    // the rest of that line (up to its newline) is discarded, never
    // parsed as a request, and the connection keeps serving — the next
    // complete line is handled normally
    let mut discarding = false;
    loop {
        // cap how much a newline-less client can make us buffer
        if !discarding && line.len() >= MAX_LINE {
            let msg = proto::err_line(&format!(
                "request line exceeds {MAX_LINE} bytes"
            ));
            if writer.write_all(msg.as_bytes()).is_err()
                || writer.write_all(b"\n").is_err()
                || writer.flush().is_err()
            {
                break;
            }
            discarding = true;
            line.clear();
        }
        let budget = if discarding {
            MAX_LINE as u64
        } else {
            (MAX_LINE - line.len()) as u64
        };
        match std::io::Read::take(&mut reader, budget).read_until(b'\n', &mut line) {
            Ok(0) => break, // EOF: client closed
            Ok(_) => {
                let complete = line.ends_with(b"\n");
                if discarding {
                    // chunks of the oversized line are dropped silently
                    // (they are the middle of a rejected request, not a
                    // request); its terminating newline ends the resync
                    if complete {
                        discarding = false;
                    }
                    line.clear();
                    continue;
                }
                if !complete && line.len() >= MAX_LINE {
                    // budget exhausted mid-line: the loop top rejects
                    // the line and starts discarding
                    continue;
                }
                // a complete line — or the connection's final,
                // EOF-terminated request without a trailing newline
                // (read_until without a newline below the cap means
                // EOF), which is answered like any other
                let text = String::from_utf8_lossy(&line);
                let resp = respond_line(&service, text.trim());
                drop(text);
                line.clear();
                if let Some(resp) = resp {
                    if writer.write_all(resp.as_bytes()).is_err()
                        || writer.write_all(b"\n").is_err()
                        || writer.flush().is_err()
                    {
                        break;
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                // idle tick; any partial input stays accumulated in `line`
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Confine a trace path received over the wire: requests may only name
/// **relative** paths without `..` components, and the path must
/// *resolve* (symlinks included) to a file under the serve process's
/// working directory. Without this, any TCP client could read and
/// statistically summarize arbitrary files on the server (the other
/// request kinds never touch the filesystem); the canonicalization step
/// closes the symlink escape a purely lexical check would leave open.
fn confined_trace_path(p: &str) -> Result<std::path::PathBuf> {
    use std::path::Component;
    let path = std::path::Path::new(p);
    let confined = !path.is_absolute()
        && path
            .components()
            .all(|c| matches!(c, Component::Normal(_) | Component::CurDir));
    if !confined {
        bail!(
            "trace path '{p}' is not allowed over the wire: server-side \
             traces must be relative paths without '..' (resolved in the \
             serve process's working directory)"
        );
    }
    let cwd = std::env::current_dir()
        .and_then(|d| d.canonicalize())
        .context("resolving the serve working directory")?;
    let real = path
        .canonicalize()
        .with_context(|| format!("resolving trace path '{p}'"))?;
    if !real.starts_with(&cwd) {
        bail!(
            "trace path '{p}' is not allowed over the wire: it resolves to \
             {} outside the serve working directory",
            real.display()
        );
    }
    Ok(real)
}

fn respond_line(service: &CampaignService, line: &str) -> Option<String> {
    if line.is_empty() {
        return None; // blank keep-alive lines are ignored
    }
    Some(match proto::parse_request(line) {
        Ok(req) => service.respond(&req),
        Err(e) => proto::err_line(&format!("{e:#}")),
    })
}

/// One-shot client: send a single request line, read a single response
/// line. Backs `grcim query` and the integration tests.
pub fn query_once(addr: &str, request_line: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.write_all(request_line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        bail!("server closed the connection without responding");
    }
    Ok(line.trim_end().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_service() -> CampaignService {
        CampaignService::new(
            CampaignConfig {
                engine: EngineKind::Rust,
                workers: 2,
                seed: 11,
                ..Default::default()
            },
            64,
        )
    }

    fn result_str(line: &str) -> String {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
        j.get("result").unwrap().to_string()
    }

    #[test]
    fn energy_response_shape_and_cache_flag() {
        let svc = test_service();
        let req = proto::parse_request(
            r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512}"#,
        )
        .unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        // rounded up to one whole coordinator job
        assert_eq!(r.get("samples").unwrap().as_usize(), Some(2048));
        let archs = r.get("archs").unwrap().items();
        assert!(archs.len() >= 2, "conventional + at least one GR");
        assert_eq!(
            archs[0].get("arch").and_then(Json::as_str),
            Some("conventional")
        );
        for a in archs {
            assert!(a.get("total_fj").unwrap().as_f64().unwrap() > 0.0);
        }

        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm), "hit must be bit-identical");
        assert_eq!(svc.aggregate_stats().computes, 2); // int + fp aggregates
    }

    #[test]
    fn energy_left_of_int_line_is_an_error() {
        let svc = test_service();
        let req = proto::parse_request(
            r#"{"cmd":"energy","dr":12.0,"sqnr":47.0}"#,
        )
        .unwrap();
        let resp = svc.respond(&req);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert!(j
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("INT line"));
    }

    #[test]
    fn sweep_reuses_energy_aggregates_only_when_specs_match() {
        let svc = test_service();
        let req = proto::parse_request(
            r#"{"cmd":"sweep","samples":512,"experiments":[
                {"name":"a","n_e":3,"n_m":2,"nr":32,"distribution":"uniform"},
                {"name":"b","n_e":4,"n_m":2,"nr":32,"distribution":"gauss_outliers"}]}"#,
        )
        .unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let rows = j.get("result").unwrap().get("experiments").unwrap().items();
        assert_eq!(rows.len(), 2);
        for row in rows {
            let conv = row.get("enob_conv").unwrap().as_f64().unwrap();
            let unit = row.get("enob_gr_unit").unwrap().as_f64().unwrap();
            assert!(conv > unit, "conv {conv} vs gr-unit {unit}");
        }
        assert_eq!(svc.aggregate_stats().computes, 2);
        let warm = svc.respond(&req);
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.aggregate_stats().computes, 2);
    }

    #[test]
    fn figure_request_is_cached_and_identical() {
        let svc = test_service();
        // table1 is closed-form: fast and deterministic
        let req = proto::parse_request(
            r#"{"cmd":"figure","id":"table1","samples":256}"#,
        )
        .unwrap();
        let cold = svc.respond(&req);
        let warm = svc.respond(&req);
        assert_eq!(result_str(&cold), result_str(&warm));
        let j = Json::parse(&warm).unwrap();
        assert_eq!(j.get("cached"), Some(&Json::Bool(true)));
        let fig = j.get("result").unwrap().get("figure").unwrap();
        assert_eq!(fig.get("name").and_then(Json::as_str), Some("table1"));
        assert_eq!(fig.get("all_hold"), Some(&Json::Bool(true)));
    }

    #[test]
    fn unknown_figure_id_is_a_clean_error() {
        let svc = test_service();
        let req =
            proto::parse_request(r#"{"cmd":"figure","id":"fig99"}"#).unwrap();
        let j = Json::parse(&svc.respond(&req)).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert!(j
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("unknown figure"));
    }

    #[test]
    fn layer_request_cached_and_reconciled() {
        let svc = test_service();
        let line = r#"{"cmd":"layer","shape":"gemm:2x24x10","nr":8,"nc":4,
            "n_e":2,"arch":"gr","distribution":"gauss_outliers"}"#;
        let req = proto::parse_request(line).unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{cold}");
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        assert_eq!(r.get("gemm").and_then(Json::as_str), Some("2x24x10"));
        assert_eq!(r.get("arch").and_then(Json::as_str), Some("gr-unit"));
        let layer = r.get("layer").unwrap();
        assert_eq!(layer.get("name").and_then(Json::as_str), Some("layer"));
        // the invariant checks (incl. energy reconciliation) all hold
        assert_eq!(layer.get("all_hold"), Some(&Json::Bool(true)), "{layer}");
        // summary + components + histogram + tiles
        assert_eq!(layer.get("tables").unwrap().items().len(), 4);

        // byte-identical hit
        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.layers.stats().computes, 1);

        // an alias that resolves identically shares the entry
        let alias = line.replace("\"gr\"", "\"gr-unit\"");
        let req2 = proto::parse_request(&alias).unwrap();
        let j2 = Json::parse(&svc.respond(&req2)).unwrap();
        assert_eq!(j2.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(svc.layers.stats().computes, 1);
    }

    #[test]
    fn layer_request_bad_inputs_are_clean_errors() {
        let svc = test_service();
        for line in [
            r#"{"cmd":"layer","shape":"warp:64"}"#,
            r#"{"cmd":"layer","shape":"gemm:2x8x8","arch":"quantum"}"#,
            r#"{"cmd":"layer","shape":"gemm:2x8x8","nr":0}"#,
            // formats a worker thread could not even construct
            r#"{"cmd":"layer","shape":"gemm:2x8x8","n_e":64}"#,
            // over the MAC cap
            r#"{"cmd":"layer","shape":"gemm:100000x100000x100000"}"#,
            // under the MAC cap but over the operand-slab cap
            r#"{"cmd":"layer","shape":"gemm:1x1048576x65536"}"#,
            // empirical activation traces are confined like workload paths
            r#"{"cmd":"layer","shape":"gemm:2x8x8",
                "distribution":"empirical:/etc/hostname"}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
        }
    }

    #[test]
    fn model_request_cached_and_reconciled() {
        let svc = test_service();
        let line = r#"{"cmd":"model","model":"mlp:16x12x8","tokens":2,"nr":8,"nc":4,
            "n_e":2,"arch":"gr","fit":true}"#;
        let req = proto::parse_request(line).unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{cold}");
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        assert_eq!(r.get("model").and_then(Json::as_str), Some("mlp:16x12x8"));
        assert_eq!(r.get("layers").unwrap().as_usize(), Some(2));
        assert_eq!(r.get("arch").and_then(Json::as_str), Some("gr-unit"));
        let report = r.get("report").unwrap();
        assert_eq!(report.get("name").and_then(Json::as_str), Some("model"));
        // the invariant checks (incl. energy reconciliation) all hold
        assert_eq!(report.get("all_hold"), Some(&Json::Bool(true)), "{report}");
        // summary + layers + histogram
        assert_eq!(report.get("tables").unwrap().items().len(), 3);

        // byte-identical hit
        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.models.stats().computes, 1);

        // an arch alias resolving identically shares the entry
        let alias = line.replace("\"gr\"", "\"gr-unit\"");
        let req2 = proto::parse_request(&alias).unwrap();
        let j2 = Json::parse(&svc.respond(&req2)).unwrap();
        assert_eq!(j2.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(svc.models.stats().computes, 1);
    }

    #[test]
    fn concurrent_model_requests_coalesce_to_one_compute() {
        use std::sync::Barrier;
        const THREADS: usize = 6;
        let svc = Arc::new(test_service());
        let barrier = Arc::new(Barrier::new(THREADS));
        let line = r#"{"cmd":"model","model":"mlp:16x12x8","tokens":2,"nr":8,"nc":4,"n_e":2}"#;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let svc = Arc::clone(&svc);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    let req = proto::parse_request(line).unwrap();
                    barrier.wait();
                    svc.respond(&req)
                })
            })
            .collect();
        let responses: Vec<String> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // single-flight: one compute total, every result byte-identical
        assert_eq!(svc.models.stats().computes, 1, "{:?}", svc.models.stats());
        let first = result_str(&responses[0]);
        for resp in &responses {
            let j = Json::parse(resp).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
            assert_eq!(result_str(resp), first);
        }
    }

    #[test]
    fn model_request_bad_inputs_are_clean_errors() {
        let svc = test_service();
        for line in [
            r#"{"cmd":"model","model":"warp:64"}"#,
            r#"{"cmd":"model","model":"mlp:16"}"#,
            r#"{"cmd":"model","model":"mlp:16x8","arch":"quantum"}"#,
            r#"{"cmd":"model","model":"mlp:16x8","nr":0}"#,
            r#"{"cmd":"model","model":"mlp:16x8","n_e":64}"#,
            // a chain whose layer *sum* exceeds the MAC cap even though
            // every single layer is within it (2 x 2^36 MACs at 4 tokens)
            r#"{"cmd":"model","model":"mlp:1048576x16384x1048576","tokens":4}"#,
            // under the MAC cap but over the operand-slab cap
            r#"{"cmd":"model","model":"gemm:1x1048576x65536"}"#,
            // each layer's slabs are individually within the cap, but
            // run_model holds every weight slab at once — the *sum* is
            // capped (2 x ~2^27 weight elements here)
            r#"{"cmd":"model","model":"gemm:1x16384x8192,gemm:1x8192x16384"}"#,
            // empirical model inputs are confined like workload paths
            r#"{"cmd":"model","model":"mlp:16x8",
                "distribution":"empirical:/etc/hostname"}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
        }
    }

    #[test]
    fn workload_request_cached_by_content_hash() {
        let svc = test_service();
        // a small deterministic synthetic-LLM trace, inline
        let mut vals = String::new();
        let mut rng = crate::rng::Pcg64::seeded(21);
        let d = Distribution::gauss_outliers();
        for i in 0..256 {
            if i > 0 {
                vals.push(',');
            }
            vals.push_str(&format!("{}", d.sample(&mut rng) as f32));
        }
        let line = format!(
            r#"{{"cmd":"workload","name":"acts","values":[{vals}],"samples":256}}"#
        );
        let req = proto::parse_request(&line).unwrap();
        let cold = svc.respond(&req);
        let j = Json::parse(&cold).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{cold}");
        assert_eq!(j.get("cached"), Some(&Json::Bool(false)));
        let r = j.get("result").unwrap();
        assert_eq!(r.get("trace").and_then(Json::as_str), Some("acts"));
        let wl = r.get("workload").unwrap();
        assert_eq!(wl.get("name").and_then(Json::as_str), Some("workload"));
        assert_eq!(wl.get("all_hold"), Some(&Json::Bool(true)));
        // three tables: summary, sqnr sweep, energy bounds
        assert_eq!(wl.get("tables").unwrap().items().len(), 3);

        // repeat: byte-identical hit
        let warm = svc.respond(&req);
        let jw = Json::parse(&warm).unwrap();
        assert_eq!(jw.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(result_str(&cold), result_str(&warm));
        assert_eq!(svc.workloads.stats().computes, 1);

        // the same payload under a different *name* shares the cache
        // entry (content-hash identity, names are labels)
        let renamed = format!(
            r#"{{"cmd":"workload","name":"other","values":[{vals}],"samples":256}}"#
        );
        let req2 = proto::parse_request(&renamed).unwrap();
        let j2 = Json::parse(&svc.respond(&req2)).unwrap();
        assert_eq!(j2.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(svc.workloads.stats().computes, 1);

        // a perturbed payload is a different trace
        let perturbed = format!(
            r#"{{"cmd":"workload","name":"acts","values":[{vals},0.123],"samples":256}}"#
        );
        let req3 = proto::parse_request(&perturbed).unwrap();
        let j3 = Json::parse(&svc.respond(&req3)).unwrap();
        assert_eq!(j3.get("cached"), Some(&Json::Bool(false)));
        assert_eq!(svc.workloads.stats().computes, 2);
    }

    #[test]
    fn workload_bad_traces_are_clean_errors() {
        let svc = test_service();
        for line in [
            r#"{"cmd":"workload","values":[0,0,0]}"#, // all-zero
            r#"{"cmd":"workload","values":[1.0]}"#,   // too small
            r#"{"cmd":"workload","path":"nonexistent-grcim.trace"}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
        }
    }

    #[test]
    fn wire_trace_paths_are_confined() {
        let svc = test_service();
        // escaping paths are rejected before touching the filesystem, for
        // both the workload request and empirical sweep distributions
        for line in [
            r#"{"cmd":"workload","path":"/etc/hostname"}"#,
            r#"{"cmd":"workload","path":"../secrets.json"}"#,
            r#"{"cmd":"workload","path":"a/../../b.grtt"}"#,
            r#"{"cmd":"sweep","experiments":[{"name":"x",
                "distribution":"empirical:/etc/hostname"}]}"#,
        ] {
            let req = proto::parse_request(line).unwrap();
            let j = Json::parse(&svc.respond(&req)).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
            assert!(
                j.get("error")
                    .and_then(Json::as_str)
                    .unwrap()
                    .contains("not allowed over the wire"),
                "{line}"
            );
        }
        // a relative path to a real file under the cwd resolves (tests run
        // with cwd = the package root, where Cargo.toml exists)
        assert!(confined_trace_path("Cargo.toml").is_ok());
        assert!(confined_trace_path("./Cargo.toml").is_ok());
        // nonexistent paths fail at resolution rather than being probed
        assert!(confined_trace_path("traces/acts.grtt").is_err());
        assert!(confined_trace_path("/abs").is_err());
        assert!(confined_trace_path("up/../../x").is_err());
    }

    #[cfg(unix)]
    #[test]
    fn wire_trace_paths_reject_symlink_escapes() {
        // a lexically clean relative path whose symlink resolves outside
        // the cwd must be rejected (canonicalization-based confinement)
        let outside = std::env::temp_dir().join("grcim_symlink_target.json");
        std::fs::write(&outside, r#"{"values":[1,2]}"#).unwrap();
        let link = std::path::Path::new("grcim-test-escape-link.json");
        let _ = std::fs::remove_file(link);
        std::os::unix::fs::symlink(&outside, link).unwrap();
        let res = confined_trace_path("grcim-test-escape-link.json");
        let _ = std::fs::remove_file(link);
        let err = format!("{:#}", res.unwrap_err());
        assert!(
            err.contains("outside the serve working directory"),
            "{err}"
        );
    }

    #[test]
    fn info_reports_engine_and_stats() {
        let svc = test_service();
        let j = Json::parse(&svc.respond(&Request::Info)).unwrap();
        let r = j.get("result").unwrap();
        assert_eq!(r.get("engine").and_then(Json::as_str), Some("rust"));
        assert_eq!(r.get("proto").unwrap().as_usize(), Some(1));
        let aggs = r.get("aggregates").unwrap();
        assert_eq!(aggs.get("computes").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn server_spawns_serves_and_shuts_down() {
        let server = Server::spawn(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            campaign: CampaignConfig {
                engine: EngineKind::Rust,
                workers: 2,
                seed: 3,
                ..Default::default()
            },
            cache_entries: 64,
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let resp = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
        assert!(Json::parse(&resp).unwrap().get("ok") == Some(&Json::Bool(true)));
        // malformed input gets an error line, connection stays usable
        let resp = query_once(&addr, "definitely not json").unwrap();
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        server.shutdown().unwrap();
        assert!(
            TcpStream::connect(&addr).is_err(),
            "listener must be closed after shutdown"
        );
    }
}
