//! The serve core's event loop: a bounded acceptor feeding a fixed pool
//! of connection-multiplexer threads, plus a fixed compute-worker pool
//! behind a bounded admission queue.
//!
//! ```text
//!             ┌──────────┐   round-robin    ┌──────────────┐
//!  clients ──▶│ acceptor │─────────────────▶│ mux 0..M     │  M nonblocking
//!             └──────────┘  (set_nonblocking)│  (conns)    │  multiplexers
//!                                            └──────┬──────┘
//!                        parse / inline info+metrics│ try_push
//!                                                   ▼
//!                                          ┌────────────────┐
//!                busy when full ◀──────────│ ComputeQueue   │ bounded
//!                                          └──────┬─────────┘
//!                                                 ▼ pop
//!                                          ┌────────────────┐
//!                                          │ worker 0..W    │ respond()
//!                                          └──────┬─────────┘
//!                                                 │ deliver(conn, line)
//!                                                 ▼
//!                                          mux inbox ──▶ client socket
//! ```
//!
//! Thread cost is **O(M + W + 1)** regardless of connection count:
//! thousands of idle connections are just entries in a mux's `Vec`.
//! Muxes with zero connections park indefinitely on their inbox condvar
//! (no idle wakeups at all); muxes holding idle connections poll them
//! under an adaptive backoff (1 ms doubling to 16 ms) because std
//! offers no portable readiness API — so idle wakeup cost is O(muxes),
//! not O(connections), and new work posted to an inbox (a fresh
//! connection, a finished compute) wakes its mux immediately.
//!
//! Per-connection ordering: at most one compute request per connection
//! is in flight at a time, and the mux stops reading a connection's
//! socket while one is (TCP backpressure does the rest). Responses
//! therefore come back in request order, which `grcim loadgen` and the
//! integration tests rely on.

use super::metrics::ServerMetrics;
use super::{proto, CampaignService, MAX_LINE};
use crate::server::proto::{Request, RequestKind};
use crate::util::sync::{
    cv_wait, cv_wait_timeout, lock_recover, panic_msg, spawn_named, Arc, AtomicBool,
    BoundedQueue, Condvar, JoinHandle, Mutex, Ordering,
};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

/// Shortest mux poll-backoff step (after any progress).
const POLL_MIN: Duration = Duration::from_millis(1);
/// Longest mux poll-backoff step (fully idle connections).
const POLL_MAX: Duration = Duration::from_millis(16);
/// Backoff before retrying `accept` after fd/buffer exhaustion.
const ACCEPT_BACKOFF: Duration = Duration::from_millis(200);
/// Outbuf high-water mark: stop reading new requests from a connection
/// whose client lets more than this many response bytes pile up.
const OUT_HIGH_WATER: usize = 2 * MAX_LINE;
/// Grace given to final response flushes at shutdown.
const FLUSH_GRACE: Duration = Duration::from_secs(2);

/// One admitted compute request, queued for a worker.
pub(super) struct ComputeJob {
    /// Index of the mux that owns the connection (response routing).
    mux: usize,
    /// Connection id within that mux.
    conn: u64,
    req: Request,
    kind: RequestKind,
    /// Absolute expiry; a worker dequeueing past it answers `deadline`.
    deadline: Option<Instant>,
    /// Admission time (latency metrics measure queue wait + compute).
    enqueued: Instant,
}

/// The bounded admission queue between muxes and compute workers:
/// [`BoundedQueue`] carrying compute jobs. `try_push` never blocks — a
/// full queue is the `busy` signal; `close` lets workers drain every
/// admitted job before exiting (graceful shutdown). The admission
/// protocol itself is model-checked in `rust/tests/loom_models.rs`.
pub(super) type ComputeQueue = BoundedQueue<ComputeJob>;

#[derive(Default)]
struct Inbox {
    conns: Vec<TcpStream>,
    responses: Vec<(u64, String)>,
    shutdown: bool,
}

/// One mux thread's mailbox: the acceptor posts fresh connections,
/// workers post finished responses, the reactor posts shutdown; each
/// post wakes the mux immediately. `alive` drops to false if the mux
/// thread panics — the acceptor stops routing connections to it.
pub(super) struct MuxShared {
    inbox: Mutex<Inbox>,
    cv: Condvar,
    alive: AtomicBool,
}

impl MuxShared {
    fn new() -> Self {
        MuxShared {
            inbox: Mutex::new(Inbox::default()),
            cv: Condvar::new(),
            alive: AtomicBool::new(true),
        }
    }

    fn add_conn(&self, stream: TcpStream) {
        lock_recover(&self.inbox).conns.push(stream);
        self.cv.notify_one();
    }

    fn deliver(&self, conn: u64, response: String) {
        lock_recover(&self.inbox).responses.push((conn, response));
        self.cv.notify_one();
    }

    fn request_shutdown(&self) {
        lock_recover(&self.inbox).shutdown = true;
        self.cv.notify_all();
    }

    fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    fn mark_dead(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }
}

/// What a mux needs to serve its connections.
struct MuxCtx {
    mux_idx: usize,
    service: Arc<CampaignService>,
    metrics: Arc<ServerMetrics>,
    queue: Arc<ComputeQueue>,
    /// Test-only fault injection: a request line containing this
    /// substring makes the mux thread panic, exercising the dead-mux
    /// recovery path (`None` in production — see
    /// `ServeConfig::mux_panic_line`).
    panic_line: Option<String>,
}

/// One nonblocking connection's state machine.
struct Conn {
    id: u64,
    stream: TcpStream,
    /// Raw accumulated request bytes (converted lossily at dispatch —
    /// see the read-path comment in `read_some`).
    acc: Vec<u8>,
    /// Resyncing after an oversized line: bytes are dropped up to the
    /// line's terminating newline, never parsed as a request.
    discarding: bool,
    /// A compute job for this connection is queued or running; the mux
    /// neither reads the socket nor dispatches buffered lines until the
    /// response comes back (per-connection ordering).
    in_flight: bool,
    read_closed: bool,
    dead: bool,
    /// Pending response bytes not yet accepted by the socket.
    out: Vec<u8>,
}

impl Conn {
    fn new(id: u64, stream: TcpStream) -> Self {
        Conn {
            id,
            stream,
            acc: Vec::new(),
            discarding: false,
            in_flight: false,
            read_closed: false,
            dead: false,
            out: Vec::new(),
        }
    }

    /// Everything sent and received; the mux drops the connection.
    fn finished(&self) -> bool {
        self.dead
            || (self.read_closed && self.acc.is_empty() && !self.in_flight && self.out.is_empty())
    }

    fn queue_line(&mut self, line: &str) {
        self.out.extend_from_slice(line.as_bytes());
        self.out.push(b'\n');
    }

    /// Flush as much buffered output as the socket accepts right now.
    fn pump_write(&mut self) -> bool {
        let mut written = 0usize;
        while written < self.out.len() {
            match self.stream.write(&self.out[written..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => written += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if written > 0 {
            self.out.drain(..written);
        }
        written > 0
    }

    /// Read whatever the socket has ready. Lines are accumulated as raw
    /// *bytes* and converted lossily at dispatch: UTF-8 validation at
    /// read time would disconnect a client whose multi-byte character
    /// straddles a read boundary — byte accumulation has no such
    /// failure mode (invalid UTF-8 simply parses as a malformed request
    /// and gets an error response).
    fn read_some(&mut self) -> bool {
        let mut buf = [0u8; 4096];
        let mut progress = false;
        loop {
            // cap how much a newline-less client can make us buffer;
            // process_lines turns an over-cap accumulation into an
            // error + resync before reading continues
            if !self.discarding && self.acc.len() > MAX_LINE {
                break;
            }
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    if self.discarding {
                        // chunks of an oversized line are dropped
                        // without buffering; its newline ends the resync
                        if let Some(i) = buf[..n].iter().position(|&b| b == b'\n') {
                            self.discarding = false;
                            self.acc.extend_from_slice(&buf[i + 1..n]);
                        }
                    } else {
                        self.acc.extend_from_slice(&buf[..n]);
                    }
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        progress
    }

    /// Dispatch complete lines from the accumulator (stopping while a
    /// compute response is in flight, to preserve ordering).
    fn process_lines(&mut self, ctx: &MuxCtx) -> bool {
        let mut progress = false;
        while !self.in_flight && !self.dead {
            if self.discarding {
                // the resync newline hasn't arrived; nothing buffers
                break;
            }
            match self.acc.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    let line: Vec<u8> = self.acc.drain(..=i).collect();
                    let text = String::from_utf8_lossy(&line);
                    self.handle_line(&text.trim().to_string(), ctx);
                    progress = true;
                }
                None => {
                    if self.acc.len() > MAX_LINE {
                        self.queue_line(&proto::err_line(&format!(
                            "request line exceeds {MAX_LINE} bytes"
                        )));
                        self.acc.clear();
                        self.discarding = true;
                        progress = true;
                        continue;
                    }
                    break;
                }
            }
        }
        // the connection's final, EOF-terminated request without a
        // trailing newline is answered like any other
        if self.read_closed
            && !self.in_flight
            && !self.dead
            && !self.discarding
            && !self.acc.is_empty()
            && !self.acc.contains(&b'\n')
            && self.acc.len() <= MAX_LINE
        {
            let line: Vec<u8> = std::mem::take(&mut self.acc);
            let text = String::from_utf8_lossy(&line);
            self.handle_line(&text.trim().to_string(), ctx);
            progress = true;
        }
        progress
    }

    /// Parse and route one request line: parse errors and inline kinds
    /// are answered on the mux; compute kinds go through admission.
    fn handle_line(&mut self, line: &str, ctx: &MuxCtx) {
        if line.is_empty() {
            return; // blank keep-alive lines are ignored
        }
        if let Some(needle) = &ctx.panic_line {
            if line.contains(needle.as_str()) {
                panic!("mux panic injected for test");
            }
        }
        let start = Instant::now();
        match proto::parse_request_meta(line) {
            Err(e) => {
                ctx.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
                self.queue_line(&proto::err_kind_line("bad_request", &format!("{e:#}")));
            }
            Ok((req, deadline)) => {
                let kind = req.kind();
                if kind.is_inline() {
                    let (resp, ok) = ctx.service.respond_with_status(&req);
                    ctx.metrics.record(kind, ok, start.elapsed());
                    self.queue_line(&resp);
                } else {
                    let job = ComputeJob {
                        mux: ctx.mux_idx,
                        conn: self.id,
                        req,
                        kind,
                        deadline: deadline.map(|d| start + d),
                        enqueued: start,
                    };
                    if ctx.queue.try_push(job) {
                        ctx.metrics.admitted.fetch_add(1, Ordering::Relaxed);
                        ctx.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                        self.in_flight = true;
                    } else {
                        ctx.metrics.rejected_busy.fetch_add(1, Ordering::Relaxed);
                        self.queue_line(&proto::err_kind_line(
                            "busy",
                            "compute queue is full; retry later",
                        ));
                    }
                }
            }
        }
    }

    /// One full service round: flush output, ingest input, dispatch
    /// lines, repeat until nothing moves. Returns whether anything did.
    fn pump(&mut self, ctx: &MuxCtx) -> bool {
        let mut progress = false;
        loop {
            let mut round = self.pump_write();
            round |= self.process_lines(ctx);
            round |= self.pump_write();
            // backpressure: don't ingest while a compute response is
            // pending or the client isn't draining its responses
            if !self.in_flight && !self.read_closed && !self.dead && self.out.len() <= OUT_HIGH_WATER
            {
                round |= self.read_some();
            }
            if !round {
                return progress;
            }
            progress = true;
        }
    }
}

/// Best-effort blocking flush of every connection's pending output at
/// shutdown, bounded by [`FLUSH_GRACE`] per socket.
fn flush_and_close(conns: &mut [Conn], metrics: &ServerMetrics) {
    for c in conns.iter_mut() {
        if c.dead || c.out.is_empty() {
            continue;
        }
        let _ = c.stream.set_nonblocking(false);
        let _ = c.stream.set_write_timeout(Some(FLUSH_GRACE));
        let _ = c.stream.write_all(&c.out);
    }
    metrics.open_conns.fetch_sub(conns.len() as u64, Ordering::Relaxed);
}

fn mux_loop(shared: Arc<MuxShared>, ctx: MuxCtx) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut next_id = 0u64;
    let mut backoff = POLL_MIN;
    loop {
        let (new_conns, responses, shutdown) = {
            let mut inbox = lock_recover(&shared.inbox);
            (
                std::mem::take(&mut inbox.conns),
                std::mem::take(&mut inbox.responses),
                inbox.shutdown,
            )
        };
        let mut progress = !new_conns.is_empty() || !responses.is_empty();
        for stream in new_conns {
            conns.push(Conn::new(next_id, stream));
            next_id += 1;
        }
        for (id, resp) in responses {
            // a worker finished this connection's in-flight request;
            // queue the response and resume reading the socket
            if let Some(c) = conns.iter_mut().find(|c| c.id == id) {
                c.in_flight = false;
                c.queue_line(&resp);
            }
        }
        if shutdown {
            flush_and_close(&mut conns, &ctx.metrics);
            return;
        }
        for c in conns.iter_mut() {
            progress |= c.pump(&ctx);
        }
        let before = conns.len();
        conns.retain(|c| !c.finished());
        if conns.len() != before {
            ctx.metrics.open_conns.fetch_sub((before - conns.len()) as u64, Ordering::Relaxed);
            progress = true;
        }
        if progress {
            backoff = POLL_MIN;
            continue;
        }
        let inbox = lock_recover(&shared.inbox);
        if !inbox.conns.is_empty() || !inbox.responses.is_empty() || inbox.shutdown {
            continue;
        }
        if conns.is_empty() {
            // zero connections: park until the acceptor or a worker knocks
            drop(cv_wait(&shared.cv, inbox));
        } else {
            // open but idle connections: adaptive poll backoff (std has
            // no portable readiness API; inbox posts still wake us
            // immediately via the condvar)
            drop(cv_wait_timeout(&shared.cv, inbox, backoff));
            backoff = (backoff * 2).min(POLL_MAX);
        }
    }
}

fn worker_loop(
    queue: Arc<ComputeQueue>,
    muxes: Arc<Vec<Arc<MuxShared>>>,
    service: Arc<CampaignService>,
    metrics: Arc<ServerMetrics>,
) {
    while let Some(job) = queue.pop() {
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        metrics.in_flight.fetch_add(1, Ordering::Relaxed);
        let expired = job.deadline.is_some_and(|dl| Instant::now() >= dl);
        let resp = if expired {
            metrics.rejected_deadline.fetch_add(1, Ordering::Relaxed);
            metrics.record(job.kind, false, job.enqueued.elapsed());
            proto::err_kind_line("deadline", "deadline_ms expired before compute started")
        } else {
            let (resp, ok) = service.respond_with_status(&job.req);
            metrics.record(job.kind, ok, job.enqueued.elapsed());
            resp
        };
        metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
        muxes[job.mux].deliver(job.conn, resp);
    }
}

/// What the accept loop should do about one `accept` error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum AcceptAction {
    /// Transient per-connection failure (reset mid-handshake etc.):
    /// retry immediately.
    Retry,
    /// Resource exhaustion (EMFILE/ENFILE/ENOBUFS/ENOMEM): back off,
    /// then retry — connections closing will free the resource.
    Backoff,
    /// The listener itself is broken: surface the error, stop accepting.
    Fatal,
}

/// Classify one `accept` error. Every error used to be treated as
/// transient EMFILE and slept on, turning a closed/invalid listener
/// into a silent busy loop; fatal errors now stop the acceptor and are
/// surfaced through [`Reactor::drain`].
pub(super) fn classify_accept_error(e: &std::io::Error) -> AcceptAction {
    match e.kind() {
        ErrorKind::WouldBlock
        | ErrorKind::TimedOut
        | ErrorKind::Interrupted
        | ErrorKind::ConnectionAborted
        | ErrorKind::ConnectionReset => AcceptAction::Retry,
        // raw errnos: ENOMEM(12), ENFILE(23), EMFILE(24), ENOBUFS
        // (55 on BSD/macOS, 105 on Linux)
        _ => match e.raw_os_error() {
            Some(12 | 23 | 24 | 55 | 105) => AcceptAction::Backoff,
            _ => AcceptAction::Fatal,
        },
    }
}

fn accept_loop(
    listener: TcpListener,
    muxes: Arc<Vec<Arc<MuxShared>>>,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<ServerMetrics>,
    fatal: Arc<Mutex<Option<String>>>,
) {
    let mut rr = 0usize;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if shutdown.load(Ordering::SeqCst) {
                    break; // the throwaway wake-up connect from drain()
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                // route to the next *live* mux: a panicked mux marks
                // itself dead and must not receive fresh connections
                // (they would never be served). All muxes dead is fatal.
                let n = muxes.len();
                let Some(target) = (0..n).map(|o| (rr + o) % n).find(|&i| muxes[i].is_alive())
                else {
                    if !shutdown.load(Ordering::SeqCst) {
                        *lock_recover(&fatal) =
                            Some("all mux threads are dead; stopping acceptor".to_string());
                    }
                    break;
                };
                metrics.accepted.fetch_add(1, Ordering::Relaxed);
                metrics.open_conns.fetch_add(1, Ordering::Relaxed);
                muxes[target].add_conn(stream);
                rr = target.wrapping_add(1);
            }
            Err(e) => match classify_accept_error(&e) {
                AcceptAction::Retry => continue,
                AcceptAction::Backoff => {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    std::thread::sleep(ACCEPT_BACKOFF);
                }
                AcceptAction::Fatal => {
                    if !shutdown.load(Ordering::SeqCst) {
                        *lock_recover(&fatal) = Some(format!("accept failed fatally: {e}"));
                    }
                    break;
                }
            },
        }
    }
    // the listener drops here, closing the port
}

/// The running event loop: acceptor + muxes + workers, torn down by the
/// one shared [`Reactor::drain`] path.
pub(super) struct Reactor {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    muxes: Arc<Vec<Arc<MuxShared>>>,
    mux_handles: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    queue: Arc<ComputeQueue>,
    accept_fatal: Arc<Mutex<Option<String>>>,
    /// First mux-thread panic, surfaced as [`Reactor::drain`]'s error.
    mux_fatal: Arc<Mutex<Option<String>>>,
}

impl Reactor {
    /// Spawn the full thread complement around a bound listener.
    /// `mux_panic_line` is the test-only fault-injection hook threaded
    /// from `ServeConfig` (always `None` in production).
    pub(super) fn spawn(
        listener: TcpListener,
        service: Arc<CampaignService>,
        metrics: Arc<ServerMetrics>,
        mux_threads: usize,
        compute_threads: usize,
        queue_cap: usize,
        mux_panic_line: Option<String>,
    ) -> Result<Reactor> {
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(ComputeQueue::new(queue_cap.max(1)));
        metrics.set_queue_cap(queue_cap.max(1));
        let muxes: Arc<Vec<Arc<MuxShared>>> =
            Arc::new((0..mux_threads.max(1)).map(|_| Arc::new(MuxShared::new())).collect());
        let mux_fatal: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));

        let mut mux_handles = Vec::new();
        for (i, shared) in muxes.iter().enumerate() {
            let shared = Arc::clone(shared);
            let ctx = MuxCtx {
                mux_idx: i,
                service: Arc::clone(&service),
                metrics: Arc::clone(&metrics),
                queue: Arc::clone(&queue),
                panic_line: mux_panic_line.clone(),
            };
            let fatal = Arc::clone(&mux_fatal);
            // a panicking mux must not take the server down silently:
            // catch the unwind, mark the mailbox dead so the acceptor
            // stops routing connections here, and record the panic for
            // Server::join to surface
            let handle = spawn_named(format!("grcim-mux-{i}"), move || {
                let mailbox = Arc::clone(&shared);
                if let Err(payload) =
                    catch_unwind(AssertUnwindSafe(move || mux_loop(shared, ctx)))
                {
                    mailbox.mark_dead();
                    let mut slot = lock_recover(&fatal);
                    if slot.is_none() {
                        *slot = Some(format!("mux {i} panicked: {}", panic_msg(&*payload)));
                    }
                }
            })
            .context("spawning mux thread")?;
            mux_handles.push(handle);
        }

        let mut workers = Vec::new();
        for i in 0..compute_threads.max(1) {
            let queue = Arc::clone(&queue);
            let muxes = Arc::clone(&muxes);
            let service = Arc::clone(&service);
            let metrics = Arc::clone(&metrics);
            let handle =
                spawn_named(format!("grcim-compute-{i}"), move || {
                    worker_loop(queue, muxes, service, metrics)
                })
                .context("spawning compute worker")?;
            workers.push(handle);
        }

        let accept_fatal = Arc::new(Mutex::new(None));
        let accept = {
            let muxes = Arc::clone(&muxes);
            let shutdown = Arc::clone(&shutdown);
            let metrics = Arc::clone(&metrics);
            let fatal = Arc::clone(&accept_fatal);
            spawn_named("grcim-accept", move || {
                accept_loop(listener, muxes, shutdown, metrics, fatal)
            })
            .context("spawning accept thread")?
        };

        Ok(Reactor {
            addr,
            shutdown,
            accept: Some(accept),
            muxes,
            mux_handles,
            workers,
            queue,
            accept_fatal,
            mux_fatal,
        })
    }

    /// Block until the acceptor exits — an external [`Reactor::drain`]
    /// or a fatal accept error (surfaced by the subsequent drain).
    pub(super) fn join_acceptor(&mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow!("accept thread panicked"))?;
        }
        Ok(())
    }

    /// The single teardown path (shutdown and join share it): stop
    /// accepting, finish every admitted compute job, deliver and flush
    /// all responses, then join every thread. Returns the acceptor's
    /// fatal error, if one stopped it.
    pub(super) fn drain(&mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake a blocking accept with a throwaway connection (a no-op
        // if the acceptor already exited and closed the listener)
        let _ = TcpStream::connect(self.addr);
        let acceptor = self.join_acceptor();
        // workers finish everything already admitted, then exit …
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // … so every response reaches its mux inbox before the muxes
        // take their final flush-and-close turn
        for m in self.muxes.iter() {
            m.request_shutdown();
        }
        for h in self.mux_handles.drain(..) {
            let _ = h.join();
        }
        // error precedence: an acceptor panic first, then a mux panic
        // (the root cause — it also makes the acceptor report "all mux
        // threads are dead" when it was the only mux), then accept-path
        // fatals
        acceptor?;
        if let Some(msg) = lock_recover(&self.mux_fatal).take() {
            bail!("{msg}");
        }
        if let Some(msg) = lock_recover(&self.accept_fatal).take() {
            bail!("{msg}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> ComputeJob {
        ComputeJob {
            mux: 0,
            conn: 0,
            req: Request::Info,
            kind: RequestKind::Info,
            deadline: None,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn queue_admits_to_cap_then_rejects() {
        let q = ComputeQueue::new(2);
        assert!(q.try_push(job()));
        assert!(q.try_push(job()));
        // the bounded queue is the admission control: a full queue
        // rejects instead of growing (the caller answers `busy`)
        assert!(!q.try_push(job()));
        assert!(q.pop().is_some());
        assert!(q.try_push(job()), "popping frees a slot");
    }

    #[test]
    fn closed_queue_drains_then_ends() {
        let q = Arc::new(ComputeQueue::new(8));
        assert!(q.try_push(job()));
        assert!(q.try_push(job()));
        q.close();
        assert!(!q.try_push(job()), "no admissions after close");
        // graceful shutdown: both admitted jobs still come out
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
        // and a blocked popper wakes up with None
        let q2 = Arc::new(ComputeQueue::new(8));
        let qq = Arc::clone(&q2);
        let h = std::thread::spawn(move || qq.pop().is_none());
        std::thread::sleep(Duration::from_millis(20));
        q2.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn accept_errors_classify_by_severity() {
        use std::io::Error;
        // transient peer-side failures retry immediately
        for kind in [
            ErrorKind::ConnectionAborted,
            ErrorKind::ConnectionReset,
            ErrorKind::WouldBlock,
            ErrorKind::TimedOut,
            ErrorKind::Interrupted,
        ] {
            let e = Error::new(kind, "transient");
            assert_eq!(classify_accept_error(&e), AcceptAction::Retry, "{kind:?}");
        }
        // resource exhaustion backs off: EMFILE, ENFILE, ENOBUFS, ENOMEM
        for errno in [24, 23, 105, 12] {
            let e = Error::from_raw_os_error(errno);
            assert_eq!(classify_accept_error(&e), AcceptAction::Backoff, "errno {errno}");
        }
        // anything else (EBADF, EINVAL: the listener itself is broken)
        // is fatal — the old code busy-slept on these forever
        for errno in [9, 22] {
            let e = Error::from_raw_os_error(errno);
            assert_eq!(classify_accept_error(&e), AcceptAction::Fatal, "errno {errno}");
        }
    }
}
