//! Minimal JSON parser (recursive descent) — the vendor set has no serde.
//! Covers the full JSON grammar except `\u` surrogate pairs (sufficient for
//! the artifact manifest and campaign result files we read and write).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (JSON numbers are f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — serialization is canonical).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing bytes are an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// The number, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to usize, if this is a [`Json::Num`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The string, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array items (empty slice for non-arrays).
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// Serialize (stable key order; numbers in shortest round-trip form).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected character at byte {}", self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(
                                char::from_u32(code)
                                    .unwrap_or(char::REPLACEMENT_CHARACTER),
                            );
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "batch": 2048, "mvm_batch": 32, "outputs": 10,
          "entries": [
            {"file": "macsim_nr32.hlo.txt", "graph": "macsim", "nr": 32, "batch": 2048}
          ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(2048));
        let e = &j.get("entries").unwrap().items()[0];
        assert_eq!(e.get("graph").unwrap().as_str(), Some("macsim"));
        assert_eq!(e.get("nr").unwrap().as_usize(), Some(32));
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a b\"").unwrap(), Json::Str("a b".into()));
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(j, Json::Str("a\n\t\"\\A".into()));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[1, [2, 3], []]").unwrap();
        assert_eq!(j.items().len(), 3);
        assert_eq!(j.items()[1].items()[1].as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn round_trip() {
        let text = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let j = Json::parse(text).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → ∑\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → ∑"));
    }

    /// parse -> serialize -> parse must be a fixed point.
    fn assert_round_trip(text: &str) -> Json {
        let j = Json::parse(text).unwrap_or_else(|e| panic!("{text}: {e}"));
        let emitted = j.to_string();
        let again = Json::parse(&emitted)
            .unwrap_or_else(|e| panic!("re-parse of {emitted}: {e}"));
        assert_eq!(j, again, "round trip of {text} via {emitted}");
        // serialization itself must also be a fixed point
        assert_eq!(emitted, again.to_string());
        again
    }

    #[test]
    fn round_trip_escapes() {
        assert_round_trip(r#""line\nbreak\ttab \"quoted\" back\\slash""#);
        assert_round_trip(r#""solidus \/ bs \b ff \f cr \r""#);
        // control characters survive via \uXXXX
        let j = assert_round_trip("\"\\u0001\\u001f\"");
        assert_eq!(j, Json::Str("\u{1}\u{1f}".into()));
        // non-ASCII passthrough
        assert_round_trip("\"héllo → ∑ 漢字\"");
        // escaped object keys
        assert_round_trip(r#"{"a\nb":1,"c\"d":[true,"\\"]}"#);
    }

    #[test]
    fn round_trip_nested_arrays() {
        assert_round_trip("[[[[1],[2,[3,[]]]],[]],[null,[true,[false]]]]");
        let j = assert_round_trip(r#"{"grid":[[1,2],[3,4],[[5],[6,7]]]}"#);
        let grid = j.get("grid").unwrap();
        assert_eq!(grid.items()[2].items()[1].items()[1].as_f64(), Some(7.0));
    }

    #[test]
    fn round_trip_number_edge_cases() {
        for text in [
            "0",
            "-1",
            "0.1",
            "-2.5e-5",
            "1e-308",
            "1.7976931348623157e308",
            "2.2250738585072014e-308",
            "9007199254740991",
            "1e15",
            "123456789.123456789",
            "6.02",
            "1e+16",
        ] {
            let j = assert_round_trip(text);
            // value preserved exactly against the reference parse
            assert_eq!(j.as_f64(), Some(text.parse::<f64>().unwrap()), "{text}");
        }
        // integer-valued floats below 1e15 serialize without exponent and
        // re-parse to the same value
        assert_eq!(Json::Num(2048.0).to_string(), "2048");
        assert_eq!(Json::parse("2.048e3").unwrap(), Json::Num(2048.0));
    }

    #[test]
    fn round_trip_mixed_document() {
        assert_round_trip(
            r#"{"_tol":1e-9,"values":{"a":1.5,"b":[0.25,-3,"x"],"c":null,"d":{"e":false}}}"#,
        );
    }
}
