//! Configuration: a minimal TOML-subset parser for campaign/figure config
//! files plus the crate's JSON codec (artifact manifest, result stores).
//!
//! Supported TOML subset: `[section]` and `[[array-of-tables]]` headers,
//! `key = value` with strings, numbers, booleans, and flat arrays; `#`
//! comments. This covers everything in `configs/*.toml`.

pub mod json;

pub use json::Json;

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A TOML scalar or flat array.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// A number (all TOML numbers parse as f64).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of values.
    Arr(Vec<Value>),
}

impl Value {
    /// The number, if this is a [`Value::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to usize, if this is a [`Value::Num`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The string, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is a [`Value::Arr`].
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// One `[section]` (or one element of an `[[array-of-tables]]`).
pub type Table = BTreeMap<String, Value>;

/// A parsed config document.
#[derive(Debug, Clone, Default)]
pub struct Config {
    /// Top-level (pre-section) keys.
    pub root: Table,
    /// Named sections in file order: (name, table).
    pub sections: Vec<(String, Table)>,
}

impl Config {
    /// Parse a TOML-subset document (see the module docs for the
    /// supported grammar).
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut current: Option<(String, Table)> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = header(line) {
                if let Some(done) = current.take() {
                    cfg.sections.push(done);
                }
                current = Some((name.to_string(), Table::new()));
            } else {
                let (k, v) = parse_kv(line)
                    .with_context(|| format!("line {}", lineno + 1))?;
                match &mut current {
                    Some((_, t)) => t.insert(k, v),
                    None => cfg.root.insert(k, v),
                };
            }
        }
        if let Some(done) = current.take() {
            cfg.sections.push(done);
        }
        Ok(cfg)
    }

    /// Read and parse a config file.
    pub fn load(path: &std::path::Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// All sections with the given name (array-of-tables semantics).
    pub fn sections_named<'a>(&'a self, name: &str) -> Vec<&'a Table> {
        self.sections
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, t)| t)
            .collect()
    }

    /// First section with the given name.
    pub fn section<'a>(&'a self, name: &str) -> Option<&'a Table> {
        self.sections_named(name).into_iter().next()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn header(line: &str) -> Option<&str> {
    let l = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]"));
    if let Some(name) = l {
        return Some(name.trim());
    }
    line.strip_prefix('[')
        .and_then(|l| l.strip_suffix(']'))
        .map(str::trim)
}

fn parse_kv(line: &str) -> Result<(String, Value)> {
    let eq = line.find('=').context("expected 'key = value'")?;
    let key = line[..eq].trim().to_string();
    if key.is_empty() {
        bail!("empty key");
    }
    let value = parse_value(line[eq + 1..].trim())?;
    Ok((key, value))
}

fn parse_value(text: &str) -> Result<Value> {
    if text.starts_with('"') {
        let inner = text
            .strip_prefix('"')
            .and_then(|t| t.strip_suffix('"'))
            .context("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if text.starts_with('[') {
        let inner = text
            .strip_prefix('[')
            .and_then(|t| t.strip_suffix(']'))
            .context("unterminated array")?;
        let mut items = Vec::new();
        // split on commas outside strings
        let mut depth_str = false;
        let mut start = 0usize;
        let bytes = inner.as_bytes();
        for i in 0..bytes.len() {
            match bytes[i] {
                b'"' => depth_str = !depth_str,
                b',' if !depth_str => {
                    let piece = inner[start..i].trim();
                    if !piece.is_empty() {
                        items.push(parse_value(piece)?);
                    }
                    start = i + 1;
                }
                _ => {}
            }
        }
        let piece = inner[start..].trim();
        if !piece.is_empty() {
            items.push(parse_value(piece)?);
        }
        return Ok(Value::Arr(items));
    }
    text.parse::<f64>()
        .map(Value::Num)
        .with_context(|| format!("cannot parse value: {text}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_campaign_style_config() {
        let text = r#"
# campaign config
seed = 42
samples = 65536        # per grid point

[engine]
kind = "pjrt"
artifacts = "artifacts"

[[experiment]]
name = "fig10"
n_e = [1, 2, 3, 4, 5]
n_m_x = 2

[[experiment]]
name = "fig11"
n_m = [1, 2, 3, 4, 5, 6]
"#;
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.root["seed"].as_usize(), Some(42));
        assert_eq!(
            cfg.section("engine").unwrap()["kind"].as_str(),
            Some("pjrt")
        );
        let exps = cfg.sections_named("experiment");
        assert_eq!(exps.len(), 2);
        assert_eq!(exps[0]["name"].as_str(), Some("fig10"));
        assert_eq!(exps[0]["n_e"].as_arr().unwrap().len(), 5);
        assert_eq!(exps[1]["n_m"].as_arr().unwrap().len(), 6);
    }

    #[test]
    fn values() {
        assert_eq!(parse_value("1.5").unwrap(), Value::Num(1.5));
        assert_eq!(parse_value("true").unwrap(), Value::Bool(true));
        assert_eq!(parse_value("\"a#b\"").unwrap(), Value::Str("a#b".into()));
        assert_eq!(
            parse_value("[1, 2]").unwrap(),
            Value::Arr(vec![Value::Num(1.0), Value::Num(2.0)])
        );
        assert_eq!(parse_value("[]").unwrap(), Value::Arr(vec![]));
    }

    #[test]
    fn comments_and_strings() {
        let cfg = Config::parse("k = \"a # not comment\" # real comment").unwrap();
        assert_eq!(cfg.root["k"].as_str(), Some("a # not comment"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = @?!").is_err());
        assert!(Config::parse("= 3").is_err());
    }

    #[test]
    fn empty_config_ok() {
        let cfg = Config::parse("\n# just comments\n").unwrap();
        assert!(cfg.root.is_empty());
        assert!(cfg.sections.is_empty());
    }
}
