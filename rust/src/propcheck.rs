//! Tiny property-testing kit (the vendor set has no proptest): generate
//! `cases` random inputs from a generator, assert a property on each, and
//! on failure report the seed + a human-readable rendering of the minimal
//! failing case found by a bounded shrink loop.
//!
//! Used by the invariant suite in `rust/tests/properties.rs` and by inline
//! module tests where hand-rolled loops would repeat boilerplate.

use crate::rng::Pcg64;
use std::fmt::Debug;

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` generated inputs. Panics with diagnostics on
/// the first failure; tries `shrink` up to 64 times to find a simpler
/// failing case (pass `|_| None` for no shrinking).
pub fn check<T, G, P, S>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: G,
    mut shrink: S,
    mut prop: P,
) where
    T: Debug + Clone,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> PropResult,
    S: FnMut(&T) -> Option<T>,
{
    let mut rng = Pcg64::seeded(seed);
    for case_idx in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // bounded shrink
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut cur = input;
            for _ in 0..64 {
                match shrink(&cur) {
                    Some(smaller) => match prop(&smaller) {
                        Err(m) => {
                            best = smaller.clone();
                            best_msg = m;
                            cur = smaller;
                        }
                        Ok(()) => break,
                    },
                    None => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}, case={case_idx}):\n  \
                 input: {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// Shorthand for properties without shrinking.
pub fn check_simple<T, G, P>(name: &str, seed: u64, cases: usize, gen: G, prop: P)
where
    T: Debug + Clone,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> PropResult,
{
    check(name, seed, cases, gen, |_| None, prop)
}

/// Property helper: require a boolean with a lazily formatted reason.
pub fn ensure(cond: bool, reason: impl FnOnce() -> String) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(reason())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_simple(
            "count",
            1,
            100,
            |rng| rng.uniform(),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 100);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_name() {
        check_simple(
            "fails",
            2,
            10,
            |rng| rng.uniform(),
            |x| ensure(*x < 0.0, || format!("{x} not negative")),
        );
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        // property fails for any v > 10; shrink halves; minimal found
        // failing value must be <= 22 (one halving above the boundary)
        let result = std::panic::catch_unwind(|| {
            check(
                "shrinks",
                3,
                50,
                |rng| (rng.uniform() * 1000.0) as u64,
                |v| if *v > 11 { Some(v / 2) } else { None },
                |v| ensure(*v <= 10, || format!("{v} too big")),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        let shown: u64 = msg
            .split("input: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(shown <= 22, "shrunk case {shown} in: {msg}");
    }

    #[test]
    fn ensure_formats_lazily() {
        assert!(ensure(true, || unreachable!()).is_ok());
        assert_eq!(ensure(false, || "bad".into()), Err("bad".into()));
    }
}
