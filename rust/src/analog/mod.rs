//! Analog circuit substrate — the behavioral replacement for the paper's
//! 22 nm post-layout SPICE (DESIGN.md §1 substitution table).
//!
//! The GR-MAC cell is a switched *linear* capacitor network, so its static
//! transfer — the quantity Fig. 8 characterizes (W-sweep linearity, E-sweep
//! exponential gain, DNL/INL under mismatch) — is exactly the solution of
//! the linear charge-redistribution equations. Three layers:
//!
//! * [`capnet`] — general capacitive-network nodal solver (charge
//!   conservation at floating nodes, Gaussian elimination);
//! * [`grmac_cell`] — the FP6_E2M3 GR-MAC netlist of Fig. 6/7: the
//!   binary-weighted mantissa divider, the gain-ranging coupling stage with
//!   the paper's two layout transformations, eq. (1) parasitic
//!   compensation, and the Table I capacitor values;
//! * [`mismatch`] — Pelgrom-model Monte Carlo (σ(ΔC/C) = K_C/√C) and the
//!   DNL/INL extraction behind Fig. 8.

pub mod capnet;
pub mod grmac_cell;
pub mod mismatch;

pub use capnet::CapNetwork;
pub use grmac_cell::GrMacCell;
pub use mismatch::{dnl_inl, MismatchModel, Sweep};
