//! Linear capacitive-network nodal solver.
//!
//! Static (evaluation-phase) solution of a network of ideal capacitors:
//! driven nodes are held at known voltages, floating nodes settle by charge
//! conservation from a discharged initial state:
//!
//! ```text
//! for every floating node i:   sum_j C_ij (V_i - V_j) = 0
//! ```
//!
//! i.e. the capacitance-weighted graph Laplacian restricted to floating
//! nodes, solved by Gaussian elimination with partial pivoting (networks
//! here are tiny — a GR-MAC cell has 2 floating nodes — but the solver is
//! general and is also used by the column-level tests with hundreds of
//! nodes).

use anyhow::{bail, Result};

/// Node handle.
pub type NodeId = usize;

#[derive(Debug, Clone, Copy)]
enum NodeKind {
    Floating,
    Driven(f64),
}

/// A capacitive network under construction.
#[derive(Debug, Clone)]
pub struct CapNetwork {
    kinds: Vec<NodeKind>,
    /// (a, b, c_farads) — undirected capacitor edges.
    caps: Vec<(NodeId, NodeId, f64)>,
}

impl Default for CapNetwork {
    fn default() -> Self {
        Self::new()
    }
}

impl CapNetwork {
    /// An empty network.
    pub fn new() -> Self {
        CapNetwork { kinds: Vec::new(), caps: Vec::new() }
    }

    /// Add a floating node (initially discharged).
    pub fn node(&mut self) -> NodeId {
        self.kinds.push(NodeKind::Floating);
        self.kinds.len() - 1
    }

    /// Add a node driven to a fixed voltage (source or ground).
    pub fn driven(&mut self, volts: f64) -> NodeId {
        self.kinds.push(NodeKind::Driven(volts));
        self.kinds.len() - 1
    }

    /// Connect a capacitor of `c` (any consistent unit) between two nodes.
    pub fn cap(&mut self, a: NodeId, b: NodeId, c: f64) {
        assert!(a < self.kinds.len() && b < self.kinds.len());
        assert!(c >= 0.0, "negative capacitance");
        if a != b && c > 0.0 {
            self.caps.push((a, b, c));
        }
    }

    /// Total nodes added so far (driven + floating).
    pub fn node_count(&self) -> usize {
        self.kinds.len()
    }

    /// Solve all node voltages. Fails if a floating node has no capacitive
    /// path at all (singular system).
    pub fn solve(&self) -> Result<Solution> {
        let n = self.kinds.len();
        // index floating nodes
        let mut f_index = vec![usize::MAX; n];
        let mut floating = Vec::new();
        for (i, k) in self.kinds.iter().enumerate() {
            if matches!(k, NodeKind::Floating) {
                f_index[i] = floating.len();
                floating.push(i);
            }
        }
        let nf = floating.len();
        let mut voltages: Vec<f64> = self
            .kinds
            .iter()
            .map(|k| match k {
                NodeKind::Driven(v) => *v,
                NodeKind::Floating => 0.0,
            })
            .collect();

        if nf > 0 {
            // assemble L_ff V_f = -L_fs V_s  (dense; networks are small)
            let mut a = vec![0.0f64; nf * nf];
            let mut rhs = vec![0.0f64; nf];
            for &(p, q, c) in &self.caps {
                for (u, v) in [(p, q), (q, p)] {
                    if f_index[u] != usize::MAX {
                        let i = f_index[u];
                        a[i * nf + i] += c;
                        match self.kinds[v] {
                            NodeKind::Floating => {
                                a[i * nf + f_index[v]] -= c;
                            }
                            NodeKind::Driven(vs) => {
                                rhs[i] += c * vs;
                            }
                        }
                    }
                }
            }
            let vf = gauss_solve(&mut a, &mut rhs, nf)?;
            for (i, &node) in floating.iter().enumerate() {
                voltages[node] = vf[i];
            }
        }

        // per-driven-node delivered charge: Q = sum_j C_ij (V_i - V_j)
        let mut charge = vec![0.0f64; n];
        for &(p, q, c) in &self.caps {
            let dq = c * (voltages[p] - voltages[q]);
            charge[p] += dq;
            charge[q] -= dq;
        }
        Ok(Solution { voltages, charge })
    }
}

/// Solved network state.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Voltage at every node.
    pub voltages: Vec<f64>,
    /// Net charge each node sourced into the network (zero at floating
    /// nodes by construction — the solver's invariant).
    pub charge: Vec<f64>,
}

/// Dense Gaussian elimination with partial pivoting; consumes its inputs.
fn gauss_solve(a: &mut [f64], rhs: &mut [f64], n: usize) -> Result<Vec<f64>> {
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = a[col * n + col].abs();
        for row in col + 1..n {
            let v = a[row * n + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if best < 1e-18 {
            bail!("singular capacitive network (floating node with no path)");
        }
        if piv != col {
            for k in 0..n {
                a.swap(col * n + k, piv * n + k);
            }
            rhs.swap(col, piv);
        }
        // eliminate
        let d = a[col * n + col];
        for row in col + 1..n {
            let f = a[row * n + col] / d;
            if f != 0.0 {
                for k in col..n {
                    a[row * n + k] -= f * a[col * n + k];
                }
                rhs[row] -= f * rhs[col];
            }
        }
    }
    // back substitution
    let mut x = vec![0.0f64; n];
    for row in (0..n).rev() {
        let mut acc = rhs[row];
        for k in row + 1..n {
            acc -= a[row * n + k] * x[k];
        }
        x[row] = acc / a[row * n + row];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn capacitive_divider() {
        // vdd --C1-- mid --C2-- gnd : V_mid = C1/(C1+C2)
        let mut net = CapNetwork::new();
        let vdd = net.driven(1.0);
        let gnd = net.driven(0.0);
        let mid = net.node();
        net.cap(vdd, mid, 3.0);
        net.cap(mid, gnd, 1.0);
        let sol = net.solve().unwrap();
        assert!(approx_eq(sol.voltages[mid], 0.75, 1e-12));
    }

    #[test]
    fn charge_conservation_at_floating_nodes() {
        let mut net = CapNetwork::new();
        let vdd = net.driven(1.0);
        let gnd = net.driven(0.0);
        let a = net.node();
        let b = net.node();
        net.cap(vdd, a, 2.0);
        net.cap(a, b, 1.5);
        net.cap(b, gnd, 0.5);
        net.cap(a, gnd, 0.7);
        let sol = net.solve().unwrap();
        assert!(sol.charge[a].abs() < 1e-12);
        assert!(sol.charge[b].abs() < 1e-12);
        // total sourced charge balances
        assert!((sol.charge[vdd] + sol.charge[gnd]).abs() < 1e-12);
    }

    #[test]
    fn series_charge_transfer() {
        // source --Ca-- n --Cb-- gnd: charge into gnd = V * (Ca || Cb)
        let mut net = CapNetwork::new();
        let src = net.driven(2.0);
        let gnd = net.driven(0.0);
        let n = net.node();
        let (ca, cb) = (4.0, 12.0);
        net.cap(src, n, ca);
        net.cap(n, gnd, cb);
        let sol = net.solve().unwrap();
        let series = ca * cb / (ca + cb);
        assert!(approx_eq(-sol.charge[gnd], 2.0 * series, 1e-12));
    }

    #[test]
    fn superposition_holds() {
        // linear network: solution scales with the source
        let build = |v: f64| {
            let mut net = CapNetwork::new();
            let s = net.driven(v);
            let g = net.driven(0.0);
            let m = net.node();
            net.cap(s, m, 1.0);
            net.cap(m, g, 2.0);
            (net, m)
        };
        let (n1, m) = build(1.0);
        let (n3, _) = build(3.0);
        let v1 = n1.solve().unwrap().voltages[m];
        let v3 = n3.solve().unwrap().voltages[m];
        assert!(approx_eq(v3, 3.0 * v1, 1e-12));
    }

    #[test]
    fn singular_network_rejected() {
        let mut net = CapNetwork::new();
        let _vdd = net.driven(1.0);
        let _orphan = net.node(); // no capacitor at all
        assert!(net.solve().is_err());
    }

    #[test]
    fn ladder_network_c2c() {
        // C-2C ladder (Razavi): in the capacitive dual of R-2R the series
        // elements are 2C and the shunts are C, terminated with an extra C
        // so every node sees 2C looking right -> exact halving per stage.
        let mut net = CapNetwork::new();
        let gnd = net.driven(0.0);
        let src = net.driven(1.0);
        let n1 = net.node();
        let n2 = net.node();
        let n3 = net.node();
        net.cap(src, n1, 2.0); // series 2C
        net.cap(n1, gnd, 1.0); // shunt C
        net.cap(n1, n2, 2.0);
        net.cap(n2, gnd, 1.0);
        net.cap(n2, n3, 2.0);
        net.cap(n3, gnd, 1.0);
        net.cap(n3, gnd, 1.0); // termination C (node total 2C)
        let sol = net.solve().unwrap();
        let r1 = sol.voltages[n2] / sol.voltages[n1];
        let r2 = sol.voltages[n3] / sol.voltages[n2];
        assert!(approx_eq(r1, 0.5, 1e-9), "r1={r1}");
        assert!(approx_eq(r2, 0.5, 1e-9), "r2={r2}");
    }

    #[test]
    fn random_networks_conserve_charge() {
        let mut rng = crate::rng::Pcg64::seeded(37);
        for _ in 0..50 {
            let mut net = CapNetwork::new();
            let s = net.driven(rng.uniform_in(-1.0, 1.0));
            let g = net.driven(0.0);
            let nodes: Vec<_> = (0..6).map(|_| net.node()).collect();
            // chain to guarantee non-singularity, then random extra caps
            let mut prev = s;
            for &n in &nodes {
                net.cap(prev, n, rng.uniform_in(0.1, 5.0));
                prev = n;
            }
            net.cap(prev, g, rng.uniform_in(0.1, 5.0));
            for _ in 0..6 {
                let a = nodes[rng.below(6) as usize];
                let b = nodes[rng.below(6) as usize];
                net.cap(a, b, rng.uniform_in(0.0, 2.0));
            }
            let sol = net.solve().unwrap();
            for &n in &nodes {
                assert!(sol.charge[n].abs() < 1e-10);
            }
        }
    }

    #[test]
    fn zero_and_self_caps_ignored() {
        let mut net = CapNetwork::new();
        let s = net.driven(1.0);
        let g = net.driven(0.0);
        let m = net.node();
        net.cap(m, m, 5.0); // self loop: ignored
        net.cap(s, m, 0.0); // zero cap: ignored
        net.cap(s, m, 1.0);
        net.cap(m, g, 1.0);
        let sol = net.solve().unwrap();
        assert!(approx_eq(sol.voltages[m], 0.5, 1e-12));
    }
}
