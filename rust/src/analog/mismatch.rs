//! Pelgrom-model capacitor mismatch Monte Carlo and DNL/INL extraction —
//! the machinery behind Fig. 8 (Sec. III-E1).
//!
//! Mismatch of a metal-oxide-metal capacitor follows Pelgrom's area
//! relation; since capacitance scales linearly with finger length for a
//! fixed cross-section this is written as
//!
//! ```text
//! sigma(dC/C) = K_C / sqrt(C)
//! ```
//!
//! with K_C in %·sqrt(fF). The paper brackets its structure between
//! K_C = 0.45 (five-layer interdigitated, from Omran's measured K_A) and
//! K_C = 0.85 (Tripathi's single-layer measurement) and simulates both.

use super::grmac_cell::GrMacCell;
use crate::rng::Pcg64;

/// Pelgrom mismatch model.
#[derive(Debug, Clone, Copy)]
pub struct MismatchModel {
    /// Matching coefficient, %·sqrt(fF).
    pub k_c_pct_sqrt_ff: f64,
}

impl MismatchModel {
    /// Lower bound of the paper's range (five-layer MOM estimate).
    pub fn low() -> Self {
        MismatchModel { k_c_pct_sqrt_ff: 0.45 }
    }

    /// Upper bound (Tripathi's 32 nm lateral-finger measurement).
    pub fn high() -> Self {
        MismatchModel { k_c_pct_sqrt_ff: 0.85 }
    }

    /// Relative sigma for a capacitor of `c` fF.
    pub fn sigma(&self, c_ff: f64) -> f64 {
        assert!(c_ff > 0.0);
        self.k_c_pct_sqrt_ff / 100.0 / c_ff.sqrt()
    }

    /// Perturb one capacitor value.
    pub fn perturb(&self, c_ff: f64, rng: &mut Pcg64) -> f64 {
        c_ff * (1.0 + self.sigma(c_ff) * rng.normal())
    }

    /// A mismatched instance of a designed cell.
    ///
    /// The normal deviates for all capacitors are drawn in one batched
    /// [`Pcg64::fill_normal`] call — bit-exact with the historical
    /// per-capacitor `normal()` sequence, so MC DNL/INL goldens are
    /// unchanged.
    pub fn instance(&self, cell: &GrMacCell, rng: &mut Pcg64) -> GrMacCell {
        let mut inst = cell.clone();
        let n = inst.c_m.len() + inst.c_e.len();
        let mut z = [0.0f64; 64];
        if n > z.len() {
            // outlandishly wide cell: keep the sequential path
            for c in inst.c_m.iter_mut().chain(inst.c_e.iter_mut()) {
                *c = self.perturb(*c, rng);
            }
            return inst;
        }
        rng.fill_normal(&mut z[..n]);
        for (c, &zi) in
            inst.c_m.iter_mut().chain(inst.c_e.iter_mut()).zip(z.iter())
        {
            *c *= 1.0 + self.sigma(*c) * zi;
        }
        inst
    }
}

/// DNL/INL of a measured staircase, in LSB, against the best-fit line
/// (Fig. 8 convention: endpoint-corrected linear reference).
#[derive(Debug, Clone)]
pub struct Sweep {
    /// Measured output per code.
    pub values: Vec<f64>,
    /// DNL per step (len = codes - 1), in LSB.
    pub dnl: Vec<f64>,
    /// INL per code, in LSB (endpoint-fit reference).
    pub inl: Vec<f64>,
}

/// Extract DNL and INL from a monotone staircase `values[code]`.
pub fn dnl_inl(values: &[f64]) -> Sweep {
    assert!(values.len() >= 2);
    let n = values.len();
    // endpoint-fit LSB
    let lsb = (values[n - 1] - values[0]) / (n - 1) as f64;
    assert!(lsb != 0.0, "degenerate staircase");
    let dnl: Vec<f64> = values
        .windows(2)
        .map(|w| (w[1] - w[0]) / lsb - 1.0)
        .collect();
    let inl: Vec<f64> = values
        .iter()
        .enumerate()
        .map(|(i, &v)| (v - (values[0] + i as f64 * lsb)) / lsb)
        .collect();
    Sweep { values: values.to_vec(), dnl, inl }
}

impl Sweep {
    /// Worst-case |DNL| across the staircase, LSB.
    pub fn max_abs_dnl(&self) -> f64 {
        self.dnl.iter().fold(0.0, |a, &b| a.max(b.abs()))
    }

    /// Worst-case |INL| across the staircase, LSB.
    pub fn max_abs_inl(&self) -> f64 {
        self.inl.iter().fold(0.0, |a, &b| a.max(b.abs()))
    }
}

/// W-sweep of a cell at one gain level: measured charge per mantissa code.
pub fn w_sweep(cell: &GrMacCell, level: usize, v_in: f64) -> Vec<f64> {
    (0..cell.m_codes())
        .map(|w| cell.transfer_closed_form(w, level, v_in))
        .collect()
}

/// E-sweep of a cell at fixed mantissa code: measured charge per level,
/// with relative error against the ideal octave response normalized to the
/// W-input LSB (Fig. 8b convention).
pub fn e_sweep_error_lsb(cell: &GrMacCell, ideal: &GrMacCell, w_code: u64, v_in: f64) -> Vec<f64> {
    let lsb_top = ideal.lsb(ideal.levels(), v_in);
    (1..=cell.levels())
        .map(|l| {
            let q = cell.transfer_closed_form(w_code, l, v_in);
            let qi = ideal.transfer_closed_form(w_code, l, v_in);
            (q - qi) / lsb_top
        })
        .collect()
}

/// Monte-Carlo DNL/INL study: returns per-run (max|DNL|, max|INL|) across
/// all gain levels, n runs.
pub fn mc_dnl_inl(
    cell: &GrMacCell,
    model: MismatchModel,
    runs: usize,
    seed: u64,
) -> Vec<(f64, f64)> {
    let mut rng = Pcg64::seeded(seed);
    (0..runs)
        .map(|_| {
            let inst = model.instance(cell, &mut rng);
            let mut worst_dnl = 0.0f64;
            let mut worst_inl = 0.0f64;
            for level in 1..=inst.levels() {
                let s = dnl_inl(&w_sweep(&inst, level, 1.0));
                worst_dnl = worst_dnl.max(s.max_abs_dnl());
                worst_inl = worst_inl.max(s.max_abs_inl());
            }
            (worst_dnl, worst_inl)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn sigma_follows_pelgrom() {
        let m = MismatchModel::low();
        // quadrupling C halves sigma
        assert!(approx_eq(m.sigma(1.0), 2.0 * m.sigma(4.0), 1e-12));
        assert!(approx_eq(m.sigma(1.0), 0.0045, 1e-12));
        assert!(approx_eq(MismatchModel::high().sigma(1.0), 0.0085, 1e-12));
    }

    #[test]
    fn perturbation_statistics() {
        let m = MismatchModel::high();
        let mut rng = Pcg64::seeded(41);
        let c = 2.0;
        let n = 50_000;
        let vals: Vec<f64> = (0..n).map(|_| m.perturb(c, &mut rng)).collect();
        let mean = vals.iter().sum::<f64>() / n as f64;
        let sd = (vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / n as f64)
            .sqrt();
        assert!(approx_eq(mean, c, 1e-3));
        assert!(approx_eq(sd / c, m.sigma(c), 0.02));
    }

    #[test]
    fn batched_instance_matches_sequential_perturb_stream() {
        use crate::analog::GrMacCell;
        let m = MismatchModel::high();
        let cell = GrMacCell::fp6_e2m3_schematic();
        let mut a = Pcg64::seeded(0x1217);
        let inst = m.instance(&cell, &mut a);
        // sequential reference: one perturb per capacitor, in order
        let mut b = Pcg64::seeded(0x1217);
        let mut reference = cell.clone();
        for c in reference.c_m.iter_mut().chain(reference.c_e.iter_mut()) {
            *c = m.perturb(*c, &mut b);
        }
        for (got, want) in inst
            .c_m
            .iter()
            .chain(inst.c_e.iter())
            .zip(reference.c_m.iter().chain(reference.c_e.iter()))
        {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        // and both generators continue identically
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn ideal_staircase_has_zero_dnl_inl() {
        let vals: Vec<f64> = (0..16).map(|i| i as f64 * 0.5).collect();
        let s = dnl_inl(&vals);
        assert!(s.max_abs_dnl() < 1e-12);
        assert!(s.max_abs_inl() < 1e-12);
    }

    #[test]
    fn known_dnl_detected() {
        // one double-height step at code 2
        let vals = vec![0.0, 1.0, 3.0, 4.0];
        let s = dnl_inl(&vals);
        // endpoint lsb = 4/3
        assert!(approx_eq(s.dnl[1], 2.0 / (4.0 / 3.0) - 1.0, 1e-12));
        assert!(s.max_abs_inl() > 0.2);
    }

    #[test]
    fn nominal_cell_is_linear() {
        let cell = GrMacCell::fp6_e2m3_schematic();
        for level in 1..=4 {
            let s = dnl_inl(&w_sweep(&cell, level, 1.0));
            assert!(s.max_abs_dnl() < 1e-9, "level {level}");
            assert!(s.max_abs_inl() < 1e-9, "level {level}");
        }
    }

    #[test]
    fn paper_fig8_mismatch_within_half_lsb() {
        // "post-layout simulation under 3sigma mismatch remains within the
        // 1/2 LSB bound": the 99.7th percentile of max|DNL|, max|INL| at
        // both K_C bounds stays below 0.5 LSB.
        let cell = GrMacCell::fp6_e2m3_schematic();
        for model in [MismatchModel::low(), MismatchModel::high()] {
            let mut runs = mc_dnl_inl(&cell, model, 1000, 7);
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let p997_dnl = runs[996].0;
            runs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let p997_inl = runs[996].1;
            assert!(
                p997_dnl < 0.5 && p997_inl < 0.5,
                "K_C={} p99.7 DNL={p997_dnl} INL={p997_inl}",
                model.k_c_pct_sqrt_ff
            );
        }
    }

    #[test]
    fn higher_kc_gives_worse_linearity() {
        let cell = GrMacCell::fp6_e2m3_schematic();
        let lo = mc_dnl_inl(&cell, MismatchModel::low(), 300, 11);
        let hi = mc_dnl_inl(&cell, MismatchModel::high(), 300, 11);
        let mean = |v: &[(f64, f64)]| {
            v.iter().map(|x| x.0).sum::<f64>() / v.len() as f64
        };
        assert!(mean(&hi) > mean(&lo));
    }

    #[test]
    fn e_sweep_error_zero_for_ideal() {
        let cell = GrMacCell::fp6_e2m3_schematic();
        let err = e_sweep_error_lsb(&cell, &cell, 15, 1.0);
        assert!(err.iter().all(|e| e.abs() < 1e-12));
    }

    #[test]
    fn low_levels_most_sensitive_in_lsb_terms() {
        // paper: "highest mismatch sensitivity occurs at low E values due
        // to the small output LSB step size" — relative to the level's own
        // LSB. Verify DNL (normalized per-level) grows as level drops.
        let cell = GrMacCell::fp6_e2m3_schematic();
        let model = MismatchModel::high();
        let mut rng = Pcg64::seeded(13);
        let mut acc = vec![0.0f64; 4];
        let runs = 200;
        for _ in 0..runs {
            let inst = model.instance(&cell, &mut rng);
            for level in 1..=4 {
                // error vs ideal octave response, normalized to the
                // *top-level* W LSB as in Fig. 8(b)
                let e = e_sweep_error_lsb(&inst, &cell, 15, 1.0);
                acc[level - 1] += e[level - 1].abs();
            }
        }
        // absolute (top-LSB-normalized) error is *largest* at the top
        // level; the sensitivity claim is about each level's own LSB:
        let per_level_lsb: Vec<f64> =
            (1..=4).map(|l| cell.lsb(l, 1.0)).collect();
        let rel: Vec<f64> = acc
            .iter()
            .zip(&per_level_lsb)
            .map(|(a, l)| a / runs as f64 * cell.lsb(4, 1.0) / l)
            .collect();
        assert!(rel[0] > rel[3], "relative sensitivity {rel:?}");
    }
}
