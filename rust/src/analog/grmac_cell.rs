//! The GR-MAC unit cell netlist (paper Fig. 6/7, Table I, Sec. III-D/E).
//!
//! Structure, for the FP6_E2M3 configuration (4 mantissa magnitude bits,
//! 4 gain levels):
//!
//! ```text
//!  V_x --[W-bit switches]--> C_M0..C_M3 --+-- n1 --[C_E stage]--> column
//!        (unselected bits drive ground)   |
//!                                        C_p1
//! ```
//!
//! The coupling stage applies the paper's two layout transformations
//! (Sec. III-E): C_E1 is hard-wired (minimum coupling switch removed, its
//! value subtracted from the higher levels), and the largest exponent
//! activates **both** C_E3 and C_E4. The effective coupling capacitance of
//! level j is therefore
//!
//! ```text
//! T_1 = C_E1,   T_2 = C_E1 + C_E2,   T_3 = C_E1 + C_E3,
//! T_4 = C_E1 + C_E3 + C_E4
//! ```
//!
//! and the level design targets series couplings in exact octaves:
//! `T_j || (C_sum + C_p1) = (C_sum + C_p1) / (2^(L-j+1) - 1)` — eq. (1) of
//! the paper generalized to include the always-on C_E1. With C_p1 = 0 and
//! C_u = 1 fF this reproduces Table I's schematic column exactly:
//! C_E = {1, 1.14, 4, 10} fF.

use super::capnet::CapNetwork;
use anyhow::Result;

/// Designed capacitor values of one GR-MAC cell (fF).
#[derive(Debug, Clone, PartialEq)]
pub struct GrMacCell {
    /// Binary-weighted mantissa divider caps, LSB first: C_u * 2^i.
    pub c_m: Vec<f64>,
    /// Coupling-stage component caps C_E1..C_EL (after transformations).
    pub c_e: Vec<f64>,
    /// Parasitic at the divider output node n1 (fF).
    pub c_p1: f64,
    /// Parasitic at the coupling output net (fF) — absorbed into the
    /// column line, does not affect linearity (Sec. III-D1).
    pub c_p2: f64,
}

impl GrMacCell {
    /// Design a cell: `m_bits` mantissa magnitude bits (4 for FP6_E2M3),
    /// `levels` gain-ranging levels, unit capacitor `c_u` fF, compensated
    /// for a parasitic `c_p1` per eq. (1). `c_p1 = 0` gives the schematic
    /// (ideal) design.
    pub fn design(m_bits: usize, levels: usize, c_u: f64, c_p1: f64) -> Self {
        // levels >= 3: the top-level transformation ("E_L activates both
        // C_E(L-1) and C_EL") presupposes a switched C_E(L-1) distinct
        // from the hard-wired C_E1.
        assert!(m_bits >= 1 && levels >= 3);
        let c_m: Vec<f64> = (0..m_bits).map(|i| c_u * (1u64 << i) as f64).collect();
        let c_sum: f64 = c_m.iter().sum();
        // eq. (1): total coupling of level j (1-based), including C_p1 in
        // the numerator so the compensated ratios stay exact octaves.
        let t = |j: usize| -> f64 {
            (c_sum + c_p1) / ((1u64 << (levels - j + 1)) as f64 - 1.0)
        };
        let mut c_e = Vec::with_capacity(levels);
        c_e.push(t(1)); // C_E1: always-on base coupling
        for j in 2..levels {
            c_e.push(t(j) - t(1)); // C_Ej adds on top of C_E1
        }
        // top level: C_EL adds on top of C_E1 + C_E(L-1)
        c_e.push(t(levels) - t(levels - 1));
        GrMacCell { c_m, c_e, c_p1, c_p2: 0.0 }
    }

    /// The FP6_E2M3 reference design of Fig. 7 / Table I (C_u = 1 fF).
    pub fn fp6_e2m3_schematic() -> Self {
        Self::design(4, 4, 1.0, 0.0)
    }

    /// Number of gain-ranging levels L.
    pub fn levels(&self) -> usize {
        self.c_e.len()
    }

    /// Number of mantissa magnitude codes (2^m_bits).
    pub fn m_codes(&self) -> u64 {
        1u64 << self.c_m.len()
    }

    /// Total divider capacitance.
    pub fn c_sum(&self) -> f64 {
        self.c_m.iter().sum()
    }

    /// Effective coupling capacitance T_j of level `level` (1-based),
    /// applying the switch transformations.
    pub fn coupling_total(&self, level: usize) -> f64 {
        assert!((1..=self.levels()).contains(&level));
        let l = self.levels();
        let mut t = self.c_e[0];
        if level >= 2 && level < l {
            t += self.c_e[level - 1];
        } else if level == l {
            t += self.c_e[l - 2] + self.c_e[l - 1];
        }
        t
    }

    /// Build the evaluation-phase network for weight code `w_code`
    /// (mantissa magnitude, 0..2^m_bits) at gain level `level`, input
    /// voltage `v_in`, and solve for the charge delivered to the column
    /// line (held at virtual ground by the accumulation convention).
    ///
    /// Returns (Q_out, V_n1).
    pub fn transfer(&self, w_code: u64, level: usize, v_in: f64) -> Result<(f64, f64)> {
        assert!(w_code < self.m_codes());
        let mut net = CapNetwork::new();
        let src = net.driven(v_in);
        let gnd = net.driven(0.0);
        let col = net.driven(0.0); // column line at virtual ground
        let n1 = net.node();
        let n2 = net.node(); // coupling output net (carries C_p2)
        for (i, &c) in self.c_m.iter().enumerate() {
            let plate = if (w_code >> i) & 1 == 1 { src } else { gnd };
            net.cap(plate, n1, c);
        }
        if self.c_p1 > 0.0 {
            net.cap(n1, gnd, self.c_p1);
        }
        // coupling stage: selected component caps bridge n1 -> n2; n2 ties
        // to the column line (ideal switch).
        let t = self.coupling_total(level);
        net.cap(n1, n2, t);
        if self.c_p2 > 0.0 {
            net.cap(n2, gnd, self.c_p2);
        }
        // ideal closed switch n2 -> column: model as a huge capacitor
        // (charge transfer limit); 1e9 x the network scale keeps the
        // solver well-conditioned while approximating a short.
        net.cap(n2, col, 1e9);
        let sol = net.solve()?;
        // charge delivered into the column node (negative of what the
        // driven node sources, by our sign convention)
        Ok((-sol.charge[col], sol.voltages[n1]))
    }

    /// Closed-form expected charge for the ideal (C_p2-free) cell:
    /// Q = V * C_sel * (T_j || (C_sum + C_p1)) / (C_sum + C_p1).
    pub fn transfer_closed_form(&self, w_code: u64, level: usize, v_in: f64) -> f64 {
        let c_sel: f64 = self
            .c_m
            .iter()
            .enumerate()
            .filter(|(i, _)| (w_code >> i) & 1 == 1)
            .map(|(_, &c)| c)
            .sum();
        let cs = self.c_sum() + self.c_p1;
        let t = self.coupling_total(level);
        v_in * c_sel * t / (cs + t)
    }

    /// Ideal LSB charge step of the W sweep at a given level (the DNL/INL
    /// normalization of Fig. 8).
    pub fn lsb(&self, level: usize, v_in: f64) -> f64 {
        let q1 = self.transfer_closed_form(1, level, v_in);
        let q0 = self.transfer_closed_form(0, level, v_in);
        q1 - q0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn table_i_schematic_values() {
        // Paper Table I, schematic column: C_M = {1,2,4,8},
        // C_E = {1, 1.14, 4, 10} fF.
        let cell = GrMacCell::fp6_e2m3_schematic();
        assert_eq!(cell.c_m, vec![1.0, 2.0, 4.0, 8.0]);
        assert!(approx_eq(cell.c_e[0], 1.0, 1e-12), "C_E1={}", cell.c_e[0]);
        assert!(approx_eq(cell.c_e[1], 8.0 / 7.0, 1e-12), "C_E2={}", cell.c_e[1]);
        assert!(approx_eq(cell.c_e[2], 4.0, 1e-12), "C_E3={}", cell.c_e[2]);
        assert!(approx_eq(cell.c_e[3], 10.0, 1e-12), "C_E4={}", cell.c_e[3]);
    }

    #[test]
    fn coupling_totals_follow_eq1() {
        // T_j = C_sum / (2^(L-j+1) - 1): {1, 15/7, 5, 15}
        let cell = GrMacCell::fp6_e2m3_schematic();
        assert!(approx_eq(cell.coupling_total(1), 1.0, 1e-12));
        assert!(approx_eq(cell.coupling_total(2), 15.0 / 7.0, 1e-12));
        assert!(approx_eq(cell.coupling_total(3), 5.0, 1e-12));
        assert!(approx_eq(cell.coupling_total(4), 15.0, 1e-12));
    }

    #[test]
    fn gain_levels_are_exact_octaves() {
        let cell = GrMacCell::fp6_e2m3_schematic();
        let w = 15; // full mantissa
        let q: Vec<f64> = (1..=4)
            .map(|l| cell.transfer(w, l, 1.0).unwrap().0)
            .collect();
        for j in 1..4 {
            assert!(
                approx_eq(q[j] / q[j - 1], 2.0, 1e-6),
                "level {} ratio {}",
                j,
                q[j] / q[j - 1]
            );
        }
    }

    #[test]
    fn w_sweep_is_linear() {
        let cell = GrMacCell::fp6_e2m3_schematic();
        for level in 1..=4 {
            let q0 = cell.transfer(0, level, 1.0).unwrap().0;
            let lsb = cell.transfer(1, level, 1.0).unwrap().0 - q0;
            for w in 0..16u64 {
                let q = cell.transfer(w, level, 1.0).unwrap().0;
                assert!(
                    approx_eq(q - q0, w as f64 * lsb, 1e-6),
                    "level {level} w {w}"
                );
            }
        }
    }

    #[test]
    fn solver_matches_closed_form() {
        let cell = GrMacCell::design(4, 4, 1.0, 0.8);
        for level in 1..=4 {
            for w in [0u64, 1, 7, 8, 15] {
                let (q, _) = cell.transfer(w, level, 0.9).unwrap();
                let qc = cell.transfer_closed_form(w, level, 0.9);
                assert!(
                    approx_eq(q, qc, 1e-6) || (q.abs() < 1e-15 && qc.abs() < 1e-15),
                    "w={w} level={level}: {q} vs {qc}"
                );
            }
        }
    }

    #[test]
    fn parasitic_compensation_restores_octaves() {
        // uncompensated parasitic perturbs the ratios...
        let c_p1 = 1.5;
        let mut naive = GrMacCell::fp6_e2m3_schematic();
        naive.c_p1 = c_p1;
        let q2 = naive.transfer(15, 2, 1.0).unwrap().0;
        let q1 = naive.transfer(15, 1, 1.0).unwrap().0;
        let naive_ratio = q2 / q1;
        assert!((naive_ratio - 2.0).abs() > 0.005, "ratio {naive_ratio}");
        // ...eq. (1) with C_p1 in the numerator restores them exactly
        let comp = GrMacCell::design(4, 4, 1.0, c_p1);
        let q2 = comp.transfer(15, 2, 1.0).unwrap().0;
        let q1 = comp.transfer(15, 1, 1.0).unwrap().0;
        assert!(approx_eq(q2 / q1, 2.0, 1e-6), "ratio {}", q2 / q1);
    }

    #[test]
    fn c_p2_does_not_affect_linearity() {
        // C_p2 hangs on the virtually-grounded column net: pure offset-free
        // attenuation of nothing (node is at 0 V), Sec. III-D1.
        let mut cell = GrMacCell::fp6_e2m3_schematic();
        let q_ref = cell.transfer(9, 3, 1.0).unwrap().0;
        cell.c_p2 = 2.0;
        let q = cell.transfer(9, 3, 1.0).unwrap().0;
        assert!(approx_eq(q, q_ref, 1e-6));
    }

    #[test]
    fn transfer_scales_with_input_voltage() {
        let cell = GrMacCell::fp6_e2m3_schematic();
        let q1 = cell.transfer(11, 2, 0.5).unwrap().0;
        let q2 = cell.transfer(11, 2, 1.0).unwrap().0;
        assert!(approx_eq(q2, 2.0 * q1, 1e-9));
    }

    #[test]
    fn zero_weight_transfers_zero() {
        let cell = GrMacCell::fp6_e2m3_schematic();
        for level in 1..=4 {
            let (q, _) = cell.transfer(0, level, 1.0).unwrap();
            assert!(q.abs() < 1e-12);
        }
    }
}
