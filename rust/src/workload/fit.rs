//! `EmpiricalDist` — the streaming fitter that turns a [`TensorTrace`]
//! into a sampleable workload distribution.
//!
//! Fitting normalizes the payload to [-1, 1] by its largest magnitude (the
//! same per-tensor calibration the CIM inference path applies,
//! `nn::cim_forward_batch`), then summarizes it in one pass over the
//! sorted data:
//!
//! * an **inverse-CDF table** of [`QUANTILE_KNOTS`] equally spaced
//!   quantile knots (linear interpolation of order statistics) — sampling
//!   draws one uniform variate and interpolates the table, so a fitted
//!   trace plugs into every Monte-Carlo path exactly like the parametric
//!   distributions;
//! * a fixed 64-bin **histogram** over [-1, 1];
//! * **dynamic range** in bits: `-log2(min nonzero |x| / max |x|)` — the
//!   empirical analogue of a format's `dr_bits`;
//! * a **robust core sigma** `(Q(0.84) - Q(0.16)) / 2` (the central-68%
//!   half-width; ±1σ for a Gaussian core, insensitive to outliers) and the
//!   **outlier mass** beyond `4·sigma_core` — mirroring the
//!   `gauss_outliers` convention of
//!   [`crate::distributions::Distribution::is_outlier`].
//!
//! The arithmetic (normalization, sort, knot interpolation, moment
//! accumulation) is implemented identically in the Python twin
//! (`tools/gen_goldens.py`), so the golden snapshot
//! (`rust/tests/golden/workload_empirical.json`) cross-checks this module
//! against a second implementation.
//!
//! # Example
//!
//! ```
//! use grcim::rng::Pcg64;
//! use grcim::workload::{EmpiricalDist, TensorTrace};
//!
//! let trace =
//!     TensorTrace::from_f64("t", vec![5], vec![-2.0, -1.0, 0.0, 1.0, 2.0]).unwrap();
//! let fit = EmpiricalDist::fit(&trace).unwrap();
//! assert_eq!(fit.scale(), 2.0); // normalized by max |x|
//! assert_eq!(fit.quantile(0.0), -1.0);
//! assert_eq!(fit.quantile(1.0), 1.0);
//! let mut rng = Pcg64::seeded(7);
//! let v = fit.sample(&mut rng);
//! assert!((-1.0..=1.0).contains(&v));
//! ```

use super::trace::TensorTrace;
use crate::rng::Pcg64;
use crate::stats::{Histogram, Moments};
use anyhow::{bail, Result};

/// Knots in the inverse-CDF sampling table (power of two + 1, so knot
/// positions land on exact binary fractions of the sample range).
pub const QUANTILE_KNOTS: usize = 513;

/// Histogram bins of the fitted density summary.
pub const HIST_BINS: usize = 64;

/// Linear interpolation of sorted order statistics at fractional position
/// `pos` (in [0, n-1]). The exact twin of `interp_sorted` in
/// `tools/gen_goldens.py`.
fn interp_sorted(sorted: &[f64], pos: f64) -> f64 {
    let i = pos.floor() as usize;
    if i + 1 >= sorted.len() {
        return sorted[sorted.len() - 1];
    }
    let frac = pos - i as f64;
    sorted[i] + (sorted[i + 1] - sorted[i]) * frac
}

/// A fitted empirical distribution over [-1, 1] (see the module docs).
#[derive(Clone)]
pub struct EmpiricalDist {
    name: String,
    content_hash: u64,
    samples: usize,
    scale: f64,
    knots: Vec<f64>,
    mean: f64,
    std: f64,
    min_nonzero: f64,
    sigma_core: f64,
    outlier_thresh: f64,
    outlier_mass: f64,
    hist: Histogram,
}

impl std::fmt::Debug for EmpiricalDist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EmpiricalDist")
            .field("name", &self.name)
            .field("content_hash", &format_args!("{:016x}", self.content_hash))
            .field("samples", &self.samples)
            .field("scale", &self.scale)
            .field("dr_bits", &self.dr_bits())
            .field("sigma_core", &self.sigma_core)
            .field("outlier_mass", &self.outlier_mass)
            .finish_non_exhaustive()
    }
}

impl EmpiricalDist {
    /// Fit a trace. Fails on traces with fewer than two elements or with
    /// no nonzero value (an all-zero tensor cannot drive a campaign).
    pub fn fit(trace: &TensorTrace) -> Result<EmpiricalDist> {
        let raw = trace.values();
        if raw.len() < 2 {
            bail!(
                "trace '{}': need at least 2 values to fit, got {}",
                trace.name(),
                raw.len()
            );
        }
        let scale = raw.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if scale == 0.0 {
            bail!("trace '{}': all values are zero", trace.name());
        }

        // normalize in capture order (moments/histogram accumulate here)
        let mut norm = Vec::with_capacity(raw.len());
        let mut moments = Moments::default();
        let mut hist = Histogram::new(-1.0, 1.0, HIST_BINS);
        let mut min_nonzero = f64::INFINITY;
        for &v in raw {
            let x = v / scale;
            moments.push(x);
            hist.push(x);
            if x != 0.0 {
                min_nonzero = min_nonzero.min(x.abs());
            }
            norm.push(x);
        }

        // sorted view: quantile knots + robust spread + outlier mass
        let mut sorted = norm;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mut knots = Vec::with_capacity(QUANTILE_KNOTS);
        for j in 0..QUANTILE_KNOTS {
            let pos =
                ((j * (n - 1)) as f64) / ((QUANTILE_KNOTS - 1) as f64);
            knots.push(interp_sorted(&sorted, pos));
        }
        let q = |p: f64| interp_sorted(&sorted, p * (n - 1) as f64);
        let sigma_core = (q(0.84) - q(0.16)) / 2.0;
        // Sparse (e.g. post-ReLU) traces can have >= 68% exact zeros, which
        // collapses the quantile spread to 0 — a zero threshold would brand
        // every nonzero sample an "outlier" and empty the core subset.
        // Fall back to the full std; a constant-magnitude trace (std = 0)
        // gets threshold 1.0, i.e. no outliers on the normalized scale.
        let spread = if sigma_core > 0.0 {
            sigma_core
        } else {
            moments.variance().sqrt()
        };
        let outlier_thresh = if spread > 0.0 { 4.0 * spread } else { 1.0 };
        let outlier_mass = sorted
            .iter()
            .filter(|x| x.abs() > outlier_thresh)
            .count() as f64
            / n as f64;

        Ok(EmpiricalDist {
            name: trace.name().to_string(),
            content_hash: trace.content_hash(),
            samples: n,
            scale,
            knots,
            mean: moments.mean(),
            std: moments.variance().sqrt(),
            min_nonzero,
            sigma_core,
            outlier_thresh,
            outlier_mass,
            hist,
        })
    }

    /// Draw one sample in [-1, 1] by inverse-CDF lookup: one uniform
    /// variate, one table interpolation. Consumes exactly one RNG draw per
    /// sample (the property the golden twin relies on).
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        let u = rng.uniform();
        let pos = u * (QUANTILE_KNOTS - 1) as f64;
        interp_sorted(&self.knots, pos)
    }

    /// Whether a (normalized) magnitude sits beyond the fitted outlier
    /// threshold `4·sigma_core`.
    pub fn is_outlier(&self, x: f64) -> bool {
        x.abs() > self.outlier_thresh
    }

    /// Quantile of the fitted (normalized) distribution at `p` in [0, 1],
    /// interpolated from the knot table.
    pub fn quantile(&self, p: f64) -> f64 {
        interp_sorted(&self.knots, p.clamp(0.0, 1.0) * (QUANTILE_KNOTS - 1) as f64)
    }

    /// Trace label the fit came from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Content hash of the source trace ([`TensorTrace::content_hash`]).
    pub fn content_hash(&self) -> u64 {
        self.content_hash
    }

    /// Number of trace elements the fit summarizes.
    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Normalization factor: the largest magnitude of the raw payload.
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Mean of the normalized values.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population standard deviation of the normalized values.
    pub fn std(&self) -> f64 {
        self.std
    }

    /// Empirical dynamic range in bits: `-log2(min nonzero |x|)` over the
    /// normalized values (full scale over the smallest resolved magnitude
    /// — the analogue of `FpFormat::dr_bits` for measured data).
    pub fn dr_bits(&self) -> f64 {
        -self.min_nonzero.log2()
    }

    /// Robust core spread: half the central-68% width, `(Q(0.84) -
    /// Q(0.16)) / 2` (±1σ for a Gaussian core, insensitive to outliers).
    /// Can be 0 for sparse traces (≥ 68% exact zeros); the outlier
    /// threshold then falls back to `4·std` (see [`EmpiricalDist::is_outlier`]).
    pub fn sigma_core(&self) -> f64 {
        self.sigma_core
    }

    /// The fitted outlier threshold on the normalized scale: `4·sigma_core`,
    /// falling back to `4·std` for sparse traces and to full scale (1.0,
    /// i.e. no outliers) for constant-magnitude ones.
    pub fn outlier_thresh(&self) -> f64 {
        self.outlier_thresh
    }

    /// Fraction of values with `|x| > 4·sigma_core` — the LLM.int8()-style
    /// emergent-outlier mass the paper's Gaussian+outliers model stands in
    /// for.
    pub fn outlier_mass(&self) -> f64 {
        self.outlier_mass
    }

    /// The fitted 64-bin density histogram over [-1, 1].
    pub fn histogram(&self) -> &Histogram {
        &self.hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::Distribution;
    use crate::propcheck::{check_simple, ensure};
    use crate::util::approx_eq;

    fn trace_from(dist: &Distribution, n: usize, seed: u64) -> TensorTrace {
        let mut rng = Pcg64::seeded(seed);
        let mut buf = vec![0.0f32; n];
        dist.fill_f32(&mut rng, &mut buf);
        TensorTrace::from_f32("test", vec![n], buf).unwrap()
    }

    #[test]
    fn uniform_trace_fits_uniform_quantiles() {
        let t = trace_from(&Distribution::Uniform, 40_000, 1);
        let fit = EmpiricalDist::fit(&t).unwrap();
        // inverse CDF of U[-1,1] is 2p - 1
        for p in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let expect = 2.0 * p - 1.0;
            assert!(
                (fit.quantile(p) - expect).abs() < 0.02,
                "Q({p}) = {} vs {expect}",
                fit.quantile(p)
            );
        }
        assert!(fit.mean().abs() < 0.02);
        assert!(approx_eq(fit.std(), (1.0f64 / 3.0).sqrt(), 0.03));
        // central-68% half width of U[-1,1] is 0.68
        assert!(approx_eq(fit.sigma_core(), 0.68, 0.05));
        assert_eq!(fit.outlier_mass(), 0.0); // 4 sigma > full scale
    }

    #[test]
    fn sampling_reproduces_the_fitted_distribution() {
        let t = trace_from(&Distribution::clipped_gauss4(), 30_000, 2);
        let fit = EmpiricalDist::fit(&t).unwrap();
        let mut rng = Pcg64::seeded(3);
        let mut m = Moments::default();
        for _ in 0..50_000 {
            let v = fit.sample(&mut rng);
            assert!((-1.0..=1.0).contains(&v));
            m.push(v);
        }
        // scale: clipped gauss has sigma 0.25; max|x| of 30k draws ~ 0.95+,
        // so the normalized std sits near 0.25 / scale
        assert!(m.mean().abs() < 0.01, "mean {}", m.mean());
        assert!(
            approx_eq(m.variance().sqrt(), fit.std(), 0.05),
            "sampled std {} vs fitted {}",
            m.variance().sqrt(),
            fit.std()
        );
    }

    #[test]
    fn round_trip_property_known_synthetic_distributions() {
        // sample a known distribution -> trace -> fit -> the fit's
        // quantiles, outlier mass, and ENOB solution match the source
        // within Monte-Carlo tolerance
        check_simple(
            "empirical-round-trip",
            7,
            3,
            |rng| rng.below(1 << 30) + 1,
            |&seed| {
                let src = Distribution::gauss_outliers();
                let t = trace_from(&src, 50_000, seed);
                let fit = EmpiricalDist::fit(&t).unwrap();
                // outlier mass ~ eps = 0.01 (the injected outliers dominate
                // the >4 sigma-core tail)
                ensure(
                    (0.006..0.016).contains(&fit.outlier_mass()),
                    || format!("outlier mass {}", fit.outlier_mass()),
                )?;
                // core sigma ~ (1/150) / scale; scale ~ 1 (outliers reach
                // full scale)
                let expect = 1.0 / 150.0 / fit.scale();
                ensure(
                    approx_eq(fit.sigma_core(), expect, 0.15),
                    || format!("sigma_core {} vs {expect}", fit.sigma_core()),
                )?;
                // median of the heavy core is ~0
                ensure(fit.quantile(0.5).abs() < 0.01, || {
                    format!("median {}", fit.quantile(0.5))
                })
            },
        );
    }

    #[test]
    fn round_trip_enob_matches_source_distribution() {
        use crate::coordinator::{run_experiment, ExperimentSpec};
        use crate::formats::FpFormat;
        use crate::mac::FormatPair;
        use crate::runtime::RustEngine;
        use crate::spec::{delta_enob, SpecConfig};

        let src = Distribution::gauss_outliers();
        let t = trace_from(&src, 50_000, 11);
        let fit = EmpiricalDist::fit(&t).unwrap();
        let spec_with = |dist_x: Distribution| ExperimentSpec {
            id: "rt".into(),
            fmts: FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1()),
            dist_x,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: 4096,
            sampler: Default::default(),
        };
        let e = RustEngine;
        let agg_src = run_experiment(&e, &spec_with(src), 5).unwrap();
        let agg_emp = run_experiment(
            &e,
            &spec_with(Distribution::empirical(fit)),
            5,
        )
        .unwrap();
        let cfg = SpecConfig::default();
        let d_src = delta_enob(&agg_src, cfg);
        let d_emp = delta_enob(&agg_emp, cfg);
        assert!(
            (d_src - d_emp).abs() < 0.75,
            "delta ENOB source {d_src} vs empirical {d_emp}"
        );
        // the headline survives the round trip
        assert!(d_emp > 6.0, "delta ENOB {d_emp}");
    }

    #[test]
    fn sampling_is_deterministic_and_uses_one_draw() {
        let t = trace_from(&Distribution::Uniform, 1000, 4);
        let fit = EmpiricalDist::fit(&t).unwrap();
        let mut a = Pcg64::seeded(9);
        let mut b = Pcg64::seeded(9);
        for _ in 0..100 {
            assert_eq!(fit.sample(&mut a).to_bits(), fit.sample(&mut b).to_bits());
        }
        // exactly one u64 consumed per sample
        let mut c = Pcg64::seeded(10);
        let mut d = Pcg64::seeded(10);
        fit.sample(&mut c);
        d.next_u64();
        assert_eq!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn rejects_degenerate_traces() {
        let z = TensorTrace::from_f64("z", vec![3], vec![0.0, 0.0, 0.0]).unwrap();
        let err = EmpiricalDist::fit(&z).unwrap_err().to_string();
        assert!(err.contains("all values are zero"), "{err}");

        let one = TensorTrace::from_f64("one", vec![1], vec![1.0]).unwrap();
        assert!(EmpiricalDist::fit(&one).unwrap_err().to_string().contains("at least 2"));
    }

    #[test]
    fn dr_bits_and_outlier_threshold() {
        // values spanning 8 binades: min nonzero = 2^-8 of full scale
        let vals = vec![1.0, 0.5, 0.25, 2f64.powi(-8), -1.0, 0.0];
        let t = TensorTrace::from_f64("dr", vec![6], vals).unwrap();
        let fit = EmpiricalDist::fit(&t).unwrap();
        assert!(approx_eq(fit.dr_bits(), 8.0, 1e-12), "{}", fit.dr_bits());
        // is_outlier matches the stored threshold
        let th = 4.0 * fit.sigma_core();
        assert!(fit.is_outlier(th + 1e-9));
        assert!(!fit.is_outlier(th - 1e-9));
    }

    #[test]
    fn sparse_relu_trace_does_not_degenerate() {
        // >= 68% exact zeros: the quantile spread collapses to 0, so the
        // outlier threshold must fall back to 4*std rather than branding
        // every nonzero activation an outlier
        let mut vals = vec![0.0f64; 900];
        let mut rng = Pcg64::seeded(12);
        for _ in 0..100 {
            vals.push(rng.uniform_in(0.1, 1.0)); // post-ReLU activations
        }
        let n = vals.len();
        let t = TensorTrace::from_f64("relu", vec![n], vals).unwrap();
        let fit = EmpiricalDist::fit(&t).unwrap();
        assert_eq!(fit.sigma_core(), 0.0);
        assert!(fit.outlier_thresh() > 0.0);
        // the bulk of the nonzero activations stay in the core
        assert!(
            fit.outlier_mass() < 0.05,
            "outlier mass {}",
            fit.outlier_mass()
        );
        // a constant-magnitude trace has no outliers at all
        let c = TensorTrace::from_f64("const", vec![4], vec![0.7; 4]).unwrap();
        let cf = EmpiricalDist::fit(&c).unwrap();
        assert_eq!(cf.outlier_thresh(), 1.0);
        assert_eq!(cf.outlier_mass(), 0.0);
    }

    #[test]
    fn histogram_covers_all_samples() {
        let t = trace_from(&Distribution::Uniform, 5000, 6);
        let fit = EmpiricalDist::fit(&t).unwrap();
        assert_eq!(fit.histogram().total, 5000);
        assert_eq!(fit.samples(), 5000);
    }
}
