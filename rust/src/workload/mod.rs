//! Empirical workload traces — drive the whole pipeline from measured
//! tensor statistics instead of parametric stand-ins.
//!
//! The paper's central claim (GR-MAC makes the ADC requirement invariant
//! to the input distribution) is motivated by *real* LLM activation
//! statistics with emergent outlier features (Sec. IV-A cites
//! LLM.int8()-style observations), but the synthetic `gauss_outliers`
//! model only approximates them. This subsystem closes the gap, the way
//! AFPR-CIM and IMAGINE validate dynamic-range adaptation on measured
//! tensors:
//!
//! * [`trace`] — [`TensorTrace`], a self-describing binary/JSON capture
//!   format (`tools/export_trace.py` emits it from synthetic-LLM models or
//!   real checkpoints), content-hashed for cache identity;
//! * [`fit`] — [`EmpiricalDist`], a fitter producing quantile /
//!   dynamic-range / outlier-mass summaries plus an inverse-CDF sampler
//!   that plugs into [`Distribution::Empirical`] — every campaign,
//!   figure, and serve request can run on a trace;
//! * [`report`] — the `grcim workload` analysis: the trace summary, a
//!   Fig. 9-style element-level SQNR sweep over exponent bits, and a
//!   conventional-vs-GR ADC/energy-bound comparison, packaged as a
//!   [`FigureResult`] so the CLI prints it and `grcim serve` returns and
//!   caches it (keyed by the trace's content hash).
//!
//! # Example
//!
//! ```
//! use grcim::distributions::Distribution;
//! use grcim::rng::Pcg64;
//! use grcim::workload::{EmpiricalDist, TensorTrace};
//!
//! // capture a synthetic activation tensor as a trace
//! let mut rng = Pcg64::seeded(3);
//! let mut acts = vec![0.0f32; 4096];
//! Distribution::gauss_outliers().fill_f32(&mut rng, &mut acts);
//! let trace = TensorTrace::from_f32("acts", vec![64, 64], acts).unwrap();
//!
//! // fit it and drive the standard sampling API from the measurement
//! let dist = Distribution::empirical(EmpiricalDist::fit(&trace).unwrap());
//! let mut out = vec![0.0; 256];
//! dist.fill(&mut Pcg64::seeded(4), &mut out);
//! assert!(out.iter().all(|v| v.abs() <= 1.0));
//! ```

pub mod fit;
pub mod trace;

pub use fit::EmpiricalDist;
pub use trace::TensorTrace;

use crate::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
use crate::distributions::Distribution;
use crate::energy::{energy_per_op, CimArch, TechParams};
use crate::figures::{fig12, fig9};
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::report::{FigureResult, Table};
use crate::spec::{required_enob, Arch, SpecConfig};
use anyhow::Result;
use crate::util::sync::Arc;

/// Array depth of the workload energy-bound comparison (the paper's
/// standard column depth).
pub const NR: usize = 32;
/// Array width used to amortize per-column/per-array energy.
pub const NC: usize = 32;
/// Input exponent-bit sweep of the energy-bound table (N_M,x = 2, the
/// Fig. 10 convention).
pub const N_E_SWEEP: [u32; 3] = [2, 3, 4];

/// Fig. 9-style element-level SQNR sweep of a distribution over exponent
/// bits n_e = 0..=5 (n_e = 0 is the same-total-bits INT point). Returns
/// `[sqnr_all_db, sqnr_core_db]` per point; "core" excludes fitted
/// outliers, exposing whether the format resolves the distribution's bulk
/// or just its extremes.
///
/// Seeding: point `n_e` uses `seed + n_e`, with the core subset sharing
/// the full-set stream (the fig. 9 convention). Pinned by the golden
/// snapshot `workload_empirical.json`.
pub fn sqnr_sweep(
    dist: &Distribution,
    samples: usize,
    seed: u64,
) -> Vec<[f64; 2]> {
    fig9::N_E_RANGE
        .map(|n_e| {
            let fmt = fig9::fmt_for(n_e);
            let s = seed.wrapping_add(n_e as u64);
            let all = fig9::sqnr_db(fmt, dist, samples, s, false, false);
            let core = fig9::sqnr_db(fmt, dist, samples, s, true, false);
            [all, core]
        })
        .collect()
}

/// One row of the energy-bound comparison.
struct BoundRow {
    fmt: FpFormat,
    enob_conv: f64,
    enob_unit: f64,
    enob_row: f64,
    e_conv: f64,
    gr_name: &'static str,
    e_gr: f64,
}

/// The full `grcim workload` analysis of a fitted trace: summary table,
/// SQNR sweep, and the conventional-vs-GR ADC/energy-bound comparison.
///
/// Deterministic given `(fit, campaign.seed, campaign.engine, samples)` —
/// the property the serve layer's workload cache key
/// ([`crate::server::proto::workload_key`]) relies on. Campaigns run
/// through the normal coordinator pool, so results are independent of the
/// worker count.
pub fn report(
    fit: &Arc<EmpiricalDist>,
    campaign: &CampaignConfig,
    samples: usize,
) -> Result<FigureResult> {
    let dist = Distribution::Empirical(Arc::clone(fit));
    let mut fr = FigureResult::new("workload");

    // ---- trace summary ----
    let mut summary = Table::new(
        "trace summary",
        &["metric", "value"],
    );
    let mut kv = |k: &str, v: String| summary.row(vec![k.into(), v]);
    kv("trace", fit.name().to_string());
    kv("content_hash", format!("{:016x}", fit.content_hash()));
    kv("samples", fit.samples().to_string());
    kv("scale_max_abs", Table::f(fit.scale()));
    kv("dynamic_range_bits", Table::f(fit.dr_bits()));
    kv("mean", Table::f(fit.mean()));
    kv("std", Table::f(fit.std()));
    kv("sigma_core", Table::f(fit.sigma_core()));
    kv("outlier_mass", Table::f(fit.outlier_mass()));
    for p in [0.01, 0.16, 0.5, 0.84, 0.99] {
        kv(&format!("q{:02.0}", p * 100.0), Table::f(fit.quantile(p)));
    }
    fr.tables.push(summary);

    // ---- Fig. 9-style SQNR sweep ----
    let sweep_samples = samples.max(4096);
    let sweep = sqnr_sweep(&dist, sweep_samples, campaign.seed ^ 0x31F9);
    let mut sq = Table::new(
        "sqnr vs exponent bits",
        &["n_e", "sqnr_db", "sqnr_core_db"],
    );
    for (i, n_e) in fig9::N_E_RANGE.enumerate() {
        sq.row(vec![
            n_e.to_string(),
            Table::f(sweep[i][0]),
            Table::f(sweep[i][1]),
        ]);
    }
    fr.tables.push(sq);

    // ---- conventional vs GR energy bounds ----
    // One campaign over the N_E sweep (N_M,x = 2, max-entropy FP4 weights
    // — the paper's sweep convention), evaluated through the ADC spec
    // solver and the Table II/III energy model at NR x NC.
    let w_fmt = FpFormat::fp4_e2m1();
    let specs: Vec<ExperimentSpec> = N_E_SWEEP
        .iter()
        .map(|&n_e| ExperimentSpec {
            id: format!("wl-ne{n_e}"),
            fmts: FormatPair::new(FpFormat::fp(n_e, 2), w_fmt),
            dist_x: dist.clone(),
            dist_w: Distribution::max_entropy(w_fmt),
            nr: NR,
            samples,
            sampler: Default::default(),
        })
        .collect();
    let aggs = run_campaign(&specs, campaign)?;

    let tech = TechParams::default();
    let cfg = SpecConfig::default();
    let mut rows = Vec::new();
    for (spec, agg) in specs.iter().zip(&aggs) {
        let enob_conv = required_enob(agg, Arch::Conventional, cfg).enob;
        let enob_unit = required_enob(agg, Arch::GrUnit, cfg).enob;
        let enob_row = required_enob(agg, Arch::GrRow, cfg).enob;
        let e_conv = energy_per_op(
            CimArch::Conventional,
            spec.fmts,
            NR,
            NC,
            enob_conv,
            &tech,
        )
        .total();
        // best *native* GR granularity (the 6-bit gain-range limit)
        let mut gr: Option<(&'static str, f64)> = None;
        for (arch, enob) in [
            (CimArch::GrUnit, enob_unit),
            (CimArch::GrRow, enob_row),
        ] {
            if !fig12::native_ok(arch, spec.fmts.x, spec.fmts.w) {
                continue;
            }
            let e = energy_per_op(arch, spec.fmts, NR, NC, enob, &tech).total();
            if gr.map(|(_, best)| e < best).unwrap_or(true) {
                gr = Some((arch.name(), e));
            }
        }
        let (gr_name, e_gr) = gr.unwrap_or(("global-norm", f64::NAN));
        rows.push(BoundRow {
            fmt: spec.fmts.x,
            enob_conv,
            enob_unit,
            enob_row,
            e_conv,
            gr_name,
            e_gr,
        });
    }

    let mut bounds = Table::new(
        "energy bounds: conventional vs gain-ranging",
        &[
            "input_fmt", "enob_conv", "enob_gr_unit", "enob_gr_row",
            "delta_enob", "e_conv_fj", "gr_granularity", "e_gr_fj",
            "savings_pct",
        ],
    );
    for r in &rows {
        let savings = 100.0 * (1.0 - r.e_gr / r.e_conv);
        bounds.row(vec![
            r.fmt.to_string(),
            Table::f(r.enob_conv),
            Table::f(r.enob_unit),
            Table::f(r.enob_row),
            Table::f(r.enob_conv - r.enob_unit),
            Table::f(r.e_conv),
            r.gr_name.into(),
            Table::f(r.e_gr),
            Table::f(savings),
        ]);
    }
    fr.tables.push(bounds);

    // ---- checks (distribution-independent invariants only: these must
    // hold for *any* valid trace, so a user's capture never trips them) ----
    let max_unit_excess = rows
        .iter()
        .map(|r| r.enob_unit - r.enob_conv)
        .fold(f64::NEG_INFINITY, f64::max);
    fr.check(
        "GR never needs more ADC resolution than conventional",
        "E[g^2] <= 1 (Sec. IV-A)",
        format!("max(enob_gr - enob_conv) = {max_unit_excess:.3} bits"),
        max_unit_excess <= 1e-9,
    );
    let row_ordered = rows
        .iter()
        .all(|r| r.enob_unit <= r.enob_row + 1e-9);
    fr.check(
        "unit normalization dominates row normalization",
        "S/NR referral <= S_x/NR referral",
        format!("holds across N_E sweep: {row_ordered}"),
        row_ordered,
    );
    let finite = sweep.iter().all(|r| r[0].is_finite())
        && rows.iter().all(|r| r.enob_conv.is_finite());
    fr.check(
        "trace yields finite SQNR and ENOB solutions",
        "finite",
        format!("finite: {finite}"),
        finite,
    );
    Ok(fr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::runtime::EngineKind;

    fn llm_fit(n: usize, seed: u64) -> Arc<EmpiricalDist> {
        let mut rng = Pcg64::seeded(seed);
        let mut buf = vec![0.0f32; n];
        Distribution::gauss_outliers().fill_f32(&mut rng, &mut buf);
        let t = TensorTrace::from_f32("llm", vec![n], buf).unwrap();
        Arc::new(EmpiricalDist::fit(&t).unwrap())
    }

    fn test_campaign() -> CampaignConfig {
        CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 17,
            ..Default::default()
        }
    }

    #[test]
    fn report_has_all_tables_and_holds() {
        let fit = llm_fit(8192, 1);
        let fr = report(&fit, &test_campaign(), 512).unwrap();
        assert_eq!(fr.name, "workload");
        assert_eq!(fr.tables.len(), 3);
        assert!(fr.all_hold(), "{:#?}", fr.checks);
        // the energy table has one row per swept format
        assert_eq!(fr.tables[2].rows.len(), N_E_SWEEP.len());
        // LLM-like traces show a large GR relief once the core resolves
        let sweep_rows = &fr.tables[2].rows;
        let delta: f64 = sweep_rows.last().unwrap()[4].parse().unwrap();
        assert!(delta > 3.0, "delta ENOB {delta}");
    }

    #[test]
    fn report_is_deterministic() {
        let fit = llm_fit(4096, 2);
        let campaign = test_campaign();
        let a = report(&fit, &campaign, 256).unwrap();
        let b = report(&fit, &campaign, 256).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        // worker count does not enter the result
        let mut wide = campaign.clone();
        wide.workers = 5;
        let c = report(&fit, &wide, 256).unwrap();
        assert_eq!(a.to_json().to_string(), c.to_json().to_string());
    }

    #[test]
    fn sqnr_sweep_shows_dead_core_at_low_exponent_bits() {
        let fit = llm_fit(16_384, 3);
        let dist = Distribution::Empirical(fit);
        let sweep = sqnr_sweep(&dist, 16_384, 99);
        // global SQNR healthy at E2 while the core is unresolved, core
        // recovers by E4 (the paper's Fig. 9 story on a measured tensor)
        assert!(sweep[2][0] > 10.0, "global at E2: {}", sweep[2][0]);
        assert!(sweep[2][1] < 10.0, "core at E2: {}", sweep[2][1]);
        assert!(sweep[4][1] > 15.0, "core at E4: {}", sweep[4][1]);
    }
}
